"""Substrate tests: data pipeline, checkpointing, serving engine, sharding."""

import dataclasses
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataConfig, DataIterator, batch_at
from repro.checkpoint import CheckpointStore
from repro.models import init, scale_down


class TestDataPipeline:
    def test_deterministic_across_restarts(self):
        cfg = DataConfig(vocab=100, global_batch=4, seq_len=16)
        a = batch_at(cfg, step=7)
        b = batch_at(cfg, step=7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_steps_differ(self):
        cfg = DataConfig(vocab=100, global_batch=4, seq_len=16)
        a = batch_at(cfg, 0)
        b = batch_at(cfg, 1)
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_host_sharding_partitions(self):
        cfg = DataConfig(vocab=100, global_batch=8, seq_len=16)
        s0 = batch_at(cfg, 0, host_id=0, n_hosts=2)
        s1 = batch_at(cfg, 0, host_id=1, n_hosts=2)
        assert s0["tokens"].shape == (4, 16)
        assert not np.array_equal(s0["tokens"], s1["tokens"])

    def test_iterator_state_roundtrip(self):
        cfg = DataConfig(vocab=100, global_batch=2, seq_len=8)
        it = DataIterator(cfg)
        next(it); next(it)
        st = it.state()
        a = next(it)
        it2 = DataIterator(cfg)
        it2.restore(st)
        b = next(it2)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab=100, global_batch=2, seq_len=8)
        b = batch_at(cfg, 0)
        assert b["tokens"].shape == b["labels"].shape


class TestCheckpoint:
    def test_roundtrip_bf16(self, tmp_path):
        store = CheckpointStore(tmp_path)
        tree = {"a": jnp.ones((3, 4), jnp.bfloat16) * 1.5,
                "b": {"c": jnp.arange(5, dtype=jnp.int32)}}
        store.save(3, tree)
        restored, meta = store.restore(tree)
        assert meta["step"] == 3
        np.testing.assert_array_equal(np.asarray(restored["a"], np.float32),
                                      np.asarray(tree["a"], np.float32))
        assert restored["a"].dtype == np.asarray(tree["a"]).dtype

    def test_latest_wins(self, tmp_path):
        store = CheckpointStore(tmp_path)
        tree = {"x": jnp.zeros(2)}
        store.save(1, tree)
        store.save(5, {"x": jnp.ones(2)})
        restored, meta = store.restore(tree)
        assert meta["step"] == 5
        np.testing.assert_array_equal(np.asarray(restored["x"]), [1.0, 1.0])

    def test_async_save(self, tmp_path):
        store = CheckpointStore(tmp_path)
        store.save_async(2, {"x": jnp.ones(3)})
        store.wait()
        assert store.latest_step() == 2

    def test_model_params_roundtrip(self, tmp_path):
        cfg = scale_down(get_config("qwen3_1_7b"))
        params = init(cfg, jax.random.PRNGKey(0))
        store = CheckpointStore(tmp_path)
        store.save(0, params)
        restored, _ = store.restore(params)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


class TestServingEngine:
    def test_engine_completes_burst(self):
        from repro.serving import Endpoint, ServingEngine
        cfg = scale_down(get_config("qwen3_1_7b"))
        eng = ServingEngine([Endpoint("f", cfg, prompt_len=2, gen_len=3)],
                            slots=2, policy="fc")
        for _ in range(5):
            eng.submit("f")
        eng.run(max_wall_s=60)
        assert eng.summary()["n"] == 5

    @pytest.mark.slow
    def test_sept_admits_cheap_first(self):
        from repro.serving import Endpoint, ServingEngine
        cheap = scale_down(get_config("qwen3_1_7b"))
        heavy = scale_down(get_config("deepseek_7b"), layers=4, d_model=128,
                           d_ff=256)
        eng = ServingEngine(
            [Endpoint("cheap", cheap, prompt_len=2, gen_len=2),
             Endpoint("heavy", heavy, prompt_len=2, gen_len=24)],
            slots=1, policy="sept")
        # seed history so SEPT can discriminate
        for _ in range(3):
            eng.estimator.observe_completion("cheap", 0.01)
            eng.estimator.observe_completion("heavy", 1.0)
        eng.submit("heavy")
        eng.submit("cheap")
        eng.submit("cheap")
        eng.run(max_wall_s=60)
        done = [r.fn for r in eng.completed]
        assert done[0] == "cheap" and done[1] == "cheap"

    def test_slot_pool_accounting(self):
        from repro.serving import SlotPool
        cfg = scale_down(get_config("qwen3_1_7b"))
        pool = SlotPool(cfg, n_slots=3, max_len=32)
        s1 = pool.assign(101)
        s2 = pool.assign(102)
        assert pool.free_slots == 1
        pool.advance(s1, 5)
        assert int(pool.lengths_array()[s1]) == 5
        pool.release(s1)
        assert pool.free_slots == 2
        with pytest.raises(AssertionError):
            pool.release(s1)
        _ = s2


class TestShardingResolver:
    def test_divisibility_fallback(self):
        """Non-divisible dims silently replicate instead of failing."""
        from repro.launch.sharding import resolve
        from repro.launch.mesh import make_smoke_mesh
        mesh = make_smoke_mesh(shape=(1, 1), axes=("data", "model"))
        s = resolve(mesh, ("data", "model"), (7, 13))
        assert s is not None  # 1-sized axes always divide

    @pytest.mark.slow
    def test_dryrun_lowering_on_forced_devices(self):
        """End-to-end mini dry-run in a subprocess with 8 host devices: the
        full sharding pipeline lowers and compiles a scaled-down arch."""
        script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "SRC")
import jax, dataclasses
import numpy as np
from repro.configs import get_config
from repro.models import scale_down
from repro.launch.steps import make_train_step, batch_struct, params_struct
from repro.launch import sharding as sh
from repro.training import optim

cfg = dataclasses.replace(
    scale_down(get_config("qwen2_moe_a2_7b"), d_model=64, n_heads=4),
    vocab=128, vocab_pad_multiple=16)
mesh = jax.sharding.Mesh(
    np.array(jax.devices()).reshape(2, 4), ("data", "model"))
params = params_struct(cfg)
pspecs = sh.param_specs(cfg, mesh)
batch = batch_struct(cfg, 4, 16, labels=True)
opt = optim.state_shapes(params)
opt_specs = optim.AdamWState(step=sh.replicated(mesh), m=pspecs, v=pspecs)
step = make_train_step(cfg)
with mesh:
    compiled = jax.jit(step, in_shardings=(
        pspecs, opt_specs, sh.batch_specs(mesh, batch))
    ).lower(params, opt, batch).compile()
cost = compiled.cost_analysis()
if isinstance(cost, (list, tuple)):   # older JAX returns [dict]
    cost = cost[0] if cost else {}
print("MINI_DRYRUN_OK", cost.get("flops", 0) > 0)
"""
        src = str(Path(__file__).resolve().parent.parent / "src")
        out = subprocess.run(
            [sys.executable, "-c", script.replace("SRC", src)],
            capture_output=True, text=True, timeout=300)
        assert "MINI_DRYRUN_OK True" in out.stdout, out.stderr[-2000:]
