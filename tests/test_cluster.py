"""Multi-node cluster: load balancing, fault tolerance, stragglers, scaling."""

import numpy as np

from repro.core import (
    Cluster,
    ClusterConfig,
    generate_burst,
    simulate_baseline_cluster,
    simulate_cluster,
    summarize,
)


def _burst(nodes=2, cores=10, intensity=30, seed=0):
    return generate_burst(cores=nodes * cores, intensity=intensity, seed=seed)


class TestAssignmentModels:
    def test_pull_completes_all(self):
        reqs = _burst()
        res = simulate_cluster(reqs, nodes=2, cores_per_node=10, policy="fc")
        assert len(res.requests) == len(reqs)

    def test_push_completes_all(self):
        reqs = _burst()
        res = simulate_cluster(reqs, nodes=2, cores_per_node=10,
                               policy="fc", assignment="push")
        assert len(res.requests) == len(reqs)

    def test_baseline_home_invoker(self):
        reqs = _burst()
        res = simulate_baseline_cluster(reqs, nodes=2, cores_per_node=10)
        assert len(res.requests) == len(reqs)

    def test_work_spreads_across_nodes(self):
        reqs = _burst(nodes=3)
        res = simulate_cluster(reqs, nodes=3, cores_per_node=10, policy="fc")
        nodes_used = {r.node for r in res.requests}
        assert len(nodes_used) == 3


class TestFaultTolerance:
    def test_pull_model_requeues_after_failure(self):
        """Node dies mid-burst; pull model re-queues its calls -> everything
        still completes (on the surviving node)."""
        reqs = _burst(nodes=2, intensity=30)
        cfg = ClusterConfig(nodes=2, cores_per_node=10, policy="fc",
                            assignment="pull")
        cluster = Cluster(cfg, warm_functions=sorted({r.fn for r in reqs}))
        cluster.fail_node(1, at=10.0)
        res = cluster.run(reqs)
        assert res.failures > 0                      # something was in flight
        done_ids = {r.id for r in res.requests}
        assert len(done_ids) == len(reqs)            # but nothing was lost
        assert all(r.node == "node0" for r in res.requests
                   if r.start is not None and r.start > 12.0)

    def test_push_model_retry_recovers(self):
        reqs = _burst(nodes=2, intensity=30)
        cfg = ClusterConfig(nodes=2, cores_per_node=10, policy="fc",
                            assignment="push", retry_on_failure=True)
        cluster = Cluster(cfg, warm_functions=sorted({r.fn for r in reqs}))
        cluster.fail_node(0, at=5.0)
        res = cluster.run(reqs)
        assert len(res.requests) == len(reqs)

    def test_push_model_without_retry_loses_requests(self):
        """Paper §III: 'if the invoker fails, the assigned requests are
        lost' in the push model."""
        reqs = _burst(nodes=2, intensity=30)
        cfg = ClusterConfig(nodes=2, cores_per_node=10, policy="fc",
                            assignment="push", retry_on_failure=False)
        cluster = Cluster(cfg, warm_functions=sorted({r.fn for r in reqs}))
        cluster.fail_node(0, at=5.0)
        res = cluster.run(reqs)
        assert len(res.requests) < len(reqs)


class TestStragglers:
    def test_backup_requests_cut_tail_with_slow_node(self):
        """One node at 20% speed receiving work via blind round-robin push;
        hedged backups should cut the tail.  (Under the pull model the slow
        node naturally takes less work, so hedging has nothing to fix --
        that interplay is exactly why both exist.)"""
        stats = {}
        for backups in (False, True):
            p95 = []
            for seed in range(2):
                reqs = _burst(nodes=2, intensity=20, seed=seed)
                res = simulate_cluster(
                    reqs, nodes=2, cores_per_node=10, policy="fc",
                    assignment="push", lb="round_robin",
                    backup_requests=backups, straggler_factor=3.0,
                    node_speeds={1: 0.2})
                p95.append(summarize(res.requests).response_pct[95])
            stats[backups] = np.mean(p95)
        assert stats[True] <= stats[False]

    def test_backups_are_issued(self):
        reqs = _burst(nodes=2, intensity=20)
        res = simulate_cluster(reqs, nodes=2, cores_per_node=10, policy="fc",
                               assignment="push", lb="round_robin",
                               backup_requests=True, straggler_factor=2.0,
                               node_speeds={1: 0.1})
        assert res.backups_issued > 0


class TestElasticScaling:
    def test_autoscaler_adds_nodes_under_overload(self):
        reqs = _burst(nodes=1, cores=10, intensity=120)
        res = simulate_cluster(reqs, nodes=1, cores_per_node=10, policy="fc",
                               autoscale=True, provision_delay_s=20.0,
                               scale_up_queue_per_slot=2.0)
        assert res.nodes_used > 1
        assert len(res.requests) == len(reqs)

    def test_scale_out_improves_makespan(self):
        reqs1 = _burst(nodes=1, cores=10, intensity=90)
        base = simulate_cluster(reqs1, nodes=1, cores_per_node=10, policy="fc")
        reqs2 = _burst(nodes=1, cores=10, intensity=90)
        scaled = simulate_cluster(reqs2, nodes=1, cores_per_node=10,
                                  policy="fc", autoscale=True,
                                  provision_delay_s=15.0,
                                  scale_up_queue_per_slot=1.0)
        m1 = summarize(base.requests).max_completion
        m2 = summarize(scaled.requests).max_completion
        assert m2 < m1
