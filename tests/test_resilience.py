"""Request-lifecycle resilience: timeouts, retry backoff, admission control.

Contracts under test:

* ``TimeoutSpec`` / ``RetryPolicy`` / ``AdmissionPolicy`` value semantics
  and constructor validation;
* backoff delays are pure functions of ``(seq, attempt)`` -- deterministic
  across engines and worker counts -- and bounded by the
  ``(1-jitter)..1`` window around ``min(cap, base * 2**(attempt-1))``
  (property-tested through the hypothesis shim);
* the reference ``Cluster`` conserves requests under resilience: every
  request ends terminal (completed xor failed), ``retries_issued`` equals
  the summed per-request attempt counters, wasted work only appears once
  timeouts can cancel running attempts;
* the scan kernel reproduces the reference *exactly* on the resilience
  counters (``timed_out`` / ``shed`` / ``retries_issued``), the
  failed-request id sets and per-request attempts -- a small grid in
  tier-1 and a >= 48-cell grid in the slow set;
* ``REPRO_SCAN_CHECK=1`` names the offending bucket/cell/field on a
  non-finite output and passes cleanly over healthy resilience cells;
* ``run_sweep`` isolates faulting cells into the ``failed`` column plus
  ``meta["errors"]``, and the batch dispatcher retries value-dependent
  batch failures per item instead of losing the whole bucket.
"""

import copy
import itertools
import math

import pytest

from _hypothesis_shim import given, settings, st
from repro.core import (
    AdmissionPolicy,
    ResilienceSpec,
    RetryPolicy,
    SweepCell,
    SweepSpec,
    TimeoutSpec,
    generate_trace_burst,
    retry_jitter_u,
    run_sweep,
    simulate_cluster,
)
from repro.core.sweep import run_cells_scan

try:
    import jax  # noqa: F401
    HAVE_JAX = True
except ImportError:
    HAVE_JAX = False

needs_jax = pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")


def _burst(seed=0, intensity=8, duration_s=30.0, cores=8):
    return generate_trace_burst(cores=cores, intensity=intensity, seed=seed,
                                kind="poisson", duration_s=duration_s)


def _run_ref(reqs, spec, policy="sept", **kw):
    base = dict(nodes=2, cores_per_node=4, policy=policy,
                assignment="push", warm=True, resilience=spec)
    base.update(kw)
    return simulate_cluster(copy.deepcopy(reqs), **base)


# ---------------------------------------------------------------------------
# spec value semantics
# ---------------------------------------------------------------------------
class TestTimeoutSpec:
    def test_deadline_is_multiple_of_estimate(self):
        spec = TimeoutSpec(multiple=4.0, floor_s=0.5)
        assert spec.deadline(10.0, 2.0) == 10.0 + 4.0 * 2.0

    def test_floor_guards_tiny_estimates(self):
        spec = TimeoutSpec(multiple=4.0, floor_s=0.5)
        # a 1 ms estimate must not produce a 4 ms deadline
        assert spec.deadline(0.0, 0.001) == 4.0 * 0.5

    def test_absolute_overrides_multiple(self):
        spec = TimeoutSpec(multiple=4.0, floor_s=0.5, absolute_s=30.0)
        assert spec.deadline(5.0, 100.0) == 35.0

    def test_validation(self):
        with pytest.raises(ValueError):
            TimeoutSpec(multiple=0.0)
        with pytest.raises(ValueError):
            TimeoutSpec(floor_s=-1.0)
        with pytest.raises(ValueError):
            TimeoutSpec(absolute_s=0.0)
        with pytest.raises(ValueError):
            TimeoutSpec(absolute_s=math.inf)


class TestRetryPolicy:
    def test_should_retry_respects_budget(self):
        pol = RetryPolicy(max_attempts=3)
        assert pol.should_retry("timeout", 1)
        assert pol.should_retry("timeout", 2)
        assert not pol.should_retry("timeout", 3)

    def test_should_retry_respects_causes(self):
        pol = RetryPolicy(max_attempts=3, retry_on=("timeout",))
        assert pol.should_retry("timeout", 1)
        assert not pol.should_retry("shed", 1)
        assert not pol.should_retry("kill", 1)

    def test_immediate_mode_has_zero_delay(self):
        pol = RetryPolicy(max_attempts=4, mode="immediate")
        assert all(pol.delay(seq, a) == 0.0
                   for seq in (0, 7, 991) for a in (1, 2, 3))

    def test_backoff_doubles_until_cap(self):
        pol = RetryPolicy(max_attempts=8, mode="backoff", base_delay_s=0.5,
                          cap_delay_s=4.0, jitter=0.0)
        # jitter=0 makes the schedule exactly min(cap, base * 2**(a-1))
        assert [pol.delay(0, a) for a in (1, 2, 3, 4, 5)] == \
            [0.5, 1.0, 2.0, 4.0, 4.0]

    def test_jitter_window(self):
        pol = RetryPolicy(max_attempts=4, mode="backoff", base_delay_s=1.0,
                          cap_delay_s=8.0, jitter=0.5)
        for seq in range(50):
            for a in (1, 2, 3):
                d = 1.0 * 2 ** (a - 1)
                assert (1 - 0.5) * d <= pol.delay(seq, a) <= d

    def test_delay_is_deterministic(self):
        pol = RetryPolicy(max_attempts=4, mode="backoff")
        assert [pol.delay(3, a) for a in (1, 2, 3)] == \
            [pol.delay(3, a) for a in (1, 2, 3)]

    def test_validation(self):
        with pytest.raises(ValueError):
            RetryPolicy(max_attempts=0)
        with pytest.raises(ValueError):
            RetryPolicy(max_attempts=17)
        with pytest.raises(ValueError):
            RetryPolicy(mode="fibonacci")
        with pytest.raises(ValueError):
            RetryPolicy(jitter=1.5)
        with pytest.raises(ValueError):
            RetryPolicy(retry_on=("tuesday",))


class TestAdmissionPolicy:
    def test_shed_compares_queue_work_per_free_slot(self):
        pol = AdmissionPolicy(threshold_s=2.0)
        assert not pol.shed(3.9, 2)        # 1.95 s/slot
        assert pol.shed(4.1, 2)            # 2.05 s/slot

    def test_zero_free_slots_counts_as_one(self):
        # a saturated node still sheds on the same work threshold rather
        # than dividing by zero
        pol = AdmissionPolicy(threshold_s=2.0)
        assert pol.shed(2.5, 0)
        assert not pol.shed(1.5, 0)

    def test_validation(self):
        with pytest.raises(ValueError):
            AdmissionPolicy(threshold_s=-1.0)
        with pytest.raises(ValueError):
            AdmissionPolicy(threshold_s=math.inf)


class TestResilienceSpec:
    def test_null_spec_collapses_to_none(self):
        assert ResilienceSpec.from_any(None) is None
        assert ResilienceSpec.from_any(ResilienceSpec()) is None

    def test_component_promotion(self):
        spec = ResilienceSpec.from_any(TimeoutSpec())
        assert isinstance(spec, ResilienceSpec)
        assert spec.timeout is not None and spec.retry is None
        assert ResilienceSpec.from_any(RetryPolicy()).retry is not None
        assert ResilienceSpec.from_any(
            AdmissionPolicy()).admission is not None
        with pytest.raises(TypeError):
            ResilienceSpec.from_any(object())

    def test_arrays_shapes(self):
        t4, r6, a2 = ResilienceSpec(
            timeout=TimeoutSpec(), retry=RetryPolicy(),
            admission=AdmissionPolicy()).arrays()
        assert (t4.shape, r6.shape, a2.shape) == ((4,), (6,), (2,))


# ---------------------------------------------------------------------------
# property tests (hypothesis shim: real hypothesis when installed,
# deterministic random draws otherwise)
# ---------------------------------------------------------------------------
class TestRetryProperties:
    @given(st.integers(min_value=0, max_value=10 ** 6),
           st.integers(min_value=1, max_value=15))
    @settings(max_examples=100)
    def test_jitter_u_in_unit_interval_and_deterministic(self, seq, attempt):
        u = retry_jitter_u(seq, attempt)
        assert 0.0 <= u < 1.0
        assert u == retry_jitter_u(seq, attempt)

    @given(st.integers(min_value=0, max_value=10 ** 6),
           st.integers(min_value=1, max_value=7),
           st.floats(min_value=0.01, max_value=4.0),
           st.floats(min_value=0.0, max_value=1.0))
    @settings(max_examples=60)
    def test_backoff_delay_bounds(self, seq, attempt, base, jitter):
        pol = RetryPolicy(max_attempts=8, mode="backoff", base_delay_s=base,
                          cap_delay_s=8.0, jitter=jitter)
        d = min(8.0, base * 2 ** (attempt - 1))
        lo, hi = (1 - jitter) * d, d
        got = pol.delay(seq, attempt)
        assert lo - 1e-12 <= got <= hi + 1e-12

    @given(st.floats(min_value=0.0, max_value=100.0),
           st.floats(min_value=0.0, max_value=50.0),
           st.floats(min_value=0.1, max_value=8.0),
           st.floats(min_value=0.0, max_value=4.0))
    @settings(max_examples=60)
    def test_deadline_bounds(self, now, estimate, multiple, floor):
        spec = TimeoutSpec(multiple=multiple, floor_s=floor)
        dl = spec.deadline(now, estimate)
        assert dl >= now + multiple * estimate
        assert dl >= now + multiple * floor
        assert dl == now + multiple * max(estimate, floor)


# ---------------------------------------------------------------------------
# reference-engine semantics
# ---------------------------------------------------------------------------
class TestReferenceSemantics:
    SPEC = ResilienceSpec(
        timeout=TimeoutSpec(multiple=1.5, floor_s=0.3),
        retry=RetryPolicy(max_attempts=3, mode="backoff", base_delay_s=0.2,
                          cap_delay_s=2.0, jitter=0.5),
        admission=AdmissionPolicy(threshold_s=1.0))

    def test_every_request_is_terminal(self):
        reqs = _burst(seed=3)
        res = _run_ref(reqs, self.SPEC)
        assert len(res.requests) == len(reqs)
        for r in res.requests:
            # completed xor failed: no request may be silently dropped,
            # none may be both
            assert (r.c is not None) != (r.failed is not None)

    def test_retries_issued_matches_attempt_counters(self):
        res = _run_ref(_burst(seed=3), self.SPEC)
        assert res.retries_issued == sum(r.attempts for r in res.requests)
        assert res.retries_issued > 0          # the tight deadline fires

    def test_failed_causes_are_known(self):
        res = _run_ref(_burst(seed=3), self.SPEC)
        causes = {r.failed for r in res.requests if r.failed is not None}
        assert causes and causes <= {"timeout", "shed", "kill"}

    def test_wasted_work_requires_cancellation(self):
        reqs = _burst(seed=5)
        # no timeouts -> nothing ever cancels mid-service -> no waste
        calm = _run_ref(reqs, ResilienceSpec(
            admission=AdmissionPolicy(threshold_s=50.0)))
        assert calm.timed_out == 0 and calm.wasted_work == 0.0
        hot = _run_ref(reqs, self.SPEC)
        assert hot.timed_out > 0 and hot.wasted_work > 0.0

    def test_shedding_feeds_retries(self):
        reqs = _burst(seed=7, intensity=16)
        spec = ResilienceSpec(
            retry=RetryPolicy(max_attempts=3, mode="immediate",
                              retry_on=("shed",)),
            admission=AdmissionPolicy(threshold_s=0.01))
        res = _run_ref(reqs, spec)
        assert res.shed > 0
        assert res.retries_issued > 0
        assert res.timed_out == 0              # no timeout policy active

    def test_run_is_deterministic(self):
        reqs = _burst(seed=11)
        a = _run_ref(reqs, self.SPEC)
        b = _run_ref(reqs, self.SPEC)
        sig = lambda r: {q.id: (q.c, q.failed, q.attempts)
                         for q in r.requests}
        assert sig(a) == sig(b)
        assert (a.timed_out, a.shed, a.retries_issued) == \
            (b.timed_out, b.shed, b.retries_issued)

    def test_hedging_and_resilience_is_a_documented_exclusion(self):
        from repro.core.stragglers import HedgingSpec
        with pytest.raises(ValueError, match="hedging"):
            _run_ref(_burst(seed=0), self.SPEC,
                     hedging=HedgingSpec(multiple=3.0))


# ---------------------------------------------------------------------------
# scan-vs-reference exact parity
# ---------------------------------------------------------------------------
RES_SPECS = {
    "timeout": ResilienceSpec(timeout=TimeoutSpec(multiple=3.0, floor_s=2.0)),
    "timeout+backoff": ResilienceSpec(
        timeout=TimeoutSpec(multiple=3.0, floor_s=2.0),
        retry=RetryPolicy(max_attempts=3, mode="backoff", base_delay_s=0.5,
                          cap_delay_s=4.0, jitter=0.5)),
    "timeout+immediate+shed": ResilienceSpec(
        timeout=TimeoutSpec(multiple=3.0, floor_s=2.0),
        retry=RetryPolicy(max_attempts=2, mode="immediate"),
        admission=AdmissionPolicy(threshold_s=1.0)),
    "full": ResilienceSpec(
        timeout=TimeoutSpec(multiple=3.0, floor_s=2.0),
        retry=RetryPolicy(max_attempts=3, mode="backoff", base_delay_s=0.5,
                          cap_delay_s=4.0, jitter=0.5),
        admission=AdmissionPolicy(threshold_s=2.0)),
}


def _assert_exact_parity(reqs, spec, policy):
    kw = dict(nodes=2, cores_per_node=4, policy=policy, assignment="push",
              warm=True, resilience=spec)
    ref = simulate_cluster(copy.deepcopy(reqs), backend="reference", **kw)
    scn = simulate_cluster(copy.deepcopy(reqs), backend="scan", **kw)
    for k in ("timed_out", "shed", "retries_issued"):
        assert getattr(ref, k) == getattr(scn, k), \
            f"{policy}: counter {k} ref={getattr(ref, k)} " \
            f"scan={getattr(scn, k)}"
    rf = {(r.id, r.failed) for r in ref.requests if r.c is None}
    sf = {(r.id, r.failed) for r in scn.requests if r.c is None}
    assert rf == sf, f"{policy}: failed-id sets differ"
    ra = {r.id: r.attempts for r in ref.requests}
    sa = {r.id: r.attempts for r in scn.requests}
    assert ra == sa, f"{policy}: per-request attempts differ"
    return ref


@needs_jax
class TestScanParity:
    def test_small_grid_exact_counters(self):
        # 2 seeds x 4 specs on sept: one padded-shape bucket, tier-1 sized
        exercised = 0
        for seed in (0, 7):
            reqs = _burst(seed=seed, intensity=10)
            for spec in RES_SPECS.values():
                ref = _assert_exact_parity(reqs, spec, "sept")
                exercised += ref.timed_out + ref.shed + ref.retries_issued
        assert exercised > 0   # the grid actually fired resilience events

    @pytest.mark.slow
    def test_large_grid_exact_counters(self):
        # >= 48 cells: policies x timeout multiple x retry x shed x seeds
        retries = (None,
                   RetryPolicy(max_attempts=2, mode="immediate"),
                   RetryPolicy(max_attempts=3, mode="backoff",
                               base_delay_s=0.5, cap_delay_s=4.0,
                               jitter=0.5))
        sheds = (None, AdmissionPolicy(threshold_s=2.0))
        cells = list(itertools.product(
            ("sept", "fc"), (2.0, 4.0), retries, sheds, (0, 13)))
        assert len(cells) >= 48
        exercised = 0
        for policy, tmult, retry, shed, seed in cells:
            spec = ResilienceSpec(
                timeout=TimeoutSpec(multiple=tmult, floor_s=2.0),
                retry=retry, admission=shed)
            reqs = _burst(seed=seed, intensity=10)
            ref = _assert_exact_parity(reqs, spec, policy)
            exercised += ref.timed_out + ref.shed + ref.retries_issued
        assert exercised > 0

    def test_sweep_backends_agree_on_counters(self):
        # the engines-side of "same seed => identical retry schedule":
        # a cross-checked sweep over both backends must aggregate to the
        # same exact counters per cell identity
        spec = SweepSpec(
            policies=("sept",), assignments=("push",), intensities=(40,),
            cores=(4,), nodes=(2,), duration_s=30.0, seeds=1,
            timeout_multiples=(3.0,), retry_attempts=(None, 3),
            shed_thresholds=(2.0,), timeout_floor_s=2.0,
            backends=("reference", "scan"), validate="cross-check")
        res = run_sweep(spec, workers=1)
        assert res.meta["failed"] == 0 and not res.meta["errors"]
        agg = res.aggregate()
        by = {}
        for r in agg:
            by.setdefault(r["retry_attempts"], {})[r["backend"]] = r
        for ratt, d in by.items():
            assert set(d) == {"reference", "scan"}
            for k in ("timed_out", "shed", "retries_issued", "n_failed"):
                assert d["reference"][k] == d["scan"][k], \
                    f"retry_attempts={ratt}: {k} differs across backends"

    def test_worker_count_does_not_change_results(self):
        # same seed => identical schedules regardless of pool size
        spec = SweepSpec(
            policies=("sept",), assignments=("push",), intensities=(30,),
            cores=(4,), nodes=(2,), duration_s=30.0, seeds=2,
            timeout_multiples=(3.0,), retry_attempts=(3,),
            timeout_floor_s=2.0, backends=("reference",))
        sig = lambda res: [(r.cell.label(), r.cell.seed,
                            r.metrics.get("timed_out"),
                            r.metrics.get("retries_issued"),
                            r.metrics.get("goodput"))
                           for r in res.results]
        assert sig(run_sweep(spec, workers=1)) == \
            sig(run_sweep(spec, workers=2))


# ---------------------------------------------------------------------------
# REPRO_SCAN_CHECK: opt-in finiteness validation
# ---------------------------------------------------------------------------
@needs_jax
class TestScanCheck:
    def test_names_bucket_cell_and_field(self):
        import numpy as np
        from repro.core.fastpath import _scan_check_outputs
        fields = {"finish": np.array([1.0, float("nan"), 2.0])}
        with pytest.raises(FloatingPointError) as err:
            _scan_check_outputs("n128x2", 5, 3, fields)
        msg = str(err.value)
        assert "n128x2" in msg and "cell 5" in msg
        assert "'finish'" in msg and "index 1" in msg

    def test_ignores_padding_beyond_n(self):
        import numpy as np
        from repro.core.fastpath import _scan_check_outputs
        fields = {"start": np.array([1.0, 2.0, float("inf")])}
        _scan_check_outputs("n128x2", 0, 2, fields)   # inf is padding

    def test_healthy_res_cells_pass_with_check_on(self, monkeypatch):
        monkeypatch.setenv("REPRO_SCAN_CHECK", "1")
        _assert_exact_parity(_burst(seed=2, intensity=10),
                             RES_SPECS["full"], "sept")


# ---------------------------------------------------------------------------
# sweep-engine graceful degradation (fault isolation)
# ---------------------------------------------------------------------------
class TestSweepFaultIsolation:
    SPEC = SweepSpec(
        policies=("sept", "fifo"), assignments=("push",), intensities=(20,),
        cores=(4,), nodes=(2,), duration_s=20.0, seeds=1,
        timeout_multiples=(3.0,), timeout_floor_s=2.0,
        backends=("reference",))

    def test_persistent_fault_becomes_failed_row(self):
        from repro.core.sweep import run_cell

        def runner(cell):
            if cell.policy == "sept":
                raise RuntimeError("injected persistent fault")
            return run_cell(cell)

        res = run_sweep(self.SPEC, runner=runner, workers=1)
        assert res.meta["failed"] == 1
        assert any("injected persistent fault" in e
                   for e in res.meta["errors"].values())
        rows = {r.cell.policy: r.metrics for r in res.results}
        assert rows["sept"] == {"failed": 1.0}          # poisoned cell
        assert rows["fifo"].get("failed") is None       # healthy sibling
        assert math.isfinite(rows["fifo"]["R_avg"])

    def test_transient_fault_is_retried_once(self):
        from repro.core.sweep import run_cell
        seen = set()

        def runner(cell):
            key = (cell.policy, cell.seed)
            if cell.policy == "sept" and key not in seen:
                seen.add(key)
                raise RuntimeError("injected transient fault")
            return run_cell(cell)

        res = run_sweep(self.SPEC, runner=runner, workers=1)
        # the retry absorbed the fault: no failed rows, no recorded errors
        assert res.meta["failed"] == 0 and not res.meta["errors"]
        assert all(math.isfinite(r.metrics["R_avg"]) for r in res.results)

    @needs_jax
    def test_batch_fault_falls_back_to_per_item_dispatch(self, monkeypatch):
        # a value-dependent mid-batch rejection must degrade to per-item
        # dispatch, not lose the whole bucket
        import repro.core.fastpath as fastpath
        real = fastpath.simulate_cluster_cells_scan

        def poisoned(items, **kw):
            if len(items) > 1:
                raise RuntimeError("injected batch fault")
            return real(items, **kw)

        monkeypatch.setattr(
            fastpath, "simulate_cluster_cells_scan", poisoned)
        cells = [SweepCell(policy="sept", assignment="push", nodes=2,
                           cores=4, intensity=20, duration_s=20.0,
                           timeout_multiple=3.0, timeout_floor_s=2.0,
                           retry_attempts=3, backend="scan", seed=s)
                 for s in (0, 1)]
        metrics = run_cells_scan(cells, strict=False)
        assert len(metrics) == 2
        for m in metrics:
            assert math.isfinite(m["R_avg"])
            assert m["retries_issued"] >= 0

    @needs_jax
    def test_strict_false_degrades_ineligible_cells(self):
        # pull-assignment resilience is outside the kernel's capability
        # matrix: strict=True raises, strict=False runs the reference and
        # marks the row degraded
        cell = SweepCell(policy="sept", assignment="pull", nodes=2,
                         cores=4, intensity=20, duration_s=20.0,
                         timeout_multiple=3.0, timeout_floor_s=2.0,
                         backend="scan", seed=0)
        with pytest.raises(ValueError, match="not scan-eligible"):
            run_cells_scan([cell], strict=True)
        (m,) = run_cells_scan([cell], strict=False)
        assert m["degraded"] == 1.0
        assert math.isfinite(m["R_avg"]) and m["timed_out"] >= 0
