"""Sweep engine + trace-driven workload tests.

Covers the engine's contract: per-cell determinism, serial == parallel,
emit/CSV/JSON compatibility, ragged grids; and the arrival processes:
empirical rate within tolerance of the configured rate, Azure-style trace
loading."""

import numpy as np
import pytest

from repro.core import (
    SweepCell,
    SweepSpec,
    diurnal_arrivals,
    generate_trace_burst,
    load_azure_trace,
    mmpp_arrivals,
    poisson_arrivals,
    requests_from_trace,
    run_cell,
    run_sweep,
    stable_hash,
)

SMALL = dict(cores=5, intensity=20)  # keeps every cell < ~100 requests


class TestCellDeterminism:
    @pytest.mark.parametrize("cell", [
        SweepCell(policy="sept", seed=1, **SMALL),
        SweepCell(policy="fc", arrival="poisson", seed=2, **SMALL),
        SweepCell(policy="baseline", seed=0, **SMALL),
        SweepCell(policy="fc", nodes=2, seed=3, **SMALL),
    ], ids=["sept", "poisson", "baseline", "cluster"])
    def test_same_cell_bit_identical(self, cell):
        """Two runs of one cell produce bit-identical metrics."""
        assert run_cell(cell) == run_cell(cell)

    def test_different_seeds_differ(self):
        a = run_cell(SweepCell(seed=0, **SMALL))
        b = run_cell(SweepCell(seed=1, **SMALL))
        assert a["R_avg"] != b["R_avg"]

    def test_paired_cells_share_workload(self):
        """Cells differing only in policy see the same burst (common random
        numbers): the request count matches exactly."""
        a = run_cell(SweepCell(policy="fifo", seed=4, **SMALL))
        b = run_cell(SweepCell(policy="sept", seed=4, **SMALL))
        assert a["n"] == b["n"]


class TestParallelRunner:
    def _spec(self):
        return SweepSpec(policies=("fifo", "sept"), intensities=(20,),
                         cores=(5,), seeds=2)

    def test_serial_equals_parallel(self):
        """workers=1 and workers=2 produce identical results, cell by cell."""
        r1 = run_sweep(self._spec(), workers=1)
        r2 = run_sweep(self._spec(), workers=2)
        assert r2.workers == 2
        assert [c.metrics for c in r1.results] == \
            [c.metrics for c in r2.results]
        assert r1.aggregate() == r2.aggregate()

    def test_aggregate_groups_seeds(self):
        res = run_sweep(self._spec(), workers=1)
        agg = res.aggregate()
        assert len(res) == 4 and len(agg) == 2
        assert all(r["seeds"] == 2 for r in agg)
        by_pol = {r["policy"]: r for r in agg}
        assert set(by_pol) == {"fifo", "sept"}

    def test_find_and_rows_contract(self):
        res = run_sweep(self._spec(), workers=1)
        row = res.find(policy="sept")
        assert row["R_avg"] > 0
        with pytest.raises(KeyError):
            res.find(policy="nope")
        emitted = res.rows(prefix="t")
        assert len(emitted) == 2
        assert all({"name", "us_per_call", "derived"} <= set(r)
                   for r in emitted)

    def test_csv_json_emission(self, tmp_path):
        res = run_sweep(self._spec(), workers=1)
        res.to_csv(tmp_path / "s.csv")
        res.to_json(tmp_path / "s.json")
        import csv as _csv
        import json as _json
        with open(tmp_path / "s.csv") as fh:
            rows = list(_csv.DictReader(fh))
        assert len(rows) == 2 and "R_avg" in rows[0]
        payload = _json.loads((tmp_path / "s.json").read_text())
        assert payload["cells"] == 4
        assert len(payload["results"]) == 4

    def test_cell_filter_prunes_grid(self):
        spec = SweepSpec(policies=("fifo", "sept"), intensities=(20,),
                         cores=(5,), seeds=1,
                         cell_filter=lambda c: c.policy != "fifo")
        cells = spec.cells()
        assert [c.policy for c in cells] == ["sept"]

    def test_failure_injection_cell(self):
        cell = SweepCell(policy="fc", nodes=2, fail_at=5.0, seed=0, **SMALL)
        m = run_cell(cell)
        assert m["failures"] > 0          # something was in flight
        assert m["n"] > 0                 # pull model recovered the rest


class TestArrivalProcesses:
    RATE, DUR = 8.0, 60.0

    def _mean_count(self, fn, n=40, **kw):
        return float(np.mean([
            len(fn(self.RATE, self.DUR, np.random.default_rng(s), **kw))
            for s in range(n)]))

    @pytest.mark.parametrize("fn", [poisson_arrivals, diurnal_arrivals,
                                    mmpp_arrivals],
                             ids=["poisson", "diurnal", "mmpp"])
    def test_empirical_rate_matches_configured(self, fn):
        expect = self.RATE * self.DUR
        assert abs(self._mean_count(fn) - expect) / expect < 0.15

    @pytest.mark.parametrize("fn", [poisson_arrivals, diurnal_arrivals,
                                    mmpp_arrivals],
                             ids=["poisson", "diurnal", "mmpp"])
    def test_times_sorted_within_window(self, fn):
        t = fn(self.RATE, self.DUR, np.random.default_rng(0))
        assert np.all(np.diff(t) >= 0)
        assert t.size == 0 or (t[0] >= 0 and t[-1] < self.DUR)

    def test_mmpp_is_burstier_than_poisson(self):
        """Dispersion index (var/mean of per-second counts) >> 1 for MMPP."""
        def dispersion(fn):
            ds = []
            for s in range(20):
                t = fn(self.RATE, self.DUR, np.random.default_rng(s))
                counts = np.bincount(t.astype(int), minlength=int(self.DUR))
                ds.append(counts.var() / max(counts.mean(), 1e-9))
            return float(np.mean(ds))
        assert dispersion(mmpp_arrivals) > 2.0 * dispersion(poisson_arrivals)

    def test_generate_trace_burst_kinds(self):
        for kind in ("poisson", "diurnal", "mmpp"):
            reqs = generate_trace_burst(seed=0, kind=kind, **SMALL)
            assert reqs == sorted(reqs, key=lambda r: r.r)
            assert all(r.p_true > 0 for r in reqs)
        with pytest.raises(ValueError):
            generate_trace_burst(seed=0, kind="nope", **SMALL)


class TestAzureTrace:
    def _write(self, tmp_path, text):
        p = tmp_path / "trace.csv"
        p.write_text(text)
        return p

    def test_load_and_expand(self, tmp_path):
        p = self._write(tmp_path,
                        "function,m0,m1,m2\n"
                        "thumbnailer,3,0,2\n"
                        "my-custom-fn,1,4,0\n")
        trace = load_azure_trace(p)
        assert trace == {"thumbnailer": [3, 0, 2], "my-custom-fn": [1, 4, 0]}
        reqs = requests_from_trace(trace, seed=0)
        assert len(reqs) == 10
        # arrivals land inside their minute
        for r in reqs:
            if r.fn == "thumbnailer":
                assert 0 <= r.r < 60 or 120 <= r.r < 180
        # deterministic for a seed
        again = requests_from_trace(trace, seed=0)
        assert [(r.fn, r.r, r.p_true) for r in reqs] == \
            [(r.fn, r.r, r.p_true) for r in again]

    def test_unknown_fn_maps_to_stable_profile(self, tmp_path):
        from repro.core.traces import profile_for
        assert profile_for("thumbnailer") == "thumbnailer"
        mapped = profile_for("my-custom-fn")
        assert mapped == profile_for("my-custom-fn")  # stable
        assert stable_hash("my-custom-fn") == stable_hash("my-custom-fn")

    def test_sweep_cell_over_trace(self, tmp_path):
        p = self._write(tmp_path, "f1,40,40\nf2,10,10\n")
        cell = SweepCell(policy="sept", cores=4, arrival="trace",
                         trace_path=str(p), seed=0)
        m = run_cell(cell)
        assert m["n"] == 100
        assert run_cell(cell) == m

    def test_bad_trace_raises(self, tmp_path):
        with pytest.raises(ValueError):
            load_azure_trace(self._write(tmp_path, "header,only\n"))
        with pytest.raises(ValueError):
            load_azure_trace(self._write(tmp_path, "f1,-3\n"))
        # a corrupt *data* row must raise, not be skipped as a header
        with pytest.raises(ValueError, match="f2"):
            load_azure_trace(self._write(tmp_path, "f1,3\nf2,1,,4\n"))


@pytest.mark.slow
class TestSweepScale:
    def test_200_cell_grid_end_to_end(self):
        """The acceptance grid: 200+ cells through the pool, serial ==
        parallel on the aggregate."""
        spec = SweepSpec(policies=("fifo", "sept", "eect", "rect", "fc"),
                         intensities=(20, 40), cores=(5,),
                         arrivals=("uniform", "poisson"), seeds=11)
        cells = spec.cells()
        assert len(cells) == 220
        res = run_sweep(spec)
        assert len(res) == 220
        agg = res.aggregate()
        assert len(agg) == 20
        assert all(r["seeds"] == 11 for r in agg)
