"""Simulator behaviour tests: conservation laws + the paper's trends +
failure-injection determinism and lost-request accounting."""

import numpy as np
import pytest

from repro.core import (
    BaselineNodeSim,
    EventLoop,
    OursNodeSim,
    SweepCell,
    generate_burst,
    generate_fairness_burst,
    run_cell,
    simulate_single_node,
    summarize,
)
from repro.core.simulator import REQ_OVERHEAD_S


def _run(cores, intensity, policy, mode, seed=0, **kw):
    reqs = generate_burst(cores=cores, intensity=intensity, seed=seed)
    res = simulate_single_node(reqs, cores=cores, policy=policy, mode=mode,
                               **kw)
    return reqs, res


class TestConservation:
    @pytest.mark.parametrize("mode,policy", [
        ("ours", "fifo"), ("ours", "sept"), ("ours", "fc"),
        ("ours", "eect"), ("ours", "rect"), ("baseline", "fifo"),
    ])
    def test_all_requests_complete(self, mode, policy):
        reqs, _ = _run(5, 30, policy, mode)
        assert all(r.c is not None for r in reqs)

    def test_response_at_least_processing(self):
        reqs, _ = _run(5, 30, "fifo", "ours")
        for r in reqs:
            assert r.response_time >= r.p_true - 1e-9

    def test_causality(self):
        reqs, _ = _run(5, 30, "sept", "ours")
        for r in reqs:
            assert r.start >= r.r
            assert r.finish >= r.start
            assert r.c >= r.finish

    def test_ours_never_oversubscribes(self):
        """Non-preemptive + dedicated core: intervals [start, finish) never
        have more than ``cores`` overlaps."""
        reqs, _ = _run(5, 60, "fc", "ours")
        events = []
        for r in reqs:
            events.append((r.start, 1))
            events.append((r.finish, -1))
        events.sort()
        busy = 0
        for _, d in events:
            busy += d
            assert busy <= 5


class TestPaperTrends:
    """Qualitative reproduction of §VII (exact numbers in benchmarks/)."""

    def test_sept_beats_fifo_mean_response_under_load(self):
        _, _ = _run(10, 60, "fifo", "ours")
        r_fifo = summarize(_run(10, 60, "fifo", "ours")[0]).response_avg
        r_sept = summarize(_run(10, 60, "sept", "ours")[0]).response_avg
        assert r_sept < 0.5 * r_fifo

    def test_sept_beats_fifo_stretch_by_large_factor(self):
        s_fifo = summarize(_run(10, 60, "fifo", "ours")[0]).stretch_avg
        s_sept = summarize(_run(10, 60, "sept", "ours")[0]).stretch_avg
        assert s_sept < 0.25 * s_fifo

    def test_ours_fifo_beats_baseline_at_20_cores(self):
        r_base = summarize(_run(20, 60, "fifo", "baseline")[0]).response_avg
        r_ours = summarize(_run(20, 60, "fifo", "ours")[0]).response_avg
        assert r_ours < r_base

    def test_baseline_beats_ours_fifo_low_cores_low_intensity(self):
        """Paper: baseline is actually better at 10 cores / intensity 30."""
        r_base = summarize(_run(10, 30, "fifo", "baseline")[0]).response_avg
        r_ours = summarize(_run(10, 30, "fifo", "ours")[0]).response_avg
        assert r_base < r_ours

    def test_cold_starts_baseline_grow_with_intensity(self):
        _, res30 = _run(10, 30, "fifo", "baseline")
        _, res120 = _run(10, 120, "fifo", "baseline")
        assert res120.cold_starts > 3 * max(res30.cold_starts, 1)

    def test_cold_starts_ours_zero_at_32gb(self):
        _, res = _run(10, 60, "fifo", "ours", memory_mb=32 * 1024)
        assert res.cold_starts == 0

    def test_cold_starts_ours_nonzero_when_memory_tight(self):
        _, res = _run(10, 60, "fifo", "ours", memory_mb=4 * 1024)
        assert res.cold_starts > 0

    def test_fc_protects_rare_long_function(self):
        """§VII-D: FC cuts the rare dna-visualisation's stretch vs SEPT."""
        dna = {}
        for pol in ("sept", "fc"):
            vals = []
            for seed in range(2):
                reqs = generate_fairness_burst(seed=seed)
                simulate_single_node(reqs, cores=10, policy=pol, mode="ours")
                s = summarize(reqs, per_function=True)
                vals.append(s.per_function["dna-visualisation"].stretch_avg)
            dna[pol] = np.mean(vals)
        assert dna["fc"] < dna["sept"]

    def test_estimator_learns_despite_nonclairvoyance(self):
        reqs, _ = _run(10, 40, "sept", "ours", seed=3)
        # late-arriving short calls should have much lower priority values
        # than long ones (estimates converged)
        short = [r for r in reqs if r.fn == "graph-bfs"][-5:]
        long_ = [r for r in reqs if r.fn == "dna-visualisation"][-5:]
        assert np.mean([r.priority for r in short]) < \
            np.mean([r.priority for r in long_])


class TestFailureInjection:
    """kill() mid-flight: deterministic under per-cell seeding, and every
    request is accounted for (completed | lost | dropped-after-death)."""

    KILL_AT = 6.0

    def _run_with_kill(self, mode, seed):
        reqs = generate_burst(cores=5, intensity=20, seed=seed)
        loop = EventLoop()
        warm = sorted({r.fn for r in reqs})
        if mode == "ours":
            node = OursNodeSim(loop, 5, policy="sept", warm_functions=warm)
        else:
            node = BaselineNodeSim(loop, 5, warm_functions=warm)
        for req in reqs:
            loop.schedule(req.r + REQ_OVERHEAD_S, lambda r=req: node.submit(r))
        box = {}
        loop.schedule(self.KILL_AT, lambda: box.setdefault("lost", node.kill()))
        loop.run()
        return reqs, node, box["lost"]

    @pytest.mark.parametrize("mode", ["ours", "baseline"])
    def test_every_request_accounted_for(self, mode):
        reqs, node, lost = self._run_with_kill(mode, seed=0)
        done_ids = {r.id for r in node.completed}
        lost_ids = {r.id for r in lost}
        # dropped: arrived after the crash, rejected at submit()
        dropped_ids = {r.id for r in reqs} - done_ids - lost_ids
        assert not done_ids & lost_ids
        assert len(done_ids) + len(lost_ids) + len(dropped_ids) == len(reqs)
        assert lost_ids, "nothing was in flight at the kill -- dead scenario"
        assert all(r.c is None for r in lost)
        assert all(r.r + REQ_OVERHEAD_S > self.KILL_AT
                   for r in reqs if r.id in dropped_ids)

    @pytest.mark.parametrize("mode", ["ours", "baseline"])
    def test_kill_includes_midflight_work(self, mode):
        """The kill must interrupt *running* calls, not only queued ones."""
        reqs, node, lost = self._run_with_kill(mode, seed=0)
        started = [r for r in lost if r.start is not None
                   and r.start <= self.KILL_AT]
        assert started, "expected at least one executing call to be lost"

    @pytest.mark.parametrize("mode", ["ours", "baseline"])
    def test_kill_deterministic(self, mode):
        """Same seed -> identical completions and identical lost set
        (request ids are a global counter, so compare by content)."""
        r1, n1, l1 = self._run_with_kill(mode, seed=1)
        r2, n2, l2 = self._run_with_kill(mode, seed=1)
        key = lambda rs: sorted((r.fn, r.r, r.c) for r in rs)  # noqa: E731
        assert key(n1.completed) == key(n2.completed)
        assert sorted((r.fn, r.r) for r in l1) == \
            sorted((r.fn, r.r) for r in l2)

    def test_sweep_failure_cell_deterministic_and_recovers(self):
        """Under the sweep engine's per-cell seeding the fail_at cell is a
        pure function of the cell, and the pull cluster re-queues lost work
        so nothing is silently dropped."""
        cell = SweepCell(policy="fc", nodes=2, cores=5, intensity=20,
                         fail_at=5.0, seed=7)
        m1, m2 = run_cell(cell), run_cell(cell)
        assert m1 == m2
        assert m1["failures"] > 0
        baseline = run_cell(SweepCell(policy="fc", nodes=2, cores=5,
                                      intensity=20, seed=7))
        assert m1["n"] == baseline["n"]   # lost requests were re-dispatched
        assert m1["R_avg"] > baseline["R_avg"]  # but the failure cost time
