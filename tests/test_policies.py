"""Unit + property tests for the paper's scheduling policies (§IV)."""

from _hypothesis_shim import given, settings, st

from repro.core import (
    EECT, FIFO, FairChoice, PriorityQueue, RECT, Request, RuntimeEstimator,
    SEPT, make_policy,
)


def _req(fn, r_prime):
    r = Request(fn=fn, r=r_prime)
    r.r_prime = r_prime
    return r


# ---------------------------------------------------------------------------
# estimator
# ---------------------------------------------------------------------------
class TestEstimator:
    def test_unseen_function_estimate_is_zero(self):
        est = RuntimeEstimator()
        assert est.estimate("nope") == 0.0

    def test_mean_of_recent(self):
        est = RuntimeEstimator()
        for p in [1.0, 2.0, 3.0]:
            est.observe_completion("f", p)
        assert abs(est.estimate("f") - 2.0) < 1e-12

    @given(st.lists(st.floats(0.001, 100.0), min_size=1, max_size=50))
    @settings(max_examples=50, deadline=None)
    def test_window_keeps_last_10(self, times):
        est = RuntimeEstimator()
        for p in times:
            est.observe_completion("f", p)
        tail = times[-10:]
        assert abs(est.estimate("f") - sum(tail) / len(tail)) < 1e-9

    def test_fc_counter_prunes_horizon(self):
        est = RuntimeEstimator(fc_horizon=60.0)
        for t in [0.0, 10.0, 50.0]:
            est.observe_arrival("f", t)
        assert est.recent_count("f", 50.0) == 3
        assert est.recent_count("f", 100.0) == 1       # only t=50 remains
        assert est.recent_count("f", 111.0) == 0

    def test_prev_arrival_tracks_previous_not_current(self):
        est = RuntimeEstimator()
        est.observe_arrival("f", 1.0)
        est.observe_arrival("f", 5.0)
        assert est.prev_arrival("f") == 1.0


# ---------------------------------------------------------------------------
# policy formulas (paper definitions, verbatim)
# ---------------------------------------------------------------------------
class TestPolicyFormulas:
    def setup_method(self):
        self.est = RuntimeEstimator()
        for p in [2.0, 2.0]:
            self.est.observe_completion("f", p)
        self.est.observe_arrival("f", 1.0)
        self.est.observe_arrival("f", 3.0)

    def test_fifo_is_receive_time(self):
        assert FIFO().priority(_req("f", 7.5), self.est, 9.0) == 7.5

    def test_sept_is_estimate(self):
        assert SEPT().priority(_req("f", 7.5), self.est, 9.0) == 2.0

    def test_eect_is_receive_plus_estimate(self):
        assert EECT().priority(_req("f", 7.5), self.est, 9.0) == 9.5

    def test_rect_uses_previous_arrival(self):
        # r̄(f) = 1.0 (previous arrival), E[p] = 2.0
        assert RECT().priority(_req("f", 7.5), self.est, 9.0) == 3.0

    def test_fc_is_count_times_estimate(self):
        # 2 arrivals in window * 2.0 estimate
        assert FairChoice().priority(_req("f", 7.5), self.est, 9.0) == 4.0

    def test_make_policy_rejects_unknown(self):
        import pytest
        with pytest.raises(ValueError):
            make_policy("lifo")


# ---------------------------------------------------------------------------
# starvation-freeness (paper §IV): EECT bounds waiting
# ---------------------------------------------------------------------------
@given(st.lists(st.tuples(st.floats(0, 100), st.floats(0.01, 10)),
                min_size=2, max_size=30))
@settings(max_examples=50, deadline=None)
def test_eect_no_infinite_bypass(arrivals):
    """If r'(j) > r'(i) + E[p(i)], then priority(j) > priority(i): a call
    can only be bypassed by calls arriving within its expected-completion
    horizon -- the paper's starvation-freeness argument."""
    est = RuntimeEstimator()
    est.observe_completion("f", 1.0)
    pol = EECT()
    arrivals = sorted(arrivals)
    for (t_i, _), (t_j, _) in zip(arrivals, arrivals[1:]):
        if t_j > t_i + est.estimate("f"):
            pi = pol.priority(_req("f", t_i), est, t_i)
            pj = pol.priority(_req("f", t_j), est, t_j)
            assert pj > pi


# ---------------------------------------------------------------------------
# priority queue
# ---------------------------------------------------------------------------
class TestPriorityQueue:
    def test_pops_in_priority_order(self):
        q = PriorityQueue()
        reqs = [_req(f"f{i}", float(i)) for i in range(5)]
        for r, p in zip(reqs, [3.0, 1.0, 4.0, 0.5, 2.0]):
            q.push(r, p)
        order = [q.pop().fn for _ in range(5)]
        assert order == ["f3", "f1", "f4", "f0", "f2"]

    def test_stable_for_equal_priorities(self):
        q = PriorityQueue()
        for i in range(10):
            q.push(_req(f"f{i}", 0.0), 1.0)
        assert [q.pop().fn for _ in range(10)] == [f"f{i}" for i in range(10)]

    def test_remove_specific(self):
        q = PriorityQueue()
        reqs = [_req(f"f{i}", 0.0) for i in range(5)]
        for i, r in enumerate(reqs):
            q.push(r, float(i))
        assert q.remove(reqs[2])
        assert not q.remove(reqs[2])
        assert [q.pop().fn for _ in range(4)] == ["f0", "f1", "f3", "f4"]

    @given(st.lists(st.floats(0, 1e6), min_size=1, max_size=100))
    @settings(max_examples=50, deadline=None)
    def test_heap_order_property(self, prios):
        q = PriorityQueue()
        for i, p in enumerate(prios):
            q.push(_req(f"f{i}", 0.0), p)
        popped = [q.pop().priority for _ in range(len(prios))]
        assert popped == sorted(popped)
