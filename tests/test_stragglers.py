"""Heterogeneity & straggler-mitigation subsystem.

Contracts under test:

* :class:`NodeSpeedProfile` / :class:`HedgingSpec` / ``rolling_restart``
  validate and evaluate correctly (speed sampling, episode windows, tensor
  form, deadline arithmetic);
* the reference ``Cluster`` consumes them: degraded nodes slow completions,
  steal-mode hedging cuts the tail and counts ``backups_issued`` /
  ``steals_won``, duplicate mode races copies and the first completion
  wins, the legacy ``backup_requests`` boolean maps onto the same spec;
* the scan kernel reproduces the reference on a policy x hetero stress
  grid: metrics to float64 rounding and ``backups``/``steals``/``failures``
  **bit-identically** (the ISSUE acceptance bar), including multi-failure
  schedules (``fail_spec`` / rolling restarts);
* ``PriorityQueue.remove`` is tombstone-based and behaviorally identical to
  the old linear-scan version (pop order, ties, len, iteration);
* ``RuntimeEstimator`` cold-start edges: zero-completion estimates, floor
  domination, and hedging determinism across repeated runs;
* the capability matrix (``supports(hedging=, hetero=)``) and the sweep
  axes route straggler cells to the right engine.
"""

import random

import numpy as np
import pytest

from repro.core import (
    Cluster,
    ClusterConfig,
    HedgingSpec,
    NodeSpeedProfile,
    PriorityQueue,
    Request,
    RuntimeEstimator,
    SweepCell,
    SweepSpec,
    cluster_scan_eligible,
    generate_burst,
    get_backend,
    rolling_restart,
    run_cell,
    run_sweep,
    simulate_cluster,
    summarize,
)
from repro.core.cluster import ClusterDynamics
from repro.core.sweep import CROSS_CHECK_EXACT, CLUSTER_XCHECK_RTOL

try:
    import jax  # noqa: F401
    HAVE_JAX = True
except ImportError:
    HAVE_JAX = False

needs_jax = pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")

POLICIES = ("fifo", "sept", "eect", "rect", "fc")


def _burst(nodes=2, cores=4, intensity=12, seed=0):
    return generate_burst(cores=nodes * cores, intensity=intensity,
                          seed=seed)


def _metrics(res):
    s = summarize(res.requests)
    return {"R_avg": s.response_avg, "R_p95": s.response_pct[95],
            "max_c": s.max_completion, "n": s.n}


# ---------------------------------------------------------------------------
# NodeSpeedProfile
# ---------------------------------------------------------------------------
class TestNodeSpeedProfile:
    def test_speed_sampling(self):
        prof = NodeSpeedProfile(speeds=(1.0, 0.5),
                                episodes=((1, 10.0, 20.0, 4.0),))
        assert prof.speed_at(0, 15.0) == 1.0
        assert prof.speed_at(1, 5.0) == 0.5
        assert prof.speed_at(1, 10.0) == 0.5 / 4.0   # t0 inclusive
        assert prof.speed_at(1, 20.0) == 0.5         # t1 exclusive
        assert prof.speed_at(7, 15.0) == 1.0         # beyond speeds: nominal

    def test_uniform_detection(self):
        assert NodeSpeedProfile().is_uniform
        assert NodeSpeedProfile(speeds=(1.0, 1.0)).is_uniform
        assert not NodeSpeedProfile(speeds=(1.0, 0.5)).is_uniform
        assert not NodeSpeedProfile(
            episodes=((0, 0.0, 1.0, 2.0),)).is_uniform

    def test_from_any_shapes(self):
        assert NodeSpeedProfile.from_any(None, None) is None
        assert NodeSpeedProfile.from_any((1.0, 1.0)) is None
        d = NodeSpeedProfile.from_any({1: 0.2})
        assert d is not None and d.speeds == (1.0, 0.2)
        s = NodeSpeedProfile.from_any([0.5, 1.0])
        assert s.base_speed(0) == 0.5

    def test_validation(self):
        with pytest.raises(ValueError):
            NodeSpeedProfile(speeds=(0.0,))
        with pytest.raises(ValueError):
            NodeSpeedProfile(episodes=((0, 5.0, 5.0, 2.0),))   # empty window
        with pytest.raises(ValueError):
            NodeSpeedProfile(episodes=((0, 0.0, 10.0, 2.0),
                                       (0, 5.0, 15.0, 3.0)))  # overlap
        # distinct nodes may overlap in time
        NodeSpeedProfile(episodes=((0, 0.0, 10.0, 2.0),
                                   (1, 5.0, 15.0, 3.0)))

    def test_max_slowdown(self):
        assert NodeSpeedProfile().max_slowdown() == 1.0
        assert NodeSpeedProfile(speeds=(0.25,)).max_slowdown() == 4.0
        prof = NodeSpeedProfile(speeds=(0.5,),
                                episodes=((0, 0.0, 1.0, 3.0),))
        assert prof.max_slowdown() == 6.0            # 3x on a half-speed node

    def test_arrays_padding(self):
        prof = NodeSpeedProfile(speeds=(0.5,),
                                episodes=((0, 1.0, 2.0, 3.0),))
        spd, epn, t0, t1, f = prof.arrays(4, 2)
        assert spd.tolist() == [0.5, 1.0, 1.0, 1.0]
        assert epn.tolist() == [0, -1]
        assert f.tolist() == [3.0, 1.0]
        with pytest.raises(ValueError):
            prof.arrays(4, 0)


# ---------------------------------------------------------------------------
# HedgingSpec / rolling_restart
# ---------------------------------------------------------------------------
class TestHedgingSpec:
    def test_deadline(self):
        h = HedgingSpec(multiple=3.0, floor_s=0.5)
        assert h.deadline(10.0, 0.0) == 10.0 + 1.5   # floor dominates cold
        assert h.deadline(10.0, 2.0) == 16.0

    def test_validation(self):
        with pytest.raises(ValueError):
            HedgingSpec(multiple=0.0)
        with pytest.raises(ValueError):
            HedgingSpec(mode="preempt")
        with pytest.raises(ValueError):
            HedgingSpec(max_backups=-1)

    def test_defaults_match_legacy_cluster_knobs(self):
        """backup_requests=True must keep meaning what it meant: the old
        straggler_factor/floor defaults, 3 attempts, steal mode."""
        cfg = ClusterConfig()
        h = HedgingSpec()
        assert h.multiple == cfg.straggler_factor
        assert h.floor_s == cfg.straggler_floor_s
        assert h.max_backups == 3 and h.mode == "steal"


class TestRollingRestart:
    def test_schedule(self):
        assert rolling_restart(3, 10.0, 20.0) == ((0, 10.0), (1, 30.0),
                                                  (2, 50.0))

    def test_validation(self):
        with pytest.raises(ValueError):
            rolling_restart(0)
        with pytest.raises(ValueError):
            rolling_restart(2, start=-1.0)


# ---------------------------------------------------------------------------
# PriorityQueue: tombstone remove, behavior parity
# ---------------------------------------------------------------------------
class _LinearQueue:
    """The old O(n)-remove implementation, kept as the parity oracle."""

    def __init__(self):
        import heapq
        import itertools
        self._heapq = heapq
        self._heap = []
        self._seq = itertools.count()

    def push(self, req, priority):
        self._heapq.heappush(self._heap, (float(priority), next(self._seq),
                                          req))

    def pop(self):
        return self._heapq.heappop(self._heap)[2]

    def remove(self, req):
        for i, (_, _, r) in enumerate(self._heap):
            if r.id == req.id:
                self._heap[i] = self._heap[-1]
                self._heap.pop()
                if i < len(self._heap):
                    self._heapq._siftup(self._heap, i)
                    self._heapq._siftdown(self._heap, 0, i)
                return True
        return False

    def __len__(self):
        return len(self._heap)


class TestPriorityQueue:
    def test_fifo_on_ties(self):
        q = PriorityQueue()
        reqs = [Request(fn=f"f{i}", r=0.0) for i in range(5)]
        for r in reqs:
            q.push(r, 1.0)
        assert [q.pop().fn for _ in range(5)] == [r.fn for r in reqs]

    def test_remove_then_pop_and_len(self):
        q = PriorityQueue()
        a, b, c = (Request(fn=x, r=0.0) for x in "abc")
        q.push(a, 2.0)
        q.push(b, 1.0)
        q.push(c, 3.0)
        assert q.remove(b) and len(q) == 2
        assert not q.remove(b)                  # already gone
        assert q.peek() is a                    # tombstone scrubbed lazily
        assert sorted(r.fn for r in q) == ["a", "c"]
        assert q.pop() is a and q.pop() is c
        assert not q and len(q) == 0
        with pytest.raises(IndexError):
            q.pop()

    def test_randomized_parity_with_linear_scan(self):
        """Same op sequence -> same pop order as the old implementation."""
        rng = random.Random(7)
        fast, slow = PriorityQueue(), _LinearQueue()
        live: list[Request] = []
        out_fast, out_slow = [], []
        for step in range(2000):
            op = rng.random()
            if op < 0.5 or not live:
                req = Request(fn=f"f{step}", r=0.0)
                prio = rng.choice([0.5, 1.0, 2.0])   # force frequent ties
                fast.push(req, prio)
                slow.push(req, prio)
                live.append(req)
            elif op < 0.75:
                victim = rng.choice(live)
                assert fast.remove(victim) == slow.remove(victim)
                live.remove(victim)
            else:
                out_fast.append(fast.pop().id)
                out_slow.append(slow.pop().id)
                live = [r for r in live if r.id != out_fast[-1]]
            assert len(fast) == len(slow)
        while live:
            out_fast.append(fast.pop().id)
            out_slow.append(slow.pop().id)
            live = [r for r in live if r.id != out_fast[-1]]
        assert out_fast == out_slow


# ---------------------------------------------------------------------------
# reference engine: hetero + hedging semantics
# ---------------------------------------------------------------------------
class TestReferenceHedging:
    def test_degraded_node_slows_tail(self):
        reqs_a = _burst(seed=1)
        reqs_b = _burst(seed=1)
        healthy = simulate_cluster(reqs_a, nodes=2, cores_per_node=4,
                                   policy="fc", assignment="push", lb="home")
        degraded = simulate_cluster(reqs_b, nodes=2, cores_per_node=4,
                                    policy="fc", assignment="push", lb="home",
                                    degrade=((0, 2.0, 300.0, 8.0),))
        assert (_metrics(degraded)["R_p95"] > _metrics(healthy)["R_p95"])

    def test_steal_hedging_recovers_tail_and_counts(self):
        kw = dict(nodes=2, cores_per_node=4, policy="fc", assignment="push",
                  lb="home", degrade=((0, 2.0, 300.0, 8.0),))
        plain = simulate_cluster(_burst(seed=2), **kw)
        hedged = simulate_cluster(_burst(seed=2),
                                  hedging=HedgingSpec(multiple=3.0), **kw)
        assert hedged.backups_issued > 0
        assert 0 < hedged.steals_won <= hedged.backups_issued
        assert _metrics(hedged)["R_p95"] < _metrics(plain)["R_p95"]
        assert _metrics(hedged)["n"] == _metrics(plain)["n"]

    def test_duplicate_mode_races_and_wins(self):
        reqs = _burst(seed=3)
        res = simulate_cluster(
            reqs, nodes=2, cores_per_node=4, policy="fc",
            assignment="push", lb="home",
            degrade=((0, 2.0, 300.0, 8.0),),
            hedging=HedgingSpec(multiple=2.0, mode="duplicate"))
        assert res.backups_issued > 0
        assert 0 < res.steals_won <= res.backups_issued
        assert len(res.requests) == len(reqs)
        # winners propagate onto the original request objects
        assert all(r.c is not None for r in reqs)

    def test_duplicate_copies_never_leak_slots(self):
        """Two same-id copies racing on one node must each complete and
        free their slot (in_flight is keyed by object identity)."""
        reqs = _burst(seed=7, intensity=20)
        cluster = Cluster(
            ClusterConfig(nodes=2, cores_per_node=4, policy="fc",
                          assignment="push",
                          speed_profile=NodeSpeedProfile(speeds=(0.1, 1.0)),
                          hedging=HedgingSpec(mode="duplicate",
                                              max_backups=3, multiple=1.5,
                                              floor_s=0.1)),
            warm_functions=sorted({r.fn for r in reqs}))
        cluster.run(reqs)
        assert sum(n.scheduler.busy for n in cluster.nodes) == 0
        assert all(len(n.in_flight) == 0 for n in cluster.nodes)

    def test_duplicate_wins_are_reported_latencies(self):
        """When the backup copy wins the race, the client saw *its*
        response: the original request must report the winner's earlier
        completion, so duplicate hedging shows up in the metrics."""
        kw = dict(nodes=3, cores_per_node=4, policy="fc",
                  assignment="push", lb="home", node_speeds=(0.2, 1.0, 1.0))
        plain = simulate_cluster(_burst(nodes=3, seed=8, intensity=16), **kw)
        dup = simulate_cluster(_burst(nodes=3, seed=8, intensity=16),
                               hedging=HedgingSpec(mode="duplicate",
                                                   max_backups=2), **kw)
        assert dup.steals_won > 0
        assert (summarize(dup.requests).response_avg
                < summarize(plain.requests).response_avg)

    def test_legacy_backup_requests_equals_explicit_spec(self):
        kw = dict(nodes=2, cores_per_node=4, policy="fc", assignment="push",
                  lb="round_robin", node_speeds={1: 0.2})
        legacy = simulate_cluster(_burst(seed=4), backup_requests=True,
                                  straggler_factor=3.0, **kw)
        spec = simulate_cluster(_burst(seed=4),
                                hedging=HedgingSpec(multiple=3.0), **kw)
        assert legacy.backups_issued == spec.backups_issued
        assert legacy.steals_won == spec.steals_won
        assert _metrics(legacy) == _metrics(spec)

    def test_pull_model_hedging_is_noop(self):
        """Late binding leaves nothing queued on a node to steal: the watch
        machinery runs but never fires a backup (structural robustness)."""
        res = simulate_cluster(_burst(seed=5), nodes=2, cores_per_node=4,
                               policy="fc", assignment="pull",
                               node_speeds=(0.2, 1.0),
                               hedging=HedgingSpec(multiple=2.0))
        assert res.backups_issued == 0 and res.steals_won == 0

    def test_hedged_runs_are_deterministic(self):
        kw = dict(nodes=2, cores_per_node=4, policy="sept",
                  assignment="push", lb="home",
                  degrade=((0, 2.0, 300.0, 6.0),),
                  hedging=HedgingSpec(multiple=3.0))
        a = simulate_cluster(_burst(seed=6), **kw)
        b = simulate_cluster(_burst(seed=6), **kw)
        assert a.backups_issued == b.backups_issued
        assert _metrics(a) == _metrics(b)


# ---------------------------------------------------------------------------
# RuntimeEstimator cold-start / degradation edges (satellite)
# ---------------------------------------------------------------------------
class TestEstimatorEdges:
    def test_zero_completions_estimate_is_default(self):
        est = RuntimeEstimator()
        assert est.estimate("unseen-fn") == 0.0
        assert est.sample_count("unseen-fn") == 0
        est.observe_arrival("unseen-fn", 1.0)       # arrivals don't estimate
        assert est.estimate("unseen-fn") == 0.0

    def test_floor_dominates_cold_controller(self):
        """The cluster controller's estimator starts empty (unlike the
        warm-seeded node estimators), so early hedging deadlines are pure
        floor multiples."""
        reqs = _burst(seed=0)
        cluster = Cluster(
            ClusterConfig(nodes=2, cores_per_node=4, policy="fc",
                          hedging=HedgingSpec(multiple=3.0, floor_s=0.5)),
            warm_functions=sorted({r.fn for r in reqs}))
        # node estimators are seeded by warm-up, the controller is not
        assert cluster.nodes[0].scheduler.estimator.sample_count(
            reqs[0].fn) > 0
        assert cluster._estimator.sample_count(reqs[0].fn) == 0
        h = cluster.hedging
        assert h.deadline(0.0, cluster._estimator.estimate(reqs[0].fn)) \
            == 3.0 * 0.5

    def test_window_truncates_degraded_history(self):
        est = RuntimeEstimator(window=3)
        for v in (8.0, 8.0, 8.0, 1.0, 1.0, 1.0):
            est.observe_completion("f", v)
        assert est.estimate("f") == 1.0             # slow samples aged out


# ---------------------------------------------------------------------------
# capability matrix + eligibility
# ---------------------------------------------------------------------------
class TestCapabilityMatrix:
    def test_reference_supports_everything(self):
        ref = get_backend("reference")
        assert ref.supports(mode="ours", policy="fc", warm=True, nodes=4,
                            assignment="push", hedging=True, hetero=True)

    def test_vectorized_rejects_stragglers(self):
        vec = get_backend("vectorized")
        assert vec.supports(mode="ours", policy="fc", warm=True)
        assert not vec.supports(mode="ours", policy="fc", warm=True,
                                hedging=True)
        assert not vec.supports(mode="ours", policy="fc", warm=True,
                                hetero=True)

    @needs_jax
    def test_scan_straggler_rows(self):
        scan = get_backend("scan")
        ok = dict(mode="ours", policy="fc", warm=True, nodes=4,
                  assignment="push")
        assert scan.supports(**ok, hedging=True, hetero=True)
        # straggler scenarios compose with capacity dynamics now
        assert scan.supports(**ok, hedging=True, autoscale=True)
        assert scan.supports(**ok, hetero=True, failures=True)
        # single-node push hedging self-steals, exactly like the reference
        assert scan.supports(mode="ours", policy="fc", warm=True,
                             nodes=1, assignment="push", hedging=True)
        # pull hedging (a structural no-op) is fine at any node count
        assert scan.supports(mode="ours", policy="fc", warm=True, nodes=1,
                             assignment="pull", hedging=True)

    def test_eligibility_gates(self):
        reqs = _burst()
        prof = NodeSpeedProfile(speeds=(0.5, 1.0))
        assert cluster_scan_eligible(reqs, 2, 4, "fc", assignment="push",
                                     profile=prof,
                                     hedging=HedgingSpec())
        # duplicate-mode racing is in-matrix (static and pull-side dynamic)
        assert cluster_scan_eligible(
            reqs, 2, 4, "fc", assignment="push",
            hedging=HedgingSpec(mode="duplicate"))
        # ...except racing copies of re-arrived lost calls under push churn
        dyn = ClusterDynamics(autoscale=True)
        assert not cluster_scan_eligible(
            reqs, 2, 4, "fc", assignment="push", dynamics=dyn,
            hedging=HedgingSpec(mode="duplicate"))
        # speeds beyond the fleet are a misconfiguration
        assert not cluster_scan_eligible(
            reqs, 1, 4, "fc", profile=NodeSpeedProfile(speeds=(1.0, 0.5)))
        # straggler + dynamics combinations run on the scan kernel now
        assert cluster_scan_eligible(reqs, 2, 4, "fc", dynamics=dyn,
                                     profile=prof)


# ---------------------------------------------------------------------------
# scan-kernel parity: the ISSUE acceptance stress grid
# ---------------------------------------------------------------------------
def _assert_parity(kw, seed=0, nodes=2, cores=4, intensity=12):
    ref = simulate_cluster(_burst(nodes, cores, intensity, seed),
                           nodes=nodes, cores_per_node=cores,
                           backend="reference", **kw)
    scan = simulate_cluster(_burst(nodes, cores, intensity, seed),
                            nodes=nodes, cores_per_node=cores,
                            backend="scan", **kw)
    mr, ms = _metrics(ref), _metrics(scan)
    for k in ("R_avg", "R_p95", "max_c"):
        assert abs(mr[k] - ms[k]) <= CLUSTER_XCHECK_RTOL * max(abs(mr[k]),
                                                               1e-9), (
            f"{k}: scan {ms[k]} vs reference {mr[k]} under {kw}")
    assert mr["n"] == ms["n"]
    # the acceptance bar: count metrics bit-identical
    assert scan.backups_issued == ref.backups_issued, kw
    assert scan.steals_won == ref.steals_won, kw
    assert scan.failures == ref.failures, kw
    return ref, scan


@needs_jax
class TestScanStragglerParity:
    @pytest.mark.parametrize("policy", POLICIES)
    def test_hedged_degraded_push_all_policies(self, policy):
        ref, scan = _assert_parity(dict(
            policy=policy, assignment="push",
            degrade=((0, 2.0, 300.0, 6.0),),
            hedging=HedgingSpec(multiple=3.0)))
        assert scan.backups_issued > 0      # the scenario actually hedges

    @pytest.mark.parametrize("assignment", ("pull", "push"))
    def test_static_speeds(self, assignment):
        _assert_parity(dict(policy="fc", assignment=assignment,
                            node_speeds=(1.0, 0.25)))

    def test_episode_windows_pull(self):
        _assert_parity(dict(policy="sept", assignment="pull",
                            degrade=((0, 5.0, 40.0, 4.0),
                                     (1, 20.0, 60.0, 2.0))))

    def test_home_lb_hedged(self):
        ref, scan = _assert_parity(dict(
            policy="fc", assignment="push", lb="home",
            node_speeds=(0.2, 1.0), hedging=HedgingSpec(multiple=2.0,
                                                        max_backups=2)))
        assert scan.backups_issued > 0

    def test_pull_hedging_noop_parity(self):
        ref, scan = _assert_parity(dict(
            policy="fc", assignment="pull", node_speeds=(0.2, 1.0),
            hedging=HedgingSpec(multiple=2.0)))
        assert scan.backups_issued == 0

    def test_scan_writes_back_attempts(self):
        reqs = _burst(seed=1)
        res = simulate_cluster(reqs, nodes=2, cores_per_node=4, policy="fc",
                               assignment="push", backend="scan",
                               degrade=((0, 2.0, 300.0, 8.0),),
                               hedging=HedgingSpec(multiple=2.0))
        assert res.backups_issued > 0
        assert sum(r.attempts for r in reqs) == res.backups_issued


@needs_jax
class TestScanMultiFailure:
    def test_fail_spec_parity_exact_losses(self):
        _assert_parity(dict(policy="fc", assignment="pull",
                            fail_spec=((0, 8.0), (1, 16.0))),
                       nodes=4, intensity=15)

    def test_rolling_restart_parity(self):
        ref, scan = _assert_parity(dict(policy="fc", assignment="pull",
                                        fail_spec=rolling_restart(2, 8.0,
                                                                  10.0)),
                                   nodes=4, intensity=15)
        assert scan.failures > 0

    def test_fail_spec_out_of_fleet_raises_upfront(self):
        reqs = _burst()
        for be in ("reference", "auto"):
            with pytest.raises(ValueError, match="outside the 2-node"):
                simulate_cluster(reqs, nodes=2, cores_per_node=4,
                                 policy="fifo", backend=be,
                                 fail_spec=rolling_restart(3, 5.0, 5.0))

    def test_fail_spec_overrides_fail_at(self):
        reqs = _burst(nodes=4, intensity=15)
        res = simulate_cluster(reqs, nodes=4, cores_per_node=4, policy="fc",
                               fail_at=5.0, fail_spec=((2, 9.0),))
        # only node 2 dies (fail_spec wins); node0 keeps serving
        assert res.timeline.deactivate[0] == float("inf")
        assert res.timeline.deactivate[2] == 9.0


# ---------------------------------------------------------------------------
# sweep integration
# ---------------------------------------------------------------------------
class TestSweepAxes:
    def test_axes_expand_and_label(self):
        spec = SweepSpec(policies=("fc",), nodes=(2,), cores=(4,),
                         intensities=(12,), assignments=("push",),
                         lbs=("home",),
                         degrades=(None, ((0, 1.0, 50.0, 4.0),)),
                         hedge_multiples=(None, 3.0), seeds=1)
        cells = spec.cells()
        assert len(cells) == 4
        labels = {c.label() for c in cells}
        assert any("deg4" in lab and "hedge3" in lab for lab in labels)
        assert any("home" in lab for lab in labels)

    def test_pull_cells_collapse_lb(self):
        spec = SweepSpec(policies=("fc",), assignments=("pull", "push"),
                         lbs=("least_loaded", "home"), nodes=(2,),
                         cores=(4,), intensities=(12,), seeds=1)
        cells = spec.cells()
        pull = [c for c in cells if c.assignment == "pull"]
        assert len(pull) == 1 and pull[0].lb == "least_loaded"
        assert len([c for c in cells if c.assignment == "push"]) == 2

    def test_baseline_rejects_straggler_axes(self):
        """Silently dropping a declared outage/slow-node axis would mislabel
        healthy baseline runs as degraded scenarios."""
        for kw in (dict(fail_spec=((0, 10.0),)),
                   dict(node_speeds=(0.5, 1.0)),
                   dict(degrade=((0, 1.0, 50.0, 4.0),)),
                   dict(hedge_multiple=3.0)):
            with pytest.raises(ValueError):
                run_cell(SweepCell(policy="baseline", mode="baseline",
                                   nodes=2, cores=4, intensity=12, **kw))

    def test_failure_reroute_voids_steal_credit(self):
        """A call stolen to a node that later dies completes via the
        failure retry, not the hedge: steals_won must not count it."""
        res = simulate_cluster(
            _burst(seed=9, intensity=20), nodes=2, cores_per_node=4,
            policy="fc", assignment="push", lb="home",
            node_speeds=(0.2, 1.0), fail_spec=((1, 6.0),),
            hedging=HedgingSpec(multiple=2.0))
        assert res.failures > 0
        assert 0 <= res.steals_won <= res.backups_issued

    def test_run_cell_reference_straggler(self):
        m = run_cell(SweepCell(policy="fc", assignment="push", lb="home",
                               nodes=2, cores=4, intensity=12,
                               degrade=((0, 2.0, 300.0, 8.0),),
                               hedge_multiple=3.0, seed=0))
        assert m["backups"] > 0
        assert m["steals"] <= m["backups"]

    @needs_jax
    def test_cross_check_hedged_cells_counts_exact(self):
        """The ISSUE satellite: hedged scan cells sampled under
        validate='cross-check' with backups mismatches as hard failures
        (CROSS_CHECK_EXACT) -- a passing sweep proves the counts agree."""
        assert "backups" in CROSS_CHECK_EXACT
        spec = SweepSpec(policies=("sept",), nodes=(2,), cores=(4,),
                         intensities=(12,), assignments=("push",),
                         degrades=(((0, 2.0, 300.0, 6.0),),),
                         hedge_multiples=(3.0,), seeds=2,
                         backends=("scan",), validate="cross-check")
        res = run_sweep(spec, workers=1)
        rows = res.aggregate()
        assert rows and all(r.get("xcheck_err", 0.0) <= CLUSTER_XCHECK_RTOL
                            for r in rows)
        assert all(r["backups"] > 0 for r in rows)
