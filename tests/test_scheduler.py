"""Scheduler + container-pool invariants (paper §IV-A, §VI)."""

from _hypothesis_shim import given, settings, st

from repro.core import ContainerPool, NodeScheduler, Request


def _req(fn, t):
    return Request(fn=fn, r=t)


class TestSlotAdmission:
    def test_never_exceeds_slots(self):
        s = NodeScheduler.build(slots=3, policy="fifo")
        started = []
        for i in range(10):
            started += s.receive(_req("f", float(i)), float(i))
        assert len(started) == 3
        assert s.busy == 3
        assert s.queued == 7

    def test_completion_backfills(self):
        s = NodeScheduler.build(slots=1, policy="fifo")
        d1 = s.receive(_req("f", 0.0), 0.0)
        s.receive(_req("f", 0.1), 0.1)
        assert s.busy == 1 and s.queued == 1
        d2 = s.complete(d1[0].request, 1.0, d1[0].acquire, 1.0)
        assert len(d2) == 1 and s.busy == 1 and s.queued == 0

    def test_sept_orders_queue(self):
        s = NodeScheduler.build(slots=1, policy="sept")
        # seed history: "fast" 0.1s, "slow" 5s
        for _ in range(3):
            s.estimator.observe_completion("fast", 0.1)
            s.estimator.observe_completion("slow", 5.0)
        d = s.receive(_req("slow", 0.0), 0.0)     # occupies the slot
        s.receive(_req("slow", 0.1), 0.1)
        s.receive(_req("fast", 0.2), 0.2)
        nxt = s.complete(d[0].request, 5.0, d[0].acquire, 5.0)
        assert nxt[0].request.fn == "fast"        # fast jumped the queue

    def test_non_clairvoyant(self):
        """The scheduler never reads p_true of queued requests."""
        s = NodeScheduler.build(slots=1, policy="sept")
        r = _req("f", 0.0)
        r.p_true = 123.0
        s.receive(r, 0.0)
        assert r.priority == 0.0                  # estimate, not p_true

    @given(st.lists(st.tuples(st.integers(0, 3), st.floats(0.01, 5)),
                    min_size=1, max_size=60),
           st.integers(1, 8),
           st.sampled_from(["fifo", "sept", "eect", "rect", "fc"]))
    @settings(max_examples=40, deadline=None)
    def test_work_conservation(self, calls, slots, policy):
        """Every received call eventually starts exactly once, and busy
        never exceeds slots (hypothesis sweep over all policies)."""
        s = NodeScheduler.build(slots=slots, policy=policy)
        t = 0.0
        running = []
        started_total = 0
        for fn_i, p in calls:
            t += 0.05
            for d in s.receive(_req(f"f{fn_i}", t), t):
                running.append((d, p))
                started_total += 1
            assert 0 <= s.busy <= slots
        # drain
        while running:
            d, p = running.pop(0)
            t += p
            for d2 in s.complete(d.request, p, d.acquire, t):
                running.append((d2, 0.01))
                started_total += 1
            assert 0 <= s.busy <= slots
        assert started_total == len(calls)
        assert s.queued == 0 and s.busy == 0


class TestContainerPool:
    def test_warm_reuse_no_cold(self):
        p = ContainerPool(memory_mb=1024, container_mb=128, cores=2)
        p.warm_up(["f"], per_fn=2)
        a = p.acquire("f", 0.0)
        assert not a.cold_start and a.startup_delay == 0.0

    def test_prewarm_init_is_cold_start(self):
        p = ContainerPool(memory_mb=1024, container_mb=128, prewarm_count=1)
        a1 = p.acquire("f", 0.0)        # prewarm init
        assert a1.cold_start and 0 < a1.startup_delay <= 1.0

    def test_create_from_scratch(self):
        p = ContainerPool(memory_mb=1024, container_mb=128, prewarm_count=0)
        a = p.acquire("f", 0.0)         # no prewarm pool: docker create
        assert a.cold_start and a.startup_delay > 1.0

    def test_memory_exhaustion_queues(self):
        p = ContainerPool(memory_mb=256, container_mb=128, prewarm_count=0)
        assert p.acquire("f", 0.0) is not None
        assert p.acquire("f", 0.0) is not None
        assert p.acquire("f", 0.0) is None          # full, all busy

    def test_eviction_lru(self):
        p = ContainerPool(memory_mb=256, container_mb=128, prewarm_count=0)
        a = p.acquire("old", 0.0)
        p.release(a.container, 1.0)
        b = p.acquire("older", 2.0)
        p.release(b.container, 3.0)
        c = p.acquire("new", 4.0)                   # must evict LRU ("old")
        assert c is not None
        assert p.evictions == 1
        fns = {x.fn for x in p.containers}
        assert "old" not in fns and "older" in fns

    def test_ours_discipline_bounds_warm_per_fn(self):
        p = ContainerPool(memory_mb=100 * 1024, container_mb=128,
                          discipline="ours", cores=2, prewarm_count=0)
        acquired = [p.acquire("f", 0.0) for _ in range(6)]
        for a in acquired:
            p.release(a.container, 1.0)
        assert p.warm_count("f") <= 2               # bounded by cores

    def test_per_function_memory(self):
        p = ContainerPool(memory_mb=1024, prewarm_count=0,
                          fn_memory={"big": 1024, "small": 128})
        a = p.acquire("big", 0.0)
        assert a is not None
        assert p.acquire("small", 0.0) is None      # big container filled pool
