"""Backend registry + vectorized/scan fast-path parity tests.

The contract under test: the ``vectorized`` backend is *bit-exact* against
the reference event loop (same priorities, container decisions, LRU
eviction order and event tie-breaking), across all five policies, cold and
tight-memory regimes; the ``scan`` backend agrees within float32 rounding;
and the sweep engine's cross-check mode enforces the 1% budget."""

import time

import pytest

from repro.core import (
    BackendMismatchError,
    SweepCell,
    SweepSpec,
    available_backends,
    generate_burst,
    generate_fairness_burst,
    generate_trace_burst,
    get_backend,
    run_cell,
    run_cells_scan,
    run_sweep,
    scan_eligible,
    simulate_single_node,
)
from repro.core.sweep import CROSS_CHECK_RTOL, _cross_check, make_workload

try:
    import jax  # noqa: F401
    HAVE_JAX = True
except ImportError:
    HAVE_JAX = False

POLICIES = ("fifo", "sept", "eect", "rect", "fc")


def _request_state(reqs):
    """Everything the simulation writes onto a request, id-independent."""
    return sorted((r.fn, r.r, r.r_prime, r.start, r.finish, r.c,
                   r.priority, r.cold_start) for r in reqs)


def _run_pair(policy, cores, intensity, seed=0, gen=generate_burst, **kw):
    a = gen(cores=cores, intensity=intensity, seed=seed)
    b = gen(cores=cores, intensity=intensity, seed=seed)
    ra = simulate_single_node(a, cores=cores, policy=policy,
                              backend="reference", **kw)
    rb = simulate_single_node(b, cores=cores, policy=policy,
                              backend="vectorized", **kw)
    return a, b, ra, rb


class TestBackendRegistry:
    def test_available_backends(self):
        assert {"reference", "vectorized"} <= set(available_backends())

    def test_unknown_backend_raises(self):
        with pytest.raises(ValueError, match="unknown simulation backend"):
            get_backend("nope")

    def test_vectorized_rejects_baseline_mode(self):
        reqs = generate_burst(cores=5, intensity=10, seed=0)
        with pytest.raises(ValueError, match="does not support"):
            simulate_single_node(reqs, cores=5, mode="baseline",
                                 backend="vectorized")

    def test_meta_records_backend(self):
        reqs = generate_burst(cores=5, intensity=10, seed=0)
        res = simulate_single_node(reqs, cores=5, backend="vectorized")
        assert res.meta["backend"] == "vectorized"


class TestVectorizedExactness:
    """The acceptance grid: policy x intensity x cores, metric agreement
    asserted cell by cell -- and in fact bit-exact."""

    @pytest.mark.parametrize("policy", POLICIES)
    @pytest.mark.parametrize("cores,intensity", [(5, 20), (10, 60)])
    def test_warm_grid_bit_exact(self, policy, cores, intensity):
        a, b, ra, rb = _run_pair(policy, cores, intensity)
        assert _request_state(a) == _request_state(b)
        assert (ra.cold_starts, ra.evictions, ra.creations) == \
            (rb.cold_starts, rb.evictions, rb.creations)

    @pytest.mark.parametrize("policy", ("sept", "fc"))
    def test_cold_start_regime_bit_exact(self, policy):
        """cores=20 overflows the 32 GB warm-up: prewarm/create/evict paths."""
        a, b, ra, rb = _run_pair(policy, 20, 40)
        assert _request_state(a) == _request_state(b)
        assert ra.cold_starts == rb.cold_starts > 0
        assert ra.evictions == rb.evictions

    def test_warm_false_bit_exact(self):
        a, b, ra, rb = _run_pair("sept", 10, 30, warm=False)
        assert _request_state(a) == _request_state(b)
        assert ra.cold_starts == rb.cold_starts > 0

    def test_tight_memory_bit_exact(self):
        a, b, ra, rb = _run_pair("fc", 10, 30, memory_mb=4 * 1024)
        assert _request_state(a) == _request_state(b)
        assert ra.evictions == rb.evictions > 0

    @pytest.mark.parametrize("kind", ["poisson", "mmpp"])
    def test_stochastic_arrivals_bit_exact(self, kind):
        gen = lambda cores, intensity, seed: generate_trace_burst(  # noqa: E731
            cores=cores, intensity=intensity, seed=seed, kind=kind)
        a, b, *_ = _run_pair("rect", 10, 30, gen=gen)
        assert _request_state(a) == _request_state(b)

    def test_fairness_burst_bit_exact(self):
        gen = lambda cores, intensity, seed: generate_fairness_burst(  # noqa: E731
            cores=cores, intensity=intensity, seed=seed)
        a, b, *_ = _run_pair("fc", 10, 90, gen=gen)
        assert _request_state(a) == _request_state(b)

    def test_sweep_cell_metrics_identical(self):
        cell = dict(policy="fc", cores=5, intensity=20, seed=3)
        ref = run_cell(SweepCell(**cell))
        vec = run_cell(SweepCell(**cell, backend="vectorized"))
        assert ref == vec

    def test_vectorized_deterministic(self):
        cell = SweepCell(policy="sept", cores=5, intensity=20, seed=1,
                         backend="vectorized")
        assert run_cell(cell) == run_cell(cell)


class TestSweepBackendSelection:
    def test_backend_axis_expands(self):
        spec = SweepSpec(policies=("fifo",), intensities=(20,), cores=(5,),
                         seeds=1, backends=("reference", "vectorized"))
        cells = spec.cells()
        assert [c.backend for c in cells] == ["reference", "vectorized"]
        assert cells[1].label().endswith("vectorized")

    def test_unknown_backend_axis_raises(self):
        with pytest.raises(ValueError, match="unknown backend"):
            SweepSpec(backends=("warp",)).cells()

    def test_baseline_cells_fall_back_to_reference(self):
        """An explicit fast selector still sweeps stock-system cells."""
        ref = run_cell(SweepCell(policy="baseline", cores=5, intensity=20))
        vec = run_cell(SweepCell(policy="baseline", cores=5, intensity=20,
                                 backend="vectorized"))
        assert ref == vec

    def test_cluster_cells_fall_back_to_reference(self):
        ref = run_cell(SweepCell(policy="fc", nodes=2, cores=5, intensity=20))
        vec = run_cell(SweepCell(policy="fc", nodes=2, cores=5, intensity=20,
                                 backend="vectorized"))
        assert ref == vec


class TestCrossCheck:
    def test_validate_marks_eligible_cells(self):
        spec = SweepSpec(policies=("fifo", "baseline"), intensities=(20,),
                         cores=(5,), seeds=2, validate="cross-check")
        cells = spec.cells()
        by_policy = {}
        for c in cells:
            assert c.backend == "reference"   # identity untouched
            by_policy.setdefault(c.policy, []).append(c.cross_check)
        assert by_policy["fifo"] == [True, True]
        assert by_policy["baseline"] == [False, False]   # ineligible

    def test_validate_stride_samples_whole_groups(self):
        """Stride samples cell *identities*: a seed group is either fully
        cross-checked or not at all, so aggregation rows never split."""
        spec = SweepSpec(policies=("fifo", "sept"), intensities=(20,),
                         cores=(5,), seeds=2, validate="cross-check",
                         validate_stride=2)
        by_policy = {}
        for c in spec.cells():
            by_policy.setdefault(c.policy, []).append(c.cross_check)
        assert by_policy["fifo"] == [True, True]
        assert by_policy["sept"] == [False, False]

    def test_cross_check_axis_sugar(self):
        """backends=("cross-check",) -- the --backend flag form -- expands
        to a reference axis with validation on."""
        spec = SweepSpec(policies=("fifo",), intensities=(20,), cores=(5,),
                         seeds=2, backends=("cross-check",))
        cells = spec.cells()
        assert [c.backend for c in cells] == ["reference", "reference"]
        assert all(c.cross_check for c in cells)

    def test_validate_on_fast_backend_axis_keeps_groups_whole(self):
        """Regression: cross-checking sampled cells of a vectorized axis
        must not split a seed group into two aggregated rows."""
        spec = SweepSpec(policies=("fifo",), intensities=(20,), cores=(5,),
                         seeds=4, backends=("vectorized",),
                         validate="cross-check", validate_stride=2)
        res = run_sweep(spec, workers=1)
        agg = res.aggregate()
        assert len(agg) == 1 and agg[0]["seeds"] == 4
        assert res.find(policy="fifo")["R_avg"] > 0

    def test_validate_two_fast_backends_no_merge(self):
        """Regression: cross_check is a flag, not a backend identity, so
        validating one axis can neither merge nor split any series."""
        if not HAVE_JAX:
            pytest.skip("scan axis needs jax")
        spec = SweepSpec(policies=("fifo",), intensities=(20,), cores=(5,),
                         seeds=2, backends=("vectorized", "scan"),
                         validate="cross-check")
        cells = spec.cells()
        assert sorted((c.backend, c.cross_check) for c in cells) == \
            [("scan", False), ("scan", False),
             ("vectorized", True), ("vectorized", True)]
        res = run_sweep(spec, workers=1)
        agg = res.aggregate()
        assert sorted((r["backend"], r["seeds"]) for r in agg) == \
            [("scan", 2), ("vectorized", 2)]

    def test_csv_keeps_ragged_metric_columns(self, tmp_path):
        """xcheck_err must survive to_csv even when the first aggregated
        group (here: ineligible baseline) does not carry it."""
        import csv as _csv
        spec = SweepSpec(policies=("baseline", "fifo"), intensities=(20,),
                         cores=(5,), seeds=1, validate="cross-check")
        res = run_sweep(spec, workers=1)
        res.to_csv(tmp_path / "s.csv")
        with open(tmp_path / "s.csv") as fh:
            rows = list(_csv.DictReader(fh))
        assert "xcheck_err" in rows[0]
        by_policy = {r["policy"]: r for r in rows}
        assert by_policy["baseline"]["xcheck_err"] == ""
        assert float(by_policy["fifo"]["xcheck_err"]) == 0.0

    def test_validate_with_reference_twin_no_merge(self):
        """With both a reference and a fast axis, only reference groups are
        sampled, so normalised cross-check cells cannot merge into the
        reference twin row."""
        spec = SweepSpec(policies=("fifo",), intensities=(20,), cores=(5,),
                         seeds=2, backends=("reference", "vectorized"),
                         validate="cross-check")
        res = run_sweep(spec, workers=1)
        agg = res.aggregate()
        assert sorted(r["backend"] for r in agg) == \
            ["reference", "vectorized"]
        assert all(r["seeds"] == 2 for r in agg)

    def test_bad_validate_mode_raises(self):
        with pytest.raises(ValueError, match="validate"):
            SweepSpec(validate="paranoid").cells()

    def test_validate_requires_vectorized_compatible_axis(self):
        """A scan-only axis must not be silently replaced by
        reference+vectorized dual-runs that never exercise scan."""
        with pytest.raises(ValueError, match="vectorized backend"):
            SweepSpec(backends=("scan",), validate="cross-check").cells()

    def test_stride_on_fast_axis_keeps_one_label_family(self):
        """Regression: sampling every other *identity* of a vectorized axis
        must not alternate the series between reference- and
        vectorized-labelled rows."""
        spec = SweepSpec(policies=("fifo",), intensities=(20, 40), cores=(5,),
                         seeds=1, backends=("vectorized",),
                         validate="cross-check", validate_stride=2)
        res = run_sweep(spec, workers=1)
        agg = res.aggregate()
        assert [r["backend"] for r in agg] == ["vectorized", "vectorized"]
        assert all(r["label"].endswith("vectorized") for r in agg)
        assert "xcheck_err" in agg[0] and "xcheck_err" not in agg[1]

    def test_cross_check_label_matches_reference_group(self):
        """Sampled and unsampled cells of one identity share the emit/CSV
        series name (the cross-check is visible via xcheck_err, not the
        label)."""
        spec = SweepSpec(policies=("fifo",), intensities=(20,), cores=(5,),
                         seeds=2, validate="cross-check")
        labels = {c.label() for c in spec.cells()}
        assert labels == {SweepCell(policy="fifo", intensity=20,
                                    cores=5).label()}

    def test_cross_check_grid_green(self):
        """The PR acceptance check: a sampled policy x intensity x cores
        grid agrees within 1% per cell (here: exactly)."""
        spec = SweepSpec(policies=POLICIES, intensities=(20, 40), cores=(5,),
                         seeds=1, validate="cross-check")
        res = run_sweep(spec, workers=1)
        errs = [cr.metrics["xcheck_err"] for cr in res.results]
        assert len(errs) == 10
        assert max(errs) == 0.0   # the vectorized backend is exact

    def test_cross_check_raises_on_disagreement(self):
        cell = SweepCell(policy="fifo", cores=5, intensity=20)
        good = {"R_avg": 10.0, "S_avg": 5.0}
        bad = {"R_avg": 10.0 * (1 + 2 * CROSS_CHECK_RTOL), "S_avg": 5.0}
        assert _cross_check(cell, good, dict(good), "vectorized") == 0.0
        with pytest.raises(BackendMismatchError, match="disagrees"):
            _cross_check(cell, good, bad, "vectorized")


@pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")
class TestScanBackend:
    def test_scan_eligibility(self):
        reqs = generate_burst(cores=10, intensity=20, seed=0)
        assert scan_eligible(reqs, cores=10, policy="sept")
        assert not scan_eligible(reqs, cores=20, policy="sept")  # partial warm
        # the cold regime is in-matrix when memory is ample...
        assert scan_eligible(reqs, cores=10, policy="sept", warm=False)
        # ...but a tight pool (evict-for-memory reachable) stays reference
        assert not scan_eligible(reqs, cores=10, policy="sept", warm=False,
                                 memory_mb=512)
        assert not scan_eligible(reqs, cores=10, policy="sept",
                                 mode="baseline")

    @pytest.mark.parametrize("policy", POLICIES)
    def test_scan_matches_reference_within_budget(self, policy):
        cell = dict(policy=policy, cores=5, intensity=20, seed=0)
        ref = run_cell(SweepCell(**cell))
        scan = run_cell(SweepCell(**cell, backend="scan"))
        for k in ("R_avg", "R_p50", "R_p95", "S_avg", "max_c", "n"):
            assert scan[k] == pytest.approx(ref[k], rel=1e-3)

    def test_scan_batch_runs_whole_grid(self):
        """An intensity x policy grid as ONE lax.scan over padded tensors."""
        spec = SweepSpec(policies=("fifo", "sept", "fc"),
                         intensities=(10, 20), cores=(5,), seeds=1)
        cells = spec.cells()
        batched = run_cells_scan(cells)
        assert len(batched) == 6
        for cell, m in zip(cells, batched):
            ref = run_cell(cell)
            assert m["n"] == ref["n"]
            assert m["R_avg"] == pytest.approx(ref["R_avg"], rel=1e-3)

    def test_scan_falls_back_when_ineligible(self):
        """cores=20 is outside the always-warm regime: the sweep engine
        degrades scan -> vectorized (which is exact) and *marks* the cell."""
        ref = run_cell(SweepCell(policy="sept", cores=20, intensity=20))
        scn = run_cell(SweepCell(policy="sept", cores=20, intensity=20,
                                 backend="scan"))
        assert scn.pop("degraded") == 1.0
        assert ref == scn

    def test_run_cells_scan_rejects_ineligible(self):
        """Autoscaling and cold-pool cells run on the scan kernel since the
        capability-matrix close; duplicate racing under push churn is a
        documented rejection and strict mode refuses it."""
        auto = run_cells_scan([SweepCell(policy="fc", nodes=2, cores=5,
                                         intensity=10, autoscale=True)])
        assert auto[0]["n"] > 0 and "degraded" not in auto[0]
        cold = run_cells_scan([SweepCell(policy="fc", nodes=2, cores=5,
                                         intensity=10, warm=False)])
        assert cold[0]["n"] > 0 and "degraded" not in cold[0]
        bad = SweepCell(policy="fc", nodes=2, cores=5, intensity=10,
                        autoscale=True, assignment="push",
                        hedge_multiple=2.0, hedge_mode="duplicate")
        with pytest.raises(ValueError, match="not scan-eligible"):
            run_cells_scan([bad])
        # ...and strict=False degrades such cells to run_cell instead
        got = run_cells_scan([bad], strict=False)[0]
        assert got.pop("degraded") == 1.0
        assert got == run_cell(bad)

    def test_run_cells_scan_runs_cold_cells(self):
        """warm=False is in-matrix now: the ample-memory prewarm regime
        runs on the scan kernel with exact cold-start accounting."""
        cell = SweepCell(policy="sept", cores=5, intensity=20, warm=False)
        got = run_cells_scan([cell])[0]
        ref = run_cell(cell)
        assert "degraded" not in got
        assert got["cold"] == ref["cold"] > 0
        for k in ("R_avg", "R_p95", "S_avg", "n"):
            assert got[k] == pytest.approx(ref[k], rel=1e-2)


@pytest.mark.slow
class TestFastpathSpeed:
    def test_vectorized_speedup_on_high_intensity_grid(self):
        """The engine_bench acceptance claim, with slack for noisy CI boxes:
        the exact fast path is many times quicker than the event loop."""
        cells = SweepSpec(policies=POLICIES, intensities=(120,), cores=(10,),
                          seeds=1).cells()
        wall = {}
        for backend in ("reference", "vectorized"):
            total = 0.0
            for cell in cells:
                reqs = make_workload(cell)
                t0 = time.perf_counter()
                simulate_single_node(reqs, cores=cell.cores,
                                     policy=cell.policy, backend=backend)
                total += time.perf_counter() - t0
            wall[backend] = total
        assert wall["reference"] / wall["vectorized"] > 4.0
