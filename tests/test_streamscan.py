"""Streaming chunked-scan replay: carry handoff parity with the
single-shot scan.

Contracts under test:

* chunked replay is *bit-identical* to the single-shot scan kernel on
  streams that fit both ways: exact counter equality
  (``failures``/``timed_out``/``shed``/``retries_issued``/...), identical
  failed-request masks, and zero clock drift on start/finish times -- the
  documented guarantee only promises clocks within
  ``CLUSTER_XCHECK_RTOL``, but the handoff is exact by construction and
  the tests pin that down;
* parity holds across chunk sizes (tiny, a pow2 bucket boundary, larger
  than the stream) and across the feature axes the carry must thread:
  dynamics (failures + autoscaling), steal hedging, resilience
  (timeout/retry/admission), cold starts, FC window counts, push
  sequencing;
* ``stream_supported`` mirrors ``cluster_scan_eligible`` for the flag
  combinations the streaming path accepts;
* peak request-tensor rows are bounded by the chunk size (plus carried
  rows), independent of total stream length;
* ``stream_from_requests`` round-trips: ``write_back`` populates the
  original Request objects exactly like ``simulate_cluster_scan``.
"""

import numpy as np
import pytest

from _hypothesis_shim import given, settings, st

from repro.core.cluster import ClusterDynamics
from repro.core.request import Request
from repro.core.resilience import (
    AdmissionPolicy,
    ResilienceSpec,
    RetryPolicy,
    TimeoutSpec,
)
from repro.core.stragglers import HedgingSpec, NodeSpeedProfile
from repro.core.sweep import CLUSTER_XCHECK_RTOL

try:
    import jax  # noqa: F401
    HAVE_JAX = True
except ImportError:
    HAVE_JAX = False

needs_jax = pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")

if HAVE_JAX:
    from repro.core.fastpath import simulate_cluster_scan
    from repro.core.streamscan import (
        ArrivalStream,
        StreamChunk,
        simulate_cluster_stream,
        stream_from_requests,
        stream_supported,
    )

FNS = ("dynamic-html", "uploader", "thumbnailer", "compression")

DYN_FAIL = ClusterDynamics(fail=((1, 6.0),), failure_detect_s=0.5)
DYN_AUTO = ClusterDynamics(autoscale=True, autoscale_interval_s=2.0,
                           max_nodes=6)
RES = ResilienceSpec(
    timeout=TimeoutSpec(multiple=3.0, floor_s=0.4),
    retry=RetryPolicy(max_attempts=3, base_delay_s=0.3, cap_delay_s=2.0,
                      jitter=0.0),
    admission=AdmissionPolicy(threshold_s=1.5),
)


def _requests(n, seed, span=25.0):
    rng = np.random.default_rng(seed)
    return [Request(fn=FNS[int(rng.integers(0, len(FNS)))], r=float(r),
                    p_true=float(rng.uniform(0.05, 0.9)))
            for r in np.sort(rng.uniform(0, span, n))]


def _assert_parity(reqs, chunk, **kw):
    """Chunked replay vs single-shot scan on the same stream: exact
    counters, exact failed masks, bitwise clocks."""
    ref = simulate_cluster_scan(
        [Request(fn=q.fn, r=q.r, p_true=q.p_true) for q in reqs], **kw)
    stream, order = stream_from_requests(reqs)
    sr = simulate_cluster_stream(stream, chunk=chunk, **kw)

    ref_start = np.array([np.nan if r.start is None else r.start
                          for r in ref.requests])[order]
    ref_finish = np.array([np.nan if r.finish is None else r.finish
                           for r in ref.requests])[order]
    ref_failed = np.array([r.failed is not None for r in ref.requests])[order]

    for key, want in (("failures", ref.failures),
                      ("timed_out", ref.timed_out),
                      ("shed", ref.shed),
                      ("retries_issued", ref.retries_issued),
                      ("cold_starts", ref.cold_starts),
                      ("steals_won", ref.steals_won),
                      ("backups_issued", ref.backups_issued)):
        assert sr.counters[key] == want, (
            f"counter {key}: chunked={sr.counters[key]} single={want}")
    assert np.array_equal(sr.failed > 0, ref_failed)
    assert np.array_equal(np.isnan(sr.start), np.isnan(ref_start))
    ok = np.isfinite(ref_start)
    # exact in practice; the documented bound is CLUSTER_XCHECK_RTOL
    np.testing.assert_allclose(sr.start[ok], ref_start[ok], rtol=0, atol=0)
    np.testing.assert_allclose(sr.finish[ok], ref_finish[ok], rtol=0, atol=0)
    assert np.nanmax(np.abs(sr.start - ref_start), initial=0.0) <= (
        CLUSTER_XCHECK_RTOL * max(1.0, np.nanmax(np.abs(ref_start),
                                                 initial=1.0)))
    return sr


# chunk sizes: tiny, a pow2 bucket boundary, larger than any test stream
CHUNKS = (17, 64, 100_000)


@needs_jax
class TestHandoffParity:
    @pytest.mark.parametrize("chunk", CHUNKS)
    @pytest.mark.parametrize("policy", ("fifo", "sept", "rect", "fc"))
    def test_pull_policies(self, policy, chunk):
        _assert_parity(_requests(140, seed=hash(policy) % 97),
                       chunk=chunk, nodes=3, cores_per_node=2,
                       policy=policy, assignment="pull")

    @pytest.mark.parametrize("chunk", CHUNKS)
    def test_push_fc(self, chunk):
        _assert_parity(_requests(140, seed=4), chunk=chunk, nodes=3,
                       cores_per_node=2, policy="fc", assignment="push")

    @pytest.mark.parametrize("chunk", CHUNKS)
    def test_push_home(self, chunk):
        _assert_parity(_requests(140, seed=5), chunk=chunk, nodes=3,
                       cores_per_node=2, policy="sept", assignment="push",
                       lb="home")

    @pytest.mark.parametrize("chunk", CHUNKS)
    def test_cold_starts(self, chunk):
        sr = _assert_parity(_requests(110, seed=6), chunk=chunk, nodes=2,
                            cores_per_node=2, policy="sept",
                            assignment="pull", warm=False)
        assert sr.counters["cold_starts"] > 0

    @pytest.mark.parametrize("chunk", CHUNKS)
    def test_dynamics_failure(self, chunk):
        sr = _assert_parity(_requests(140, seed=7), chunk=chunk, nodes=3,
                            cores_per_node=2, policy="sept",
                            assignment="push", dynamics=DYN_FAIL)
        assert sr.counters["failures"] > 0

    @pytest.mark.parametrize("chunk", CHUNKS)
    def test_dynamics_autoscale(self, chunk):
        sr = _assert_parity(_requests(260, seed=1), chunk=chunk, nodes=2,
                            cores_per_node=3, policy="rect",
                            assignment="pull", dynamics=DYN_AUTO)
        assert sr.nodes_used > 2

    @pytest.mark.parametrize("chunk", CHUNKS)
    def test_hedging_steal(self, chunk):
        _assert_parity(_requests(140, seed=8), chunk=chunk, nodes=3,
                       cores_per_node=2, policy="sept", assignment="push",
                       profile=NodeSpeedProfile(speeds=(1.0, 0.7, 1.3)),
                       hedging=HedgingSpec(mode="steal", multiple=3.0,
                                           floor_s=0.5))

    @pytest.mark.parametrize("chunk", CHUNKS)
    def test_resilience(self, chunk):
        sr = _assert_parity(_requests(160, seed=9, span=12.0), chunk=chunk,
                            nodes=2, cores_per_node=2, policy="sept",
                            assignment="push", resilience=RES)
        assert sr.counters["retries_issued"] > 0

    @given(st.integers(min_value=3, max_value=160),
           st.integers(min_value=1, max_value=5000),
           st.sampled_from(("pull", "push")))
    @settings(max_examples=6, deadline=None)
    def test_random_chunk_sizes(self, seed, chunk, assignment):
        _assert_parity(_requests(90, seed=seed, span=18.0), chunk=chunk,
                       nodes=2, cores_per_node=2, policy="sept",
                       assignment=assignment)


@needs_jax
class TestStreamMechanics:
    def test_peak_rows_bounded(self):
        """Doubling the stream does not grow the per-chunk request tensor."""
        kw = dict(nodes=3, cores_per_node=2, policy="sept",
                  assignment="pull", chunk=64)
        a, _ = stream_from_requests(_requests(200, seed=11, span=60.0))
        b, _ = stream_from_requests(_requests(400, seed=11, span=120.0))
        ra = simulate_cluster_stream(a, **kw)
        rb = simulate_cluster_stream(b, **kw)
        assert rb.chunks > ra.chunks
        assert rb.peak_rows == ra.peak_rows

    def test_write_back_matches_scan(self):
        reqs = _requests(90, seed=12)
        ref = simulate_cluster_scan(
            [Request(fn=q.fn, r=q.r, p_true=q.p_true) for q in reqs],
            nodes=2, cores_per_node=2, policy="sept", assignment="pull")
        stream, order = stream_from_requests(reqs)
        sr = simulate_cluster_stream(stream, nodes=2, cores_per_node=2,
                                     policy="sept", assignment="pull",
                                     chunk=32)
        sr.write_back(reqs, order)
        for got, want in zip(reqs, ref.requests):
            assert got.node == want.node
            assert got.start == pytest.approx(want.start, abs=0)
            assert got.finish == pytest.approx(want.finish, abs=0)
            assert got.failed == want.failed

    def test_tie_safe_batching(self):
        """Simultaneous arrivals are never split across a chunk edge."""
        reqs = []
        for i in range(60):
            t = float(i // 4)  # runs of 4 identical arrival times
            reqs.append(Request(fn=FNS[i % len(FNS)], r=t, p_true=0.2))
        _assert_parity(reqs, chunk=5, nodes=2, cores_per_node=2,
                       policy="fifo", assignment="pull")

    def test_batches_callable_hint(self):
        """A zero-arg callable hint is sampled once per batch, after the
        previous batch was consumed -- the adaptive-batching contract the
        driver relies on to fit carry + fresh into one compiled shape."""
        from repro.core.streamscan import _batches

        def chunks():
            t = np.arange(30, dtype=np.float64) * 0.5
            yield StreamChunk(r=t, fn=np.zeros(30, dtype=np.int64),
                              p=np.full(30, 0.2))

        stream = ArrivalStream(fns=("dynamic-html",), chunks=chunks)
        targets = [10, 3, 5, 100]
        sampled = []

        def hint():
            sampled.append(targets[len(sampled)])
            return sampled[-1]

        sizes = [len(b[0]) for b in _batches(stream, hint)]
        # distinct times -> the tie-safe cut lands exactly on each target;
        # the final batch is the remainder
        assert sizes == [10, 3, 5, 12]
        assert sampled == [10, 3, 5, 100]

    def test_chunk_iterator_is_lazy(self):
        pulled = []

        def chunks():
            for k in range(4):
                t = np.arange(10, dtype=np.float64) * 0.1 + k
                pulled.append(k)
                yield StreamChunk(r=t, fn=np.zeros(10, dtype=np.int64),
                                  p=np.full(10, 0.2))

        stream = ArrivalStream(fns=("dynamic-html",), chunks=chunks)
        sr = simulate_cluster_stream(stream, nodes=2, cores_per_node=2,
                                     policy="sept", assignment="pull",
                                     chunk=10)
        assert sr.n == 40
        assert pulled == [0, 1, 2, 3]

    def test_lazy_tiling_parity(self):
        """iter_tiled_chunks == tile_trace + the same per-minute expansion,
        bit for bit (the --repeat lazy path vs the materialized path)."""
        from repro.core.traces import (
            iter_tiled_chunks,
            load_azure_trace,
            tiled_requests_materialized,
            tiled_stream,
        )
        import pathlib
        path = (pathlib.Path(__file__).resolve().parent.parent
                / "data" / "azure_trace_slice.csv")
        trace = load_azure_trace(path)
        lazy = list(iter_tiled_chunks(trace, seed=3, repeat=3, scale=1.5))
        mat = tiled_requests_materialized(trace, seed=3, repeat=3, scale=1.5)
        fns = sorted(trace)
        assert sum(c.r.size for c in lazy) == len(mat) > 0
        lr = np.concatenate([c.r for c in lazy])
        lf = np.concatenate([c.fn for c in lazy])
        lp = np.concatenate([c.p for c in lazy])
        assert np.array_equal(lr, np.array([q.r for q in mat]))
        assert np.array_equal(lf, np.array([fns.index(q.fn) for q in mat]))
        assert np.array_equal(lp, np.array([q.p_true for q in mat]))
        assert np.all(np.diff(lr) >= 0)
        # the ArrivalStream wrapper is re-playable
        s = tiled_stream(trace, seed=3, repeat=2)
        n1 = sum(c.r.size for c in s.iter_chunks())
        n2 = sum(c.r.size for c in s.iter_chunks())
        assert n1 == n2 > 0

    def test_supported_matrix(self):
        ok = dict(policy="sept", assignment="pull", lb="least_loaded",
                  warm=True, dynamics=None, profile=None, hedging=None,
                  resilience=None)
        assert stream_supported(**ok)
        assert not stream_supported(**{**ok, "policy": "nonesuch"})
        # duplicate hedging is reference-engine-only
        assert not stream_supported(
            **{**ok, "assignment": "push",
               "hedging": HedgingSpec(mode="duplicate")})
        # resilience requires push + warm
        assert not stream_supported(**{**ok, "resilience": RES})
        assert stream_supported(
            **{**ok, "assignment": "push", "resilience": RES})
