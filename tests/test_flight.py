"""Flight recorder: unified lifecycle traces, probes, and triage.

Contracts under test:

* **trace parity** -- the canonical lifecycle stream reconstructed from the
  scan kernel's written-back request tensors matches the instrumented
  reference event loop's stream across the feature matrix (pull/push,
  dynamics, steal/duplicate hedging, resilience, cold starts), and the
  streaming chunked-scan path matches too;
* the rich reference stream's :meth:`SimTrace.canonical` projection is
  self-consistent with :func:`trace_from_result` on the same run;
* :func:`first_divergence` names the right event/field for injected
  perturbations (time drift, wrong node, missing event, attempt count,
  failure cause) and stays silent on agreeing streams;
* :func:`triage_cell` pinpoints a perturbed request end-to-end, and a
  cross-check :class:`BackendMismatchError` carries the triage report;
* probes/exporters: windowed probe series are conservation-consistent,
  the Chrome-trace export is loadable JSON with one lane per busy slot,
  ``explain`` renders a lifecycle narrative, manifests capture provenance;
* ``run_sweep(progress=...)`` fires the callback and ``ProgressReporter``
  rate-limits correctly; ``scan_timings_clear`` resets the one-shot
  profile latch (regression);
* tracing is opt-in: ``trace=False`` attaches nothing and installs no
  recorder in the engines.
"""

import io
import json
import math

import pytest

from repro.core import (
    CANONICAL_KINDS,
    FlightRecorder,
    ProgressReporter,
    SimTrace,
    SweepCell,
    SweepSpec,
    TraceEvent,
    first_divergence,
    generate_burst,
    run_manifest,
    run_sweep,
    simulate_cluster,
    simulate_single_node,
    trace_from_requests,
    trace_from_result,
    triage_cell,
    write_manifest,
)
from repro.core.cluster import Cluster, ClusterConfig
from repro.core.resilience import (
    AdmissionPolicy,
    ResilienceSpec,
    RetryPolicy,
    TimeoutSpec,
)
from repro.core.simulator import REQ_OVERHEAD_S
from repro.core.stragglers import HedgingSpec

try:
    import jax  # noqa: F401
    HAVE_JAX = True
except ImportError:
    HAVE_JAX = False

needs_jax = pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")

CELL = dict(nodes=3, cores_per_node=4, policy="fc")


def _twin(seed=0, cores=12, intensity=30):
    """Two identical bursts (ids differ: Request ids are global)."""
    return (generate_burst(cores=cores, intensity=intensity, seed=seed),
            generate_burst(cores=cores, intensity=intensity, seed=seed))


RES = ResilienceSpec(
    timeout=TimeoutSpec(multiple=3.0, floor_s=2.0),
    retry=RetryPolicy(max_attempts=3, mode="backoff", base_delay_s=0.5,
                      cap_delay_s=4.0, jitter=0.5),
    admission=AdmissionPolicy(threshold_s=1.0))


# ---------------------------------------------------------------------------
# unit: canonical projection, relabel, first_divergence
# ---------------------------------------------------------------------------
def _ev(t, kind, req=0, node=0, attempt=0, info=""):
    return TraceEvent(t, kind, req, node, "fn", attempt, info)


def _trace(events, **kw):
    kw.setdefault("nodes", 2)
    kw.setdefault("slots_per_node", 2)
    return SimTrace(events=list(events), **kw)


class TestCanonical:
    def test_winning_run_rules(self):
        # req 0: killed on node 0, re-dispatched on node 1 -> the winner is
        # the node-1 run; the canonical stream keeps one arrival, the
        # winning dispatch/complete pair, nothing else
        rec = FlightRecorder()
        rec.emit(1.0, "arrival", req=0)
        rec.emit(1.1, "enqueue", req=0)
        rec.emit(1.2, "dispatch", req=0, node=0)
        rec.emit(2.0, "kill", req=0, node=0)
        rec.emit(2.0, "arrival", req=0)            # retry re-arrival
        rec.emit(2.5, "dispatch", req=0, node=1, attempt=1)
        rec.emit(4.0, "complete", req=0, node=1, attempt=1)
        canon = rec.to_trace(nodes=2).canonical()
        assert canon.counts() == {"arrival": 1, "dispatch": 1, "complete": 1}
        arr, = canon.by_kind("arrival")
        assert arr.t == 1.0                        # earliest arrival wins
        disp, = canon.by_kind("dispatch")
        assert (disp.node, disp.t, disp.attempt) == (1, 2.5, 1)

    def test_duplicate_race_keeps_winner(self):
        # duplicate hedging: both copies complete; the earlier completion
        # and ITS dispatch survive the projection
        rec = FlightRecorder()
        rec.emit(0.0, "arrival", req=7)
        rec.emit(1.0, "dispatch", req=7, node=0)
        rec.emit(2.0, "dispatch", req=7, node=1)   # racing backup
        rec.emit(3.0, "complete", req=7, node=1)   # backup wins
        rec.emit(9.0, "complete", req=7, node=0)
        canon = rec.to_trace(nodes=2).canonical()
        comp, = canon.by_kind("complete")
        disp, = canon.by_kind("dispatch")
        assert comp.t == 3.0 and comp.node == 1 and disp.node == 1

    def test_fail_only_without_completion(self):
        rec = FlightRecorder()
        rec.emit(0.0, "arrival", req=1)
        rec.emit(5.0, "fail", req=1, info="timeout")
        canon = rec.to_trace().canonical()
        assert canon.counts() == {"arrival": 1, "fail": 1}

    def test_relabel(self):
        tr = _trace([_ev(0.0, "arrival", req=100), _ev(1.0, "dispatch",
                                                       req=100)])
        out = tr.relabel({100: 3})
        assert [e.req for e in out.events] == [3, 3]
        assert [e.req for e in tr.events] == [100, 100]   # original intact


class TestFirstDivergence:
    BASE = [_ev(0.0, "arrival", req=0, node=-1),
            _ev(1.0, "dispatch", req=0, node=0, attempt=1),
            _ev(2.0, "complete", req=0, node=0, attempt=1),
            _ev(0.5, "arrival", req=1, node=-1),
            _ev(float("nan"), "fail", req=1, node=0, info="timeout")]

    def _perturbed(self, **patch):
        evs = []
        for e in self.BASE:
            if e.kind == patch.get("kind") and e.req == patch.get("req", 0):
                evs.append(TraceEvent(patch.get("t", e.t), e.kind, e.req,
                                      patch.get("node", e.node), e.fn,
                                      patch.get("attempt", e.attempt),
                                      patch.get("info", e.info)))
            else:
                evs.append(e)
        return _trace(evs)

    def test_agreement_is_none(self):
        assert first_divergence(_trace(self.BASE), _trace(self.BASE)) is None

    def test_time_drift(self):
        got = self._perturbed(kind="complete", t=2.5)
        rep = first_divergence(_trace(self.BASE), got, rtol=1e-2)
        assert (rep.kind, rep.req, rep.fld) == ("complete", 0, "t")
        # within rtol the same drift is tolerated
        assert first_divergence(_trace(self.BASE), got, rtol=0.5) is None

    def test_wrong_node(self):
        # move the whole winning run (dispatch + complete) to node 1: the
        # earliest field-level divergence is the dispatch's node
        got = self._perturbed(kind="dispatch", node=1)
        got = _trace([TraceEvent(e.t, e.kind, e.req, 1, e.fn, e.attempt,
                                 e.info) if e.kind == "complete"
                      and e.req == 0 else e for e in got.events])
        rep = first_divergence(_trace(self.BASE), got)
        assert (rep.kind, rep.fld, rep.got_value) == ("dispatch", "node", 1)

    def test_missing_event(self):
        got = _trace([e for e in self.BASE if not (e.kind == "dispatch")])
        rep = first_divergence(_trace(self.BASE), got)
        assert (rep.kind, rep.fld, rep.ref_value, rep.got_value) == (
            "dispatch", "count", 1, 0)

    def test_orphaned_dispatch_collapses_to_count(self):
        # a dispatch on the wrong node does not pair with the surviving
        # completion, so the canonical projection drops it entirely: the
        # divergence surfaces as a dispatch-count gap, not a node diff
        rep = first_divergence(
            _trace(self.BASE), self._perturbed(kind="dispatch", node=1))
        assert (rep.kind, rep.fld, rep.got_value) == ("dispatch", "count", 0)

    def test_attempt_gap_and_optout(self):
        got = self._perturbed(kind="dispatch", attempt=2)
        rep = first_divergence(_trace(self.BASE), got)
        assert (rep.fld, rep.got_value) == ("attempt", 2)
        assert first_divergence(_trace(self.BASE), got,
                                compare_attempts=False) is None

    def test_fail_compares_cause_not_node(self):
        # node on a terminal failure is engine bookkeeping -> ignored
        got = self._perturbed(kind="fail", req=1, node=2)
        assert first_divergence(_trace(self.BASE), got) is None
        got = self._perturbed(kind="fail", req=1, info="shed")
        rep = first_divergence(_trace(self.BASE), got)
        assert (rep.kind, rep.fld, rep.got_value) == ("fail", "cause", "shed")

    def test_earliest_divergence_wins(self):
        # two divergences: dispatch time drift at t=1.0 and a dropped fail
        # (NaN anchor sorts last) -- the report names the earlier one
        got = self._perturbed(kind="dispatch", t=1.5)
        evs = [e for e in got.events if e.kind != "fail"]
        rep = first_divergence(_trace(self.BASE), _trace(evs), rtol=1e-2)
        assert rep.t == 1.0 and rep.kind == "dispatch" and rep.fld == "t"


# ---------------------------------------------------------------------------
# reference engine: rich stream, self-consistency, probes, exporters
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def ref_traced():
    a = generate_burst(cores=12, intensity=30, seed=0)
    res = simulate_cluster(a, backend="reference", trace=True, **CELL)
    return a, res


class TestReferenceTrace:
    def test_rich_stream_shape(self, ref_traced):
        a, res = ref_traced
        tr = res.trace
        assert tr is not None
        counts = tr.counts()
        n = len(a)
        assert counts["arrival"] == n
        assert counts["complete"] == n
        assert counts["dispatch"] == n
        assert counts["node_up"] == CELL["nodes"]
        assert counts["channel_enter"] == n        # rich-only kind present
        # time-sorted with deterministic tie-breaks
        keys = [(e.t, e.kind) for e in tr.events]
        assert all(keys[i][0] <= keys[i + 1][0] for i in range(len(keys) - 1))

    def test_hook_matches_reconstruction(self, ref_traced):
        # the instrumented stream's canonical projection must equal the
        # written-back-state reconstruction of the SAME run, exactly
        a, res = ref_traced
        rebuilt = trace_from_result(res, requests=a,
                                    slots_per_node=CELL["cores_per_node"])
        assert first_divergence(res.trace, rebuilt, rtol=1e-9) is None
        assert set(rebuilt.counts()) <= set(CANONICAL_KINDS)

    def test_trace_off_attaches_nothing(self):
        a = generate_burst(cores=12, intensity=30, seed=0)
        res = simulate_cluster(a, backend="reference", **CELL)
        assert res.trace is None
        cluster = Cluster(ClusterConfig(nodes=2, cores_per_node=2))
        assert cluster._flight is None
        assert all(n.trace is None for n in cluster.nodes)

    def test_probes_conservation(self, ref_traced):
        a, res = ref_traced
        p = res.trace.probes(bins=32)
        n = len(a)
        assert sum(p["arrivals"]) == n
        assert sum(p["completions"]) == n
        # every arrival eventually dispatches: queue drains to zero
        assert p["queue_depth"][-1] == 0
        assert p["busy"][-1] == 0
        assert p["channel_backlog"][-1] == 0
        assert max(p["busy"]) <= CELL["nodes"] * CELL["cores_per_node"]
        assert all(0.0 <= u <= 1.0 + 1e-9 for u in p["utilization"])
        assert all(q >= 0 for q in p["queue_depth"])
        assert p["active_nodes"][-1] == CELL["nodes"]
        lens = {len(v) for k, v in p.items() if isinstance(v, list)}
        assert lens == {32}

    def test_chrome_export(self, ref_traced, tmp_path):
        a, res = ref_traced
        out = tmp_path / "trace.json"
        doc = res.trace.to_chrome(out)
        loaded = json.loads(out.read_text())
        assert loaded["traceEvents"] == doc["traceEvents"]
        evs = doc["traceEvents"]
        execs = [e for e in evs if e["ph"] == "X"]
        assert len(execs) == len(a)               # one slice per winning run
        assert all(e["dur"] >= 0 for e in execs)
        # lanes stay within the per-node slot count
        assert max(e["tid"] for e in execs) <= CELL["cores_per_node"]
        names = {e["args"]["name"] for e in evs if e["ph"] == "M"}
        assert "node0" in names

    def test_to_arrays_and_explain(self, ref_traced):
        a, res = ref_traced
        cols = res.trace.to_arrays()
        assert len(cols["t"]) == len(res.trace)
        rid = a[0].id
        text = res.trace.explain(rid)
        assert f"request {rid}" in text
        assert "queued" in text and "completed" in text
        assert "no events recorded" in res.trace.explain(10**9)

    def test_single_node_rich_trace(self):
        reqs = generate_burst(cores=4, intensity=20, seed=1)
        res = simulate_single_node(reqs, cores=4, policy="fc",
                                   backend="reference", trace=True)
        counts = res.trace.counts()
        assert counts["arrival"] == len(reqs)
        assert counts["complete"] == len(reqs)
        assert "channel_enter" in counts
        res_off = simulate_single_node(reqs, cores=4, policy="fc",
                                       backend="reference")
        assert res_off.trace is None


class TestManifest:
    def test_run_manifest_fields(self):
        man = run_manifest({"custom": 1})
        assert man["custom"] == 1
        assert man["python"] and man["platform"]
        assert len(man.get("git_sha", "0" * 40)) == 40
        assert isinstance(man["env"], dict)
        assert all(k.startswith(("REPRO_", "JAX_", "XLA_"))
                   for k in man["env"])

    def test_write_manifest_with_sweep(self, tmp_path):
        spec = SweepSpec(policies=("fc",), intensities=(10,), cores=(4,),
                         seeds=1)
        result = run_sweep(spec, workers=1)
        path = tmp_path / "out" / "manifest.json"
        man = write_manifest(path, sweep=result)
        loaded = json.loads(path.read_text())
        assert loaded["sweep"]["cells"] == len(result.results)
        assert man["sweep"]["degraded"] == 0


class TestProgress:
    def test_reporter_rate_limit_and_final(self):
        buf = io.StringIO()
        clock = iter(float(i) for i in range(100))
        rep = ProgressReporter(every=2, min_interval_s=0.0, stream=buf,
                               clock=lambda: next(clock))
        for done in range(1, 11):
            rep(done, 10)
        lines = buf.getvalue().strip().splitlines()
        assert rep.lines == len(lines) == 5            # 2,4,6,8,10
        assert "[sweep] 10/10 cells (100%)" in lines[-1]
        assert "cells/s" in lines[-1] and "eta" in lines[-1]

    def test_reporter_min_interval(self):
        buf = io.StringIO()
        rep = ProgressReporter(every=1, min_interval_s=60.0, stream=buf,
                               clock=lambda: 0.0)
        for done in range(1, 5):
            rep(done, 10)
        assert rep.lines == 1           # first line, then rate-limited
        rep(10, 10)
        assert rep.lines == 2           # final line always emits

    def test_run_sweep_calls_progress(self):
        calls = []
        spec = SweepSpec(policies=("fifo", "fc"), intensities=(10,),
                         cores=(4,), seeds=1)
        run_sweep(spec, workers=1, progress=lambda d, t: calls.append((d, t)))
        assert calls == [(1, 2), (2, 2)]

    def test_run_sweep_progress_reporter(self):
        buf = io.StringIO()
        spec = SweepSpec(policies=("fifo",), intensities=(10,), cores=(4,),
                         seeds=1)
        run_sweep(spec, workers=1,
                  progress=ProgressReporter(every=1, min_interval_s=0.0,
                                            stream=buf))
        assert "[sweep] 1/1 cells" in buf.getvalue()


def test_scan_timings_clear_resets_profile_latch():
    # regression: the one-shot REPRO_SCAN_PROFILE summary latch used to
    # survive scan_timings_clear(), so a second profiled run stayed silent
    from repro.core import fastpath
    fastpath._SCAN_PROFILE_DONE = True
    fastpath._SCAN_TIMINGS.append({"cells": 1})
    fastpath.scan_timings_clear()
    assert fastpath._SCAN_PROFILE_DONE is False
    assert fastpath.scan_bucket_timings() == []


# ---------------------------------------------------------------------------
# cross-engine trace parity (the observability parity surface)
# ---------------------------------------------------------------------------
PARITY_CASES = [
    ("base_pull", {}, True),
    ("push", dict(assignment="push"), True),
    ("dynamics", dict(autoscale=True, fail_at=6.0), False),
    ("steal", dict(hedging=HedgingSpec(mode="steal")), True),
    ("duplicate", dict(hedging=HedgingSpec(mode="duplicate")), True),
    ("resilience", dict(assignment="push", resilience=RES), True),
    ("cold", dict(warm=False), True),
]


@needs_jax
@pytest.mark.parametrize("label,kw,cmp_att",
                         PARITY_CASES, ids=[c[0] for c in PARITY_CASES])
def test_scan_trace_parity(label, kw, cmp_att):
    """The scan kernel's canonical lifecycle stream must match the
    instrumented reference loop event for event: same kinds and counts per
    request, nodes identical, clocks within CLUSTER_XCHECK_RTOL.  Dynamics
    cells skip the attempt compare (the kernel re-routes kill-lost calls
    without writing back a resubmission count -- documented gap)."""
    from repro.core.sweep import CLUSTER_XCHECK_RTOL

    a, b = _twin()
    ref = simulate_cluster(a, backend="reference", trace=True, **CELL, **kw)
    fast = simulate_cluster(b, backend="scan", trace=True, **CELL, **kw)
    assert fast.trace is not None
    assert fast.trace.meta.get("backend") == "scan"
    remap = {qb.id: qa.id for qa, qb in zip(a, b)}
    rep = first_divergence(ref.trace, fast.trace.relabel(remap),
                           rtol=CLUSTER_XCHECK_RTOL,
                           compare_attempts=cmp_att)
    assert rep is None, f"{label}: {rep}"


@needs_jax
def test_streamscan_trace_parity():
    """The chunked carry-handoff path reconstructs the same canonical
    stream: StreamResult.trace(order) vs the traced reference loop."""
    from repro.core.streamscan import (simulate_cluster_stream,
                                      stream_from_requests)
    from repro.core.sweep import CLUSTER_XCHECK_RTOL

    a, b = _twin()
    ref = simulate_cluster(a, backend="reference", trace=True, **CELL)
    stream, order = stream_from_requests(b, chunk=128)
    sr = simulate_cluster_stream(stream, nodes=CELL["nodes"],
                                 cores_per_node=CELL["cores_per_node"],
                                 policy=CELL["policy"], chunk=128)
    tr = sr.trace(order)
    assert tr.meta.get("backend") == "streamscan"
    idx_to_aid = {i: a[i].id for i in range(len(a))}
    rep = first_divergence(ref.trace, tr.relabel(idx_to_aid),
                           rtol=CLUSTER_XCHECK_RTOL)
    assert rep is None, str(rep)


# ---------------------------------------------------------------------------
# triage
# ---------------------------------------------------------------------------
@needs_jax
class TestTriage:
    CELL_SPEC = dict(policy="fc", nodes=2, cores=6, intensity=15, seed=0,
                     backend="scan", cross_check=False)

    def test_agreeing_cell_returns_none(self):
        assert triage_cell(SweepCell(**self.CELL_SPEC)) is None

    def test_pinpoints_perturbed_request(self, monkeypatch):
        # make_workload is called twice (reference side, then fast side);
        # slow down one call's true runtime on the FAST side only -- triage
        # must name that request's lifecycle, not just "metrics differ"
        from repro.core import sweep as sweep_mod
        real = sweep_mod.make_workload
        state = {"calls": 0, "victim": None}

        def crooked(cell):
            reqs = real(cell)
            state["calls"] += 1
            if state["calls"] == 2:
                victim = reqs[len(reqs) // 2]
                victim.p_true = victim.p_true * 40.0
                state["victim"] = len(reqs) // 2
            return reqs

        monkeypatch.setattr(sweep_mod, "make_workload", crooked)
        rep = triage_cell(SweepCell(**self.CELL_SPEC))
        assert rep is not None
        # the report names a real lifecycle event; the perturbation makes
        # the victim (or a call queued behind it) diverge in time/ordering
        assert rep.kind in CANONICAL_KINDS
        assert rep.fld in ("t", "node", "count", "attempt")

    def test_baseline_has_no_triage(self):
        cell = SweepCell(policy="baseline", nodes=1, cores=4, intensity=10,
                         seed=0)
        assert triage_cell(cell) is None

    def test_mismatch_error_carries_report(self, monkeypatch):
        from repro.core import sweep as sweep_mod
        from repro.core.flight import DivergenceReport

        fake = DivergenceReport(1.0, "dispatch", 3, "node", 0, 1)
        monkeypatch.setattr(sweep_mod, "triage_cell",
                            lambda cell, rtol=None: fake)
        err = sweep_mod._mismatch(SweepCell(**self.CELL_SPEC), 1e-2, "boom")
        assert err.report is fake
        assert "first divergence" in str(err)
        assert "kind=dispatch" in str(err)
