"""The README capability table is generated, not hand-written.

``tools/gen_capability_table.py`` renders the backend-capability matrix by
querying every registered backend's ``supports()`` over a canonical
scenario grid and splices it between README markers.  This test regenerates
the table and diffs it against the README, so either editing the table by
hand or regressing a previously-green ``supports()`` row fails the suite.
"""
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def test_readme_capability_table_in_sync():
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "gen_capability_table.py"),
         "--check"],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, (
        f"README capability table drifted from supports():\n"
        f"{proc.stdout}{proc.stderr}")


def test_generator_marks_scan_rows_green():
    """The tentpole rows must render as supported for the scan backend --
    a supports() regression flips the rendered cell and trips the README
    check, but assert it directly too so the failure names the row."""
    sys.path.insert(0, str(REPO / "tools"))
    try:
        import gen_capability_table as gen
    finally:
        sys.path.pop(0)
    table = gen.render_table()
    closed = (
        "ours, single node, cold starts",
        "hedging x failures",
        "hedging x autoscaling",
        "hetero x failures x hedging",
    )
    for row in table.splitlines():
        if any(label in row for label in closed):
            assert row.rstrip().endswith("| yes |"), row
