"""Optional-hypothesis shim for the property-based tests.

When ``hypothesis`` is installed, this module re-exports the real
``given`` / ``settings`` / ``strategies``.  When it is not (the container
does not bake it in, and tier-1 must not die at import), a deterministic
mini-implementation takes over: each strategy knows how to draw an example
from a seeded ``random.Random``, and ``given`` runs the test body over
``max_examples`` drawn samples.  The property sweeps keep running either
way -- only shrinking and coverage-guided generation are lost.
"""

from __future__ import annotations

HAVE_HYPOTHESIS = True
try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import random

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def integers(min_value=0, max_value=100, **_kw):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def lists(elements, min_size=0, max_size=10, **_kw):
            return _Strategy(lambda rng: [
                elements.example(rng)
                for _ in range(rng.randint(min_size, max_size))
            ])

        @staticmethod
        def tuples(*elements):
            return _Strategy(lambda rng: tuple(e.example(rng)
                                               for e in elements))

        @staticmethod
        def sampled_from(seq):
            items = list(seq)
            return _Strategy(lambda rng: items[rng.randrange(len(items))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.getrandbits(1)))

    st = _Strategies()

    def settings(max_examples: int = 25, **_kw):
        """Records max_examples on the test function; other hypothesis
        options (deadline, ...) are accepted and ignored."""
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        """Run the wrapped test over deterministically drawn examples."""
        def deco(fn):
            n = getattr(fn, "_shim_max_examples", 25)

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = random.Random(0xC0FFEE)
                for _ in range(n):
                    drawn = tuple(s.example(rng) for s in strategies)
                    fn(*args, *drawn, **kwargs)
            # pytest introspects signatures through __wrapped__ and would
            # mistake the strategy parameters for fixtures
            del wrapper.__wrapped__
            return wrapper
        return deco


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
