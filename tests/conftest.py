import sys
from pathlib import Path

# NOTE: deliberately no XLA_FLAGS here -- smoke tests and benches must see
# the single real CPU device; only launch/dryrun.py forces 512 host devices.
SRC = Path(__file__).resolve().parent.parent / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))
