"""Synthesizer-fit regression: the Azure-calibrated workload model stays
faithful to the vendored slice.

Contracts under test (thresholds are the documented fit budget -- the
measured values sit well inside them, see ``SynthModel.fit_report``):

* K-S statistic on the inter-arrival marginal (synth vs expanded trace)
  <= 0.05;
* K-S statistic on the duration marginal <= 0.05;
* Spearman rank correlation between synthesized and traced per-function
  invocation counts >= 0.90;
* generation is bit-deterministic per seed and re-iterable (chunk
  factories can be consumed twice);
* :func:`expand_catalog` extrapolates the popularity tail with the
  fitted Zipf decay and preserves the measured head.
"""

from pathlib import Path

import numpy as np
import pytest

from repro.core.synth import (
    SynthModel,
    expand_catalog,
    fit_azure_csv,
    fit_azure_trace,
    ks_statistic,
    spearman_rank,
)
from repro.core.traces import load_azure_trace

SLICE = Path(__file__).resolve().parent.parent / "data" / "azure_trace_slice.csv"

KS_IAT_MAX = 0.05
KS_DURATION_MAX = 0.05
SPEARMAN_MIN = 0.90


@pytest.fixture(scope="module")
def model():
    return fit_azure_csv(SLICE)


@pytest.fixture(scope="module")
def trace():
    return load_azure_trace(SLICE)


class TestFit:
    def test_fit_shape(self, model, trace):
        assert len(model.fns) == len(trace)
        assert model.popularity.sum() == pytest.approx(1.0)
        # popularity is rank-ordered descending
        assert np.all(np.diff(model.popularity) <= 1e-12)
        assert 0.1 <= model.zipf_alpha <= 4.0
        # arrival mass is conserved: sum of minute rates == total count
        assert model.minute_rate.sum() == pytest.approx(
            sum(sum(v) for v in trace.values()))

    def test_fit_report_under_thresholds(self, model, trace):
        rep = model.fit_report(trace, seed=0, cycles=4)
        assert rep["ks_iat"] <= KS_IAT_MAX, rep
        assert rep["ks_duration"] <= KS_DURATION_MAX, rep
        assert rep["popularity_spearman"] >= SPEARMAN_MIN, rep
        assert rep["n_synth"] > 0 and rep["n_ref"] > 0

    def test_empty_trace_rejected(self):
        with pytest.raises(ValueError):
            fit_azure_trace({"f": [0, 0]})


class TestGeneration:
    def test_deterministic_per_seed_and_reiterable(self, model):
        s = model.stream(seed=7, minutes=8)
        first = list(s.iter_chunks())
        second = list(s.iter_chunks())   # same stream object, re-iterated
        other = list(model.stream(seed=7, minutes=8).iter_chunks())
        assert len(first) == len(second) == len(other) > 0
        for a, b, c in zip(first, second, other):
            for x in (b, c):
                assert np.array_equal(a.r, x.r)
                assert np.array_equal(a.fn, x.fn)
                assert np.array_equal(a.p, x.p)

    def test_seed_changes_stream(self, model):
        a = next(iter(model.stream(seed=1, minutes=4).iter_chunks()))
        b = next(iter(model.stream(seed=2, minutes=4).iter_chunks()))
        assert not (a.r.size == b.r.size and np.array_equal(a.r, b.r))

    def test_chunks_sorted_and_bounded(self, model):
        total = 0
        last = -np.inf
        for ch in model.stream(seed=3, max_invocations=500).iter_chunks():
            assert np.all(np.diff(ch.r) >= 0)
            assert ch.r.size and ch.r[0] >= last
            last = ch.r[-1]
            assert np.all(ch.p >= 1e-4)
            total += ch.r.size
        assert total == 500

    def test_stream_requires_bound(self, model):
        with pytest.raises(ValueError):
            model.stream(seed=0)


class TestExpandCatalog:
    def test_head_preserved_tail_decays(self, model):
        big = expand_catalog(model, 500)
        assert len(big.fns) == 500
        assert big.fns[:len(model.fns)] == model.fns
        head = big.popularity[:len(model.fns)]
        np.testing.assert_allclose(head / head.sum(), model.popularity,
                                   rtol=1e-12)
        tail = big.popularity[len(model.fns):]
        assert np.all(np.diff(tail) <= 1e-15)
        assert tail[0] <= big.popularity[len(model.fns) - 1]

    def test_rate_scale(self, model):
        big = expand_catalog(model, 100, rate_scale=3.0)
        assert big.mean_rate_per_s == pytest.approx(
            3.0 * model.mean_rate_per_s)

    def test_tail_functions_generate(self, model):
        big = expand_catalog(model, 64, rate_scale=2.0)
        ch = next(iter(big.stream(seed=5, minutes=3).iter_chunks()))
        assert ch.fn.max() < 64

    def test_shrinking_rejected(self, model):
        with pytest.raises(ValueError):
            expand_catalog(model, 3)


class TestMetrics:
    def test_ks_statistic(self):
        rng = np.random.default_rng(0)
        a = rng.normal(size=4000)
        assert ks_statistic(a, rng.normal(size=4000)) < 0.05
        assert ks_statistic(a, rng.normal(3.0, size=4000)) > 0.5
        assert ks_statistic(a, np.array([])) == 1.0

    def test_spearman(self):
        x = np.arange(50.0)
        assert spearman_rank(x, 3 * x + 1) == pytest.approx(1.0)
        assert spearman_rank(x, -x) == pytest.approx(-1.0)
        assert abs(spearman_rank(x, np.ones(50))) == 0.0
