"""Cluster-scale scan backend: multi-node kernel parity, bucketed compile
cache, and sweep-engine batch dispatch.

Contracts under test:

* the scan kernel reproduces the reference :class:`Cluster` (pull model:
  any policy; push model: least-loaded/home for everything but FC) within
  ``CLUSTER_XCHECK_RTOL`` in the always-warm regime -- typical cells are at
  float32 rounding;
* the compilation cache is keyed by padded bucket shape: re-running a sweep
  reuses compiled runners (hits grow, misses do not);
* ``run_sweep`` dispatches scan-backend cells as bucketed batches and its
  results match the per-cell reference engines;
* eligibility rules reject what the kernel cannot model (push-FC, partial
  warm-up, autoscaling/failures), and ``simulate_cluster(backend=...)``
  raises/falls back accordingly.
"""

import pytest

from repro.core import (
    ClusterConfig,
    SweepCell,
    SweepSpec,
    cluster_scan_eligible,
    generate_burst,
    home_invoker_index,
    least_loaded_index,
    most_free_index,
    run_cell,
    run_cells_scan,
    run_sweep,
    scan_cache_clear,
    scan_cache_stats,
    simulate_cluster,
    summarize,
)
from repro.core.fastpath import (
    CLUSTER_CONTAINER_MB,
    CLUSTER_MEMORY_MB,
    simulate_cluster_cells_scan,
)
from repro.core.sweep import CLUSTER_XCHECK_RTOL

try:
    import jax  # noqa: F401
    HAVE_JAX = True
except ImportError:
    HAVE_JAX = False

needs_jax = pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")

POLICIES = ("fifo", "sept", "eect", "rect", "fc")
SMALL = dict(nodes=2, cores=6, intensity=15)


def _burst(nodes=2, cores=6, intensity=15, seed=0):
    return generate_burst(cores=nodes * cores, intensity=intensity, seed=seed)


def _metrics(res):
    s = summarize(res.requests)
    return {"R_avg": s.response_avg, "R_p50": s.response_pct[50],
            "R_p95": s.response_pct[95], "S_avg": s.stretch_avg,
            "max_c": s.max_completion, "n": s.n}


def _worst_rel(a, b):
    return max(abs(a[k] - b[k]) / max(abs(a[k]), abs(b[k]), 1e-9) for k in a)


class TestEligibility:
    def test_pull_any_policy(self):
        reqs = _burst()
        for policy in POLICIES:
            assert cluster_scan_eligible(reqs, 2, 6, policy)

    def test_push_accepts_all_policies(self):
        """Push-FC is modelled with per-(node, fn) count rings, so the full
        5-policy x {pull, push-LL, push-home} matrix is scan-eligible."""
        reqs = _burst()
        for policy in POLICIES:
            for lb in ("least_loaded", "home"):
                assert cluster_scan_eligible(reqs, 2, 6, policy,
                                             assignment="push", lb=lb)
        assert not cluster_scan_eligible(reqs, 2, 6, "sept",
                                         assignment="push", lb="round_robin")

    def test_partial_warmup_ineligible(self):
        """18-core nodes overflow the 40 GB warm-up for the full SeBS set
        (the paper's fig6 sizing) -- outside the always-warm regime."""
        reqs = _burst(cores=18)
        assert not cluster_scan_eligible(reqs, 2, 18, "fc")

    def test_cold_regime_eligibility(self):
        """warm=False is in-matrix in the ample-memory prewarm regime; a
        tight pool (evictions-for-memory reachable) stays reference-only."""
        assert cluster_scan_eligible(_burst(), 2, 6, "fc", warm=False)
        assert not cluster_scan_eligible(_burst(), 2, 6, "fc", warm=False,
                                         memory_mb=512)

    def test_defaults_mirror_cluster_config(self):
        """fastpath's eligibility constants must track ClusterConfig, or the
        scan path would judge warm-up against the wrong node size."""
        cfg = ClusterConfig()
        assert CLUSTER_MEMORY_MB == cfg.memory_mb
        assert CLUSTER_CONTAINER_MB == cfg.container_mb


class TestRoutingFunctions:
    """The pure controller-routing functions the scan kernel mirrors."""

    def test_least_loaded_first_on_ties(self):
        assert least_loaded_index([2, 1, 1]) == 1
        assert least_loaded_index([0, 0]) == 0

    def test_most_free_first_on_ties(self):
        assert most_free_index([0, 3, 3]) == 1
        assert most_free_index([1]) == 0

    def test_home_walks_to_free_else_stays(self):
        fn = "graph-bfs"
        from repro.core import stable_hash
        home = stable_hash(fn) % 3
        assert home_invoker_index(fn, [1, 1, 1]) == home
        blocked = [1, 1, 1]
        blocked[home] = 0
        assert home_invoker_index(fn, blocked) == (home + 1) % 3
        assert home_invoker_index(fn, [0, 0, 0]) == home


@needs_jax
class TestClusterScanParity:
    @pytest.mark.parametrize("policy", POLICIES)
    def test_pull_matches_reference(self, policy):
        ref = simulate_cluster(_burst(), nodes=2, cores_per_node=6,
                               policy=policy)
        scan = simulate_cluster_cells_scan([(_burst(), 2, 6, policy)])[0]
        assert _worst_rel(_metrics(ref), _metrics(scan)) < CLUSTER_XCHECK_RTOL

    @pytest.mark.parametrize("lb", ("least_loaded", "home"))
    def test_push_matches_reference(self, lb):
        for policy in ("fifo", "sept", "rect"):
            ref = simulate_cluster(_burst(seed=1), nodes=2, cores_per_node=6,
                                   policy=policy, assignment="push", lb=lb)
            scan = simulate_cluster_cells_scan(
                [(_burst(seed=1), 2, 6, policy, "push", lb)])[0]
            assert _worst_rel(_metrics(ref), _metrics(scan)) \
                < CLUSTER_XCHECK_RTOL

    def test_pull_eect_equals_sept(self):
        """Documented pull-model identity: EECT ranks by now + E[p] with a
        shared `now`, so it orders exactly like SEPT -- in the reference and
        therefore in the scan coefficients too."""
        a = simulate_cluster(_burst(), nodes=2, cores_per_node=6,
                             policy="sept")
        b = simulate_cluster(_burst(), nodes=2, cores_per_node=6,
                             policy="eect")
        assert _metrics(a) == _metrics(b)

    def test_batch_preserves_cell_order(self):
        batch = [(_burst(seed=s), 2, 6, p)
                 for s in (0, 1) for p in ("fifo", "sept")]
        results = simulate_cluster_cells_scan(batch)
        assert len(results) == 4
        for (reqs, nodes, cores, policy), res in zip(batch, results):
            assert res.meta["policy"] == policy
            assert res.meta["nodes"] == nodes
            assert res.requests is reqs

    def test_deterministic(self):
        a = simulate_cluster_cells_scan([(_burst(), 3, 6, "fc")])[0]
        b = simulate_cluster_cells_scan([(_burst(), 3, 6, "fc")])[0]
        assert _metrics(a) == _metrics(b)

    def test_requests_spread_across_nodes(self):
        res = simulate_cluster_cells_scan([(_burst(nodes=3), 3, 6, "fc")])[0]
        assert {r.node for r in res.requests} == {"node0", "node1", "node2"}

    def test_ineligible_batch_raises(self):
        with pytest.raises(ValueError, match="ours regime"):
            simulate_cluster_cells_scan(
                [(_burst(), 2, 6, "fc", "push", "round_robin")])


@needs_jax
class TestSimulateClusterBackend:
    def test_scan_backend_matches_reference(self):
        ref = simulate_cluster(_burst(), nodes=2, cores_per_node=6,
                               policy="fc", backend="reference")
        scan = simulate_cluster(_burst(), nodes=2, cores_per_node=6,
                                policy="fc", backend="scan")
        assert scan.meta["backend"] == "scan"
        assert _worst_rel(_metrics(ref), _metrics(scan)) < CLUSTER_XCHECK_RTOL

    def test_scan_strict_raises_outside_regime(self):
        with pytest.raises(ValueError, match="ours regime"):
            simulate_cluster(_burst(cores=18), nodes=2, cores_per_node=18,
                             policy="fc", backend="scan")

    def test_auto_falls_back(self):
        res = simulate_cluster(_burst(cores=18), nodes=2, cores_per_node=18,
                               policy="fc", backend="auto")
        assert len(res.requests) == len(_burst(cores=18))

    def test_extra_kwargs_force_reference(self):
        res = simulate_cluster(_burst(), nodes=2, cores_per_node=6,
                               policy="fc", backend="auto",
                               backup_requests=True)
        assert res.meta.get("backend") != "scan"

    def test_unknown_backend_raises(self):
        with pytest.raises(ValueError, match="unknown cluster backend"):
            simulate_cluster(_burst(), nodes=2, backend="warp")


@needs_jax
class TestSweepBatching:
    def _spec(self, **kw):
        base = dict(policies=("fifo", "fc"), nodes=(1, 2), cores=(6,),
                    intensities=(15,), seeds=2, backends=("scan",))
        base.update(kw)
        return SweepSpec(**base)

    def test_run_sweep_batches_scan_cells(self):
        res = run_sweep(self._spec(), workers=1)
        assert res.meta["scan_batched"] == len(res)
        ref = run_sweep(self._spec(backends=("reference",)), workers=1)
        for a, b in zip(res.results, ref.results):
            assert abs(a.metrics["R_avg"] - b.metrics["R_avg"]) \
                <= CLUSTER_XCHECK_RTOL * b.metrics["R_avg"]

    def test_batched_sweep_deterministic(self):
        a = run_sweep(self._spec(), workers=1)
        b = run_sweep(self._spec(), workers=1)
        assert [c.metrics for c in a.results] == \
            [c.metrics for c in b.results]

    def test_mixed_grid_falls_back_per_cell(self):
        """Baseline cells are never scan-batchable; they run through
        run_cell and land in the right output slots."""
        spec = self._spec(policies=("baseline", "fc"), nodes=(2,))
        res = run_sweep(spec, workers=1)
        assert res.meta["scan_batched"] == 2          # the fc seed-group
        by_policy = {r["policy"]: r for r in res.aggregate()}
        ref = run_cell(SweepCell(policy="baseline", mode="baseline",
                                 nodes=2, cores=6, intensity=15, seed=0))
        assert by_policy["baseline"]["seeds"] == 2
        assert by_policy["baseline"]["R_avg"] > 0
        assert ref["R_avg"] > 0

    def test_run_cells_scan_strict_false_degrades(self):
        """Partial-warm-up cells degrade to run_cell and are *counted*:
        the degraded column marks them, eligible cells carry none."""
        cells = [SweepCell(policy="fc", nodes=2, cores=18, intensity=15,
                           seed=0),
                 SweepCell(policy="fc", nodes=2, cores=6, intensity=15,
                           seed=0)]
        ms = run_cells_scan(cells, strict=False)
        assert ms[0].pop("degraded") == 1.0
        assert ms[0] == run_cell(cells[0])
        assert ms[1]["n"] > 0
        assert "degraded" not in ms[1]


@needs_jax
class TestCompileCache:
    def test_bucket_reuse_across_sweeps(self):
        """The acceptance contract: a second run_sweep over the same grid
        shapes compiles nothing new -- every bucket dispatch is a cache hit."""
        scan_cache_clear()
        spec = SweepSpec(policies=("fifo", "sept"), nodes=(2,), cores=(6,),
                         intensities=(15,), seeds=2, backends=("scan",))
        run_sweep(spec, workers=1)
        first = scan_cache_stats()
        assert first["misses"] >= 1
        run_sweep(spec, workers=1)
        second = scan_cache_stats()
        assert second["misses"] == first["misses"]    # no recompile
        assert second["hits"] > first["hits"]
        assert second["size"] == first["misses"]

    def test_bucket_shapes_are_padded_pow2(self):
        from repro.core.fastpath import (
            _ScanCell,
            _arrival_features,
            _mask_features,
        )
        reqs = _burst()
        cell = _ScanCell(requests=reqs, feats=_arrival_features(reqs),
                         cores=6, nodes=3, policy="fc", assignment="pull")
        (mask, n_b, nodes_b, slots_b, f_b, kq, window, fc_ring, n_ep,
         n_copies, xtra) = cell.bucket()
        flags = _mask_features(mask)
        assert not flags["freeze"] and flags["use_fc"]
        assert not flags["fc_push"] and not flags["cold"]
        assert not any(flags[k] for k in ("dyn", "het", "hedge", "dup"))
        assert xtra == 0 and n_copies == 1
        for v in (n_b, nodes_b, slots_b, f_b, kq):
            assert v & (v - 1) == 0                   # powers of two
        assert n_b >= len(reqs) and nodes_b >= 3 and slots_b >= 6

    def test_clear_resets(self):
        scan_cache_clear()
        assert scan_cache_stats() == {"hits": 0, "misses": 0, "size": 0,
                                      "entries": {}}


@needs_jax
class TestClusterCrossCheck:
    def test_validate_samples_scan_cluster_cells(self):
        spec = SweepSpec(policies=("fc",), nodes=(2,), cores=(6,),
                         intensities=(15,), seeds=2, backends=("scan",),
                         validate="cross-check")
        cells = spec.cells()
        assert all(c.cross_check for c in cells)
        res = run_sweep(spec, workers=1)
        errs = [cr.metrics["xcheck_err"] for cr in res.results]
        assert len(errs) == 2
        assert max(errs) <= CLUSTER_XCHECK_RTOL

    def test_single_node_scan_only_axis_still_rejected(self):
        """Without cluster cells a scan-only axis has nothing to validate
        against (single-node scan parity lives in test_fastpath)."""
        with pytest.raises(ValueError, match="vectorized backend"):
            SweepSpec(backends=("scan",), validate="cross-check").cells()
