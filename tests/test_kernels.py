"""Pallas kernel validation: interpret-mode sweep vs pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.rwkv6_scan import rwkv6_scan

RNG = np.random.default_rng(0)


def _arr(*shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-3, atol=2e-3)


class TestFlashAttention:
    @pytest.mark.parametrize("B,S,Hq,Hkv,dh", [
        (1, 128, 4, 4, 64),      # MHA
        (2, 256, 8, 2, 64),      # GQA 4:1
        (1, 256, 4, 1, 128),     # MQA
        (1, 512, 2, 2, 32),      # long seq, small heads
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal_matches_ref(self, B, S, Hq, Hkv, dh, dtype):
        q = _arr(B, S, Hq, dh, dtype=dtype)
        k = _arr(B, S, Hkv, dh, dtype=dtype)
        v = _arr(B, S, Hkv, dh, dtype=dtype)
        out = flash_attention(q, k, v, causal=True, interpret=True)
        exp = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(exp, np.float32),
            **_tol(dtype))

    @pytest.mark.parametrize("window", [32, 64, 128])
    def test_sliding_window(self, window):
        q, k, v = (_arr(1, 256, 4, 64) for _ in range(3))
        out = flash_attention(q, k, v, causal=True, window=window,
                              interpret=True)
        exp = ref.attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=2e-3, atol=2e-3)

    def test_bidirectional(self):
        q, k, v = (_arr(1, 128, 2, 64) for _ in range(3))
        out = flash_attention(q, k, v, causal=False, interpret=True)
        exp = ref.attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=2e-3, atol=2e-3)

    def test_cross_lengths(self):
        """Sq != Sk (suffix-aligned decode-like block)."""
        q = _arr(1, 128, 2, 64)
        k = _arr(1, 256, 2, 64)
        v = _arr(1, 256, 2, 64)
        out = flash_attention(q, k, v, causal=True, interpret=True)
        exp = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=2e-3, atol=2e-3)

    @pytest.mark.parametrize("bq,bk", [(64, 64), (128, 256), (256, 128)])
    def test_block_shape_invariance(self, bq, bk):
        q, k, v = (_arr(1, 512, 2, 64) for _ in range(3))
        out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                              interpret=True)
        exp = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=2e-3, atol=2e-3)


class TestDecodeAttention:
    @pytest.mark.parametrize("B,Sk,Hq,Hkv,dh", [
        (2, 256, 4, 4, 64),
        (4, 512, 8, 2, 64),
        (1, 1024, 4, 1, 128),
    ])
    def test_matches_ref(self, B, Sk, Hq, Hkv, dh):
        q = _arr(B, Hq, dh)
        k = _arr(B, Sk, Hkv, dh)
        v = _arr(B, Sk, Hkv, dh)
        lengths = jnp.asarray(RNG.integers(1, Sk + 1, B), jnp.int32)
        out = decode_attention(q, k, v, lengths, interpret=True)
        exp = ref.decode_attention_ref(q, k, v, lengths)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=2e-3, atol=2e-3)

    def test_ragged_lengths_mask_tail(self):
        """Entries beyond ``lengths`` must not affect the result."""
        q = _arr(1, 2, 64)
        k = _arr(1, 256, 2, 64)
        v = _arr(1, 256, 2, 64)
        lengths = jnp.array([100], jnp.int32)
        out1 = decode_attention(q, k, v, lengths, interpret=True)
        k2 = k.at[:, 100:].set(99.0)
        v2 = v.at[:, 100:].set(-99.0)
        out2 = decode_attention(q, k2, v2, lengths, interpret=True)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                                   rtol=1e-6, atol=1e-6)


class TestRGLRU:
    @pytest.mark.parametrize("B,S,W", [(1, 256, 512), (2, 512, 1024),
                                       (1, 128, 2048)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, B, S, W, dtype):
        a = jnp.asarray(RNG.uniform(0.8, 0.999, (B, S, W)), dtype)
        gx = _arr(B, S, W, dtype=dtype, scale=0.1)
        h0 = _arr(B, W, dtype=dtype, scale=0.1)
        hs, hT = rglru_scan(a, gx, h0, interpret=True)
        ehs, ehT = ref.rglru_ref(a, gx, h0)
        tol = _tol(dtype)
        np.testing.assert_allclose(np.asarray(hs, np.float32),
                                   np.asarray(ehs, np.float32), **tol)
        np.testing.assert_allclose(np.asarray(hT, np.float32),
                                   np.asarray(ehT, np.float32), **tol)

    def test_block_shape_invariance(self):
        a = jnp.asarray(RNG.uniform(0.9, 0.999, (1, 256, 512)), jnp.float32)
        gx = _arr(1, 256, 512, scale=0.1)
        h0 = _arr(1, 512, scale=0.1)
        hs1, _ = rglru_scan(a, gx, h0, block_w=128, block_t=64, interpret=True)
        hs2, _ = rglru_scan(a, gx, h0, block_w=512, block_t=256,
                            interpret=True)
        np.testing.assert_allclose(np.asarray(hs1), np.asarray(hs2),
                                   rtol=1e-5, atol=1e-5)


class TestRWKV6:
    @pytest.mark.parametrize("B,S,H,dh", [(1, 128, 2, 64), (2, 256, 4, 32)])
    def test_matches_ref(self, B, S, H, dh):
        r = _arr(B, S, H, dh)
        k = _arr(B, S, H, dh, scale=0.2)
        v = _arr(B, S, H, dh, scale=0.2)
        w = jnp.asarray(RNG.uniform(0.9, 0.999, (B, S, H, dh)), jnp.float32)
        u = _arr(H, dh, scale=0.1)
        out = rwkv6_scan(r, k, v, w, u, interpret=True)
        s0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        exp, _ = ref.rwkv6_ref(r, k, v, w, u, s0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=5e-3, atol=5e-3)

    def test_decay_forgets_distant_past(self):
        """With strong decay, early tokens stop influencing late outputs."""
        B, S, H, dh = 1, 64, 1, 32
        r = _arr(B, S, H, dh)
        k = _arr(B, S, H, dh, scale=0.2)
        v = _arr(B, S, H, dh, scale=0.2)
        w = jnp.full((B, S, H, dh), 0.1, jnp.float32)    # fast decay
        u = _arr(H, dh, scale=0.1)
        out1 = rwkv6_scan(r, k, v, w, u, interpret=True)
        k2 = k.at[:, 0].add(5.0)
        out2 = rwkv6_scan(r, k2, v, w, u, interpret=True)
        np.testing.assert_allclose(np.asarray(out1[:, -1]),
                                   np.asarray(out2[:, -1]),
                                   rtol=1e-3, atol=1e-3)


class TestModelIntegration:
    def test_flash_matches_model_attention(self):
        """The kernel agrees with the model's chunked-jnp attention path."""
        from repro.models.layers import attention
        q, k, v = (_arr(1, 256, 4, 64) for _ in range(3))
        qpos = jnp.broadcast_to(jnp.arange(256, dtype=jnp.int32), (1, 256))
        model_out = attention(q, k, v, qpos, qpos, causal=True, q_chunk=64)
        kern_out = flash_attention(q, k, v, causal=True, interpret=True)
        np.testing.assert_allclose(np.asarray(model_out),
                                   np.asarray(kern_out),
                                   rtol=2e-3, atol=2e-3)
