"""Fused event-step megakernel: Pallas parity, metrics-only parity, the
chunk auto-tuner, and the scan-path observability hooks.

Contracts under test:

* the Pallas megakernel (``repro.kernels.event_step``) is **bit-identical**
  to the pure-jnp oracle on rows ``[:n]`` for every supported combination
  (base pull, with and without FC pull counts), running under
  ``interpret=True`` on CPU; unsupported combinations refuse
  ``force="pallas"`` loudly instead of silently falling back;
* ``run_cells_scan(metrics_only=True)`` rows are exactly equal to the
  write-back rows across the whole supported feature matrix -- including
  the failure / backup / steal counters;
* the chunk auto-tuner runs once per bucket shape, persists its choice on
  the cache entry (visible in ``scan_cache_stats()``), and repeated asks
  are memoized no-ops -- the determinism contract;
* degraded (ineligible) cells under ``strict=False`` do not churn the
  compile cache: batch-size variation folds into one entry per shape;
* ``scan_bucket_timings()`` records every dispatched chunk and
  ``REPRO_SCAN_PROFILE=1`` dumps one jax.profiler trace.
"""

import sys
from functools import partial
from pathlib import Path

import numpy as np
import pytest

from repro.core import (
    SweepCell,
    run_cell,
    run_cells_scan,
    scan_bucket_timings,
    scan_cache_clear,
    scan_cache_stats,
    scan_timings_clear,
)

try:
    import jax  # noqa: F401
    import jax.numpy as jnp
    HAVE_JAX = True
except ImportError:
    HAVE_JAX = False

needs_jax = pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")

ROOT = Path(__file__).resolve().parent.parent
if str(ROOT) not in sys.path:
    sys.path.insert(0, str(ROOT))


BASE_FLAGS = dict(freeze=False, use_fc=False, fc_push=False, dyn=False,
                  het=False, hedge=False, cold=False, dup=False)


def _smoke_inputs(use_fc, B=3, n=8, F=2, NN=2, NS=4, W=4, KQ=8, seed=0):
    """Small hand-built bucket: sorted arrivals, warm-seeded estimator ring,
    three coefficient rows (FIFO / SEPT / FC-ish) so every dispatch branch
    of the kernel is exercised."""
    rng = np.random.default_rng(seed)
    n1 = n + 1
    inp = {
        "t": np.full((B, n1), np.inf, dtype=np.float32),
        "fnid": np.zeros((B, n1), dtype=np.int32),
        "p": np.zeros((B, n1), dtype=np.float32),
        "cost": np.zeros((B, n1), dtype=np.float32),
        "cnt": np.zeros((B, n1), dtype=np.float32),
        "home0": np.zeros((B, n1), dtype=np.int32),
        "coef": np.zeros((B, 5), dtype=np.float32),
        "cores": np.zeros(B, dtype=np.int32),
        "nodes": np.ones(B, dtype=np.int32),
        "route": np.zeros(B, dtype=np.int32),
        "ring0": np.zeros((B, 1, F, W), dtype=np.float32),
        "rsum0": np.zeros((B, 1, F), dtype=np.float32),
        "rlen0": np.zeros((B, 1, F), dtype=np.int32),
        "rpos0": np.zeros((B, 1, F), dtype=np.int32),
        "cumf": np.zeros((B, n1 if use_fc else 1, F), dtype=np.float32),
        "fn_ev": np.full((B, F, KQ), n, dtype=np.int32),
    }
    coefs = [[1.0, 0.0, 0.0, 0.0, 0.0],      # FIFO
             [0.0, 0.0, 1.0, 0.0, 0.0],      # SEPT
             [0.0, 0.0, 1.0, 0.3, 0.0]]      # FC-ish
    for b in range(B):
        t = np.sort(rng.uniform(0, 2.0, n)).astype(np.float32)
        fn = rng.integers(0, F, n).astype(np.int32)
        inp["t"][b, :n] = t
        inp["fnid"][b, :n] = fn
        inp["p"][b, :n] = rng.lognormal(-1, 0.5, n).astype(np.float32)
        inp["cost"][b, :n] = 0.001
        inp["coef"][b] = coefs[b % len(coefs)]
        inp["cores"][b] = 1 + (b % 2)
        inp["nodes"][b] = 1 + b % NN
        inp["ring0"][b, 0, :, 0] = 0.5
        inp["rsum0"][b, 0, :] = 0.5
        inp["rlen0"][b, 0, :] = 1
        if use_fc:
            for f in range(F):
                inp["cumf"][b, 1:, f] = np.cumsum(fn == f)
        for f in range(F):
            ev = np.nonzero(fn == f)[0]
            inp["fn_ev"][b, f, :len(ev)] = ev
    # _make_planes takes the carry-shaping flags only; use_fc is a kernel
    # static that does not change the carry layout
    plane_flags = dict(n_nodes=NN, n_slots=NS, window=W, n_copies=1,
                      fc_ring=1,
                      **{k: v for k, v in BASE_FLAGS.items() if k != "use_fc"})
    static = dict(plane_flags, use_fc=use_fc, n_ep=1, horizon=1.0,
                  n_steps=2 * n + 2)
    return inp, plane_flags, static, n


@needs_jax
class TestPallasParity:
    """The megakernel is bit-identical to the jnp oracle (interpret=True)."""

    @pytest.mark.parametrize("use_fc", [False, True])
    def test_bit_identical_outputs(self, use_fc):
        from repro.core import fastpath as _fp
        from repro.kernels import ops

        inp, flags, static, n = _smoke_inputs(use_fc)
        arrs = {k: jnp.asarray(v) for k, v in inp.items()}
        clk, ctr = jax.vmap(partial(_fp._make_planes, **flags))(arrs)

        ref = ops.event_step(clk, ctr, arrs, force="ref", **static)
        pal = ops.event_step(clk, ctr, arrs, force="pallas",
                             interpret=True, **static)
        for name, a, b in zip(("start", "finish", "prio", "node"),
                              ref[:4], pal[:4]):
            # row n is the shared no-op sentinel both paths scribble into
            np.testing.assert_array_equal(
                np.asarray(a)[:, :n], np.asarray(b)[:, :n],
                err_msg=f"{name} diverged (use_fc={use_fc})")

    def test_supported_matrix(self):
        from repro.kernels.event_step import event_step_supported

        assert event_step_supported(**BASE_FLAGS)
        assert event_step_supported(**{**BASE_FLAGS, "use_fc": True})
        for feat in ("freeze", "fc_push", "dyn", "het", "hedge", "cold",
                     "dup"):
            assert not event_step_supported(**{**BASE_FLAGS, feat: True}), \
                feat

    def test_force_pallas_refuses_unsupported(self):
        from repro.core import fastpath as _fp
        from repro.kernels import ops

        inp, flags, static, _ = _smoke_inputs(False)
        arrs = {k: jnp.asarray(v) for k, v in inp.items()}
        clk, ctr = jax.vmap(partial(_fp._make_planes, **flags))(arrs)
        bad = dict(static, dyn=True)
        with pytest.raises(NotImplementedError):
            ops.event_step(clk, ctr, arrs, force="pallas", interpret=True,
                           **bad)


# every supports()=yes regime: base pull per policy, push, FC pull counts,
# capacity dynamics, heterogeneity + degradation, hedging, cold starts
PARITY_CELLS = [
    SweepCell(policy="fifo", nodes=2, cores=4, intensity=10, seed=0,
              backend="scan"),
    SweepCell(policy="sept", nodes=2, cores=4, intensity=10, seed=1,
              backend="scan"),
    SweepCell(policy="fc", nodes=2, cores=4, intensity=10, seed=2,
              backend="scan"),
    SweepCell(policy="sept", assignment="push", lb="least_loaded", nodes=2,
              cores=4, intensity=10, seed=3, backend="scan"),
    SweepCell(policy="sept", nodes=2, cores=4, intensity=10, seed=4,
              autoscale=True, backend="scan"),
    SweepCell(policy="sept", nodes=3, cores=4, intensity=10, seed=5,
              fail_spec=((1, 2.0),), backend="scan"),
    SweepCell(policy="sept", nodes=2, cores=4, intensity=10, seed=6,
              node_speeds=(1.0, 1.6),
              degrade=((1, 1.0, 3.0, 2.0),), backend="scan"),
    SweepCell(policy="sept", nodes=2, cores=4, intensity=10, seed=7,
              hedge_multiple=3.0, backend="scan"),
    SweepCell(policy="sept", nodes=2, cores=4, intensity=10, seed=8,
              warm=False, backend="scan"),
]


@needs_jax
class TestMetricsOnlyParity:
    def test_rows_exactly_equal_write_back(self):
        """metrics_only=True rows are bit-identical to the write-back rows
        across the supported feature matrix, including the lost / backup /
        steal counters (satellite contract of the mega sweep)."""
        wb = run_cells_scan(PARITY_CELLS, metrics_only=False)
        mo = run_cells_scan(PARITY_CELLS, metrics_only=True)
        for cell, a, b in zip(PARITY_CELLS, wb, mo):
            assert set(a) == set(b), cell.label()
            for k, v in a.items():
                assert b[k] == v, f"{cell.label()}: {k} {b[k]} != {v}"

    def test_workload_sharing_matches_unshared(self):
        """Cells differing only by policy share one burst under
        metrics_only -- and still match their individually-run rows."""
        cells = [SweepCell(policy=p, nodes=2, cores=4, intensity=10, seed=0,
                           backend="scan")
                 for p in ("fifo", "sept", "eect", "rect", "fc")]
        together = run_cells_scan(cells, metrics_only=True)
        for cell, row in zip(cells, together):
            solo = run_cells_scan([cell], metrics_only=True)[0]
            assert solo == row, cell.label()


@needs_jax
class TestAutotune:
    # tiny base-pull bucket: tuning compiles two candidate runners only
    KEY = (0x0, 16, 2, 4, 4, 4, 4, 1, 1, 1, 0)

    def test_tunes_once_and_memoizes(self, monkeypatch):
        from repro.core import fastpath as fp

        scan_cache_clear()
        monkeypatch.setattr(fp, "SCAN_AUTOTUNE", True)
        monkeypatch.setattr(fp, "SCAN_BATCH_MAX", 64)   # force a tune at 130
        calls = []
        real = fp._autotune_chunk

        def counting(key, n_cells):
            calls.append(key)
            return real(key, n_cells)

        monkeypatch.setattr(fp, "_autotune_chunk", counting)
        c1 = fp._bucket_chunk(self.KEY, 130)
        c2 = fp._bucket_chunk(self.KEY, 130)
        assert c1 == c2
        assert c1 in (128, 256)          # _pow2(130) caps the candidates
        assert len(calls) == 1           # second ask is a memoized no-op
        tag = fp._bucket_tag(self.KEY)
        assert scan_cache_stats()["entries"][tag]["chunk"] == c1
        scan_cache_clear()

    def test_no_tuning_below_default_chunk(self, monkeypatch):
        from repro.core import fastpath as fp

        scan_cache_clear()
        monkeypatch.setattr(fp, "SCAN_AUTOTUNE", True)
        monkeypatch.setattr(fp, "_autotune_chunk",
                            lambda *a: pytest.fail("tuned a small bucket"))
        assert fp._bucket_chunk(self.KEY, 64) == fp.SCAN_BATCH_MAX
        scan_cache_clear()

    def test_autotune_disabled(self, monkeypatch):
        from repro.core import fastpath as fp

        scan_cache_clear()
        monkeypatch.setattr(fp, "SCAN_AUTOTUNE", False)
        assert fp._bucket_chunk(self.KEY, 5000) == fp.SCAN_BATCH_MAX
        scan_cache_clear()


@needs_jax
class TestDegradedCacheChurn:
    def test_degraded_cells_do_not_churn_cache(self):
        """strict=False fallback cells never touch the scan cache, and
        batch-size variation folds into one entry per bucket shape --
        re-running a mixed grid adds hits, not misses (regression: degraded
        cells used to recompile per call)."""
        eligible = [SweepCell(policy="fifo", nodes=2, cores=4, intensity=10,
                              seed=s, backend="scan") for s in range(3)]
        degraded = SweepCell(policy="fc", nodes=2, cores=18, intensity=15,
                             seed=0, backend="scan")
        scan_cache_clear()
        ms = run_cells_scan(eligible + [degraded], strict=False,
                            metrics_only=True)
        assert ms[-1]["degraded"] == 1.0
        assert all("degraded" not in m for m in ms[:-1])
        s1 = scan_cache_stats()
        assert s1["misses"] > 0

        ms2 = run_cells_scan(eligible + [degraded], strict=False,
                             metrics_only=True)
        s2 = scan_cache_stats()
        assert ms2[:-1] == ms[:-1]
        assert s2["misses"] == s1["misses"]      # no recompiles
        assert s2["hits"] > s1["hits"]
        assert s2["size"] == s1["size"]

        # growing the batch folds into the same entry: one more compiled
        # runner (the new batch size), no new shape entry
        more = [SweepCell(policy="fifo", nodes=2, cores=4, intensity=10,
                          seed=s, backend="scan") for s in range(5)]
        run_cells_scan(more, metrics_only=True)
        s3 = scan_cache_stats()
        assert len(s3["entries"]) == len(s2["entries"])
        assert s3["size"] == s2["size"] + 1
        scan_cache_clear()


@needs_jax
class TestObservability:
    CELLS = [SweepCell(policy="fifo", nodes=2, cores=4, intensity=10,
                       seed=s, backend="scan") for s in range(2)]

    def test_bucket_timings_record_chunks(self):
        scan_timings_clear()
        run_cells_scan(self.CELLS, metrics_only=True)
        recs = scan_bucket_timings()
        assert recs
        assert sum(r["cells"] for r in recs) == len(self.CELLS)
        for r in recs:
            for k in ("bucket", "bsz", "cells", "build_s", "compile_s",
                      "dispatch_s", "sync_s"):
                assert k in r
        scan_timings_clear()
        assert scan_bucket_timings() == []

    def test_analyse_scan_buckets(self):
        from benchmarks.roofline import analyse_scan_buckets

        recs = [
            {"bucket": "a", "bsz": 4, "cells": 4, "build_s": 0.1,
             "compile_s": 1.0, "dispatch_s": 0.0, "sync_s": 0.2},
            {"bucket": "a", "bsz": 8, "cells": 6, "build_s": 0.1,
             "compile_s": 0.0, "dispatch_s": 0.0, "sync_s": 0.2},
            {"bucket": "b", "bsz": 4, "cells": 2, "build_s": 0.0,
             "compile_s": 0.0, "dispatch_s": 0.0, "sync_s": 0.1},
        ]
        out = analyse_scan_buckets(recs)
        assert [o["bucket"] for o in out] == ["a", "b"]   # by total desc
        a = out[0]
        assert a["cells"] == 10 and a["chunks"] == 2 and a["bsz"] == 8
        assert a["dominant"] == "compile_s"
        assert a["total_s"] == pytest.approx(1.6)
        assert a["cells_per_s"] == pytest.approx(10 / 1.6)

    def test_profile_trace_dump(self, monkeypatch, tmp_path):
        from repro.core import fastpath as fp

        monkeypatch.setattr(fp, "_SCAN_PROFILE_DONE", False)
        monkeypatch.setenv("REPRO_SCAN_PROFILE", "1")
        monkeypatch.setenv("REPRO_SCAN_PROFILE_DIR", str(tmp_path))
        run_cells_scan(self.CELLS, metrics_only=True)
        assert fp._SCAN_PROFILE_DONE
        assert any(tmp_path.rglob("*"))      # one trace, dumped once
