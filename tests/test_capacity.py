"""Dynamic-capacity engine core: CapacityTimeline, the backend capability
matrix, and autoscale / failure-injection parity between the reference event
loop and the scan kernel.

Contracts under test:

* :class:`CapacityTimeline` describes per-node activation/deactivation
  intervals; the reference :class:`Cluster` records one as it runs and the
  scan kernel reconstructs the *same* realized timeline from its activation
  tensors (activation times equal, kills equal).
* lost-request counts under ``fail_at`` are **bit-identical** between the
  two engines; metrics agree within ``CLUSTER_XCHECK_RTOL`` (dynamic
  buckets run in float64, so typical agreement is ~1e-6).
* the autoscaler respects ``max_nodes`` *including scheduled provisions*
  (no overshoot when the provision delay spans several tick intervals), and
  both engines provision identical fleets.
* ``supports(autoscale=, failures=)`` -- the capability matrix -- routes
  cells: the scan backend accepts dynamics on pull / push clusters, the
  single-node fast paths refuse them.
* the scan compile cache's LRU cap is env-tunable and eviction does not
  break batch dispatch.
"""

import math

import pytest

from repro.core import (
    CapacityTimeline,
    Cluster,
    ClusterConfig,
    ClusterDynamics,
    SweepCell,
    SweepSpec,
    cluster_scan_eligible,
    generate_burst,
    get_backend,
    run_cell,
    run_cells_scan,
    run_sweep,
    scan_cache_clear,
    scan_cache_stats,
    simulate_cluster,
    summarize,
)
from repro.core.sweep import CLUSTER_XCHECK_RTOL

from tests._hypothesis_shim import given, settings, st

try:
    import jax  # noqa: F401
    HAVE_JAX = True
except ImportError:
    HAVE_JAX = False

needs_jax = pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")


def _burst(cores=12, intensity=15, seed=0):
    return generate_burst(cores=cores, intensity=intensity, seed=seed)


def _metrics(res):
    s = summarize(res.requests)
    return {"R_avg": s.response_avg, "R_p95": s.response_pct[95],
            "S_avg": s.stretch_avg, "max_c": s.max_completion, "n": s.n}


def _worst_rel(a, b):
    return max(abs(a[k] - b[k]) / max(abs(a[k]), abs(b[k]), 1e-9) for k in a)


class TestCapacityTimeline:
    def test_static_fleet(self):
        tl = CapacityTimeline.static(3)
        assert tl.nodes_total == 3
        assert tl.activate == [0.0, 0.0, 0.0]
        assert tl.active_at(0.0) == [True, True, True]
        assert tl.count_active(100.0) == 3

    def test_fail_interval(self):
        tl = CapacityTimeline.static(2, fail=((0, 10.0),))
        assert tl.active_at(9.99) == [True, True]
        assert tl.active_at(10.0) == [False, True]   # [a, d) half-open
        assert tl.count_active(20.0) == 1

    def test_add_node_and_kill(self):
        tl = CapacityTimeline.static(1)
        idx = tl.add_node(25.0)
        assert idx == 1 and tl.count_active(20.0) == 1
        assert tl.count_active(25.0) == 2
        tl.kill(idx, 30.0)
        assert tl.active_at(30.0) == [True, False]

    def test_arrays_pad_with_inf(self):
        import numpy as np
        act, kill = CapacityTimeline.static(2, fail=((1, 5.0),)).arrays(4)
        assert act.tolist() == [0.0, 0.0, np.inf, np.inf]
        assert kill.tolist() == [np.inf, 5.0, np.inf, np.inf]

    def test_dynamics_capacity_bound(self):
        d = ClusterDynamics(autoscale=True, max_nodes=8)
        assert d.capacity_bound(2) == 8
        assert ClusterDynamics().capacity_bound(3) == 3
        assert ClusterDynamics().is_static
        assert not ClusterDynamics(fail=((0, 1.0),)).is_static


class TestReferenceTimeline:
    def test_cluster_records_static_timeline(self):
        res = simulate_cluster(_burst(), nodes=2, cores_per_node=6,
                               policy="fc")
        assert res.timeline.activate == [0.0, 0.0]
        assert res.timeline.deactivate == [math.inf, math.inf]

    def test_cluster_records_failure(self):
        res = simulate_cluster(_burst(), nodes=2, cores_per_node=6,
                               policy="fc", fail_at=10.0)
        assert res.timeline.deactivate[0] == 10.0
        assert res.failures > 0
        assert len(res.requests) == len(_burst())   # pull re-queues the lost

    def test_autoscaler_records_provisions(self):
        res = simulate_cluster(_burst(cores=10, intensity=90), nodes=1,
                               cores_per_node=10, policy="fc",
                               autoscale=True, provision_delay_s=15.0,
                               scale_up_queue_per_slot=1.0, max_nodes=4)
        tl = res.timeline
        assert tl.nodes_total == res.nodes_used > 1
        assert tl.activate[0] == 0.0
        assert all(a >= 15.0 for a in tl.activate[1:])  # provision delay
        assert tl.activate == sorted(tl.activate)

    def test_autoscaler_cap_counts_scheduled_provisions(self):
        """provision_delay spanning many tick intervals must not overshoot
        max_nodes: pending provisions count toward the cap."""
        res = simulate_cluster(_burst(cores=10, intensity=120), nodes=1,
                               cores_per_node=10, policy="fc",
                               autoscale=True, provision_delay_s=60.0,
                               autoscale_interval_s=2.0,
                               scale_up_queue_per_slot=0.5, max_nodes=3)
        assert res.nodes_used <= 3

    @settings(max_examples=8, deadline=None)
    @given(st.integers(min_value=20, max_value=90),
           st.floats(min_value=5.0, max_value=40.0),
           st.floats(min_value=0.5, max_value=4.0),
           st.integers(min_value=2, max_value=6))
    def test_autoscaler_invariants(self, intensity, delay, thr, max_nodes):
        """Property sweep (hypothesis or the deterministic shim): the
        autoscaled reference cluster never exceeds max_nodes, never shrinks
        below the initial fleet, serves every request, and its timeline is
        monotone with the provision delay respected."""
        reqs = _burst(cores=10, intensity=intensity, seed=intensity)
        res = simulate_cluster(reqs, nodes=1, cores_per_node=10, policy="fc",
                               autoscale=True, provision_delay_s=delay,
                               scale_up_queue_per_slot=thr,
                               max_nodes=max_nodes)
        assert 1 <= res.nodes_used <= max_nodes
        assert len(res.requests) == len(reqs)
        tl = res.timeline
        assert tl.nodes_total == res.nodes_used
        assert tl.activate == sorted(tl.activate)
        assert all(a >= delay for a in tl.activate[1:])


class TestCapabilityMatrix:
    def test_reference_supports_everything(self):
        be = get_backend("reference")
        assert be.supports(mode="baseline", policy="fifo", warm=False,
                           nodes=8, autoscale=True, failures=True)

    def test_vectorized_rejects_dynamics(self):
        be = get_backend("vectorized")
        assert be.supports(mode="ours", policy="fc", warm=True)
        assert not be.supports(mode="ours", policy="fc", warm=True,
                               autoscale=True)
        assert not be.supports(mode="ours", policy="fc", warm=True,
                               failures=True)

    @needs_jax
    def test_scan_capability_matrix(self):
        be = get_backend("scan")
        for assignment in ("pull", "push"):
            for policy in ("fifo", "sept", "eect", "rect", "fc"):
                assert be.supports(mode="ours", policy=policy, warm=True,
                                   nodes=4, assignment=assignment,
                                   autoscale=True, failures=True)
        # failures need a surviving node
        assert not be.supports(mode="ours", policy="fc", warm=True,
                               nodes=1, failures=True)
        assert not be.supports(mode="baseline", policy="fifo", warm=True,
                               nodes=4, autoscale=True)
        # the cold regime is in-matrix since the capability close
        assert be.supports(mode="ours", policy="fc", warm=False, nodes=4)

    @needs_jax
    def test_eligibility_rejects_unsupported_dynamics(self):
        reqs = _burst()
        dyn = ClusterDynamics(fail=((0, 5.0), (1, 6.0)))
        # killing the whole initial fleet leaves nowhere to re-queue
        assert not cluster_scan_eligible(reqs, 2, 6, "fc", dynamics=dyn)
        # dynamic home routing depends on the alive fleet size
        assert not cluster_scan_eligible(
            reqs, 2, 6, "sept", assignment="push", lb="home",
            dynamics=ClusterDynamics(fail=((0, 5.0),)))
        assert cluster_scan_eligible(
            reqs, 2, 6, "sept", assignment="push", lb="home")


@needs_jax
class TestFailureParity:
    """fail_at cells: lost counts bit-identical, metrics within the cluster
    budget, timelines equal between engines."""

    @pytest.mark.parametrize("policy", ("fifo", "sept", "eect", "rect",
                                        "fc"))
    def test_pull_failure_parity(self, policy):
        ref = simulate_cluster(_burst(), nodes=2, cores_per_node=6,
                               policy=policy, fail_at=10.0)
        scan = simulate_cluster(_burst(), nodes=2, cores_per_node=6,
                                policy=policy, fail_at=10.0, backend="scan")
        assert scan.failures == ref.failures          # bit-identical
        assert scan.failures > 0
        assert scan.timeline.deactivate[0] == ref.timeline.deactivate[0]
        assert _worst_rel(_metrics(ref), _metrics(scan)) \
            < CLUSTER_XCHECK_RTOL

    @pytest.mark.parametrize("policy", ("fifo", "fc"))
    def test_push_failure_parity(self, policy):
        """Push kills lose queued calls too; both engines count and retry
        them identically (incl. FC via the per-(node, fn) count rings)."""
        ref = simulate_cluster(_burst(), nodes=2, cores_per_node=6,
                               policy=policy, assignment="push",
                               fail_at=8.0)
        scan = simulate_cluster(_burst(), nodes=2, cores_per_node=6,
                                policy=policy, assignment="push",
                                fail_at=8.0, backend="scan")
        assert scan.failures == ref.failures
        assert scan.failures > 0
        assert _worst_rel(_metrics(ref), _metrics(scan)) \
            < CLUSTER_XCHECK_RTOL

    def test_all_requests_complete_after_failure(self):
        reqs = _burst()
        scan = simulate_cluster(reqs, nodes=2, cores_per_node=6,
                                policy="fc", fail_at=10.0, backend="scan")
        assert len(scan.requests) == len(reqs)
        assert all(r.c is not None for r in reqs)

    def test_failure_after_drain_loses_nothing(self):
        ref = simulate_cluster(_burst(), nodes=2, cores_per_node=6,
                               policy="fc", fail_at=1e6)
        scan = simulate_cluster(_burst(), nodes=2, cores_per_node=6,
                                policy="fc", fail_at=1e6, backend="scan")
        assert ref.failures == scan.failures == 0

    def test_duplicate_kills_keep_the_earliest(self):
        """The reference no-ops a second kill of a dead node; the scan must
        honor the earliest time too, not the last-listed one."""
        from repro.core.fastpath import simulate_cluster_cells_scan
        dyn = ClusterDynamics(fail=((0, 20.0), (0, 5.0)))
        scan = simulate_cluster_cells_scan(
            [(_burst(), 3, 6, "fc", "pull", "least_loaded", dyn)])[0]
        cfg = ClusterConfig(nodes=3, cores_per_node=6, policy="fc")
        cl = Cluster(cfg, warm_functions=sorted({r.fn for r in _burst()}))
        cl.fail_node(0, at=20.0)
        cl.fail_node(0, at=5.0)
        ref = cl.run(_burst())
        assert scan.timeline.deactivate[0] == 5.0
        assert scan.failures == ref.failures

    def test_fail_time_not_quantized_to_float32(self):
        """Dynamic buckets build inputs in float64: a kill time that is not
        float32-representable must survive into the realized timeline."""
        scan = simulate_cluster(_burst(), nodes=2, cores_per_node=6,
                                policy="fc", fail_at=7.3, backend="scan")
        assert scan.timeline.deactivate[0] == 7.3


@needs_jax
class TestAutoscaleParity:
    def test_pull_autoscale_parity(self):
        kw = dict(nodes=1, cores_per_node=10, policy="fc", autoscale=True,
                  provision_delay_s=15.0, scale_up_queue_per_slot=2.0,
                  max_nodes=6)
        ref = simulate_cluster(_burst(cores=10, intensity=90), **kw)
        scan = simulate_cluster(_burst(cores=10, intensity=90),
                                backend="scan", **kw)
        assert scan.nodes_used == ref.nodes_used > 1
        assert scan.timeline.activate == ref.timeline.activate
        assert _worst_rel(_metrics(ref), _metrics(scan)) \
            < CLUSTER_XCHECK_RTOL

    def test_combined_autoscale_and_failure(self):
        kw = dict(nodes=2, cores_per_node=8, policy="sept", autoscale=True,
                  provision_delay_s=12.0, scale_up_queue_per_slot=2.0,
                  max_nodes=5, fail_at=20.0)
        ref = simulate_cluster(_burst(cores=16, intensity=60), **kw)
        scan = simulate_cluster(_burst(cores=16, intensity=60),
                                backend="scan", **kw)
        assert scan.failures == ref.failures > 0
        assert scan.nodes_used == ref.nodes_used
        assert _worst_rel(_metrics(ref), _metrics(scan)) \
            < CLUSTER_XCHECK_RTOL

    def test_sweep_batches_dynamic_cells(self):
        """run_sweep routes autoscale/failure scan cells through the
        bucketed batch dispatch, none degraded."""
        spec = SweepSpec(policies=("fc",), nodes=(2,), cores=(6,),
                         intensities=(20,), autoscale=(False, True),
                         provision_delays=(10.0,), scale_ups=(2.0,),
                         max_nodes=4, failures=(None, 10.0), seeds=2,
                         backends=("scan",))
        res = run_sweep(spec, workers=1)
        assert res.meta["scan_batched"] == len(res)
        assert res.meta["degraded"] == 0
        ref = run_sweep(SweepSpec(
            policies=("fc",), nodes=(2,), cores=(6,), intensities=(20,),
            autoscale=(False, True), provision_delays=(10.0,),
            scale_ups=(2.0,), max_nodes=4, failures=(None, 10.0), seeds=2,
            backends=("reference",)), workers=1)
        for a, b in zip(res.results, ref.results):
            assert a.metrics["failures"] == b.metrics["failures"]
            assert a.metrics["nodes_used"] == b.metrics["nodes_used"]
            assert abs(a.metrics["R_avg"] - b.metrics["R_avg"]) \
                <= CLUSTER_XCHECK_RTOL * b.metrics["R_avg"]

    def test_cross_check_covers_dynamic_cells(self):
        spec = SweepSpec(policies=("fc",), nodes=(2,), cores=(6,),
                         intensities=(15,), failures=(10.0,), seeds=2,
                         backends=("scan",), validate="cross-check")
        cells = spec.cells()
        assert all(c.cross_check for c in cells)
        res = run_sweep(spec, workers=1)
        errs = [cr.metrics["xcheck_err"] for cr in res.results]
        assert len(errs) == 2 and max(errs) <= CLUSTER_XCHECK_RTOL


@needs_jax
class TestPushFcRings:
    """Push-FC runs on the scan kernel via bounded per-(node, fn) count
    rings -- completing 5-policy x 3-assignment coverage."""

    @pytest.mark.parametrize("lb", ("least_loaded", "home"))
    def test_static_push_fc_parity(self, lb):
        ref = simulate_cluster(_burst(seed=3), nodes=3, cores_per_node=6,
                               policy="fc", assignment="push", lb=lb)
        scan = simulate_cluster(_burst(seed=3), nodes=3, cores_per_node=6,
                                policy="fc", assignment="push", lb=lb,
                                backend="scan")
        assert scan.meta["backend"] == "scan"
        assert _worst_rel(_metrics(ref), _metrics(scan)) \
            < CLUSTER_XCHECK_RTOL


@needs_jax
class TestScanCacheLimit:
    def test_cache_cap_is_env_tunable(self, monkeypatch):
        import importlib
        monkeypatch.setenv("REPRO_SCAN_CACHE_MAX", "7")
        import repro.core.fastpath as fp
        importlib.reload(fp)
        try:
            assert fp.SCAN_CACHE_MAX == 7
        finally:
            monkeypatch.delenv("REPRO_SCAN_CACHE_MAX")
            importlib.reload(fp)

    def test_eviction_keeps_batch_dispatch_correct(self, monkeypatch):
        """With the cap forced to 1, every new bucket shape evicts the
        previous runner; sweeps still produce correct (identical) metrics
        and the resident size stays bounded."""
        import repro.core.fastpath as fp
        monkeypatch.setattr(fp, "SCAN_CACHE_MAX", 1)
        scan_cache_clear()
        cells = [SweepCell(policy="fifo", nodes=2, cores=6, intensity=12,
                           backend="scan"),
                 SweepCell(policy="fifo", nodes=2, cores=6, intensity=12,
                           assignment="push", backend="scan"),
                 SweepCell(policy="fc", nodes=2, cores=6, intensity=12,
                           backend="scan")]
        first = run_cells_scan(cells)
        stats = scan_cache_stats()
        assert stats["size"] <= 1 and stats["misses"] >= 2
        second = run_cells_scan(cells)          # all buckets re-compiled
        assert first == second
        for m, cell in zip(first, cells):
            assert m == run_cell(cell)


class TestDegradedAccounting:
    def test_run_sweep_counts_degraded(self):
        """A scan-axis grid mixing eligible cluster cells with stock
        baseline cells surfaces the fallback count instead of silently
        folding reference timings into the scan path."""
        spec = SweepSpec(policies=("fc", "baseline"), nodes=(2,), cores=(6,),
                         intensities=(12,), seeds=2, backends=("scan",))
        res = run_sweep(spec, workers=1)
        n_baseline = sum(1 for cr in res.results
                         if cr.cell.policy == "baseline")
        assert n_baseline == 2
        assert res.meta["degraded"] == (n_baseline if HAVE_JAX
                                        else len(res))
        agg = {r["policy"]: r for r in res.aggregate()}
        assert agg["baseline"].get("degraded") == 1.0
        assert "degraded" not in agg["fc"] or agg["fc"]["degraded"] in (
            0.0, None) or not HAVE_JAX
