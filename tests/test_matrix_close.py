"""Capability-matrix close: every ``mode="ours"`` cross-product runs on
the scan kernel, and the silent-fallback / dropped-parameter bugs around
the matrix are fixed.

Contracts under test:

* parity at the established tolerances for the newly-closed rows --
  hedging x autoscale, hedging x failure schedules (kills void in-flight
  watches), duplicate-mode racing (static, under failures, under pull-side
  autoscale), heterogeneity x dynamics, the cold (``warm=False``) regime
  single-node and cluster, and single-node push self-steal -- with
  ``failures`` / ``backups_issued`` / ``steals_won`` and cold-start /
  eviction counts **bit-identical**;
* ``ScanBackend.simulate`` / ``VectorizedBackend.simulate`` refuse a
  non-default ``kappa`` instead of silently dropping it (the parameter
  only parameterizes the baseline PS node neither kernel models);
* ``supports()`` <-> ``run_cells_scan`` consistency: combinations the
  matrix rejects raise under ``strict=True`` and degrade (counted, with
  ``degraded=1.0``) under ``strict=False``;
* ``validate="cross-check"`` sampling skips cells that would degrade at
  run time (their dual-run would silently never happen -- false parity)
  and counts them in ``meta["xcheck_skipped_degraded"]``;
* seed-mean ``degraded`` aggregation: 1 degraded seed of 5 reads 0.2,
  and a fully-eligible sweep emits ``degraded=0.0`` rather than omitting
  the column.
"""

import pytest

from repro.core import (
    HedgingSpec,
    SweepCell,
    generate_burst,
    get_backend,
    rolling_restart,
    run_cell,
    run_sweep,
    simulate_cluster,
    summarize,
)
from repro.core.simulator import PS_KAPPA, simulate_single_node
from repro.core.sweep import (
    CLUSTER_XCHECK_RTOL,
    CellResult,
    SweepResult,
    SweepSpec,
    run_cells_scan,
)

try:
    import jax  # noqa: F401
    HAVE_JAX = True
except ImportError:
    HAVE_JAX = False

needs_jax = pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")


def _burst(nodes=2, cores=4, intensity=12, seed=0):
    return generate_burst(cores=nodes * cores, intensity=intensity,
                          seed=seed)


def _metrics(res):
    s = summarize(res.requests)
    return {"R_avg": s.response_avg, "R_p95": s.response_pct[95],
            "max_c": s.max_completion, "n": s.n}


# ---------------------------------------------------------------------------
# parity for the newly-closed capability rows (exact counts: the ISSUE bar)
# ---------------------------------------------------------------------------
@needs_jax
class TestClosedRowParity:
    def _assert_parity(self, kw, seed=0, nodes=2, cores=4, intensity=12):
        ref = simulate_cluster(_burst(nodes, cores, intensity, seed),
                               nodes=nodes, cores_per_node=cores,
                               backend="reference", **kw)
        scan = simulate_cluster(_burst(nodes, cores, intensity, seed),
                                nodes=nodes, cores_per_node=cores,
                                backend="scan", **kw)
        mr, ms = _metrics(ref), _metrics(scan)
        for k in ("R_avg", "R_p95", "max_c"):
            assert abs(mr[k] - ms[k]) <= CLUSTER_XCHECK_RTOL * max(
                abs(mr[k]), 1e-9), (
                f"{k}: scan {ms[k]} vs reference {mr[k]} under {kw}")
        assert mr["n"] == ms["n"]
        assert scan.backups_issued == ref.backups_issued, kw
        assert scan.steals_won == ref.steals_won, kw
        assert scan.failures == ref.failures, kw
        assert scan.cold_starts == ref.cold_starts, kw
        assert scan.evictions == ref.evictions, kw
        return ref, scan

    def test_hedging_composes_with_autoscale(self):
        """Steal deadlines fire while the fleet is still provisioning; the
        steal targets respect the live active mask."""
        for seed in range(2):
            ref, _ = self._assert_parity(
                dict(policy="fc", assignment="push",
                     degrade=((0, 1.0, 300.0, 6.0),),
                     hedging=HedgingSpec(multiple=2.0),
                     scale_up_queue_per_slot=1.0, max_nodes=4,
                     provision_delay_s=2.0), seed=seed, intensity=25)
            assert ref.backups_issued > 0          # the row actually fires

    def test_hedging_composes_with_failures(self):
        """Kills void in-flight hedge watches: a call lost mid-execution
        keeps its stale start in the reference and never hedges again."""
        for seed in range(2):
            ref, _ = self._assert_parity(
                dict(policy="sept", assignment="push",
                     degrade=((0, 1.0, 300.0, 5.0),),
                     hedging=HedgingSpec(multiple=2.0),
                     fail_spec=rolling_restart(1, start=8.0)),
                seed=seed, nodes=3, intensity=20)
            assert ref.failures > 0 and ref.backups_issued > 0

    def test_hedging_queued_at_kill_reroute_order(self):
        """A kill that loses *queued* calls (failures > cores) re-routes
        the lost set in the reference node.kill() order -- in-flight in
        launch order, then the queue in priority order -- which decides
        the least-loaded targets, FC counts and later steal cascades."""
        for policy in ("fc", "sept"):
            for seed, intensity in ((0, 16), (2, 20), (1, 25)):
                ref, _ = self._assert_parity(
                    dict(policy=policy, assignment="push",
                         degrade=((0, 1.0, 300.0, 5.0),),
                         hedging=HedgingSpec(multiple=2.0),
                         fail_spec=rolling_restart(1, start=8.0)),
                    seed=seed, nodes=3, cores=6, intensity=intensity)
                assert ref.failures > 6      # queued losses actually occur

    def test_duplicate_racing_static_push(self):
        ref, _ = self._assert_parity(
            dict(policy="fc", assignment="push",
                 degrade=((0, 1.0, 300.0, 6.0),),
                 hedging=HedgingSpec(multiple=2.0, mode="duplicate")),
            intensity=25)
        assert ref.backups_issued > 0

    def test_duplicate_racing_under_failures(self):
        """Racing copies with winner propagation while nodes die (pull)."""
        ref, _ = self._assert_parity(
            dict(policy="fc", assignment="pull",
                 degrade=((0, 1.0, 300.0, 5.0),),
                 hedging=HedgingSpec(multiple=2.0, mode="duplicate"),
                 fail_at=8.0), nodes=3, intensity=20)
        assert ref.failures > 0

    def test_duplicate_racing_under_autoscale(self):
        # pull-side watches arm on node-less queued calls, which the
        # reference's fire check skips -- structurally zero backups, and
        # the kernel must agree on that zero (push x dynamics x duplicate
        # is the documented rejection)
        ref, _ = self._assert_parity(
            dict(policy="fc", assignment="pull",
                 degrade=((0, 1.0, 300.0, 8.0),),
                 hedging=HedgingSpec(multiple=2.0, mode="duplicate"),
                 scale_up_queue_per_slot=1.0, max_nodes=4,
                 provision_delay_s=2.0), intensity=25)
        assert ref.backups_issued == 0

    def test_hetero_composes_with_autoscale(self):
        self._assert_parity(
            dict(policy="fc", assignment="push",
                 node_speeds=(0.5, 1.0),
                 scale_up_queue_per_slot=1.0, max_nodes=4,
                 provision_delay_s=2.0), intensity=25)

    def test_single_node_push_self_steal(self):
        """With no peer, the reference steal re-submits to the same node
        (attempts still increment, FC window counts re-log the arrival)."""
        ref, _ = self._assert_parity(
            dict(policy="fc", assignment="push",
                 degrade=((0, 1.0, 300.0, 4.0),),
                 hedging=HedgingSpec(multiple=3.0)),
            nodes=1, intensity=5)
        assert ref.backups_issued > 0 and ref.steals_won >= 0

    @pytest.mark.parametrize("kw", (dict(policy="fc", assignment="push"),
                                    dict(policy="sept", assignment="pull")))
    def test_cold_cluster_parity(self, kw):
        for seed in range(2):
            ref, _ = self._assert_parity(dict(warm=False, **kw), seed=seed)
            assert ref.cold_starts > 0

    def test_cold_composes_with_hetero_and_hedging(self):
        self._assert_parity(
            dict(policy="fc", assignment="push", warm=False,
                 degrade=((0, 1.0, 300.0, 5.0),),
                 hedging=HedgingSpec(multiple=2.0)), intensity=20)

    @needs_jax
    def test_cold_single_node_parity(self):
        reqs = generate_burst(cores=4, intensity=12, seed=0)
        ref = simulate_single_node(reqs, 4, policy="fc", warm=False,
                                   backend="reference")
        scan = simulate_single_node(generate_burst(cores=4, intensity=12,
                                                   seed=0),
                                    4, policy="fc", warm=False,
                                    backend="scan")
        mr, ms = _metrics(ref), _metrics(scan)
        assert mr["n"] == ms["n"]
        assert abs(mr["R_avg"] - ms["R_avg"]) <= 1e-2 * mr["R_avg"]
        assert scan.cold_starts == ref.cold_starts > 0
        assert scan.evictions == ref.evictions
        # per-request cold-start flags line up, not just the total
        assert (sorted(r.r for r in ref.requests if r.cold_start)
                == sorted(r.r for r in scan.requests if r.cold_start))


# ---------------------------------------------------------------------------
# dropped-parameter regression: kappa must not be silently swallowed
# ---------------------------------------------------------------------------
class TestKappaNotDropped:
    def _reqs(self):
        return generate_burst(cores=4, intensity=10, seed=0)

    @pytest.mark.parametrize("name", ("vectorized",
                                      pytest.param("scan", marks=needs_jax)))
    def test_fast_backends_reject_nondefault_kappa(self, name):
        be = get_backend(name)
        with pytest.raises(ValueError, match="kappa"):
            be.simulate(self._reqs(), 4, policy="fc", kappa=PS_KAPPA * 2)

    @pytest.mark.parametrize("name", ("vectorized",
                                      pytest.param("scan", marks=needs_jax)))
    def test_fast_backends_accept_default_kappa(self, name):
        res = get_backend(name).simulate(self._reqs(), 4, policy="fc",
                                         kappa=PS_KAPPA)
        assert all(r.c is not None for r in res.requests)

    def test_reference_consumes_kappa(self):
        """The baseline PS node actually uses kappa once the node is
        oversubscribed: changing it changes the metrics (so dropping it
        would have been a real bug)."""
        reqs = lambda: generate_burst(cores=4, intensity=40, seed=0)
        a = get_backend("reference").simulate(reqs(), 4, mode="baseline")
        b = get_backend("reference").simulate(reqs(), 4, mode="baseline",
                                              kappa=PS_KAPPA * 4)
        assert _metrics(a)["R_avg"] != _metrics(b)["R_avg"]


# ---------------------------------------------------------------------------
# matrix-driven consistency: supports() False => strict raises, non-strict
# degrades with degraded=1.0
# ---------------------------------------------------------------------------
@needs_jax
class TestMatrixConsistency:
    # (cell kwargs, supports kwargs) for rows the matrix REJECTS
    REJECTED = (
        # stock baseline mode never runs on the scan kernel
        (dict(policy="fifo", mode="baseline", nodes=2),
         dict(mode="baseline", policy="fifo", warm=True, nodes=2)),
        (dict(policy="baseline", nodes=1),
         dict(mode="baseline", policy="fifo", warm=True, nodes=1)),
        # failure injection with no surviving node
        (dict(policy="fc", nodes=1, fail_at=10.0),
         dict(mode="ours", policy="fc", warm=True, nodes=1, failures=True)),
    )

    def test_supports_says_no(self):
        scan = get_backend("scan")
        for _, sup_kw in self.REJECTED:
            assert not scan.supports(**sup_kw)

    def test_strict_raises_for_every_rejected_row(self):
        for cell_kw, _ in self.REJECTED:
            cell = SweepCell(cores=4, intensity=8, **cell_kw)
            with pytest.raises(ValueError, match="not scan-eligible"):
                run_cells_scan([cell])

    def test_non_strict_degrades_and_counts(self):
        # the baseline rows have reference semantics; run them through the
        # degrade path and check the marker (the nodes=1 failure row has no
        # reference-defined recovery, so strict-raise coverage is enough)
        for cell_kw, _ in self.REJECTED[:2]:
            cell = SweepCell(cores=4, intensity=8, **cell_kw)
            got = run_cells_scan([cell], strict=False)[0]
            assert got.pop("degraded") == 1.0
            ref = dict(run_cell(cell))
            ref.pop("degraded", None)
            assert got == ref

    def test_supported_rows_do_not_degrade(self):
        """Every ours-mode cross-product in the matrix runs on the kernel:
        no degraded marker on any supported row."""
        cells = [
            SweepCell(policy="fc", nodes=2, cores=4, intensity=8),
            SweepCell(policy="sept", nodes=2, cores=4, intensity=8,
                      assignment="push"),
            SweepCell(policy="fc", nodes=2, cores=4, intensity=8,
                      autoscale=True, hedge_multiple=2.0,
                      degrade=((0, 1.0, 300.0, 5.0),)),
            SweepCell(policy="fc", nodes=2, cores=4, intensity=8,
                      fail_at=8.0, node_speeds=(0.5, 1.0)),
            SweepCell(policy="fc", nodes=2, cores=4, intensity=8,
                      warm=False),
            SweepCell(policy="sept", nodes=1, cores=4, intensity=8,
                      warm=False),
        ]
        for m in run_cells_scan(cells):
            assert "degraded" not in m and m["n"] > 0


# ---------------------------------------------------------------------------
# cross-check sampling must not pick cells that would degrade
# ---------------------------------------------------------------------------
@needs_jax
class TestCrossCheckSampling:
    def _spec(self):
        # cores=18 is statically capable but fails the warm-up check for
        # its actual workload -> would degrade at run time
        return SweepSpec(policies=("fc",), nodes=(2,), cores=(6, 18),
                         intensities=(15,), seeds=2, backends=("scan",),
                         validate="cross-check")

    def test_degraded_groups_are_skipped(self):
        spec = self._spec()
        cells = spec.cells()
        for c in cells:
            assert c.cross_check == (c.cores == 6)
        assert spec._xcheck_skipped_degraded == 2    # both seeds

    def test_run_sweep_counts_skips_in_meta(self):
        res = run_sweep(self._spec(), workers=1)
        assert res.meta["xcheck_skipped_degraded"] == 2
        assert res.meta["xcheck_sampled"] == 2
        for cr in res.results:
            if cr.cell.cores == 18:
                # degraded cells ran on the reference, unsampled: no
                # xcheck_err pretending a dual-run happened
                assert cr.metrics.get("degraded") == 1.0
                assert "xcheck_err" not in cr.metrics
            else:
                assert "xcheck_err" in cr.metrics


# ---------------------------------------------------------------------------
# degraded-fraction aggregation
# ---------------------------------------------------------------------------
class TestDegradedAggregation:
    def test_seed_mean_fraction(self):
        """1 degraded seed of 5 reads 0.2 in the aggregate (and in the CSV
        / JSON columns derived from it), not 1.0."""
        cells = [SweepCell(policy="fc", nodes=2, cores=6, intensity=15,
                           seed=s, backend="scan") for s in range(5)]
        metrics = [{"R_avg": 1.0, "n": 10.0} for _ in cells]
        metrics[3] = {"R_avg": 1.0, "n": 10.0, "degraded": 1.0}
        res = SweepResult(results=[CellResult(c, m)
                                   for c, m in zip(cells, metrics)])
        row, = res.aggregate()
        assert row["seeds"] == 5
        assert row["degraded"] == pytest.approx(0.2)

    def test_fully_eligible_emits_zero_not_missing(self):
        cells = [SweepCell(policy="fc", seed=s) for s in range(2)]
        res = SweepResult(results=[CellResult(c, {"R_avg": 2.0})
                                   for c in cells])
        row, = res.aggregate()
        assert row["degraded"] == 0.0

    @needs_jax
    def test_end_to_end_sweep_emits_zero(self):
        spec = SweepSpec(policies=("fifo",), nodes=(2,), cores=(6,),
                         intensities=(10,), seeds=1, backends=("scan",))
        res = run_sweep(spec, workers=1)
        row, = res.aggregate()
        assert row["degraded"] == 0.0
