"""Per-arch smoke tests (reduced configs) + model-level invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, applicable_shapes, get_config
from repro.models import (
    decode_step,
    forward,
    init,
    init_cache,
    prefill,
    scale_down,
)

RNG = jax.random.PRNGKey(0)

# Per-arch compile sweeps dominate suite wall-clock (~2-14 s per arch per
# test on CPU).  Tier-1 keeps one cheap representative; the full matrix is
# the `slow` calibration set.
FAST_ARCHS = {"qwen3_1_7b"}


def _arch_params(archs):
    return [a if a in FAST_ARCHS else pytest.param(a, marks=pytest.mark.slow)
            for a in archs]


def _batch(cfg, B=2, S=32):
    b = {"tokens": jax.random.randint(RNG, (B, S), 0, cfg.vocab)}
    if cfg.mrope:
        b["positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32), (3, B, S))
    if cfg.is_encdec:
        b["enc_embeds"] = jnp.full((B, S, cfg.d_model), 0.01,
                                   jnp.dtype(cfg.dtype))
    return b


@pytest.mark.parametrize("arch", _arch_params(ARCHS))
class TestArchSmoke:
    """One reduced-config forward/train + decode step per assigned arch."""

    def test_forward_shape_and_finite(self, arch):
        cfg = scale_down(get_config(arch))
        params = init(cfg, RNG)
        B, S = 2, 32
        logits = forward(params, cfg, _batch(cfg, B, S))
        S_out = S // cfg.decoder_ratio if cfg.is_encdec else S
        if cfg.is_encdec:
            # decoder length = enc length // ratio in batch_struct; here the
            # smoke batch uses tokens of length S directly
            S_out = S
        assert logits.shape == (B, S_out, cfg.padded_vocab)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    def test_prefill_then_decode(self, arch):
        cfg = scale_down(get_config(arch))
        params = init(cfg, RNG)
        B, S = 2, 32
        cache = init_cache(cfg, B, 64, enc_len=S if cfg.is_encdec else 0)
        logits, cache = prefill(params, cfg, _batch(cfg, B, S), cache)
        assert logits.shape == (B, cfg.padded_vocab)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        logits2, cache = decode_step(params, cfg, tok, cache, jnp.int32(S))
        assert logits2.shape == (B, cfg.padded_vocab)
        assert bool(jnp.isfinite(logits2.astype(jnp.float32)).all())

    def test_train_step_no_nan(self, arch):
        from repro.training import TrainConfig, make_train_step, optim
        cfg = dataclasses.replace(scale_down(get_config(arch)),
                                  vocab=128, vocab_pad_multiple=16)
        params = init(cfg, RNG)
        opt = optim.init_state(params)
        step = make_train_step(cfg, TrainConfig(lr=1e-3))
        batch = _batch(cfg, 2, 16)
        batch["tokens"] = batch["tokens"] % cfg.vocab
        batch["labels"] = batch["tokens"]
        params, opt, loss = jax.jit(step)(params, opt, batch)
        assert bool(jnp.isfinite(loss))


class TestDecodeParity:
    """Incremental decode must equal the full forward pass."""

    @pytest.mark.parametrize("arch", _arch_params(
        ["qwen3_1_7b", "gemma3_27b", "rwkv6_3b", "recurrentgemma_9b"]))
    def test_decode_matches_forward(self, arch):
        cfg = dataclasses.replace(scale_down(get_config(arch), layers=6),
                                  dtype="float32")
        params = init(cfg, RNG)
        T = 12
        toks = jax.random.randint(jax.random.PRNGKey(7), (1, T), 0, cfg.vocab)
        full = forward(params, cfg, {"tokens": toks})
        cache = init_cache(cfg, 1, T + 4)
        lg, cache = prefill(params, cfg, {"tokens": toks[:, :T - 1]}, cache)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full[:, T - 2]),
                                   rtol=3e-4, atol=3e-4)
        lg2, _ = decode_step(params, cfg, toks[:, T - 1], cache,
                             jnp.int32(T - 1))
        np.testing.assert_allclose(np.asarray(lg2),
                                   np.asarray(full[:, T - 1]),
                                   rtol=3e-4, atol=3e-4)


class TestModelInvariants:
    def test_sliding_window_limits_attention(self):
        """Token far outside the window must not influence the last logit."""
        from repro.models.config import LayerSpec
        cfg = dataclasses.replace(
            scale_down(get_config("qwen3_1_7b")),
            period=(LayerSpec(window=4),), dtype="float32")
        params = init(cfg, RNG)
        toks = jax.random.randint(jax.random.PRNGKey(3), (1, 16), 0, cfg.vocab)
        toks2 = toks.at[0, 0].set((toks[0, 0] + 7) % cfg.vocab)  # outside window
        a = forward(params, cfg, {"tokens": toks})
        b = forward(params, cfg, {"tokens": toks2})
        np.testing.assert_allclose(np.asarray(a[0, -1]), np.asarray(b[0, -1]),
                                   rtol=1e-5, atol=1e-5)

    def test_causality(self):
        """Future tokens must not influence earlier logits."""
        cfg = dataclasses.replace(scale_down(get_config("deepseek_7b")),
                                  dtype="float32")
        params = init(cfg, RNG)
        toks = jax.random.randint(jax.random.PRNGKey(4), (1, 16), 0, cfg.vocab)
        toks2 = toks.at[0, -1].set((toks[0, -1] + 1) % cfg.vocab)
        a = forward(params, cfg, {"tokens": toks})
        b = forward(params, cfg, {"tokens": toks2})
        np.testing.assert_allclose(np.asarray(a[0, :-1]),
                                   np.asarray(b[0, :-1]),
                                   rtol=1e-5, atol=1e-5)

    def test_moe_routing_distributes_tokens(self):
        from repro.models.layers import moe_mlp, moe_params_shapes
        cfg = scale_down(get_config("qwen2_moe_a2_7b"))
        shapes = moe_params_shapes(cfg)
        key = jax.random.PRNGKey(5)
        params = {}
        for name, shape in shapes.items():
            key, sub = jax.random.split(key)
            params[name] = jax.random.normal(sub, shape, jnp.float32) * 0.05
        x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32)
        y = moe_mlp(params, x, cfg)
        assert y.shape == x.shape
        assert bool(jnp.isfinite(y).all())

    def test_param_count_close_to_published(self):
        """Sanity: derived parameter counts are near the published sizes."""
        expected = {
            "gemma3_27b": 27e9, "qwen2_5_14b": 14e9, "deepseek_7b": 6.9e9,
            "rwkv6_3b": 2.7e9, "qwen3_1_7b": 1.7e9,
        }
        for arch, n in expected.items():
            got = get_config(arch).param_count()
            assert abs(got - n) / n < 0.15, (arch, got, n)

    def test_long_500k_skip_rules(self):
        """long_500k only for sub-quadratic archs (DESIGN.md §4)."""
        runs = {a for a in ARCHS
                if "long_500k" in applicable_shapes(get_config(a))}
        assert runs == {"recurrentgemma_9b", "gemma3_27b", "rwkv6_3b"}


class TestServingOptimizations:
    """Perf-hillclimb features (EXPERIMENTS.md §Perf) stay correct."""

    def test_int8_kv_cache_parity(self):
        cfg = dataclasses.replace(scale_down(get_config("qwen3_1_7b")),
                                  dtype="float32")
        cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
        params = init(cfg, RNG)
        toks = jax.random.randint(jax.random.PRNGKey(7), (1, 9), 0, cfg.vocab)
        full = forward(params, cfg, {"tokens": toks})
        cache = init_cache(cfg8, 1, 16)
        assert cache["groups"]["pos0"]["k"].dtype == jnp.int8
        _, cache = prefill(params, cfg8, {"tokens": toks[:, :8]}, cache)
        lg, _ = decode_step(params, cfg8, toks[:, 8], cache, jnp.int32(8))
        a = np.asarray(lg).ravel()
        b = np.asarray(full[:, 8]).ravel()
        corr = float(np.corrcoef(a, b)[0, 1])
        assert corr > 0.995, corr

    def test_moe_group_dispatch_matches_global(self):
        cfg = dataclasses.replace(scale_down(get_config("qwen2_moe_a2_7b")),
                                  dtype="float32", capacity_factor=8.0)
        grouped = dataclasses.replace(cfg, moe_groups=2)
        params = init(cfg, RNG)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(8), (2, 16),
                                              0, cfg.vocab)}
        a = forward(params, cfg, batch)
        b = forward(params, grouped, batch)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)
