"""End-to-end behaviour tests for the paper's system.

These are the headline claims (§VII/§VIII), asserted as inequalities on the
calibrated simulator + the multi-node cluster.
"""

import numpy as np
import pytest

from repro.core import (
    generate_burst,
    simulate_baseline_cluster,
    simulate_cluster,
    simulate_single_node,
    summarize,
)

# whole-burst calibration runs at 10-20 cores: the slow tier
pytestmark = pytest.mark.slow


def _summary(cores, intensity, policy, mode, seeds=2):
    outs = []
    for seed in range(seeds):
        reqs = generate_burst(cores=cores, intensity=intensity, seed=seed)
        simulate_single_node(reqs, cores=cores, policy=policy, mode=mode)
        outs.append(summarize(reqs))
    return outs


class TestHeadlineClaims:
    def test_policies_ranking_under_load(self):
        """Paper Table III @ 10 cores / intensity 60: FC ~ SEPT << EECT ~
        RECT << FIFO on mean response."""
        means = {}
        for pol in ("fifo", "sept", "eect", "rect", "fc"):
            means[pol] = np.mean([s.response_avg
                                  for s in _summary(10, 60, pol, "ours")])
        assert means["sept"] < means["eect"] < means["fifo"]
        assert means["fc"] < means["eect"]
        assert means["rect"] < means["fifo"]

    def test_smart_policies_cut_mean_response_3x(self):
        """Paper: SEPT improves mean response ~3.6x over FIFO."""
        fifo = np.mean([s.response_avg for s in _summary(10, 60, "fifo", "ours")])
        sept = np.mean([s.response_avg for s in _summary(10, 60, "sept", "ours")])
        assert fifo / sept > 3.0

    def test_stretch_improvement_order_of_magnitude(self):
        """Paper: mean stretch improves ~15-18x (SEPT/FC vs FIFO)."""
        fifo = np.mean([s.stretch_avg for s in _summary(10, 60, "fifo", "ours")])
        fc = np.mean([s.stretch_avg for s in _summary(10, 60, "fc", "ours")])
        assert fifo / fc > 8.0

    def test_makespan_roughly_preserved(self):
        """Reordering must not inflate total completion much (Table II/III)."""
        fifo = np.mean([s.max_completion for s in _summary(10, 60, "fifo", "ours")])
        sept = np.mean([s.max_completion for s in _summary(10, 60, "sept", "ours")])
        assert sept < 1.3 * fifo

    def test_fewer_machines_same_service(self):
        """Paper §VIII: FC on 3 nodes vs stock OpenWhisk on 4 nodes.  With
        our conservative baseline model we assert FC@3 stays within 2.5x of
        baseline@4 mean response while using 25% fewer machines (the paper
        measured an outright 71% win; see EXPERIMENTS.md §Repro for the
        residual discussion).  The bound was 2.0x under salted-hash home
        routing, where baseline@4 varied run to run; deterministic CRC32
        routing (core.traces.stable_hash) lands this workload on a slightly
        luckier baseline layout (ratio ~2.17)."""
        base4, fc3 = [], []
        for seed in range(2):
            reqs = generate_burst(cores=72, intensity=30, seed=seed)
            res = simulate_baseline_cluster(reqs, nodes=4, cores_per_node=18)
            base4.append(summarize(res.requests).response_avg)
            reqs = generate_burst(cores=72, intensity=30, seed=seed)
            res = simulate_cluster(reqs, nodes=3, cores_per_node=18,
                                   policy="fc")
            fc3.append(summarize(res.requests).response_avg)
        assert np.mean(fc3) < 2.5 * np.mean(base4)

    def test_tail_latency_improves_at_equal_nodes(self):
        """FC@4 should beat baseline@4 on the p95 tail."""
        b, f = [], []
        for seed in range(2):
            reqs = generate_burst(cores=72, intensity=30, seed=seed)
            res = simulate_baseline_cluster(reqs, nodes=4, cores_per_node=18)
            b.append(summarize(res.requests).response_pct[95])
            reqs = generate_burst(cores=72, intensity=30, seed=seed)
            res = simulate_cluster(reqs, nodes=4, cores_per_node=18,
                                   policy="fc")
            f.append(summarize(res.requests).response_pct[95])
        assert np.mean(f) < np.mean(b)
