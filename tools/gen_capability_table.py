"""Regenerate the README backend-capability table from ``supports()``.

The markdown table between the ``<!-- capability-matrix:begin -->`` /
``<!-- capability-matrix:end -->`` markers in README.md is *generated*, not
hand-written: every yes/no is the literal return value of the registered
backend's ``supports()`` for that scenario, so the docs cannot drift from
the routing matrix.  ``--check`` mode (used by tests and CI) regenerates
the table and fails if the README disagrees -- which also catches a
previously-green ``supports()`` row regressing to ``False``.

Usage::

    PYTHONPATH=src python tools/gen_capability_table.py            # rewrite
    PYTHONPATH=src python tools/gen_capability_table.py --check    # verify
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.core.simulator import get_backend

BEGIN = "<!-- capability-matrix:begin -->"
END = "<!-- capability-matrix:end -->"

BACKENDS = ("reference", "vectorized", "scan")

# Canonical scenario rows: label -> supports() kwargs.  Axes not named
# default to the single-node ours-mode warm regime.
SCENARIOS: list[tuple[str, dict]] = [
    ("ours, single node, warm",
     dict()),
    ("ours, single node, cold starts (`warm=False`)",
     dict(warm=False)),
    ("stock baseline (processor sharing)",
     dict(mode="baseline")),
    ("cluster, pull assignment",
     dict(nodes=4, assignment="pull")),
    ("cluster, push assignment",
     dict(nodes=4, assignment="push")),
    ("cluster, cold starts",
     dict(nodes=4, assignment="pull", warm=False)),
    ("autoscaling",
     dict(nodes=4, assignment="push", autoscale=True)),
    ("failure injection (`nodes >= 2`)",
     dict(nodes=4, assignment="push", failures=True)),
    ("failure injection, single node",
     dict(nodes=1, failures=True)),
    ("heterogeneous speeds / degradation",
     dict(nodes=4, assignment="pull", hetero=True)),
    ("hedging (steal or duplicate)",
     dict(nodes=4, assignment="push", hedging=True)),
    ("hedging x failures",
     dict(nodes=4, assignment="push", hedging=True, failures=True)),
    ("hedging x autoscaling",
     dict(nodes=4, assignment="push", hedging=True, autoscale=True)),
    ("hetero x failures x hedging",
     dict(nodes=4, assignment="push", hetero=True, failures=True,
          hedging=True)),
    ("timeouts (deadline cancellation)",
     dict(nodes=4, assignment="push", timeouts=True)),
    ("timeouts + retries (backoff / immediate)",
     dict(nodes=4, assignment="push", timeouts=True, retries=True)),
    ("admission control (load shedding)",
     dict(nodes=4, assignment="push", shedding=True)),
    ("full resilience (timeouts x retries x shedding)",
     dict(nodes=4, assignment="push", timeouts=True, retries=True,
          shedding=True)),
    ("resilience, pull assignment",
     dict(nodes=4, assignment="pull", timeouts=True, retries=True)),
    ("resilience x hedging",
     dict(nodes=4, assignment="push", timeouts=True, hedging=True)),
]


def _supports(backend_name: str, kwargs: dict) -> bool:
    base = dict(mode="ours", policy="fc", warm=True, nodes=1,
                assignment="pull", autoscale=False, failures=False,
                hedging=False, hetero=False, timeouts=False, retries=False,
                shedding=False, streaming=False, trace=False)
    base.update(kwargs)
    return bool(get_backend(backend_name).supports(**base))


def render_table() -> str:
    # the trailing `streaming` column asks the scan backend about the
    # chunked carry-handoff replay path (core/streamscan.py) for the same
    # scenario -- bounded-memory streams on every row it says yes to; the
    # `trace` column asks the reference backend for the rich instrumented
    # flight-recorder stream (core/flight.py) -- the canonical trace needs
    # no capability bit, trace_from_result reconstructs it from any
    # backend's written-back request state
    lines = [
        "| scenario | " + " | ".join(f"`{b}`" for b in BACKENDS)
        + " | `streaming` | `trace` |",
        "|" + "---|" * (len(BACKENDS) + 3),
    ]
    for label, kwargs in SCENARIOS:
        cells = " | ".join(
            "yes" if _supports(b, kwargs) else "no" for b in BACKENDS)
        stream = "yes" if _supports(
            "scan", {**kwargs, "streaming": True}) else "no"
        trace = "yes" if _supports(
            "reference", {**kwargs, "trace": True}) else "no"
        lines.append(f"| {label} | {cells} | {stream} | {trace} |")
    return "\n".join(lines)


def splice(readme: str, table: str) -> str:
    try:
        head, rest = readme.split(BEGIN, 1)
        _, tail = rest.split(END, 1)
    except ValueError:
        raise SystemExit(
            f"README.md is missing the {BEGIN} / {END} markers") from None
    return f"{head}{BEGIN}\n{table}\n{END}{tail}"


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="verify README matches supports(); do not write")
    ap.add_argument("--readme", type=Path,
                    default=Path(__file__).resolve().parent.parent
                    / "README.md")
    args = ap.parse_args(argv)

    readme = args.readme.read_text()
    updated = splice(readme, render_table())
    if args.check:
        if updated != readme:
            print("capability table out of date: run "
                  "PYTHONPATH=src python tools/gen_capability_table.py",
                  file=sys.stderr)
            return 1
        print("capability table in sync with supports()")
        return 0
    args.readme.write_text(updated)
    print(f"wrote capability table ({len(SCENARIOS)} scenarios) "
          f"to {args.readme}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
