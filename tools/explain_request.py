"""Explain a request's lifecycle from a traced simulation run.

Builds one sweep-style cell workload (the same ``generate_burst`` the
sweeps use), runs it with the flight recorder on (``trace=True``) through
the reference event loop or the scan engine, and prints the per-request
lifecycle narrative (``SimTrace.explain``) for the requests you name with
``--req`` and/or the ``--slowest N`` responses.  Optionally exports the
whole trace as Chrome-trace JSON (``--chrome``, load at chrome://tracing
or https://ui.perfetto.dev), the run manifest (``--manifest``), and the
windowed-probe timeline figure (``--timeline``).

Usage::

    PYTHONPATH=src python tools/explain_request.py --slowest 3
    PYTHONPATH=src python tools/explain_request.py --backend scan --req 17
    PYTHONPATH=src python tools/explain_request.py \\
        --chrome artifacts/flight_trace.json \\
        --manifest artifacts/manifest.json
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
for p in (str(ROOT / "src"), str(ROOT)):   # repro.core + benchmarks.plots
    if p not in sys.path:
        sys.path.insert(0, p)

from repro.core import generate_burst, simulate_cluster, write_manifest


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="trace one cell and explain request lifecycles")
    ap.add_argument("--nodes", type=int, default=3)
    ap.add_argument("--cores", type=int, default=4,
                    help="cores per node")
    ap.add_argument("--policy", default="fc")
    ap.add_argument("--assignment", default="pull",
                    choices=("pull", "push"))
    ap.add_argument("--intensity", type=float, default=30.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="reference",
                    choices=("reference", "scan", "auto"),
                    help="reference = rich instrumented stream; "
                         "scan = canonical reconstruction")
    ap.add_argument("--req", type=int, action="append", default=None,
                    metavar="ID", help="request id(s) to explain")
    ap.add_argument("--slowest", type=int, default=None, metavar="N",
                    help="also explain the N slowest responses "
                         "(default 3 when no --req given)")
    ap.add_argument("--chrome", default=None, metavar="PATH",
                    help="write the Chrome-trace JSON export here")
    ap.add_argument("--manifest", default=None, metavar="PATH",
                    help="write the run manifest JSON here")
    ap.add_argument("--timeline", default=None, metavar="PNG",
                    help="write the windowed-probe timeline figure here")
    args = ap.parse_args(argv)

    requests = generate_burst(cores=args.nodes * args.cores,
                              intensity=args.intensity, seed=args.seed)
    res = simulate_cluster(requests, nodes=args.nodes,
                           cores_per_node=args.cores, policy=args.policy,
                           assignment=args.assignment,
                           backend=args.backend, trace=True)
    trace = res.trace
    if trace is None:
        print("backend attached no trace", file=sys.stderr)
        return 1
    counts = trace.counts()
    print(f"# {len(requests)} requests, {args.nodes}x{args.cores} cores, "
          f"policy={args.policy}, assignment={args.assignment}, "
          f"backend={trace.meta.get('backend', args.backend)}")
    print("# events: " + ", ".join(f"{k}={v}"
                                   for k, v in sorted(counts.items())))

    ids = list(args.req or [])
    slowest = args.slowest if args.slowest is not None else (
        0 if ids else 3)
    if slowest:
        done = sorted((r for r in requests if r.c is not None),
                      key=lambda r: r.c - r.r, reverse=True)
        ids.extend(r.id for r in done[:slowest] if r.id not in ids)
    for rid in ids:
        print()
        print(trace.explain(rid))

    for path in (args.chrome, args.manifest, args.timeline):
        if path:
            Path(path).parent.mkdir(parents=True, exist_ok=True)
    if args.chrome:
        trace.to_chrome(args.chrome)
        print(f"\nwrote Chrome trace to {args.chrome}")
    if args.manifest:
        write_manifest(args.manifest)
        print(f"wrote run manifest to {args.manifest}")
    if args.timeline:
        from benchmarks.plots import plot_timeline
        plot_timeline(trace, out=args.timeline)
        print(f"wrote timeline figure to {args.timeline}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
