"""Jit'd dispatch wrappers: Pallas on TPU, jnp oracle elsewhere.

The model code calls these entry points; on a real TPU the Pallas kernels
run (interpret=False), on CPU (this container, and all tests) the pure-jnp
references execute.  ``force`` overrides for kernel validation tests
(interpret=True runs the Pallas kernel body in Python on CPU).
"""

from __future__ import annotations

from functools import partial

import jax

from . import ref
from .decode_attention import decode_attention as _decode_pallas
from .flash_attention import flash_attention as _flash_pallas
from .rglru_scan import rglru_scan as _rglru_pallas
from .rwkv6_scan import rwkv6_scan as _rwkv6_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, window=-1, softmax_scale=None,
                    force: str | None = None, interpret: bool = False):
    """force: None (auto) | "pallas" | "ref"."""
    use_pallas = force == "pallas" or (force is None and _on_tpu())
    if use_pallas:
        return _flash_pallas(q, k, v, causal=causal, window=window,
                             softmax_scale=softmax_scale,
                             interpret=interpret or not _on_tpu())
    return ref.attention_ref(q, k, v, causal=causal, window=window,
                             softmax_scale=softmax_scale)


def decode_attention(q, k, v, lengths, *, softmax_scale=None,
                     force: str | None = None, interpret: bool = False):
    use_pallas = force == "pallas" or (force is None and _on_tpu())
    if use_pallas:
        return _decode_pallas(q, k, v, lengths, softmax_scale=softmax_scale,
                              interpret=interpret or not _on_tpu())
    return ref.decode_attention_ref(q, k, v, lengths,
                                    softmax_scale=softmax_scale)


def rglru_scan(a, gx, h0, *, force: str | None = None,
               interpret: bool = False):
    use_pallas = force == "pallas" or (force is None and _on_tpu())
    if use_pallas:
        return _rglru_pallas(a, gx, h0, interpret=interpret or not _on_tpu())
    return ref.rglru_ref(a, gx, h0)


def event_step(clk, ctr, inp, *, force: str | None = None,
               interpret: bool = False, **static):
    """Batched fused cluster event scan -- the simulator hot path.

    ``clk``/``ctr`` are the ``(B, len_f)`` / ``(B, len_i)`` packed carry
    plane pairs (see ``repro.core.fastpath._PlaneLayout``) and ``inp`` a
    dict of batched per-cell input arrays; ``static`` carries the kernel's
    compile-time shape/feature kwargs.  Returns what the per-cell scan
    kernel returns, batched.

    The pure-jnp oracle (a vmap over ``_scan_cell_kernel``) *is* the fused
    CPU path -- XLA fuses the plane unpack/update/pack chain into the step
    body.  On TPU the base pull configuration runs as a Pallas megakernel
    with the carry planes resident in VMEM across the scan
    (``repro.kernels.event_step``); unsupported feature combinations fall
    back to the oracle unless ``force="pallas"``."""
    from ..core import fastpath as _fp     # lazy: core is heavy

    use_pallas = force == "pallas" or (force is None and _on_tpu())
    if use_pallas:
        from .event_step import event_step_pallas, event_step_supported

        if event_step_supported(**static):
            return event_step_pallas(clk, ctr, inp,
                                     interpret=interpret or not _on_tpu(),
                                     **static)
        if force == "pallas":
            raise NotImplementedError(
                "the Pallas event_step covers only the base pull "
                "configuration (no freeze/dyn/het/hedge/cold/dup)")
    return jax.vmap(partial(_fp._scan_cell_kernel, **static))(clk, ctr, inp)


def rwkv6_scan(r, k, v, w, u, *, force: str | None = None,
               interpret: bool = False):
    use_pallas = force == "pallas" or (force is None and _on_tpu())
    if use_pallas:
        return _rwkv6_pallas(r, k, v, w, u,
                             interpret=interpret or not _on_tpu())
    import jax.numpy as jnp
    s0 = jnp.zeros((r.shape[0], r.shape[2], r.shape[3], r.shape[3]),
                   jnp.float32)
    out, _ = ref.rwkv6_ref(r, k, v, w, u, s0)
    return out
