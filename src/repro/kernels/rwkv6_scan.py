"""RWKV-6 time-mix recurrence kernel (Pallas TPU).

Per head the state is a (dh x dh) fp32 matrix updated per timestep with a
rank-1 (k v^T) outer product and a per-channel data-dependent decay w_t:

    out_t = r_t @ (S + diag(u) k_t v_t^T)
    S    <- diag(w_t) S + k_t v_t^T

Grid = (batch, head, time_blocks); the state matrix lives in VMEM scratch
carried across the (innermost) time axis; one invocation consumes a
(block_t, dh) tile of each of r/k/v/w.  dh = 64 keeps the state at 16 KiB,
far under VMEM; the VPU executes the rank-1 updates while the (block_t, dh)
IO amortises HBM latency.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_scr, *,
            block_t: int):
    tj = pl.program_id(2)

    @pl.when(tj == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0, :, 0, :].astype(jnp.float32)   # (block_t, dh)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    w = w_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)            # (dh,)

    def step(t, s):
        kv = k[t][:, None] * v[t][None, :]      # (dh, dh)
        acc = s + u[:, None] * kv
        out = r[t] @ acc                        # (dh,)
        o_ref[0, t, 0, :] = out.astype(o_ref.dtype)
        return w[t][:, None] * s + kv

    s_scr[...] = lax.fori_loop(0, block_t, step, s_scr[...])


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def rwkv6_scan(r, k, v, w, u, *, block_t=128, interpret=False):
    """r,k,v,w: (B, S, H, dh); u: (H, dh) -> out (B, S, H, dh).

    Fresh state per call (prefill semantics); the serving engine carries
    state across calls via the jnp reference path."""
    B, S, H, dh = r.shape
    block_t = min(block_t, S)
    assert S % block_t == 0, (S, block_t)
    t_blocks = S // block_t

    kernel = functools.partial(_kernel, block_t=block_t)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, t_blocks),
        in_specs=[
            pl.BlockSpec((1, block_t, 1, dh), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, block_t, 1, dh), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, block_t, 1, dh), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, block_t, 1, dh), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, dh), lambda b, h, t: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_t, 1, dh),
                               lambda b, h, t: (b, t, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, dh), r.dtype),
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return out
