"""Split-KV decode attention kernel (Pallas TPU, flash-decoding style).

One new token attends over a long KV cache.  The KV sequence is tiled over
the innermost grid dimension; online-softmax state is carried in VMEM
scratch; per-row cache lengths (ragged batches, the serving engine's slot
fill levels) mask invalid tail entries.  Because q_len = 1, tiles are
(block_k, dh) MXU matvec-shaped; batch and head are leading grid dims.

The ``lengths`` operand is scalar-prefetched (SMEM) so block masking can be
computed before the tile loads.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(lengths_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, scale: float, block_k: int, kv_blocks: int):
    b = pl.program_id(0)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lengths_ref[b]
    q = q_ref[0, 0]                          # (1, dh)
    k = k_ref[0, 0]                          # (block_k, dh)
    s = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                        preferred_element_type=jnp.float32) * scale  # (1, bk)
    k_pos = kj * block_k + lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(k_pos < length, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.where(m_new[:, None] <= NEG_INF / 2, 0.0,
                  jnp.exp(s - m_new[:, None]))
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_new))
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1)
    v = v_ref[0, 0]                          # (block_k, dh)
    pv = lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                         preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kj == kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("softmax_scale", "block_k", "interpret"))
def decode_attention(q, k, v, lengths, *, softmax_scale=None, block_k=256,
                     interpret=False):
    """q: (B, Hq, dh); k, v: (B, Sk, Hkv, dh); lengths: (B,) int32.
    Returns (B, Hq, dh)."""
    B, Hq, dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = softmax_scale or (1.0 / math.sqrt(dh))
    block_k = min(block_k, Sk)
    assert Sk % block_k == 0, (Sk, block_k)
    kv_blocks = Sk // block_k

    qt = q[:, :, None, :]                    # (B, Hq, 1, dh)
    kt = k.transpose(0, 2, 1, 3)             # (B, Hkv, Sk, dh)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_kernel, scale=scale, block_k=block_k,
                               kv_blocks=kv_blocks)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, Hq, kv_blocks),
            in_specs=[
                pl.BlockSpec((1, 1, 1, dh),
                             lambda b, h, j, lens: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, block_k, dh),
                             lambda b, h, j, lens: (b, h // G, j, 0)),
                pl.BlockSpec((1, 1, block_k, dh),
                             lambda b, h, j, lens: (b, h // G, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, 1, dh),
                                   lambda b, h, j, lens: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((1,), jnp.float32),
                pltpu.VMEM((1,), jnp.float32),
                pltpu.VMEM((1, dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hq, 1, dh), q.dtype),
        interpret=interpret,
    )(lengths, qt, kt, vt)
    return out[:, :, 0, :]
