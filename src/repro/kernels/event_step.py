"""Pallas megakernel for the cluster event scan (base pull configuration).

One ``pl.pallas_call`` program per batched cell (grid over the batch axis):
the packed ``(clk, ctr)`` carry planes stay resident in VMEM across the
whole ``fori_loop`` over events, and the per-dispatch outputs are written
with dynamic stores -- so a cell's entire event history is one kernel
launch instead of ``n_steps`` host-visible scan iterations.

Scope: the **base pull** regime only -- late-binding queue, one controller
estimator ring, optional FC pull counts (``use_fc``); no frozen-priority
(``freeze``), capacity dynamics, heterogeneity, hedging, cold-start or
duplicate machinery.  Everything else dispatches to the pure-jnp oracle in
``repro.kernels.ops.event_step`` (which *is* the fused CPU path).  The
kernel body mirrors the oracle's step op-for-op against the same
:class:`repro.core.fastpath._PlaneLayout` offsets, with two mechanical
substitutions for TPU friendliness: every dynamic gather becomes a one-hot
masked reduction (exact -- the sum adds a single selected value to zeros)
and ``searchsorted`` becomes a ``sum(t <= v)`` count (identical on the
sorted arrival stream).  Rows ``[:n]`` of the outputs are therefore
bit-identical to the oracle; row ``n`` is the shared garbage sentinel both
paths scribble no-op events into.  The parity suite runs this kernel under
``interpret=True`` on CPU.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def event_step_supported(*, freeze, use_fc, fc_push, dyn, het, hedge, cold,
                         dup, stream=False, **_static) -> bool:
    """True when the static feature set falls inside the Pallas kernel's
    scope (base pull, with or without FC pull counts).  ``stream`` (the
    chunked carry-handoff variant) always falls back to the jnp oracle: the
    Pallas body predates the t_stop gate / CSR fn_ev / qcnt carry."""
    return not (freeze or fc_push or dyn or het or hedge or cold or dup
                or stream)


def _gat(vec, i):
    """``vec[i]`` as a one-hot masked reduction (no dynamic gather, which
    Mosaic lowers poorly); exact -- one selected value summed with zeros."""
    ids = jnp.arange(vec.shape[0])
    return jnp.sum(jnp.where(ids == i, vec, jnp.zeros_like(vec)))


def _event_kernel(clk_ref, ctr_ref, t_ref, fnid_ref, p_ref, cost_ref,
                  coef_ref, cores_ref, nodes_ref, cumf_ref, fnev_ref,
                  start_ref, finish_ref, prio_ref, node_ref, *,
                  layout, n, n_nodes, n_slots, window, n_fns, kq, use_fc,
                  horizon, n_steps, ft):
    t_arr = t_ref[0]
    fnid = fnid_ref[0]
    p = p_ref[0]
    cost = cost_ref[0]
    coef = coef_ref[0]
    cores = cores_ref[0]
    nodes = nodes_ref[0]
    cumf = cumf_ref[0]
    fn_ev = fnev_ref[0]

    inf = jnp.asarray(jnp.inf, dtype=ft)
    node_ids = jnp.arange(n_nodes)
    slot_ids = jnp.arange(n_slots)
    fn_ids = jnp.arange(n_fns)
    win_ids = jnp.arange(window)
    ev_ids = jnp.arange(n + 1)
    active = node_ids < nodes
    kmax = kq - 1

    # can=False steps land on the sentinel row n; never-dispatched rows
    # (none exist for a filled cell) read as the oracle's scatter zeros
    start_ref[...] = jnp.zeros((1, n + 1), dtype=ft)
    finish_ref[...] = jnp.zeros((1, n + 1), dtype=ft)
    prio_ref[...] = jnp.zeros((1, n + 1), dtype=ft)
    node_ref[...] = jnp.zeros((1, n + 1), dtype=jnp.int32)

    def step(_, planes):
        st = layout.unpack(*planes)
        ai, head = st["ai"], st["head"]
        fin_s, idx_s = st["fin_s"], st["idx_s"]
        busy, qn, chan = st["busy"], st["qn"], st["chan"]
        ring, rsum, rlen, rpos = (st["ring"], st["rsum"], st["rlen"],
                                  st["rpos"])
        last_t, prev_t, narr = st["last_t"], st["prev_t"], st["narr"]

        # -- event selection: arrival vs earliest completion (arrival wins
        # exact ties, matching the oracle's first-min argmin precedence)
        t_a = _gat(t_arr, ai)
        flat = fin_s.reshape(-1)
        kflat = jnp.argmin(flat)
        t_c = jnp.min(flat)
        now = jnp.minimum(t_a, t_c)
        none_left = jnp.isinf(now)
        do_arr = (t_a <= t_c) & ~none_left
        do_comp = (t_c < t_a) & ~none_left

        # -- completion: free the slot, feed the controller ring ------------
        kn = (kflat // n_slots).astype(jnp.int32)
        ks = kflat % n_slots
        j_done = _gat(idx_s.reshape(-1), kflat)
        f_done = _gat(fnid, j_done)
        m_fd = fn_ids == f_done
        m_cf = m_fd[None, :] & do_comp               # (1, F): en_c == 0
        pos = _gat(rpos[0], f_done)
        v = _gat(p, j_done)
        old = jnp.sum(jnp.where(m_fd[:, None] & (win_ids == pos)[None, :],
                                ring[0], jnp.zeros_like(ring[0])))
        full = _gat(rlen[0], f_done) == window
        rsum = jnp.where(m_cf, rsum + v - jnp.where(full, old, 0.0), rsum)
        ring = jnp.where(m_cf[:, :, None] & (win_ids == pos), v, ring)
        rlen = jnp.where(m_cf & ~full, rlen + 1, rlen)
        rpos = jnp.where(m_cf, (rpos + 1) % window, rpos)
        m_kn = (node_ids == kn) & do_comp
        busy = jnp.where(m_kn, busy - 1, busy)
        fin_s = jnp.where(m_kn[:, None] & (slot_ids == ks), inf, fin_s)

        # -- arrival: enqueue, observe on the controller estimator ----------
        i_ins = jnp.minimum(ai, n)
        do_ins = do_arr
        f_i = _gat(fnid, i_ins)
        first = _gat(narr[0], f_i) == 0
        prev_used = jnp.where(first, now, _gat(last_t[0], f_i))
        m_af = ((fn_ids == f_i) & do_ins)[None, :]
        prev_t = jnp.where(m_af, prev_used, prev_t)
        last_t = jnp.where(m_af, now, last_t)
        narr = jnp.where(m_af, narr + 1, narr)
        qn = jnp.where((node_ids == 0) & do_ins, qn + 1, qn)
        ai = ai + do_arr.astype(jnp.int32)

        # -- dispatch: most-free invoker pulls the global best head ---------
        fs = jnp.where(active, cores - busy, -1)
        k_d = jnp.argmax(fs).astype(jnp.int32)
        est_f = jnp.where(rlen[0] > 0,
                          rsum[0] / jnp.maximum(rlen[0], 1), 0.0)
        hm = jnp.minimum(head, kmax)
        idx_f = jnp.sum(jnp.where(jnp.arange(kq)[None, :] == hm[:, None],
                                  fn_ev, jnp.zeros_like(fn_ev)), axis=1)
        valid = head < narr[0]
        if use_fc:
            # searchsorted(t_arr, v, "right") == count of entries <= v on
            # the sorted stream; the +inf sentinel keeps k0 <= n in range
            k0 = jnp.sum((t_arr <= now - horizon).astype(jnp.int32))
            row_a = jnp.sum(jnp.where((ev_ids == ai)[:, None], cumf,
                                      jnp.zeros_like(cumf)), axis=0)
            row_0 = jnp.sum(jnp.where((ev_ids == k0)[:, None], cumf,
                                      jnp.zeros_like(cumf)), axis=0)
            cnt_f = (row_a - row_0).astype(jnp.float32)
            w_est = coef[2] + coef[3] * cnt_f
        else:
            w_est = coef[2]
        base_f = coef[1] * prev_t[0] + w_est * est_f
        t_idx = jnp.sum(jnp.where(idx_f[:, None] == ev_ids[None, :],
                                  t_arr[None, :],
                                  jnp.zeros_like(t_arr)[None, :]), axis=1)
        prio_f = jnp.where(valid, coef[0] * t_idx + base_f, inf)
        best = jnp.min(prio_f)
        j = jnp.min(jnp.where(valid & (prio_f == best), idx_f, n))
        has_q = j < n
        prio_j = best
        can = ~none_left & (_gat(busy, k_d) < cores) & has_q
        cost_j = _gat(cost, j)
        exec_start = jnp.maximum(now, _gat(chan, k_d)) + cost_j
        m_kd = node_ids == k_d
        chan = jnp.where(m_kd & can, exec_start, chan)
        fin_j = exec_start + _gat(p, j)
        fin_kd = jnp.sum(jnp.where(m_kd[:, None], fin_s,
                                   jnp.zeros_like(fin_s)), axis=0)
        slot_free = jnp.isinf(fin_kd) & (slot_ids < cores)
        s = jnp.argmax(slot_free)
        m_ds = (m_kd[:, None] & (slot_ids == s)[None, :]) & can
        fin_s = jnp.where(m_ds, fin_j, fin_s)
        idx_s = jnp.where(m_ds, j, idx_s)
        busy = jnp.where(m_kd & can, busy + 1, busy)
        qn = jnp.where(m_kd & can, qn - 1, qn)
        head = jnp.where((fn_ids == _gat(fnid, j)) & can, head + 1, head)

        # -- per-dispatch record, stored straight into the output rows ------
        jn = jnp.where(can, j, n).astype(jnp.int32)
        r0 = pl.dslice(0, 1)
        pl.store(start_ref, (r0, pl.dslice(jn, 1)),
                 jnp.full((1, 1), exec_start, dtype=ft))
        pl.store(finish_ref, (r0, pl.dslice(jn, 1)),
                 jnp.full((1, 1), fin_j, dtype=ft))
        pl.store(prio_ref, (r0, pl.dslice(jn, 1)),
                 jnp.full((1, 1), prio_j, dtype=ft))
        pl.store(node_ref, (r0, pl.dslice(jn, 1)),
                 jnp.full((1, 1), k_d, dtype=jnp.int32))

        nxt = {"ai": ai, "head": head, "fin_s": fin_s, "idx_s": idx_s,
               "busy": busy, "qn": qn, "chan": chan,
               "ring": ring, "rsum": rsum, "rlen": rlen, "rpos": rpos,
               "last_t": last_t, "prev_t": prev_t, "narr": narr}
        return layout.pack(nxt)

    lax.fori_loop(0, n_steps, step, (clk_ref[0], ctr_ref[0]))


def event_step_pallas(clk, ctr, inp, *, interpret=False, n_nodes, n_slots,
                      window, use_fc, horizon, n_steps, n_copies=1,
                      fc_ring=1, **_static):
    """Batched base-pull event scan as one Pallas launch per cell.

    Same contract as the oracle path of ``repro.kernels.ops.event_step``:
    ``clk``/``ctr`` are the packed ``(B, f_len)`` / ``(B, i_len)`` carry
    planes, ``inp`` the batched bucket input dict; returns the
    ``(start, finish, prio, node, aux)`` tuple with ``aux == {}``."""
    from ..core import fastpath as _fp     # lazy: core is heavy

    B, n1 = inp["t"].shape
    n = n1 - 1
    n_fns, kq = inp["fn_ev"].shape[1], inp["fn_ev"].shape[2]
    nc = inp["cumf"].shape[1]
    ncoef = inp["coef"].shape[1]
    ft = inp["t"].dtype

    spec = {k: jax.ShapeDtypeStruct(v.shape[1:], v.dtype)
            for k, v in inp.items()}
    layout = _fp._carry_layout(spec, n_nodes=n_nodes, n_slots=n_slots,
                               window=window, freeze=False, fc_push=False,
                               dyn=False, het=False, hedge=False,
                               cold=False, dup=False, n_copies=n_copies,
                               fc_ring=fc_ring)

    kernel = partial(_event_kernel, layout=layout, n=n, n_nodes=n_nodes,
                     n_slots=n_slots, window=window, n_fns=n_fns, kq=kq,
                     use_fc=use_fc, horizon=horizon, n_steps=n_steps, ft=ft)
    row = lambda b: (b, 0)
    start, finish, prio, node = pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, layout.f_len), row),
            pl.BlockSpec((1, layout.i_len), row),
            pl.BlockSpec((1, n1), row),                      # t
            pl.BlockSpec((1, n1), row),                      # fnid
            pl.BlockSpec((1, n1), row),                      # p
            pl.BlockSpec((1, n1), row),                      # cost
            pl.BlockSpec((1, ncoef), row),                   # coef
            pl.BlockSpec((1,), lambda b: (b,)),              # cores
            pl.BlockSpec((1,), lambda b: (b,)),              # nodes
            pl.BlockSpec((1, nc, n_fns), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, n_fns, kq), lambda b: (b, 0, 0)),
        ],
        out_specs=[pl.BlockSpec((1, n1), row)] * 4,
        out_shape=[
            jax.ShapeDtypeStruct((B, n1), ft),
            jax.ShapeDtypeStruct((B, n1), ft),
            jax.ShapeDtypeStruct((B, n1), ft),
            jax.ShapeDtypeStruct((B, n1), jnp.int32),
        ],
        interpret=interpret,
    )(clk, ctr, inp["t"], inp["fnid"], inp["p"], inp["cost"], inp["coef"],
      inp["cores"], inp["nodes"], inp["cumf"], inp["fn_ev"])
    return start, finish, prio, node, {}
