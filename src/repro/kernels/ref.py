"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests).

These are deliberately naive (materialise full score matrices, sequential
scans) -- correctness first, no cleverness.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def attention_ref(q, k, v, *, causal=True, window=-1, softmax_scale=None):
    """q: (B, Sq, Hq, dh); k, v: (B, Sk, Hkv, dh); GQA by head folding.
    Positions are assumed to be aligned suffixes: q token i sits at absolute
    position Sk - Sq + i (the usual prefill/decode layout)."""
    B, Sq, Hq, dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = softmax_scale or (1.0 / math.sqrt(dh))
    qg = q.reshape(B, Sq, Hkv, G, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
    q_pos = jnp.arange(Sq) + (Sk - Sq)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return out.reshape(B, Sq, Hq, dh)


def decode_attention_ref(q, k, v, lengths, *, softmax_scale=None):
    """Single-token decode.  q: (B, Hq, dh); k, v: (B, Sk, Hkv, dh);
    lengths: (B,) int32 -- number of valid cache entries per row."""
    B, Hq, dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = softmax_scale or (1.0 / math.sqrt(dh))
    qg = q.reshape(B, Hkv, G, dh)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k).astype(jnp.float32) * scale
    valid = jnp.arange(Sk)[None] < lengths[:, None]          # (B, Sk)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v.dtype), v)
    return out.reshape(B, Hq, dh)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------
def rglru_ref(a, gx, h0):
    """h_t = a_t * h_{t-1} + gx_t.  a, gx: (B, S, W); h0: (B, W).
    Returns (hs (B, S, W), hT (B, W))."""
    def step(h, inp):
        a_t, gx_t = inp
        h = a_t * h + gx_t
        return h, h

    hT, hs = lax.scan(step, h0, (a.swapaxes(0, 1), gx.swapaxes(0, 1)))
    return hs.swapaxes(0, 1), hT


# ---------------------------------------------------------------------------
# RWKV-6
# ---------------------------------------------------------------------------
def rwkv6_ref(r, k, v, w, u, s0):
    """RWKV-6 recurrence.  r,k,v,w: (B, S, H, dh); u: (H, dh);
    s0: (B, H, dh, dh) fp32 state.  Returns (out (B,S,H,dh), sT)."""
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                 # (B, H, dh)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t).astype(jnp.float32)
        acc = s + u[None, :, :, None].astype(jnp.float32) * kv
        out = jnp.einsum("bhk,bhkv->bhv", r_t.astype(jnp.float32), acc)
        s = w_t[..., None].astype(jnp.float32) * s + kv
        return s, out.astype(r_t.dtype)

    seq = tuple(t.swapaxes(0, 1) for t in (r, k, v, w))
    sT, outs = lax.scan(step, s0, seq)
    return outs.swapaxes(0, 1), sT
