"""Flash attention forward kernel (Pallas TPU).

TPU-native schedule: the grid is (batch, q_head, q_blocks, kv_blocks) with
the kv axis innermost -- TPU grids execute sequentially over the trailing
dimension, so the online-softmax running state (m, l, acc) lives in VMEM
scratch and is carried across kv iterations; the output tile is written on
the last kv block.  Blocks are MXU-aligned (128-multiple q/kv blocks).

Supports causal masking, sliding windows (via absolute positions derived
from block indices) and GQA (kv head = q head // group in the index maps).
Validated in interpret mode against ref.attention_ref (tests/test_kernels).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, q_offset: int,
            block_q: int, block_k: int, kv_blocks: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]                       # (block_q, dh)
    k = k_ref[0, 0]                       # (block_k, dh)
    s = lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale        # (bq, bk)

    q_pos = qi * block_q + lax.broadcasted_iota(jnp.int32, s.shape, 0) \
        + q_offset
    k_pos = kj * block_k + lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                   # (bq,)
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    # fully-masked-so-far rows keep contributing zeros
    p = jnp.where(m_new[:, None] <= NEG_INF / 2, 0.0,
                  jnp.exp(s - m_new[:, None]))
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_new))
    l_new = alpha * l_prev + jnp.sum(p, axis=-1)
    v = v_ref[0, 0]                       # (bk, dh)
    pv = lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kj == kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softmax_scale", "block_q",
                     "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=-1, softmax_scale=None,
                    block_q=128, block_k=128, interpret=False):
    """q: (B, Sq, Hq, dh); k, v: (B, Sk, Hkv, dh) -> (B, Sq, Hq, dh).

    Suffix-aligned positions (q token i at absolute position Sk - Sq + i),
    matching ref.attention_ref."""
    B, Sq, Hq, dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = softmax_scale or (1.0 / math.sqrt(dh))
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, block_q, Sk, block_k)
    q_blocks, kv_blocks = Sq // block_q, Sk // block_k

    # (B, S, H, dh) -> (B, H, S, dh) for clean 2D tiles
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        q_offset=Sk - Sq, block_q=block_q, block_k=block_k,
        kv_blocks=kv_blocks)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, q_blocks, kv_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
