"""RG-LRU linear recurrence kernel (Pallas TPU).

The Griffin recurrence h_t = a_t * h_{t-1} + gx_t is elementwise per
channel, so the channel axis tiles freely over the grid while time is
carried sequentially: grid = (batch, channel_blocks, time_blocks), with the
running state h in VMEM scratch carried across the (innermost) time axis.
Each invocation processes a (block_t, block_w) tile with an in-register
fori_loop over block_t steps -- HBM traffic is exactly one read of (a, gx)
and one write of hs, the memory-bound optimum.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, gx_ref, h0_ref, hs_ref, hT_ref, h_scr, *,
            block_t: int, t_blocks: int):
    tj = pl.program_id(2)

    @pl.when(tj == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0]                 # (block_t, block_w)
    gx = gx_ref[0]

    def step(t, h):
        h = a[t].astype(jnp.float32) * h + gx[t].astype(jnp.float32)
        hs_ref[0, t, :] = h.astype(hs_ref.dtype)
        return h

    h = lax.fori_loop(0, block_t, step, h_scr[...])
    h_scr[...] = h

    @pl.when(tj == t_blocks - 1)
    def _final():
        hT_ref[0] = h_scr[...].astype(hT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_w", "block_t",
                                             "interpret"))
def rglru_scan(a, gx, h0, *, block_w=512, block_t=256, interpret=False):
    """a, gx: (B, S, W); h0: (B, W) -> (hs (B, S, W), hT (B, W))."""
    B, S, W = a.shape
    block_w = min(block_w, W)
    block_t = min(block_t, S)
    assert W % block_w == 0 and S % block_t == 0, (W, block_w, S, block_t)
    w_blocks, t_blocks = W // block_w, S // block_t

    kernel = functools.partial(_kernel, block_t=block_t, t_blocks=t_blocks)
    hs, hT = pl.pallas_call(
        kernel,
        grid=(B, w_blocks, t_blocks),
        in_specs=[
            pl.BlockSpec((1, block_t, block_w), lambda b, w, t: (b, t, w)),
            pl.BlockSpec((1, block_t, block_w), lambda b, w, t: (b, t, w)),
            pl.BlockSpec((1, block_w), lambda b, w, t: (b, w)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, block_w), lambda b, w, t: (b, t, w)),
            pl.BlockSpec((1, block_w), lambda b, w, t: (b, w)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), a.dtype),
            jax.ShapeDtypeStruct((B, W), a.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        interpret=interpret,
    )(a, gx, h0)
    return hs, hT
