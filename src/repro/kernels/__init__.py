"""Pallas TPU kernels for the serving hot spots + pure-jnp oracles.

Layout per the brief: <name>.py holds the pl.pallas_call + BlockSpec kernel,
ops.py the jit'd dispatch wrapper, ref.py the oracles.
"""

from .ops import decode_attention, flash_attention, rglru_scan, rwkv6_scan

__all__ = ["decode_attention", "flash_attention", "rglru_scan", "rwkv6_scan"]
