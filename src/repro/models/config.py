"""Model configuration covering every assigned architecture family.

One dataclass describes dense / MoE / hybrid (RG-LRU) / SSM (RWKV6) /
encoder-decoder / VLM backbones.  Layer stacks are expressed as a repeating
``period``: a tuple of :class:`LayerSpec` that is tiled ``n_layers//len``
times and scanned over (scan-over-layers keeps the HLO size depth-
independent, which matters for the 62-layer dry-runs).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace

# layer mixer kinds
ATTN = "attn"          # softmax attention (causal/bidir/windowed via window)
RGLRU = "rglru"        # Griffin recurrent block (RG-LRU + conv1d)
RWKV = "rwkv"          # RWKV-6 time-mix (data-dependent decay)

GLOBAL_WINDOW = -1     # window sentinel: full attention


@dataclass(frozen=True)
class LayerSpec:
    kind: str = ATTN
    window: int = GLOBAL_WINDOW    # sliding-window size; -1 = full attention
    moe: bool = False              # MoE MLP instead of dense MLP


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                      # 0 -> d_model // n_heads
    period: tuple = (LayerSpec(),)  # repeating layer pattern
    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    mrope: bool = False                  # 3D multimodal RoPE (qwen2-vl)
    mrope_sections: tuple = (16, 24, 24)  # t/h/w splits of d_head/2
    # MoE
    n_experts: int = 0
    top_k: int = 1
    n_shared_experts: int = 0
    moe_d_ff: int = 0                    # per-expert hidden (0 -> d_ff)
    capacity_factor: float = 1.25
    # recurrent (RG-LRU / RWKV)
    lru_width: int = 0                   # 0 -> d_model
    conv1d_width: int = 4
    rwkv_head_size: int = 64
    # encoder-decoder
    encoder_layers: int = 0              # >0 => enc-dec model
    decoder_ratio: int = 4               # dec_len = seq_len // ratio
    # embeddings / housekeeping
    tie_embeddings: bool = True
    vocab_pad_multiple: int = 256        # pad vocab for clean TP sharding
    dtype: str = "bfloat16"
    # distribution
    weight_sharding: str = "tp"          # "tp" | "fsdp_tp" | "fsdp_full"
    batch_sharding: str = "dp"           # "dp" | "full" (batch over all axes)
    moe_constraint: str = ""             # "" | "ep_model" | "ep_data" |
                                         # "tokens_data" -- explicit sharding
                                         # constraints on the MoE dispatch
                                         # buffers (perf hillclimb knob)
    rwkv_state_tp: bool = True           # shard the (dh) state axis over TP
                                         # (baseline; False = batch-only,
                                         # recurrence stays collective-free)
    moe_groups: int = 1                  # >1: per-group (DP-shard-local)
                                         # dispatch -- capacity per group,
                                         # no cross-shard sort/scatter
    kv_cache_dtype: str = ""             # "" (model dtype) | "int8"
                                         # (quantized KV, static scale)
    remat: bool = True
    # modality frontend stub: "none" | "audio" | "vision"
    frontend: str = "none"
    # dry-run instrumentation: XLA cost_analysis counts while-loop bodies
    # ONCE, so the dry-run compiles small unrolled variants to calibrate the
    # per-layer-group cost (see launch/dryrun.py)
    unroll_layers: bool = False
    unroll_q_chunks: bool = False

    # ---------------------------------------------------------------- derived
    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return math.ceil(self.vocab / m) * m

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def lru_dim(self) -> int:
        return self.lru_width or self.d_model

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_size

    @property
    def n_groups(self) -> int:
        """Number of full period repetitions (remainder layers go to the
        unrolled tail -- e.g. gemma3's 62 = 10*6 + 2)."""
        return self.n_layers // len(self.period)

    @property
    def n_tail(self) -> int:
        return self.n_layers % len(self.period)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def max_window(self) -> int:
        return max((s.window for s in self.period), default=GLOBAL_WINDOW)

    def full_attention_everywhere(self) -> bool:
        """True if every mixer is full softmax attention (=> long_500k skip)."""
        return all(s.kind == ATTN and s.window == GLOBAL_WINDOW
                   for s in self.period)

    def layer_specs(self) -> list[LayerSpec]:
        return list(self.period) * self.n_groups + list(self.period[: self.n_tail])

    # -- parameter count (for roofline MODEL_FLOPS = 6*N*D) -------------------
    def param_count(self, active_only: bool = False) -> int:
        d, dh = self.d_model, self.head_dim
        attn = d * self.n_heads * dh + 2 * d * self.n_kv_heads * dh \
            + self.n_heads * dh * d
        dense_mlp = 3 * d * self.d_ff
        ep = self.expert_d_ff
        total = 0
        for spec in self.layer_specs():
            if spec.kind == ATTN:
                total += attn
            elif spec.kind == RGLRU:
                w = self.lru_dim
                total += 2 * d * w + w * d + self.conv1d_width * w + 3 * w
            elif spec.kind == RWKV:
                total += 4 * d * d + d * d  # r,k,v,g,o (decay LoRAs are small)
            if spec.kind == RWKV:
                total += 2 * d * int(3.5 * d)  # channel-mix
            elif spec.moe:
                n_e = self.top_k if active_only else self.n_experts
                total += n_e * 3 * d * ep + d * self.n_experts
                total += self.n_shared_experts * 3 * d * ep
            else:
                total += dense_mlp
            total += 2 * d  # norms
        total += self.padded_vocab * d  # embed (tied)
        if not self.tie_embeddings:
            total += self.padded_vocab * d
        if self.is_encdec:
            # encoder stack (self-attn + mlp) and decoder cross-attention
            enc = self.encoder_layers * (attn + dense_mlp + 2 * d)
            cross = self.n_layers * attn
            total += enc + cross
        return total


def _scale_sections(sections: tuple, d_half: int) -> tuple:
    """Rescale M-RoPE t/h/w sections to a smaller half-head-dim."""
    total = sum(sections)
    scaled = [max(1, s * d_half // total) for s in sections]
    scaled[0] += d_half - sum(scaled)
    return tuple(scaled)


def scale_down(cfg: ModelConfig, layers: int = 2, d_model: int = 64,
               n_heads: int = 4, n_kv_heads: int | None = None,
               d_ff: int = 128, vocab: int = 512) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    period_len = len(cfg.period)
    n_layers = max(layers, period_len)
    n_layers -= n_layers % period_len
    n_kv = n_kv_heads if n_kv_heads is not None else min(cfg.n_kv_heads, n_heads)
    return replace(
        cfg,
        n_layers=n_layers or period_len,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=max(1, n_kv),
        d_head=d_model // n_heads,
        d_ff=d_ff,
        vocab=vocab,
        vocab_pad_multiple=16,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.n_experts else 1,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        moe_d_ff=d_ff if cfg.n_experts else 0,
        lru_width=d_model if cfg.lru_width else 0,
        rwkv_head_size=d_model // n_heads,
        mrope_sections=_scale_sections(cfg.mrope_sections,
                                       (d_model // n_heads) // 2)
        if cfg.mrope else cfg.mrope_sections,
        encoder_layers=min(cfg.encoder_layers, 2) if cfg.encoder_layers else 0,
        period=tuple(
            replace(s, window=min(s.window, 64) if s.window > 0 else s.window)
            for s in cfg.period
        ),
        weight_sharding="tp",
        remat=False,
    )
