"""Neural building blocks (pure JAX, parameter dicts, scan-friendly).

All functions take explicit parameter dicts so layer stacks can be stacked
along a leading axis and driven by ``jax.lax.scan`` (depth-independent HLO).
Attention is implemented flash-style (chunked online softmax over query
blocks) so 32k-token prefill never materialises an S x S score matrix; the
Pallas kernels in ``repro.kernels`` are drop-in TPU replacements validated
against these functions.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * lax.rsqrt(var + eps).astype(x.dtype)) * (1.0 + scale)


def group_norm_heads(x: jax.Array, scale: jax.Array, eps: float = 64e-5) -> jax.Array:
    """Per-head LayerNorm used by RWKV time-mix output.  x: (..., H, dh)."""
    xf = x.astype(jnp.float32)
    mean = xf.mean(axis=-1, keepdims=True)
    var = xf.var(axis=-1, keepdims=True)
    return ((xf - mean) * lax.rsqrt(var + eps)).astype(x.dtype) * scale


# ---------------------------------------------------------------------------
# rotary embeddings (RoPE + 3D multimodal M-RoPE)
# ---------------------------------------------------------------------------
def _rope_angles(positions: jax.Array, d_half: int, theta: float) -> jax.Array:
    """positions (..., S) -> angles (..., S, d_half)."""
    freqs = theta ** (-jnp.arange(0, d_half, dtype=jnp.float32) / d_half)
    return positions[..., None].astype(jnp.float32) * freqs


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 1e4) -> jax.Array:
    """x: (B, S, H, dh), positions: (B, S)."""
    d_half = x.shape[-1] // 2
    ang = _rope_angles(positions, d_half, theta)          # (B, S, d_half)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def apply_mrope(x: jax.Array, positions: jax.Array, sections: tuple,
                theta: float = 1e6) -> jax.Array:
    """Qwen2-VL multimodal RoPE.  positions: (3, B, S) for (t, h, w); the
    rotary dimension is split into ``sections`` (summing to dh/2), each
    rotated by its own positional stream."""
    d_half = x.shape[-1] // 2
    assert sum(sections) == d_half, (sections, d_half)
    ang_parts = []
    off = 0
    for sec, pos in zip(sections, positions):
        freqs = theta ** (-(jnp.arange(off, off + sec, dtype=jnp.float32)) / d_half)
        ang_parts.append(pos[..., None].astype(jnp.float32) * freqs)
        off += sec
    ang = jnp.concatenate(ang_parts, axis=-1)             # (B, S, d_half)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# ---------------------------------------------------------------------------
# attention (flash-style chunked online softmax; GQA; windows; causal/bidir)
# ---------------------------------------------------------------------------
NEG_INF = -1e30


def _mask(q_pos, k_pos, causal: bool, window: int):
    """q_pos (B,Sq), k_pos (B,Sk) -> bool (B,1,1,Sq,Sk); True = attend."""
    dq = q_pos[:, :, None]
    dk = k_pos[:, None, :]
    m = jnp.ones(dq.shape[:1] + (dq.shape[1], dk.shape[2]), dtype=bool)
    if causal:
        m &= dk <= dq
    if window > 0:
        m &= (dq - dk) < window
    m &= dk >= 0          # negative k positions mark invalid (ring buffer)
    return m[:, None, None]


def attention(q, k, v, q_pos, k_pos, *, causal=True, window=-1,
              q_chunk=512, softmax_scale=None, unroll=False):
    """GQA attention.

    q: (B, Sq, Hq, dh); k, v: (B, Sk, Hkv, dh); positions are absolute.
    Returns (B, Sq, Hq, dh).  Query-chunked so peak memory is
    O(Sq_chunk x Sk) regardless of Sq (flash-attention schedule; the kv-axis
    online softmax lives in the Pallas kernel, XLA fuses this form well).
    """
    B, Sq, Hq, dh = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    scale = softmax_scale or (1.0 / math.sqrt(dh))
    qg = q.reshape(B, Sq, Hkv, G, dh)

    def block(q_blk, qpos_blk):
        # q_blk: (B, C, Hkv, G, dh)
        s = jnp.einsum("bchgd,bkhd->bhgck", q_blk, k).astype(jnp.float32) * scale
        m = _mask(qpos_blk, k_pos, causal, window)          # (B,1,1,C,Sk)
        s = jnp.where(m, s, NEG_INF)
        s = jax.nn.softmax(s, axis=-1)
        # guard fully-masked rows (all NEG_INF -> uniform garbage)
        any_valid = jnp.any(m, axis=-1, keepdims=True)
        s = jnp.where(any_valid, s, 0.0).astype(q.dtype)
        return jnp.einsum("bhgck,bkhd->bchgd", s, v)

    if Sq <= q_chunk:
        out = block(qg, q_pos)
        return out.reshape(B, Sq, Hq, dh)

    assert Sq % q_chunk == 0, (Sq, q_chunk)
    n = Sq // q_chunk
    qs = qg.reshape(B, n, q_chunk, Hkv, G, dh).transpose(1, 0, 2, 3, 4, 5)
    ps = q_pos.reshape(B, n, q_chunk).transpose(1, 0, 2)
    if unroll:
        # python-unrolled chunks so XLA cost_analysis sees every block
        # (while-loop bodies are otherwise counted once -- dry-run only)
        out = jnp.stack([block(qs[i], ps[i]) for i in range(n)])
    else:
        out = lax.map(lambda args: block(*args), (qs, ps))  # (n,B,C,Hkv,G,dh)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, Hq, dh)
    return out


# ---------------------------------------------------------------------------
# attention layer (projections + rope + cache handling)
# ---------------------------------------------------------------------------
def attn_params_shapes(cfg, cross: bool = False) -> dict:
    d, dh = cfg.d_model, cfg.head_dim
    shapes = {
        "wq": (d, cfg.n_heads * dh),
        "wk": (d, cfg.n_kv_heads * dh),
        "wv": (d, cfg.n_kv_heads * dh),
        "wo": (cfg.n_heads * dh, d),
    }
    if cfg.qkv_bias and not cross:
        shapes |= {"bq": (cfg.n_heads * dh,), "bk": (cfg.n_kv_heads * dh,),
                   "bv": (cfg.n_kv_heads * dh,)}
    if cfg.qk_norm:
        shapes |= {"q_norm": (dh,), "k_norm": (dh,)}
    return shapes


def attn_project_qkv(p: dict, x: jax.Array, cfg, positions,
                     rope: bool = True):
    B, S, _ = x.shape
    dh = cfg.head_dim
    q = (x @ p["wq"] + p.get("bq", 0)).reshape(B, S, cfg.n_heads, dh)
    k = (x @ p["wk"] + p.get("bk", 0)).reshape(B, S, cfg.n_kv_heads, dh)
    v = (x @ p["wv"] + p.get("bv", 0)).reshape(B, S, cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if rope:
        if cfg.mrope:
            q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
            k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def mlp_params_shapes(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {"w_gate": (d, f), "w_up": (d, f), "w_down": (f, d)}


def swiglu_mlp(p: dict, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


# ---------------------------------------------------------------------------
# Mixture of Experts (GShard-style capacity dispatch; correct FLOP count)
# ---------------------------------------------------------------------------
def _moe_constrain(x, cfg):
    """Optional explicit sharding constraint on the (E, cap, d) dispatch
    buffers -- the perf-hillclimb lever that stops GSPMD from replicating
    the dispatch (EXPERIMENTS.md §Perf)."""
    if not cfg.moe_constraint:
        return x
    from jax.sharding import PartitionSpec as P
    spec = {
        "ep_model": P("model", None, None),     # experts across TP axis
        "ep_data": P("data", None, None),       # experts across DP axis
        "tokens_data": P(None, "data", None),   # capacity rows across DP
    }[cfg.moe_constraint]
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, TypeError):
        return x  # no ambient mesh (single-device smoke tests)
def moe_params_shapes(cfg) -> dict:
    d, f, e = cfg.d_model, cfg.expert_d_ff, cfg.n_experts
    shapes = {
        "router": (d, e),
        "e_gate": (e, d, f),
        "e_up": (e, d, f),
        "e_down": (e, f, d),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * f
        shapes |= {"s_gate": (d, fs), "s_up": (d, fs), "s_down": (fs, d)}
    return shapes


def moe_mlp(p: dict, x: jax.Array, cfg) -> jax.Array:
    """Top-k routed experts with capacity, sort-based dispatch.

    Memory is O(T*k*d) (scatter/gather into an (E, cap, d) buffer) rather
    than the O(T*E*cap) of one-hot GShard dispatch, so 1M-token prefills
    stay lowerable.  FLOPs are the true active-expert FLOPs
    (E*cap*d*f*3*2 with E*cap ~= T*k*capacity_factor).

    With cfg.moe_groups > 1 the dispatch runs per group (GShard-style
    per-group capacity): the group axis aligns with the DP shards so the
    sort/scatter/gather never crosses devices (EXPERIMENTS.md §Perf)."""
    if cfg.moe_groups > 1:
        B, S, d = x.shape
        g = cfg.moe_groups
        assert (B * S) % g == 0, (B, S, g)
        xg = x.reshape(g, (B * S) // g, 1, d)
        if cfg.moe_constraint == "group_data":
            from jax.sharding import PartitionSpec as P
            try:
                xg = jax.lax.with_sharding_constraint(
                    xg, P("data", None, None, None))
            except (ValueError, TypeError):
                pass
        import dataclasses
        sub = dataclasses.replace(cfg, moe_groups=1, moe_constraint="")
        yg = jax.vmap(lambda t: moe_mlp(p, t, sub))(xg)
        return yg.reshape(B, S, d)
    B, S, d = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    cap = max(1, int(cfg.capacity_factor * T * k / E))
    xt = x.reshape(T, d)

    if cfg.moe_constraint == "tokens_data":
        from jax.sharding import PartitionSpec as P
        try:
            xt = jax.lax.with_sharding_constraint(xt, P("data", None))
        except (ValueError, TypeError):
            pass

    gates = jax.nn.softmax(xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    top_w, top_i = lax.top_k(gates, k)                      # (T, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # sort (token, choice) pairs by expert; position within expert via the
    # sorted rank minus the expert's start offset
    flat_e = top_i.reshape(T * k)
    flat_t = jnp.arange(T * k, dtype=jnp.int32) // k
    flat_w = top_w.reshape(T * k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_t = flat_t[order]
    sorted_w = flat_w[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts                    # (E,)
    pos_in_e = jnp.arange(T * k, dtype=jnp.int32) - starts[sorted_e]
    keep = pos_in_e < cap
    slot = jnp.where(keep, sorted_e * cap + pos_in_e, E * cap)  # overflow bin

    xe = jnp.zeros((E * cap + 1, d), xt.dtype).at[slot].set(xt[sorted_t])
    xe = xe[:-1].reshape(E, cap, d)
    xe = _moe_constrain(xe, cfg)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["e_gate"])) \
        * jnp.einsum("ecd,edf->ecf", xe, p["e_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["e_down"])
    ye = _moe_constrain(ye, cfg).reshape(E * cap, d)

    contrib = ye[jnp.minimum(slot, E * cap - 1)] * (
        sorted_w * keep).astype(xt.dtype)[:, None]
    y = jnp.zeros((T, d), xt.dtype).at[sorted_t].add(contrib)
    if cfg.moe_constraint == "tokens_data":
        from jax.sharding import PartitionSpec as P
        try:
            y = jax.lax.with_sharding_constraint(y, P("data", None))
        except (ValueError, TypeError):
            pass

    if cfg.n_shared_experts:
        y = y + (jax.nn.silu(xt @ p["s_gate"]) * (xt @ p["s_up"])) @ p["s_down"]
    return y.reshape(B, S, d)


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (Griffin / RecurrentGemma)
# ---------------------------------------------------------------------------
def rglru_params_shapes(cfg) -> dict:
    d, w = cfg.d_model, cfg.lru_dim
    return {
        "w_x": (d, w), "w_y": (d, w), "w_out": (w, d),
        "conv_w": (cfg.conv1d_width, w), "conv_b": (w,),
        "w_rg": (w, w), "b_rg": (w,),       # recurrence gate
        "w_ig": (w, w), "b_ig": (w,),       # input gate
        "lambda": (w,),                      # per-channel decay parameter
    }


def _rglru_coeffs(p, x, c: float = 8.0):
    """x: (..., w) -> (a, gated_in): decay and gated input per step."""
    r = jax.nn.sigmoid(x @ p["w_rg"] + p["b_rg"])
    i = jax.nn.sigmoid(x @ p["w_ig"] + p["b_ig"])
    log_a = -c * jax.nn.softplus(p["lambda"]) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a.astype(x.dtype), (beta.astype(x.dtype) * i * x)


def rglru_scan(p: dict, xb: jax.Array, h0: jax.Array):
    """Sequential RG-LRU over time.  xb: (B, S, w); h0: (B, w)."""
    a, gx = _rglru_coeffs(p, xb)

    def step(h, inp):
        a_t, gx_t = inp
        h = a_t * h + gx_t
        return h, h

    hT, hs = lax.scan(step, h0, (a.swapaxes(0, 1), gx.swapaxes(0, 1)))
    return hs.swapaxes(0, 1), hT            # (B, S, w), (B, w)


def rglru_block(p: dict, x: jax.Array, cfg, state: dict | None):
    """Griffin recurrent block: dual branch, causal conv1d, RG-LRU.

    state: {"h": (B, w), "conv": (B, width-1, w)} or None for fresh prefill.
    Returns (out (B,S,d), new_state).
    """
    B, S, _ = x.shape
    w = cfg.lru_dim
    width = cfg.conv1d_width
    gate = jax.nn.gelu(x @ p["w_y"])                        # (B, S, w)
    xb = x @ p["w_x"]
    # causal conv1d with carried context
    ctx = state["conv"] if state is not None else jnp.zeros(
        (B, width - 1, w), x.dtype)
    xc = jnp.concatenate([ctx, xb], axis=1)                 # (B, S+width-1, w)
    kernel = p["conv_w"]                                    # (width, w)
    conv = sum(xc[:, i:i + S, :] * kernel[i] for i in range(width))
    conv = conv + p["conv_b"]
    h0 = state["h"] if state is not None else jnp.zeros((B, w), x.dtype)
    hs, hT = rglru_scan(p, conv, h0)
    out = (gate * hs) @ p["w_out"]
    new_state = {"h": hT, "conv": xc[:, S:, :] if width > 1 else ctx}
    return out, new_state


# ---------------------------------------------------------------------------
# RWKV-6 ("Finch") time-mix + channel-mix
# ---------------------------------------------------------------------------
RWKV_LORA = 32


def rwkv_params_shapes(cfg) -> dict:
    d = cfg.d_model
    h = cfg.rwkv_heads
    dh = cfg.rwkv_head_size
    f = int(3.5 * d)
    return {
        # time-mix
        "mu": (5, d),                       # static token-shift mix (r,k,v,g,w)
        "maa_w1": (d, 5 * RWKV_LORA), "maa_w2": (5, RWKV_LORA, d),
        "w0": (d,), "wd_w1": (d, RWKV_LORA * 2), "wd_w2": (RWKV_LORA * 2, d),
        "wr": (d, d), "wk": (d, d), "wv": (d, d), "wg": (d, d), "wo": (d, d),
        "u": (h, dh),                       # bonus for current token
        "ln_x": (d,),
        # channel-mix
        "cm_mu_k": (d,), "cm_mu_r": (d,),
        "cm_wk": (d, f), "cm_wv": (f, d), "cm_wr": (d, d),
    }


def _rwkv_shift(x, x_prev):
    """Token shift: previous timestep per position.  x: (B,S,d); x_prev (B,d)."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def rwkv_time_mix(p: dict, x: jax.Array, cfg, state: dict):
    """state: {"shift": (B,d), "wkv": (B,H,dh,dh) fp32}."""
    B, S, d = x.shape
    H, dh = cfg.rwkv_heads, cfg.rwkv_head_size
    xs = _rwkv_shift(x, state["shift"])
    dx = xs - x
    # data-dependent token-shift mixing (5 LoRA'd mixes: w,k,v,r,g)
    xxx = x + dx * p["mu"][0]
    lora = jnp.tanh(xxx @ p["maa_w1"]).reshape(B, S, 5, RWKV_LORA)
    mixes = jnp.einsum("bsfr,frd->bsfd", lora, p["maa_w2"]) + p["mu"]
    xw, xk, xv, xr, xg = [x + dx * mixes[:, :, i] for i in range(5)]

    # data-dependent per-channel decay
    ww = jnp.tanh(xw @ p["wd_w1"]) @ p["wd_w2"]
    w = jnp.exp(-jnp.exp((p["w0"] + ww).astype(jnp.float32)))  # (B,S,d) in (0,1)

    r = (xr @ p["wr"]).reshape(B, S, H, dh)
    k = (xk @ p["wk"]).reshape(B, S, H, dh)
    v = (xv @ p["wv"]).reshape(B, S, H, dh)
    g = jax.nn.silu(xg @ p["wg"])
    w = w.reshape(B, S, H, dh)

    def step(S_state, inp):
        r_t, k_t, v_t, w_t = inp             # (B,H,dh) each
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t).astype(jnp.float32)
        out = jnp.einsum("bhk,bhkv->bhv", r_t,
                         (S_state + p["u"][None, :, :, None].astype(jnp.float32) * kv
                          ).astype(r_t.dtype).astype(jnp.float32))
        S_state = w_t[..., None].astype(jnp.float32) * S_state + kv
        return S_state, out.astype(r_t.dtype)

    seq = (r.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1), w.swapaxes(0, 1))
    S_new, outs = lax.scan(step, state["wkv"], seq)
    out = outs.swapaxes(0, 1).reshape(B, S, H, dh)
    out = group_norm_heads(out, 1.0 + p["ln_x"].reshape(H, dh))
    out = (out.reshape(B, S, d) * g) @ p["wo"]
    return out, {"shift": x[:, -1, :], "wkv": S_new}


def rwkv_channel_mix(p: dict, x: jax.Array, state: dict):
    xs = _rwkv_shift(x, state["cm_shift"])
    dx = xs - x
    xk = x + dx * p["cm_mu_k"]
    xr = x + dx * p["cm_mu_r"]
    kk = jnp.square(jax.nn.relu(xk @ p["cm_wk"]))
    out = jax.nn.sigmoid(xr @ p["cm_wr"]) * (kk @ p["cm_wv"])
    return out, {"cm_shift": x[:, -1, :]}
