"""Model zoo core: one decoder-stack implementation covering dense, MoE,
hybrid (RG-LRU), SSM (RWKV6), enc-dec and VLM backbones.

Layer stacks are organised as ``n_groups`` repetitions of a period (tuple of
LayerSpec) and executed with ``jax.lax.scan`` over stacked parameters, so
HLO size is independent of depth.  Remainder layers (62 = 10*6 + 2 for
gemma3) live in an unrolled ``tail``.

Public entry points (all pure functions, jit/pjit-friendly):
  init(cfg, rng)                                -> params
  train_step-compatible ``forward(params, batch)`` -> logits
  prefill(params, batch)                        -> logits, cache
  decode_step(params, token_batch, cache, pos)  -> logits, cache
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from . import layers as L
from .config import ATTN, GLOBAL_WINDOW, RGLRU, RWKV, LayerSpec, ModelConfig

# ---------------------------------------------------------------------------
# parameter shape trees
# ---------------------------------------------------------------------------
def _layer_shapes(cfg: ModelConfig, spec: LayerSpec, cross: bool = False) -> dict:
    d = cfg.d_model
    shapes: dict = {"ln1": (d,), "ln2": (d,)}
    if spec.kind == ATTN:
        shapes["attn"] = L.attn_params_shapes(cfg)
    elif spec.kind == RGLRU:
        shapes["rglru"] = L.rglru_params_shapes(cfg)
    elif spec.kind == RWKV:
        shapes["tm"] = {k: v for k, v in L.rwkv_params_shapes(cfg).items()
                        if not k.startswith("cm_")}
    else:
        raise ValueError(spec.kind)
    if spec.kind == RWKV:
        shapes["cm"] = {k: v for k, v in L.rwkv_params_shapes(cfg).items()
                        if k.startswith("cm_")}
    elif spec.moe:
        shapes["moe"] = L.moe_params_shapes(cfg)
    else:
        shapes["mlp"] = L.mlp_params_shapes(cfg)
    if cross:
        shapes["ln_cross"] = (d,)
        shapes["cross"] = L.attn_params_shapes(cfg, cross=True)
    return shapes


def param_shapes(cfg: ModelConfig) -> dict:
    """Full parameter shape tree (leaves are shape tuples)."""
    d, V = cfg.d_model, cfg.padded_vocab
    n_tail = cfg.n_layers % len(cfg.period)
    n_groups = cfg.n_layers // len(cfg.period)
    cross = cfg.is_encdec

    def stack(shape_dict: dict, n: int) -> dict:
        return jax.tree.map(lambda s: (n, *s), shape_dict,
                            is_leaf=lambda x: isinstance(x, tuple))

    tree: dict = {
        "embed": (V, d),
        "final_norm": (d,),
        "groups": {
            f"pos{i}": stack(_layer_shapes(cfg, spec, cross), n_groups)
            for i, spec in enumerate(cfg.period)
        },
    }
    if n_tail:
        tree["tail"] = {
            f"layer{i}": _layer_shapes(cfg, cfg.period[i], cross)
            for i in range(n_tail)
        }
    if not cfg.tie_embeddings:
        tree["lm_head"] = (d, V)
    if cfg.is_encdec:
        enc_cfg = cfg  # same widths; encoder is bidirectional full attention
        tree["enc"] = {
            "groups": {
                "pos0": stack(_layer_shapes(enc_cfg, LayerSpec()), cfg.encoder_layers)
            },
            "final_norm": (d,),
        }
    return tree


def init(cfg: ModelConfig, rng: jax.Array) -> dict:
    """Materialise parameters (smoke tests / examples only -- the dry-run
    uses ``jax.eval_shape(lambda: init(cfg, rng))`` and never allocates)."""
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree.flatten(shapes, is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(rng, len(flat))
    dtype = jnp.dtype(cfg.dtype)

    def make(key, shape):
        if len(shape) <= 1:
            return jnp.zeros(shape, dtype)
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        return (jax.random.normal(key, shape, jnp.float32)
                / math.sqrt(fan_in)).astype(dtype)

    return jax.tree.unflatten(treedef, [make(k, s) for k, s in zip(keys, flat)])


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------
def _layer_cache_shapes(cfg: ModelConfig, spec: LayerSpec, batch: int,
                        cache_len: int) -> dict:
    dh = cfg.head_dim
    if spec.kind == ATTN:
        s = cache_len if spec.window <= 0 else min(spec.window, cache_len)
        shapes = {"k": (batch, s, cfg.n_kv_heads, dh),
                  "v": (batch, s, cfg.n_kv_heads, dh)}
        if cfg.kv_cache_dtype == "int8":
            # per-token, per-head symmetric scales (float32 planes)
            shapes["k_scale"] = (batch, s, cfg.n_kv_heads)
            shapes["v_scale"] = (batch, s, cfg.n_kv_heads)
        return shapes
    if spec.kind == RGLRU:
        w = cfg.lru_dim
        return {"h": (batch, w), "conv": (batch, cfg.conv1d_width - 1, w)}
    if spec.kind == RWKV:
        return {"shift": (batch, cfg.d_model),
                "wkv": (batch, cfg.rwkv_heads, cfg.rwkv_head_size,
                        cfg.rwkv_head_size),
                "cm_shift": (batch, cfg.d_model)}
    raise ValueError(spec.kind)


def cache_shapes(cfg: ModelConfig, batch: int, cache_len: int,
                 enc_len: int = 0) -> dict:
    n_tail = cfg.n_layers % len(cfg.period)
    n_groups = cfg.n_layers // len(cfg.period)

    def stack(d: dict, n: int) -> dict:
        return jax.tree.map(lambda s: (n, *s), d,
                            is_leaf=lambda x: isinstance(x, tuple))

    tree: dict = {
        "groups": {
            f"pos{i}": stack(_layer_cache_shapes(cfg, spec, batch, cache_len),
                             n_groups)
            for i, spec in enumerate(cfg.period)
        }
    }
    if n_tail:
        tree["tail"] = {
            f"layer{i}": _layer_cache_shapes(cfg, cfg.period[i], batch, cache_len)
            for i in range(n_tail)
        }
    if cfg.is_encdec:
        # cross-attention memory: encoder K/V per decoder layer
        dh = cfg.head_dim
        ck = {"ck": (batch, enc_len, cfg.n_kv_heads, dh),
              "cv": (batch, enc_len, cfg.n_kv_heads, dh)}
        tree["cross_groups"] = {
            f"pos{i}": stack(ck, n_groups) for i in range(len(cfg.period))
        }
        if n_tail:
            tree["cross_tail"] = {f"layer{i}": ck for i in range(n_tail)}
    return tree


def _kv_quant(x):
    """Per-token, per-head symmetric int8 quantization over head_dim.

    Returns (int8 values, float32 scales); scales have the value shape minus
    the trailing head_dim axis.  Dynamic scaling tracks the actual K/V
    magnitudes (which vary strongly across layers and positions), unlike a
    static global scale."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1) / 127.0, 1e-8)
    q = jnp.round(xf / scale[..., None]).astype(jnp.int8)
    return q, scale


def _kv_dequant(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               enc_len: int = 0, dtype=None) -> dict:
    dt = dtype or jnp.dtype(cfg.dtype)
    kv_dt = jnp.int8 if cfg.kv_cache_dtype == "int8" else dt

    def make(path, shape):
        name = str(path[-1])
        if "wkv" in name or "_scale" in name:
            return jnp.zeros(shape, jnp.float32)
        if name in ("['k']", "['v']"):      # self-attn KV only (cross stays
            return jnp.zeros(shape, kv_dt)  # full precision)
        return jnp.zeros(shape, dt)

    shapes = cache_shapes(cfg, batch, cache_len, enc_len)
    return jax.tree_util.tree_map_with_path(
        make, shapes, is_leaf=lambda x: isinstance(x, tuple))


# ---------------------------------------------------------------------------
# single layer application
# ---------------------------------------------------------------------------
@dataclass
class Ctx:
    cfg: ModelConfig
    positions: jax.Array          # (B, S) or (3, B, S) for mrope
    mode: str                     # "train" | "prefill" | "decode"
    pos: jax.Array | None = None  # decode write index (scalar int32)
    cross_x: jax.Array | None = None   # encoder output (enc-dec prefill)


def _attn_sublayer(p, spec, x, ctx: Ctx, cache):
    cfg = ctx.cfg
    B, S, _ = x.shape
    q, k_new, v_new = L.attn_project_qkv(p["attn"], x, cfg, ctx.positions)
    qpos = ctx.positions[0] if cfg.mrope else ctx.positions  # (B,S) time axis

    quant = cache is not None and cache["k"].dtype == jnp.int8
    if ctx.mode == "decode":
        Sc = cache["k"].shape[1]
        if spec.window > 0 and spec.window <= Sc:
            slot = ctx.pos % Sc
        else:
            slot = jnp.minimum(ctx.pos, Sc - 1)

        def store(name, new):
            vals, scales = _kv_quant(new) if quant else (new, None)
            val_cache = lax.dynamic_update_slice_in_dim(
                cache[name], vals, slot, axis=1)
            if not quant:
                return val_cache, None, val_cache
            scale_cache = lax.dynamic_update_slice_in_dim(
                cache[f"{name}_scale"], scales, slot, axis=1)
            return val_cache, scale_cache, _kv_dequant(val_cache, scale_cache,
                                                       q.dtype)

        k_cache, ks_cache, k_att = store("k", k_new)
        v_cache, vs_cache, v_att = store("v", v_new)
        idx = jnp.arange(Sc)
        if spec.window > 0 and spec.window <= Sc:
            ages = (ctx.pos - idx) % Sc
            k_pos = ctx.pos - ages                    # absolute; <0 invalid
        else:
            k_pos = jnp.where(idx <= ctx.pos, idx, -1)
        k_pos = jnp.broadcast_to(k_pos[None, :], (B, Sc))
        out = L.attention(q, k_att, v_att, qpos, k_pos,
                          causal=True, window=spec.window,
                          unroll=cfg.unroll_q_chunks)
        new_cache = {"k": k_cache, "v": v_cache}
        if quant:
            new_cache |= {"k_scale": ks_cache, "v_scale": vs_cache}
    else:
        out = L.attention(q, k_new, v_new, qpos, qpos,
                          causal=True, window=spec.window,
                          unroll=cfg.unroll_q_chunks)
        if ctx.mode == "prefill":
            Sc = cache["k"].shape[1]

            def store(name, new):
                vals, scales = _kv_quant(new) if quant else (new, None)
                if S >= Sc:
                    # ring buffer: position s must land in slot s % Sc
                    shift = S % Sc
                    keep = jnp.roll(vals[:, -Sc:], shift, axis=1)
                    keep_s = (jnp.roll(scales[:, -Sc:], shift, axis=1)
                              if quant else None)
                else:
                    keep = lax.dynamic_update_slice_in_dim(
                        cache[name], vals, 0, axis=1)
                    keep_s = (lax.dynamic_update_slice_in_dim(
                        cache[f"{name}_scale"], scales, 0, axis=1)
                        if quant else None)
                return keep, keep_s

            keep_k, keep_ks = store("k", k_new)
            keep_v, keep_vs = store("v", v_new)
            new_cache = {"k": keep_k, "v": keep_v}
            if quant:
                new_cache |= {"k_scale": keep_ks, "v_scale": keep_vs}
        else:
            new_cache = cache
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return out @ p["attn"]["wo"], new_cache


def _bidir_attn_sublayer(p, x, ctx: Ctx):
    """Encoder self-attention (bidirectional, full)."""
    cfg = ctx.cfg
    q, k, v = L.attn_project_qkv(p["attn"], x, cfg, ctx.positions)
    out = L.attention(q, k, v, ctx.positions, ctx.positions,
                      causal=False, window=-1, unroll=cfg.unroll_q_chunks)
    out = out.reshape(*x.shape[:2], cfg.n_heads * cfg.head_dim)
    return out @ p["attn"]["wo"]


def _cross_attn_sublayer(p, x, ctx: Ctx, cache):
    """Decoder cross-attention over encoder memory (no rope)."""
    cfg = ctx.cfg
    B, S, _ = x.shape
    dh = cfg.head_dim
    q = (x @ p["cross"]["wq"]).reshape(B, S, cfg.n_heads, dh)
    if ctx.mode == "decode":
        ck, cv = cache["ck"], cache["cv"]
    else:
        mem = ctx.cross_x
        Sm = mem.shape[1]
        ck = (mem @ p["cross"]["wk"]).reshape(B, Sm, cfg.n_kv_heads, dh)
        cv = (mem @ p["cross"]["wv"]).reshape(B, Sm, cfg.n_kv_heads, dh)
    Sm = ck.shape[1]
    qpos = jnp.zeros((B, S), jnp.int32)
    kpos = jnp.broadcast_to(jnp.arange(Sm)[None], (B, Sm))
    out = L.attention(q, ck, cv, qpos, kpos, causal=False, window=-1,
                      unroll=cfg.unroll_q_chunks)
    out = out.reshape(B, S, cfg.n_heads * dh)
    return out @ p["cross"]["wo"], {"ck": ck, "cv": cv}


def apply_layer(p: dict, spec: LayerSpec, x: jax.Array, ctx: Ctx,
                cache: dict | None, cross_cache: dict | None = None):
    """Pre-norm residual layer; returns (x, new_cache, new_cross_cache)."""
    cfg = ctx.cfg
    h = L.rms_norm(x, p["ln1"])
    if spec.kind == ATTN:
        out, cache = _attn_sublayer(p, spec, h, ctx, cache)
    elif spec.kind == RGLRU:
        out, cache = L.rglru_block(p["rglru"], h, cfg,
                                   cache if ctx.mode != "train" else None)
        if ctx.mode == "train":
            cache = None
    elif spec.kind == RWKV:
        st = cache if ctx.mode != "train" else {
            "shift": jnp.zeros((x.shape[0], cfg.d_model), x.dtype),
            "wkv": jnp.zeros((x.shape[0], cfg.rwkv_heads, cfg.rwkv_head_size,
                              cfg.rwkv_head_size), jnp.float32),
            "cm_shift": jnp.zeros((x.shape[0], cfg.d_model), x.dtype),
        }
        out, tm_new = L.rwkv_time_mix(p["tm"], h, cfg, st)
        cache = (cache or st) | tm_new if ctx.mode != "train" else None
    else:
        raise ValueError(spec.kind)
    x = x + out

    if cfg.is_encdec and "cross" in p:
        h = L.rms_norm(x, p["ln_cross"])
        out, cross_cache = _cross_attn_sublayer(p, h, ctx, cross_cache)
        x = x + out

    h = L.rms_norm(x, p["ln2"])
    if spec.kind == RWKV:
        st = cache if ctx.mode != "train" else {
            "cm_shift": jnp.zeros((x.shape[0], cfg.d_model), x.dtype)}
        out, cm_new = L.rwkv_channel_mix(p["cm"], h, st)
        if ctx.mode != "train":
            cache = cache | cm_new
    elif spec.moe:
        out = L.moe_mlp(p["moe"], h, cfg)
    else:
        out = L.swiglu_mlp(p["mlp"], h)
    x = x + out
    return x, cache, cross_cache


# ---------------------------------------------------------------------------
# full stacks
# ---------------------------------------------------------------------------
def _run_stack(params: dict, x: jax.Array, ctx: Ctx, cache: dict | None):
    """Scan over period groups, then the unrolled tail."""
    cfg = ctx.cfg
    period = cfg.period
    have_cache = cache is not None
    remat = cfg.remat and ctx.mode == "train"

    def make_layer_fn(spec):
        fn = lambda p, h, c, cc: apply_layer(p, spec, h, ctx, c, cc)  # noqa: E731
        if remat:
            fn = jax.checkpoint(
                fn, policy=jax.checkpoint_policies.nothing_saveable)
        return fn

    layer_fns = [make_layer_fn(spec) for spec in period]

    def group_step(h, xs):
        gp, gcache, gcross = xs
        new_caches, new_crosses = [], []
        for i, _spec in enumerate(period):
            c = gcache[f"pos{i}"] if have_cache else None
            cc = gcross[f"pos{i}"] if (gcross is not None) else None
            h, c_new, cc_new = layer_fns[i](gp[f"pos{i}"], h, c, cc)
            new_caches.append(c_new)
            new_crosses.append(cc_new)
        ys = ({f"pos{i}": c for i, c in enumerate(new_caches)}
              if have_cache else None,
              {f"pos{i}": c for i, c in enumerate(new_crosses)}
              if gcross is not None else None)
        return h, ys

    # re-nest stacked params: groups dict pos{i} -> leaves (n_groups, ...)
    gp = params["groups"]
    gcache = cache["groups"] if have_cache else None
    gcross = cache.get("cross_groups") if (have_cache and cfg.is_encdec) else None

    if cfg.unroll_layers:
        # python loop over groups (dry-run flop calibration; see dryrun.py)
        n_groups = cfg.n_groups
        take = lambda t, g: (jax.tree.map(lambda a: a[g], t)  # noqa: E731
                             if t is not None else None)
        ys_list = []
        for g in range(n_groups):
            x, ys_g = group_step(x, (take(gp, g), take(gcache, g),
                                     take(gcross, g)))
            ys_list.append(ys_g)
        stack = lambda *ts: jnp.stack(ts)  # noqa: E731
        new_cache = (jax.tree.map(stack, *[y[0] for y in ys_list])
                     if have_cache else None)
        new_cross = (jax.tree.map(stack, *[y[1] for y in ys_list])
                     if (have_cache and gcross is not None) else None)
    else:
        xs = (gp, gcache, gcross)
        # lax.scan needs every xs leaf to share the leading dim (n_groups)
        if gcache is None and gcross is None:
            x, ys = lax.scan(lambda h, p_: group_step(h, (p_, None, None)),
                             x, gp)
            new_cache, new_cross = None, None
        elif gcross is None:
            x, ys = lax.scan(lambda h, pc: group_step(h, (*pc, None)), x,
                             (gp, gcache))
            new_cache, new_cross = ys[0], None
        else:
            x, ys = lax.scan(group_step, x, xs)
            new_cache, new_cross = ys

    tail_cache, tail_cross = {}, {}
    if "tail" in params:
        for i in range(len(params["tail"])):
            c = cache["tail"][f"layer{i}"] if have_cache else None
            cc = (cache.get("cross_tail", {}).get(f"layer{i}")
                  if have_cache and cfg.is_encdec else None)
            x, c_new, cc_new = layer_fns[i](params["tail"][f"layer{i}"],
                                            x, c, cc)
            tail_cache[f"layer{i}"] = c_new
            tail_cross[f"layer{i}"] = cc_new

    if not have_cache:
        return x, None
    out_cache: dict = {"groups": new_cache}
    if "tail" in params:
        out_cache["tail"] = tail_cache
    if cfg.is_encdec:
        out_cache["cross_groups"] = new_cross
        if tail_cross:
            out_cache["cross_tail"] = tail_cross
    return x, out_cache


def _encode(params: dict, cfg: ModelConfig, emb: jax.Array,
            positions: jax.Array) -> jax.Array:
    """Bidirectional encoder stack (enc-dec models)."""
    ctx = Ctx(cfg=cfg, positions=positions, mode="train")

    def step(h, gp):
        hn = L.rms_norm(h, gp["ln1"])
        out = _bidir_attn_sublayer(gp, hn, ctx)
        h = h + out
        hn = L.rms_norm(h, gp["ln2"])
        h = h + L.swiglu_mlp(gp["mlp"], hn)
        return h, None

    if cfg.unroll_layers:
        x = emb
        stacked = params["enc"]["groups"]["pos0"]
        n = jax.tree.leaves(stacked)[0].shape[0]
        for g in range(n):
            x, _ = step(x, jax.tree.map(lambda a: a[g], stacked))
    else:
        x, _ = lax.scan(step, emb, params["enc"]["groups"]["pos0"])
    return L.rms_norm(x, params["enc"]["final_norm"])


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------
def _embed(params, cfg, tokens=None, embeds=None):
    if embeds is not None:
        return embeds.astype(jnp.dtype(cfg.dtype))
    return params["embed"][tokens]


def _unembed(params, cfg, x):
    x = L.rms_norm(x, params["final_norm"])
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    return x @ head


def _default_positions(cfg, B, S, offset=0):
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None] + offset, (B, S))
    if cfg.mrope:
        pos = jnp.broadcast_to(pos[None], (3, B, S))
    return pos


def forward(params: dict, cfg: ModelConfig, batch: dict) -> jax.Array:
    """Training forward -> logits (B, S, V).

    batch keys: tokens (B,S) int32; optional positions; enc-dec adds
    enc_embeds (B,Se,d) [audio stub] or enc_tokens; vlm adds embeds."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = batch.get("positions")
    if positions is None:
        positions = _default_positions(cfg, B, S)
    x = _embed(params, cfg, tokens, batch.get("embeds"))
    ctx = Ctx(cfg=cfg, positions=positions, mode="train")
    if cfg.is_encdec:
        enc_in = batch.get("enc_embeds")
        if enc_in is None:
            enc_in = _embed(params, cfg, batch["enc_tokens"])
        Se = enc_in.shape[1]
        enc_pos = _default_positions(cfg, B, Se)
        ctx.cross_x = _encode(params, cfg, enc_in, enc_pos)
    x, _ = _run_stack(params, x, ctx, None)
    return _unembed(params, cfg, x)


def prefill(params: dict, cfg: ModelConfig, batch: dict, cache: dict):
    """Prompt processing; fills ``cache`` (created by init_cache) and returns
    (last-token logits (B, V), cache)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = batch.get("positions")
    if positions is None:
        positions = _default_positions(cfg, B, S)
    x = _embed(params, cfg, tokens, batch.get("embeds"))
    ctx = Ctx(cfg=cfg, positions=positions, mode="prefill")
    if cfg.is_encdec:
        enc_in = batch.get("enc_embeds")
        if enc_in is None:
            enc_in = _embed(params, cfg, batch["enc_tokens"])
        Se = enc_in.shape[1]
        ctx.cross_x = _encode(params, cfg, enc_in, _default_positions(cfg, B, Se))
    x, cache = _run_stack(params, x, ctx, cache)
    logits = _unembed(params, cfg, x[:, -1:, :])
    return logits[:, 0, :], cache


def decode_step(params: dict, cfg: ModelConfig, tokens: jax.Array,
                cache: dict, pos: jax.Array):
    """One decode step.  tokens (B,) int32; pos scalar int32 (current index).
    Returns (logits (B, V), new cache)."""
    B = tokens.shape[0]
    positions = _default_positions(cfg, B, 1, offset=pos)
    x = _embed(params, cfg, tokens[:, None])
    ctx = Ctx(cfg=cfg, positions=positions, mode="decode", pos=pos)
    x, cache = _run_stack(params, x, ctx, cache)
    logits = _unembed(params, cfg, x)
    return logits[:, 0, :], cache
