"""Model zoo: config + pure-JAX implementations of the assigned archs."""

from .config import ATTN, GLOBAL_WINDOW, LayerSpec, ModelConfig, RGLRU, RWKV, scale_down
from .model import (
    cache_shapes,
    decode_step,
    forward,
    init,
    init_cache,
    param_shapes,
    prefill,
)

__all__ = [
    "ATTN", "GLOBAL_WINDOW", "LayerSpec", "ModelConfig", "RGLRU", "RWKV",
    "cache_shapes", "decode_step", "forward", "init", "init_cache",
    "param_shapes", "prefill", "scale_down",
]
