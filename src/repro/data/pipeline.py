"""Deterministic synthetic token pipeline (sharded, restart-safe).

Every batch is a pure function of (seed, step) so a restarted job resumes
byte-identically from the checkpointed step -- the data-side half of
fault tolerance.  ``host_shard`` slices the global batch for multi-host
feeding (each host materialises only its slice; device placement is then
handled by jit in_shardings).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    global_batch: int
    seq_len: int
    seed: int = 1234


def batch_at(cfg: DataConfig, step: int, host_id: int = 0,
             n_hosts: int = 1) -> dict:
    """Synthetic LM batch for ``step``: tokens + next-token labels."""
    assert cfg.global_batch % n_hosts == 0
    per_host = cfg.global_batch // n_hosts
    rng = np.random.default_rng((cfg.seed, step, host_id))
    toks = rng.integers(0, cfg.vocab, (per_host, cfg.seq_len + 1),
                        dtype=np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class DataIterator:
    """Stateful wrapper with explicit step save/restore."""

    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 host_id: int = 0, n_hosts: int = 1):
        self.cfg = cfg
        self.step = start_step
        self.host_id = host_id
        self.n_hosts = n_hosts

    def __next__(self) -> dict:
        batch = batch_at(self.cfg, self.step, self.host_id, self.n_hosts)
        self.step += 1
        return batch

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])
