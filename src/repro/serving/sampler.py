"""Token samplers (greedy / temperature / top-k)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature(logits: jax.Array, rng: jax.Array, t: float = 1.0) -> jax.Array:
    return jax.random.categorical(rng, logits / max(t, 1e-4)).astype(jnp.int32)


def top_k(logits: jax.Array, rng: jax.Array, k: int = 40,
          t: float = 1.0) -> jax.Array:
    vals, idx = jax.lax.top_k(logits, k)
    choice = jax.random.categorical(rng, vals / max(t, 1e-4))
    return jnp.take_along_axis(idx, choice[..., None], axis=-1)[..., 0] \
        .astype(jnp.int32)
