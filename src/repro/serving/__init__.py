"""Serving engine: the paper's scheduler driving real JAX model execution."""

from .engine import Endpoint, ServingEngine
from .kvcache import SlotPool

__all__ = ["Endpoint", "ServingEngine", "SlotPool"]
