"""JAX serving engine driven by the paper's node scheduler.

This is the real-execution counterpart of the simulator: endpoints are
(model config, generation profile) pairs, each with resident JAX params
("warm container" = materialised params + jitted step; cold start = param
init + XLA compile, measured for real).  The node has ``slots`` decode
lanes; admission is **non-preemptive and slot-based** exactly as in paper
§IV-A: a request admitted to a lane generates to completion, the queue is a
priority queue over FIFO/SEPT/EECT/RECT/FC, and E[p] comes from the last-10
completed calls of the same endpoint.

On CPU this runs tiny models for tests/examples; on TPU the same engine
drives full models (the decode step is whatever ``make_serve_fn`` returns).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.estimator import RuntimeEstimator
from repro.core.policies import make_policy
from repro.core.queues import PriorityQueue
from repro.core.request import Request
from repro.models import decode_step, forward, init, init_cache
from repro.models.config import ModelConfig


@dataclass
class Endpoint:
    """A deployable function: model + generation profile."""

    name: str
    cfg: ModelConfig
    prompt_len: int = 8
    gen_len: int = 16
    params: dict | None = None        # resident weights (warm)
    _decode = None                    # jitted decode step

    def warm_up(self, rng) -> float:
        """Materialise params + compile (the 'container cold start').
        Returns wall seconds spent."""
        t0 = time.monotonic()
        if self.params is None:
            self.params = init(self.cfg, rng)
        if self._decode is None:
            cfg = self.cfg
            self._decode = jax.jit(
                lambda p, t, c, pos: decode_step(p, cfg, t, c, pos))
            cache = init_cache(cfg, 1, self.prompt_len + self.gen_len + 8)
            tok = jnp.zeros((1,), jnp.int32)
            jax.block_until_ready(
                self._decode(self.params, tok, cache, jnp.int32(0))[0])
        return time.monotonic() - t0

    @property
    def is_warm(self) -> bool:
        return self.params is not None and self._decode is not None


@dataclass
class ActiveCall:
    request: Request
    endpoint: Endpoint
    cache: dict
    pos: int
    remaining: int
    token: jnp.ndarray


class ServingEngine:
    """Single-node engine: priority queue + slot lanes + per-endpoint decode."""

    def __init__(self, endpoints: list[Endpoint], slots: int = 4,
                 policy: str = "fc", seed: int = 0,
                 prewarm: bool = True):
        self.endpoints = {e.name: e for e in endpoints}
        self.slots = slots
        self.policy = make_policy(policy)
        self.estimator = RuntimeEstimator()
        self.queue = PriorityQueue()
        self.active: list[ActiveCall] = []
        self.completed: list[Request] = []
        self.cold_starts = 0
        self._rng = jax.random.PRNGKey(seed)
        self._t0 = time.monotonic()
        if prewarm:
            for ep in endpoints:
                self._rng, sub = jax.random.split(self._rng)
                ep.warm_up(sub)

    # -- clock ----------------------------------------------------------------
    def now(self) -> float:
        return time.monotonic() - self._t0

    # -- intake ---------------------------------------------------------------
    def submit(self, endpoint: str, request_time: float | None = None) -> Request:
        req = Request(fn=endpoint, r=request_time if request_time is not None
                      else self.now())
        now = self.now()
        req.r_prime = now
        self.estimator.observe_arrival(req.fn, now)
        self.queue.push(req, self.policy.priority(req, self.estimator, now))
        return req

    # -- scheduling (paper §IV: slot admission, non-preemptive) ---------------
    def _admit(self) -> None:
        while self.queue and len(self.active) < self.slots:
            req = self.queue.pop()
            ep = self.endpoints[req.fn]
            if not ep.is_warm:                  # cold start, measured
                self._rng, sub = jax.random.split(self._rng)
                ep.warm_up(sub)
                self.cold_starts += 1
                req.cold_start = True
            req.start = self.now()
            cache = init_cache(ep.cfg, 1, ep.prompt_len + ep.gen_len + 8)
            self.active.append(ActiveCall(
                request=req, endpoint=ep, cache=cache, pos=0,
                remaining=ep.prompt_len + ep.gen_len,
                token=jnp.zeros((1,), jnp.int32)))

    # -- execution -------------------------------------------------------------
    def _step_call(self, call: ActiveCall) -> None:
        ep = call.endpoint
        logits, call.cache = ep._decode(
            ep.params, call.token, call.cache, jnp.int32(call.pos))
        call.token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        call.pos += 1
        call.remaining -= 1

    def run(self, until_idle: bool = True, max_wall_s: float = 120.0) -> None:
        """Drive the engine until all submitted work completes."""
        deadline = time.monotonic() + max_wall_s
        while (self.queue or self.active) and time.monotonic() < deadline:
            self._admit()
            if not self.active:
                time.sleep(0.001)
                continue
            # one decode step per active lane (lockstep batch iteration)
            for call in list(self.active):
                self._step_call(call)
                if call.remaining <= 0:
                    self._finish(call)

    def _finish(self, call: ActiveCall) -> None:
        self.active.remove(call)
        req = call.request
        req.finish = self.now()
        req.c = req.finish
        service = req.finish - req.start
        req.p_true = service
        self.estimator.observe_completion(req.fn, service)
        self.completed.append(req)

    # -- metrics ----------------------------------------------------------------
    def summary(self) -> dict:
        resp = np.array([r.response_time for r in self.completed])
        return {
            "n": len(self.completed),
            "R_avg": float(resp.mean()),
            "R_p50": float(np.percentile(resp, 50)),
            "R_p95": float(np.percentile(resp, 95)),
            "cold_starts": self.cold_starts,
        }
