"""Slot-based KV cache manager for batched serving.

A fixed pool of ``n_slots`` lanes, each with a ``max_len`` KV budget --
the TPU analogue of "exactly one CPU core per container" (paper §IV-A):
a request owns one lane with a fixed HBM reservation until completion, so
the batch is never recomposed mid-flight (no churn / preemption).

The manager tracks per-slot fill levels for ragged attention (the
``lengths`` operand of kernels.decode_attention) and exposes assign /
release with O(1) free-list operations.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.models import init_cache
from repro.models.config import ModelConfig


@dataclass
class SlotPool:
    cfg: ModelConfig
    n_slots: int
    max_len: int
    cache: dict = None                  # batched cache, leaves (..., B, S, ...)
    lengths: np.ndarray = None          # (n_slots,) fill level
    owners: list = None                 # request id per slot (None = free)
    _free: list = field(default_factory=list)

    def __post_init__(self):
        self.cache = init_cache(self.cfg, self.n_slots, self.max_len)
        self.lengths = np.zeros(self.n_slots, np.int32)
        self.owners = [None] * self.n_slots
        self._free = list(range(self.n_slots))

    @property
    def free_slots(self) -> int:
        return len(self._free)

    def assign(self, request_id: int) -> int:
        """Reserve a lane; raises IndexError when full (caller queues)."""
        slot = self._free.pop()
        self.owners[slot] = request_id
        self.lengths[slot] = 0
        return slot

    def advance(self, slot: int, n: int = 1) -> None:
        self.lengths[slot] = min(self.lengths[slot] + n, self.max_len)

    def release(self, slot: int) -> None:
        assert self.owners[slot] is not None, f"slot {slot} already free"
        self.owners[slot] = None
        self.lengths[slot] = 0
        self._free.append(slot)

    def lengths_array(self) -> jnp.ndarray:
        return jnp.asarray(self.lengths)

    def utilization(self) -> float:
        return 1.0 - len(self._free) / self.n_slots
