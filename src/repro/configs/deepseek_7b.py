"""DeepSeek-LLM 7B: llama-architecture dense MHA.

[arXiv:2401.02954; hf]
30L d_model=4096 32H (kv=32, MHA) d_ff=11008 vocab=102400.
Full attention => long_500k skipped.
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab=102400,
    period=(LayerSpec(),),
    rope_theta=1e4,
    tie_embeddings=False,
)
