"""Qwen3-1.7B: dense GQA with qk-norm.

[hf:Qwen/Qwen3-1.7B; hf]
28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936, head_dim=128.
Full attention => long_500k skipped.
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=6144,
    vocab=151936,
    period=(LayerSpec(),),
    qk_norm=True,
    rope_theta=1e6,
)
