"""RWKV-6 "Finch" 3B: attention-free, data-dependent decay.

[arXiv:2404.05892; hf]
32L d_model=2560 d_ff~=8960 (3.5x) vocab=65536, head_size=64 (40 heads).
Constant-size state => long_500k RUNS (O(1) decode state).
"""

from repro.models.config import LayerSpec, ModelConfig, RWKV

CONFIG = ModelConfig(
    name="rwkv6-3b",
    n_layers=32,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=8960,
    vocab=65536,
    period=(LayerSpec(kind=RWKV),),
    rwkv_head_size=64,
)
