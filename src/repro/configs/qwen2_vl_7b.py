"""Qwen2-VL 7B language backbone with M-RoPE.

[arXiv:2409.12191; hf]
28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.  The vision
frontend (dynamic-resolution ViT) is a STUB: input_specs() provides
3D position ids (t/h/w) and precomputed patch embeddings.  Full
attention => long_500k skipped.
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    period=(LayerSpec(),),
    qkv_bias=True,
    mrope=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    frontend="vision",
)
