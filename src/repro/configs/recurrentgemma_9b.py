"""RecurrentGemma-9B (Griffin): RG-LRU + local attention, 2:1 pattern.

[arXiv:2402.19427; unverified]
38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000, window 2048.
38 = 12 * (rec, rec, attn) + tail (rec, rec).  Sub-quadratic =>
long_500k RUNS (constant-size recurrent state + bounded window KV).
"""

from repro.models.config import ATTN, LayerSpec, ModelConfig, RGLRU

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    period=(
        LayerSpec(kind=RGLRU),
        LayerSpec(kind=RGLRU),
        LayerSpec(kind=ATTN, window=2048),
    ),
    lru_width=4096,
    conv1d_width=4,
    rope_theta=1e4,
)
