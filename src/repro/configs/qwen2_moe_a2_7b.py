"""Qwen1.5/2-MoE A2.7B: 60 routed experts top-4 + 4 shared experts.

[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
24L d_model=2048 16H (kv=16, MHA) expert d_ff=1408 vocab=151936.
60 % 16 != 0 => expert-internal d_ff TP fallback (DESIGN.md §5).
Full attention => long_500k skipped.
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=151936,
    period=(LayerSpec(moe=True),),
    n_experts=60,
    top_k=4,
    n_shared_experts=4,
    moe_d_ff=1408,
    qkv_bias=True,
    rope_theta=1e6,
)
