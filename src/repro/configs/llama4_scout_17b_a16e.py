"""Llama-4 Scout 17B-active / 16-expert MoE (early-fusion multimodal LM).

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (GQA kv=8) expert d_ff=8192 vocab=202048; 16 routed
experts top-1 + 1 shared expert per layer.  Pure full attention =>
long_500k is skipped (DESIGN.md §4).  109B total params => 2D (FSDP x TP)
weight sharding.
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    period=(LayerSpec(moe=True),),
    n_experts=16,
    top_k=1,
    n_shared_experts=1,
    moe_d_ff=8192,
    rope_theta=5e5,
    weight_sharding="fsdp_tp",
)
