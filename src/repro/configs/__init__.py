"""Assigned-architecture registry: ``get_config(arch_id)`` + shape sets.

Each architecture has its own module with the exact published configuration;
``SHAPES`` defines the four evaluation cells shared by the LM family.
``applicable_shapes(cfg)`` applies the brief's skip rules (long_500k only
for sub-quadratic archs; decode shapes only for archs with a decoder).
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass

from repro.models.config import ModelConfig

ARCHS = [
    "llama4_scout_17b_a16e",
    "qwen2_moe_a2_7b",
    "recurrentgemma_9b",
    "seamless_m4t_large_v2",
    "gemma3_27b",
    "qwen2_5_14b",
    "qwen3_1_7b",
    "deepseek_7b",
    "rwkv6_3b",
    "qwen2_vl_7b",
]

# dashed aliases as listed in the assignment
ALIASES = {
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "gemma3-27b": "gemma3_27b",
    "qwen2.5-14b": "qwen2_5_14b",
    "qwen3-1.7b": "qwen3_1_7b",
    "deepseek-7b": "deepseek_7b",
    "rwkv6-3b": "rwkv6_3b",
    "qwen2-vl-7b": "qwen2_vl_7b",
}


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """Brief's skip rules.  long_500k needs sub-quadratic attention (skip for
    pure full-attention archs; see DESIGN.md §4)."""
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if not cfg.full_attention_everywhere() and not cfg.is_encdec:
        shapes.append("long_500k")
    return shapes


def all_cells() -> list[tuple[str, str]]:
    """All (arch, shape) cells -- 40 total, skips excluded at the caller."""
    return [(a, s) for a in ARCHS for s in SHAPES]
