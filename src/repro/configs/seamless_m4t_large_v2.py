"""SeamlessM4T-large-v2 transformer backbone (speech/text enc-dec).

[arXiv:2308.11596; hf]
24L encoder + 24L decoder, d_model=1024 16H (MHA kv=16) d_ff=8192
vocab=256206.  Modality frontend (w2v-BERT conv feature extractor) is a
STUB: input_specs() provides precomputed frame embeddings (B, S, d).
Decoder length = seq_len // 4.  vocab padded 256206 -> %256 for TP.
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    period=(LayerSpec(),),
    encoder_layers=24,
    decoder_ratio=4,
    tie_embeddings=True,
    frontend="audio",
)
