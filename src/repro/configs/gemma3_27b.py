"""Gemma-3 27B: dense, 5 local : 1 global attention, 128k context.

[hf:google/gemma-3-*; unverified]
62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144, qk-norm,
window=1024 for local layers.  62 = 10*(5 local + 1 global) + 2 local
tail.  5/6 of layers have bounded KV => long_500k RUNS (global layers
hold the full 512k KV, sequence-sharded).
"""

from repro.models.config import LayerSpec, ModelConfig

_LOCAL = LayerSpec(window=1024)
_GLOBAL = LayerSpec(window=-1)

CONFIG = ModelConfig(
    name="gemma3-27b",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_head=128,
    d_ff=21504,
    vocab=262144,
    period=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
    qk_norm=True,
    rope_theta=1e6,
)
