"""Azure-calibrated workload synthesizer: fit a trace, generate planet-days.

The vendored Azure slice (``data/azure_trace_slice.csv``) is 32 functions
over 15 minutes -- enough to calibrate on, far too short to stress a
100+-node fleet.  This module fits the slice's *marginals* and then
synthesizes arbitrarily long, arbitrarily wide streams lazily, as
:class:`~repro.core.streamscan.StreamChunk` iterators, so a
multi-hour / 10k-function / million-invocation day never has to exist in
memory at once.

Calibration recipe
------------------
The method follows the OS-Scheduling load-generator workflow
(``loadgen/dataset/gen_workload.py`` + ``compare_workload_to_azure.py`` in
the panosstef/OS-Scheduling repo; the files are not vendored in this
checkout, so the recipe is inlined here):

1. **Bin** the trace into per-minute invocation counts per function (the
   Azure Functions 2019 dataset's native shape -- our CSV is already
   binned).
2. **Popularity**: each function's share of total invocations.  Azure
   popularity is heavy-tailed, so fit a Zipf exponent ``alpha`` by least
   squares on ``log(count) ~ -alpha * log(rank)``; the fitted exponent
   lets :func:`expand_catalog` extrapolate the measured head (32 fns) to a
   synthetic tail (10k+ fns) with the same decay.
3. **Arrival intensity**: the per-minute *total* count profile, kept as a
   piecewise-constant diurnal cycle.  Generation draws each simulated
   minute's count ``~ Poisson(rate)`` from the cycled profile and places
   arrivals uniformly within the minute -- exactly the expansion
   :func:`~repro.core.traces.requests_from_trace` applies to the real
   trace, so the synthesized inter-arrival (IAT) marginal matches the
   trace's by construction, up to Poisson noise.
4. **Durations**: per-function service times come from the calibrated
   SeBS profiles (Table I lognormals); trace names map onto profiles via
   the deterministic CRC32 mapping (:func:`~repro.core.traces.profile_for`),
   again matching the real-trace expansion.
5. **Verify** the fit with distance metrics
   (:func:`SynthModel.fit_report`): two-sample Kolmogorov-Smirnov
   statistics on the IAT and duration marginals (synth stream vs the
   expanded real trace) and Spearman rank correlation between synthesized
   and traced per-function invocation counts.  Thresholds are pinned by
   ``tests/test_synth.py``.

Everything is deterministic per ``seed``: each simulated minute draws
from ``default_rng([seed, minute])``, so chunk iterators can be
re-instantiated (the streaming engine may iterate a stream more than
once) and a given ``(model, seed)`` always produces the identical stream.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterator

import numpy as np

from .traces import load_azure_trace, profile_for
from .workload import PROFILES

__all__ = [
    "SynthModel",
    "expand_catalog",
    "fit_azure_trace",
    "fit_azure_csv",
    "ks_statistic",
    "spearman_rank",
]


def ks_statistic(a: np.ndarray, b: np.ndarray) -> float:
    """Two-sample Kolmogorov-Smirnov statistic, sup |F_a - F_b|."""
    a = np.sort(np.asarray(a, dtype=np.float64))
    b = np.sort(np.asarray(b, dtype=np.float64))
    if a.size == 0 or b.size == 0:
        return 1.0
    grid = np.concatenate([a, b])
    fa = np.searchsorted(a, grid, side="right") / a.size
    fb = np.searchsorted(b, grid, side="right") / b.size
    return float(np.max(np.abs(fa - fb)))


def spearman_rank(x: np.ndarray, y: np.ndarray) -> float:
    """Spearman rank correlation (Pearson on average ranks)."""
    def ranks(v):
        v = np.asarray(v, dtype=np.float64)
        order = np.argsort(v, kind="stable")
        r = np.empty(v.size)
        r[order] = np.arange(v.size, dtype=np.float64)
        # average ties so equal counts share a rank
        for u in np.unique(v):
            m = v == u
            if m.sum() > 1:
                r[m] = r[m].mean()
        return r

    rx, ry = ranks(x), ranks(y)
    sx, sy = rx.std(), ry.std()
    if sx == 0 or sy == 0:
        return 0.0
    return float(np.mean((rx - rx.mean()) * (ry - ry.mean())) / (sx * sy))


@dataclass
class SynthModel:
    """A fitted workload model: function catalog + popularity + diurnal
    arrival-intensity cycle.  Generation is lazy and deterministic per
    seed (see module docstring)."""

    fns: tuple[str, ...]                 # catalog, popularity-rank order
    popularity: np.ndarray               # (F,) probabilities, sums to 1
    minute_rate: np.ndarray              # (M,) expected arrivals per minute
    minute_s: float = 60.0
    zipf_alpha: float = 1.0              # fitted popularity decay exponent
    profile_names: tuple[str, ...] = ()  # SeBS profile per catalog fn
    _medians: np.ndarray = field(default=None, repr=False)
    _sigmas: np.ndarray = field(default=None, repr=False)

    def __post_init__(self):
        self.popularity = np.asarray(self.popularity, dtype=np.float64)
        self.popularity = self.popularity / self.popularity.sum()
        self.minute_rate = np.asarray(self.minute_rate, dtype=np.float64)
        if not self.profile_names:
            self.profile_names = tuple(profile_for(f) for f in self.fns)
        self._medians = np.array(
            [PROFILES[p].median_s for p in self.profile_names])
        self._sigmas = np.array(
            [PROFILES[p].sigma for p in self.profile_names])

    @property
    def mean_rate_per_s(self) -> float:
        return float(self.minute_rate.mean() / self.minute_s)

    # -- generation --------------------------------------------------------

    def _minute(self, minute: int, seed: int, rate_scale: float):
        """One simulated minute: (times, fn indices, durations)."""
        rng = np.random.default_rng([seed, minute])
        rate = self.minute_rate[minute % self.minute_rate.size] * rate_scale
        count = int(rng.poisson(rate))
        if count == 0:
            z = np.zeros(0)
            return z, np.zeros(0, dtype=np.int64), z
        t = np.sort(rng.uniform(minute * self.minute_s,
                                (minute + 1) * self.minute_s, size=count))
        f = rng.choice(self.popularity.size, size=count, p=self.popularity)
        # per-fn lognormal service times (workload.Profile.sample, batched)
        p = self._medians[f] * np.exp(self._sigmas[f] * rng.standard_normal(count))
        return t, f.astype(np.int64), np.maximum(p, 1e-4)

    def iter_minutes(self, seed: int = 0, *, minutes: int | None = None,
                     max_invocations: int | None = None,
                     rate_scale: float = 1.0) -> Iterator:
        """Yield per-minute :class:`StreamChunk`-shaped triples lazily."""
        from .streamscan import StreamChunk
        total = 0
        m = 0
        while True:
            if minutes is not None and m >= minutes:
                return
            t, f, p = self._minute(m, seed, rate_scale)
            if max_invocations is not None and total + t.size >= max_invocations:
                keep = max_invocations - total
                yield StreamChunk(r=t[:keep], fn=f[:keep], p=p[:keep])
                return
            if t.size:
                yield StreamChunk(r=t, fn=f, p=p)
                total += t.size
            m += 1

    def stream(self, seed: int = 0, *, minutes: int | None = None,
               max_invocations: int | None = None, rate_scale: float = 1.0):
        """An :class:`~repro.core.streamscan.ArrivalStream` over the model.

        The chunk factory re-derives every minute's RNG from
        ``(seed, minute)``, so the stream can be iterated repeatedly and
        is bit-identical per seed."""
        from .streamscan import ArrivalStream
        if minutes is None and max_invocations is None:
            raise ValueError("bound the stream with minutes= or "
                             "max_invocations=")

        def chunks():
            return self.iter_minutes(seed, minutes=minutes,
                                     max_invocations=max_invocations,
                                     rate_scale=rate_scale)

        return ArrivalStream(fns=self.fns, chunks=chunks,
                             total=max_invocations)

    # -- fit verification --------------------------------------------------

    def fit_report(self, trace: dict[str, list[int]], *, seed: int = 0,
                   cycles: int = 4) -> dict[str, float]:
        """Distance metrics between a synthesized stream and the expanded
        real trace (recipe step 5): K-S on IAT and duration marginals,
        Spearman rank correlation on per-function counts."""
        from .traces import requests_from_trace, tile_trace
        minutes = cycles * len(next(iter(trace.values())))
        ref = requests_from_trace(tile_trace(trace, repeat=cycles),
                                  seed=seed + 1, minute_s=self.minute_s)
        ref_t = np.array([r.r for r in ref])
        ref_p = np.array([r.p_true for r in ref])
        ref_counts = np.zeros(len(self.fns))
        fn_index = {f: i for i, f in enumerate(self.fns)}
        for r in ref:
            i = fn_index.get(r.fn)
            if i is not None:
                ref_counts[i] += 1

        t = np.zeros(0)
        f = np.zeros(0, dtype=np.int64)
        p = np.zeros(0)
        for ch in self.iter_minutes(seed, minutes=minutes):
            t = np.concatenate([t, ch.r])
            f = np.concatenate([f, ch.fn])
            p = np.concatenate([p, ch.p])
        counts = np.bincount(f, minlength=len(self.fns)).astype(np.float64)

        return {
            "n_synth": int(t.size),
            "n_ref": int(ref_t.size),
            "ks_iat": ks_statistic(np.diff(t), np.diff(np.sort(ref_t))),
            "ks_duration": ks_statistic(p, ref_p),
            "popularity_spearman": spearman_rank(counts, ref_counts),
        }


def fit_azure_trace(trace: dict[str, list[int]],
                    minute_s: float = 60.0) -> SynthModel:
    """Fit a :class:`SynthModel` to an Azure-style per-minute count trace
    (recipe steps 1-4)."""
    fns = sorted(trace, key=lambda f: (-sum(trace[f]), f))
    totals = np.array([sum(trace[f]) for f in fns], dtype=np.float64)
    if totals.sum() <= 0:
        raise ValueError("trace has no invocations to fit")
    n_min = len(trace[fns[0]])
    minute_rate = np.zeros(n_min)
    for f in fns:
        minute_rate[:len(trace[f])] += trace[f]

    # Zipf decay: least-squares log(count) ~ -alpha log(rank) on the
    # nonzero head (rank is 1-based; single-function traces fall back to 1)
    nz = totals > 0
    ranks = np.arange(1, totals.size + 1, dtype=np.float64)[nz]
    if ranks.size >= 2:
        x = np.log(ranks)
        y = np.log(totals[nz])
        alpha = -float(np.polyfit(x, y, 1)[0])
        alpha = float(np.clip(alpha, 0.1, 4.0))
    else:
        alpha = 1.0

    return SynthModel(fns=tuple(fns), popularity=totals / totals.sum(),
                      minute_rate=minute_rate, minute_s=minute_s,
                      zipf_alpha=alpha)


def fit_azure_csv(path: str | Path, minute_s: float = 60.0) -> SynthModel:
    """Convenience: :func:`fit_azure_trace` on a CSV file."""
    return fit_azure_trace(load_azure_trace(path), minute_s=minute_s)


def expand_catalog(model: SynthModel, n_fns: int, *,
                   rate_scale: float = 1.0,
                   tail_alpha: float | None = None) -> SynthModel:
    """Extrapolate a fitted model's catalog to ``n_fns`` functions.

    The measured functions keep their fitted popularity mass in rank
    order; synthetic tail functions ``synth-%05d`` continue a Zipf decay
    (``rank**-alpha``) below the last measured function, so a 32-function
    slice grows into a 10k-function catalog with the same head behaviour
    and a realistic long tail.  ``rate_scale`` scales the arrival
    intensity (more functions usually means more total load).

    ``tail_alpha`` overrides the decay exponent for the synthetic tail
    only: a head-only slice over-estimates the decay (ours fits ~2.0 on
    32 functions, while the full Azure dataset's app popularity decays
    with alpha ~= 1), so planet-scale catalogs pass a milder exponent to
    keep the tail warm enough that every function is actually invoked."""
    if n_fns < len(model.fns):
        raise ValueError(f"n_fns={n_fns} below measured catalog "
                         f"{len(model.fns)}")
    k = len(model.fns)
    alpha = model.zipf_alpha if tail_alpha is None else float(tail_alpha)
    pop = np.zeros(n_fns)
    pop[:k] = model.popularity
    if n_fns > k:
        # continue the decay below the last measured share: the rank-k
        # function anchors the tail, so share(rank) = share(k) * (rank/k)^-a
        ranks = np.arange(k + 1, n_fns + 1, dtype=np.float64)
        pop[k:] = model.popularity[-1] * (ranks / k) ** (-alpha)
    fns = tuple(model.fns) + tuple(
        f"synth-{i:05d}" for i in range(k, n_fns))
    return SynthModel(fns=fns, popularity=pop / pop.sum(),
                      minute_rate=model.minute_rate * rate_scale,
                      minute_s=model.minute_s, zipf_alpha=model.zipf_alpha)
