"""Vectorized single-node fast path (the ``"vectorized"`` / ``"scan"``
simulation backends).

The reference event loop in :mod:`.simulator` pays a heavy constant per
event: every dispatch scans the full container list, every release rebuilds
the per-function pools, and every event goes through closure-carrying heap
entries.  On a loaded node that is O(requests x containers) Python work, and
it dominates sweep wall-clock at high intensity.

This module re-implements the **ours-mode single node** (slot admission +
serialized management channel + non-preemptive 1-core execution, all five
policies) in array form:

* :class:`VectorizedBackend` -- numpy precomputation (arrival features,
  per-request channel costs) + a tight O(1)-per-event loop over counter-based
  pool / estimator state.  **Exact**: it replays the reference semantics
  decision-for-decision (same priorities, same container choices, same LRU
  eviction order, same event tie-breaking), so metrics agree to the bit --
  including cold starts, tight-memory eviction and ``warm=False`` runs.
* :class:`ScanBackend` / :func:`simulate_cells_scan` -- a ``jax.lax.scan``
  variant that runs a whole batch of cells as one scan over a padded request
  tensor (one event per step, cells vmapped).  It assumes the *always-warm*
  regime -- every function has ``cores`` warm containers after warm-up, so
  the pool never cold-starts or evicts -- which holds for the default 32 GB
  node up to 10 cores (see :func:`scan_eligible`).  Arithmetic is float32 on
  accelerators, so agreement with the reference is within rounding (well
  inside the 1% cross-check budget), not bitwise.

The baseline (stock OpenWhisk) node is processor-sharing with state-dependent
rates; it stays on the reference backend (``supports`` says no and the sweep
engine falls back).
"""

from __future__ import annotations

import heapq
from collections import deque
from dataclasses import dataclass
from functools import lru_cache

import numpy as np

from .request import Request
from .simulator import (
    OURS_BASE,
    OURS_COLD_EXTRA,
    OURS_PREWARM_EXTRA,
    OURS_SCALE,
    PS_KAPPA,
    REQ_OVERHEAD_S,
    RESP_OVERHEAD_S,
    SimResult,
    container_weight,
    register_backend,
)
from .containers import COLD_CREATE_S, PREWARM_INIT_S
from .estimator import DEFAULT_FC_HORIZON, DEFAULT_WINDOW
from .workload import PROFILES, SEBS_MEMORY_MB

POLICY_NAMES = ("fifo", "sept", "eect", "rect", "fc")


# ---------------------------------------------------------------------------
# static arrival features (identical for both fast backends)
# ---------------------------------------------------------------------------
@dataclass
class _Arrivals:
    """Per-request features that depend only on the arrival stream."""

    order: np.ndarray      # request indices in event order
    t: np.ndarray          # invoker receive times r + REQ_OVERHEAD (sorted)
    fn_ids: np.ndarray     # function id per event
    p: np.ndarray          # true processing time per event
    chan_cost: np.ndarray  # warm-path management cost per event
    prev: np.ndarray       # RECT r-bar: previous same-fn arrival (own t first)
    count: np.ndarray      # FC #(fn, -T) including the current arrival
    fns: list[str]         # id -> function name


def _arrival_features(requests: list[Request],
                      horizon: float = DEFAULT_FC_HORIZON) -> _Arrivals:
    n = len(requests)
    r = np.array([q.r for q in requests], dtype=np.float64)
    t_all = r + REQ_OVERHEAD_S
    order = np.argsort(t_all, kind="stable")
    t = t_all[order]
    fns = sorted({q.fn for q in requests})
    fn_index = {f: i for i, f in enumerate(fns)}
    fn_ids = np.array([fn_index[requests[i].fn] for i in order], dtype=np.int64)
    p = np.array([requests[i].p_true for i in order], dtype=np.float64)
    # channel cost is a per-function constant for profiled functions; only
    # unknown (trace) names fall back to the per-request p_true proxy
    fn_cost = [OURS_BASE + OURS_SCALE * container_weight(f, float("nan"))
               if f in PROFILES else None for f in fns]
    chan_cost = np.array(
        [fn_cost[fid] if fn_cost[fid] is not None
         else OURS_BASE + OURS_SCALE * container_weight(requests[i].fn,
                                                        requests[i].p_true)
         for i, fid in zip(order, fn_ids)], dtype=np.float64)

    prev = np.empty(n, dtype=np.float64)
    count = np.empty(n, dtype=np.int64)
    for f in range(len(fns)):
        idx = np.nonzero(fn_ids == f)[0]
        tf = t[idx]
        # estimator.observe_arrival: the first call's r-bar is its own time
        prev[idx] = np.concatenate(([tf[0]], tf[:-1])) if idx.size else tf
        # (now - T, now] sliding window, current arrival included
        lo = np.searchsorted(tf, tf - horizon, side="right")
        count[idx] = np.arange(1, idx.size + 1) - lo
    return _Arrivals(order=order, t=t, fn_ids=fn_ids, p=p,
                     chan_cost=chan_cost, prev=prev, count=count, fns=fns)


# ---------------------------------------------------------------------------
# exact counter-based replica of ContainerPool (discipline="ours")
# ---------------------------------------------------------------------------
class _FastPool:
    """Bookkeeping-identical port of :class:`~repro.core.containers.
    ContainerPool` for the ours discipline, without the per-operation scans.

    Containers are (last_used, position, memory) triples grouped by function;
    ``position`` is the global insertion counter, which reproduces the
    reference's stable LRU tie-breaking (its ``sort`` is stable over list
    order, and list order is insertion order)."""

    def __init__(self, memory_mb: int, container_mb: int, cores: int,
                 fn_memory: dict | None, prewarm_count: int = 2) -> None:
        self.memory_mb = memory_mb
        self.container_mb = container_mb
        self.cores = cores
        self.fn_memory = fn_memory if fn_memory is not None else SEBS_MEMORY_MB
        self.prewarm_count = prewarm_count
        self._pos = 0
        self.mem_used = 0
        self.free: dict[str, list[list]] = {}   # fn -> [[last_used, pos, mb]]
        self.prewarm: list[list] = []           # [[last_used, pos, mb]]
        self.n_prewarm = 0
        self.cold_starts = 0
        self.evictions = 0
        self.creations = 0
        for _ in range(prewarm_count):
            if self.mem_used + container_mb <= memory_mb:
                self._add_prewarm()

    def _add_prewarm(self) -> None:
        self.prewarm.append([0.0, self._pos, self.container_mb])
        self._pos += 1
        self.n_prewarm += 1
        self.mem_used += self.container_mb

    def _size(self, fn: str) -> int:
        return int(self.fn_memory.get(fn, self.container_mb))

    def warm_up(self, fns: list[str], per_fn: int) -> None:
        for _ in range(per_fn):
            for fn in fns:
                mb = self._size(fn)
                if self.mem_used + mb <= self.memory_mb:
                    self.free.setdefault(fn, []).append([0.0, self._pos, mb])
                    self._pos += 1
                    self.mem_used += mb

    # -- acquire / release ---------------------------------------------------
    def acquire(self, fn: str, now: float):
        """Returns (startup_delay, cold_start, handle) or None; ``handle`` is
        the (fn, memory, position) triple release needs -- the container keeps
        its insertion position across busy periods, like the reference's
        containers list does."""
        # 1. warm container: most recently used, earliest-inserted on ties.
        # The free list stays sorted by last_used (releases are monotone in
        # simulation time), so the MRU is the tail; ties defer to the exact
        # (max last_used, min position) rule the reference's list scan gives.
        lst = self.free.get(fn)
        if lst:
            if len(lst) > 1 and lst[-2][0] >= lst[-1][0]:
                best = 0
                for i in range(1, len(lst)):
                    if (lst[i][0] > lst[best][0]
                            or (lst[i][0] == lst[best][0]
                                and lst[i][1] < lst[best][1])):
                        best = i
                entry = lst.pop(best)
            else:
                entry = lst.pop()
            return 0.0, False, (fn, entry[2], entry[1])
        # 2. prewarm container (first in list order)
        if self.prewarm:
            entry = self.prewarm.pop(0)
            self.n_prewarm -= 1
            self.cold_starts += 1
            while (self.n_prewarm < self.prewarm_count
                   and self.mem_used + self.container_mb <= self.memory_mb):
                self._add_prewarm()
            return PREWARM_INIT_S, True, (fn, entry[2], entry[1])
        # 3. create when memory allows
        mb = self._size(fn)
        if self.mem_used + mb <= self.memory_mb:
            self.mem_used += mb
            pos = self._pos
            self._pos += 1
            self.creations += 1
            self.cold_starts += 1
            return COLD_CREATE_S, True, (fn, mb, pos)
        # 4. evict idle non-matching containers (LRU), then create
        victims = [(e[0], e[1], None, i)
                   for i, e in enumerate(self.prewarm)]
        for f, entries in self.free.items():
            if f != fn:
                victims.extend((e[0], e[1], f, i)
                               for i, e in enumerate(entries))
        victims.sort(key=lambda v: (v[0], v[1]))
        doomed: list = []
        for lu, pos, f, _ in victims:
            if self.mem_used + mb <= self.memory_mb:
                break
            doomed.append((f, pos))
            size = (self.container_mb if f is None
                    else next(e[2] for e in self.free[f] if e[1] == pos))
            self.mem_used -= size
            self.evictions += 1
        for f, pos in doomed:
            if f is None:
                self.prewarm = [e for e in self.prewarm if e[1] != pos]
                self.n_prewarm -= 1
            else:
                self.free[f] = [e for e in self.free[f] if e[1] != pos]
        if self.mem_used + mb <= self.memory_mb:
            self.mem_used += mb
            pos = self._pos
            self._pos += 1
            self.creations += 1
            self.cold_starts += 1
            return COLD_CREATE_S, True, (fn, mb, pos)
        # 5. nothing available: head-of-line blocks
        return None

    def release(self, handle, now: float) -> None:
        fn, mb, pos = handle
        lst = self.free.setdefault(fn, [])
        lst.append([now, pos, mb])
        # _trim_ours: warm containers per function are bounded by cores
        if len(lst) > self.cores:
            lst.sort(key=lambda e: (e[0], e[1]))
            for victim in lst[: len(lst) - self.cores]:
                self.mem_used -= victim[2]
                self.evictions += 1
            del lst[: len(lst) - self.cores]

# ---------------------------------------------------------------------------
# numpy fast path: exact ours-node replay
# ---------------------------------------------------------------------------
def simulate_ours_vectorized(
    requests: list[Request],
    cores: int,
    policy: str = "fifo",
    memory_mb: int = 32 * 1024,
    container_mb: int = 128,
    warm: bool = True,
) -> SimResult:
    """Array-precomputed, O(1)-per-event replay of the reference ours node.

    Agrees with the reference backend decision-for-decision; see the module
    docstring for the argument."""
    if policy not in POLICY_NAMES:
        raise ValueError(f"unknown policy {policy!r}")
    n = len(requests)
    meta = {"mode": "ours", "policy": policy, "cores": cores,
            "backend": "vectorized"}
    if n == 0:
        return SimResult(requests=requests, cold_starts=0, evictions=0,
                         creations=0, meta=meta)

    arr = _arrival_features(requests)
    pool = _FastPool(memory_mb=memory_mb, container_mb=container_mb,
                     cores=cores, fn_memory=SEBS_MEMORY_MB)
    # estimator ring buffers; warm-up seeds min(cores, window) observations
    # of the profile median per function (experiment protocol, §V-A)
    times: list[deque] = [deque() for _ in arr.fns]
    if warm:
        pool.warm_up(arr.fns, per_fn=cores)
        seed_n = min(cores, DEFAULT_WINDOW)
        for f, fn in enumerate(arr.fns):
            w = PROFILES[fn].median_s if fn in PROFILES else 0.1
            times[f].extend([w] * seed_n)
    # Always-warm regime: when warm-up provisioned every function with
    # ``cores`` containers, acquisition is provably always a warm hit (per-fn
    # busy <= total busy < cores at dispatch) and trim/evict/cold never fire,
    # so pool bookkeeping can be skipped entirely.
    trivial_pool = warm and all(
        len(pool.free.get(fn, ())) >= cores for fn in arr.fns)

    # Python lists index ~10x faster than numpy scalars in the event loop;
    # float64 -> float via tolist() is value-preserving (both IEEE doubles)
    t_arr = arr.t.tolist()
    fn_ids = arr.fn_ids.tolist()
    p = arr.p.tolist()
    chan_cost = arr.chan_cost.tolist()
    prev = arr.prev.tolist()
    count = arr.count.tolist()
    fns = arr.fns
    start = [0.0] * n
    finish = [0.0] * n
    prio_out = [0.0] * n
    cold_out = [False] * n
    # per-fn estimate cache: sum(buf)/len(buf) is recomputed (in reference
    # summation order, for bitwise identity) only after a completion of fn
    est_cache = [sum(b) / len(b) if b else 0.0 for b in times]

    queue: list[tuple[float, int, int]] = []   # (priority, push seq, event id)
    comps: list[tuple[float, int, int, tuple]] = []  # (t, seq, event, handle)
    busy = 0
    chan_free = 0.0
    comp_seq = 0
    ai = 0
    window = DEFAULT_WINDOW

    def dispatch(now: float) -> None:
        nonlocal busy, chan_free, comp_seq
        while queue and busy < cores:
            j = queue[0][2]
            cost = chan_cost[j]
            if trivial_pool:
                handle = None
            else:
                acq = pool.acquire(fns[fn_ids[j]], now)
                if acq is None:
                    break  # head-of-line blocks; priority order is preserved
                delay, cold, handle = acq
                if cold:
                    cold_out[j] = True
                    cost += (OURS_COLD_EXTRA if delay > 1.0
                             else OURS_PREWARM_EXTRA)
            heapq.heappop(queue)
            busy += 1
            op_start = chan_free if chan_free > now else now
            chan_free = op_start + cost      # channel.occupy returns the time
            exec_start = chan_free           # the management op *finishes*
            start[j] = exec_start
            fin = exec_start + p[j]
            finish[j] = fin
            heapq.heappush(comps, (fin, comp_seq, j, handle))
            comp_seq += 1

    while True:
        next_arr = t_arr[ai] if ai < n else None
        # reference tie-break: arrival events are scheduled first, so at equal
        # times the arrival's heap sequence number is lower and it runs first
        if next_arr is not None and (not comps or next_arr <= comps[0][0]):
            e, now = ai, next_arr
            ai += 1
            if policy == "fifo":
                prio = now
            else:
                est = est_cache[fn_ids[e]]
                if policy == "sept":
                    prio = est
                elif policy == "eect":
                    prio = now + est
                elif policy == "rect":
                    prio = prev[e] + est
                else:  # fc
                    prio = count[e] * est
            prio_out[e] = prio
            heapq.heappush(queue, (prio, e, e))
            if busy < cores:
                dispatch(now)
        elif comps:
            now, _, e, handle = heapq.heappop(comps)
            f = fn_ids[e]
            buf = times[f]
            buf.append(p[e])
            if len(buf) > window:
                buf.popleft()
            est_cache[f] = sum(buf) / len(buf)
            if handle is not None:
                pool.release(handle, now)
            busy -= 1
            if queue:
                dispatch(now)
        else:
            break

    assert not queue and busy == 0, "requests left unserved"
    # write results back into the Request objects (same contract as the
    # reference backend: callers read metrics off the request list)
    order = arr.order.tolist()
    for e in range(n):
        req = requests[order[e]]
        req.node = "node0"
        req.r_prime = t_arr[e]
        req.priority = prio_out[e]
        req.cold_start = cold_out[e]
        req.start = start[e]
        req.finish = finish[e]
        req.c = finish[e] + RESP_OVERHEAD_S
    return SimResult(
        requests=requests,
        cold_starts=pool.cold_starts,
        evictions=pool.evictions,
        creations=pool.creations,
        meta=meta,
    )


class VectorizedBackend:
    """Exact array fast path for the ours-mode single node."""

    name = "vectorized"

    def supports(self, *, mode: str, policy: str, warm: bool) -> bool:
        return mode == "ours" and policy in POLICY_NAMES

    def simulate(
        self,
        requests: list[Request],
        cores: int,
        policy: str = "fifo",
        mode: str = "ours",
        memory_mb: int = 32 * 1024,
        container_mb: int = 128,
        warm: bool = True,
        kappa: float = PS_KAPPA,
    ) -> SimResult:
        if mode != "ours":
            raise ValueError(
                "the vectorized backend models the ours-mode node only; "
                "baseline (processor sharing) runs on backend='reference'")
        return simulate_ours_vectorized(
            requests, cores, policy=policy, memory_mb=memory_mb,
            container_mb=container_mb, warm=warm)


register_backend(VectorizedBackend())


# ---------------------------------------------------------------------------
# jax.lax.scan batched variant: a whole grid as one scan
# ---------------------------------------------------------------------------
# priority = a*r' + b*rbar + (c + d*count) * E[p]  -- all five policies are
# points in this 4-coefficient family, so one scan body serves the whole grid
_POLICY_COEF = {
    "fifo": (1.0, 0.0, 0.0, 0.0),
    "sept": (0.0, 0.0, 1.0, 0.0),
    "eect": (1.0, 0.0, 1.0, 0.0),
    "rect": (0.0, 1.0, 1.0, 0.0),
    "fc":   (0.0, 0.0, 0.0, 1.0),
}


def scan_eligible(
    requests: list[Request],
    cores: int,
    policy: str = "fifo",
    mode: str = "ours",
    memory_mb: int = 32 * 1024,
    container_mb: int = 128,
    warm: bool = True,
) -> bool:
    """True when the scan backend reproduces the reference exactly (modulo
    float32): ours mode, known policy, and the always-warm regime where the
    §V-A warm-up provisions ``cores`` containers for *every* function, so the
    container pool never cold-starts, evicts or blocks."""
    if mode != "ours" or policy not in POLICY_NAMES or not warm:
        return False
    fns = sorted({r.fn for r in requests})
    pool = _FastPool(memory_mb=memory_mb, container_mb=container_mb,
                     cores=cores, fn_memory=SEBS_MEMORY_MB)
    pool.warm_up(fns, per_fn=cores)
    return all(len(pool.free.get(fn, ())) >= cores for fn in fns)


def _scan_one_cell(t_arr, fnid, p, cost, prev, cnt, coef, cores, ring0,
                   rsum0, rlen0, rpos0, n_slots, window):
    """Single-cell event scan; vmapped over the batch by the caller."""
    import jax
    import jax.numpy as jnp

    n = t_arr.shape[0] - 1           # t_arr carries a trailing +inf sentinel
    inf = jnp.float32(jnp.inf)

    def step(state, _):
        (ai, busy, chan_free, pending, fin_s, idx_s,
         ring, rsum, rlen, rpos, start, finish, prio) = state
        t_a = t_arr[ai]
        t_c = jnp.min(fin_s)
        arrival = t_a <= t_c         # arrivals beat completions on ties
        none_left = jnp.isinf(t_a) & jnp.isinf(t_c)
        now = jnp.minimum(t_a, t_c)

        # -- arrival: compute the (frozen) priority, join the queue
        i = jnp.minimum(ai, n)
        f_i = fnid[i]
        est_i = jnp.where(rlen[f_i] > 0,
                          rsum[f_i] / jnp.maximum(rlen[f_i], 1), 0.0)
        prio_i = (coef[0] * t_a + coef[1] * prev[i]
                  + (coef[2] + coef[3] * cnt[i]) * est_i)
        do_arr = arrival & ~none_left
        pending = pending.at[i].set(jnp.where(do_arr, prio_i, pending[i]))
        prio = prio.at[i].set(jnp.where(do_arr, prio_i, prio[i]))
        ai = ai + do_arr

        # -- completion: free the slot, feed the estimator ring
        k = jnp.argmin(fin_s)
        j_done = idx_s[k]
        f_done = fnid[j_done]
        do_comp = ~arrival & ~none_left
        v = p[j_done]
        old = ring[f_done, rpos[f_done]]
        full = rlen[f_done] == window
        rsum = rsum.at[f_done].add(
            jnp.where(do_comp, v - jnp.where(full, old, 0.0), 0.0))
        ring = ring.at[f_done, rpos[f_done]].set(
            jnp.where(do_comp, v, old))
        rlen = rlen.at[f_done].add(
            jnp.where(do_comp & ~full, 1, 0))
        rpos = rpos.at[f_done].set(
            jnp.where(do_comp, (rpos[f_done] + 1) % window, rpos[f_done]))
        busy = busy - do_comp
        fin_s = fin_s.at[k].set(jnp.where(do_comp, inf, fin_s[k]))

        # -- dispatch: lowest priority (earliest arrival on ties), one per
        # event -- always-warm admission means a free slot implies an empty
        # queue, so a single launch restores the invariant
        j = jnp.argmin(pending)
        can = ~none_left & (busy < cores) & (pending[j] < inf)
        exec_start = jnp.maximum(now, chan_free) + cost[j]
        chan_free = jnp.where(can, exec_start, chan_free)
        fin_j = exec_start + p[j]
        slot_free = jnp.isinf(fin_s) & (jnp.arange(n_slots) < cores)
        s = jnp.argmax(slot_free)
        fin_s = fin_s.at[s].set(jnp.where(can, fin_j, fin_s[s]))
        idx_s = idx_s.at[s].set(jnp.where(can, j, idx_s[s]))
        busy = busy + can
        pending = pending.at[j].set(jnp.where(can, inf, pending[j]))
        start = start.at[j].set(jnp.where(can, exec_start, start[j]))
        finish = finish.at[j].set(jnp.where(can, fin_j, finish[j]))

        return (ai, busy, chan_free, pending, fin_s, idx_s,
                ring, rsum, rlen, rpos, start, finish, prio), None

    state0 = (
        jnp.int32(0), jnp.int32(0), jnp.float32(0.0),
        jnp.full(n, inf), jnp.full(n_slots, inf),
        jnp.zeros(n_slots, dtype=jnp.int32),
        ring0, rsum0, rlen0, rpos0,
        jnp.zeros(n), jnp.zeros(n), jnp.zeros(n),
    )
    state, _ = jax.lax.scan(step, state0, None, length=2 * n)
    return state[10], state[11], state[12]     # start, finish, priority


@lru_cache(maxsize=8)
def _scan_runner(n_slots: int, window: int):
    """Jitted, vmapped cell scanner, cached per (slots, window) so repeated
    calls -- per-cell ScanBackend runs, sweep batches of the same grid --
    reuse XLA compilations instead of re-tracing from scratch (jit only
    caches on the callable identity plus input shapes)."""
    import jax

    return jax.jit(jax.vmap(
        lambda *xs: _scan_one_cell(*xs, n_slots=n_slots, window=window)))


def simulate_cells_scan(
    batch: list[tuple[list[Request], int, str]],
    memory_mb: int = 32 * 1024,
    container_mb: int = 128,
) -> list[SimResult]:
    """Run a batch of (requests, cores, policy) ours-mode scenarios as ONE
    ``jax.lax.scan`` over a padded request tensor (cells vmapped).

    Every cell must satisfy :func:`scan_eligible`; this is checked and raises
    ``ValueError`` otherwise.  Start/finish times are written back into the
    request objects exactly like the other backends."""
    import jax
    import jax.numpy as jnp

    if not batch:
        return []
    feats = []
    for requests, cores, policy in batch:
        if not scan_eligible(requests, cores, policy, memory_mb=memory_mb,
                             container_mb=container_mb):
            raise ValueError(
                "scan backend requires the always-warm ours regime "
                f"(policy={policy!r}, cores={cores}); use "
                "backend='vectorized' for the general exact fast path")
        feats.append(_arrival_features(requests))

    bsz = len(batch)
    n_max = max(len(f.t) for f in feats)
    f_max = max(len(f.fns) for f in feats)
    c_max = max(cores for _, cores, _ in batch)
    window = DEFAULT_WINDOW

    t_arr = np.full((bsz, n_max + 1), np.inf, dtype=np.float32)
    fnid = np.zeros((bsz, n_max + 1), dtype=np.int32)
    p = np.zeros((bsz, n_max + 1), dtype=np.float32)
    cost = np.zeros((bsz, n_max + 1), dtype=np.float32)
    prev = np.zeros((bsz, n_max + 1), dtype=np.float32)
    cnt = np.zeros((bsz, n_max + 1), dtype=np.float32)
    coef = np.zeros((bsz, 4), dtype=np.float32)
    cores_v = np.zeros(bsz, dtype=np.int32)
    ring0 = np.zeros((bsz, f_max, window), dtype=np.float32)
    rsum0 = np.zeros((bsz, f_max), dtype=np.float32)
    rlen0 = np.zeros((bsz, f_max), dtype=np.int32)
    rpos0 = np.zeros((bsz, f_max), dtype=np.int32)

    for b, ((requests, cores, policy), f) in enumerate(zip(batch, feats)):
        n = len(f.t)
        t_arr[b, :n] = f.t
        fnid[b, :n] = f.fn_ids
        p[b, :n] = f.p
        cost[b, :n] = f.chan_cost
        prev[b, :n] = f.prev
        cnt[b, :n] = f.count
        coef[b] = _POLICY_COEF[policy]
        cores_v[b] = cores
        seed_n = min(cores, window)
        for fi, fn in enumerate(f.fns):
            w = PROFILES[fn].median_s if fn in PROFILES else 0.1
            ring0[b, fi, :seed_n] = w
            rsum0[b, fi] = seed_n * w
            rlen0[b, fi] = seed_n
            rpos0[b, fi] = seed_n % window

    run = _scan_runner(c_max, window)
    start_b, finish_b, prio_b = run(
        jnp.asarray(t_arr), jnp.asarray(fnid), jnp.asarray(p),
        jnp.asarray(cost), jnp.asarray(prev), jnp.asarray(cnt),
        jnp.asarray(coef), jnp.asarray(cores_v), jnp.asarray(ring0),
        jnp.asarray(rsum0), jnp.asarray(rlen0), jnp.asarray(rpos0))
    start_b = np.asarray(start_b, dtype=np.float64)
    finish_b = np.asarray(finish_b, dtype=np.float64)
    prio_b = np.asarray(prio_b, dtype=np.float64)

    out = []
    for b, ((requests, cores, policy), f) in enumerate(zip(batch, feats)):
        order = f.order.tolist()
        t_list = f.t.tolist()
        for e, ridx in enumerate(order):
            req = requests[ridx]
            req.node = "node0"
            req.r_prime = t_list[e]
            req.priority = float(prio_b[b, e])   # float32-rounded
            req.cold_start = False               # always-warm regime
            req.start = float(start_b[b, e])
            req.finish = float(finish_b[b, e])
            req.c = req.finish + RESP_OVERHEAD_S
        out.append(SimResult(
            requests=requests, cold_starts=0, evictions=0, creations=0,
            meta={"mode": "ours", "policy": policy, "cores": cores,
                  "backend": "scan"},
        ))
    return out


class ScanBackend:
    """Batched jax.lax.scan variant (always-warm ours regime, float32)."""

    name = "scan"

    def supports(self, *, mode: str, policy: str, warm: bool) -> bool:
        if mode != "ours" or policy not in POLICY_NAMES or not warm:
            return False
        try:
            import jax  # noqa: F401
        except ImportError:
            return False
        return True

    def simulate(
        self,
        requests: list[Request],
        cores: int,
        policy: str = "fifo",
        mode: str = "ours",
        memory_mb: int = 32 * 1024,
        container_mb: int = 128,
        warm: bool = True,
        kappa: float = PS_KAPPA,
    ) -> SimResult:
        if mode != "ours" or not warm:
            raise ValueError("scan backend requires ours mode with warm=True")
        return simulate_cells_scan(
            [(requests, cores, policy)], memory_mb=memory_mb,
            container_mb=container_mb)[0]


register_backend(ScanBackend())
