"""Vectorized single-node fast path (the ``"vectorized"`` / ``"scan"``
simulation backends).

The reference event loop in :mod:`.simulator` pays a heavy constant per
event: every dispatch scans the full container list, every release rebuilds
the per-function pools, and every event goes through closure-carrying heap
entries.  On a loaded node that is O(requests x containers) Python work, and
it dominates sweep wall-clock at high intensity.

This module re-implements the **ours-mode single node** (slot admission +
serialized management channel + non-preemptive 1-core execution, all five
policies) in array form:

* :class:`VectorizedBackend` -- numpy precomputation (arrival features,
  per-request channel costs) + a tight O(1)-per-event loop over counter-based
  pool / estimator state.  **Exact**: it replays the reference semantics
  decision-for-decision (same priorities, same container choices, same LRU
  eviction order, same event tie-breaking), so metrics agree to the bit --
  including cold starts, tight-memory eviction and ``warm=False`` runs.
* :class:`ScanBackend` / :func:`simulate_cells_scan` /
  :func:`simulate_cluster_cells_scan` -- a ``jax.lax.scan`` variant that runs
  a whole batch of cells as one scan over padded request tensors (one event
  per step, cells vmapped).  The kernel is **multi-node**: slot occupancy and
  management-channel clocks carry a node axis, and the per-event dispatch
  computes the cluster routing decision (pull most-free-slots, push
  least-loaded / home-invoker) inside the scan step, so an entire N-node
  cluster cell is one scan and a whole nodes x intensity x policy grid is a
  handful of bucketed XLA dispatches.  Capacity is **time-varying**: cells
  with a :class:`~repro.core.cluster.ClusterDynamics` carry per-node
  activation masks updated inside the step -- autoscaler ticks provision
  nodes after the configured delay, scheduled kills wipe a node and re-queue
  its lost calls after the detection delay (counted exactly like the
  reference), and push-model FC runs off bounded per-(node, fn) arrival
  count rings.  Warm cells run the *always-warm* regime -- every function
  has ``cores`` warm containers after warm-up, so the pool never cold-starts
  or evicts -- which holds for the default 32 GB node up to 10 cores (see
  :func:`scan_eligible`) and the cluster's 40 GB nodes up to ~13 (see
  :func:`cluster_scan_eligible`); ``warm=False`` cells instead carry
  per-(node, fn) container tensors (MRU reuse, LRU eviction,
  prewarm/create/evict costs) matching the reference pool
  decision-for-decision.  Static-capacity arithmetic is float32, so
  agreement with the reference is within rounding for single nodes (~1e-6)
  and within the documented cluster tolerance for clusters (near-tie
  orderings can flip; see ``repro.core.sweep.CLUSTER_XCHECK_RTOL``);
  dynamic-capacity buckets run in float64 so failure/autoscale accounting is
  order-exact.

Compilations are cached per padded bucket shape (powers of two over requests
x nodes x slots x functions x batch; :func:`scan_cache_stats`), so repeated
``run_sweep`` calls pay one XLA compile per bucket per process.

The baseline (stock OpenWhisk) node is processor-sharing with state-dependent
rates; it stays on the reference backend (``supports`` says no and the sweep
engine falls back).
"""

from __future__ import annotations

import contextlib
import heapq
import os
import time
from collections import deque
from dataclasses import dataclass, field
from functools import partial

import numpy as np

from .request import Request
from .simulator import (
    OURS_BASE,
    OURS_COLD_EXTRA,
    OURS_PREWARM_EXTRA,
    OURS_SCALE,
    PS_KAPPA,
    REQ_OVERHEAD_S,
    RESP_OVERHEAD_S,
    SimResult,
    container_weight,
    register_backend,
)
from .containers import COLD_CREATE_S, PREWARM_INIT_S
from .estimator import DEFAULT_FC_HORIZON, DEFAULT_WINDOW
from .workload import PROFILES, SEBS_MEMORY_MB, STRETCH_REFERENCE_S

POLICY_NAMES = ("fifo", "sept", "eect", "rect", "fc")


# ---------------------------------------------------------------------------
# static arrival features (identical for both fast backends)
# ---------------------------------------------------------------------------
@dataclass
class _Arrivals:
    """Per-request features that depend only on the arrival stream."""

    order: np.ndarray      # request indices in event order
    t: np.ndarray          # invoker receive times r + REQ_OVERHEAD (sorted)
    fn_ids: np.ndarray     # function id per event
    p: np.ndarray          # true processing time per event
    chan_cost: np.ndarray  # warm-path management cost per event
    prev: np.ndarray       # RECT r-bar: previous same-fn arrival (own t first)
    count: np.ndarray      # FC #(fn, -T) including the current arrival
    fns: list[str]         # id -> function name


def _arrival_features(requests: list[Request],
                      horizon: float = DEFAULT_FC_HORIZON) -> _Arrivals:
    n = len(requests)
    r = np.array([q.r for q in requests], dtype=np.float64)
    t_all = r + REQ_OVERHEAD_S
    order = np.argsort(t_all, kind="stable")
    t = t_all[order]
    fns = sorted({q.fn for q in requests})
    fn_index = {f: i for i, f in enumerate(fns)}
    fn_ids = np.array([fn_index[requests[i].fn] for i in order], dtype=np.int64)
    p = np.array([requests[i].p_true for i in order], dtype=np.float64)
    # channel cost is a per-function constant for profiled functions; only
    # unknown (trace) names fall back to the per-request p_true proxy
    fn_cost = [OURS_BASE + OURS_SCALE * container_weight(f, float("nan"))
               if f in PROFILES else None for f in fns]
    chan_cost = np.array(
        [fn_cost[fid] if fn_cost[fid] is not None
         else OURS_BASE + OURS_SCALE * container_weight(requests[i].fn,
                                                        requests[i].p_true)
         for i, fid in zip(order, fn_ids)], dtype=np.float64)

    prev = np.empty(n, dtype=np.float64)
    count = np.empty(n, dtype=np.int64)
    for f in range(len(fns)):
        idx = np.nonzero(fn_ids == f)[0]
        tf = t[idx]
        # estimator.observe_arrival: the first call's r-bar is its own time
        prev[idx] = np.concatenate(([tf[0]], tf[:-1])) if idx.size else tf
        # (now - T, now] sliding window, current arrival included
        lo = np.searchsorted(tf, tf - horizon, side="right")
        count[idx] = np.arange(1, idx.size + 1) - lo
    return _Arrivals(order=order, t=t, fn_ids=fn_ids, p=p,
                     chan_cost=chan_cost, prev=prev, count=count, fns=fns)


# ---------------------------------------------------------------------------
# exact counter-based replica of ContainerPool (discipline="ours")
# ---------------------------------------------------------------------------
class _FastPool:
    """Bookkeeping-identical port of :class:`~repro.core.containers.
    ContainerPool` for the ours discipline, without the per-operation scans.

    Containers are (last_used, position, memory) triples grouped by function;
    ``position`` is the global insertion counter, which reproduces the
    reference's stable LRU tie-breaking (its ``sort`` is stable over list
    order, and list order is insertion order)."""

    def __init__(self, memory_mb: int, container_mb: int, cores: int,
                 fn_memory: dict | None, prewarm_count: int = 2) -> None:
        self.memory_mb = memory_mb
        self.container_mb = container_mb
        self.cores = cores
        self.fn_memory = fn_memory if fn_memory is not None else SEBS_MEMORY_MB
        self.prewarm_count = prewarm_count
        self._pos = 0
        self.mem_used = 0
        self.free: dict[str, list[list]] = {}   # fn -> [[last_used, pos, mb]]
        self.prewarm: list[list] = []           # [[last_used, pos, mb]]
        self.n_prewarm = 0
        self.cold_starts = 0
        self.evictions = 0
        self.creations = 0
        for _ in range(prewarm_count):
            if self.mem_used + container_mb <= memory_mb:
                self._add_prewarm()

    def _add_prewarm(self) -> None:
        self.prewarm.append([0.0, self._pos, self.container_mb])
        self._pos += 1
        self.n_prewarm += 1
        self.mem_used += self.container_mb

    def _size(self, fn: str) -> int:
        return int(self.fn_memory.get(fn, self.container_mb))

    def warm_up(self, fns: list[str], per_fn: int) -> None:
        for _ in range(per_fn):
            for fn in fns:
                mb = self._size(fn)
                if self.mem_used + mb <= self.memory_mb:
                    self.free.setdefault(fn, []).append([0.0, self._pos, mb])
                    self._pos += 1
                    self.mem_used += mb

    # -- acquire / release ---------------------------------------------------
    def acquire(self, fn: str, now: float):
        """Returns (startup_delay, cold_start, handle) or None; ``handle`` is
        the (fn, memory, position) triple release needs -- the container keeps
        its insertion position across busy periods, like the reference's
        containers list does."""
        # 1. warm container: most recently used, earliest-inserted on ties.
        # The free list stays sorted by last_used (releases are monotone in
        # simulation time), so the MRU is the tail; ties defer to the exact
        # (max last_used, min position) rule the reference's list scan gives.
        lst = self.free.get(fn)
        if lst:
            if len(lst) > 1 and lst[-2][0] >= lst[-1][0]:
                best = 0
                for i in range(1, len(lst)):
                    if (lst[i][0] > lst[best][0]
                            or (lst[i][0] == lst[best][0]
                                and lst[i][1] < lst[best][1])):
                        best = i
                entry = lst.pop(best)
            else:
                entry = lst.pop()
            return 0.0, False, (fn, entry[2], entry[1])
        # 2. prewarm container (first in list order)
        if self.prewarm:
            entry = self.prewarm.pop(0)
            self.n_prewarm -= 1
            self.cold_starts += 1
            while (self.n_prewarm < self.prewarm_count
                   and self.mem_used + self.container_mb <= self.memory_mb):
                self._add_prewarm()
            return PREWARM_INIT_S, True, (fn, entry[2], entry[1])
        # 3. create when memory allows
        mb = self._size(fn)
        if self.mem_used + mb <= self.memory_mb:
            self.mem_used += mb
            pos = self._pos
            self._pos += 1
            self.creations += 1
            self.cold_starts += 1
            return COLD_CREATE_S, True, (fn, mb, pos)
        # 4. evict idle non-matching containers (LRU), then create
        victims = [(e[0], e[1], None, i)
                   for i, e in enumerate(self.prewarm)]
        for f, entries in self.free.items():
            if f != fn:
                victims.extend((e[0], e[1], f, i)
                               for i, e in enumerate(entries))
        victims.sort(key=lambda v: (v[0], v[1]))
        doomed: list = []
        for lu, pos, f, _ in victims:
            if self.mem_used + mb <= self.memory_mb:
                break
            doomed.append((f, pos))
            size = (self.container_mb if f is None
                    else next(e[2] for e in self.free[f] if e[1] == pos))
            self.mem_used -= size
            self.evictions += 1
        for f, pos in doomed:
            if f is None:
                self.prewarm = [e for e in self.prewarm if e[1] != pos]
                self.n_prewarm -= 1
            else:
                self.free[f] = [e for e in self.free[f] if e[1] != pos]
        if self.mem_used + mb <= self.memory_mb:
            self.mem_used += mb
            pos = self._pos
            self._pos += 1
            self.creations += 1
            self.cold_starts += 1
            return COLD_CREATE_S, True, (fn, mb, pos)
        # 5. nothing available: head-of-line blocks
        return None

    def release(self, handle, now: float) -> None:
        fn, mb, pos = handle
        lst = self.free.setdefault(fn, [])
        lst.append([now, pos, mb])
        # _trim_ours: warm containers per function are bounded by cores
        if len(lst) > self.cores:
            lst.sort(key=lambda e: (e[0], e[1]))
            for victim in lst[: len(lst) - self.cores]:
                self.mem_used -= victim[2]
                self.evictions += 1
            del lst[: len(lst) - self.cores]

# ---------------------------------------------------------------------------
# numpy fast path: exact ours-node replay
# ---------------------------------------------------------------------------
def simulate_ours_vectorized(
    requests: list[Request],
    cores: int,
    policy: str = "fifo",
    memory_mb: int = 32 * 1024,
    container_mb: int = 128,
    warm: bool = True,
) -> SimResult:
    """Array-precomputed, O(1)-per-event replay of the reference ours node.

    Agrees with the reference backend decision-for-decision; see the module
    docstring for the argument."""
    if policy not in POLICY_NAMES:
        raise ValueError(f"unknown policy {policy!r}")
    n = len(requests)
    meta = {"mode": "ours", "policy": policy, "cores": cores,
            "backend": "vectorized"}
    if n == 0:
        return SimResult(requests=requests, cold_starts=0, evictions=0,
                         creations=0, meta=meta)

    arr = _arrival_features(requests)
    pool = _FastPool(memory_mb=memory_mb, container_mb=container_mb,
                     cores=cores, fn_memory=SEBS_MEMORY_MB)
    # estimator ring buffers; warm-up seeds min(cores, window) observations
    # of the profile median per function (experiment protocol, §V-A)
    times: list[deque] = [deque() for _ in arr.fns]
    if warm:
        pool.warm_up(arr.fns, per_fn=cores)
        seed_n = min(cores, DEFAULT_WINDOW)
        for f, fn in enumerate(arr.fns):
            w = PROFILES[fn].median_s if fn in PROFILES else 0.1
            times[f].extend([w] * seed_n)
    # Always-warm regime: when warm-up provisioned every function with
    # ``cores`` containers, acquisition is provably always a warm hit (per-fn
    # busy <= total busy < cores at dispatch) and trim/evict/cold never fire,
    # so pool bookkeeping can be skipped entirely.
    trivial_pool = warm and all(
        len(pool.free.get(fn, ())) >= cores for fn in arr.fns)

    # Python lists index ~10x faster than numpy scalars in the event loop;
    # float64 -> float via tolist() is value-preserving (both IEEE doubles)
    t_arr = arr.t.tolist()
    fn_ids = arr.fn_ids.tolist()
    p = arr.p.tolist()
    chan_cost = arr.chan_cost.tolist()
    prev = arr.prev.tolist()
    count = arr.count.tolist()
    fns = arr.fns
    start = [0.0] * n
    finish = [0.0] * n
    prio_out = [0.0] * n
    cold_out = [False] * n
    # per-fn estimate cache: sum(buf)/len(buf) is recomputed (in reference
    # summation order, for bitwise identity) only after a completion of fn
    est_cache = [sum(b) / len(b) if b else 0.0 for b in times]

    queue: list[tuple[float, int, int]] = []   # (priority, push seq, event id)
    comps: list[tuple[float, int, int, tuple]] = []  # (t, seq, event, handle)
    busy = 0
    chan_free = 0.0
    comp_seq = 0
    ai = 0
    window = DEFAULT_WINDOW

    def dispatch(now: float) -> None:
        nonlocal busy, chan_free, comp_seq
        while queue and busy < cores:
            j = queue[0][2]
            cost = chan_cost[j]
            if trivial_pool:
                handle = None
            else:
                acq = pool.acquire(fns[fn_ids[j]], now)
                if acq is None:
                    break  # head-of-line blocks; priority order is preserved
                delay, cold, handle = acq
                if cold:
                    cold_out[j] = True
                    cost += (OURS_COLD_EXTRA if delay > 1.0
                             else OURS_PREWARM_EXTRA)
            heapq.heappop(queue)
            busy += 1
            op_start = chan_free if chan_free > now else now
            chan_free = op_start + cost      # channel.occupy returns the time
            exec_start = chan_free           # the management op *finishes*
            start[j] = exec_start
            fin = exec_start + p[j]
            finish[j] = fin
            heapq.heappush(comps, (fin, comp_seq, j, handle))
            comp_seq += 1

    while True:
        next_arr = t_arr[ai] if ai < n else None
        # reference tie-break: arrival events are scheduled first, so at equal
        # times the arrival's heap sequence number is lower and it runs first
        if next_arr is not None and (not comps or next_arr <= comps[0][0]):
            e, now = ai, next_arr
            ai += 1
            if policy == "fifo":
                prio = now
            else:
                est = est_cache[fn_ids[e]]
                if policy == "sept":
                    prio = est
                elif policy == "eect":
                    prio = now + est
                elif policy == "rect":
                    prio = prev[e] + est
                else:  # fc
                    prio = count[e] * est
            prio_out[e] = prio
            heapq.heappush(queue, (prio, e, e))
            if busy < cores:
                dispatch(now)
        elif comps:
            now, _, e, handle = heapq.heappop(comps)
            f = fn_ids[e]
            buf = times[f]
            buf.append(p[e])
            if len(buf) > window:
                buf.popleft()
            est_cache[f] = sum(buf) / len(buf)
            if handle is not None:
                pool.release(handle, now)
            busy -= 1
            if queue:
                dispatch(now)
        else:
            break

    assert not queue and busy == 0, "requests left unserved"
    # write results back into the Request objects (same contract as the
    # reference backend: callers read metrics off the request list)
    order = arr.order.tolist()
    for e in range(n):
        req = requests[order[e]]
        req.node = "node0"
        req.r_prime = t_arr[e]
        req.priority = prio_out[e]
        req.cold_start = cold_out[e]
        req.start = start[e]
        req.finish = finish[e]
        req.c = finish[e] + RESP_OVERHEAD_S
    return SimResult(
        requests=requests,
        cold_starts=pool.cold_starts,
        evictions=pool.evictions,
        creations=pool.creations,
        meta=meta,
    )


class VectorizedBackend:
    """Exact array fast path for the ours-mode single node."""

    name = "vectorized"

    def supports(self, *, mode: str, policy: str, warm: bool,
                 nodes: int = 1, assignment: str = "pull",
                 autoscale: bool = False, failures: bool = False,
                 hedging: bool = False, hetero: bool = False,
                 timeouts: bool = False, retries: bool = False,
                 shedding: bool = False,
                 streaming: bool = False, trace: bool = False) -> bool:
        # trace: no rich event hooks -- the canonical lifecycle stream is
        # reconstructed from written-back request state instead
        return (mode == "ours" and policy in POLICY_NAMES and nodes <= 1
                and not autoscale and not failures
                and not hedging and not hetero
                and not timeouts and not retries and not shedding
                and not streaming and not trace)

    def simulate(
        self,
        requests: list[Request],
        cores: int,
        policy: str = "fifo",
        mode: str = "ours",
        memory_mb: int = 32 * 1024,
        container_mb: int = 128,
        warm: bool = True,
        kappa: float = PS_KAPPA,
    ) -> SimResult:
        if mode != "ours":
            raise ValueError(
                "the vectorized backend models the ours-mode node only; "
                "baseline (processor sharing) runs on backend='reference'")
        if kappa != PS_KAPPA:
            raise ValueError(
                "kappa parameterizes the baseline processor-sharing node, "
                "which the vectorized backend does not model; use "
                "backend='reference' for non-default kappa")
        return simulate_ours_vectorized(
            requests, cores, policy=policy, memory_mb=memory_mb,
            container_mb=container_mb, warm=warm)


register_backend(VectorizedBackend())


# ---------------------------------------------------------------------------
# jax.lax.scan batched variant: a whole grid as one scan
# ---------------------------------------------------------------------------
# priority = a*r' + b*rbar + (c + d*count) * E[p]  -- all five policies are
# points in this 4-coefficient family, so one scan body serves the whole grid
_POLICY_COEF = {
    "fifo": (1.0, 0.0, 0.0, 0.0),
    "sept": (0.0, 0.0, 1.0, 0.0),
    "eect": (1.0, 0.0, 1.0, 0.0),
    "rect": (0.0, 1.0, 1.0, 0.0),
    "fc":   (0.0, 0.0, 0.0, 1.0),
}

# Pull-model coefficients differ in two places from the frozen-at-enqueue
# family above, both faithful to the reference Cluster semantics:
#  * fifo -- the global queue is ranked at pull time when r' is still unset,
#    so the reference degenerates to queue insertion order; ranking by the
#    (static) controller receive time is the same order without the all-equal
#    ties.
#  * eect -- "now + E[p]" shares the same `now` across every queued call, so
#    the ranking is identical to SEPT's; we drop the common term.
_PULL_COEF = {
    "fifo": (1.0, 0.0, 0.0, 0.0),
    "sept": (0.0, 0.0, 1.0, 0.0),
    "eect": (0.0, 0.0, 1.0, 0.0),
    "rect": (0.0, 1.0, 1.0, 0.0),
    "fc":   (0.0, 0.0, 0.0, 1.0),
}

# Dynamic-capacity pull cells carry a 5th coefficient on the *enqueue clock*:
# a request re-queued after its node died has a real r' (its first pull
# time), so the shared-`now` identities above no longer cancel across the
# queue -- FIFO and EECT rank fresh calls by `now` but re-queued ones by
# their recorded first-dispatch time (always earlier, exactly like the
# reference's r'-based priorities).  Heads add coef[4]*now (a shared
# constant, order-preserving), re-queued candidates add coef[4]*r'.
_PULL_COEF_DYN = {
    "fifo": (0.0, 0.0, 0.0, 0.0, 1.0),
    "sept": (0.0, 0.0, 1.0, 0.0, 0.0),
    "eect": (0.0, 0.0, 1.0, 0.0, 1.0),
    "rect": (0.0, 1.0, 1.0, 0.0, 0.0),
    "fc":   (0.0, 0.0, 0.0, 1.0, 0.0),
}

# ClusterConfig defaults, mirrored here so scan eligibility is judged against
# the same node sizing the reference cluster uses (tests assert they agree;
# cluster.py is only imported lazily to keep this module importable alone)
CLUSTER_MEMORY_MB = 40 * 1024
CLUSTER_CONTAINER_MB = 128


def _cold_regime_ok(
    requests: list[Request],
    cores: int,
    memory_mb: int,
    container_mb: int,
    prewarm_count: int = 2,
) -> bool:
    """True when a ``warm=False`` run is inside the *ample-memory prewarm*
    regime the scan kernel models exactly.

    With no warm-up, every container is born from the prewarm pool
    (``PREWARM_INIT_S`` <= 1s, so every cold start pays exactly
    ``OURS_PREWARM_EXTRA``) and keeps the generic ``container_mb``
    reservation -- function-sized containers only ever appear via warm-up or
    the create path.  If the prewarm pool can always replenish, the create /
    evict-for-memory / head-of-line-block paths of ``ContainerPool.acquire``
    are provably unreachable, which is what lets the kernel track the pool
    as per-(node, fn) free *counts*: the MRU-vs-LRU container choice has no
    timing or accounting effect when all containers are interchangeable.

    Worst-case resident containers per node: ``prewarm_count`` prewarms +
    ``cores`` busy + ``cores`` free per function (the release trim bound),
    plus one transient during the release-then-trim and replenish windows
    each.  Ample memory means that bound times ``container_mb`` fits."""
    n_fns = len({r.fn for r in requests})
    bound = container_mb * (prewarm_count + cores * (1 + n_fns) + 2)
    return bound <= memory_mb


def scan_eligible(
    requests: list[Request],
    cores: int,
    policy: str = "fifo",
    mode: str = "ours",
    memory_mb: int = 32 * 1024,
    container_mb: int = 128,
    warm: bool = True,
) -> bool:
    """True when the scan backend reproduces the reference exactly (modulo
    float32): ours mode, known policy, and a container regime the kernel
    models -- either the always-warm regime where the §V-A warm-up provisions
    ``cores`` containers for *every* function (the pool never cold-starts,
    evicts or blocks), or the ``warm=False`` ample-memory prewarm regime
    (every cold start is a prewarm hit; see :func:`_cold_regime_ok`), where
    the kernel carries per-(node, fn) container counts and charges the
    prewarm management extra on cold dispatches."""
    if mode != "ours" or policy not in POLICY_NAMES:
        return False
    if not warm:
        return _cold_regime_ok(requests, cores, memory_mb, container_mb)
    fns = sorted({r.fn for r in requests})
    pool = _FastPool(memory_mb=memory_mb, container_mb=container_mb,
                     cores=cores, fn_memory=SEBS_MEMORY_MB)
    pool.warm_up(fns, per_fn=cores)
    return all(len(pool.free.get(fn, ())) >= cores for fn in fns)


# re-route rank sentinel: ex-queued kill losses order after every
# ex-running one (launch-sequence stamps stay far below this)
_RORD_Q = 2 ** 30


class _PlaneLayout:
    """Contiguous batch-major packing of the scan carry.

    Every float entry of the carry dict flattens into one **clocks plane**
    (``clk``, f32 for static buckets / f64 for dynamic ones) and every
    int/bool entry into one **counters plane** (``ctr``, int32), in
    sorted-key order -- so the whole per-step state is two dense tensors
    instead of ~20 scattered arrays.  That is what lets the step compile to
    a handful of fused kernels (XLA fuses the unpack/update/pack chain into
    the step body) and what makes the carry resident as two VMEM buffers on
    the Pallas path (``repro.kernels.event_step``).  The layout is a pure
    function of the carry *spec* (shapes + dtypes), so the packer
    (:func:`_make_planes`) and the kernel's unpacker derive identical
    offsets independently."""

    __slots__ = ("fparts", "iparts", "f_len", "i_len")

    def __init__(self, spec: dict):
        import jax.numpy as jnp

        self.fparts: list[tuple[str, int, int, tuple]] = []
        self.iparts: list[tuple[str, int, int, tuple, bool]] = []
        fo = io = 0
        for k in sorted(spec):
            s = spec[k]
            size = 1
            for d in s.shape:
                size *= int(d)
            if jnp.issubdtype(s.dtype, jnp.floating):
                self.fparts.append((k, fo, fo + size, tuple(s.shape)))
                fo += size
            else:
                self.iparts.append((k, io, io + size, tuple(s.shape),
                                    s.dtype == jnp.bool_))
                io += size
        self.f_len, self.i_len = fo, io

    def pack(self, st: dict):
        """Carry dict -> ``(clk, ctr)`` plane pair (bools widen to int32)."""
        import jax.numpy as jnp

        clk = jnp.concatenate([jnp.ravel(st[k]) for k, _, _, _
                               in self.fparts])
        ctr = jnp.concatenate([jnp.ravel(st[k]).astype(jnp.int32)
                               for k, _, _, _, _ in self.iparts])
        return clk, ctr

    def unpack(self, clk, ctr) -> dict:
        """``(clk, ctr)`` plane pair -> carry dict (static slices, so XLA
        sees them as zero-copy views into the planes)."""
        st = {}
        for k, lo, hi, shape in self.fparts:
            st[k] = clk[lo:hi].reshape(shape)
        for k, lo, hi, shape, isbool in self.iparts:
            v = ctr[lo:hi].reshape(shape)
            st[k] = v.astype(bool) if isbool else v
        return st


def _make_state0(inp, *, n_nodes, n_slots, window, freeze, fc_push, dyn,
                 het, hedge, cold, dup, n_copies, fc_ring, res=False,
                 stream=False):
    """Initial carry dict for one cell (the ``state0`` of the event scan).

    Split out of the kernel so three consumers share one definition: the
    kernel itself (via :func:`_carry_layout` / ``jax.eval_shape`` -- the
    plane layout is derived from this function's output spec), the jitted
    plane initializer (:func:`_make_planes`, whose output buffers the scan
    runner donates back as the carry), and the Pallas kernel's static
    offset table."""
    import jax.numpy as jnp

    t_arr = inp["t"]
    nodes = inp["nodes"]
    ring0, rsum0, rlen0, rpos0 = (inp["ring0"], inp["rsum0"],
                                  inp["rlen0"], inp["rpos0"])
    n = t_arr.shape[0] - 1           # trailing +inf sentinel
    ft = t_arr.dtype
    inf = jnp.asarray(jnp.inf, dtype=ft)
    nq = n_copies * (n + 1) if dup else n + 1
    n_est = n_nodes if freeze else 1
    n_fns = ring0.shape[1]
    state0 = {
        "ai": jnp.int32(0),
        "head": jnp.zeros(n_fns, dtype=jnp.int32),
        "fin_s": jnp.full((n_nodes, n_slots), jnp.inf, dtype=ft),
        "idx_s": jnp.zeros((n_nodes, n_slots), dtype=jnp.int32),
        "busy": jnp.zeros(n_nodes, dtype=jnp.int32),
        "qn": jnp.zeros(n_nodes, dtype=jnp.int32),
        "chan": jnp.zeros(n_nodes, dtype=ft),
        "ring": ring0, "rsum": rsum0, "rlen": rlen0, "rpos": rpos0,
        "last_t": jnp.zeros((n_est, n_fns), dtype=ft),
        "prev_t": jnp.zeros((n_est, n_fns), dtype=ft),
        "narr": jnp.zeros((n_est, n_fns), dtype=jnp.int32),
    }
    if freeze:
        state0.update(
            pend=jnp.zeros(nq, dtype=bool),
            fprio=jnp.zeros(nq, dtype=ft),
            node_of=jnp.zeros(nq, dtype=jnp.int32),
        )
    if fc_push:
        state0.update(
            fcr=jnp.full((n_nodes, n_fns, fc_ring), -jnp.inf, dtype=ft),
            fcp=jnp.zeros((n_nodes, n_fns), dtype=jnp.int32),
        )
    if cold:
        state0.update(
            # every pool starts empty in the warm=False regime (reference:
            # warm_functions=None skips warm_up); ample memory keeps the
            # prewarm pool inexhaustible, so only free-counts need carrying
            freec=jnp.zeros((n_nodes, n_fns), dtype=jnp.int32),
            ncold=jnp.int32(0), nevt=jnp.int32(0),
            coldq=jnp.zeros(n + 1, dtype=bool),
        )
    if hedge:
        state0.update(
            hedge_t=jnp.full(n + 1, jnp.inf, dtype=ft),
            att=jnp.zeros(n + 1, dtype=jnp.int32),
            nbk=jnp.int32(0),
            stolen=jnp.zeros(n + 1, dtype=bool),
            # controller estimator starts EMPTY, like the reference
            # Cluster's _estimator (nodes get the §V-A warm seed, the
            # controller does not)
            cring=jnp.zeros((n_fns, window), dtype=ft),
            crsum=jnp.zeros(n_fns, dtype=ft),
            crlen=jnp.zeros(n_fns, dtype=jnp.int32),
            crpos=jnp.zeros(n_fns, dtype=jnp.int32),
            qseq=jnp.zeros(nq, dtype=jnp.int32),
            stepc=jnp.int32(0),
            ndone=jnp.int32(0),
        )
        if dyn:
            state0.update(unhedge=jnp.zeros(n + 1, dtype=bool))
            if freeze:
                state0.update(hedge_t2=jnp.full(n + 1, jnp.inf, dtype=ft))
    if dup:
        state0.update(
            done0=jnp.zeros(n + 1, dtype=bool),
            win_start=jnp.zeros(n + 1, dtype=ft),
            win_fin=jnp.zeros(n + 1, dtype=ft),
            win_node=jnp.zeros(n + 1, dtype=jnp.int32),
            start_q=jnp.zeros(nq, dtype=ft),
        )
    if het and freeze:
        state0["sspd"] = jnp.ones((n_nodes, n_slots), dtype=ft)
    if dyn:
        state0.update(
            act_t=inp["act0"], dead=jnp.zeros(n_nodes, dtype=bool),
            killq=inp["killt"],
            act_pend=jnp.zeros(n_nodes, dtype=bool),
            rearr=jnp.full(n + 1, jnp.inf, dtype=ft),
            next_tick=jnp.where(inp["dynp"][4] > 0, inp["dynp"][0], inf),
            prov=nodes.astype(jnp.int32),
            nfail=jnp.int32(0), ndone=jnp.int32(0),
        )
        if freeze:
            state0.update(
                dseq=jnp.zeros((n_nodes, n_slots), dtype=jnp.int32),
                dcnt=jnp.int32(0),
                rord=jnp.zeros(n + 1, dtype=jnp.int32),
            )
        if not freeze:
            state0["xq"] = jnp.zeros(n + 1, dtype=bool)
            state0["rq_rt"] = jnp.zeros(n + 1, dtype=ft)
            state0["enq_t"] = t_arr          # fresh calls enqueue at receive
    if res:
        state0.update(
            # request lifecycle (timeouts / retries / shedding): active
            # timeout deadline and pending retry re-arrival per request,
            # the queued-E[p] snapshot each admission added to the shed
            # pressure gauge, submission counts, terminal-failure mask +
            # cause, per-slot exec starts (wasted-work accounting), and the
            # counters cross-checked exactly against the reference Cluster
            to_t=jnp.full(n + 1, jnp.inf, dtype=ft),
            rto=jnp.full(n + 1, jnp.inf, dtype=ft),
            eps=jnp.zeros(n + 1, dtype=ft),
            qep=jnp.zeros((), dtype=ft),
            ratt=jnp.zeros(n + 1, dtype=jnp.int32),
            nfl=jnp.zeros(n + 1, dtype=bool),
            fcz=jnp.zeros(n + 1, dtype=jnp.int32),   # 1=timeout, 2=shed
            sst=jnp.zeros((n_nodes, n_slots), dtype=ft),
            nto=jnp.int32(0), nsh=jnp.int32(0), nrt=jnp.int32(0),
            wst=jnp.zeros((), dtype=ft),
            ndn=jnp.int32(0),        # completions + terminal failures
            # queue-push sequence: a retry re-arrival re-pushes a LOW-index
            # call LATE, so push order decouples from request-index order
            # -- the reference's stable per-node PriorityQueue breaks
            # priority ties by it (same device as the hedge qseq)
            qsq=jnp.zeros(n + 1, dtype=jnp.int32),
            stp=jnp.int32(0),
            # controller estimator (deadline/shed estimates) starts EMPTY,
            # like the reference Cluster's _estimator (nodes get the §V-A
            # warm seed, the controller does not)
            zring=jnp.zeros((n_fns, window), dtype=ft),
            zrsum=jnp.zeros(n_fns, dtype=ft),
            zrlen=jnp.zeros(n_fns, dtype=jnp.int32),
            zrpos=jnp.zeros(n_fns, dtype=jnp.int32),
        )
    if stream and not freeze:
        # chunked-stream pull validity counter: ``narr`` carries the
        # *cumulative* per-function arrival count across chunk boundaries
        # (its zero-vs-nonzero state is the RECT first-arrival detector), so
        # the head-window validity test needs its own chunk-rebased counter
        # (carried queued entries preloaded by the handoff, fresh arrivals
        # incremented in-step)
        state0["qcnt"] = jnp.zeros(n_fns, dtype=jnp.int32)
    return state0


def _carry_layout(inp, **flags) -> _PlaneLayout:
    """Plane layout for a cell's carry, derived shape-only (``eval_shape``
    never materializes the state).  ``inp`` may hold concrete arrays,
    tracers or ``ShapeDtypeStruct`` leaves; float64 buckets must call this
    under ``enable_x64`` so the spec dtypes are not canonicalized down."""
    import jax

    return _PlaneLayout(jax.eval_shape(partial(_make_state0, **flags), inp))


def _make_planes(inp, **flags):
    """Per-cell initial carry as the packed ``(clk, ctr)`` plane pair.
    vmapped + jitted by the scan runner; its output buffers are donated
    straight back into the scan dispatch."""
    layout = _carry_layout(inp, **flags)
    return layout.pack(_make_state0(inp, **flags))


def _scan_cell_kernel(clk, ctr, inp, *, n_nodes, n_slots, window, freeze,
                      use_fc, fc_push, dyn, het, hedge, cold, dup, n_copies,
                      n_ep, fc_ring, horizon, n_steps, res=False,
                      stream=False):
    """One cell's event scan over a whole **cluster**: slot-occupancy and
    channel clocks carry a node axis, and the per-event dispatch includes the
    routing decision.  vmapped over the batch by the caller (via the
    ``repro.kernels.ops.event_step`` dispatcher); ``inp`` is a dict of
    per-cell arrays (see ``_run_scan_bucket``) and ``(clk, ctr)`` is the
    cell's initial carry as a packed :class:`_PlaneLayout` plane pair
    (produced by :func:`_make_planes`, whose buffers the runner donates).
    The ``lax.scan`` carry is that same plane pair -- two contiguous
    tensors -- with the per-segment dict view reconstructed by static
    slicing inside the step, so XLA fuses the whole step into a handful of
    kernels instead of threading ~20 small carry arrays.

    The carry is assembled as an **ordered pipeline of feature-flagged
    segments** (see ``_CARRY_SEGMENTS``): base slots/queue/channel state,
    frozen-priority queue entries (``freeze``), per-(node, fn) push-FC
    arrival rings (``fc_push``), container free-counts (``cold``), hedge
    watches + controller ring (``hedge``), racing-copy winner state
    (``dup``), per-slot effective speeds (``het``) and capacity-dynamics
    masks (``dyn``).  Each enabled segment contributes its slice of the
    carry dict and its update inside the step below (the banner comments
    mark the segment boundaries); the compile-cache key carries the enabled
    set as a feature bitmask (:func:`_feature_mask`).

    Two static regimes share the body:

    * ``freeze=True`` -- single-node and push-assignment semantics: the
      priority is computed once at arrival from the *routed node's* estimator
      state (rings/prev-arrival are ``(n_nodes, F)``), and each event only
      dispatches on the node it touched.  ``route`` selects the push balancer
      per cell: 0 = least-loaded (min busy+queued, first on ties), 1 = home
      invoker (``home0`` carries the per-request CRC32 start index; walk
      forward to the first node with a free slot).  ``fc_push=True``
      additionally carries bounded per-(node, fn) **arrival-time count
      rings**: FC's sliding-window count depends on the dynamic routing
      history, so each routed arrival is logged in its node's ring and the
      window count is the number of logged times still inside the horizon --
      the ring is sized to the workload's worst global per-function window
      count, so it can never undercount.
    * ``freeze=False`` -- the pull model: queued calls are re-ranked at every
      pull from the *controller's* estimator (rings are ``(1, F)`` and start
      empty, exactly like the reference controller), the dispatch node is the
      one with the most free slots, and the FC window count is reconstructed
      exactly from the static arrival stream (``cumf[k, f]`` = calls of f
      among the first k arrivals, so #(f, (now-T, now]) = cumf[a] - cumf[k0]
      with k0 found by searchsorted).

      The global best-of-queue is found in O(F), not O(n): a pull-time
      priority is a per-*function* value (every queued call of f shares
      est/prev/count, and the FIFO coefficient orders a function's calls by
      arrival), so each function's queue is the contiguous tail of its static
      arrival sequence ``fn_ev[f]`` and the reference's argmin over the whole
      queue equals the argmin over the F queue *heads*, with the first-index
      tie-break preserved by taking the smallest head event index among the
      minimum-priority functions.

    ``het=True`` compiles the **heterogeneity** machinery: per-node base
    speeds plus a padded ``(node, t0, t1, slowdown)`` episode table (a
    :class:`~repro.core.stragglers.NodeSpeedProfile` in tensor form).  The
    routed node's *effective speed at dispatch time* divides both the
    management-op cost and the execution time, exactly like the reference
    ``OursNodeSim._launch``; in push mode the node estimator rings log the
    *measured* (speed-scaled) service while the controller ring keeps raw
    ``p_true``, mirroring the reference's node-vs-controller asymmetry.

    ``hedge=True`` (push/freeze only -- the pull model's late binding makes
    hedging a structural no-op) compiles **straggler hedging**: per-request
    deadline events armed at arrival from a controller-side estimator ring
    (``now + multiple x max(E[p], floor)``), which -- when the call is still
    queued and under its backup budget -- cancel it on its node and re-route
    it to the least-loaded peer with a freshly computed priority, exactly
    the reference ``Cluster._maybe_backup`` steal.  When no live peer
    exists the steal re-submits to the call's own node (the reference's
    ``min(others) if others else node`` self-steal), so single-node push
    hedging is modelled too.  ``backups_issued`` / ``steals_won`` counts
    replicate the reference bit-exactly; a dispatched call's watch is
    cleared so no-op fires do not consume scan steps.  Both flags force the
    bucket into float64 (like ``dyn``): deadline-vs-start and
    episode-boundary orderings decide integer counts that must not flip
    under float32 clock drift.

    ``dup=True`` (requires ``hedge``) switches the hedge action to
    **duplicate-mode racing copies**: the queue state grows a copy axis --
    entry ``q = c*(n+1) + j`` is copy ``c`` of request ``j``, with
    ``n_copies = 1 + max_backups`` -- and a deadline fire on a still-queued
    original issues copy ``attempts+1`` on the least-loaded live peer
    (no-op without re-arm when no peer exists, like the reference's ``if
    not others: return``).  Copies race: the first completion of any copy
    records the winner's start/finish/node (the reference ``_on_complete``
    min-c rule with first-wins ties), pops the watch, and ``steals_won``
    counts originals whose winner was a backup copy.  The original is never
    cancelled -- both runs occupy slots and feed the estimators, exactly
    like the reference.

    ``cold=True`` compiles the ``warm=False`` **ample-memory prewarm
    regime** (:func:`_cold_regime_ok`): estimator rings start empty, the
    carry tracks per-(node, fn) free-container counts, a dispatch with no
    free container is a prewarm cold start charging ``OURS_PREWARM_EXTRA``
    on the management channel, and a release that would exceed the
    ``cores`` per-function bound counts an eviction -- matching
    ``ContainerPool`` exactly, where creations are provably zero and the
    MRU/LRU container choice has no observable effect.

    ``dyn=True`` compiles the **time-varying capacity** machinery on top:
    per-node activation times and a dead mask (the cell's
    :class:`~repro.core.cluster.CapacityTimeline` in tensor form) gate
    routing, slot admission and the management-channel clocks; scheduled
    kills wipe a node's slots (and, push, its queue) and re-arrive the lost
    requests after the detection delay, counted exactly like the reference's
    ``failures``; autoscaler ticks evaluate the queue-per-slot rule inside
    the scan step and schedule provisions ``provision_delay`` ahead; a
    newly-activated node drains the global queue through repeated
    activation-dispatch events.  Event precedence at equal times is kill,
    arrival, completion, re-arrival, activation, tick (kills are scheduled
    before the burst in the reference, ticks after).  The step count
    ``n_steps`` must cover 2n plus the dynamics budget (see
    ``_ScanCell.dyn_budget``); the caller verifies the returned completion
    count.

    ``stream=True`` compiles the **chunked-stream** variant used by
    :mod:`repro.core.streamscan`: the scan stops *freezing the carry* at the
    chunk horizon ``t_stop`` (every event at ``now >= t_stop`` defers to the
    next chunk, whose candidate stack replays the same precedence), the
    final ``(clk, ctr)`` planes are returned so the host can hand the carry
    off into the next chunk's tensors, and three chunk-local indirections
    replace whole-stream lookups: the pull head-window validity test reads
    the chunk-rebased ``qcnt`` carry instead of the cumulative ``narr``,
    the per-function event lists arrive in CSR form (``fnev``/``fnst``,
    O(n + F) instead of the dense ``(F, kq)`` table), and the resilience
    retry-jitter hash reads the request's *global* arrival rank from
    ``gseq`` so backoff delays are bit-identical to the single-shot run.
    Dispatch records are returned raw for every mode (the host resolves
    last-wins across chunks).
    """
    import jax
    import jax.numpy as jnp

    t_arr = inp["t"]
    fnid = inp["fnid"]
    p = inp["p"]
    cost = inp["cost"]
    cnt = inp["cnt"]
    home0 = inp["home0"]
    coef = inp["coef"]
    cores = inp["cores"]
    nodes = inp["nodes"]
    route = inp["route"]
    ring0, rsum0, rlen0, rpos0 = (inp["ring0"], inp["rsum0"],
                                  inp["rlen0"], inp["rpos0"])
    cumf = inp["cumf"]
    fn_ev = inp["fn_ev"]
    if stream:
        t_stop = inp["t_stop"]
        if not freeze:
            fnev_flat = inp["fnev"]      # CSR per-fn event lists
            fn_start = inp["fnst"]
        if res:
            gseq = inp["gseq"]           # global arrival ranks

    n = t_arr.shape[0] - 1           # t_arr carries a trailing +inf sentinel
    # float dtype follows the inputs: float32 for static-capacity buckets,
    # float64 for dynamic ones (dispatched under enable_x64 so that f32
    # clock drift cannot flip completion-vs-kill/arrival event orderings
    # that failure accounting depends on)
    ft = t_arr.dtype
    inf = jnp.asarray(jnp.inf, dtype=ft)
    node_ids = jnp.arange(n_nodes)
    slot_ids = jnp.arange(n_slots)
    fn_ids_ax = jnp.arange(ring0.shape[1])
    win_ids = jnp.arange(window)
    oreq_ids = jnp.arange(n + 1)     # one entry per *original* request
    if dup:
        # duplicate-mode copy axis, flattened into the request axis: queue
        # entry q = c*(n+1) + j is copy c of request j, so every frozen-
        # queue structure below (pend/fprio/node_of/qseq, slot back-refs)
        # works unchanged on the widened axis.  Static per-entry features
        # are shared across a request's copies by tiling.
        nq = n_copies * (n + 1)
        fnid = jnp.tile(fnid, n_copies)
        p = jnp.tile(p, n_copies)
        cost = jnp.tile(cost, n_copies)
        cnt = jnp.tile(cnt, n_copies)
        home0 = jnp.tile(home0, n_copies)
    else:
        nq = n + 1
    req_ids = jnp.arange(nq)
    if dyn:
        interval, thr, delay, detect, auto_f = (inp["dynp"][k]
                                                for k in range(5))
    if res:
        # request-lifecycle resilience (timeouts / retries / shedding)
        # compiles only the static warm push regime -- every other combo is
        # rejected by cluster_scan_eligible / ScanBackend.supports
        assert freeze and not (dyn or hedge or dup or het or cold), \
            "res carry segment requires the static warm push regime"
        rto_p = inp["rto_p"]   # [on, multiple, floor, absolute]
        rrt_p = inp["rrt_p"]   # [max_attempts, base, cap, jitter, on_timeout,
        #                         on_shed]
        adm_p = inp["adm_p"]   # [on, threshold]

        def _res_delay(seq, a):
            # bit-identical to RetryPolicy.delay: 16-bit hash fraction for
            # the per-(request, attempt) jitter, exponential doubling via an
            # integer left-shift (exp2/power are not bit-exact), f64 ops in
            # the same order as the Python reference.  ``seq`` is the event
            # index == the reference's stable arrival rank; int64 keeps the
            # hash exact for any stream length (res buckets run under x64).
            base, cap, jit = rrt_p[1], rrt_p[2], rrt_p[3]
            u = (((seq.astype(jnp.int64) * 7919
                   + a.astype(jnp.int64) * 104729 + 12345)
                  % 65536).astype(ft)) / 65536.0
            shift = jnp.left_shift(
                jnp.ones((), jnp.int32),
                jnp.maximum(a - 1, 0)).astype(ft)
            raw = jnp.minimum(cap, base * shift)
            return raw * ((1.0 - jit) + jit * u)

        if stream:
            # the jitter hash is keyed on the reference's stable arrival
            # rank; a chunk-local row index would change the delay, so the
            # handoff supplies each row's global rank
            def _res_seq(i):
                return gseq[i]
        else:
            def _res_seq(i):
                return i

    # XLA's CPU scatter runs a slow generic per-element path, so every
    # fixed-size state update below is a dense one-hot ``where`` instead of
    # an ``.at[]`` scatter -- the masks are tiny ((F,), (nodes, slots), ...)
    # and the elementwise chains fuse into a handful of kernels per step.
    def step(st, _):
        ai = st["ai"]
        head = st["head"]
        fin_s, idx_s = st["fin_s"], st["idx_s"]
        busy, qn, chan = st["busy"], st["qn"], st["chan"]
        ring, rsum, rlen, rpos = st["ring"], st["rsum"], st["rlen"], st["rpos"]
        last_t, prev_t, narr = st["last_t"], st["prev_t"], st["narr"]
        if freeze:
            pend, fprio, node_of = st["pend"], st["fprio"], st["node_of"]
        if res:
            to_t, rto = st["to_t"], st["rto"]
            eps, qep = st["eps"], st["qep"]
            ratt, nfl, fcz = st["ratt"], st["nfl"], st["fcz"]
            sst = st["sst"]
            nto, nsh, nrt = st["nto"], st["nsh"], st["nrt"]
            wst, ndn = st["wst"], st["ndn"]
            maxa = rrt_p[0].astype(jnp.int32)
            on_to, on_sh = rrt_p[4] > 0, rrt_p[5] > 0

        t_a = t_arr[ai]
        flat = fin_s.reshape(-1)
        kflat = jnp.argmin(flat)
        t_c = flat[kflat]
        if dyn:
            act_t, dead, killq = st["act_t"], st["dead"], st["killq"]
            act_pend, rearr = st["act_pend"], st["rearr"]
            cand_l = [jnp.min(killq), t_a, t_c, jnp.min(rearr),
                      jnp.min(jnp.where(act_pend, act_t, inf)),
                      st["next_tick"]]
            if hedge:
                # hedge deadlines rank last at exact ties (measure-zero:
                # deadlines are estimate multiples)
                cand_l.append(jnp.min(st["hedge_t"]))
            cand = jnp.stack(cand_l)
        elif hedge:
            # hedge deadlines rank after completions at exact ties (a
            # measure-zero case: deadlines are estimate multiples)
            cand = jnp.stack([t_a, t_c, jnp.min(st["hedge_t"])])
        elif res:
            # timeout fires rank after completions and retry re-arrivals
            # after both; the reference heap would fire a timeout watch
            # first at a deadline == completion exact tie (lower schedule
            # seq), but deadlines are estimate multiples and re-arrivals
            # jittered backoff sums -- measure-zero, like hedge
            cand = jnp.stack([t_a, t_c, jnp.min(to_t), jnp.min(rto)])
        else:
            cand = jnp.stack([t_a, t_c])
        # argmin takes the *first* minimum: at equal times the stack order is
        # the event precedence (kill < arrival <= completion < ... < tick)
        e = jnp.argmin(cand)
        now = cand[e]
        none_left = jnp.isinf(now)
        if stream:
            # chunk horizon: every event at or past ``t_stop`` defers to the
            # next chunk -- the carry freezes exactly as it was before the
            # next chunk's first event, and the next chunk's candidate stack
            # replays the same same-instant precedence order
            none_left = none_left | (now >= t_stop)
        off = 1 if dyn else 0
        do_arr = (e == off) & ~none_left
        do_comp = (e == off + 1) & ~none_left
        if hedge:
            do_hedge = (e == (6 if dyn else 2)) & ~none_left
        if res:
            do_to = (e == 2) & ~none_left
            do_rto = (e == 3) & ~none_left
        if dyn:
            do_kill = (e == 0) & ~none_left
            do_re = (e == 3) & ~none_left
            do_act = (e == 4) & ~none_left
            do_tick = (e == 5) & ~none_left
            active = (act_t <= now) & ~dead
        else:
            active = node_ids < nodes

        # -- completion: free the slot, feed the estimator ring -------------
        kn = (kflat // n_slots).astype(jnp.int32)
        ks = kflat % n_slots
        j_done = idx_s[kn, ks]
        f_done = fnid[j_done]
        en_c = kn if freeze else 0   # which estimator observed it
        m_en = (jnp.arange(ring.shape[0]) == en_c)
        m_fd = (fn_ids_ax == f_done)
        m_cf = (m_en[:, None] & m_fd[None, :]) & do_comp     # (NE, F)
        pos = rpos[en_c, f_done]
        v = p[j_done]
        if het and freeze:
            # node estimators log the *measured* (speed-scaled) service; the
            # controller ring (pull mode / hedging below) keeps raw p_true
            v = v / st["sspd"][kn, ks]
        old = ring[en_c, f_done, pos]
        full = rlen[en_c, f_done] == window
        rsum = jnp.where(m_cf, rsum + v - jnp.where(full, old, 0.0), rsum)
        ring = jnp.where(m_cf[:, :, None] & (win_ids == pos), v, ring)
        rlen = jnp.where(m_cf & ~full, rlen + 1, rlen)
        rpos = jnp.where(m_cf, (rpos + 1) % window, rpos)
        if hedge:
            # controller-side estimator (hedging deadlines): observes every
            # completion's p_true, like the reference Cluster._on_complete
            cpos = st["crpos"][f_done]
            cfull = st["crlen"][f_done] == window
            cold_v = st["cring"][f_done, cpos]
            m_cfd = (fn_ids_ax == f_done) & do_comp
            crsum = jnp.where(m_cfd, st["crsum"] + p[j_done]
                              - jnp.where(cfull, cold_v, 0.0), st["crsum"])
            cring = jnp.where(m_cfd[:, None] & (win_ids == cpos),
                              p[j_done], st["cring"])
            crlen = jnp.where(m_cfd & ~cfull, st["crlen"] + 1, st["crlen"])
            crpos = jnp.where(m_cfd, (cpos + 1) % window, st["crpos"])
        if res:
            # completion voids the timeout watch (the reference's
            # completed-set staleness check) and feeds the controller
            # estimator ring that admission/deadline estimates read
            # (Cluster._on_complete observes p_true; nodes see the same
            # value -- het is excluded from res buckets)
            to_t = jnp.where((req_ids == j_done) & do_comp, inf, to_t)
            ndn = ndn + do_comp.astype(jnp.int32)
            zpos = st["zrpos"][f_done]
            zfull = st["zrlen"][f_done] == window
            zold = st["zring"][f_done, zpos]
            m_zfd = (fn_ids_ax == f_done) & do_comp
            zrsum = jnp.where(m_zfd, st["zrsum"] + p[j_done]
                              - jnp.where(zfull, zold, 0.0), st["zrsum"])
            zring = jnp.where(m_zfd[:, None] & (win_ids == zpos),
                              p[j_done], st["zring"])
            zrlen = jnp.where(m_zfd & ~zfull, st["zrlen"] + 1, st["zrlen"])
            zrpos = jnp.where(m_zfd, (zpos + 1) % window, st["zrpos"])
        m_kn = (node_ids == kn) & do_comp
        busy = jnp.where(m_kn, busy - 1, busy)
        fin_s = jnp.where(m_kn[:, None] & (slot_ids == ks), inf, fin_s)
        if cold:
            # -- container segment, release half (ContainerPool.release +
            # _trim_ours): the freed container re-enters its (node, fn) free
            # pool unless the fn already holds ``cores`` free ones, in which
            # case the LRU free container is evicted instead (which one is
            # unobservable here: all prewarm-born containers are identical)
            freec = st["freec"]
            rel_cap = freec[kn, f_done] >= cores
            m_rel = (((node_ids == kn)[:, None]
                      & (fn_ids_ax == f_done)[None, :])
                     & do_comp & ~rel_cap)
            freec = jnp.where(m_rel, freec + 1, freec)
            nevt = st["nevt"] + (do_comp & rel_cap).astype(jnp.int32)
        if dup:
            # -- racing-copy winner: the first completion among a request's
            # copies is the reference's min-c winner (_on_complete keeps the
            # strictly smaller c, so ties go to the earlier completion
            # event); later copies still release their slot and feed the
            # estimators but change nothing the client sees
            orig_done = (j_done % (n + 1)).astype(jnp.int32)
            take = do_comp & ~st["done0"][orig_done]
            m_win = (oreq_ids == orig_done) & take
            done0 = st["done0"] | m_win
            win_start = jnp.where(m_win, st["start_q"][j_done],
                                  st["win_start"])
            win_fin = jnp.where(m_win, now, st["win_fin"])
            win_node = jnp.where(m_win, kn.astype(jnp.int32),
                                 st["win_node"])
        if hedge:
            # -- hedge deadline fires: eligible when the call is still
            # queued on its node and under the backup budget (mirrors
            # Cluster._maybe_backup: completed/started/attempt-capped
            # fires are no-ops and do not re-arm)
            att, hedge_t, stolen = st["att"], st["hedge_t"], st["stolen"]
            if dyn and freeze:
                # second watch slot (sorted: hedge_t <= hedge_t2): the
                # reference never cancels scheduled watch fires, so a
                # queued-at-kill call keeps its old deadline pending
                # alongside the one re-armed at re-arrival
                hedge_t2 = st["hedge_t2"]
            if dup:
                # any copy's completion pops the watch (_watched.pop in
                # _on_complete): a raced request never hedges again.  In
                # dup mode ``stolen`` records *won* races -- originals whose
                # first completion was a backup copy (steals_won parity)
                hedge_t = jnp.where(m_win, inf, hedge_t)
                stolen = stolen | (m_win & (j_done >= n + 1))
            jh = jnp.argmin(hedge_t).astype(jnp.int32)
            act_able = do_hedge & pend[jh] & (att[jh] < inp["hmax"])
            if dyn:
                # a call lost *mid-execution* keeps its stale req.start
                # after the failure re-route, so every later watch fire is
                # a reference no-op (_maybe_backup's started check) -- it
                # never hedges again; queued-at-kill calls keep hedging
                act_able = act_able & ~st["unhedge"][jh]
            if dyn and freeze:
                # a fire consumes the earliest pending deadline; any later
                # one (a kill survivor) shifts down and stays armed
                m_jh = (oreq_ids == jh) & do_hedge
                hedge_t = jnp.where(m_jh, hedge_t2, hedge_t)
                hedge_t2 = jnp.where(m_jh, inf, hedge_t2)
            else:
                hedge_t = jnp.where((oreq_ids == jh) & do_hedge, inf,
                                    hedge_t)
            old_node = node_of[jh]
            peer_ok = active & (node_ids != old_node)
            if dup:
                # duplicate issue additionally needs a live peer (reference:
                # ``if not others: return`` -- a no-op *without* re-arm)
                steal_ok = act_able & jnp.any(peer_ok)
            else:
                steal_ok = act_able

        if dyn:
            ndone = st["ndone"] + do_comp.astype(jnp.int32)

            # -- kill: wipe the node, schedule the lost for re-arrival ------
            kk = jnp.argmin(killq)
            m_kk = (node_ids == kk)
            lost_slot = jnp.isfinite(fin_s[kk])              # (S,)
            m_lost = jnp.any((idx_s[kk][None, :] == req_ids[:, None])
                             & lost_slot[None, :], axis=1) & do_kill
            if freeze:
                m_lostq = pend & (node_of == kk) & do_kill
                pend = pend & ~m_lostq
                lost_any = m_lost | m_lostq
                # record the _do_fail re-route rank: ex-running keep their
                # launch sequence, ex-queued sort after them (by their
                # enqueue-time priority, resolved at re-arrival)
                rval = jnp.sum(jnp.where(
                    (idx_s[kk][None, :] == req_ids[:, None])
                    & lost_slot[None, :], st["dseq"][kk][None, :], 0),
                    axis=1).astype(jnp.int32)
                rord = jnp.where(m_lost, rval,
                                 jnp.where(m_lostq, jnp.int32(_RORD_Q),
                                           st["rord"]))
            else:
                lost_any = m_lost
            rearr = jnp.where(lost_any, now + detect, rearr)
            nfail = st["nfail"] + jnp.sum(lost_any).astype(jnp.int32)
            if hedge:
                # _do_fail on a hedged cell: the failure retry bumps
                # attempts and voids any earlier hedge credit
                # (_stolen_ids.discard); the re-arrival below re-arms the
                # watch through the insert path, like the reference's
                # _route -> _arm_straggler_watch
                att = jnp.where(lost_any, att + 1, att)
                stolen = stolen & ~lost_any
                if freeze:
                    # queued-at-kill: pending watch fires survive (the
                    # reference's loop callbacks are never cancelled).
                    # Fires landing inside the outage window [kill,
                    # re-arrival] are dead-node no-ops without re-arm, so
                    # only deadlines past it are kept (re-sorted)
                    h1k = jnp.where(hedge_t > now + detect, hedge_t, inf)
                    h2k = jnp.where(hedge_t2 > now + detect, hedge_t2, inf)
                    hedge_t = jnp.where(m_lostq, jnp.minimum(h1k, h2k),
                                        hedge_t)
                    hedge_t2 = jnp.where(m_lostq, jnp.maximum(h1k, h2k),
                                         hedge_t2)
                    # lost mid-execution: the stale req.start makes every
                    # later fire a no-op -- drop both slots outright
                    hedge_t = jnp.where(m_lost, inf, hedge_t)
                    hedge_t2 = jnp.where(m_lost, inf, hedge_t2)
                else:
                    hedge_t = jnp.where(lost_any, inf, hedge_t)
                unhedge = st["unhedge"] | m_lost
            fin_s = jnp.where((m_kk & do_kill)[:, None], inf, fin_s)
            busy = jnp.where(m_kk & do_kill, 0, busy)
            if freeze:   # pull: qn[0] is the global queue -- kills keep it
                qn = jnp.where(m_kk & do_kill, 0, qn)
            dead = dead | (m_kk & do_kill)
            killq = jnp.where(m_kk & do_kill, inf, killq)

            # -- autoscaler tick: queue-per-slot rule on the live state -----
            alldone = ndone >= inp["nreq"]
            n_alive = jnp.sum(active.astype(jnp.int32))
            queued = jnp.sum(qn).astype(jnp.float32)
            prov = st["prov"]
            fire = (do_tick & ~alldone & (prov < inp["maxn"])
                    & (queued > thr * jnp.maximum(n_alive * cores,
                                                  1).astype(jnp.float32)))
            m_new = (node_ids == prov) & fire
            act_t = jnp.where(m_new, now + delay, act_t)
            act_pend = act_pend | m_new
            prov = prov + fire.astype(jnp.int32)
            next_tick = jnp.where(
                do_tick, jnp.where(alldone, inf, now + interval),
                st["next_tick"])

            # -- re-arrival: a lost request re-enters the system ------------
            if freeze:
                # same-instant re-arrivals replay the reference _do_fail
                # order -- node.kill() returns the in-flight dict (launch
                # order) first, then the queue popped in (priority, push
                # seq) order, and _route callbacks run in that sequence;
                # the order decides least-loaded targets and FC counts
                tie = rearr <= jnp.min(rearr)
                ib31 = jnp.int32(2 ** 31 - 1)
                run_k = jnp.where(tie & (rord < _RORD_Q), rord, ib31)
                ir_run = jnp.argmin(run_k).astype(jnp.int32)
                any_run = run_k[ir_run] < ib31
                qp = jnp.where(tie & (rord >= _RORD_Q), fprio, inf)
                if hedge:
                    qk = jnp.where(qp <= jnp.min(qp), st["qseq"], ib31)
                    ir_q = jnp.argmin(qk).astype(jnp.int32)
                else:
                    ir_q = jnp.argmin(qp).astype(jnp.int32)
                ir = jnp.where(any_run, ir_run, ir_q).astype(jnp.int32)
            else:
                ir = jnp.argmin(rearr).astype(jnp.int32)
            m_ir = (req_ids == ir) & do_re
            rearr = jnp.where(m_ir, inf, rearr)
            if not freeze:
                xq = st["xq"] | m_ir     # joins the (virtual) global queue
                enq_t = jnp.where(m_ir, now, st["enq_t"])

        if res:
            # -- request-timeout fire: cancel the queued or running attempt.
            # The invariant "finite to_t => queued xor running" holds
            # because the watch is armed at admission, survives dispatch and
            # is cleared at completion / fire / re-arm, so exactly one of
            # the two branches acts per fire (Cluster._maybe_timeout)
            jt = jnp.argmin(to_t).astype(jnp.int32)
            is_q = pend[jt] & do_to
            slot_match = (idx_s == jt) & jnp.isfinite(fin_s)  # (nodes, S)
            is_run = do_to & ~is_q & jnp.any(slot_match)
            # queued: leave the node queue (scheduler.cancel) and return
            # the admission's E[p] snapshot to the shed gauge, like the
            # reference's queued-cancel -> _on_start
            pend = jnp.where((req_ids == jt) & is_q, False, pend)
            qn = jnp.where((node_ids == node_of[jt]) & is_q, qn - 1, qn)
            qep = qep - jnp.where(is_q, eps[jt], 0.0)
            # running: free the slot mid-flight (scheduler.abort) and
            # account the execution seconds bought and thrown away
            m_rc = slot_match & is_run
            rn = (jnp.argmax(slot_match.ravel()) // n_slots).astype(
                jnp.int32)
            sst_v = jnp.sum(jnp.where(m_rc, sst, 0.0))
            wst = wst + jnp.where(is_run,
                                  jnp.maximum(now - sst_v, 0.0), 0.0)
            fin_s = jnp.where(m_rc, inf, fin_s)
            busy = jnp.where((node_ids == rn) & is_run, busy - 1, busy)
            nto = nto + do_to.astype(jnp.int32)
            to_t = jnp.where((req_ids == jt) & do_to, inf, to_t)
            # retry-or-fail (Cluster._res_fail_or_retry): ``ratt`` already
            # counts this attempt, so the 1-based failed-attempt number is
            # ratt[jt] itself
            can_rt = do_to & on_to & (ratt[jt] < maxa)
            rto = jnp.where((req_ids == jt) & can_rt,
                            now + _res_delay(_res_seq(jt), ratt[jt]), rto)
            nrt = nrt + can_rt.astype(jnp.int32)
            died = do_to & ~can_rt
            nfl = nfl | ((req_ids == jt) & died)
            fcz = jnp.where((req_ids == jt) & died, 1, fcz)
            ndn = ndn + died.astype(jnp.int32)

        # -- arrival / re-arrival: route (freeze) / enqueue, observe --------
        i_orig = jnp.minimum(ai, n)
        if dyn and hedge:
            # arrivals, failure re-arrivals and hedge steals all enter the
            # queue through the same insert path (each is an exclusive
            # event type, so the selection chain below is unambiguous)
            do_ins = do_arr | do_re | steal_ok
            i_ins = jnp.where(do_arr, i_orig, jnp.where(do_re, ir, jh))
        elif dyn:
            do_ins = do_arr | do_re
            i_ins = jnp.where(do_arr, i_orig, ir)
        elif hedge:
            # a steal re-enters the system like an arrival on the target
            # node (reference: target.submit -> receive -> observe_arrival);
            # a dup issue enqueues copy ``attempts + 1`` of request jh
            do_ins = do_arr | steal_ok
            if dup:
                i_dup = ((att[jh] + 1) * (n + 1) + jh).astype(jnp.int32)
                i_ins = jnp.where(do_arr, i_orig, i_dup)
            else:
                i_ins = jnp.where(do_arr, i_orig, jh)
        elif res:
            # a retry re-arrival re-enters through the same insert path as
            # a fresh arrival (reference: loop.schedule(now + delay, _route))
            jr = jnp.argmin(rto).astype(jnp.int32)
            rto = jnp.where((req_ids == jr) & do_rto, inf, rto)
            do_ins = do_arr | do_rto
            i_ins = jnp.where(do_arr, i_orig, jr)
        else:
            do_ins = do_arr
            i_ins = i_orig
        f_i = fnid[i_ins]
        if res:
            # -- admission (Cluster._res_admit, kept in sync line-for-line):
            # count the submission, shed when the queued-E[p] backlog per
            # free slot exceeds the threshold, else snapshot the controller
            # estimate into the gauge and arm the timeout watch.  A shed
            # submission never reaches a node: everything downstream gated
            # on do_ins (node observe, FC log, queue insert, dispatch)
            # stays untouched, exactly like _route returning early.
            do_ins0 = do_ins
            ratt = jnp.where((req_ids == i_ins) & do_ins0, ratt + 1, ratt)
            att_i = ratt[i_ins]          # submissions including this one
            est_z = jnp.where(zrlen[f_i] > 0,
                              zrsum[f_i] / jnp.maximum(zrlen[f_i], 1), 0.0)
            free_tot = jnp.sum(jnp.where(active, cores - busy, 0))
            shed_now = (do_ins0 & (adm_p[0] > 0)
                        & (qep / jnp.maximum(free_tot, 1) > adm_p[1]))
            nsh = nsh + shed_now.astype(jnp.int32)
            sh_rt = shed_now & on_sh & (att_i < maxa)
            rto = jnp.where((req_ids == i_ins) & sh_rt,
                            now + _res_delay(_res_seq(i_ins), att_i), rto)
            nrt = nrt + sh_rt.astype(jnp.int32)
            sh_die = shed_now & ~sh_rt
            nfl = nfl | ((req_ids == i_ins) & sh_die)
            fcz = jnp.where((req_ids == i_ins) & sh_die, 2, fcz)
            ndn = ndn + sh_die.astype(jnp.int32)
            do_ins = do_ins0 & ~shed_now
            eps = jnp.where((req_ids == i_ins) & do_ins, est_z, eps)
            qep = qep + jnp.where(do_ins, est_z, 0.0)
            dl = jnp.where(rto_p[3] > 0, now + rto_p[3],
                           now + rto_p[1] * jnp.maximum(est_z, rto_p[2]))
            to_t = jnp.where((req_ids == i_ins) & do_ins & (rto_p[0] > 0),
                             dl, to_t)
        if freeze:
            # push least-loaded: min busy+queued over nodes, first on ties
            load = jnp.where(active, busy + qn, jnp.int32(2 ** 30))
            k_ll = jnp.argmin(load)
            if dyn:
                k_arr = k_ll         # home routing stays static-capacity
            else:
                # push home invoker: hash start, walk to the first free node
                free_n = (busy < cores) & active
                walk = (home0[i_ins] + node_ids) % jnp.maximum(nodes, 1)
                wfree = free_n[walk] & active
                k_home = jnp.where(jnp.any(wfree), walk[jnp.argmax(wfree)],
                                   home0[i_ins])
                k_arr = jnp.where(route == 1, k_home, k_ll)
            if hedge:
                # steal/copy target: least-loaded *live* peer, the slow node
                # excluded (reference: min(others, key=load), first on
                # ties); with no live peer a steal re-submits to the call's
                # own node (the reference's ``if others else node``) -- dup
                # never reaches the fallback, its steal_ok requires a peer
                load_x = jnp.where(peer_ok, busy + qn, jnp.int32(2 ** 30))
                k_tgt = jnp.where(jnp.any(peer_ok), jnp.argmin(load_x),
                                  old_node)
                k_arr = jnp.where(steal_ok, k_tgt, k_arr)
            k_arr = k_arr.astype(jnp.int32)
        else:
            k_arr = jnp.int32(0)
        en_a = k_arr if freeze else 0
        # pull re-arrivals skip the estimator: the reference re-queues them
        # without a second controller observe_arrival; push re-arrivals go
        # through node.submit -> receive and *are* re-observed
        do_obs = do_ins if freeze else do_arr
        first = narr[en_a, f_i] == 0
        prev_used = jnp.where(first, now, last_t[en_a, f_i])
        m_ea = (jnp.arange(ring.shape[0]) == en_a)
        m_af = (m_ea[:, None] & (fn_ids_ax == f_i)[None, :]) & do_obs
        prev_t = jnp.where(m_af, prev_used, prev_t)
        last_t = jnp.where(m_af, now, last_t)
        narr = jnp.where(m_af, narr + 1, narr)
        if stream and not freeze:
            # chunk-rebased head-window validity counter: counts only fresh
            # arrivals of this chunk (carried queued rows were preloaded by
            # the handoff), matching the CSR fnev row order
            qcnt = jnp.where((fn_ids_ax == f_i) & do_arr,
                             st["qcnt"] + 1, st["qcnt"])
        if hedge and not dup:
            # the stolen call leaves its old node's queue (scheduler.cancel);
            # duplicate mode races a fresh copy instead -- the original
            # stays queued on its own node
            qn = jnp.where((node_ids == old_node) & steal_ok, qn - 1, qn)
        qn = jnp.where((node_ids == k_arr) & do_ins, qn + 1, qn)
        ai = ai + do_arr.astype(jnp.int32)
        if freeze:
            if fc_push:
                # bounded per-(node, fn) arrival ring: log, then count the
                # window (the logged time itself is inside it, matching the
                # reference's observe-then-rank order)
                fcr, fcp = st["fcr"], st["fcp"]
                pos_fc = fcp[k_arr, f_i]
                m_nf = ((node_ids == k_arr)[:, None]
                        & (fn_ids_ax == f_i)[None, :]) & do_ins
                fcr = jnp.where(m_nf[:, :, None]
                                & (jnp.arange(fc_ring) == pos_fc), now, fcr)
                fcp = jnp.where(m_nf, (pos_fc + 1) % fc_ring, fcp)
                cnt_i = jnp.sum(fcr[k_arr, f_i]
                                > now - horizon).astype(jnp.float32)
            else:
                cnt_i = cnt[i_ins]
            est_i = jnp.where(rlen[en_a, f_i] > 0,
                              rsum[en_a, f_i]
                              / jnp.maximum(rlen[en_a, f_i], 1), 0.0)
            prio_i = (coef[0] * now + coef[1] * prev_used
                      + (coef[2] + coef[3] * cnt_i) * est_i)
            pend = pend.at[i_ins].set(jnp.where(do_ins, True, pend[i_ins]))
            fprio = fprio.at[i_ins].set(jnp.where(do_ins, prio_i,
                                                  fprio[i_ins]))
            node_of = node_of.at[i_ins].set(jnp.where(do_ins, k_arr,
                                                      node_of[i_ins]))
            if res:
                qsq = jnp.where((req_ids == i_ins) & do_ins, st["stp"],
                                st["qsq"])
            if hedge:
                # (re-)arm the watch from the controller estimate -- both
                # fresh arrivals and just-stolen/raced calls keep being
                # watched (the watch always tracks the *original* request)
                est_h = jnp.where(crlen[f_i] > 0,
                                  crsum[f_i] / jnp.maximum(crlen[f_i], 1),
                                  0.0)
                arm = now + inp["hmult"] * jnp.maximum(est_h, inp["hfloor"])
                w_ins = (i_ins % (n + 1)).astype(jnp.int32)
                m_w = (oreq_ids == w_ins) & do_ins
                if dyn:
                    # merge the new deadline into the sorted slot pair: a
                    # failure re-arrival may find the pre-kill deadline
                    # still pending (see the kill handler above), and both
                    # keep firing in the reference
                    lo1 = jnp.minimum(hedge_t, hedge_t2)
                    hi1 = jnp.maximum(hedge_t, hedge_t2)
                    hedge_t = jnp.where(m_w, jnp.minimum(lo1, arm), hedge_t)
                    hedge_t2 = jnp.where(
                        m_w, jnp.minimum(hi1, jnp.maximum(lo1, arm)),
                        hedge_t2)
                else:
                    hedge_t = jnp.where(m_w, arm, hedge_t)
                att = jnp.where((oreq_ids == jh) & steal_ok, att + 1, att)
                nbk = st["nbk"] + steal_ok.astype(jnp.int32)
                if dup:
                    # dup ``stolen`` (won races) is set at completion above;
                    # ndone counts first completions only -- once every
                    # request has a winner no event can change the outputs
                    ndone = st["ndone"] + take.astype(jnp.int32)
                else:
                    stolen = stolen | ((oreq_ids == jh) & steal_ok)
                    ndone = st["ndone"] + do_comp.astype(jnp.int32)
                # queue-push sequence: a steal re-pushes the call on its
                # target, so push order decouples from event-index order --
                # the reference's stable queue breaks priority ties by it
                qseq = jnp.where((req_ids == i_ins) & do_ins, st["stepc"],
                                 st["qseq"])

        # -- dispatch: one launch restores the "queued => saturated"
        # invariant (always-warm admission never blocks); a newly-activated
        # node keeps its activation event pending until it is saturated or
        # the queue drains, so multi-slot backfill costs one step per launch
        if dyn:
            ka = jnp.argmin(jnp.where(act_pend, act_t, inf)).astype(jnp.int32)
        if freeze:
            # an event only changes its own node's queue/slots
            k_d = jnp.where(do_ins, k_arr, kn)
            if dyn:
                k_d = jnp.where(do_act, ka, k_d)
            if res:
                # a running-timeout frees a slot on the watched node and
                # backfills there (scheduler.abort -> _dispatch)
                k_d = jnp.where(do_to & is_run, rn, k_d)
            prio_vec = jnp.where(pend & (node_of == k_d), fprio, inf)
            if hedge or res:
                # exact priority ties (common under SEPT/FC: same fn, same
                # estimate) resolve by queue push order, like the
                # reference's stable per-node PriorityQueue -- hedge steals
                # and retry re-arrivals both re-push out of index order
                best = jnp.min(prio_vec)
                seq_v = qseq if hedge else qsq
                qv = jnp.where(prio_vec == best, seq_v, jnp.int32(2 ** 30))
                j = jnp.argmin(qv).astype(jnp.int32)
                has_q = best < inf
                prio_j = best
            else:
                j = jnp.argmin(prio_vec).astype(jnp.int32)
                has_q = prio_vec[j] < inf
                prio_j = prio_vec[j]
        else:
            # pull: the invoker with the most free slots pulls the global
            # best head, ranked fresh from the controller estimator --
            # O(F) over the function-queue heads (see the docstring)
            fs = jnp.where(active, cores - busy, -1)
            k_d = jnp.argmax(fs).astype(jnp.int32)
            est_f = jnp.where(rlen[0] > 0,
                              rsum[0] / jnp.maximum(rlen[0], 1), 0.0)
            if stream:
                # CSR per-function event lists: fnev is the n+1 chunk rows
                # grouped by function, fnst the per-function offsets --
                # O(n + F) memory where the dense (F, kq) table would be
                # O(F * max-calls-per-fn).  Overruns clip onto the sentinel
                # row (t = +inf) and are masked by ``valid`` anyway.
                idx_f = fnev_flat[jnp.clip(fn_start + head, 0, n)]
                valid = head < qcnt
            else:
                kmax = fn_ev.shape[1] - 1
                idx_f = jnp.take_along_axis(
                    fn_ev, jnp.minimum(head, kmax)[:, None], axis=1)[:, 0]
                valid = head < narr[0]
            if use_fc:               # FC window counts: static-stream lookup
                k0 = jnp.searchsorted(t_arr, now - horizon, side="right")
                cnt_f = (cumf[ai] - cumf[k0]).astype(jnp.float32)
                w_est = coef[2] + coef[3] * cnt_f
            else:
                w_est = coef[2]
            base_f = coef[1] * prev_t[0] + w_est * est_f
            prio_f = coef[0] * t_arr[idx_f] + base_f
            if dyn:                  # enqueue-clock term (see _PULL_COEF_DYN)
                prio_f = prio_f + coef[4] * now
            prio_f = jnp.where(valid, prio_f, inf)
            best = jnp.min(prio_f)
            # first-index tie-break over the (virtual) global queue
            j = jnp.min(jnp.where(valid & (prio_f == best), idx_f, n))
            has_q = j < n
            prio_j = best
            if dyn:
                # re-queued lost requests live outside the head windows;
                # same per-function pull formula, but their enqueue clock is
                # the recorded first-dispatch time (their reference r')
                prio_x = jnp.where(xq, coef[0] * t_arr + base_f[fnid]
                                   + coef[4] * st["rq_rt"], inf)
                j_x = jnp.argmin(prio_x).astype(jnp.int32)
                best_x = prio_x[j_x]
                # equal-priority ties resolve by global queue *append* order
                # (the reference's first-in-queue argmin): a re-queued call
                # re-enters at its re-queue time, after every fresh call
                # that was already waiting
                pick_x = (best_x < prio_j) | ((best_x == prio_j)
                                              & (st["enq_t"][j_x] < t_arr[j]))
                j = jnp.where(pick_x, j_x, j)
                prio_j = jnp.minimum(best_x, prio_j)
                has_q = prio_j < inf
        if dyn:
            allow = do_ins | do_comp | do_act
            can = allow & active[k_d] & (busy[k_d] < cores) & has_q
        elif hedge:
            # an ineligible hedge fire is a pure no-op event: no dispatch
            can = (do_ins | do_comp) & (busy[k_d] < cores) & has_q
        elif res:
            # queued-timeouts and shed inserts free no slot: no dispatch
            can = ((do_ins | do_comp | (do_to & is_run))
                   & (busy[k_d] < cores) & has_q)
        else:
            can = ~none_left & (busy[k_d] < cores) & has_q
        if cold:
            # container acquire at dispatch (ContainerPool.acquire): a free
            # (node, fn) container is a warm hit; otherwise the prewarm pool
            # serves -- the ample-memory eligibility bound guarantees the
            # pool never creates from scratch, so every miss charges exactly
            # OURS_PREWARM_EXTRA on the management channel
            f_j = fnid[j]
            warm_hit = freec[k_d, f_j] > 0
            cost_j = cost[j] + jnp.where(warm_hit, 0.0, OURS_PREWARM_EXTRA)
            m_acq = (((node_ids == k_d)[:, None]
                      & (fn_ids_ax == f_j)[None, :]) & can & warm_hit)
            freec = jnp.where(m_acq, freec - 1, freec)
            ncold = st["ncold"] + (can & ~warm_hit).astype(jnp.int32)
            # per-request cold flag: the *original's own* dispatch decides
            # it (dup copies never set it; winner propagation does not copy
            # cold_start in the reference); last-wins across re-dispatches
            coldq = jnp.where((oreq_ids == j) & can, ~warm_hit, st["coldq"])
        else:
            cost_j = cost[j]
        if het:
            # effective speed of the routed node at dispatch time divides
            # the management cost and the execution (OursNodeSim._launch);
            # padding episodes carry node -1 / factor 1 and never match
            slow = jnp.prod(jnp.where((inp["epn"] == k_d)
                                      & (inp["ept0"] <= now)
                                      & (now < inp["ept1"]),
                                      inp["epf"], 1.0))
            eff = inp["spd"][k_d] / slow
            exec_start = jnp.maximum(now, chan[k_d]) + cost_j / eff
        else:
            exec_start = jnp.maximum(now, chan[k_d]) + cost_j
        m_kd = (node_ids == k_d)
        chan = jnp.where(m_kd & can, exec_start, chan)
        fin_j = exec_start + (p[j] / eff if het else p[j])
        slot_free = jnp.isinf(fin_s[k_d]) & (slot_ids < cores)
        s = jnp.argmax(slot_free)
        m_ds = (m_kd[:, None] & (slot_ids == s)[None, :]) & can
        fin_s = jnp.where(m_ds, fin_j, fin_s)
        idx_s = jnp.where(m_ds, j, idx_s)
        if res:
            sst = jnp.where(m_ds, exec_start, sst)
            # the dispatched call leaves the shed gauge (the reference
            # on_start hook): subtract the same stored snapshot its
            # admission added, so the +/- sequence matches bit-for-bit
            qep = qep - jnp.where(can, eps[j], 0.0)
        if dyn and freeze:
            # launch-sequence stamp: orders the in-flight half of a kill's
            # lost set (the reference in_flight dict is insertion-ordered)
            dseq = jnp.where(m_ds, st["dcnt"], st["dseq"])
            dcnt = st["dcnt"] + can.astype(jnp.int32)
        if het and freeze:
            sspd = jnp.where(m_ds, eff, st["sspd"])
        busy = jnp.where(m_kd & can, busy + 1, busy)
        qn = jnp.where(m_kd & can, qn - 1, qn)
        if freeze:
            pend = pend.at[j].set(jnp.where(can, False, pend[j]))
            if hedge:
                # a dispatched call's watch can never act again (steal: the
                # call left the queue; dup: a started original makes fires
                # no-ops without re-arm): clear it so no-op fires do not
                # consume scan steps.  Under dup the oreq mask is all-False
                # for copy dispatches (j >= n+1), which keep the watch live.
                hedge_t = jnp.where((oreq_ids == j) & can, inf, hedge_t)
                if dyn:
                    hedge_t2 = jnp.where((oreq_ids == j) & can, inf,
                                         hedge_t2)
            if dup:
                # winner recording at completion needs the copy's own
                # exec_start, so it is carried per queue entry
                start_q = jnp.where((req_ids == j) & can, exec_start,
                                    st["start_q"])
        else:
            if dyn:
                from_x = can & pick_x
                xq = jnp.where((req_ids == j) & from_x, False, xq)
                adv = can & ~pick_x
                # the reference sets r' at node receive, i.e. the pull moment
                rq_rt = jnp.where((req_ids == j) & can, now, st["rq_rt"])
            else:
                adv = can
            head = jnp.where((fn_ids_ax == fnid[j]) & adv, head + 1, head)
        if dyn:
            # keep the activation event current while the new node can
            # still absorb queued work
            still = do_act & can & (jnp.sum(qn) > 0) & (busy[ka] < cores)
            act_pend = jnp.where((node_ids == ka) & do_act, still, act_pend)

        # per-dispatch record: scattered into per-request arrays after the
        # scan, so the carry holds no O(n) output state (the pull carry is
        # O(F + nodes), which is what makes long streams cheap)
        out = (jnp.where(can, j, n), exec_start, fin_j, prio_j, k_d)
        nxt = {"ai": ai, "head": head, "fin_s": fin_s, "idx_s": idx_s,
               "busy": busy, "qn": qn, "chan": chan,
               "ring": ring, "rsum": rsum, "rlen": rlen, "rpos": rpos,
               "last_t": last_t, "prev_t": prev_t, "narr": narr}
        if freeze:
            nxt.update(pend=pend, fprio=fprio, node_of=node_of)
        if fc_push:
            nxt.update(fcr=fcr, fcp=fcp)
        if cold:
            nxt.update(freec=freec, ncold=ncold, nevt=nevt, coldq=coldq)
        if hedge:
            nxt.update(hedge_t=hedge_t, att=att, nbk=nbk, stolen=stolen,
                       cring=cring, crsum=crsum, crlen=crlen, crpos=crpos,
                       qseq=qseq, stepc=st["stepc"] + 1, ndone=ndone)
        if dup:
            nxt.update(done0=done0, win_start=win_start, win_fin=win_fin,
                       win_node=win_node, start_q=start_q)
        if het and freeze:
            nxt.update(sspd=sspd)
        if dyn:
            if freeze:
                nxt.update(dseq=dseq, dcnt=dcnt, rord=rord)
            nxt.update(act_t=act_t, dead=dead, killq=killq,
                       act_pend=act_pend, rearr=rearr, next_tick=next_tick,
                       prov=prov, nfail=nfail, ndone=ndone)
            if hedge:
                nxt.update(unhedge=unhedge)
                if freeze:
                    nxt.update(hedge_t2=hedge_t2)
            if not freeze:
                nxt.update(xq=xq, rq_rt=rq_rt, enq_t=enq_t)
        if res:
            nxt.update(to_t=to_t, rto=rto, eps=eps, qep=qep, ratt=ratt,
                       nfl=nfl, fcz=fcz, sst=sst, nto=nto, nsh=nsh,
                       nrt=nrt, wst=wst, ndn=ndn, qsq=qsq,
                       stp=st["stp"] + 1, zring=zring,
                       zrsum=zrsum, zrlen=zrlen, zrpos=zrpos)
        if stream and not freeze:
            nxt.update(qcnt=qcnt)
        return nxt, out

    # the scan carry is the packed (clk, ctr) plane pair; the dict view the
    # step works on is reconstructed by static slicing, which XLA folds into
    # the step body (the unpack/update/pack chain fuses away)
    layout = _carry_layout(inp, n_nodes=n_nodes, n_slots=n_slots,
                           window=window, freeze=freeze, fc_push=fc_push,
                           dyn=dyn, het=het, hedge=hedge, cold=cold,
                           dup=dup, n_copies=n_copies, fc_ring=fc_ring,
                           res=res, stream=stream)

    def plane_step(planes, x):
        nxt, rec = step(layout.unpack(*planes), x)
        return layout.pack(nxt), rec

    (clk, ctr), (j_s, es_s, fs_s, pj_s, kd_s) = jax.lax.scan(
        plane_step, (clk, ctr), None, length=n_steps)
    if stream:
        # chunked-stream mode: the host handoff needs the final carry
        # planes (everything a summary would report lives in them) plus the
        # raw dispatch records -- last-wins resolution across re-dispatches
        # happens host-side in global chunk order for every feature set
        return (clk, ctr), (j_s, es_s, fs_s, pj_s, kd_s)
    state = layout.unpack(clk, ctr)
    aux = {}
    if cold:
        aux.update(ncold=state["ncold"], nevt=state["nevt"],
                   coldq=state["coldq"])
    if hedge:
        # steal mode: every stolen call completes on its hedge target, so
        # distinct-stolen == steals won; dup mode: ``stolen`` marks
        # originals whose race was won by a backup copy (accounting parity
        # with Cluster either way).  ndone lets the caller detect an
        # exhausted optimistic step budget.
        aux.update(nbk=state["nbk"],
                   nstl=jnp.sum(state["stolen"].astype(jnp.int32)),
                   att=state["att"], ndone=state["ndone"])
    if dyn:
        # a lost request is dispatched twice; XLA scatter order over
        # duplicate indices is undefined, so the last-wins resolution
        # happens host-side in step order (see _run_scan_bucket)
        summary = {"nfail": state["nfail"], "ndone": state["ndone"],
                   "prov": state["prov"], "act_t": state["act_t"],
                   "dead": state["dead"], **aux}
        if freeze:
            summary.update(prio=state["fprio"], node=state["node_of"])
        return (j_s, es_s, fs_s, pj_s, kd_s), summary
    if res:
        # a timed-out-and-retried request is dispatched more than once, so
        # the step records resolve host-side last-wins like dyn; ``ndn``
        # lets the caller verify the step budget covered every lifecycle
        summary = {"nto": state["nto"], "nsh": state["nsh"],
                   "nrt": state["nrt"], "wst": state["wst"],
                   "nfl": state["nfl"], "fcz": state["fcz"],
                   "ratt": state["ratt"], "ndn": state["ndn"],
                   "prio": state["fprio"], "node": state["node_of"]}
        return (j_s, es_s, fs_s, pj_s, kd_s), summary
    if dup:
        # a raced request's client-visible outcome is its first-completed
        # copy (the reference run() back-copies the winner's
        # start/finish/node onto the original); copy-0 keeps the frozen
        # arrival priority, which winner propagation never overwrites
        return (state["win_start"], state["win_fin"],
                state["fprio"][:n + 1], state["win_node"], aux)
    # one batched scatter per output; can=False steps landed on sentinel n
    start = jnp.zeros(n + 1).at[j_s].set(es_s)
    finish = jnp.zeros(n + 1).at[j_s].set(fs_s)
    if freeze:
        prio = state["fprio"]        # frozen at arrival, never overwritten
        node = state["node_of"]
    else:
        prio = jnp.zeros(n + 1).at[j_s].set(pj_s)
        node = jnp.zeros(n + 1, dtype=jnp.int32).at[j_s].set(kd_s)
    return start, finish, prio, node, aux


# ---------------------------------------------------------------------------
# compilation cache keyed by padded bucket shape
# ---------------------------------------------------------------------------
# Shapes are padded to powers of two (requests, nodes, slots, functions and
# batch) so a whole sweep resolves to a handful of distinct bucket keys; each
# key holds one jitted vmapped kernel, shared across run_sweep calls, so the
# XLA compile is paid once per bucket per process.
SCAN_BATCH_MAX = 256         # default cells/chunk (auto-tuner may override)
# async dispatch window: chunks of a bucket are dispatched ahead of the host
# sync so XLA overlaps transfer and compute, but every in-flight chunk pins
# its host inputs (hedge re-dispatch needs them) and its device results, so
# the window caps peak memory
SCAN_INFLIGHT = int(os.environ.get("REPRO_SCAN_INFLIGHT", "4"))
# one-time per-(bucket-shape, backend) chunk-size measurement; disable with
# REPRO_SCAN_AUTOTUNE=0 to pin SCAN_BATCH_MAX.  Candidate chunks are capped
# by the REPRO_SCAN_MEM_MB device-footprint budget.
SCAN_AUTOTUNE = os.environ.get("REPRO_SCAN_AUTOTUNE", "1") != "0"
SCAN_MEM_MB = float(os.environ.get("REPRO_SCAN_MEM_MB", "512"))
# resident compiled runners (LRU beyond this); long sweep sessions over
# ever-changing shapes can bound their footprint via the environment
SCAN_CACHE_MAX = int(os.environ.get("REPRO_SCAN_CACHE_MAX", "32"))


@dataclass
class _CacheEntry:
    """Compiled state for one bucket *shape*, across every batch size it has
    been dispatched at.  Folding the batch axis into the entry (instead of
    the cache key) means tail chunks, auto-tune candidates and degraded-cell
    retries extend an existing entry rather than churning LRU eviction of
    other shapes' runners."""

    runners: dict = field(default_factory=dict)    # bsz -> (init_c, scan_c)
    compile_s: dict = field(default_factory=dict)  # bsz -> seconds
    hits: int = 0                 # chunk dispatches that reused a runner
    chunk: int | None = None      # auto-tuned cells/chunk (None = untuned)


_SCAN_CACHE: dict[tuple, _CacheEntry] = {}   # shape key -> entry (LRU order)
_SCAN_CACHE_STATS = {"hits": 0, "misses": 0}

# per-chunk dispatch timing records (input build vs compile vs device
# dispatch vs host sync), appended by ``_run_scan_bucket`` and surfaced by
# ``engine_bench --rows mega``; bounded so long sessions don't grow them
_SCAN_TIMINGS: list[dict] = []
_SCAN_TIMINGS_MAX = 4096
_SCAN_PROFILE_DONE = False       # REPRO_SCAN_PROFILE one-shot latch


def _pow2(x: int) -> int:
    """Smallest power of two >= x (>= 1)."""
    return 1 << max(0, int(x) - 1).bit_length()


def _bucket_tag(shape_key: tuple) -> str:
    """Human-readable stats/timing key for one bucket shape."""
    return ("mask=%#x,n=%d,nodes=%d,slots=%d,fns=%d,kq=%d,win=%d,ring=%d,"
            "ep=%d,cp=%d,xtra=%d" % shape_key)


def scan_cache_stats() -> dict:
    """Bucket-cache counters: ``misses`` = runner compilations in this
    process, ``hits`` = chunk dispatches that reused one, ``size`` =
    resident compiled runners across all bucket shapes, and ``entries`` =
    per-shape detail (hit count, compiled batch sizes, compile seconds and
    the auto-tuned chunk size)."""
    entries = {
        _bucket_tag(k): {
            "hits": e.hits,
            "batches": sorted(e.runners),
            "compiles": len(e.compile_s),
            "compile_s": round(sum(e.compile_s.values()), 6),
            "chunk": e.chunk,
        }
        for k, e in _SCAN_CACHE.items()
    }
    return {**_SCAN_CACHE_STATS,
            "size": sum(len(e.runners) for e in _SCAN_CACHE.values()),
            "entries": entries}


def scan_cache_clear() -> None:
    _SCAN_CACHE.clear()
    _SCAN_CACHE_STATS["hits"] = 0
    _SCAN_CACHE_STATS["misses"] = 0


def scan_bucket_timings() -> list[dict]:
    """Per-chunk dispatch timing records (most recent last).  Each record:
    ``bucket`` tag, ``bsz`` (padded batch), ``cells`` (real cells), and
    seconds split into ``build_s`` (host input fill), ``compile_s`` (XLA
    compile, zero on cache hits), ``dispatch_s`` (device call issue) and
    ``sync_s`` (host block + unpack).  A bucket whose chunk size was
    auto-tuned additionally carries one ``cells == 0`` record with the
    probe wall in ``tune_s`` -- one-time setup cost, like compiles."""
    return list(_SCAN_TIMINGS)


def scan_timings_clear() -> None:
    """Reset the timing log *and* the REPRO_SCAN_PROFILE one-shot latch, so
    a later sweep in the same process can dump a fresh profiler trace."""
    global _SCAN_PROFILE_DONE
    _SCAN_TIMINGS.clear()
    _SCAN_PROFILE_DONE = False


def _record_timing(rec: dict) -> None:
    if len(_SCAN_TIMINGS) >= _SCAN_TIMINGS_MAX:
        del _SCAN_TIMINGS[:_SCAN_TIMINGS_MAX // 2]
    _SCAN_TIMINGS.append(rec)


# The carry of ``_scan_cell_kernel`` is an ordered pipeline of feature-flagged
# segments: each entry names a compile flag and the carry keys the segment
# contributes when enabled (always-on base state -- slots, queues, channel
# clocks, estimator rings -- is not listed).  Bit i of a bucket key's leading
# feature mask enables segment i, so the compile cache distinguishes exactly
# the distinct enabled-segment sets and nothing else.
_CARRY_SEGMENTS: tuple[tuple[str, tuple[str, ...]], ...] = (
    ("freeze", ("pend", "fprio", "node_of")),
    ("use_fc", ()),                       # static-stream lookup, carry-free
    ("fc_push", ("fcr", "fcp")),
    ("cold", ("freec", "ncold", "nevt", "coldq")),
    ("hedge", ("hedge_t", "att", "nbk", "stolen", "cring", "crsum", "crlen",
               "crpos", "qseq", "stepc", "ndone", "unhedge", "hedge_t2")),
    ("dup", ("done0", "win_start", "win_fin", "win_node", "start_q")),
    ("het", ("sspd",)),
    ("dyn", ("act_t", "dead", "killq", "act_pend", "rearr", "next_tick",
             "prov", "nfail", "ndone", "xq", "rq_rt", "enq_t",
             "dseq", "dcnt", "rord")),
    ("res", ("to_t", "rto", "eps", "qep", "ratt", "nfl", "fcz", "sst",
             "nto", "nsh", "nrt", "wst", "ndn", "qsq", "stp",
             "zring", "zrsum", "zrlen", "zrpos")),
    ("stream", ("qcnt",)),               # chunked-stream carry handoff
)


def _feature_mask(**flags: bool) -> int:
    """Pack kernel compile flags into the bucket key's leading bitmask
    (bit i = segment i of ``_CARRY_SEGMENTS``)."""
    mask = 0
    for bit, (name, _) in enumerate(_CARRY_SEGMENTS):
        if flags.pop(name, False):
            mask |= 1 << bit
    if flags:
        raise TypeError(f"unknown feature flags: {sorted(flags)}")
    return mask


def _mask_features(mask: int) -> dict[str, bool]:
    """Decode a bucket key's feature bitmask back into kernel flag kwargs."""
    if mask >> len(_CARRY_SEGMENTS):
        raise ValueError(f"feature mask {mask:#x} has unknown bits")
    return {name: bool(mask >> bit & 1)
            for bit, (name, _) in enumerate(_CARRY_SEGMENTS)}


def _use64(flags: dict) -> bool:
    # dynamic-capacity, heterogeneous, hedged, cold and resilience buckets
    # compute in float64 (enable_x64): failure, backup, cold-start and
    # timeout/shed accounting depend on exact completion-vs-kill/deadline
    # event orderings, which float32 channel-clock drift can flip under
    # heavy backlog
    return (flags["dyn"] or flags["het"] or flags["hedge"] or flags["cold"]
            or flags["res"])


def _x64_ctx(use64: bool):
    if use64:
        from jax.experimental import enable_x64
        return enable_x64()
    return contextlib.nullcontext()


def _alloc_bucket_inputs(shape_key: tuple, bsz: int) -> dict:
    """Zero-filled host input arrays for one bucket shape at batch ``bsz``.
    ``t`` defaults to +inf, so the untouched allocation is a valid *idle*
    bucket whose per-step cost matches a loaded one (the step does the same
    gathers/wheres regardless of values) -- the auto-tuner measures on
    exactly this, the AOT lowering takes its arg specs from it, and
    ``_run_scan_bucket`` fills rows in place."""
    (mask, n_b, nodes_b, slots_b, f_b, kq, window, fc_ring, n_ep, n_copies,
     xtra) = shape_key
    flags = _mask_features(mask)
    freeze, use_fc = flags["freeze"], flags["use_fc"]
    dyn, het, hedge = flags["dyn"], flags["het"], flags["hedge"]
    stream = flags["stream"]
    fdt = np.float64 if _use64(flags) else np.float32
    n1 = n_b + 1
    n_est = nodes_b if freeze else 1

    inp: dict[str, np.ndarray] = {
        "t": np.full((bsz, n1), np.inf, dtype=fdt),
        "fnid": np.zeros((bsz, n1), dtype=np.int32),
        "p": np.zeros((bsz, n1), dtype=fdt),
        "cost": np.zeros((bsz, n1), dtype=fdt),
        "cnt": np.zeros((bsz, n1), dtype=fdt),
        "home0": np.zeros((bsz, n1), dtype=np.int32),
        "coef": np.zeros((bsz, 5), dtype=fdt),
        "cores": np.zeros(bsz, dtype=np.int32),
        "nodes": np.ones(bsz, dtype=np.int32),
        "route": np.zeros(bsz, dtype=np.int32),
        "ring0": np.zeros((bsz, n_est, f_b, window), dtype=fdt),
        "rsum0": np.zeros((bsz, n_est, f_b), dtype=fdt),
        "rlen0": np.zeros((bsz, n_est, f_b), dtype=np.int32),
        "rpos0": np.zeros((bsz, n_est, f_b), dtype=np.int32),
        # FC pull counts and the per-function queue sequences come from
        # the static arrival stream; freeze buckets get dummy rows (the
        # kernel never traces those branches there)
        "cumf": np.zeros((bsz, n1 if use_fc else 1, f_b), dtype=fdt),
        "fn_ev": (np.full((bsz, f_b, kq), n_b, dtype=np.int32)
                  if not freeze and not stream
                  else np.zeros((bsz, 1, 1), dtype=np.int32)),
    }
    if stream:
        # chunk horizon; +inf = run to exhaustion (the final chunk)
        inp["t_stop"] = np.full(bsz, np.inf, dtype=fdt)
        if not freeze:
            # CSR per-function event lists replace the dense fn_ev table
            inp["fnev"] = np.full((bsz, n1), n_b, dtype=np.int32)
            inp["fnst"] = np.zeros((bsz, f_b), dtype=np.int32)
        if flags["res"]:
            inp["gseq"] = np.zeros((bsz, n1), dtype=np.int32)
    if dyn:
        inp["act0"] = np.full((bsz, nodes_b), np.inf, dtype=fdt)
        inp["killt"] = np.full((bsz, nodes_b), np.inf, dtype=fdt)
        # [autoscale_interval, scale_up_threshold, provision_delay,
        #  failure_detect, autoscale_flag]
        inp["dynp"] = np.zeros((bsz, 5), dtype=fdt)
        inp["maxn"] = np.zeros(bsz, dtype=np.int32)
        inp["nreq"] = np.zeros(bsz, dtype=np.int32)
    if het:
        inp["spd"] = np.ones((bsz, nodes_b), dtype=fdt)
        inp["epn"] = np.full((bsz, n_ep), -1, dtype=np.int32)
        inp["ept0"] = np.zeros((bsz, n_ep), dtype=fdt)
        inp["ept1"] = np.zeros((bsz, n_ep), dtype=fdt)
        inp["epf"] = np.ones((bsz, n_ep), dtype=fdt)
    if hedge:
        inp["hmult"] = np.ones(bsz, dtype=fdt)
        inp["hfloor"] = np.zeros(bsz, dtype=fdt)
        inp["hmax"] = np.zeros(bsz, dtype=np.int32)
    if flags["res"]:
        # ResilienceSpec.arrays() tensor form: timeout [on, multiple,
        # floor, absolute], retry [max_attempts, base, cap, jitter,
        # on_timeout, on_shed], admission [on, threshold].  The idle
        # default (all off, max_attempts=1) never fires an event.
        inp["rto_p"] = np.zeros((bsz, 4), dtype=fdt)
        inp["rrt_p"] = np.zeros((bsz, 6), dtype=fdt)
        inp["rrt_p"][:, 0] = 1.0
        inp["adm_p"] = np.zeros((bsz, 2), dtype=fdt)
    return inp


def _build_runner(shape_key: tuple, bsz: int):
    """Trace + AOT-compile the ``(init, scan)`` executable pair for one
    (bucket shape, batch size), timing the compile.  ``init`` is the vmapped
    plane packer (:func:`_make_planes`); ``scan`` is the fused event-step
    dispatch (:func:`repro.kernels.ops.event_step`) jitted with the carry
    planes **donated**, so the initial-state buffers are reused as the scan
    carry instead of double-allocating large buckets.  AOT lowering (instead
    of plain ``jax.jit`` call-site tracing) is what lets the compile be
    timed separately from the dispatch.  float64 buckets lower under
    ``enable_x64`` -- eval_shape / lowering outside it would silently
    canonicalize the f64 specs back to f32."""
    import jax

    from ..kernels import ops as _kops

    (mask, n_req, n_nodes, n_slots, _, _, window, fc_ring, n_ep, n_copies,
     xtra) = shape_key
    flags = _mask_features(mask)
    state_kw = dict(n_nodes=n_nodes, n_slots=n_slots, window=window,
                    freeze=flags["freeze"], fc_push=flags["fc_push"],
                    dyn=flags["dyn"], het=flags["het"],
                    hedge=flags["hedge"], cold=flags["cold"],
                    dup=flags["dup"], n_copies=n_copies, fc_ring=fc_ring,
                    res=flags["res"], stream=flags["stream"])
    step_kw = dict(state_kw, use_fc=flags["use_fc"], n_ep=n_ep,
                   horizon=DEFAULT_FC_HORIZON, n_steps=2 * n_req + xtra)

    init_fn = jax.jit(jax.vmap(partial(_make_planes, **state_kw)))
    scan_fn = jax.jit(partial(_kops.event_step, **step_kw),
                      donate_argnums=(0, 1))

    import warnings

    with _x64_ctx(_use64(flags)), warnings.catch_warnings():
        # the donated planes rarely alias an output (the kernel returns
        # event records, not the final carry), but donation still lets XLA
        # recycle them for scan temporaries -- silence the advisory
        warnings.filterwarnings("ignore",
                                message="Some donated buffers were not")
        specs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                 for k, v in _alloc_bucket_inputs(shape_key, bsz).items()}
        t0 = time.perf_counter()
        init_c = init_fn.lower(specs).compile()
        clk, ctr = jax.eval_shape(init_fn, specs)
        scan_c = scan_fn.lower(clk, ctr, specs).compile()
        return (init_c, scan_c), time.perf_counter() - t0


def _cache_entry(shape_key: tuple) -> _CacheEntry:
    entry = _SCAN_CACHE.pop(shape_key, None)
    if entry is None:
        entry = _CacheEntry()
    _SCAN_CACHE[shape_key] = entry       # re-insert: most-recently-used last
    return entry


def _evict_runners(current: tuple) -> None:
    """Bound total resident executables: drop whole LRU entries first, then
    the oldest batch-size runner inside the current entry -- never the one
    just built."""
    cap = max(SCAN_CACHE_MAX, 1)
    while sum(len(e.runners) for e in _SCAN_CACHE.values()) > cap:
        victim = next((k for k in _SCAN_CACHE if k != current), None)
        if victim is not None:
            _SCAN_CACHE.pop(victim)
            continue
        entry = _SCAN_CACHE[current]
        if len(entry.runners) <= 1:
            break
        bsz = next(iter(entry.runners))
        entry.runners.pop(bsz)
        entry.compile_s.pop(bsz, None)


def _scan_runner(key: tuple):
    """AOT-compiled ``(init, scan)`` pair for one bucket shape at one chunk
    batch size: ``key = (feature_mask, n_req, n_nodes, n_slots, n_fns,
    fn_queue_cap, window, fc_ring, n_ep, n_copies, xtra, batch)`` -- the
    leading element is the :func:`_feature_mask` bitmask of enabled carry
    segments, the trailing one the padded chunk batch.  All batch sizes of
    one shape share a single LRU cache entry (see :class:`_CacheEntry`)."""
    shape_key, bsz = key[:-1], key[-1]
    entry = _cache_entry(shape_key)
    pair = entry.runners.pop(bsz, None)
    if pair is not None:
        entry.runners[bsz] = pair        # MRU within the entry as well
        entry.hits += 1
        _SCAN_CACHE_STATS["hits"] += 1
        return pair
    _SCAN_CACHE_STATS["misses"] += 1
    pair, secs = _build_runner(shape_key, bsz)
    entry.runners[bsz] = pair
    entry.compile_s[bsz] = secs
    _evict_runners(shape_key)
    return pair


def _bucket_bytes(shape_key: tuple, bsz: int) -> int:
    """Rough device footprint of one chunk at batch ``bsz``: inputs, packed
    carry planes and stacked step outputs (the x3 covers planes + XLA
    temporaries + donation slack)."""
    per_cell = sum(v.nbytes
                   for v in _alloc_bucket_inputs(shape_key, 1).values())
    n_b, xtra = shape_key[1], shape_key[10]
    itemsize = 8 if _use64(_mask_features(shape_key[0])) else 4
    outs = (2 * n_b + xtra) * 5 * itemsize
    return (per_cell * 3 + outs) * bsz


def _bucket_chunk(shape_key: tuple, n_cells: int) -> int:
    """Cells per dispatched chunk for this bucket: the auto-tuned value when
    one exists, else :data:`SCAN_BATCH_MAX`.  Tuning runs once per (shape,
    backend) the first time the bucket arrives with more cells than the
    default chunk, and the choice persists on the cache entry (visible in
    ``scan_cache_stats()["entries"]``)."""
    entry = _cache_entry(shape_key)
    if entry.chunk is not None:
        return entry.chunk
    if not SCAN_AUTOTUNE or n_cells <= SCAN_BATCH_MAX:
        return SCAN_BATCH_MAX
    entry.chunk = _autotune_chunk(shape_key, n_cells)
    return entry.chunk


def _autotune_chunk(shape_key: tuple, n_cells: int) -> int:
    """One-time chunk-size measurement for one bucket shape: time the idle
    bucket (per-step cost is value-independent) at power-of-two batch sizes
    under the :data:`SCAN_MEM_MB` footprint cap and keep the cells/sec
    argmax.  Candidates ascend and ``max`` keeps the first maximum, so exact
    ties resolve to the smaller batch; re-tuning the same resident entry is
    a no-op (the choice is cached), which is what the determinism contract
    promises."""
    import jax
    import jax.numpy as jnp

    flags = _mask_features(shape_key[0])
    cap = _pow2(min(n_cells, 1024))
    cands = [b for b in (128, 256, 512, 1024)
             if b <= cap and _bucket_bytes(shape_key, b) <= SCAN_MEM_MB * 2**20]
    if not cands:
        return min(SCAN_BATCH_MAX, cap)

    def _rate(bsz: int) -> float:
        init_c, scan_c = _scan_runner(shape_key + (bsz,))
        inp = _alloc_bucket_inputs(shape_key, bsz)
        best = np.inf
        for _ in range(3):       # min-of-3: robust to scheduler noise
            arrs = {k: jnp.asarray(v) for k, v in inp.items()}
            clk, ctr = init_c(arrs)
            t0 = time.perf_counter()
            res = scan_c(clk, ctr, arrs)
            jax.block_until_ready(res)
            best = min(best, time.perf_counter() - t0)
        return bsz / best

    with _x64_ctx(_use64(flags)):
        rates = [(b, _rate(b)) for b in cands]
    return max(rates, key=lambda kv: kv[1])[0]


@dataclass
class _ScanCell:
    """One prepared cell: features + shape parameters for bucketing."""

    requests: list
    feats: _Arrivals
    cores: int
    nodes: int
    policy: str
    assignment: str      # "single" | "pull" | "push"
    lb: str = "least_loaded"
    warm: bool = True
    dynamics: object | None = None      # ClusterDynamics | None
    profile: object | None = None       # NodeSpeedProfile | None
    hedging: object | None = None       # HedgingSpec | None
    resilience: object | None = None    # ResilienceSpec | None

    @property
    def dyn(self) -> bool:
        return self.dynamics is not None and not self.dynamics.is_static

    @property
    def res(self) -> bool:
        return self.resilience is not None and not self.resilience.is_null

    @property
    def het(self) -> bool:
        return self.profile is not None and not self.profile.is_uniform

    @property
    def hedge(self) -> bool:
        # hedging only ever acts on queued-on-node calls, which the pull
        # model never has (late binding): pull cells run without the hedge
        # machinery and report backups_issued == 0, like the reference
        return self.hedging is not None and self.assignment == "push"

    @property
    def cold(self) -> bool:
        return not self.warm

    @property
    def dup(self) -> bool:
        return self.hedge and self.hedging.mode == "duplicate"

    @property
    def n_copies(self) -> int:
        # duplicate-mode queue width: the original plus one racing copy per
        # allowed backup (see the kernel's flattened copy axis)
        return 1 + int(self.hedging.max_backups) if self.dup else 1

    def node_cap(self) -> int:
        """Largest node count the cell can reach (autoscaler headroom)."""
        return (self.dynamics.capacity_bound(self.nodes)
                if self.dynamics is not None else self.nodes)

    def dyn_budget(self) -> int:
        """Upper bound on the extra scan steps capacity dynamics consume:
        kill events, lost-request re-arrivals, autoscaler ticks (bounded by
        a work-conserving makespan bound over the tick interval) and
        activation backfill dispatches."""
        if not self.dyn:
            return 0
        d = self.dynamics
        n = len(self.feats.t)
        kills = len(d.fail)
        lost = kills * self.cores
        if self.assignment == "push" and kills:
            lost += n                # queued-on-node calls are lost too
        extra = kills + lost
        if d.autoscale:
            grow = max(0, d.capacity_bound(self.nodes) - self.nodes)
            work = 0.0
            if n:
                per_req = self.feats.p + self.feats.chan_cost
                work = (float(self.feats.t[-1]) + float(per_req.sum())
                        + kills * d.failure_detect_s
                        + lost * float(per_req.max()))
            ticks = int(np.ceil(work / max(d.autoscale_interval_s, 1e-6))) + 2
            extra += ticks + grow * (1 + self.cores)
        return extra

    def hedge_budget(self) -> int:
        """*Optimistic* extra scan steps for hedging: a watch is cleared the
        moment its call dispatches, so realized deadline fires are only the
        steals plus attempt-capped no-ops -- empirically well under ``n``.
        ``_run_scan_bucket`` verifies completion (``ndone``) and re-runs a
        chunk at :meth:`hedge_budget_full` when this guess was short, so
        the bound is a performance knob, never a correctness one."""
        if not self.hedge:
            return 0
        return len(self.feats.t)

    def hedge_budget_full(self) -> int:
        """Strict upper bound on the extra scan steps hedging consumes.
        Steal mode: every arm fires at most once and arms = arrivals +
        steals <= n * (1 + max_backups).  Duplicate mode: fires are bounded
        the same way, and each issued copy additionally costs one extra
        completion event, <= n * max_backups more."""
        if not self.hedge:
            return 0
        n = len(self.feats.t)
        hmax = int(self.hedging.max_backups)
        full = n * (1 + 2 * hmax) if self.dup else n * (1 + hmax)
        if self.dyn and self.assignment == "push":
            # each queued-at-kill loss can leave one extra pending deadline
            # (the uncancelled pre-kill watch) that fires once
            full += len(self.dynamics.fail) * self.cores + n
        return full

    def res_budget(self) -> int:
        """*Optimistic* extra scan steps for resilience: realized extra
        events are timeout fires plus retry re-arrivals plus resubmission
        terminals -- ``n`` exactly when retries are off (<= one fire per
        submission), and empirically ~2 n even in a full retry storm.
        ``_run_scan_bucket`` verifies completion (``ndn``) and re-runs a
        chunk at :meth:`res_budget_full` when this guess was short, so the
        bound is a performance knob, never a correctness one."""
        if not self.res:
            return 0
        n = len(self.feats.t)
        return n if int(self.resilience.max_attempts) <= 1 else 2 * n

    def res_budget_full(self) -> int:
        """Strict upper bound on the extra scan steps resilience consumes:
        each of the <= n * max_attempts submissions costs at most one
        insert event (covered by the base arrival budget for the first) and
        one terminal event (completion or timeout fire), plus one retry
        re-arrival event per resubmission -- <= n * (2 * max_attempts - 1)
        extra, rounded up to ``2 n max_attempts``.  Sheds happen inside the
        insert event and stale watch fires never exist (the deadline slot
        is overwritten at re-arm), so no slack is needed for either."""
        if not self.res:
            return 0
        return 2 * len(self.feats.t) * int(self.resilience.max_attempts)

    def bucket(self) -> tuple:
        freeze = self.assignment != "pull"
        dyn = self.dyn
        use_fc = not freeze and self.policy == "fc"
        # single-node static push-FC can use the precomputed global window
        # counts -- unless hedging or retries re-log re-submissions on the
        # node (and shedding withholds arrivals from it), which only the
        # live per-(node, fn) rings can track
        fc_push = (freeze and self.policy == "fc"
                   and (self.nodes > 1 or dyn or self.hedge or self.res))
        if freeze:
            kq = 1                   # fn_ev unused in frozen-priority mode
        else:                        # per-function queue capacity
            kq = _pow2(int(np.bincount(self.feats.fn_ids).max())
                       if len(self.feats.fn_ids) else 1)
        # the per-(node, fn) ring is sized to the worst *global* window
        # count, which bounds any node-local count from above; hedged cells
        # additionally re-log each steal/copy on its target node, so every
        # arrival can contribute up to 1 + max_backups entries in-window
        fc_mult = 1 + int(self.hedging.max_backups) if self.hedge else 1
        if self.res:
            # every admitted resubmission re-logs on its target node
            fc_mult = max(fc_mult, int(self.resilience.max_attempts))
        fc_ring = (_pow2(int(self.feats.count.max()) * fc_mult)
                   if fc_push and len(self.feats.count) else 1)
        n_ep = (_pow2(max(1, len(self.profile.episodes)))
                if self.het else 1)
        extra = self.dyn_budget() + self.hedge_budget() + self.res_budget()
        xtra = _pow2(extra) if extra else 0
        mask = _feature_mask(freeze=freeze, use_fc=use_fc, fc_push=fc_push,
                             cold=self.cold, hedge=self.hedge, dup=self.dup,
                             het=self.het, dyn=dyn, res=self.res)
        return (mask, _pow2(len(self.feats.t)),
                _pow2(self.node_cap()), _pow2(self.cores),
                _pow2(len(self.feats.fns)), kq, DEFAULT_WINDOW,
                fc_ring, n_ep, self.n_copies, xtra)


def _scan_check_outputs(tag: str, cell_idx: int, n: int,
                        fields: dict) -> None:
    """Opt-in (``REPRO_SCAN_CHECK=1``) numerical validation of one cell's
    carry-derived outputs, run after each chunk's host sync: every live
    entry must be finite.  A NaN/inf here means a kernel carry segment went
    numerically bad (e.g. an inf sentinel leaked through a mask); the error
    names the bucket, the cell and the offending field/event so the bad
    segment is identifiable without bisecting the sweep."""
    for name, arr in fields.items():
        a = np.asarray(arr[:n], dtype=np.float64)
        bad = ~np.isfinite(a)
        if bad.any():
            e = int(np.nonzero(bad)[0][0])
            raise FloatingPointError(
                f"REPRO_SCAN_CHECK: non-finite scan output in bucket {tag} "
                f"cell {cell_idx}: field {name!r} = {a[e]!r} at event "
                f"index {e}")


def _run_scan_bucket(key: tuple, cells: list[_ScanCell]) -> list[tuple]:
    """Dispatch one shape bucket in auto-tuned chunks (each padded to a
    power-of-two batch) and return per-cell ``(start, finish, prio, node,
    extras)`` arrays in event order; ``extras`` is ``None`` for plain
    static-capacity cells and a dict (failure/backup counters, cold-start
    flags, activation/dead vectors as applicable) otherwise.  Chunks are
    dispatched asynchronously -- up to :data:`SCAN_INFLIGHT` in flight ahead
    of the host sync -- with the carry planes donated inside the runner, so
    device work overlaps the host-side fill of the next chunk."""
    import jax
    import jax.numpy as jnp

    global _SCAN_PROFILE_DONE

    (mask, n_b, nodes_b, slots_b, f_b, kq, window, fc_ring, n_ep, n_copies,
     xtra) = key
    flags = _mask_features(mask)
    freeze, use_fc, fc_push = (flags["freeze"], flags["use_fc"],
                               flags["fc_push"])
    dyn, het, hedge = flags["dyn"], flags["het"], flags["hedge"]
    cold, dup, resil = flags["cold"], flags["dup"], flags["res"]
    check = os.environ.get("REPRO_SCAN_CHECK") == "1"
    n1 = n_b + 1
    use64 = _use64(flags)
    tag = _bucket_tag(key)
    t_tune = time.perf_counter()
    chunk_max = _bucket_chunk(key, len(cells))
    t_tune = time.perf_counter() - t_tune
    if t_tune > 0.005:
        # the auto-tuner probed this shape (compiles + timed runs): surface
        # the one-time cost as its own record so rate analyses can separate
        # it from steady-state dispatch, like compile time
        _record_timing({"bucket": tag, "bsz": 0, "cells": 0, "build_s": 0.0,
                        "compile_s": 0.0, "dispatch_s": 0.0, "sync_s": 0.0,
                        "tune_s": t_tune})
    out: list[tuple | None] = [None] * len(cells)
    pending: deque = deque()

    def _dispatch(inp, xtra_now: int, rec: dict):
        """Issue one chunk on the device and return the *un-synced* result
        tree (JAX dispatch is asynchronous, so this returns as soon as the
        work is enqueued)."""
        bsz = inp["cores"].shape[0]
        t0 = time.perf_counter()
        init_c, scan_c = _scan_runner((mask, n_b, nodes_b, slots_b, f_b,
                                       kq, window, fc_ring, n_ep, n_copies,
                                       xtra_now, bsz))
        rec["compile_s"] += time.perf_counter() - t0
        t0 = time.perf_counter()
        with _x64_ctx(use64):
            # float64 buckets convert inputs *inside* enable_x64 --
            # quantizing kill/arrival/deadline times through float32 first
            # would merge distinct event times and reintroduce exactly the
            # ordering flips the promotion prevents
            arrs = {k: jnp.asarray(v) for k, v in inp.items()}
            clk, ctr = init_c(arrs)
            res = scan_c(clk, ctr, arrs)
        rec["dispatch_s"] += time.perf_counter() - t0
        return res

    def _finish(lo: int, chunk: list, inp: dict, res, rec: dict) -> None:
        """Host-sync one in-flight chunk, verify hedge step budgets
        (re-running at the strict bound when the optimistic guess fell
        short) and unpack per-cell outputs into ``out``."""
        t0 = time.perf_counter()
        res = jax.tree_util.tree_map(np.asarray, res)    # blocks
        if hedge:
            ndone_b = (res[1] if dyn else res[4])["ndone"]
            if any(int(ndone_b[b]) != len(chunk[b].feats.t)
                   for b in range(len(chunk))):
                # the optimistic hedge step budget fell short (a cell fired
                # far more deadlines than requests): re-run the chunk at
                # the strict worst-case bound, which cannot fall short by
                # construction
                full = max(c.dyn_budget() + c.hedge_budget_full()
                           for c in chunk)
                res = jax.tree_util.tree_map(
                    np.asarray, _dispatch(inp, _pow2(full), rec))
                ndone_b = (res[1] if dyn else res[4])["ndone"]
                for b, cell in enumerate(chunk):
                    if int(ndone_b[b]) != len(cell.feats.t):
                        raise RuntimeError(
                            "hedge scan step budget exhausted at the "
                            f"strict bound ({full}); this is a kernel "
                            "budget bug")
        if resil:
            ndn_b = res[1]["ndn"]
            if any(int(ndn_b[b]) != len(chunk[b].feats.t)
                   for b in range(len(chunk))):
                # the optimistic resilience step budget fell short (a storm
                # fired far more timeouts/retries than the ~2n guess): re-run
                # the chunk at the strict worst-case bound, which cannot fall
                # short by construction -- the per-cell ndn check below then
                # only fires on a genuine kernel budget bug
                full = max(c.dyn_budget() + c.hedge_budget()
                           + c.res_budget_full() for c in chunk)
                res = jax.tree_util.tree_map(
                    np.asarray, _dispatch(inp, _pow2(full), rec))
        rec["sync_s"] += time.perf_counter() - t0
        _record_timing(rec)
        if not dyn and not resil:
            start_b, finish_b, prio_b, node_b, aux = res
            for b in range(len(chunk)):
                ex: dict | None = {}
                if hedge:
                    ex.update(backups=int(aux["nbk"][b]),
                              steals=int(aux["nstl"][b]),
                              attempts=aux["att"][b])
                if cold:
                    ex.update(cold_starts=int(aux["ncold"][b]),
                              evictions=int(aux["nevt"][b]),
                              coldq=aux["coldq"][b])
                if check:
                    _scan_check_outputs(
                        tag, lo + b, len(chunk[b].feats.t),
                        {"start": start_b[b], "finish": finish_b[b],
                         "prio": prio_b[b]})
                out[lo + b] = (np.asarray(start_b[b], dtype=np.float64),
                               np.asarray(finish_b[b], dtype=np.float64),
                               np.asarray(prio_b[b], dtype=np.float64),
                               node_b[b], ex or None)
            return
        (j_s, es_s, fs_s, pj_s, kd_s), summary = res
        es_s = np.asarray(es_s, dtype=np.float64)
        fs_s = np.asarray(fs_s, dtype=np.float64)
        pj_s = np.asarray(pj_s, dtype=np.float64)
        for b, cell in enumerate(chunk):
            n = len(cell.feats.t)
            ndone = int(summary["ndn" if resil else "ndone"][b])
            if ndone != n:
                raise RuntimeError(
                    f"scan {'resilience' if resil else 'dynamics'} step "
                    f"budget exhausted: cell resolved {ndone}/{n} requests "
                    f"(bucket xtra={xtra}); this is a kernel budget bug")
            # a re-dispatched lost/retried request appears twice in the step
            # record; numpy fancy assignment resolves duplicates last-wins
            # in step order, which is exactly the re-dispatch overriding
            # the cancelled one
            start = np.zeros(n1)
            finish = np.zeros(n1)
            start[j_s[b]] = es_s[b]
            finish[j_s[b]] = fs_s[b]
            if freeze:
                prio = summary["prio"][b].astype(np.float64)
                node = summary["node"][b]
            else:
                prio = np.zeros(n1)
                node = np.zeros(n1, dtype=np.int64)
                prio[j_s[b]] = pj_s[b]
                node[j_s[b]] = kd_s[b]
            if resil:
                extras = {
                    "timed_out": int(summary["nto"][b]),
                    "shed": int(summary["nsh"][b]),
                    "retries_issued": int(summary["nrt"][b]),
                    "wasted_work": float(summary["wst"][b]),
                    "failed_mask": summary["nfl"][b],
                    "failed_cause": summary["fcz"][b],
                    "attempts_res": summary["ratt"][b],
                }
            else:
                extras = {
                    "failures": int(summary["nfail"][b]),
                    "nodes_used": int(summary["prov"][b]),
                    "act_t": summary["act_t"][b],
                    "dead": summary["dead"][b],
                    "killt": inp["killt"][b],
                }
                if hedge:
                    extras.update(backups=int(summary["nbk"][b]),
                                  steals=int(summary["nstl"][b]),
                                  attempts=summary["att"][b])
                if cold:
                    extras.update(cold_starts=int(summary["ncold"][b]),
                                  evictions=int(summary["nevt"][b]),
                                  coldq=summary["coldq"][b])
            if check:
                _scan_check_outputs(tag, lo + b, n,
                                    {"start": start, "finish": finish,
                                     "prio": prio})
            out[lo + b] = (start, finish, prio, node, extras)

    for lo in range(0, len(cells), chunk_max):
        chunk = cells[lo:lo + chunk_max]
        bsz = _pow2(len(chunk))
        t_build = time.perf_counter()
        inp = _alloc_bucket_inputs(key, bsz)

        for b, cell in enumerate(chunk):
            f = cell.feats
            n = len(f.t)
            inp["t"][b, :n] = f.t
            inp["fnid"][b, :n] = f.fn_ids
            inp["p"][b, :n] = f.p
            inp["cost"][b, :n] = f.chan_cost
            inp["cnt"][b, :n] = f.count
            inp["cores"][b] = cell.cores
            inp["nodes"][b] = cell.nodes
            if dyn:
                d = cell.dynamics
                inp["act0"][b, :cell.nodes] = 0.0
                for idx, at in d.fail:
                    # duplicate kills of one node: the earliest wins, like
                    # the reference's _do_fail no-op on an already-dead node
                    inp["killt"][b, idx] = min(inp["killt"][b, idx], at)
                inp["dynp"][b] = (d.autoscale_interval_s,
                                  d.scale_up_queue_per_slot,
                                  d.provision_delay_s,
                                  d.failure_detect_s,
                                  1.0 if d.autoscale else 0.0)
                inp["maxn"][b] = cell.node_cap()
                inp["nreq"][b] = n
            if het:
                spd, epn, ept0, ept1, epf = cell.profile.arrays(nodes_b,
                                                                n_ep)
                inp["spd"][b] = spd
                inp["epn"][b] = epn
                inp["ept0"][b] = ept0
                inp["ept1"][b] = ept1
                inp["epf"][b] = epf
            if hedge:
                h = cell.hedging
                inp["hmult"][b] = h.multiple
                inp["hfloor"][b] = h.floor_s
                inp["hmax"][b] = h.max_backups
            if resil:
                t4, r6, a2 = cell.resilience.arrays()
                inp["rto_p"][b] = t4
                inp["rrt_p"][b] = r6
                inp["adm_p"][b] = a2
            if cell.assignment == "pull":
                if dyn:
                    inp["coef"][b] = _PULL_COEF_DYN[cell.policy]
                else:
                    inp["coef"][b, :4] = _PULL_COEF[cell.policy]
                if use_fc:
                    onehot = np.zeros((n, f_b), dtype=np.float32)
                    onehot[np.arange(n), f.fn_ids] = 1.0
                    inp["cumf"][b, 1:n + 1] = np.cumsum(onehot, axis=0)
                    inp["cumf"][b, n + 1:] = inp["cumf"][b, n]
                for fi in range(len(f.fns)):
                    idx = np.nonzero(f.fn_ids == fi)[0]
                    inp["fn_ev"][b, fi, :idx.size] = idx
                continue
            inp["coef"][b, :4] = _POLICY_COEF[cell.policy]
            if cell.assignment == "push" and cell.lb == "home":
                from .traces import stable_hash
                inp["route"][b] = 1
                hashes = np.array([stable_hash(fn) for fn in f.fns],
                                  dtype=np.int64)
                inp["home0"][b, :n] = (hashes % cell.nodes)[f.fn_ids]
            # §V-A warm-up seeds every node's estimator with the profile
            # median (single-node semantics at nodes=1); autoscaled nodes
            # warm up the same way the moment they are provisioned.  The
            # warm=False regime skips the seed: the reference only seeds
            # estimators alongside container warm-up (warm_functions)
            if cell.warm:
                seed_n = min(cell.cores, window)
                for fi, fn in enumerate(f.fns):
                    w = PROFILES[fn].median_s if fn in PROFILES else 0.1
                    inp["ring0"][b, :, fi, :seed_n] = w
                    inp["rsum0"][b, :, fi] = seed_n * w
                    inp["rlen0"][b, :, fi] = seed_n
                    inp["rpos0"][b, :, fi] = seed_n % window

        rec = {"bucket": tag, "bsz": bsz, "cells": len(chunk),
               "build_s": time.perf_counter() - t_build,
               "compile_s": 0.0, "dispatch_s": 0.0, "sync_s": 0.0}
        if (os.environ.get("REPRO_SCAN_PROFILE") == "1"
                and not _SCAN_PROFILE_DONE):
            # one-shot REPRO_SCAN_PROFILE=1 hook: dump a jax.profiler trace
            # of a single bucket dispatch (view with TensorBoard / xprof)
            _SCAN_PROFILE_DONE = True
            tdir = os.environ.get("REPRO_SCAN_PROFILE_DIR",
                                  "/tmp/repro_scan_profile")
            with jax.profiler.trace(tdir):
                res = _dispatch(inp, xtra, rec)
                jax.block_until_ready(res)
        else:
            res = _dispatch(inp, xtra, rec)
        pending.append((lo, chunk, inp, res, rec))
        # bounded async window: every chunk is dispatched before its
        # predecessors are synced, so device work overlaps the host-side
        # fill of the next chunk without pinning the whole bucket
        while len(pending) >= max(SCAN_INFLIGHT, 1):
            _finish(*pending.popleft())
    while pending:
        _finish(*pending.popleft())
    return out


@dataclass
class ScanMetrics:
    """Metrics-only output for one scan cell: response-time / stretch
    arrays in **request order** plus the extras counters, with no Request
    objects touched.  Request-order arrays make the means bit-identical to
    the write-back path (``np.mean`` pairwise summation is order-sensitive
    in the last ulp), and not mutating the requests is what lets callers
    share one workload across every policy/fleet cell that uses it."""

    resp: np.ndarray          # response times, request order
    stretch: np.ndarray       # stretch values, request order
    max_c: float              # makespan (max completion time)
    fnids: np.ndarray         # per-request index into ``fns``
    fns: tuple                # sorted function names
    cold_starts: int = 0
    evictions: int = 0
    failures: int = 0
    backups: int = 0
    steals: int = 0
    nodes_used: int = 0


def _cell_scan_metrics(cell: _ScanCell, finish, extras,
                       req_cache: dict) -> ScanMetrics:
    """Fold one cell's event-order finish times into request-order metric
    arrays, replicating the write-back arithmetic operation-for-operation
    (``c = finish + RESP_OVERHEAD_S``; ``resp = c - r``; ``stretch = resp /
    max(ref-or-p_true, 1e-9)``) so the results agree bitwise.  ``req_cache``
    memoizes the per-workload arrays by list identity within one batch call
    -- cells sharing a workload pay the Python-level extraction once."""
    f = cell.feats
    n = len(f.t)
    cached = req_cache.get(id(cell.requests))
    if cached is None:
        r_req = np.array([req.r for req in cell.requests], dtype=np.float64)
        den = np.array([max(STRETCH_REFERENCE_S.get(req.fn) or req.p_true,
                            1e-9) for req in cell.requests])
        cached = req_cache[id(cell.requests)] = (r_req, den)
    r_req, den = cached
    finish_req = np.empty(n, dtype=np.float64)
    finish_req[f.order] = np.asarray(finish[:n], dtype=np.float64)
    c_req = finish_req + RESP_OVERHEAD_S
    resp = c_req - r_req
    fnids = np.empty(n, dtype=np.int64)
    fnids[f.order] = f.fn_ids
    ex = extras or {}
    return ScanMetrics(
        resp=resp, stretch=resp / den, max_c=float(c_req.max()),
        fnids=fnids, fns=tuple(f.fns),
        cold_starts=ex.get("cold_starts", 0),
        evictions=ex.get("evictions", 0),
        failures=ex.get("failures", 0), backups=ex.get("backups", 0),
        steals=ex.get("steals", 0),
        nodes_used=ex.get("nodes_used", cell.nodes))


def _run_scan_cells(cells: list[_ScanCell],
                    metrics_only: bool = False) -> list:
    """Bucket, dispatch and write back a list of prepared cells (any mix of
    single-node / pull / push, static or dynamic capacity), preserving input
    order.  ``metrics_only=True`` skips the per-request write-back and
    returns :class:`ScanMetrics` rows instead of :class:`SimResult` -- the
    interactive-sweep mode, where cells share workloads and only aggregate
    metrics leave the batch."""
    buckets: dict[tuple, list[int]] = {}
    for i, cell in enumerate(cells):
        buckets.setdefault(cell.bucket(), []).append(i)
    results: list = [None] * len(cells)
    req_cache: dict = {}
    for key, idxs in buckets.items():
        arrays = _run_scan_bucket(key, [cells[i] for i in idxs])
        for i, (start, finish, prio, node, extras) in zip(idxs, arrays):
            cell = cells[i]
            if metrics_only:
                if cell.res:
                    # resilience cells can terminate requests without a
                    # completion; the metrics-only fold assumes every
                    # request finished, so those cells always write back
                    raise ValueError(
                        "metrics_only is not supported for resilience "
                        "cells; run them through the write-back path")
                results[i] = _cell_scan_metrics(cell, finish, extras,
                                                req_cache)
                continue
            f = cell.feats
            order = f.order.tolist()
            t_list = f.t.tolist()
            att = extras.get("attempts") if extras is not None else None
            coldq = extras.get("coldq") if extras is not None else None
            fmask = (extras.get("failed_mask")
                     if extras is not None else None)
            fcause = extras.get("failed_cause") if extras is not None else None
            ratt = extras.get("attempts_res") if extras is not None else None
            for e, ridx in enumerate(order):
                req = cell.requests[ridx]
                req.node = f"node{int(node[e])}"
                req.r_prime = t_list[e]
                req.priority = float(prio[e])    # float32-rounded
                # warm cells never cold-start; cold cells carry the
                # original's own dispatch decision per request
                req.cold_start = bool(coldq[e]) if coldq is not None else False
                if fmask is not None and bool(fmask[e]):
                    # terminal failure: the recorded start/finish belong to
                    # a cancelled attempt -- the client never saw a response
                    req.start = req.finish = req.c = None
                    req.failed = "timeout" if int(fcause[e]) == 1 else "shed"
                    req.attempts = max(int(ratt[e]) - 1, 0)
                    continue
                req.start = float(start[e])
                req.finish = float(finish[e])
                req.c = req.finish + RESP_OVERHEAD_S
                req.failed = None
                if att is not None:              # hedged cell: backup count
                    req.attempts = int(att[e])
                if ratt is not None:             # resubmission count
                    req.attempts = max(int(ratt[e]) - 1, 0)
            meta = {"mode": "ours", "policy": cell.policy,
                    "cores": cell.cores, "backend": "scan"}
            if cell.assignment != "single":
                meta["nodes"] = cell.nodes
                meta["assignment"] = cell.assignment
            failures = backups = steals = 0
            cold_starts = evictions = 0
            timed_out = shed = retries_issued = 0
            wasted_work = 0.0
            nodes_used = cell.nodes
            timeline = None
            if extras is not None:
                failures = extras.get("failures", 0)
                backups = extras.get("backups", 0)
                steals = extras.get("steals", 0)
                cold_starts = extras.get("cold_starts", 0)
                evictions = extras.get("evictions", 0)
                timed_out = extras.get("timed_out", 0)
                shed = extras.get("shed", 0)
                retries_issued = extras.get("retries_issued", 0)
                wasted_work = extras.get("wasted_work", 0.0)
                if "act_t" in extras:        # dynamic-capacity cell
                    from .cluster import CapacityTimeline
                    nodes_used = extras["nodes_used"]
                    timeline = CapacityTimeline(
                        activate=[float(a)
                                  for a in extras["act_t"][:nodes_used]],
                        deactivate=[float(extras["killt"][k])
                                    if bool(extras["dead"][k])
                                    else float("inf")
                                    for k in range(nodes_used)])
            results[i] = SimResult(
                requests=cell.requests, cold_starts=cold_starts,
                evictions=evictions, creations=0, failures=failures,
                backups_issued=backups, steals_won=steals,
                nodes_used=nodes_used, timeline=timeline,
                timed_out=timed_out, shed=shed,
                retries_issued=retries_issued, wasted_work=wasted_work,
                meta=meta)
    return results  # type: ignore[return-value]


def _feats_cache():
    """Per-batch-call ``_arrival_features`` memo keyed by request-list
    identity: cells sharing one workload (the metrics-only sweep mode) pay
    the numpy feature extraction once.  Scoped to a single batch call so
    recycled ``id()`` values can never alias across calls."""
    cache: dict[int, _Arrivals] = {}

    def feats(requests: list[Request]) -> _Arrivals:
        f = cache.get(id(requests))
        if f is None:
            f = cache[id(requests)] = _arrival_features(requests)
        return f

    return feats


def simulate_cells_scan(
    batch: list[tuple],
    memory_mb: int = 32 * 1024,
    container_mb: int = 128,
    validate: bool = True,
    metrics_only: bool = False,
) -> list[SimResult]:
    """Run a batch of ``(requests, cores, policy[, warm])`` ours-mode
    **single-node** scenarios through the bucketed scan path (cells vmapped,
    one XLA compile per padded bucket shape, shared across calls).

    ``warm`` defaults to ``True``; ``warm=False`` cells run the cold-start /
    eviction regime (prewarm-pool misses and per-function trim evictions
    modelled inside the step, see :func:`_cold_regime_ok`).

    Every cell must satisfy :func:`scan_eligible`; this is checked and raises
    ``ValueError`` otherwise (callers that already checked pass
    ``validate=False`` to skip the re-check).  Start/finish times are written
    back into the request objects exactly like the other backends --
    unless ``metrics_only=True``, which leaves the requests untouched and
    returns :class:`ScanMetrics` rows instead (so one workload can be
    shared across many cells)."""
    if not batch:
        return []
    feats = _feats_cache()
    cells = []
    for item in batch:
        requests, cores, policy = item[:3]
        warm = item[3] if len(item) > 3 else True
        if validate and not scan_eligible(requests, cores, policy,
                                          warm=warm, memory_mb=memory_mb,
                                          container_mb=container_mb):
            raise ValueError(
                "scan backend requires the ours regime, a known policy and "
                "(cold cells) ample container memory "
                f"(policy={policy!r}, cores={cores}, warm={warm}); use "
                "backend='vectorized' for the general exact fast path")
        cells.append(_ScanCell(requests=requests, feats=feats(requests),
                               cores=cores, nodes=1, policy=policy,
                               assignment="single", warm=warm))
    return _run_scan_cells(cells, metrics_only=metrics_only)


# ---------------------------------------------------------------------------
# cluster-scale scan: N-node cells, whole grids as bucketed batches
# ---------------------------------------------------------------------------
def cluster_scan_eligible(
    requests: list[Request],
    nodes: int,
    cores: int,
    policy: str = "fc",
    assignment: str = "pull",
    lb: str = "least_loaded",
    warm: bool = True,
    memory_mb: int = CLUSTER_MEMORY_MB,
    container_mb: int = CLUSTER_CONTAINER_MB,
    dynamics=None,
    profile=None,
    hedging=None,
    resilience=None,
) -> bool:
    """True when the scan kernel reproduces the reference cluster within
    float32 rounding: ours mode, known policy, a container regime the kernel
    models (always-warm -- the §V-A warm-up provisions ``cores`` containers
    per function on the cluster's 40 GB nodes, so up to ~13 cores for the
    full SeBS set -- or the ``warm=False`` ample-memory prewarm regime, see
    :func:`_cold_regime_ok`), and

    * ``assignment="pull"`` -- any policy (priorities are re-ranked at pull
      time from the controller estimator, exactly like the reference), or
    * ``assignment="push"`` with ``lb`` least_loaded/home -- any policy
      including FC, whose per-node sliding-window count is modelled with
      bounded per-(node, fn) arrival-time rings.

    ``dynamics`` (a :class:`~repro.core.cluster.ClusterDynamics`) extends
    eligibility to **time-varying capacity**: autoscaling and scheduled node
    failures run inside the scan step.  Dynamic cells additionally require
    the least-loaded balancer for push (the home walk depends on the alive
    fleet size), failures confined to the initial fleet with at least one
    initial survivor, and -- for failures -- at least two initial nodes, so
    lost requests always have somewhere to go when they re-arrive.

    ``profile`` (a :class:`~repro.core.stragglers.NodeSpeedProfile`) and
    ``hedging`` (a :class:`~repro.core.stragglers.HedgingSpec`) extend
    eligibility to **heterogeneous fleets and straggler hedging**, composing
    freely with capacity dynamics: per-node effective speeds scale slot
    completion times inside the step (profile indices cover autoscaled
    nodes, like the reference's index-based ``_add_node``), steal-mode
    deadlines re-route still-queued calls to the least-loaded live peer (or
    back onto their own node when no peer exists, the reference's
    self-steal) and kills void in-flight watches, and duplicate-mode
    deadlines race copies with winner propagation.  The one remaining
    rejection: **duplicate-mode hedging under push with non-static
    capacity** -- racing copies of re-arrived lost requests have no
    reference-documented semantics, so such cells stay on the event loop.
    """
    if policy not in POLICY_NAMES or nodes < 1:
        return False
    if assignment == "push":
        if lb not in ("least_loaded", "home"):
            return False
    elif assignment != "pull":
        return False
    dyn = dynamics is not None and not dynamics.is_static
    if resilience is not None and not resilience.is_null:
        # the res carry segment models the push (frozen-priority) static
        # warm regime; resilience x pull / dynamics / hedging /
        # heterogeneity / cold-starts runs on the reference loop
        if (assignment != "push" or not warm or dyn
                or hedging is not None
                or (profile is not None and not profile.is_uniform)):
            return False
    if hedging is not None:
        if hedging.mode not in ("steal", "duplicate"):
            return False
        if hedging.mode == "duplicate" and dyn and assignment == "push":
            return False             # racing copies under churn: reference
    cap = dynamics.capacity_bound(nodes) if dynamics is not None else nodes
    if profile is not None and len(profile.speeds) > cap:
        return False                 # speeds beyond the fleet: misconfigured
    if dyn:
        if assignment == "push" and lb != "least_loaded":
            return False
        if dynamics.fail:
            failed = {idx for idx, _ in dynamics.fail}
            if (max(failed) >= nodes or len(failed) >= nodes
                    or any(at < 0 for _, at in dynamics.fail)):
                return False
    if not warm:
        return _cold_regime_ok(requests, cores, memory_mb, container_mb)
    fns = sorted({r.fn for r in requests})
    pool = _FastPool(memory_mb=memory_mb, container_mb=container_mb,
                     cores=cores, fn_memory=SEBS_MEMORY_MB)
    pool.warm_up(fns, per_fn=cores)
    return all(len(pool.free.get(fn, ())) >= cores for fn in fns)


def simulate_cluster_cells_scan(
    batch: list[tuple],
    memory_mb: int = CLUSTER_MEMORY_MB,
    container_mb: int = CLUSTER_CONTAINER_MB,
    validate: bool = True,
    metrics_only: bool = False,
) -> list[SimResult]:
    """Run a batch of ``(requests, nodes, cores, policy[, assignment[, lb[,
    dynamics[, profile[, hedging[, warm[, resilience]]]]]]])`` ours-mode
    cluster scenarios
    as bucketed vmapped scans -- an entire nodes x intensity x policy grid
    becomes a handful of XLA dispatches.  ``dynamics`` (a
    :class:`~repro.core.cluster.ClusterDynamics`, or ``None``) adds
    autoscaling and scheduled failures, ``profile`` (a
    :class:`~repro.core.stragglers.NodeSpeedProfile`) heterogeneous node
    speeds, ``hedging`` (a :class:`~repro.core.stragglers.HedgingSpec`)
    straggler work stealing or duplicate racing, and ``warm=False`` the
    cold-start/eviction regime -- all modelled inside the scan step, in any
    combination :func:`cluster_scan_eligible` accepts.

    Every cell must satisfy :func:`cluster_scan_eligible` (raises
    ``ValueError`` otherwise; ``validate=False`` skips the re-check for
    callers that already ran it).  Semantics follow the reference
    :class:`~repro.core.cluster.Cluster`; agreement is within the documented
    cluster cross-check tolerance (float32 clocks, index-order
    tie-breaking), see ``repro.core.sweep.CLUSTER_XCHECK_RTOL``; lost
    request, backup/steal and cold-start/eviction counts are exact.
    ``metrics_only=True`` skips the per-request write-back and returns
    :class:`ScanMetrics` rows (bit-identical aggregate metrics, shareable
    workloads).
    """
    if not batch:
        return []
    feats = _feats_cache()
    cells = []
    for item in batch:
        requests, nodes, cores, policy = item[:4]
        assignment = item[4] if len(item) > 4 else "pull"
        lb = item[5] if len(item) > 5 else "least_loaded"
        dynamics = item[6] if len(item) > 6 else None
        profile = item[7] if len(item) > 7 else None
        hedging = item[8] if len(item) > 8 else None
        warm = item[9] if len(item) > 9 else True
        resilience = item[10] if len(item) > 10 else None
        if validate and not cluster_scan_eligible(
                requests, nodes, cores, policy, assignment=assignment,
                lb=lb, warm=warm, memory_mb=memory_mb,
                container_mb=container_mb, dynamics=dynamics,
                profile=profile, hedging=hedging, resilience=resilience):
            raise ValueError(
                "scan cluster backend requires the ours regime with "
                "supported dynamics/heterogeneity/hedging/resilience and, "
                "for cold cells, ample container memory "
                f"(policy={policy!r}, nodes={nodes}, cores={cores}, "
                f"assignment={assignment!r}, warm={warm}, "
                f"dynamics={dynamics!r}, hedging={hedging!r}, "
                f"resilience={resilience!r}); use backend='reference'")
        cells.append(_ScanCell(requests=requests, feats=feats(requests),
                               cores=cores, nodes=nodes, policy=policy,
                               assignment=assignment, lb=lb, warm=warm,
                               dynamics=dynamics, profile=profile,
                               hedging=hedging, resilience=resilience))
    return _run_scan_cells(cells, metrics_only=metrics_only)


def simulate_cluster_scan(
    requests: list[Request],
    nodes: int,
    cores_per_node: int = 18,
    policy: str = "fc",
    assignment: str = "pull",
    lb: str = "least_loaded",
    warm: bool = True,
    memory_mb: int = CLUSTER_MEMORY_MB,
    container_mb: int = CLUSTER_CONTAINER_MB,
    dynamics=None,
    profile=None,
    hedging=None,
    resilience=None,
) -> SimResult:
    """Single-cell convenience wrapper over
    :func:`simulate_cluster_cells_scan`."""
    return simulate_cluster_cells_scan(
        [(requests, nodes, cores_per_node, policy, assignment, lb,
          dynamics, profile, hedging, warm, resilience)],
        memory_mb=memory_mb, container_mb=container_mb)[0]


class ScanBackend:
    """Batched jax.lax.scan variant of the ours-mode simulator.

    Supports single nodes *and* clusters: any of the five policies under the
    pull assignment or the push assignment (FC via per-(node, fn) count
    rings), time-varying capacity -- autoscaling and failure injection --
    heterogeneous node speeds (``hetero``), hedging in both steal and
    duplicate (racing-copy) modes, and the cold-start/eviction regime
    (``warm=False``) -- composable in any combination; the per-event scan
    step is an ordered pipeline of feature-flagged carry segments, so each
    combination compiles only the segments it enables.

    The one feature the scan kernel does not model is the stock baseline
    (``mode="baseline"``): processor sharing gives every in-flight call a
    state-dependent service rate that changes at each arrival/departure,
    which does not fit the fixed-slot one-core step; baseline cells run on
    ``backend='reference'``.  Per-cell restrictions that depend on *values*
    rather than flags (degenerate dynamics schedules, cold-regime memory
    bounds) live in :func:`cluster_scan_eligible`."""

    name = "scan"

    def supports(self, *, mode: str, policy: str, warm: bool,
                 nodes: int = 1, assignment: str = "pull",
                 autoscale: bool = False, failures: bool = False,
                 hedging: bool = False, hetero: bool = False,
                 timeouts: bool = False, retries: bool = False,
                 shedding: bool = False,
                 streaming: bool = False, trace: bool = False) -> bool:
        # streaming (the chunked carry-handoff path, core/streamscan.py)
        # covers the same flag matrix as the single-shot kernel, so the
        # flag never changes the answer here
        if trace:
            # no rich event hooks inside the kernel; the canonical
            # lifecycle stream comes from flight.trace_from_result
            return False
        if mode != "ours" or policy not in POLICY_NAMES:
            return False
        if assignment not in ("pull", "push"):
            return False
        if failures and nodes < 2:
            return False             # lost calls need a surviving node
        if timeouts or retries or shedding:
            # the res carry segment models the push (freeze-priority)
            # static warm regime; resilience x pull / dynamics / hedging /
            # heterogeneity / cold-starts runs on the reference loop
            if (assignment != "push" or not warm or autoscale or failures
                    or hedging or hetero):
                return False
        try:
            import jax  # noqa: F401
        except ImportError:
            return False
        return True

    def simulate(
        self,
        requests: list[Request],
        cores: int,
        policy: str = "fifo",
        mode: str = "ours",
        memory_mb: int = 32 * 1024,
        container_mb: int = 128,
        warm: bool = True,
        kappa: float = PS_KAPPA,
    ) -> SimResult:
        if mode != "ours":
            raise ValueError("scan backend requires ours mode")
        if kappa != PS_KAPPA:
            raise ValueError(
                "kappa parameterizes the baseline processor-sharing node, "
                "which the scan backend does not model; use "
                "backend='reference' for non-default kappa")
        return simulate_cells_scan(
            [(requests, cores, policy, warm)], memory_mb=memory_mb,
            container_mb=container_mb)[0]


register_backend(ScanBackend())
