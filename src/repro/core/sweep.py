"""Parallel scenario-sweep engine.

The paper's headline numbers (4x mean response, 18x stretch, 3 nodes beating
a 4-node baseline) all come from sweeping scenario grids -- policy x
intensity x cores x nodes x seeds.  This module makes those grids first-class:

* :class:`SweepSpec` -- a declarative cartesian grid over policy, assignment
  model, intensity, cores, nodes, arrival process, autoscaling, failure
  injection and seeds, with an optional ``cell_filter`` for ragged grids.
* :func:`run_sweep` -- executes every cell through a process pool with
  deterministic per-cell seeding; ``workers=1`` runs inline and produces
  *bit-identical* metrics to ``workers=N`` (each cell is a self-contained
  pure function of its :class:`SweepCell`).
* :class:`SweepResult` -- structured per-cell metrics, seed-aggregated rows
  (mean response / percentiles / stretch / makespan per cell), and JSON/CSV
  emission compatible with the ``benchmarks.common.emit`` contract.

The engine imports no JAX at module scope: reference/vectorized cells run
pure Python, so pool workers fork instantly and a 200+-cell grid saturates
all cores.  Cells on the ``"scan"`` backend never go to the pool at all --
``run_sweep`` partitions them into padded shape buckets (powers of two over
requests x nodes x slots x functions) and dispatches each bucket as one
batched ``jax.lax.scan`` call in the parent process, reusing one cached XLA
compilation per bucket shape across sweeps (``scan_cache_stats``).
"""

from __future__ import annotations

import csv
import itertools
import json
import math
import multiprocessing
import os
import sys
import time
from concurrent.futures import ProcessPoolExecutor
from dataclasses import dataclass, field, fields, replace
from functools import partial
from typing import Callable, Sequence

import numpy as np

from .metrics import summarize, summarize_arrays
from .request import Request
from .workload import (
    generate_burst,
    generate_fairness_burst,
    generate_trace_burst,
)

# grid axes that identify a cell up to its seed (aggregation groups by these)
GRID_FIELDS = ("policy", "mode", "assignment", "lb", "arrival", "intensity",
               "cores", "nodes", "autoscale", "provision_delay", "scale_up",
               "max_nodes", "fail_at", "fail_spec", "node_speeds", "degrade",
               "hedge_multiple", "timeout_multiple", "retry_attempts",
               "retry_mode", "shed_threshold", "backend")

# simulation-backend selectors accepted by SweepCell.backend; the SweepSpec
# backends axis additionally accepts "cross-check" as sugar for
# backends=("reference",) + validate="cross-check"
BACKEND_CHOICES = ("reference", "vectorized", "scan", "auto")

# per-cell agreement budget for cross-checked backends (relative); the
# vectorized backend is exact, so any drift here is a real bug
CROSS_CHECK_RTOL = 1e-2
# Cluster scan-vs-reference budget.  The multi-node scan kernel replays the
# reference Cluster's pull/push semantics but computes clocks and priorities
# in float32 and resolves exact ties by array index, so near-tie orderings
# can flip and cascade through routing under heavy backlog: worst observed
# drift over a policy x nodes x intensity x arrival stress grid is ~1.3%
# (tail percentiles of FC/RECT at sustained overload); typical cells are at
# float32 rounding (~1e-6).  3% leaves headroom without masking real bugs.
CLUSTER_XCHECK_RTOL = 3e-2
# Scan cells whose request stream exceeds this many rows route through the
# chunked streaming path (core/streamscan.py, bit-identical carry handoff)
# instead of padding the whole stream into one device tensor; override with
# REPRO_STREAM_THRESHOLD (0 disables streaming routing entirely).
STREAM_CELL_THRESHOLD = int(os.environ.get("REPRO_STREAM_THRESHOLD",
                                           65536))
# metrics the cross-check compares (count-like metrics must match exactly
# anyway; near-zero values use an absolute epsilon)
CROSS_CHECK_KEYS = ("R_avg", "R_p50", "R_p75", "R_p95", "R_p99",
                    "S_avg", "S_p50", "S_p95", "max_c", "cold", "n")


class BackendMismatchError(AssertionError):
    """Cross-check failed: a fast backend disagreed with the reference."""

# metrics averaged across seeds in aggregate()
METRIC_KEYS = ("R_avg", "R_p50", "R_p75", "R_p95", "R_p99",
               "S_avg", "S_p50", "S_p75", "S_p95", "S_p99",
               "max_c", "cold", "n", "failures", "backups", "steals",
               "nodes_used",
               # resilience cells additionally report (see metrics.
               # resilience_row): successful completions per second,
               # p95 response over successes only, counters, wasted work
               "goodput", "R_ok_p95", "wasted_frac", "timed_out", "shed",
               "retries_issued", "wasted_work", "n_failed")
# count-like metrics the cross-check requires to match *exactly* -- a fast
# backend miscounting backups or lost calls is a hard failure regardless of
# how small the relative error looks (ISSUE: accounting parity).  The
# resilience counters join the list: the scan kernel's res segment replays
# the reference lifecycle bit-for-bit, so any drift is a real bug.
CROSS_CHECK_EXACT = ("failures", "backups", "steals",
                     "timed_out", "shed", "retries_issued", "n_failed")


@dataclass(frozen=True)
class SweepCell:
    """One fully-specified scenario: everything a worker needs to run it."""

    policy: str = "fifo"          # fifo|sept|eect|rect|fc|baseline (sentinel)
    mode: str = "ours"            # ours | baseline
    assignment: str = "pull"      # cluster request-assignment model
    lb: str = "least_loaded"      # push balancer: least_loaded|home|round_robin
    arrival: str = "uniform"      # uniform|poisson|diurnal|mmpp|fairness|trace
    intensity: int = 30
    cores: int = 10               # per node
    nodes: int = 1
    autoscale: bool = False
    # autoscaler knobs (None = ClusterConfig defaults); first-class grid
    # axes so provision-delay x scale-up-threshold frontiers are sweepable
    provision_delay: float | None = None
    scale_up: float | None = None
    max_nodes: int | None = None
    fail_at: float | None = None  # inject: node 0 dies at this time
    # multi-failure schedule ((node, time), ...) -- see stragglers.
    # rolling_restart; overrides fail_at when set
    fail_spec: tuple[tuple[int, float], ...] | None = None
    # heterogeneity: per-node speed multipliers + degradation episodes
    node_speeds: tuple[float, ...] | None = None
    degrade: tuple[tuple[int, float, float, float], ...] | None = None
    # straggler hedging: the estimate-multiple deadline (None = off); the
    # non-axis knobs below fill out the HedgingSpec
    hedge_multiple: float | None = None
    hedge_floor_s: float = 0.5
    hedge_max_backups: int = 3
    hedge_mode: str = "steal"
    # request-lifecycle resilience (None on each axis = that policy off);
    # the non-axis knobs below fill out TimeoutSpec / RetryPolicy
    timeout_multiple: float | None = None   # deadline = mult x max(E[p], floor)
    retry_attempts: int | None = None       # total submissions allowed
    retry_mode: str = "backoff"             # backoff | immediate
    shed_threshold: float | None = None     # queued-E[p]/free-slot limit
    timeout_floor_s: float = 0.5
    timeout_absolute_s: float | None = None
    retry_base_s: float = 0.5
    retry_cap_s: float = 8.0
    retry_jitter: float = 0.5
    retry_on: tuple[str, ...] = ("timeout", "shed", "kill")
    seed: int = 0
    duration_s: float = 60.0
    workload_cores: int | None = None  # burst sized for this many cores
                                       # (default: cores * nodes)
    per_function: tuple[str, ...] = ()  # extra per-function metric columns
    trace_path: str | None = None       # for arrival == "trace"
    trace_repeat: int = 1               # tile the trace into longer streams
    trace_scale: float = 1.0            # scale per-minute trace rates
    warm: bool = True
    backend: str = "reference"          # simulation engine (BACKEND_CHOICES)
    # validation flag, orthogonal to the backend identity: a cross-checked
    # cell runs its own backend normally AND a counterpart backend, asserts
    # agreement, and reports xcheck_err -- so sampled cells keep the exact
    # key()/label() of their unsampled seed-group siblings
    cross_check: bool = False

    def key(self) -> tuple:
        """Identity of the cell up to its seed (the aggregation group)."""
        return tuple(getattr(self, f) for f in GRID_FIELDS)

    def label(self) -> str:
        parts = [f"{self.mode}-{self.policy}", f"c{self.cores}",
                 f"v{self.intensity}"]
        if self.nodes != 1:
            parts.append(f"n{self.nodes}")
        if self.assignment == "push" and self.lb != "least_loaded":
            parts.append(self.lb)
        if self.arrival != "uniform":
            parts.append(self.arrival)
        if self.autoscale:
            parts.append("autoscale")
            if self.provision_delay is not None:
                parts.append(f"pd{self.provision_delay:g}")
            if self.scale_up is not None:
                parts.append(f"su{self.scale_up:g}")
        if self.fail_at is not None:
            parts.append(f"fail{self.fail_at:g}")
        if self.fail_spec:
            parts.append(f"fails{len(self.fail_spec)}")
        if self.node_speeds or self.degrade:
            from .stragglers import NodeSpeedProfile
            prof = NodeSpeedProfile.from_any(self.node_speeds, self.degrade)
            if prof is not None:
                parts.append(f"deg{prof.max_slowdown():g}")
        if self.hedge_multiple is not None:
            parts.append(f"hedge{self.hedge_multiple:g}")
        if self.timeout_multiple is not None or self.timeout_absolute_s:
            parts.append(f"to{self.timeout_absolute_s:g}s"
                         if self.timeout_absolute_s
                         else f"to{self.timeout_multiple:g}x")
        if self.retry_attempts is not None:
            suffix = "i" if self.retry_mode == "immediate" else "b"
            parts.append(f"rt{self.retry_attempts}{suffix}")
        if self.shed_threshold is not None:
            parts.append(f"shed{self.shed_threshold:g}")
        if self.backend != "reference":
            parts.append(self.backend)
        return "_".join(parts)


@dataclass
class SweepSpec:
    """Declarative cartesian grid; ``cells()`` expands it."""

    policies: Sequence[str] = ("fifo",)
    modes: Sequence[str] = ("ours",)
    assignments: Sequence[str] = ("pull",)
    lbs: Sequence[str] = ("least_loaded",)   # push balancer axis
    arrivals: Sequence[str] = ("uniform",)
    intensities: Sequence[int] = (30,)
    cores: Sequence[int] = (10,)
    nodes: Sequence[int] = (1,)
    autoscale: Sequence[bool] = (False,)
    provision_delays: Sequence[float | None] = (None,)
    scale_ups: Sequence[float | None] = (None,)
    max_nodes: int | None = None         # autoscaler headroom (all cells)
    failures: Sequence[float | None] = (None,)
    # straggler / availability axes: multi-failure schedules, per-node speed
    # multipliers, degradation episodes, hedging deadline multiples
    fail_specs: Sequence[tuple | None] = (None,)
    node_speeds: Sequence[tuple | None] = (None,)
    degrades: Sequence[tuple | None] = (None,)
    hedge_multiples: Sequence[float | None] = (None,)
    hedge_floor_s: float = 0.5           # HedgingSpec knobs (all hedged cells)
    hedge_max_backups: int = 3
    hedge_mode: str = "steal"
    # resilience axes (None = that policy off for the cell) + shared knobs
    timeout_multiples: Sequence[float | None] = (None,)
    retry_attempts: Sequence[int | None] = (None,)
    retry_modes: Sequence[str] = ("backoff",)
    shed_thresholds: Sequence[float | None] = (None,)
    timeout_floor_s: float = 0.5
    timeout_absolute_s: float | None = None
    retry_base_s: float = 0.5
    retry_cap_s: float = 8.0
    retry_jitter: float = 0.5
    retry_on: tuple[str, ...] = ("timeout", "shed", "kill")
    seeds: int | Sequence[int] = 3
    base_seed: int = 0
    duration_s: float = 60.0
    workload_cores: int | None = None
    per_function: tuple[str, ...] = ()
    trace_path: str | None = None
    trace_repeat: int = 1
    trace_scale: float = 1.0
    warm: bool = True
    backends: Sequence[str] = ("reference",)
    # validate="cross-check" re-runs sampled vectorized-eligible cells on
    # BOTH backends and raises BackendMismatchError if any reported metric
    # drifts beyond CROSS_CHECK_RTOL; validate_stride samples every k-th
    # eligible cell identity (1 = all of them, whole seed-groups at a time)
    validate: str | None = None
    validate_stride: int = 1
    # prune the cartesian product (ragged grids, e.g. baseline only at n=4);
    # evaluated in the parent process, so any callable works
    cell_filter: Callable[[SweepCell], bool] | None = None

    def seed_list(self) -> list[int]:
        if isinstance(self.seeds, int):
            return [self.base_seed + s for s in range(self.seeds)]
        return [self.base_seed + s for s in self.seeds]

    def cells(self) -> list[SweepCell]:
        if self.validate not in (None, "cross-check"):
            raise ValueError(f"unknown validate mode {self.validate!r}; "
                             "expected None or 'cross-check'")
        validate = self.validate
        self._xcheck_skipped_degraded = 0
        backends: list[str] = []
        for b in self.backends:
            if b == "cross-check":      # axis sugar used by --backend flags
                validate = "cross-check"
                b = "reference"
            if b not in BACKEND_CHOICES:
                raise ValueError(f"unknown backend {b!r}; "
                                 f"available: {BACKEND_CHOICES}")
            if b not in backends:
                backends.append(b)
        out = []
        for (pol, mode, asg, lb, arr, inten, c, n, auto, pd, su, fail,
             fspec, spd, deg, hedge, tmult, ratt, rmode, shed, be,
             seed) in itertools.product(
                self.policies, self.modes, self.assignments, self.lbs,
                self.arrivals, self.intensities, self.cores,
                self.nodes, self.autoscale, self.provision_delays,
                self.scale_ups, self.failures, self.fail_specs,
                self.node_speeds, self.degrades, self.hedge_multiples,
                self.timeout_multiples, self.retry_attempts,
                self.retry_modes, self.shed_thresholds,
                backends, self.seed_list()):
            cell = SweepCell(
                policy=pol, mode=mode, assignment=asg,
                lb=lb if asg == "push" else "least_loaded",
                arrival=arr,
                intensity=inten, cores=c, nodes=n, autoscale=auto,
                provision_delay=pd if auto else None,
                scale_up=su if auto else None,
                max_nodes=self.max_nodes if auto else None,
                fail_at=fail,
                fail_spec=tuple(tuple(f) for f in fspec) if fspec else None,
                node_speeds=tuple(spd) if spd else None,
                degrade=tuple(tuple(e) for e in deg) if deg else None,
                hedge_multiple=hedge,
                hedge_floor_s=self.hedge_floor_s,
                hedge_max_backups=self.hedge_max_backups,
                hedge_mode=self.hedge_mode,
                timeout_multiple=tmult,
                # the mode axis only means something on retrying cells;
                # collapse it elsewhere (mirrors the lb/autoscale knobs)
                retry_attempts=ratt,
                retry_mode=rmode if ratt is not None else "backoff",
                shed_threshold=shed,
                timeout_floor_s=self.timeout_floor_s,
                timeout_absolute_s=(self.timeout_absolute_s
                                    if tmult is not None else None),
                retry_base_s=self.retry_base_s,
                retry_cap_s=self.retry_cap_s,
                retry_jitter=self.retry_jitter,
                retry_on=tuple(self.retry_on),
                seed=seed, duration_s=self.duration_s,
                workload_cores=self.workload_cores,
                per_function=self.per_function, trace_path=self.trace_path,
                trace_repeat=self.trace_repeat,
                trace_scale=self.trace_scale,
                warm=self.warm, backend=be,
            )
            if self.cell_filter is None or self.cell_filter(cell):
                out.append(cell)
        # autoscaler knobs only mean something on autoscale cells (and lb on
        # push cells); collapsing them to None elsewhere would otherwise
        # duplicate static cells
        if (len(self.provision_delays) > 1 or len(self.scale_ups) > 1
                or len(self.lbs) > 1 or len(self.retry_modes) > 1):
            seen: set = set()
            dedup = []
            for cell in out:
                key = (cell.key(), cell.seed)
                if key in seen:
                    continue
                seen.add(key)
                dedup.append(cell)
            out = dedup
        if validate == "cross-check":
            stride = max(1, self.validate_stride)
            # Cross-checking dual-runs a cell's own engine against a
            # reference counterpart (see run_cell).  Single-node cells
            # validate the exact vectorized/reference pair, so the sampled
            # axis value must resolve to one of those; scan-backend
            # *cluster* cells validate scan-vs-reference-Cluster at
            # CLUSTER_XCHECK_RTOL and are sampled off the scan axis itself.
            compat = [b for b in backends
                      if b in ("reference", "vectorized", "auto")]
            cluster_groups: dict[tuple, list[int]] = {}
            for i, cell in enumerate(out):
                if cell.backend == "scan" and _cluster_scan_capable(cell):
                    cluster_groups.setdefault(cell.key(), []).append(i)
            if not compat and not cluster_groups:
                raise ValueError(
                    "validate='cross-check' validates the vectorized backend;"
                    " include 'reference', 'vectorized' or 'auto' in backends"
                    " (the scan backend is covered by its own parity tests)")
            # Sample whole seed-groups (cell identities) of ONE backend axis
            # value.  cross_check is a flag, not a backend identity, so the
            # sampled cells keep exactly the key()/label() of their group.
            groups: dict[tuple, list[int]] = {}
            if compat:
                sample_be = "reference" if "reference" in compat else compat[0]
                for i, cell in enumerate(out):
                    if (_vectorized_eligible(cell)
                            and cell.backend == sample_be):
                        groups.setdefault(cell.key(), []).append(i)
            # A statically-capable scan group can still be outside the
            # kernel's regime for its actual workload (e.g. partial
            # warm-up): at run time such a cell degrades to the event loop
            # and the dual-run silently never happens, so sampling it would
            # read as validation coverage that never ran.  Skip those
            # groups here -- the next eligible group takes the sampling
            # slot -- and count the skipped cells (surfaced as
            # ``meta["xcheck_skipped_degraded"]`` by run_sweep).
            for gdict in (groups, cluster_groups):
                g = 0
                for key, idxs in gdict.items():
                    if g % stride == 0 and gdict is cluster_groups:
                        def _ok(c):
                            policy = ("fifo" if c.policy == "baseline"
                                      else c.policy)
                            return _cluster_scan_ok(c, make_workload(c),
                                                    policy)
                        if not all(_ok(out[i]) for i in idxs):
                            self._xcheck_skipped_degraded += len(idxs)
                            continue   # g unchanged: sample the next group
                    if g % stride == 0:
                        for i in idxs:
                            out[i] = replace(out[i], cross_check=True)
                    g += 1
        return out


# ---------------------------------------------------------------------------
# cell execution (must stay a picklable module-level function)
# ---------------------------------------------------------------------------
def make_workload(cell: SweepCell) -> list[Request]:
    """Deterministic workload for a cell; cells differing only in policy /
    mode / nodes share the same burst (paired common random numbers, exactly
    how the paper compares strategies)."""
    wcores = cell.workload_cores or cell.cores * cell.nodes
    if cell.arrival == "uniform":
        return generate_burst(cores=wcores, intensity=cell.intensity,
                              seed=cell.seed, duration_s=cell.duration_s)
    if cell.arrival == "fairness":
        return generate_fairness_burst(cores=wcores, intensity=cell.intensity,
                                       seed=cell.seed,
                                       duration_s=cell.duration_s)
    if cell.arrival == "trace":
        from .traces import generate_trace_requests
        if cell.trace_path is None:
            raise ValueError("arrival='trace' requires trace_path")
        return generate_trace_requests(cell.trace_path, seed=cell.seed,
                                       repeat=cell.trace_repeat,
                                       scale=cell.trace_scale)
    return generate_trace_burst(cores=wcores, intensity=cell.intensity,
                                seed=cell.seed, kind=cell.arrival,
                                duration_s=cell.duration_s)


def _cell_straggler(cell: SweepCell) -> bool:
    """Does the cell declare any heterogeneity / hedging / multi-failure?"""
    return (cell.fail_spec is not None or cell.node_speeds is not None
            or cell.degrade is not None or cell.hedge_multiple is not None)


def _cell_profile(cell: SweepCell):
    """The cell's :class:`~repro.core.stragglers.NodeSpeedProfile`, or
    ``None`` for a uniform fleet."""
    if cell.node_speeds is None and cell.degrade is None:
        return None
    from .stragglers import NodeSpeedProfile
    return NodeSpeedProfile.from_any(cell.node_speeds, cell.degrade)


def _cell_hedging(cell: SweepCell):
    """The cell's :class:`~repro.core.stragglers.HedgingSpec`, or ``None``
    when hedging is off."""
    if cell.hedge_multiple is None:
        return None
    from .stragglers import HedgingSpec
    return HedgingSpec(multiple=cell.hedge_multiple,
                       floor_s=cell.hedge_floor_s,
                       max_backups=cell.hedge_max_backups,
                       mode=cell.hedge_mode)


def _cell_resilience(cell: SweepCell):
    """The cell's :class:`~repro.core.resilience.ResilienceSpec`, or
    ``None`` when every lifecycle policy is off."""
    if (cell.timeout_multiple is None and cell.retry_attempts is None
            and cell.shed_threshold is None):
        return None
    from .resilience import (
        AdmissionPolicy,
        ResilienceSpec,
        RetryPolicy,
        TimeoutSpec,
    )
    timeout = None
    if cell.timeout_multiple is not None:
        timeout = TimeoutSpec(multiple=cell.timeout_multiple,
                              floor_s=cell.timeout_floor_s,
                              absolute_s=cell.timeout_absolute_s)
    retry = None
    if cell.retry_attempts is not None:
        retry = RetryPolicy(max_attempts=cell.retry_attempts,
                            mode=cell.retry_mode,
                            base_delay_s=cell.retry_base_s,
                            cap_delay_s=cell.retry_cap_s,
                            jitter=cell.retry_jitter,
                            retry_on=tuple(cell.retry_on))
    admission = (AdmissionPolicy(threshold_s=cell.shed_threshold)
                 if cell.shed_threshold is not None else None)
    return ResilienceSpec(timeout=timeout, retry=retry, admission=admission)


def _vectorized_eligible(cell: SweepCell) -> bool:
    """Can the cell run on the vectorized (ours-node) fast path?"""
    mode = "baseline" if (cell.mode == "baseline"
                          or cell.policy == "baseline") else "ours"
    return (mode == "ours" and cell.nodes <= 1 and not cell.autoscale
            and cell.fail_at is None and not _cell_straggler(cell)
            and _cell_resilience(cell) is None)


def _cell_dynamics(cell: SweepCell):
    """The cell's :class:`~repro.core.cluster.ClusterDynamics`, or ``None``
    for a fixed fleet.  Defaults resolve through the same
    ``_dynamics_from_kwargs`` path ``simulate_cluster`` uses, so both
    engines see identical autoscaler parameters."""
    if (not cell.autoscale and cell.fail_at is None
            and cell.fail_spec is None):
        return None
    from .cluster import _dynamics_from_kwargs
    kwargs: dict = {"autoscale": cell.autoscale}
    if cell.provision_delay is not None:
        kwargs["provision_delay_s"] = cell.provision_delay
    if cell.scale_up is not None:
        kwargs["scale_up_queue_per_slot"] = cell.scale_up
    if cell.max_nodes is not None:
        kwargs["max_nodes"] = cell.max_nodes
    return _dynamics_from_kwargs(kwargs, cell.fail_at,
                                 cell.fail_spec or ())


def _cluster_scan_capable(cell: SweepCell) -> bool:
    """Static (workload-independent) part of scan-cluster eligibility,
    answered by the scan backend's **capability matrix**: ours mode, a
    cluster-shaped scenario (>1 node, autoscaling, failure injection, or a
    straggler scenario), and ``supports(...)`` saying yes for the cell's
    policy / assignment / dynamics / hedging / heterogeneity combination.
    Both hedging modes and the cold (``warm=False``) regime are in-matrix;
    the workload-dependent half (warm-up / ample-memory checks) happens in
    :func:`run_cells_scan` / ``cluster_scan_eligible``."""
    mode = "baseline" if (cell.mode == "baseline"
                          or cell.policy == "baseline") else "ours"
    resil = _cell_resilience(cell)
    cluster_shaped = (cell.nodes > 1 or cell.autoscale
                      or cell.fail_at is not None or _cell_straggler(cell)
                      or resil is not None)
    if mode != "ours" or not cluster_shaped:
        return False
    dyn_cap = (cell.autoscale or cell.fail_at is not None
               or cell.fail_spec is not None)
    if (cell.hedge_multiple is not None and cell.hedge_mode == "duplicate"
            and dyn_cap and cell.assignment == "push"):
        return False                 # racing copies under churn: reference
    if cell.assignment == "push":
        if cell.lb not in ("least_loaded", "home"):
            return False             # round_robin push stays on the reference
        if dyn_cap and cell.lb != "least_loaded":
            return False             # dynamic home walk needs the event loop
    profile = _cell_profile(cell)
    from .simulator import get_backend
    return get_backend("scan").supports(
        mode=mode, policy=cell.policy, warm=cell.warm, nodes=cell.nodes,
        assignment=cell.assignment, autoscale=cell.autoscale,
        failures=cell.fail_at is not None or cell.fail_spec is not None,
        hedging=cell.hedge_multiple is not None,
        hetero=profile is not None,
        timeouts=resil is not None and resil.timeout is not None,
        retries=resil is not None and resil.retry is not None,
        shedding=resil is not None and resil.admission is not None)


def _stream_routable(cell: SweepCell, reqs, dynamics, profile, hedging,
                     resilience) -> bool:
    """Chunked-cell routing predicate: a scan-eligible cell whose request
    stream is longer than :data:`STREAM_CELL_THRESHOLD` replays through
    the streaming carry-handoff path (bounded device memory) when the
    stream engine covers its feature combination."""
    if STREAM_CELL_THRESHOLD <= 0 or len(reqs) <= STREAM_CELL_THRESHOLD:
        return False
    from .streamscan import stream_supported
    return stream_supported(
        policy=cell.policy, assignment=cell.assignment, lb=cell.lb,
        warm=cell.warm, dynamics=dynamics, profile=profile,
        hedging=hedging, resilience=resilience)


def _run_stream_cell(cell: SweepCell, reqs, policy, dynamics, profile,
                     hedging, resilience):
    """Run one cluster cell through the streaming chunked-scan engine and
    adapt its result to the SimResult attribute surface the metrics code
    reads (requests are written back in place)."""
    from types import SimpleNamespace

    from .streamscan import simulate_cluster_stream, stream_from_requests
    stream, order = stream_from_requests(reqs)
    sr = simulate_cluster_stream(
        stream, nodes=cell.nodes, cores_per_node=cell.cores, policy=policy,
        assignment=cell.assignment, lb=cell.lb, warm=cell.warm,
        dynamics=dynamics, profile=profile, hedging=hedging,
        resilience=resilience)
    sr.write_back(reqs, order)
    c = sr.counters
    return SimpleNamespace(
        requests=reqs, cold_starts=c["cold_starts"],
        failures=c["failures"], backups_issued=c["backups_issued"],
        nodes_used=sr.nodes_used, steals_won=c["steals_won"],
        timed_out=c["timed_out"], shed=c["shed"],
        retries_issued=c["retries_issued"], wasted_work=c["wasted_work"])


def _scan_batchable(cell: SweepCell) -> bool:
    """Should run_sweep route this cell into a bucketed scan batch?
    Cross-checked cells stay on the per-cell path (they dual-run)."""
    if cell.backend != "scan" or cell.cross_check:
        return False
    return _vectorized_eligible(cell) or _cluster_scan_capable(cell)


def _resolve_backend(cell: SweepCell, reqs, mode: str, policy: str) -> str:
    """Map a backend *selector* to a concrete backend for this cell.

    Explicit fast selectors degrade gracefully: a grid that mixes baseline
    (reference-only) cells with ours cells can still be swept with
    ``backends=("vectorized",)`` -- the stock-system cells simply stay on
    the event loop.  ``simulate_single_node`` itself stays strict."""
    want = cell.backend
    if want not in BACKEND_CHOICES:
        raise ValueError(f"unknown backend {want!r}; "
                         f"available: {BACKEND_CHOICES}")
    if want == "reference":
        return "reference"
    if not _vectorized_eligible(cell):
        return "reference"
    if want == "scan":
        from .fastpath import scan_eligible
        try:
            import jax  # noqa: F401
        except ImportError:
            return "vectorized"
        if scan_eligible(reqs, cell.cores, policy, mode=mode,
                         warm=cell.warm):
            return "scan"
        return "vectorized"
    return "vectorized"  # "auto" | "vectorized"


def _cell_metrics(cell: SweepCell, done, cold, failures, backups,
                  nodes_used, steals: int = 0,
                  res_counts: tuple | None = None) -> dict[str, float]:
    resil = _cell_resilience(cell)
    if resil is not None and not any(r.c is not None for r in done):
        # a storm cell can shed/time out *every* call; summarize() would
        # raise, but a fully-failed cell is a legitimate data point on the
        # overload frontier -- report zeros plus the failure counters
        from .metrics import PERCENTILES, resilience_row
        metrics = {
            "R_avg": 0.0, "S_avg": 0.0, "max_c": 0.0, "cold": float(cold),
            "n": 0.0, "failures": float(failures),
            "backups": float(backups), "steals": float(steals),
            "nodes_used": float(nodes_used),
        }
        for p in PERCENTILES:
            metrics[f"R_p{p}"] = 0.0
            metrics[f"S_p{p}"] = 0.0
        to, sh, rt, ww = res_counts or (0, 0, 0, 0.0)
        metrics.update(resilience_row(done, timed_out=to, shed=sh,
                                      retries_issued=rt, wasted_work=ww))
        return metrics
    s = summarize(done, per_function=bool(cell.per_function))
    metrics: dict[str, float] = {
        "R_avg": s.response_avg, "S_avg": s.stretch_avg,
        "max_c": s.max_completion, "cold": float(cold), "n": float(s.n),
        "failures": float(failures), "backups": float(backups),
        "steals": float(steals), "nodes_used": float(nodes_used),
    }
    for p, v in s.response_pct.items():
        metrics[f"R_p{p}"] = v
    for p, v in s.stretch_pct.items():
        metrics[f"S_p{p}"] = v
    for fn in cell.per_function:
        sub = s.per_function.get(fn)
        if sub is not None:
            metrics[f"R_avg:{fn}"] = sub.response_avg
            metrics[f"S_avg:{fn}"] = sub.stretch_avg
    if resil is not None:
        from .metrics import resilience_row
        to, sh, rt, ww = res_counts or (0, 0, 0, 0.0)
        metrics.update(resilience_row(done, timed_out=to, shed=sh,
                                      retries_issued=rt, wasted_work=ww))
    return metrics


def _mismatch(cell: SweepCell, rtol: float,
              msg: str) -> BackendMismatchError:
    """Build a BackendMismatchError with first-divergence triage attached:
    rerun the cell traced on both engines and name the first divergent
    lifecycle event.  Triage is best-effort — it must never mask the
    original mismatch — so any triage failure just drops the report."""
    report = None
    try:
        report = triage_cell(cell, rtol=rtol)
    except Exception:   # noqa: BLE001 -- diagnostic layer only
        report = None
    if report is not None:
        msg = f"{msg}\n  {report}"
    err = BackendMismatchError(msg)
    err.report = report
    return err


def _cross_check(cell: SweepCell, ref: dict[str, float],
                 fast: dict[str, float], backend: str,
                 rtol: float = CROSS_CHECK_RTOL) -> float:
    """Max relative disagreement over CROSS_CHECK_KEYS; raises on breach.
    Count-like metrics (CROSS_CHECK_EXACT: failures / backups / steals)
    must match *bit-identically* -- any difference is a hard failure.
    A raised :class:`BackendMismatchError` carries the first-divergence
    triage report (``err.report``) when one could be computed."""
    worst = 0.0
    for k in CROSS_CHECK_KEYS:
        a, b = ref.get(k), fast.get(k)
        if a is None or b is None:
            continue
        err = abs(a - b) / max(abs(a), abs(b), 1e-9)
        worst = max(worst, err)
        if err > rtol:
            raise _mismatch(
                cell, rtol,
                f"backend {backend!r} disagrees with reference on "
                f"{cell.label()} seed={cell.seed}: {k} {b!r} vs {a!r} "
                f"(rel err {err:.2e} > {rtol})")
    for k in CROSS_CHECK_EXACT:
        a, b = ref.get(k), fast.get(k)
        if a is None or b is None:
            continue
        if a != b:
            raise _mismatch(
                cell, rtol,
                f"backend {backend!r} miscounts {k} on {cell.label()} "
                f"seed={cell.seed}: {b!r} vs reference {a!r} "
                "(count metrics must match exactly)")
    return worst


def _cluster_scan_ok(cell: SweepCell, reqs: list[Request],
                     policy: str) -> bool:
    """Workload-dependent half of scan-cluster eligibility (+ jax)."""
    try:
        import jax  # noqa: F401
    except ImportError:
        return False
    from .fastpath import cluster_scan_eligible
    return cluster_scan_eligible(reqs, cell.nodes, cell.cores, policy,
                                 assignment=cell.assignment, lb=cell.lb,
                                 warm=cell.warm,
                                 dynamics=_cell_dynamics(cell),
                                 profile=_cell_profile(cell),
                                 hedging=_cell_hedging(cell),
                                 resilience=_cell_resilience(cell))


def _cluster_kwargs(cell: SweepCell, policy: str) -> dict:
    """The ``simulate_cluster`` keyword set a cell expands to — shared by
    :func:`run_cell` and :func:`triage_cell` so a triage rerun is guaranteed
    to reproduce exactly the scenario the cross-check ran."""
    kw = dict(nodes=cell.nodes, cores_per_node=cell.cores,
              policy=policy, assignment=cell.assignment,
              lb=cell.lb,
              warm=cell.warm, fail_at=cell.fail_at,
              fail_spec=cell.fail_spec or (),
              node_speeds=cell.node_speeds,
              degrade=cell.degrade or (),
              hedging=_cell_hedging(cell),
              resilience=_cell_resilience(cell),
              autoscale=cell.autoscale)
    if cell.provision_delay is not None:
        kw["provision_delay_s"] = cell.provision_delay
    if cell.scale_up is not None:
        kw["scale_up_queue_per_slot"] = cell.scale_up
    if cell.max_nodes is not None:
        kw["max_nodes"] = cell.max_nodes
    return kw


def triage_cell(cell: SweepCell, rtol: float | None = None):
    """First-divergence triage: rerun ``cell`` on the reference engine and
    its fast counterpart, reconstruct both canonical lifecycle streams
    (:func:`repro.core.flight.trace_from_result`) and return the
    :class:`~repro.core.flight.DivergenceReport` naming the first divergent
    event — or ``None`` when the streams agree (or the cell has no fast
    counterpart to triage against).  Called automatically when a
    ``validate="cross-check"`` comparison fails, so the raised
    ``BackendMismatchError`` names the event, not just the metric."""
    from .cluster import simulate_cluster
    from .flight import first_divergence, trace_from_result
    from .simulator import simulate_single_node

    mode = "baseline" if (cell.mode == "baseline"
                          or cell.policy == "baseline") else "ours"
    if mode == "baseline":
        return None                    # stock baseline has no fast engine
    policy = "fifo" if cell.policy == "baseline" else cell.policy
    a, b = make_workload(cell), make_workload(cell)
    remap = {qb.id: qa.id for qa, qb in zip(a, b)}
    single = (cell.nodes <= 1 and not cell.autoscale and cell.fail_at is None
              and not _cell_straggler(cell)
              and _cell_resilience(cell) is None)
    try:
        if single:
            if not _vectorized_eligible(cell):
                return None
            fast_name = (cell.backend if cell.backend in ("vectorized",
                                                          "scan")
                         else "vectorized")
            ref = simulate_single_node(a, cores=cell.cores, policy=policy,
                                       mode=mode, warm=cell.warm,
                                       backend="reference")
            fast = simulate_single_node(b, cores=cell.cores, policy=policy,
                                        mode=mode, warm=cell.warm,
                                        backend=fast_name)
            rtol = CROSS_CHECK_RTOL if rtol is None else rtol
        else:
            if not (_cluster_scan_capable(cell)
                    and _cluster_scan_ok(cell, a, policy)):
                return None
            from .fastpath import simulate_cluster_cells_scan
            ref = simulate_cluster(a, **_cluster_kwargs(cell, policy))
            fast = simulate_cluster_cells_scan(
                [(b, cell.nodes, cell.cores, policy, cell.assignment,
                  cell.lb, _cell_dynamics(cell), _cell_profile(cell),
                  _cell_hedging(cell), cell.warm,
                  _cell_resilience(cell))])[0]
            rtol = CLUSTER_XCHECK_RTOL if rtol is None else rtol
    except (ValueError, ImportError):
        return None                    # no fast engine for this scenario
    # the scan kernel re-routes kill-lost calls but does not write back a
    # per-request resubmission count outside hedge/resilience cells
    kills = cell.fail_at is not None or bool(cell.fail_spec)
    cmp_att = not (kills and _cell_hedging(cell) is None
                   and _cell_resilience(cell) is None)
    return first_divergence(
        trace_from_result(ref, requests=a),
        trace_from_result(fast, requests=b).relabel(remap),
        rtol=rtol, compare_attempts=cmp_att)


def run_cell(cell: SweepCell) -> dict[str, float]:
    """Run one scenario end-to-end; pure function of the cell (bit-identical
    metrics for identical cells, in any process)."""
    from .cluster import simulate_baseline_cluster, simulate_cluster
    from .simulator import simulate_single_node

    reqs = make_workload(cell)
    mode = "baseline" if (cell.mode == "baseline"
                          or cell.policy == "baseline") else "ours"
    policy = "fifo" if cell.policy == "baseline" else cell.policy
    failures = backups = steals = 0
    nodes_used = cell.nodes
    cold = 0

    if (cell.nodes <= 1 and not cell.autoscale and cell.fail_at is None
            and not _cell_straggler(cell)
            and _cell_resilience(cell) is None):
        backend = _resolve_backend(cell, reqs, mode, policy)
        res = simulate_single_node(reqs, cores=cell.cores, policy=policy,
                                   mode=mode, warm=cell.warm,
                                   backend=backend)
        if cell.cross_check and _vectorized_eligible(cell):
            # dual-run the exact counterpart on the same burst (fresh
            # objects) and assert metric agreement within the 1% budget
            other = "vectorized" if backend == "reference" else "reference"
            metrics = _cell_metrics(cell, res.requests, res.cold_starts,
                                    0, 0, nodes_used)
            other_res = simulate_single_node(
                make_workload(cell), cores=cell.cores, policy=policy,
                mode=mode, warm=cell.warm, backend=other)
            other_m = _cell_metrics(cell, other_res.requests,
                                    other_res.cold_starts, 0, 0, nodes_used)
            metrics["xcheck_err"] = _cross_check(cell, metrics, other_m,
                                                 other)
            return metrics
        metrics = _cell_metrics(cell, res.requests, res.cold_starts,
                                0, 0, nodes_used)
        if cell.backend == "scan" and backend != "scan":
            metrics["degraded"] = 1.0
        return metrics
    elif mode == "baseline":
        if (cell.fail_at is not None or _cell_straggler(cell)
                or _cell_resilience(cell) is not None):
            raise ValueError(
                "failure injection, straggler and resilience axes "
                "(fail_spec, node_speeds, degrade, hedge_multiple, "
                "timeout_multiple, retry_attempts, shed_threshold) are "
                "unsupported for the stock baseline cluster (no "
                "retry/hedging/speed semantics) -- silently dropping them "
                "would mislabel healthy runs as degraded scenarios")
        res = simulate_baseline_cluster(reqs, nodes=cell.nodes,
                                        cores_per_node=cell.cores,
                                        warm=cell.warm)
        done, cold = res.requests, res.cold_starts
        if cell.backend == "scan":     # stock system never runs on scan
            metrics = _cell_metrics(cell, done, cold, 0, 0, nodes_used)
            metrics["degraded"] = 1.0
            return metrics
    else:
        # scan-backend cluster cells run the multi-node kernel (per-cell
        # here; run_sweep batches whole buckets instead where it can);
        # cross-checked cells keep their own engine as primary and dual-run
        # the counterpart, asserting CLUSTER_XCHECK_RTOL agreement
        dynamics = _cell_dynamics(cell)
        profile = _cell_profile(cell)
        hedging = _cell_hedging(cell)
        resilience = _cell_resilience(cell)
        scan_ok = (cell.backend == "scan" or cell.cross_check) \
            and _cluster_scan_capable(cell) \
            and _cluster_scan_ok(cell, reqs, policy)
        ref_kw = _cluster_kwargs(cell, policy)
        def _counts(r):
            return (r.timed_out, r.shed, r.retries_issued, r.wasted_work)

        if cell.backend == "scan" and scan_ok:
            from .fastpath import simulate_cluster_cells_scan
            if _stream_routable(cell, reqs, dynamics, profile, hedging,
                                resilience):
                # chunked-cell routing: oversized streams replay through
                # the carry-handoff path -- O(chunk) device memory,
                # bit-identical counters/clocks to the single-shot kernel
                res = _run_stream_cell(cell, reqs, policy, dynamics,
                                       profile, hedging, resilience)
            else:
                res = simulate_cluster_cells_scan(
                    [(reqs, cell.nodes, cell.cores, policy,
                      cell.assignment, cell.lb, dynamics, profile, hedging,
                      cell.warm, resilience)])[0]
            metrics = _cell_metrics(cell, res.requests, res.cold_starts,
                                    res.failures, res.backups_issued,
                                    res.nodes_used, steals=res.steals_won,
                                    res_counts=_counts(res))
            if cell.cross_check:
                other = simulate_cluster(make_workload(cell), **ref_kw)
                other_m = _cell_metrics(cell, other.requests,
                                        other.cold_starts, other.failures,
                                        other.backups_issued,
                                        other.nodes_used,
                                        steals=other.steals_won,
                                        res_counts=_counts(other))
                metrics["xcheck_err"] = _cross_check(
                    cell, other_m, metrics, "scan",
                    rtol=CLUSTER_XCHECK_RTOL)
            return metrics
        res = simulate_cluster(reqs, **ref_kw)
        done, cold = res.requests, res.cold_starts
        failures, backups = res.failures, res.backups_issued
        steals, nodes_used = res.steals_won, res.nodes_used
        res_counts = _counts(res)
        if cell.cross_check and scan_ok:
            from .fastpath import simulate_cluster_cells_scan
            metrics = _cell_metrics(cell, done, cold, failures, backups,
                                    nodes_used, steals=steals,
                                    res_counts=res_counts)
            other = simulate_cluster_cells_scan(
                [(make_workload(cell), cell.nodes, cell.cores, policy,
                  cell.assignment, cell.lb, dynamics, profile,
                  hedging, cell.warm, resilience)])[0]
            other_m = _cell_metrics(cell, other.requests, other.cold_starts,
                                    other.failures, other.backups_issued,
                                    other.nodes_used,
                                    steals=other.steals_won,
                                    res_counts=_counts(other))
            metrics["xcheck_err"] = _cross_check(
                cell, metrics, other_m, "scan", rtol=CLUSTER_XCHECK_RTOL)
            return metrics
        if cell.backend == "scan":
            # a scan-requested cluster cell outside the kernel's regime ran
            # on the reference event loop: count it (satellite contract)
            metrics = _cell_metrics(cell, done, cold, failures, backups,
                                    nodes_used, steals=steals,
                                    res_counts=res_counts)
            metrics["degraded"] = 1.0
            return metrics
        return _cell_metrics(cell, done, cold, failures, backups,
                             nodes_used, steals=steals,
                             res_counts=res_counts)

    return _cell_metrics(cell, done, cold, failures, backups, nodes_used,
                         steals=steals)


def _run_guard(fn: Callable[[SweepCell], dict],
               cell: SweepCell) -> dict[str, float]:
    """Fault-isolating cell runner for :func:`run_sweep` (module-level so
    pool workers can unpickle it): run the cell, retry once on any
    exception (transient faults -- a worker hiccup, an engine cache race),
    and on the second failure return an error marker instead of raising,
    so one bad cell cannot sink a 10k-cell sweep."""
    try:
        return fn(cell)
    except Exception:
        pass
    try:
        return fn(cell)
    except Exception as exc:  # noqa: BLE001 -- marker row, surfaced in meta
        return {"__error__": f"{type(exc).__name__}: {exc}"}


def _workload_key(cell: SweepCell) -> tuple:
    """Identity of a cell's deterministic workload (everything
    :func:`make_workload` reads): cells agreeing on this key generate
    bit-identical request lists."""
    wcores = cell.workload_cores or cell.cores * cell.nodes
    return (cell.arrival, cell.intensity, cell.seed, cell.duration_s,
            wcores, cell.trace_path, cell.trace_repeat, cell.trace_scale)


def _metrics_from_scan(cell: SweepCell, mo) -> dict[str, float]:
    """Metrics row from a metrics-only scan result
    (:class:`repro.core.fastpath.ScanMetrics`), matching
    :func:`_cell_metrics` bit-for-bit: the arrays are request-ordered, so
    every mean sums in the same order the write-back path does."""
    s = summarize_arrays(mo.resp, mo.stretch, mo.max_c)
    metrics: dict[str, float] = {
        "R_avg": s.response_avg, "S_avg": s.stretch_avg,
        "max_c": s.max_completion, "cold": float(mo.cold_starts),
        "n": float(s.n), "failures": float(mo.failures),
        "backups": float(mo.backups), "steals": float(mo.steals),
        "nodes_used": float(mo.nodes_used),
    }
    for p, v in s.response_pct.items():
        metrics[f"R_p{p}"] = v
    for p, v in s.stretch_pct.items():
        metrics[f"S_p{p}"] = v
    for fn in cell.per_function:
        if fn not in mo.fns:
            continue
        m = mo.fnids == mo.fns.index(fn)
        if m.any():
            metrics[f"R_avg:{fn}"] = float(mo.resp[m].mean())
            metrics[f"S_avg:{fn}"] = float(mo.stretch[m].mean())
    return metrics


def _run_cells_scan_partial(
        cells: Sequence[SweepCell],
        metrics_only: bool = False) -> list[dict[str, float] | None]:
    """Bucketed scan dispatch over whichever cells are eligible; returns
    ``None`` in the slots of ineligible cells (the caller decides how to run
    those -- :func:`run_sweep` sends them to its pool).

    Workloads are only generated after the static eligibility checks pass,
    and eligibility is checked exactly once per cell (the batch calls run
    with ``validate=False``).  ``metrics_only=True`` additionally **shares**
    one request list across every cell with the same :func:`_workload_key`
    (safe because nothing is written back), which removes the dominant
    per-cell cost of large grids -- a 5-policy x fleet grid generates each
    burst once instead of once per cell."""
    from .fastpath import (
        scan_eligible,
        simulate_cells_scan,
        simulate_cluster_cells_scan,
    )
    try:
        import jax  # noqa: F401
    except ImportError:
        return [None] * len(cells)

    workloads: dict[tuple, list[Request]] = {}

    def _cell_reqs(cell: SweepCell) -> list[Request]:
        if not metrics_only:     # write-back mutates: never share
            return make_workload(cell)
        key = _workload_key(cell)
        reqs = workloads.get(key)
        if reqs is None:
            reqs = workloads[key] = make_workload(cell)
        return reqs

    metrics: list[dict[str, float] | None] = [None] * len(cells)
    singles: list[tuple[int, SweepCell, list[Request]]] = []
    clusters: list[tuple[int, SweepCell, list[Request]]] = []
    res_clusters: list[tuple[int, SweepCell, list[Request]]] = []
    for pos, cell in enumerate(cells):
        mode = "baseline" if (cell.mode == "baseline"
                              or cell.policy == "baseline") else "ours"
        policy = "fifo" if cell.policy == "baseline" else cell.policy
        if _cluster_scan_capable(cell):
            if _cell_resilience(cell) is not None:
                # resilience cells always write back (failed-request
                # nulling): give each its own burst even in metrics_only
                # mode, and batch them separately below
                reqs = make_workload(cell)
                if _cluster_scan_ok(cell, reqs, policy):
                    res_clusters.append((pos, cell, reqs))
                continue
            reqs = _cell_reqs(cell)
            if _cluster_scan_ok(cell, reqs, policy):
                clusters.append((pos, cell, reqs))
        elif _vectorized_eligible(cell) and mode == "ours":
            reqs = _cell_reqs(cell)
            if scan_eligible(reqs, cell.cores, policy, warm=cell.warm):
                singles.append((pos, cell, reqs))

    def _dispatch(batch, runner):
        """Run ``runner`` over the whole batch; when a *value-dependent*
        mid-dispatch rejection surfaces (an eligibility race the static
        checks could not see), re-run cell by cell so one bad cell
        degrades alone (``None`` -> reference fallback, counted in
        ``degraded``) instead of sinking its entire shape bucket."""
        try:
            return runner(batch)
        except Exception:
            out = []
            for item in batch:
                try:
                    out.append(runner([item])[0])
                except Exception:
                    out.append(None)
            return out

    if singles:
        results = _dispatch(
            [(reqs, cell.cores, cell.policy, cell.warm)
             for _, cell, reqs in singles],
            lambda b: simulate_cells_scan(b, validate=False,
                                          metrics_only=metrics_only))
        for (pos, cell, _), res in zip(singles, results):
            if res is None:
                continue
            if metrics_only:
                metrics[pos] = _metrics_from_scan(cell, res)
            else:
                metrics[pos] = _cell_metrics(cell, res.requests,
                                             res.cold_starts, 0, 0,
                                             cell.nodes)
    if clusters:
        results = _dispatch(
            [(reqs, cell.nodes, cell.cores, cell.policy, cell.assignment,
              cell.lb, _cell_dynamics(cell), _cell_profile(cell),
              _cell_hedging(cell), cell.warm)
             for _, cell, reqs in clusters],
            lambda b: simulate_cluster_cells_scan(
                b, validate=False, metrics_only=metrics_only))
        for (pos, cell, _), res in zip(clusters, results):
            if res is None:
                continue
            if metrics_only:
                metrics[pos] = _metrics_from_scan(cell, res)
            else:
                metrics[pos] = _cell_metrics(cell, res.requests,
                                             res.cold_starts, res.failures,
                                             res.backups_issued,
                                             res.nodes_used,
                                             steals=res.steals_won)
    if res_clusters:
        results = _dispatch(
            [(reqs, cell.nodes, cell.cores, cell.policy, cell.assignment,
              cell.lb, _cell_dynamics(cell), _cell_profile(cell),
              _cell_hedging(cell), cell.warm, _cell_resilience(cell))
             for _, cell, reqs in res_clusters],
            lambda b: simulate_cluster_cells_scan(b, validate=False))
        for (pos, cell, _), res in zip(res_clusters, results):
            if res is None:
                continue
            metrics[pos] = _cell_metrics(
                cell, res.requests, res.cold_starts, res.failures,
                res.backups_issued, res.nodes_used, steals=res.steals_won,
                res_counts=(res.timed_out, res.shed, res.retries_issued,
                            res.wasted_work))
    return metrics


def run_cells_scan(cells: Sequence[SweepCell],
                   strict: bool = True,
                   metrics_only: bool = False) -> list[dict[str, float]]:
    """Run a whole list of cells through the bucketed ``jax.lax.scan`` path
    (padded tensors, cells vmapped, one XLA dispatch per shape bucket) and
    return per-cell metrics in order.

    Handles single-node *and* cluster cells: single-node cells must satisfy
    :func:`repro.core.fastpath.scan_eligible`, cluster cells
    :func:`repro.core.fastpath.cluster_scan_eligible` -- both including
    autoscale / failure-injection dynamics.  With ``strict=True`` (default)
    an ineligible cell raises ``ValueError``; with ``strict=False``
    ineligible cells run through :func:`run_cell` instead and are *counted*:
    their metrics carry ``degraded=1.0`` (surfaced as a ``degraded`` column
    in ``SweepResult`` aggregates) rather than silently folding into
    scan-path timings.  Unlike :func:`run_sweep` this executes in-process:
    the batch IS the parallelism.

    ``metrics_only=True`` is the interactive-sweep mode: request objects are
    never written back, cells with identical workload parameters share one
    generated burst, and the returned rows are built from request-ordered
    arrays -- bit-identical to the default mode's rows."""
    metrics = _run_cells_scan_partial(cells, metrics_only=metrics_only)
    for pos, m in enumerate(metrics):
        if m is None:
            if strict:
                raise ValueError(
                    f"cell {cells[pos].label()} is not scan-eligible")
            fallback = dict(run_cell(cells[pos]))
            fallback["degraded"] = 1.0
            metrics[pos] = fallback
    return metrics  # type: ignore[return-value]


# ---------------------------------------------------------------------------
# results
# ---------------------------------------------------------------------------
@dataclass
class CellResult:
    cell: SweepCell
    metrics: dict[str, float]


@dataclass
class SweepResult:
    results: list[CellResult]
    wall_s: float = 0.0
    workers: int = 1
    meta: dict = field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.results)

    # -- aggregation --------------------------------------------------------
    def aggregate(self) -> list[dict]:
        """Mean metrics per cell identity (across seeds), in first-seen
        order.  Adds ``seeds`` (count) and ``R_avg_std``."""
        groups: dict[tuple, list[CellResult]] = {}
        for cr in self.results:
            groups.setdefault(cr.cell.key(), []).append(cr)
        rows = []
        for key, crs in groups.items():
            row: dict = dict(zip(GRID_FIELDS, key))
            row["label"] = crs[0].cell.label()
            row["seeds"] = len(crs)
            # "degraded" is always a column -- a fully-eligible sweep reads
            # degraded=0.0 rather than omitting it, so downstream consumers
            # can assert on it unconditionally
            metric_keys = sorted({k for cr in crs
                                  for k in cr.metrics}
                                 | {"degraded", "failed"})
            for mk in metric_keys:
                if mk in ("degraded", "failed"):
                    # fallback / error *fraction*: cells that ran on their
                    # requested engine (or succeeded) simply lack the key
                    # and count as 0, so a group where 1 of 5 seeds
                    # degraded reads 0.2, not 1.0
                    vals = [cr.metrics.get(mk, 0.0) for cr in crs]
                else:
                    vals = [cr.metrics[mk] for cr in crs if mk in cr.metrics]
                # a group whose every seed failed has no real metric values
                # at all: report NaN rather than crashing the aggregation
                row[mk] = float(np.mean(vals)) if vals else float("nan")
            r_avgs = [cr.metrics["R_avg"] for cr in crs
                      if "R_avg" in cr.metrics]
            row["R_avg_std"] = (float(np.std(r_avgs)) if r_avgs
                                else float("nan"))
            rows.append(row)
        return rows

    def find(self, **conds) -> dict:
        """The single aggregated row matching ``conds`` (grid-field values)."""
        hits = [r for r in self.aggregate()
                if all(r.get(k) == v for k, v in conds.items())]
        if len(hits) != 1:
            raise KeyError(f"{conds} matched {len(hits)} aggregated rows")
        return hits[0]

    # -- emission -----------------------------------------------------------
    def rows(self, prefix: str = "sweep") -> list[dict]:
        """``benchmarks.common.emit``-compatible rows (one per aggregate)."""
        out = []
        for r in self.aggregate():
            derived = (f"R_avg={r['R_avg']:.2f};S_avg={r['S_avg']:.1f};"
                       f"max_c={r['max_c']:.1f};seeds={r['seeds']}")
            out.append({"name": f"{prefix}/{r['label']}",
                        "us_per_call": r["R_avg"] * 1e6,
                        "derived": derived})
        return out

    def to_json(self, path) -> None:
        payload = {
            "wall_s": self.wall_s, "workers": self.workers,
            "cells": len(self.results), "meta": self.meta,
            "results": [
                {"cell": {f.name: getattr(cr.cell, f.name)
                          for f in fields(SweepCell)},
                 "metrics": cr.metrics}
                for cr in self.results
            ],
            "aggregate": self.aggregate(),
        }
        with open(path, "w") as fh:
            json.dump(payload, fh, indent=1, default=str)

    def to_csv(self, path) -> None:
        rows = self.aggregate()
        # union of columns in first-seen order: ragged grids carry metrics
        # not every group has (xcheck_err, per-function columns, ...)
        cols: list[str] = []
        for r in rows:
            for k in r:
                if k not in cols:
                    cols.append(k)
        with open(path, "w", newline="") as fh:
            w = csv.DictWriter(fh, fieldnames=cols)
            w.writeheader()
            w.writerows(rows)


# ---------------------------------------------------------------------------
# runner
class ProgressReporter:
    """Default ``run_sweep`` progress callback: a log line every ``every``
    cells (and at completion) with done/total, cells/s and ETA — so a
    100k-cell mega sweep is no longer silent for minutes.  ``every=None``
    auto-picks ~1% of the total (at least 1); ``min_interval_s`` rate-limits
    output when cells are fast.  Writes to ``stream`` (stderr by default;
    any ``write()``-able object works, tests pass ``io.StringIO``)."""

    def __init__(self, every: int | None = None, min_interval_s: float = 5.0,
                 stream=None, clock: Callable[[], float] = time.monotonic):
        self.every = every
        self.min_interval_s = min_interval_s
        self.stream = stream
        self._clock = clock
        self._t0: float | None = None
        self._last_emit = -math.inf
        self.lines = 0

    def __call__(self, done: int, total: int) -> None:
        now = self._clock()
        if self._t0 is None:
            self._t0 = now
        every = self.every or max(1, total // 100)
        if done < total and (done % every != 0
                             or now - self._last_emit < self.min_interval_s):
            return
        self._last_emit = now
        elapsed = max(now - self._t0, 1e-9)
        rate = done / elapsed
        eta = (total - done) / rate if rate > 0 else float("inf")
        line = (f"[sweep] {done}/{total} cells "
                f"({100.0 * done / total:.0f}%) "
                f"{rate:.1f} cells/s eta {eta:.0f}s")
        self.lines += 1
        print(line, file=self.stream or sys.stderr, flush=True)


# ---------------------------------------------------------------------------
def run_sweep(
    spec: SweepSpec,
    workers: int | None = None,
    runner: Callable[[SweepCell], dict] | None = None,
    progress: "Callable[[int, int], None] | bool | None" = None,
    executor: str | None = None,
) -> SweepResult:
    """Execute every cell of ``spec``.

    ``workers=1`` runs inline (no pool); ``workers=N`` fans cells out over a
    process pool.  Results are identical either way: a cell's metrics depend
    only on the cell itself.  ``runner`` overrides the per-cell function
    (must be picklable for N > 1, e.g. a module-level function).

    ``executor`` pins the pool start method: ``"fork"`` (fastest),
    ``"spawn"`` (required for XLA-using runners -- engines do not survive a
    fork, so benchmarks like ``engine_bench`` pass ``executor="spawn"`` to
    run their cells concurrently), or ``None`` to pick automatically.

    Cells on the ``"scan"`` backend are *not* sent to the pool: they are
    partitioned into padded shape buckets and dispatched as batched
    ``jax.lax.scan`` calls in-process (see :func:`run_cells_scan`) -- for a
    10k-cell cluster grid that is a handful of XLA dispatches after one
    compile per bucket, far faster than any per-cell pool.

    ``progress`` is called as ``progress(done, total)`` after every
    completed cell (and after each batched-scan bucket); pass ``True`` for
    the default :class:`ProgressReporter` log line (done/total, cells/s,
    ETA)."""
    if progress is True:
        progress = ProgressReporter()
    elif progress is False:
        progress = None
    cells = spec.cells()
    if not cells:
        raise ValueError("SweepSpec expands to zero cells")
    if executor not in (None, "fork", "spawn"):
        raise ValueError(f"unknown executor {executor!r}; "
                         "expected None, 'fork' or 'spawn'")
    fn = runner or run_cell
    if workers is None:
        env = os.environ.get("SWEEP_WORKERS")
        workers = int(env) if env else (os.cpu_count() or 1)
    workers = max(1, min(workers, len(cells)))

    t0 = time.monotonic()
    metrics: list[dict | None] = [None] * len(cells)
    done = 0

    # batched scan dispatch: whole shape buckets as single vmapped calls;
    # cells that turn out ineligible at runtime (no jax, partial warm-up)
    # come back as None and go to the pool below with everything else
    scan_pos = [i for i, c in enumerate(cells)
                if runner is None and _scan_batchable(c)]
    scan_batched = 0
    errors: dict[str, str] = {}
    if scan_pos:
        scan_cells = [cells[i] for i in scan_pos]
        try:
            batch = _run_cells_scan_partial(scan_cells)
        except Exception:
            # batched dispatch itself fell over (not a per-cell rejection,
            # those degrade inside): retry once, then send every scan cell
            # through the pool path below instead of failing the sweep
            try:
                batch = _run_cells_scan_partial(scan_cells)
            except Exception:
                batch = [None] * len(scan_pos)
        for i, m in zip(scan_pos, batch):
            if m is not None:
                metrics[i] = m
                scan_batched += 1
        done = scan_batched
        if done and progress is not None:
            progress(done, len(cells))

    # scan-requested cells the batched path could not take (no jax, cold
    # pool, unsupported dynamics) degrade to run_cell below -- count them
    degraded_pos = {i for i in scan_pos if metrics[i] is None}

    guarded = partial(_run_guard, fn)
    rest = [i for i in range(len(cells)) if metrics[i] is None]
    pool_workers = max(1, min(workers, len(rest)))
    if rest and (pool_workers == 1 or len(rest) == 1):
        for i in rest:
            metrics[i] = guarded(cells[i])
            done += 1
            if progress is not None:
                progress(done, len(cells))
    elif rest:
        chunk = max(1, len(rest) // (pool_workers * 8))
        # fork is fastest, but forking a process that already initialised
        # JAX/XLA can deadlock; fall back to spawn in that case (workers
        # re-import repro.core, which stays JAX-free by design)
        method = executor or ("spawn" if ("jax" in sys.modules
                                          or not hasattr(os, "fork"))
                              else "fork")
        if method == "spawn" and executor is None and hasattr(os, "fork"):
            main_file = getattr(sys.modules.get("__main__"), "__file__", None)
            if main_file is not None and not os.path.exists(main_file):
                # a "<stdin>" main cannot be re-imported by spawn; fork is
                # the only pool that works there (accepting the JAX risk)
                method = "fork"
        ctx = multiprocessing.get_context(method)
        with ProcessPoolExecutor(max_workers=pool_workers,
                                 mp_context=ctx) as ex:
            it = ex.map(guarded, [cells[i] for i in rest], chunksize=chunk)
            for i, m in zip(rest, it):
                metrics[i] = m
                done += 1
                if progress is not None:
                    progress(done, len(cells))
    for i in degraded_pos:
        if metrics[i] is not None and "degraded" not in metrics[i]:
            metrics[i] = {**metrics[i], "degraded": 1.0}
    # cells that raised twice come back as error markers: convert to a
    # ``failed`` metrics row (aggregate() reports the failed fraction per
    # group) and record the error strings in the sweep metadata
    for i, m in enumerate(metrics):
        if m is not None and "__error__" in m:
            errors[f"{cells[i].label()}#seed{cells[i].seed}"] = m["__error__"]
            metrics[i] = {"failed": 1.0}
    wall = time.monotonic() - t0
    return SweepResult(
        results=[CellResult(c, m) for c, m in zip(cells, metrics)],
        wall_s=wall, workers=workers,
        meta={"cells": len(cells), "scan_batched": scan_batched,
              "degraded": sum(1 for m in metrics
                              if m is not None and m.get("degraded")),
              "failed": sum(1 for m in metrics
                            if m is not None and m.get("failed")),
              "errors": errors,
              "xcheck_sampled": sum(1 for c in cells if c.cross_check),
              "xcheck_skipped_degraded": getattr(
                  spec, "_xcheck_skipped_degraded", 0)},
    )


def compare(spec: SweepSpec, baseline_policy: str = "fifo",
            metric: str = "R_avg", workers: int | None = None) -> list[dict]:
    """Convenience: run the sweep and report each policy's ``metric`` as a
    ratio to ``baseline_policy`` within the same (non-policy) cell identity."""
    res = run_sweep(spec, workers=workers)
    agg = res.aggregate()
    base = {tuple(r[f] for f in GRID_FIELDS if f != "policy"): r[metric]
            for r in agg if r["policy"] == baseline_policy}
    out = []
    for r in agg:
        key = tuple(r[f] for f in GRID_FIELDS if f != "policy")
        ref = base.get(key)
        out.append({**r, f"{metric}_vs_{baseline_policy}":
                    (r[metric] / ref) if ref else float("nan")})
    return out
