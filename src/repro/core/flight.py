"""Flight recorder: unified lifecycle traces, probes, and triage.

The paper's argument is entirely about per-request lifecycle (queue wait,
management-channel cost, non-preemptive execution) and time-varying load,
yet aggregates alone cannot say *which event* diverged or *when* a queue
built up.  This module provides:

- ``TraceEvent`` / ``SimTrace``: a canonical lifecycle event schema shared
  by every engine.  The reference ``Cluster``/``OursNodeSim`` emit rich
  events through a zero-cost-when-disabled ``FlightRecorder`` hook; the
  scan/streamscan paths reconstruct the *canonical* subset (arrival,
  dispatch, complete, fail) from their per-request output tensors via
  :func:`trace_from_result`, so the trace itself is a parity surface next
  to ``CROSS_CHECK_EXACT``.
- windowed time-series probes (:meth:`SimTrace.probes`): queue depth, busy
  slots / utilization, channel backlog, active nodes, arrivals /
  completions / retries per window.
- :func:`first_divergence`: align two canonical streams and name the first
  divergent event (time, kind, request, node, field) — attached to
  ``BackendMismatchError`` by the sweep cross-checker.
- exporters: Chrome-trace/Perfetto JSON (:meth:`SimTrace.to_chrome`, one
  lane per node slot), array bundles for ``plots.plot_timeline``
  (:meth:`SimTrace.to_arrays`), and a per-run ``manifest.json``
  (:func:`run_manifest` / :func:`write_manifest`).
- :meth:`SimTrace.explain`: a human-readable single-request lifecycle.

Event vocabulary (``kind``):

======================  =====================================================
kind                    meaning
======================  =====================================================
``arrival``             invoker receives the call (``r + REQ_OVERHEAD_S``)
``enqueue``             call enters a queue (global pull queue: ``node=-1``)
``channel_enter``       slot granted; management channel work begins
``dispatch``            execution starts on a node slot (``req.start``)
``complete``            execution finishes (``req.finish``)
``fail``                terminal failure (``info`` = cause)
``kill``                in-flight/queued call lost to a node failure
``timeout``             resilience deadline fired (``info``: queued/running)
``shed``                admission control rejected the call
``retry``               failed attempt re-armed (``info`` = cause + delay)
``hedge_arm``           straggler watch armed for a call
``steal``               hedged call cancelled+restolen to another node
``duplicate``           racing backup copy issued to another node
``dup_win``             the backup copy beat the original
``container_cold``      cold container created for this dispatch
``container_prewarm``   warm-pool container consumed for this dispatch
``container_evict``     idle container evicted to free memory
``node_up``             node activated (startup or autoscale-out)
``node_down``           node killed / scaled in
``autoscale_tick``      autoscaler evaluated its rule (``info`` = inputs)
======================  =====================================================

Canonical kinds — reconstructible from final per-request state on *every*
backend — are ``arrival``/``dispatch``/``complete``/``fail``.  All other
kinds are only observable from the instrumented reference event loop.
"""
from __future__ import annotations

import json
import math
import os
import subprocess
import sys
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Iterable, Sequence

__all__ = [
    "CANONICAL_KINDS",
    "TraceEvent",
    "FlightRecorder",
    "SimTrace",
    "DivergenceReport",
    "trace_from_result",
    "trace_from_requests",
    "first_divergence",
    "run_manifest",
    "write_manifest",
]

# canonical = derivable from written-back request state on any backend
CANONICAL_KINDS = ("arrival", "dispatch", "complete", "fail")

# deterministic tie-break order for same-time events (lifecycle order)
_KIND_RANK = {
    "node_up": 0, "arrival": 1, "enqueue": 2, "shed": 3, "hedge_arm": 4,
    "channel_enter": 5, "container_evict": 6, "container_cold": 7,
    "container_prewarm": 8, "dispatch": 9, "steal": 10, "duplicate": 11,
    "timeout": 12, "retry": 13, "complete": 14, "dup_win": 15, "kill": 16,
    "fail": 17, "node_down": 18, "autoscale_tick": 19,
}


def _node_index(name: Any) -> int:
    """Map a node name ("node3") or index to an int lane; -1 = none/global."""
    if name is None:
        return -1
    if isinstance(name, (int,)):
        return int(name)
    s = str(name)
    if s.startswith("node"):
        try:
            return int(s[4:])
        except ValueError:
            return -1
    return -1


@dataclass(frozen=True)
class TraceEvent:
    """One lifecycle event.  ``t`` may be NaN when the engine cannot
    recover the wall-clock (e.g. terminal failures reconstructed from scan
    output tensors); comparisons skip NaN times."""

    t: float
    kind: str
    req: int = -1
    node: int = -1
    fn: str = ""
    attempt: int = 0
    info: str = ""

    def as_dict(self) -> dict[str, Any]:
        return {"t": self.t, "kind": self.kind, "req": self.req,
                "node": self.node, "fn": self.fn, "attempt": self.attempt,
                "info": self.info}

    def render(self) -> str:
        t = "      ?" if math.isnan(self.t) else f"{self.t:10.4f}"
        node = f" node{self.node}" if self.node >= 0 else ""
        att = f" attempt={self.attempt}" if self.attempt else ""
        info = f"  [{self.info}]" if self.info else ""
        return f"{t}s  {self.kind:<16}{node}{att}{info}"


class FlightRecorder:
    """Mutable event sink the reference engines emit into.

    Engines hold ``trace: FlightRecorder | None`` and guard every emission
    site with ``if trace is not None`` — the disabled path costs one
    attribute load + None check per site, nothing else.
    """

    __slots__ = ("events",)

    def __init__(self) -> None:
        self.events: list[TraceEvent] = []

    def emit(self, t: float, kind: str, *, req: int = -1, node: int = -1,
             fn: str = "", attempt: int = 0, info: str = "") -> None:
        self.events.append(TraceEvent(float(t), kind, int(req),
                                      _node_index(node), fn, int(attempt),
                                      info))

    def to_trace(self, *, nodes: int = 1, slots_per_node: int = 1,
                 meta: dict[str, Any] | None = None) -> "SimTrace":
        return SimTrace(events=sorted(
            self.events, key=lambda e: (e.t, _KIND_RANK.get(e.kind, 99),
                                        e.req, e.node)),
            nodes=nodes, slots_per_node=slots_per_node, meta=meta or {})


@dataclass
class SimTrace:
    """An immutable, time-sorted lifecycle event stream plus topology."""

    events: list[TraceEvent]
    nodes: int = 1
    slots_per_node: int = 1
    meta: dict[str, Any] = field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.events)

    # ------------------------------------------------------------------
    # views
    # ------------------------------------------------------------------
    def by_kind(self, *kinds: str) -> list[TraceEvent]:
        want = set(kinds)
        return [e for e in self.events if e.kind in want]

    def for_request(self, req: int) -> list[TraceEvent]:
        return [e for e in self.events if e.req == req]

    def relabel(self, mapping: dict[int, int]) -> "SimTrace":
        """Return a copy with request ids mapped through ``mapping``
        (ids absent from the map pass through).  Request ids are allocated
        globally, so two separately-generated twin workloads carry distinct
        ids for the same call; relabel one side before comparing streams."""
        evs = [TraceEvent(e.t, e.kind, mapping.get(e.req, e.req), e.node,
                          e.fn, e.attempt, e.info) for e in self.events]
        return SimTrace(events=evs, nodes=self.nodes,
                        slots_per_node=self.slots_per_node,
                        meta=dict(self.meta))

    def counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for e in self.events:
            out[e.kind] = out.get(e.kind, 0) + 1
        return out

    def canonical(self) -> "SimTrace":
        """Project the rich stream down to the canonical per-request
        lifecycle: every arrival, plus the *winning* dispatch/complete pair
        per request (hedged duplicates and killed/retried attempts emit
        extra dispatch events; the winner is the one that produced the
        surviving completion), plus terminal fails.

        A canonical projection of a reference trace is directly comparable
        with :func:`trace_from_result` output from any backend.
        """
        per_req: dict[int, dict[str, list[TraceEvent]]] = {}
        for e in self.events:
            if e.kind in ("arrival", "dispatch", "complete", "fail"):
                per_req.setdefault(e.req, {}).setdefault(e.kind, []).append(e)
        out: list[TraceEvent] = []
        for req, kinds in per_req.items():
            arrs = kinds.get("arrival", [])
            if arrs:
                # retry/backoff re-arrivals re-emit "arrival" in the rich
                # stream; canonically a request arrives once, at the start
                out.append(min(arrs, key=lambda e: e.t))
            comps = kinds.get("complete", [])
            if comps:
                win = min(comps, key=lambda e: e.t)
                out.append(win)
                # winning dispatch: latest dispatch on the winner's node at
                # or before the winning completion (attempts are sequential
                # per node, so this is the run that completed)
                cands = [d for d in kinds.get("dispatch", [])
                         if d.node == win.node and d.t <= win.t + 1e-12]
                if cands:
                    out.append(max(cands, key=lambda e: e.t))
            else:
                out.extend(kinds.get("fail", []))
        out.sort(key=lambda e: (math.inf if math.isnan(e.t) else e.t,
                                _KIND_RANK.get(e.kind, 99), e.req))
        return SimTrace(events=out, nodes=self.nodes,
                        slots_per_node=self.slots_per_node,
                        meta=dict(self.meta, canonical=True))

    # ------------------------------------------------------------------
    # probes
    # ------------------------------------------------------------------
    def span(self) -> tuple[float, float]:
        ts = [e.t for e in self.events if not math.isnan(e.t)]
        if not ts:
            return (0.0, 0.0)
        return (min(ts), max(ts))

    def probes(self, window_s: float | None = None, *,
               bins: int = 64) -> dict[str, Any]:
        """Windowed time-series probes.

        Returns a dict of equal-length lists: ``t`` (window right edges),
        rate-like series counted per window (``arrivals``, ``completions``,
        ``retries``, ``timeouts``, ``sheds``, ``steals``), and level-like
        series sampled at each edge (``queue_depth``, ``busy``,
        ``utilization``, ``active_nodes``, ``channel_backlog``).

        Level series are derived from lifecycle intervals, so they work on
        canonical traces from any backend: queued = [arrival, dispatch),
        busy = [dispatch, complete).  ``channel_backlog`` needs the rich
        reference stream (``channel_enter`` events) and is all-zero
        otherwise.  ``active_nodes`` uses node_up/node_down when present,
        else the static node count.
        """
        lo, hi = self.span()
        if hi <= lo:
            hi = lo + 1.0
        if window_s is None:
            window_s = (hi - lo) / max(1, bins)
        n_win = max(1, int(math.ceil((hi - lo) / window_s - 1e-9)))
        edges = [lo + window_s * (i + 1) for i in range(n_win)]

        def win_of(t: float) -> int:
            return min(n_win - 1, max(0, int((t - lo) / window_s)))

        zeros = lambda: [0] * n_win
        rates = {k: zeros() for k in ("arrivals", "completions", "retries",
                                      "timeouts", "sheds", "steals")}
        rate_kind = {"arrival": "arrivals", "complete": "completions",
                     "retry": "retries", "timeout": "timeouts",
                     "shed": "sheds", "steal": "steals"}

        # level series via +/-1 deltas, then prefix-sum sampled at edges
        dq, db, dc, dn = zeros(), zeros(), zeros(), zeros()
        per_req: dict[int, dict[str, TraceEvent]] = {}
        have_node_events = False
        for e in self.events:
            if math.isnan(e.t):
                continue
            key = rate_kind.get(e.kind)
            if key is not None:
                rates[key][win_of(e.t)] += 1
            if e.kind in ("arrival", "dispatch", "complete", "channel_enter"):
                per_req.setdefault(e.req, {}).setdefault(e.kind, e)
            elif e.kind == "node_up":
                have_node_events = True
                dn[win_of(e.t)] += 1
            elif e.kind == "node_down":
                have_node_events = True
                dn[win_of(e.t)] -= 1
        for evs in per_req.values():
            arr, disp = evs.get("arrival"), evs.get("dispatch")
            comp, chan = evs.get("complete"), evs.get("channel_enter")
            if arr is not None and disp is not None:
                dq[win_of(arr.t)] += 1
                dq[win_of(disp.t)] -= 1
            if disp is not None and comp is not None:
                db[win_of(disp.t)] += 1
                db[win_of(comp.t)] -= 1
            if chan is not None and disp is not None:
                dc[win_of(chan.t)] += 1
                dc[win_of(disp.t)] -= 1

        def cumsum(deltas: list[int], base: int = 0) -> list[int]:
            out, acc = [], base
            for d in deltas:
                acc += d
                out.append(acc)
            return out

        queue = cumsum(dq)
        busy = cumsum(db)
        backlog = cumsum(dc)
        active = (cumsum(dn) if have_node_events
                  else [self.nodes] * n_win)
        total_slots = [max(1, a) * self.slots_per_node for a in active]
        util = [b / s for b, s in zip(busy, total_slots)]
        return {"t": edges, "window_s": window_s,
                "queue_depth": queue, "busy": busy, "utilization": util,
                "channel_backlog": backlog, "active_nodes": active,
                **rates}

    # ------------------------------------------------------------------
    # exporters
    # ------------------------------------------------------------------
    def to_arrays(self) -> dict[str, list[Any]]:
        """Column-oriented view (DataFrame-ish) for plotting/analysis."""
        cols: dict[str, list[Any]] = {k: [] for k in
                                      ("t", "kind", "req", "node", "fn",
                                       "attempt", "info")}
        for e in self.events:
            cols["t"].append(e.t)
            cols["kind"].append(e.kind)
            cols["req"].append(e.req)
            cols["node"].append(e.node)
            cols["fn"].append(e.fn)
            cols["attempt"].append(e.attempt)
            cols["info"].append(e.info)
        return cols

    def to_chrome(self, path: str | os.PathLike | None = None) -> dict:
        """Chrome-trace/Perfetto JSON: one process per node, one lane
        (thread) per node slot, execution runs as complete ("X") events,
        everything else as instants.  Load the file at ``chrome://tracing``
        or https://ui.perfetto.dev."""
        trace_events: list[dict[str, Any]] = []
        # execution intervals from the canonical winning runs
        canon = self.canonical()
        runs: dict[int, dict[str, TraceEvent]] = {}
        for e in canon.events:
            if e.kind in ("dispatch", "complete"):
                runs.setdefault(e.req, {})[e.kind] = e
        intervals = sorted(
            ((d["dispatch"].t, d["complete"].t, d["dispatch"]) for d in
             runs.values() if "dispatch" in d and "complete" in d
             and not math.isnan(d["dispatch"].t)),
            key=lambda iv: iv[0])
        # greedy slot-lane assignment per node (interval partitioning)
        lanes: dict[int, list[float]] = {}
        for start, end, disp in intervals:
            free = lanes.setdefault(disp.node, [])
            lane = next((i for i, t_free in enumerate(free)
                         if t_free <= start + 1e-12), None)
            if lane is None:
                lane = len(free)
                free.append(end)
            else:
                free[lane] = end
            trace_events.append({
                "name": disp.fn or f"req{disp.req}", "cat": "exec",
                "ph": "X", "ts": start * 1e6, "dur": (end - start) * 1e6,
                "pid": disp.node + 1, "tid": lane + 1,
                "args": {"req": disp.req, "attempt": disp.attempt},
            })
        for e in self.events:
            if e.kind in ("dispatch", "complete") or math.isnan(e.t):
                continue
            trace_events.append({
                "name": e.kind, "cat": "lifecycle", "ph": "i", "s": "t",
                "ts": e.t * 1e6, "pid": (e.node + 1 if e.node >= 0 else 0),
                "tid": 0,
                "args": {"req": e.req, "fn": e.fn, "attempt": e.attempt,
                         "info": e.info},
            })
        for pid in sorted({ev["pid"] for ev in trace_events}):
            trace_events.append({
                "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                "args": {"name": ("controller" if pid == 0
                                  else f"node{pid - 1}")}})
        doc = {"traceEvents": trace_events, "displayTimeUnit": "ms",
               "otherData": dict(self.meta)}
        if path is not None:
            Path(path).write_text(json.dumps(doc))
        return doc

    # ------------------------------------------------------------------
    # human-readable lifecycle
    # ------------------------------------------------------------------
    def explain(self, req: int) -> str:
        """Render one request's lifecycle, e.g. ``queued 3.2s behind 7
        calls, stolen to node 2, completed attempt 2``."""
        evs = self.for_request(req)
        if not evs:
            return f"request {req}: no events recorded"
        lines = [f"request {req}" + (f" fn={evs[0].fn}" if evs[0].fn else "")]
        lines += ["  " + e.render() for e in evs]
        arr = next((e for e in evs if e.kind == "arrival"), None)
        comp = next((e for e in evs if e.kind == "complete"), None)
        disp = [e for e in evs if e.kind == "dispatch"]
        summary: list[str] = []
        if arr is not None and disp:
            d0 = min(disp, key=lambda e: e.t)
            wait = d0.t - arr.t
            behind = sum(1 for e in self.events
                         if e.kind == "dispatch" and e.req != req
                         and e.node == d0.node and arr.t < e.t <= d0.t)
            summary.append(f"queued {wait:.3f}s behind {behind} call"
                           + ("s" if behind != 1 else ""))
        for e in evs:
            if e.kind == "steal":
                summary.append(f"stolen to node {e.node}")
            elif e.kind == "duplicate":
                summary.append(f"duplicated to node {e.node}")
            elif e.kind == "retry":
                summary.append(f"retried ({e.info})" if e.info else "retried")
        if comp is not None:
            att = f" attempt {comp.attempt}" if comp.attempt > 1 else ""
            summary.append(f"completed{att} on node {comp.node} "
                           f"at {comp.t:.3f}s")
        else:
            fail = next((e for e in evs if e.kind == "fail"), None)
            if fail is not None:
                summary.append(f"failed ({fail.info or 'unknown'})")
        if summary:
            lines.append("  => " + ", ".join(summary))
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# canonical reconstruction from written-back request state (any backend)
# ---------------------------------------------------------------------------
def trace_from_requests(requests: Iterable[Any], *, nodes: int = 1,
                        slots_per_node: int = 1,
                        meta: dict[str, Any] | None = None) -> SimTrace:
    """Build the canonical lifecycle stream from final ``Request`` state.

    Every backend — reference event loop, vectorized replay, scan kernel,
    streaming scan — writes the same per-request fields (start/finish
    clocks, node, attempts, cold_start, failed cause), so this function is
    the engine-independent half of the trace parity surface.
    """
    from .simulator import REQ_OVERHEAD_S

    events: list[TraceEvent] = []
    for q in requests:
        rid = int(getattr(q, "id", -1))
        node = _node_index(getattr(q, "node", None))
        att = int(getattr(q, "attempts", 0) or 0)
        events.append(TraceEvent(q.r + REQ_OVERHEAD_S, "arrival", rid,
                                 -1, q.fn, 0))
        failed = getattr(q, "failed", None)
        if failed:
            # terminal-failure wall clock is not recoverable from scan
            # output tensors; NaN time => compared by kind/cause only
            events.append(TraceEvent(float("nan"), "fail", rid, node,
                                     q.fn, att, str(failed)))
        elif q.start is not None and q.finish is not None:
            info = "cold" if getattr(q, "cold_start", False) else ""
            events.append(TraceEvent(float(q.start), "dispatch", rid, node,
                                     q.fn, att, info))
            events.append(TraceEvent(float(q.finish), "complete", rid, node,
                                     q.fn, att))
    events.sort(key=lambda e: (math.inf if math.isnan(e.t) else e.t,
                               _KIND_RANK.get(e.kind, 99), e.req))
    return SimTrace(events=events, nodes=nodes,
                    slots_per_node=slots_per_node,
                    meta=dict(meta or {}, canonical=True))


def trace_from_result(result: Any, *, requests: Sequence[Any] | None = None,
                      slots_per_node: int = 1,
                      meta: dict[str, Any] | None = None) -> SimTrace:
    """Canonical trace from a ``SimResult`` (any backend)."""
    reqs = result.requests if requests is None else requests
    nodes = max(1, int(getattr(result, "nodes_used", 1) or 1))
    m = {"cold_starts": result.cold_starts,
         "failures": getattr(result, "failures", 0)}
    if meta:
        m.update(meta)
    return trace_from_requests(reqs, nodes=nodes,
                               slots_per_node=slots_per_node, meta=m)


# ---------------------------------------------------------------------------
# first-divergence triage
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class DivergenceReport:
    """Names the first divergent event between two canonical streams."""

    t: float
    kind: str
    req: int
    fld: str
    ref_value: Any
    got_value: Any
    occurrence: int = 0

    def __str__(self) -> str:
        t = "t=?" if math.isnan(self.t) else f"t={self.t:.6f}s"
        return (f"first divergence at {t} kind={self.kind} req={self.req} "
                f"field={self.fld}: reference={self.ref_value!r} vs "
                f"other={self.got_value!r}"
                + (f" (occurrence {self.occurrence})"
                   if self.occurrence else ""))


def _rel_err(a: float, b: float) -> float:
    return abs(a - b) / max(abs(a), abs(b), 1e-9)


def first_divergence(ref: SimTrace, got: SimTrace, *,
                     rtol: float = 3e-2, atol: float = 1e-6,
                     compare_attempts: bool = True,
                     ) -> DivergenceReport | None:
    """Align two canonical streams and return the earliest divergence.

    Events are matched by ``(req, kind, occurrence index)`` rather than by
    global time-sorted position: backend clocks legitimately differ within
    ``rtol`` (float32 rounding on the scan path), so positional alignment
    on a time sort would manufacture false divergences at every near-tie.
    Compared fields: event multiplicity per (req, kind), ``t`` (relative
    tolerance, NaNs skip), ``node``, ``attempt`` (dispatch/complete), and
    ``info`` on fail events (the failure cause).  Fail events compare the
    cause but not the node: a terminally-failed call's last-touched node
    is engine bookkeeping, not client-visible outcome, and the backends
    legitimately record it differently (the reference keeps ``None`` for
    calls shed before routing).  Pass ``compare_attempts=False`` for
    failure-injection cells without hedging/resilience: the scan kernel
    re-routes kill-lost calls but does not write back a per-request
    resubmission count there (a documented gap).  Returns ``None`` when
    the streams agree.
    """
    rc, gc = ref.canonical(), got.canonical()

    def index(tr: SimTrace) -> dict[tuple[int, str], list[TraceEvent]]:
        out: dict[tuple[int, str], list[TraceEvent]] = {}
        for e in tr.events:
            out.setdefault((e.req, e.kind), []).append(e)
        return out

    ri, gi = index(rc), index(gc)
    worst: DivergenceReport | None = None

    def earlier(a: DivergenceReport, b: DivergenceReport | None) -> bool:
        if b is None:
            return True
        ta = math.inf if math.isnan(a.t) else a.t
        tb = math.inf if math.isnan(b.t) else b.t
        return ta < tb

    for key in sorted(set(ri) | set(gi),
                      key=lambda k: (min((e.t for e in ri.get(k, gi.get(k, []))
                                          if not math.isnan(e.t)),
                                         default=math.inf), k)):
        req, kind = key
        revs, gevs = ri.get(key, []), gi.get(key, [])
        if len(revs) != len(gevs):
            anchor = (revs or gevs)[0]
            rep = DivergenceReport(anchor.t, kind, req, "count",
                                   len(revs), len(gevs))
            if earlier(rep, worst):
                worst = rep
            continue
        for occ, (re_, ge) in enumerate(zip(revs, gevs)):
            rep: DivergenceReport | None = None
            if (not math.isnan(re_.t) and not math.isnan(ge.t)
                    and _rel_err(re_.t, ge.t) > rtol
                    and abs(re_.t - ge.t) > atol):
                rep = DivergenceReport(re_.t, kind, req, "t", re_.t, ge.t,
                                       occ)
            elif kind != "fail" and re_.node != ge.node:
                rep = DivergenceReport(re_.t, kind, req, "node", re_.node,
                                       ge.node, occ)
            elif (compare_attempts and kind in ("dispatch", "complete")
                    and re_.attempt != ge.attempt):
                rep = DivergenceReport(re_.t, kind, req, "attempt",
                                       re_.attempt, ge.attempt, occ)
            elif kind == "fail" and re_.info != ge.info:
                rep = DivergenceReport(re_.t, kind, req, "cause", re_.info,
                                       ge.info, occ)
            if rep is not None and earlier(rep, worst):
                worst = rep
    return worst


# ---------------------------------------------------------------------------
# run manifest
# ---------------------------------------------------------------------------
_ENV_PREFIXES = ("REPRO_", "JAX_", "XLA_")


def run_manifest(extra: dict[str, Any] | None = None) -> dict[str, Any]:
    """Provenance snapshot for a sweep/bench run: git sha, platform,
    scan compile-cache + per-bucket timing stats, and REPRO_*/JAX_*/XLA_*
    env flags.  Every lookup is best-effort — a manifest must never fail
    the run it documents."""
    man: dict[str, Any] = {
        "generated_unix": time.time(),
        "python": sys.version.split()[0],
        "platform": sys.platform,
    }
    try:
        repo = Path(__file__).resolve().parents[3]
        sha = subprocess.run(["git", "rev-parse", "HEAD"], cwd=repo,
                             capture_output=True, text=True, timeout=5)
        if sha.returncode == 0:
            man["git_sha"] = sha.stdout.strip()
    except Exception:
        pass
    try:
        import jax
        man["jax"] = {"version": jax.__version__,
                      "backend": jax.default_backend(),
                      "device_count": jax.device_count()}
    except Exception:
        man["jax"] = None
    try:
        from .fastpath import scan_bucket_timings, scan_cache_stats
        man["scan_cache"] = scan_cache_stats()
        timings = scan_bucket_timings()
        man["scan_buckets"] = {
            "records": len(timings),
            "cells": sum(int(t.get("cells", 0)) for t in timings),
            **{f"total_{k}": round(sum(t.get(k, 0.0) for t in timings), 6)
               for k in ("build_s", "compile_s", "dispatch_s", "sync_s",
                         "tune_s")},
        }
    except Exception:
        pass
    man["env"] = {k: v for k, v in sorted(os.environ.items())
                  if k.startswith(_ENV_PREFIXES)}
    if extra:
        man.update(extra)
    return man


def write_manifest(path: str | os.PathLike, *, sweep: Any = None,
                   extra: dict[str, Any] | None = None) -> dict[str, Any]:
    """Write ``manifest.json`` next to sweep artifacts.  ``sweep`` may be
    a ``SweepResult``; its degraded/failed/error counts are included."""
    info: dict[str, Any] = dict(extra or {})
    if sweep is not None:
        results = list(getattr(sweep, "results", []) or [])
        meta = dict(getattr(sweep, "meta", {}) or {})
        info["sweep"] = {
            "cells": len(results),
            "degraded": sum(1 for cr in results
                            if cr.metrics.get("degraded")),
            "errors": sum(1 for cr in results if cr.metrics.get("error")),
            "wall_s": getattr(sweep, "wall_s", 0.0),
        }
        for k in ("backend", "validate"):
            if k in meta:
                info["sweep"][k] = meta[k]
    man = run_manifest(info)
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(man, indent=2, sort_keys=True, default=str))
    return man
