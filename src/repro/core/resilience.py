"""Request-lifecycle resilience: timeouts, retries with backoff, shedding.

The paper's clients are infinitely patient: no call ever times out, retries,
or is refused, so the sim cannot reproduce the regime where overload becomes
*self-sustaining* (burst -> timeouts -> client retries -> more load -> more
timeouts -- the metastable failure mode of real serving fleets).  This
module makes client/controller resilience a first-class, *declarative*
scenario consumed by both engines:

* :class:`TimeoutSpec` -- a per-request deadline armed at controller
  receive, either ``multiple x max(E[p], floor_s)`` (the same last-10
  controller estimate hedging uses) or absolute.  A *queued* timeout
  cancels the call on its node; a *running* timeout frees the slot and the
  elapsed execution counts as ``wasted_work``.
* :class:`RetryPolicy` -- client retry behavior: up to ``max_attempts``
  submissions, re-arriving either immediately or after capped exponential
  backoff with deterministic per-(request, attempt) jitter (a pure integer
  hash of the request's arrival rank and the attempt number, so both
  engines -- and any worker count -- reproduce the exact same retry
  schedule).  ``retry_on`` selects which fates re-arrive: timeouts, shed
  responses, and/or kill-lost calls.
* :class:`AdmissionPolicy` -- controller-side load shedding: refuse a call
  on arrival when the estimated wait -- total queued E[p] per free slot,
  reusing the estimator rings -- exceeds ``threshold_s``.  Shed responses
  feed the retry path, which is exactly how real retry storms couple.

The reference :class:`~repro.core.cluster.Cluster` implements this with
deadline watch events and backoff re-arrivals on the event loop; the scan
kernel carries a ``res`` feature segment (timeout watches, retry
re-arrival clocks, a queued-E[p] accumulator for shed decisions -- float64
buckets) with bit-identical ``timed_out`` / ``shed`` / ``retries_issued``
accounting.

Pure data + arithmetic: no simulator imports, so both engines (and the
sweep layer) can depend on it without cycles.
"""

from __future__ import annotations

import math

from dataclasses import dataclass

RETRY_MODES = ("immediate", "backoff")
RETRY_CAUSES = ("timeout", "shed", "kill")


def retry_jitter_u(seq: int, attempt: int) -> float:
    """Deterministic jitter draw in [0, 1) for retry ``attempt`` of the
    request with stable arrival rank ``seq``.

    A pure integer hash (no RNG state), chosen so the scan kernel can
    evaluate the *identical* value in int32 arithmetic inside the step:
    products stay far below 2^31 for any realistic burst, and the result
    is a 16-bit integer over 65536 -- exactly representable in float, so
    reference-python and jnp-float64 agree bit-for-bit.  Keep in sync with
    the ``res`` segment of ``fastpath._scan_cell_kernel``."""
    h = (seq * 7919 + attempt * 104729 + 12345) % 65536
    return h / 65536.0


@dataclass(frozen=True)
class TimeoutSpec:
    """Per-request deadline armed when the controller receives the call.

    ``deadline = now + multiple x max(E[p], floor_s)`` with the
    controller-side last-10 estimate (the same ring hedging reads), or
    ``now + absolute_s`` when ``absolute_s`` is set (absolute wins).  A
    queued timeout cancels the call on its node; a running timeout frees
    the slot mid-execution and the elapsed time counts as wasted work.
    """

    multiple: float = 4.0
    floor_s: float = 0.5
    absolute_s: float | None = None

    def __post_init__(self) -> None:
        if self.absolute_s is not None:
            if not (self.absolute_s > 0 and math.isfinite(self.absolute_s)):
                raise ValueError(f"absolute timeout must be finite > 0, "
                                 f"got {self.absolute_s}")
        if not (self.multiple > 0):
            raise ValueError(f"timeout multiple must be > 0, "
                             f"got {self.multiple}")
        if self.floor_s < 0:
            raise ValueError(f"timeout floor must be >= 0, "
                             f"got {self.floor_s}")

    def deadline(self, now: float, estimate: float) -> float:
        """When the watch armed at ``now`` fires."""
        if self.absolute_s is not None:
            return now + self.absolute_s
        return now + self.multiple * max(estimate, self.floor_s)


@dataclass(frozen=True)
class RetryPolicy:
    """Client retry behavior for timed-out / shed / kill-lost calls.

    A request may be submitted at most ``max_attempts`` times in total
    (first submission included).  ``mode="immediate"`` re-arrives at the
    failure instant -- the naive client that fuels retry storms;
    ``mode="backoff"`` waits ``min(cap_delay_s, base_delay_s * 2^(a-1))``
    after failed attempt ``a``, scaled by ``(1 - jitter) + jitter * u``
    with the deterministic draw :func:`retry_jitter_u`.
    """

    max_attempts: int = 3
    mode: str = "backoff"
    base_delay_s: float = 0.5
    cap_delay_s: float = 8.0
    jitter: float = 0.5
    retry_on: tuple[str, ...] = ("timeout", "shed", "kill")

    def __post_init__(self) -> None:
        object.__setattr__(self, "retry_on",
                           tuple(str(c) for c in self.retry_on))
        if not (1 <= self.max_attempts <= 16):
            raise ValueError(f"max_attempts must be in [1, 16], "
                             f"got {self.max_attempts}")
        if self.mode not in RETRY_MODES:
            raise ValueError(f"unknown retry mode {self.mode!r}; "
                             f"available: {RETRY_MODES}")
        if self.base_delay_s < 0 or self.cap_delay_s < 0:
            raise ValueError("base/cap delay must be >= 0")
        if not (0.0 <= self.jitter <= 1.0):
            raise ValueError(f"jitter must be in [0, 1], got {self.jitter}")
        for c in self.retry_on:
            if c not in RETRY_CAUSES:
                raise ValueError(f"unknown retry cause {c!r}; "
                                 f"available: {RETRY_CAUSES}")

    def retries(self, cause: str) -> bool:
        """Does this policy retry a failure of ``cause``?"""
        return cause in self.retry_on

    def should_retry(self, cause: str, attempt: int) -> bool:
        """May failed submission number ``attempt`` (1-based) re-arrive?"""
        return self.retries(cause) and attempt < self.max_attempts

    def delay(self, seq: int, attempt: int) -> float:
        """Backoff delay after failed submission ``attempt`` (1-based) of
        the request with stable arrival rank ``seq``.  Mirrored term-for-
        term by the scan kernel's ``res`` segment: the power of two is an
        exact integer shift and the jitter draw an exact 16-bit fraction,
        so both engines compute bit-identical re-arrival times."""
        if self.mode == "immediate":
            return 0.0
        base = min(self.cap_delay_s,
                   self.base_delay_s * float(1 << (attempt - 1)))
        u = retry_jitter_u(seq, attempt)
        return base * ((1.0 - self.jitter) + self.jitter * u)


@dataclass(frozen=True)
class AdmissionPolicy:
    """Controller-side load shedding on estimated wait.

    An arriving (or re-arriving) call is refused when
    ``queued_ep / max(free_slots, 1) > threshold_s``, where ``queued_ep``
    is the sum of controller E[p] snapshots of every currently-queued call
    (each snapshot taken once at its enqueue, removed at dispatch or
    cancel -- so both engines accumulate in the identical event order) and
    ``free_slots`` the fleet's total idle cores.  Shed responses feed the
    retry path."""

    threshold_s: float = 2.0

    def __post_init__(self) -> None:
        if not (self.threshold_s >= 0 and math.isfinite(self.threshold_s)):
            raise ValueError(f"shed threshold must be finite >= 0, "
                             f"got {self.threshold_s}")

    def shed(self, queued_ep: float, free_slots: int) -> bool:
        return queued_ep / max(free_slots, 1) > self.threshold_s


@dataclass(frozen=True)
class ResilienceSpec:
    """Bundle of the three lifecycle policies; any subset may be active.

    ``ResilienceSpec()`` with all three ``None`` is the null spec --
    :func:`ResilienceSpec.from_any` collapses it to ``None`` so engine
    code can branch on ``spec is None``."""

    timeout: TimeoutSpec | None = None
    retry: RetryPolicy | None = None
    admission: AdmissionPolicy | None = None

    @property
    def is_null(self) -> bool:
        return (self.timeout is None and self.retry is None
                and self.admission is None)

    @property
    def max_attempts(self) -> int:
        return self.retry.max_attempts if self.retry is not None else 1

    @classmethod
    def from_any(cls, spec) -> "ResilienceSpec | None":
        """Normalize loose inputs (None, a spec, or one of the three
        component policies) to a non-null ``ResilienceSpec`` or ``None``."""
        if spec is None:
            return None
        if isinstance(spec, cls):
            return None if spec.is_null else spec
        if isinstance(spec, TimeoutSpec):
            return cls(timeout=spec)
        if isinstance(spec, RetryPolicy):
            return cls(retry=spec)
        if isinstance(spec, AdmissionPolicy):
            return cls(admission=spec)
        raise TypeError(f"cannot build ResilienceSpec from {spec!r}")

    # -- tensor form (scan kernel) ------------------------------------------
    def arrays(self):
        """``(timeout4, retry6, adm2)`` float64 parameter rows for one scan
        bucket cell: ``timeout4 = [on, multiple, floor, absolute]``
        (absolute <= 0 means estimate-multiple), ``retry6 = [max_attempts,
        base, cap, jitter, on_timeout, on_shed]``, ``adm2 = [on,
        threshold]``.  Immediate mode encodes as base = cap = 0 (delay
        collapses to 0 exactly)."""
        import numpy as np
        to = self.timeout
        rt = self.retry
        ad = self.admission
        t4 = np.zeros(4, dtype=np.float64)
        if to is not None:
            t4[:] = (1.0, to.multiple, to.floor_s,
                     to.absolute_s if to.absolute_s is not None else 0.0)
        r6 = np.zeros(6, dtype=np.float64)
        r6[0] = 1.0
        if rt is not None:
            backoff = rt.mode == "backoff"
            r6[:] = (float(rt.max_attempts),
                     rt.base_delay_s if backoff else 0.0,
                     rt.cap_delay_s if backoff else 0.0,
                     rt.jitter if backoff else 0.0,
                     1.0 if rt.retries("timeout") else 0.0,
                     1.0 if rt.retries("shed") else 0.0)
        a2 = np.zeros(2, dtype=np.float64)
        if ad is not None:
            a2[:] = (1.0, ad.threshold_s)
        return t4, r6, a2
