"""Discrete-event simulation of FaaS worker nodes (paper §V-§VII).

Execution model (reverse-engineered from the paper's published tables)
----------------------------------------------------------------------
The binding resource on a loaded node is **not** the CPU executing function
bodies: with the paper's method, node throughput is ~2.2-2.5 requests/s at
5, 10 AND 20 cores (makespan x cores / #requests ~= 0.42 core-s per call,
vs a 1.04 s mean function runtime and <80% function-work utilisation), and
the paper itself attributes this to "system overheads (related to container
management)" whose impact grows with the core count (§VII-C).  We therefore
model each node with an explicit **management channel** (invoker dispatch
loop + Docker daemon) through which every call start must pass:

* per-operation cost scales with the *weight* of the function's container
  (idle-median service time as proxy): heavy containers (dna-visualisation)
  take seconds to unpause/create, trivial ones (graph-bfs) milliseconds.
  This is what lets SEPT/FC reorderings cut the *mean* response time ~3-4x
  while leaving the makespan roughly unchanged, exactly as in Table III.
* ours (:class:`OursNodeSim`): the modified invoker dispatches serially
  (1 channel server: docker update --cpus + unpause per call), admission is
  slot-based (busy <= cores), the queue is a priority queue, execution then
  owns one core at rate 1 (non-preemptive, no oversubscription).
* baseline (:class:`BaselineNodeSim`): stock OpenWhisk.  Greedy memory-based
  admission; the channel has a small thread pool (4 servers) but per-op cost
  inflates with the number of live containers (daemon contention) and cold
  starts are frequent under load (greedy creation + LRU eviction churn).
  Executions share the CPU: egalitarian processor sharing with a
  context-switch degradation term -- the OS preemption the paper eliminates.

Calibration targets (paper Tables III/IV): ours-FIFO 10c/int40 avg R ~ 58 s,
makespan ~ 195 s; ours-SEPT ~ 17 s; baseline 10c/int40 ~ 64 s / 251 s;
baseline *beats* ours-FIFO at 10c/int30; baseline much worse at 20 cores;
ours makespan at 5c/int30 ~ 87 s vs baseline ~ 73 s (Table II ratios > 1).
"""

from __future__ import annotations

import heapq
import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Protocol, runtime_checkable

from .containers import ContainerPool
from .flight import FlightRecorder, SimTrace, _node_index, trace_from_result
from .request import Request
from .scheduler import NodeScheduler, StartDecision
from .workload import PROFILES, SEBS_MEMORY_MB

REQ_OVERHEAD_S = 0.008    # client -> invoker (Kafka + HTTP)
RESP_OVERHEAD_S = 0.002   # invoker -> client

# -- management-channel constants (seconds) ---------------------------------
# ours: serialized dispatch, cost = OURS_BASE + OURS_SCALE * weight
OURS_BASE = 0.06
OURS_SCALE = 0.35
OURS_PREWARM_EXTRA = 0.35
OURS_COLD_EXTRA = 0.9
# baseline: serialized dockerd channel; hot (unpaused) containers bypass it
BASE_HOT = 0.02           # container reused within pause grace: no docker op
BASE_HOT_SCALE = 0.03
BASE_WARM = 0.05          # paused warm container: docker unpause
BASE_WARM_SCALE = 0.22
BASE_PREWARM_EXTRA = 0.2  # init function inside prewarm container
BASE_COLD_EXTRA = 0.35    # docker create + init (serialized portion)
PAUSE_GRACE_S = 10.0      # stock OpenWhisk keeps hot containers unpaused
NU = 0.9                  # baseline dockerd degradation per 100 live containers
NU_CAP = 4.0              # contention-factor ceiling
PS_KAPPA = 0.25           # baseline context-switch degradation coefficient
SHARE_CAP = 0.125         # baseline memory-proportional cpu-shares cap: a
                          # 256 MB container on a node provisioned at ~2 GB
                          # per core is entitled to ~1/8 core.  Soft: bursts
                          # to full speed while the node is uncontended; the
                          # CFS + cgroup machinery starts enforcing shares
                          # once the *absolute* number of busy containers
                          # crosses CONTENTION_ABS (the dockerd/invoker is a
                          # per-node singleton, so the collapse point does
                          # not scale with cores -- cf. paper §VII-C).
CONTENTION_ABS = 8.0
WEIGHT_CAP_S = 9.0        # cap on the weight proxy


def container_weight(fn: str, p_fallback: float) -> float:
    """Weight proxy for management cost: the function's idle-median service
    time (Table I) -- heavy containers hold more processes/pages and are
    slower to create/pause/unpause."""
    prof = PROFILES.get(fn)
    w = prof.median_s if prof is not None else p_fallback
    return min(w, WEIGHT_CAP_S)


# --------------------------------------------------------------------------
# event loop
# --------------------------------------------------------------------------
class EventLoop:
    def __init__(self) -> None:
        self._heap: list[tuple[float, int, Callable[[], None]]] = []
        self._seq = itertools.count()
        self.now = 0.0

    def schedule(self, t: float, cb: Callable[[], None]) -> None:
        if t < self.now - 1e-12:
            t = self.now
        heapq.heappush(self._heap, (t, next(self._seq), cb))

    def run(self, until: float | None = None) -> None:
        while self._heap:
            t, _, cb = heapq.heappop(self._heap)
            if until is not None and t > until:
                self.now = until
                return
            self.now = t
            cb()


class ManagementChannel:
    """k-server FIFO resource for container-management operations."""

    def __init__(self, loop: EventLoop, servers: int = 1) -> None:
        self.loop = loop
        self._free_at = [0.0] * servers
        self.ops = 0
        self.busy_time = 0.0

    def occupy(self, cost: float) -> float:
        """Reserve the earliest-free server for ``cost`` s; returns ready time."""
        i = min(range(len(self._free_at)), key=lambda j: self._free_at[j])
        start = max(self.loop.now, self._free_at[i])
        self._free_at[i] = start + cost
        self.ops += 1
        self.busy_time += cost
        return self._free_at[i]


# --------------------------------------------------------------------------
# our node (paper §IV)
# --------------------------------------------------------------------------
class OursNodeSim:
    """Simulated worker running the paper's scheduler."""

    def __init__(
        self,
        loop: EventLoop,
        cores: int,
        policy: str = "fc",
        memory_mb: int = 32 * 1024,
        container_mb: int = 128,
        name: str = "node0",
        speed: float = 1.0,
        speed_fn: Callable[[float], float] | None = None,
        warm_functions: list[str] | None = None,
        on_complete: Callable[[Request], None] | None = None,
        on_start: Callable[[Request], None] | None = None,
        fn_memory: dict | None = None,
        trace: "FlightRecorder | None" = None,
        trace_node: int = -1,
    ) -> None:
        if fn_memory is None:
            fn_memory = SEBS_MEMORY_MB
        self.loop = loop
        self.name = name
        # flight-recorder hook: every emission site is guarded by a single
        # ``is not None`` check so the disabled path stays zero-cost
        self.trace = trace
        self.trace_node = trace_node if trace_node >= 0 else _node_index(name)
        self.speed = speed
        # time-varying effective speed (heterogeneity episodes): sampled at
        # dispatch time, overriding the static ``speed`` when provided
        self.speed_fn = speed_fn
        self.alive = True
        self.on_complete = on_complete
        # fired when a call leaves the queue for a slot (admission-control
        # bookkeeping: the controller's queued-E[p] accumulator drops the
        # call's enqueue-time snapshot here, in dispatch order)
        self.on_start = on_start
        self.channel = ManagementChannel(loop, servers=1)
        self.scheduler = NodeScheduler.build(
            slots=cores, policy=policy, memory_mb=memory_mb,
            container_mb=container_mb, fn_memory=fn_memory,
        )
        if warm_functions:
            # experiment warm-up (§V-A): c parallel calls per function; these
            # also seed the invoker's processing-time history.
            self.scheduler.pool.warm_up(warm_functions, per_fn=cores)
            for fn in warm_functions:
                w = PROFILES[fn].median_s if fn in PROFILES else 0.1
                for _ in range(min(cores, self.scheduler.estimator.window)):
                    self.scheduler.estimator.observe_completion(fn, w)
        self.completed: list[Request] = []
        self.in_flight: dict[int, StartDecision] = {}

    # the invoker pulls the call at ``now`` (= r + REQ_OVERHEAD)
    def submit(self, req: Request) -> None:
        if not self.alive:
            return
        req.node = self.name
        tr = self.trace
        if tr is not None:
            tr.emit(self.loop.now, "enqueue", req=req.id,
                    node=self.trace_node, fn=req.fn, attempt=req.attempts)
            ev0 = self.scheduler.pool.evictions
        for dec in self.scheduler.receive(req, self.loop.now):
            self._launch(dec)
        if tr is not None:
            for _ in range(self.scheduler.pool.evictions - ev0):
                tr.emit(self.loop.now, "container_evict",
                        node=self.trace_node)

    def _launch(self, dec: StartDecision) -> None:
        req = dec.request
        # keyed by *object* identity: duplicate-mode hedging can race two
        # copies sharing one request id onto the same node, and each
        # launched execution must complete (and free its slot) on its own.
        # The *decision* is the value so a stale completion event -- the
        # request timed out mid-run, retried, and re-launched on this very
        # node under the same object identity -- cannot finish the newer
        # execution early (``_finish`` checks decision identity).
        self.in_flight[id(req)] = dec
        # serialized management: cpu pin + unpause (+ init when not warm);
        # a degraded node (speed < 1) is slow at management too.  The
        # effective speed is sampled once, at dispatch -- non-preemptive
        # execution never changes rate mid-run.
        speed = (self.speed_fn(self.loop.now) if self.speed_fn is not None
                 else self.speed)
        cost = OURS_BASE + OURS_SCALE * container_weight(req.fn, req.p_true)
        if dec.acquire.cold_start:
            cost += (OURS_COLD_EXTRA if dec.acquire.startup_delay > 1.0
                     else OURS_PREWARM_EXTRA)
        exec_start = self.channel.occupy(cost / speed)
        req.start = exec_start
        service = req.p_true / speed
        finish = exec_start + service
        tr = self.trace
        if tr is not None:
            tr.emit(self.loop.now, "channel_enter", req=req.id,
                    node=self.trace_node, fn=req.fn, attempt=req.attempts)
            if dec.acquire.cold_start:
                tr.emit(self.loop.now,
                        ("container_cold" if dec.acquire.startup_delay > 1.0
                         else "container_prewarm"),
                        req=req.id, node=self.trace_node, fn=req.fn)
            tr.emit(exec_start, "dispatch", req=req.id, node=self.trace_node,
                    fn=req.fn, attempt=req.attempts,
                    info="cold" if dec.acquire.cold_start else "")
        if self.on_start is not None:
            self.on_start(req)
        self.loop.schedule(finish, lambda d=dec, s=service: self._finish(d, s))

    def _finish(self, dec: StartDecision, service: float) -> None:
        req = dec.request
        if not self.alive or self.in_flight.get(id(req)) is not dec:
            return  # node died, or the call was cancelled (timeout) mid-run
        del self.in_flight[id(req)]
        req.finish = self.loop.now
        req.c = self.loop.now + RESP_OVERHEAD_S
        self.completed.append(req)
        tr = self.trace
        if tr is not None:
            tr.emit(self.loop.now, "complete", req=req.id,
                    node=self.trace_node, fn=req.fn, attempt=req.attempts)
            ev0 = self.scheduler.pool.evictions
        # the invoker logs the *measured* processing time
        follow = self.scheduler.complete(req, service, dec.acquire, self.loop.now)
        if tr is not None:
            for _ in range(self.scheduler.pool.evictions - ev0):
                tr.emit(self.loop.now, "container_evict",
                        node=self.trace_node)
        if self.on_complete is not None:
            self.on_complete(req)
        for d in follow:
            self._launch(d)

    # -- resilience hooks -----------------------------------------------------
    def cancel_queued(self, req: Request) -> bool:
        """Drop a still-queued call (request timeout before dispatch)."""
        return self.scheduler.cancel(req)

    def cancel_running(self, req: Request) -> bool:
        """Cancel a running call (request timeout mid-execution): free the
        slot and container without completion history, backfill the slot.
        The already-scheduled finish event becomes a stale no-op."""
        dec = self.in_flight.pop(id(req), None)
        if dec is None:
            return False
        for d in self.scheduler.abort(dec.acquire, self.loop.now):
            self._launch(d)
        return True

    # -- fault injection ------------------------------------------------------
    def kill(self) -> list[Request]:
        """Node failure: everything queued or running is lost."""
        self.alive = False
        lost = [d.request for d in self.in_flight.values()]
        self.in_flight.clear()
        while self.scheduler.queue:
            lost.append(self.scheduler.queue.pop())
        if self.trace is not None:
            for q in lost:
                self.trace.emit(self.loop.now, "kill", req=q.id,
                                node=self.trace_node, fn=q.fn,
                                attempt=q.attempts)
        return lost

    @property
    def load(self) -> int:
        return self.scheduler.busy + self.scheduler.queued

    @property
    def free_slots(self) -> int:
        return max(0, self.scheduler.slots - self.scheduler.busy)


# --------------------------------------------------------------------------
# baseline node (stock OpenWhisk)
# --------------------------------------------------------------------------
@dataclass
class _PSJob:
    req: Request
    remaining: float          # seconds of work left at rate 1
    acquire: object           # container handle
    started: float = 0.0


class BaselineNodeSim:
    """Stock OpenWhisk invoker: FIFO + memory-based greedy admission + OS
    preemption (processor sharing) + dockerd contention."""

    def __init__(
        self,
        loop: EventLoop,
        cores: int,
        memory_mb: int = 32 * 1024,
        container_mb: int = 128,
        name: str = "node0",
        kappa: float = PS_KAPPA,
        nu: float = NU,
        warm_functions: list[str] | None = None,
        prewarm_count: int = 2,
        on_complete: Callable[[Request], None] | None = None,
        fn_memory: dict | None = None,
    ) -> None:
        if fn_memory is None:
            fn_memory = SEBS_MEMORY_MB
        self.loop = loop
        self.name = name
        self.cores = cores
        self.kappa = kappa
        self.nu = nu
        self.alive = True
        self.on_complete = on_complete
        self.channel = ManagementChannel(loop, servers=1)
        self.pool = ContainerPool(
            memory_mb=memory_mb, container_mb=container_mb,
            discipline="baseline", cores=cores, prewarm_count=prewarm_count,
            fn_memory=fn_memory,
        )
        if warm_functions:
            self.pool.warm_up(warm_functions, per_fn=min(cores, 4))
        self.jobs: dict[int, _PSJob] = {}
        self.pending: dict[int, Request] = {}   # dispatched, waiting on channel
        self.fifo: deque[Request] = deque()
        self.completed: list[Request] = []
        self._last_advance = 0.0
        self._version = 0

    # -- processor-sharing mechanics -----------------------------------------
    def _rate(self) -> float:
        n = len(self.jobs)
        if n == 0:
            return 0.0
        # memory-proportional cpu-shares are soft: containers burst to full
        # speed while the node is uncontended; once busy containers exceed
        # CONTENTION_FRAC x cores the CFS enforces the per-container share,
        # degraded further by context-switch overhead when oversubscribed.
        if n <= CONTENTION_ABS:
            return min(1.0, self.cores / n)
        share = min(SHARE_CAP, self.cores / n)
        overhead = 1.0 + self.kappa * max(0.0, (n - self.cores) / self.cores)
        return share / overhead

    def _advance(self) -> None:
        now = self.loop.now
        dt = now - self._last_advance
        if dt > 0 and self.jobs:
            rate = self._rate()
            for job in self.jobs.values():
                job.remaining -= rate * dt
        self._last_advance = now

    def _reschedule(self) -> None:
        """(Re)arm the next-completion event; stale events are version-gated."""
        self._version += 1
        if not self.jobs:
            return
        rate = self._rate()
        nxt = min(job.remaining for job in self.jobs.values())
        eta = self.loop.now + max(nxt, 0.0) / rate
        v = self._version
        self.loop.schedule(eta, lambda: self._on_timer(v))

    def _on_timer(self, version: int) -> None:
        if version != self._version or not self.alive:
            return
        self._advance()
        done = [j for j in self.jobs.values() if j.remaining <= 1e-9]
        for job in done:
            self._complete(job)
        self._drain_fifo()
        self._reschedule()

    # -- OpenWhisk behaviour ----------------------------------------------------
    def submit(self, req: Request) -> None:
        if not self.alive:
            return
        req.node = self.name
        req.r_prime = self.loop.now
        self._advance()
        if not self._try_dispatch(req):
            self.fifo.append(req)
        self._reschedule()

    def _contention(self) -> float:
        # superlinear dockerd degradation: a crowded daemon (hundreds of
        # containers) slows every operation (paper: "Docker had problems
        # running them" at high container counts)
        live = len(self.pool.containers)
        return min(1.0 + self.nu * (live / 100.0) ** 2, NU_CAP)

    def _try_dispatch(self, req: Request) -> bool:
        acq = self.pool.acquire(req.fn, self.loop.now)
        if acq is None:
            return False
        req.cold_start = acq.cold_start
        w = container_weight(req.fn, req.p_true)
        self.pending[req.id] = req
        if (not acq.cold_start
                and self.loop.now - acq.container.last_used <= PAUSE_GRACE_S):
            # HOT path: container still unpaused -> no docker op, no queueing
            ready = self.loop.now + (BASE_HOT + BASE_HOT_SCALE * w)
        else:
            # dockerd (serialized): unpause / init / create, slower when many
            # containers are live (daemon contention); creation's serialized
            # portion is contention-free (image setup is mostly I/O)
            cost = (BASE_WARM + BASE_WARM_SCALE * w) * self._contention()
            if acq.cold_start:
                cost += (BASE_COLD_EXTRA if acq.startup_delay > 1.0
                         else BASE_PREWARM_EXTRA)
            ready = self.channel.occupy(cost)
        self.loop.schedule(ready, lambda r=req, a=acq: self._begin_exec(r, a))
        return True

    def _begin_exec(self, req: Request, acq) -> None:
        if not self.alive or req.id not in self.pending:
            return
        del self.pending[req.id]
        self._advance()
        req.start = self.loop.now
        self.jobs[req.id] = _PSJob(req=req, remaining=req.p_true, acquire=acq,
                                   started=self.loop.now)
        self._reschedule()

    def _drain_fifo(self) -> None:
        while self.fifo:
            if self._try_dispatch(self.fifo[0]):
                self.fifo.popleft()
            else:
                break

    def _complete(self, job: _PSJob) -> None:
        req = job.req
        del self.jobs[req.id]
        self.pool.release(job.acquire.container, self.loop.now)
        req.finish = self.loop.now
        req.c = self.loop.now + RESP_OVERHEAD_S
        self.completed.append(req)
        if self.on_complete is not None:
            self.on_complete(req)

    def kill(self) -> list[Request]:
        self.alive = False
        self._version += 1
        lost = ([j.req for j in self.jobs.values()]
                + list(self.pending.values()) + list(self.fifo))
        self.jobs.clear()
        self.pending.clear()
        self.fifo.clear()
        return lost

    @property
    def load(self) -> int:
        return len(self.jobs) + len(self.pending) + len(self.fifo)

    @property
    def free_slots(self) -> int:
        return max(0, self.cores - len(self.jobs) - len(self.pending))


# --------------------------------------------------------------------------
# single-node experiment driver (paper §V-A protocol)
# --------------------------------------------------------------------------
@dataclass
class SimResult:
    requests: list[Request]
    cold_starts: int
    evictions: int
    creations: int
    failures: int = 0
    backups_issued: int = 0
    steals_won: int = 0       # hedged calls whose winning run was the backup
    nodes_used: int = 1
    # resilience counters (ISSUE 8): attempts that hit their deadline,
    # arrivals refused by admission control, client retries scheduled, and
    # seconds of execution thrown away by running-call cancellation
    timed_out: int = 0
    shed: int = 0
    retries_issued: int = 0
    wasted_work: float = 0.0
    # realized per-node capacity intervals (cluster runs only); typed loosely
    # to keep this module import-independent of .cluster
    timeline: object | None = None
    # flight-recorder lifecycle stream (populated only when tracing was
    # requested): rich on the reference event loop, canonical elsewhere
    trace: SimTrace | None = None
    meta: dict = field(default_factory=dict)


# --------------------------------------------------------------------------
# backend registry
# --------------------------------------------------------------------------
@runtime_checkable
class SimBackend(Protocol):
    """A simulation engine: submit requests -> :class:`SimResult`.

    Backends are interchangeable where :meth:`supports` says so; the
    ``reference`` backend (the discrete-event loop above) defines the
    semantics, alternative backends must agree with it on every metric the
    sweep engine reports (see ``SweepSpec(validate="cross-check")``).

    ``supports`` is a **capability matrix**: callers pass the full scenario
    shape -- ``nodes``/``assignment`` for clusters, ``autoscale``/``failures``
    for capacity dynamics, ``hedging``/``hetero`` for straggler scenarios --
    and a backend declares whether it can run it.  The scan backend runs
    every ours-mode scenario -- clusters (warm or cold-start) including
    autoscaling, failure injection, heterogeneous node speeds and hedging
    in both steal and duplicate modes -- and says no only to the stock
    baseline and to failure injection without a surviving peer; the
    vectorized fast path says no for ``nodes > 1`` and for any capacity
    dynamics.  The sweep
    engine routes cells by asking this matrix rather than hard-coding
    per-backend rules.
    """

    name: str

    def supports(self, *, mode: str, policy: str, warm: bool,
                 nodes: int = 1, assignment: str = "pull",
                 autoscale: bool = False, failures: bool = False,
                 hedging: bool = False, hetero: bool = False,
                 timeouts: bool = False, retries: bool = False,
                 shedding: bool = False,
                 streaming: bool = False, trace: bool = False) -> bool:
        """Can this backend run the scenario exactly?

        ``trace=True`` asks for the **rich** instrumented lifecycle stream
        (enqueue/channel/steal/container/... events).  Every backend can
        produce the *canonical* stream (arrival/dispatch/complete/fail via
        ``flight.trace_from_result``) for any scenario it runs, so the
        canonical trace needs no capability bit."""
        ...

    def simulate(
        self,
        requests: list[Request],
        cores: int,
        policy: str = "fifo",
        mode: str = "ours",
        memory_mb: int = 32 * 1024,
        container_mb: int = 128,
        warm: bool = True,
        kappa: float = PS_KAPPA,
    ) -> SimResult:
        ...


class ReferenceBackend:
    """The pure-Python discrete-event loop; supports every scenario except
    resilience on the stock baseline (processor sharing has no slot/queue
    structure for deadline cancellation to act on) and resilience combined
    with straggler hedging (a hedge copy and a deadline watch would both
    re-dispatch the same request id -- a documented exclusion)."""

    name = "reference"

    def supports(self, *, mode: str, policy: str, warm: bool,
                 nodes: int = 1, assignment: str = "pull",
                 autoscale: bool = False, failures: bool = False,
                 hedging: bool = False, hetero: bool = False,
                 timeouts: bool = False, retries: bool = False,
                 shedding: bool = False,
                 streaming: bool = False, trace: bool = False) -> bool:
        if streaming:
            return False       # the event loop materializes the full stream
        if trace and mode == "baseline":
            return False       # processor-sharing node is not instrumented
        resil = timeouts or retries or shedding
        if mode == "baseline" and resil:
            return False
        if hedging and resil:
            return False
        return True

    def simulate(
        self,
        requests: list[Request],
        cores: int,
        policy: str = "fifo",
        mode: str = "ours",
        memory_mb: int = 32 * 1024,
        container_mb: int = 128,
        warm: bool = True,
        kappa: float = PS_KAPPA,
        trace: bool = False,
    ) -> SimResult:
        loop = EventLoop()
        warm_fns = sorted({r.fn for r in requests}) if warm else None
        rec = FlightRecorder() if (trace and mode == "ours") else None
        node: OursNodeSim | BaselineNodeSim
        if mode == "ours":
            node = OursNodeSim(loop, cores, policy=policy, memory_mb=memory_mb,
                               container_mb=container_mb,
                               warm_functions=warm_fns,
                               trace=rec, trace_node=0)
            pool = node.scheduler.pool
        elif mode == "baseline":
            node = BaselineNodeSim(loop, cores, memory_mb=memory_mb,
                                   container_mb=container_mb, kappa=kappa,
                                   warm_functions=warm_fns)
            pool = node.pool
        else:
            raise ValueError(f"unknown mode {mode!r}")

        base_cold = pool.cold_starts  # warm-up colds are not measured (§V-A)
        if rec is not None:
            rec.emit(0.0, "node_up", node=0)
            for req in requests:
                rec.emit(req.r + REQ_OVERHEAD_S, "arrival", req=req.id,
                         fn=req.fn)
        for req in requests:
            loop.schedule(req.r + REQ_OVERHEAD_S, lambda r=req: node.submit(r))
        loop.run()

        missing = [r for r in requests if r.c is None]
        assert not missing, f"{len(missing)} requests never completed"
        return SimResult(
            requests=requests,
            cold_starts=pool.cold_starts - base_cold,
            evictions=pool.evictions,
            creations=pool.creations,
            trace=(rec.to_trace(nodes=1, slots_per_node=cores,
                                meta={"mode": mode, "policy": policy})
                   if rec is not None else None),
            meta={"mode": mode, "policy": policy, "cores": cores,
                  "backend": self.name},
        )


_BACKENDS: dict[str, SimBackend] = {}


def register_backend(backend: SimBackend) -> None:
    _BACKENDS[backend.name] = backend


def get_backend(name: str) -> SimBackend:
    """Look up a registered backend.

    The fast backends register themselves when :mod:`.fastpath` is imported
    -- normally via the ``repro.core`` package import; the import here is a
    safety net for callers that reached this module another way.  (Neither
    import pulls in JAX: fastpath defers its jax imports to the scan calls,
    so sweep workers still fork cleanly.)"""
    if name not in _BACKENDS:
        from . import fastpath  # noqa: F401  (registers its backends)
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown simulation backend {name!r}; "
            f"available: {sorted(_BACKENDS)}") from None


def available_backends() -> list[str]:
    from . import fastpath  # noqa: F401  (registers its backends)
    return sorted(_BACKENDS)


register_backend(ReferenceBackend())


def simulate_single_node(
    requests: list[Request],
    cores: int,
    policy: str = "fifo",
    mode: str = "ours",
    memory_mb: int = 32 * 1024,
    container_mb: int = 128,
    warm: bool = True,
    kappa: float = PS_KAPPA,
    backend: str = "reference",
    trace: bool = False,
) -> SimResult:
    """Run one burst on one node; returns completed requests + counters.

    ``backend`` selects the simulation engine: ``"reference"`` (the event
    loop), ``"vectorized"`` (array fast path, ours mode only) or ``"scan"``
    (batched jax.lax.scan variant).  A backend raises ``ValueError`` when it
    does not support the scenario; the sweep engine's ``backend="auto"``
    selector (``SweepSpec(backends=("auto",))``) falls back gracefully.

    ``trace=True`` attaches a flight-recorder stream to ``result.trace``:
    the rich instrumented stream on the reference ours-mode loop, the
    canonical reconstruction (``flight.trace_from_result``) everywhere
    else -- same schema, directly comparable."""
    be = get_backend(backend)
    if not be.supports(mode=mode, policy=policy, warm=warm):
        raise ValueError(
            f"backend {be.name!r} does not support mode={mode!r} "
            f"policy={policy!r} warm={warm!r}; use backend='reference' "
            f"or backend='auto' in the sweep engine")
    if trace and be.supports(mode=mode, policy=policy, warm=warm, trace=True):
        return be.simulate(requests, cores, policy=policy, mode=mode,
                           memory_mb=memory_mb, container_mb=container_mb,
                           warm=warm, kappa=kappa, trace=True)
    res = be.simulate(requests, cores, policy=policy, mode=mode,
                      memory_mb=memory_mb, container_mb=container_mb,
                      warm=warm, kappa=kappa)
    if trace:
        res.trace = trace_from_result(res, slots_per_node=cores,
                                      meta={"backend": be.name})
    return res
