"""Azure-Functions-style trace loading -> simulator workloads.

Production FaaS providers publish per-function invocation-rate traces (the
Azure Functions 2019 dataset is the canonical one: one row per function,
per-minute invocation counts).  This module loads that shape of CSV and
turns it into :class:`~repro.core.request.Request` streams the simulator and
sweep engine consume, so sweeps can replay production-shaped load instead of
only the paper's synthetic 60-second bursts.

Accepted CSV layout (header optional)::

    function,m0,m1,m2,...
    thumbnailer,12,40,9,...
    my-custom-fn,3,0,7,...

Function names that match a SeBS profile (Table I) keep their measured
processing-time distribution; unknown names are mapped deterministically
(CRC32) onto a SeBS profile so any trace can drive the calibrated simulator.
"""

from __future__ import annotations

import csv
import zlib
from pathlib import Path

import numpy as np

from .request import Request
from .workload import FUNCTIONS, PROFILES


def stable_hash(name: str) -> int:
    """Process-independent string hash (CRC32).  Python's builtin ``hash``
    is salted per interpreter, which would make trace->profile mapping and
    home-invoker routing differ between sweep workers and across runs."""
    return zlib.crc32(name.encode("utf-8"))


def profile_for(fn: str) -> str:
    """Map an arbitrary trace function name onto a SeBS profile name."""
    if fn in PROFILES:
        return fn
    return FUNCTIONS[stable_hash(fn) % len(FUNCTIONS)]


def load_azure_trace(path: str | Path) -> dict[str, list[int]]:
    """Parse an Azure-style ``(fn, invocations_per_minute...)`` CSV.

    Returns ``{function_name: [count_minute_0, count_minute_1, ...]}``.
    A header row (first data cell not an integer) is skipped automatically.
    """
    out: dict[str, list[int]] = {}
    with open(path, newline="") as fh:
        for i, row in enumerate(csv.reader(fh)):
            if not row or not row[0].strip():
                continue
            cells = [c.strip() for c in row]
            try:
                counts = [int(float(c)) for c in cells[1:]]
            except ValueError:
                if i == 0:
                    continue  # header row
                raise ValueError(
                    f"unparsable invocation counts for {cells[0]!r} "
                    f"(row {i + 1})") from None
            if any(c < 0 for c in counts):
                raise ValueError(f"negative invocation count for {cells[0]!r}")
            out[cells[0]] = counts
    if not out:
        raise ValueError(f"no trace rows parsed from {path}")
    return out


def tile_trace(trace: dict[str, list[int]], repeat: int = 1,
               scale: float = 1.0) -> dict[str, list[int]]:
    """Tile a per-minute trace ``repeat`` times end-to-end and scale its
    per-minute counts -- the minutes-scale vendored slice becomes an
    hours-scale stream (``repeat=8`` on the 15-minute Azure slice is two
    hours of load).  Counts are scaled deterministically
    (``round(count * scale)``), so the result is reproducible."""
    if repeat < 1:
        raise ValueError(f"repeat must be >= 1, got {repeat}")
    if scale <= 0:
        raise ValueError(f"scale must be > 0, got {scale}")
    out: dict[str, list[int]] = {}
    for fn, counts in trace.items():
        tiled = list(counts) * repeat
        if scale != 1.0:
            tiled = [int(round(c * scale)) for c in tiled]
        out[fn] = tiled
    return out


def requests_from_trace(
    trace: dict[str, list[int]],
    seed: int,
    minute_s: float = 60.0,
    max_minutes: int | None = None,
) -> list[Request]:
    """Expand per-minute invocation counts into a request stream.

    Each invocation arrives uniformly at random within its minute; the
    processing time is drawn from the (mapped) SeBS profile.  Iteration order
    is sorted by function name so the stream is deterministic for a seed."""
    rng = np.random.default_rng(seed)
    reqs: list[Request] = []
    for fn in sorted(trace):
        counts = trace[fn]
        if max_minutes is not None:
            counts = counts[:max_minutes]
        profile = PROFILES[profile_for(fn)]
        for minute, count in enumerate(counts):
            if count <= 0:
                continue
            times = rng.uniform(minute * minute_s, (minute + 1) * minute_s,
                                size=count)
            procs = profile.sample(rng, count)
            for t, p in zip(times, procs):
                reqs.append(Request(fn=fn, r=float(t),
                                    p_true=float(max(p, 1e-4))))
    reqs.sort(key=lambda r: r.r)
    return reqs


def generate_trace_requests(
    path: str | Path,
    seed: int = 0,
    minute_s: float = 60.0,
    max_minutes: int | None = None,
    repeat: int = 1,
    scale: float = 1.0,
) -> list[Request]:
    """Convenience: load an Azure-style CSV and expand it to requests.

    ``repeat``/``scale`` tile and scale the per-minute counts (see
    :func:`tile_trace`) *before* the ``max_minutes`` cut, so a repeated
    trace can still be truncated to a window."""
    trace = load_azure_trace(path)
    if repeat != 1 or scale != 1.0:
        trace = tile_trace(trace, repeat=repeat, scale=scale)
    return requests_from_trace(trace, seed,
                               minute_s=minute_s, max_minutes=max_minutes)


# ---------------------------------------------------------------------------
# lazy tiling: stream the tiled trace without materializing it
# ---------------------------------------------------------------------------
# tile_trace() + requests_from_trace() costs O(repeat * n) host memory twice
# over (the tiled count lists, then every Request).  The functions below
# generate the same per-minute expansion lazily, one minute at a time, with
# a per-(seed, minute) derived RNG so a minute's arrivals depend only on the
# minute's counts -- which makes the lazy tiled stream *bit-identical* to
# expanding a materialized tile_trace() result through the same per-minute
# rule (the parity tests pin this down).

def _scaled_count(count: int, scale: float) -> int:
    return int(round(count * scale)) if scale != 1.0 else count


def _minute_arrivals(trace: dict[str, list[int]], minute: int, seed: int,
                     minute_s: float, scale: float, fns: list[str],
                     src_minute: int):
    """Expand one tiled minute: time-sorted (r, fn_index, p_true) arrays.

    ``src_minute`` is the minute's index into the *source* trace (tiling is
    ``minute % len(counts)``); ``minute`` is the absolute output minute and
    seeds the RNG, so every tiled copy of a source minute draws fresh."""
    rng = np.random.default_rng([seed, minute])
    ts, fs, ps = [], [], []
    for fi, fn in enumerate(fns):
        counts = trace[fn]
        count = _scaled_count(counts[src_minute % len(counts)], scale)
        if count <= 0:
            continue
        ts.append(rng.uniform(minute * minute_s, (minute + 1) * minute_s,
                              size=count))
        fs.append(np.full(count, fi, dtype=np.int64))
        ps.append(np.maximum(
            PROFILES[profile_for(fn)].sample(rng, count), 1e-4))
    if not ts:
        z = np.zeros(0)
        return z, np.zeros(0, dtype=np.int64), z
    t = np.concatenate(ts)
    order = np.argsort(t, kind="stable")
    return (t[order], np.concatenate(fs)[order], np.concatenate(ps)[order])


def iter_tiled_chunks(trace: dict[str, list[int]], seed: int = 0,
                      repeat: int = 1, scale: float = 1.0,
                      minute_s: float = 60.0):
    """Lazily yield the tiled trace as time-ordered
    :class:`~repro.core.streamscan.StreamChunk` slabs, one per minute --
    O(one minute) host memory regardless of ``repeat``, in place of
    ``tile_trace`` + ``requests_from_trace``'s O(repeat * n)."""
    from .streamscan import StreamChunk
    if repeat < 1:
        raise ValueError(f"repeat must be >= 1, got {repeat}")
    if scale <= 0:
        raise ValueError(f"scale must be > 0, got {scale}")
    fns = sorted(trace)
    n_min = max(len(c) for c in trace.values())
    for minute in range(repeat * n_min):
        t, f, p = _minute_arrivals(trace, minute, seed, minute_s, scale,
                                   fns, minute % n_min)
        if t.size:
            yield StreamChunk(r=t, fn=f, p=p)


def tiled_stream(trace: dict[str, list[int]], seed: int = 0, repeat: int = 1,
                 scale: float = 1.0, minute_s: float = 60.0):
    """The lazy tiled trace as a re-playable
    :class:`~repro.core.streamscan.ArrivalStream`."""
    from .streamscan import ArrivalStream
    return ArrivalStream(
        fns=tuple(sorted(trace)),
        chunks=lambda: iter_tiled_chunks(trace, seed=seed, repeat=repeat,
                                         scale=scale, minute_s=minute_s))


def tiled_requests_materialized(trace: dict[str, list[int]], seed: int = 0,
                                repeat: int = 1, scale: float = 1.0,
                                minute_s: float = 60.0) -> list[Request]:
    """The materialized path the lazy iterator must match: tile the whole
    trace up front with :func:`tile_trace` (O(repeat * n)), then expand it
    through the same per-minute rule.  Exists as the parity oracle for
    :func:`iter_tiled_chunks` and for callers that genuinely need a
    request list."""
    tiled = tile_trace(trace, repeat=repeat, scale=scale)
    fns = sorted(tiled)
    n_min = max(len(c) for c in tiled.values())
    reqs: list[Request] = []
    for minute in range(n_min):
        t, f, p = _minute_arrivals(tiled, minute, seed, minute_s, 1.0,
                                   fns, minute)
        reqs.extend(Request(fn=fns[fi], r=float(ti), p_true=float(pi))
                    for ti, fi, pi in zip(t, f, p))
    return reqs
