"""Heterogeneity & straggler mitigation (beyond-paper subsystem).

The paper's method assumes identical invokers; its weakest regime is a fleet
where one node is degraded -- a single slow machine turns short calls into
tail catastrophes, exactly where per-core late binding (Kaffes et al.) and
pull-based scheduling (Hiku) claim robustness.  This module makes that
regime a first-class, *declarative* scenario consumed by both engines:

* :class:`NodeSpeedProfile` -- per-node static speed multipliers plus
  time-windowed degradation episodes ("node 2 runs 4x slow from t=100 to
  t=300").  A node's *effective speed* is sampled at dispatch time and
  scales both the management-channel cost and the execution time; the
  reference :class:`~repro.core.cluster.Cluster` consults it through the
  node's ``speed_fn``, the scan kernel through per-node speed tensors and a
  padded episode table evaluated inside the scan step.
* :class:`HedgingSpec` -- estimate-multiple straggler deadlines
  (generalizing the old boolean ``ClusterConfig.backup_requests``): a call
  still *queued* past ``multiple x max(E[p], floor_s)`` is either **stolen**
  (cancelled on its slow node, re-submitted to the least-loaded peer -- the
  non-preemptive-safe default) or **duplicated** (a backup copy races the
  original; first completion wins).  Both engines report
  ``backups_issued`` / ``steals_won`` with accounting parity; the scan
  kernel models both modes (duplicate racing carries a copy axis in the
  queue state), with one value-dependent rejection -- duplicate mode x
  failure schedules x push assignment stays on the reference loop.
* :func:`rolling_restart` -- a multi-failure helper: staggered per-node
  kills for availability sweeps (``SweepCell.fail_spec``).

Pure data + arithmetic: no simulator imports, so both engines (and the
sweep layer) can depend on it without cycles.
"""

from __future__ import annotations

import math

from dataclasses import dataclass

# (node index, window start, window end, slowdown factor >= strictly 0)
Episode = tuple[int, float, float, float]


@dataclass(frozen=True)
class NodeSpeedProfile:
    """Per-node speed model: static multipliers + degradation episodes.

    ``speeds[i]`` is node ``i``'s base speed multiplier (1.0 = nominal,
    0.25 = a machine running at quarter speed); nodes beyond the tuple --
    including autoscaler-provisioned ones -- run at 1.0.  ``episodes`` are
    ``(node, t0, t1, slowdown)`` windows: during ``[t0, t1)`` the node's
    effective speed is ``base / slowdown`` (slowdown 4.0 = "runs 4x slow").
    Episodes of one node must not overlap; the effective speed is sampled
    at *dispatch time* and fixed for the call (non-preemptive execution
    never changes rate mid-run, matching the reference node model).
    """

    speeds: tuple[float, ...] = ()
    episodes: tuple[Episode, ...] = ()

    def __post_init__(self) -> None:
        object.__setattr__(self, "speeds",
                           tuple(float(s) for s in self.speeds))
        object.__setattr__(self, "episodes",
                           tuple((int(n), float(t0), float(t1), float(f))
                                 for n, t0, t1, f in self.episodes))
        for s in self.speeds:
            if not (s > 0.0 and math.isfinite(s)):
                raise ValueError(f"node speed must be finite > 0, got {s}")
        per_node: dict[int, list[tuple[float, float]]] = {}
        for n, t0, t1, f in self.episodes:
            if n < 0:
                raise ValueError(f"episode node index must be >= 0, got {n}")
            if not (t1 > t0):
                raise ValueError(f"episode window must satisfy t1 > t0, "
                                 f"got [{t0}, {t1})")
            if not (f > 0.0 and math.isfinite(f)):
                raise ValueError(f"episode slowdown must be finite > 0, "
                                 f"got {f}")
            per_node.setdefault(n, []).append((t0, t1))
        for n, wins in per_node.items():
            wins.sort()
            for (a0, a1), (b0, b1) in zip(wins, wins[1:]):
                if b0 < a1:
                    raise ValueError(
                        f"episodes of node {n} overlap: "
                        f"[{a0}, {a1}) and [{b0}, {b1})")

    # -- construction --------------------------------------------------------
    @classmethod
    def from_any(cls, node_speeds=None,
                 degrade=None) -> "NodeSpeedProfile | None":
        """Build a profile from loose inputs: ``node_speeds`` may be a
        ``{node: speed}`` dict (the legacy ``ClusterConfig.node_speeds``
        shape) or a per-node sequence; ``degrade`` an episode sequence.
        Returns ``None`` when the result would be uniform (no profile)."""
        speeds: tuple[float, ...] = ()
        if isinstance(node_speeds, dict):
            if node_speeds:
                n = max(node_speeds) + 1
                speeds = tuple(float(node_speeds.get(i, 1.0))
                               for i in range(n))
        elif node_speeds:
            speeds = tuple(float(s) for s in node_speeds)
        prof = cls(speeds=speeds,
                   episodes=tuple(tuple(e) for e in (degrade or ())))
        return prof if not prof.is_uniform else None

    # -- queries -------------------------------------------------------------
    @property
    def is_uniform(self) -> bool:
        """True when every node runs at nominal speed the whole time."""
        return not self.episodes and all(s == 1.0 for s in self.speeds)

    def base_speed(self, node: int) -> float:
        return self.speeds[node] if node < len(self.speeds) else 1.0

    def slowdown_at(self, node: int, t: float) -> float:
        for n, t0, t1, f in self.episodes:
            if n == node and t0 <= t < t1:
                return f
        return 1.0

    def speed_at(self, node: int, t: float) -> float:
        """Effective speed of ``node`` at time ``t`` (dispatch-time rate)."""
        return self.base_speed(node) / self.slowdown_at(node, t)

    def max_slowdown(self) -> float:
        """Worst effective slowdown anywhere in the profile (1.0 = uniform);
        the 'degradation severity' axis of the straggler frontier plots."""
        worst = 1.0
        for i, s in enumerate(self.speeds):
            worst = max(worst, 1.0 / s)
            for n, _, _, f in self.episodes:
                if n == i:
                    worst = max(worst, f / s)
        for n, _, _, f in self.episodes:
            if n >= len(self.speeds):
                worst = max(worst, f)
        return worst

    # -- tensor form (scan kernel) -------------------------------------------
    def arrays(self, n_pad: int, ep_pad: int):
        """``(speeds, ep_node, ep_t0, ep_t1, ep_factor)`` numpy arrays padded
        to ``n_pad`` nodes / ``ep_pad`` episodes; padding episodes carry node
        ``-1`` (never matched by the kernel) and factor 1."""
        import numpy as np
        if len(self.episodes) > ep_pad:
            raise ValueError(f"{len(self.episodes)} episodes > pad {ep_pad}")
        spd = np.ones(n_pad, dtype=np.float64)
        spd[: len(self.speeds)] = self.speeds[:n_pad]
        epn = np.full(ep_pad, -1, dtype=np.int32)
        ept0 = np.zeros(ep_pad, dtype=np.float64)
        ept1 = np.zeros(ep_pad, dtype=np.float64)
        epf = np.ones(ep_pad, dtype=np.float64)
        for i, (n, t0, t1, f) in enumerate(self.episodes):
            epn[i], ept0[i], ept1[i], epf[i] = n, t0, t1, f
        return spd, epn, ept0, ept1, epf


HEDGE_MODES = ("steal", "duplicate")


@dataclass(frozen=True)
class HedgingSpec:
    """Estimate-driven straggler hedging (generalizes the reference's
    boolean ``backup_requests``).

    A watch armed at controller receive fires at
    ``now + multiple x max(E[p], floor_s)`` (controller-side last-10
    estimate); a call still queued on its node past the deadline is hedged,
    at most ``max_backups`` times:

    * ``mode="steal"`` -- cancel on the slow node, re-submit to the
      least-loaded peer (never duplicates running work; safe under
      non-preemptive execution).  Scan-kernel eligible.
    * ``mode="duplicate"`` -- leave the original queued and race a backup
      copy on the least-loaded peer; the first completion wins (the loser's
      work is wasted -- classic request hedging).  Reference engine only.

    Hedging only ever acts on *queued* calls, so under the pull model --
    where a call is late-bound and dispatched the moment a slot frees -- it
    is a structural no-op (``backups_issued == 0``): pull's global queue is
    already the robustness mechanism hedging retrofits onto push.
    """

    multiple: float = 3.0
    floor_s: float = 0.5
    max_backups: int = 3
    mode: str = "steal"

    def __post_init__(self) -> None:
        if not (self.multiple > 0):
            raise ValueError(f"hedge multiple must be > 0, got {self.multiple}")
        if self.floor_s < 0:
            raise ValueError(f"hedge floor must be >= 0, got {self.floor_s}")
        if self.max_backups < 0:
            raise ValueError(f"max_backups must be >= 0, "
                             f"got {self.max_backups}")
        if self.mode not in HEDGE_MODES:
            raise ValueError(f"unknown hedge mode {self.mode!r}; "
                             f"available: {HEDGE_MODES}")

    def deadline(self, now: float, estimate: float) -> float:
        """When the watch armed at ``now`` fires."""
        return now + self.multiple * max(estimate, self.floor_s)


def rolling_restart(node_count: int, start: float = 30.0,
                    every: float = 30.0) -> tuple[tuple[int, float], ...]:
    """Staggered kill schedule for availability sweeps: node ``i`` goes down
    at ``start + i * every`` -- the shape of a rolling fleet restart.  Kills
    are permanent in this model, so either roll through fewer nodes than the
    fleet holds or pair it with the autoscaler to re-provision capacity
    (``SweepCell(fail_spec=rolling_restart(2), autoscale=True)``)."""
    if node_count < 1:
        raise ValueError(f"node_count must be >= 1, got {node_count}")
    if every < 0 or start < 0:
        raise ValueError("start/every must be >= 0")
    return tuple((i, start + i * every) for i in range(node_count))
