"""Streaming chunked-scan replay: bounded-memory request tensors with an
explicit carry handoff across chunk boundaries.

Every scan-backend entry point in :mod:`repro.core.fastpath` pads the *whole*
request stream into one device tensor, so trace length bounds device memory.
This module splits a long arrival stream into bounded chunks and threads the
full kernel carry -- slots, queues, estimator rings, FC count rings,
container counts, resilience/hedge watch slots -- across chunk boundaries:

* the kernel runs with ``stream=True`` (the ``stream`` carry segment), which
  gates every step on a per-chunk horizon ``t_stop`` and reads its pull
  queues through chunk-local CSR event lists instead of the dense
  per-function table;
* at each boundary the final carry planes come back to the host, every
  request still in flight (running, queued, pending re-arrival / retry
  backoff / hedge watch) is re-materialized into the next chunk's row space
  -- priorities, push-sequence (``qseq``/``qsq``) and dispatch-sequence
  (``dseq``) carries intact -- and everything else (clocks, rings, counters)
  is copied verbatim;
* precomputed static-stream features become chunk-local with cross-chunk
  prefix state: FC pull window counts stay a cumulative-count +
  ``searchsorted`` difference because every arrival still inside the sliding
  window is re-materialized as an inert *history row*, and the RECT
  previous-arrival feature needs nothing at all (the kernel carries
  ``last_t``/``prev_t``).

Peak device memory is O(chunk), independent of trace length, and the replay
is *event-for-event identical* to the single-shot scan: each chunk's first
event re-evaluates the same candidate stack the unchunked kernel would, so
boundary ties resolve with identical precedence, exact counters are
bit-identical and clocks agree to the documented cross-check tolerance
(bitwise, in practice, since every event computes from identical state).
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Callable, Iterable, Iterator

import numpy as np

from .estimator import DEFAULT_FC_HORIZON, DEFAULT_WINDOW
from .fastpath import (
    CLUSTER_CONTAINER_MB,
    CLUSTER_MEMORY_MB,
    POLICY_NAMES,
    _POLICY_COEF,
    _PULL_COEF,
    _PULL_COEF_DYN,
    _alloc_bucket_inputs,
    _bucket_bytes,
    _carry_layout,
    _cold_regime_ok,
    _feature_mask,
    _mask_features,
    _pow2,
    _scan_runner,
    _use64,
    _x64_ctx,
)
from .simulator import (
    OURS_BASE,
    OURS_SCALE,
    REQ_OVERHEAD_S,
    RESP_OVERHEAD_S,
    WEIGHT_CAP_S,
    container_weight,
)
from .workload import PROFILES, STRETCH_REFERENCE_S

__all__ = [
    "ArrivalStream",
    "StreamChunk",
    "StreamBudgetError",
    "StreamResult",
    "simulate_cluster_stream",
    "stream_from_requests",
    "stream_supported",
]


# ---------------------------------------------------------------------------
# stream protocol
# ---------------------------------------------------------------------------
@dataclass
class StreamChunk:
    """One slab of arrivals: client submit times (globally non-decreasing
    across the whole stream), function ids into the stream's fixed table,
    and true processing times."""

    r: np.ndarray
    fn: np.ndarray
    p: np.ndarray

    def __post_init__(self):
        self.r = np.asarray(self.r, dtype=np.float64)
        self.fn = np.asarray(self.fn, dtype=np.int64)
        self.p = np.asarray(self.p, dtype=np.float64)


@dataclass
class ArrivalStream:
    """A lazily-generated arrival stream: a fixed function-name table plus an
    iterable of :class:`StreamChunk` slabs in time order.  ``chunks`` may be
    a zero-arg factory returning a fresh iterator, which makes the stream
    re-playable (the memory-evidence runs replay the same stream twice)."""

    fns: tuple
    chunks: Iterable[StreamChunk] | Callable[[], Iterator[StreamChunk]]
    total: int | None = None

    def iter_chunks(self) -> Iterator[StreamChunk]:
        c = self.chunks
        return iter(c() if callable(c) else c)


def stream_from_requests(requests, chunk: int = 4096):
    """Wrap a materialized request list as an :class:`ArrivalStream`.

    Reproduces :func:`repro.core.fastpath._arrival_features` event ordering
    exactly (receive time ``r + REQ_OVERHEAD_S``, stable sort), so the
    returned ``order`` maps event index -> request index for cross-checking
    against the single-shot scan.  Returns ``(stream, order)``."""
    n = len(requests)
    r = np.array([q.r for q in requests], dtype=np.float64)
    order = np.argsort(r + REQ_OVERHEAD_S, kind="stable")
    fns = tuple(sorted({q.fn for q in requests}))
    fn_index = {f: i for i, f in enumerate(fns)}
    fn_ids = np.array([fn_index[requests[i].fn] for i in order],
                      dtype=np.int64)
    p = np.array([requests[i].p_true for i in order], dtype=np.float64)
    rs = r[order]

    def _gen():
        for lo in range(0, n, max(chunk, 1)):
            hi = min(lo + max(chunk, 1), n)
            yield StreamChunk(r=rs[lo:hi], fn=fn_ids[lo:hi], p=p[lo:hi])

    return ArrivalStream(fns=fns, chunks=_gen, total=n), order


class StreamBudgetError(RuntimeError):
    """A chunk failed to drain below its horizon even at the retry-doubled
    step budget cap -- a kernel budget bug, never a workload property."""


def stream_supported(
    *,
    policy: str = "fc",
    assignment: str = "pull",
    lb: str = "least_loaded",
    warm: bool = True,
    dynamics=None,
    profile=None,
    hedging=None,
    resilience=None,
) -> bool:
    """Flags-only eligibility for the chunked-stream path: the scan kernel's
    feature envelope (see :func:`~repro.core.fastpath.cluster_scan_eligible`)
    minus duplicate-mode hedging, whose racing-copy queue width has no
    incremental re-materialization (copies of one request span chunk
    boundaries asymmetrically), so those cells stay on the single-shot
    path."""
    if policy not in POLICY_NAMES:
        return False
    if assignment == "push":
        if lb not in ("least_loaded", "home"):
            return False
    elif assignment != "pull":
        return False
    dyn = dynamics is not None and not dynamics.is_static
    if resilience is not None and not resilience.is_null:
        if (assignment != "push" or not warm or dyn
                or hedging is not None
                or (profile is not None and not profile.is_uniform)):
            return False
    if hedging is not None:
        if hedging.mode != "steal":
            return False             # duplicate racing: single-shot only
        if assignment == "push" and lb != "least_loaded" and dyn:
            return False
    if dyn:
        if assignment == "push" and lb != "least_loaded":
            return False
    return True


# ---------------------------------------------------------------------------
# tie-safe rebatcher
# ---------------------------------------------------------------------------
def _batches(stream: ArrivalStream, hint):
    """Re-slice a stream into kernel batches of ~``hint`` events whose
    horizon ``t_stop`` falls strictly *between* event times: the cut point
    only ever lands where ``t[cut-1] < t[cut]``, so equal-time runs never
    straddle a boundary and the chunk horizon gate (``now >= t_stop``) can
    never split a tie the unchunked kernel would have resolved in one
    candidate-stack evaluation.  ``hint`` is either a fixed event count or
    a zero-arg callable sampled once per batch, which lets the driver
    shrink the fresh slice when carried rows already fill the compiled
    shape.  Yields ``(t, fn, p, t_stop, final)`` with ``t`` the invoker
    receive times (``r + REQ_OVERHEAD_S``)."""
    def _target() -> int:
        return max(int(hint() if callable(hint) else hint), 1)

    it = stream.iter_chunks()
    bt: list[np.ndarray] = []
    bf: list[np.ndarray] = []
    bp: list[np.ndarray] = []
    nbuf = 0
    done = False
    last_t = -np.inf
    target = _target()
    want = target
    while True:
        while not done and nbuf <= want:
            try:
                c = next(it)
            except StopIteration:
                done = True
                break
            if len(c.r) == 0:
                continue
            t = c.r + REQ_OVERHEAD_S
            if t[0] < last_t or np.any(np.diff(t) < 0):
                raise ValueError("stream arrival times must be sorted")
            last_t = float(t[-1])
            bt.append(t)
            bf.append(np.asarray(c.fn, dtype=np.int64))
            bp.append(c.p)
            nbuf += len(t)
        if nbuf == 0:
            return
        t = np.concatenate(bt)
        fn = np.concatenate(bf)
        p = np.concatenate(bp)
        if done:
            yield t, fn, p, np.inf, True
            return
        cut = min(target, nbuf - 1)
        while cut < nbuf and t[cut] == t[cut - 1]:
            cut += 1
        if cut >= nbuf:
            # the tie run reaches the buffer end: pull more before cutting
            bt, bf, bp = [t], [fn], [p]
            want = nbuf                  # force another pull
            continue
        yield t[:cut], fn[:cut], p[:cut], float(t[cut]), False
        bt, bf, bp = [t[cut:]], [fn[cut:]], [p[cut:]]
        nbuf -= cut
        target = _target()
        want = target


# ---------------------------------------------------------------------------
# numpy plane (un)packing -- the host side of _PlaneLayout
# ---------------------------------------------------------------------------
def _np_pack(layout, st: dict, fdt):
    clk = (np.concatenate([np.ravel(np.asarray(st[k], dtype=fdt))
                           for k, _, _, _ in layout.fparts])
           if layout.fparts else np.zeros(0, dtype=fdt))
    ctr = (np.concatenate([np.ravel(np.asarray(st[k])).astype(np.int32)
                           for k, _, _, _, _ in layout.iparts])
           if layout.iparts else np.zeros(0, dtype=np.int32))
    return clk, ctr


def _np_unpack(layout, clk: np.ndarray, ctr: np.ndarray) -> dict:
    st = {}
    for k, lo, hi, shape in layout.fparts:
        st[k] = np.array(clk[lo:hi]).reshape(shape)
    for k, lo, hi, shape, isbool in layout.iparts:
        v = np.array(ctr[lo:hi]).reshape(shape)
        st[k] = v.astype(bool) if isbool else v
    return st


# every carry entry indexed by *local request row* -- the handoff relocates
# these (defaults for fresh rows, old values scattered onto carried rows);
# everything else in the carry copies across the boundary verbatim
_PER_REQUEST_KEYS = (
    "pend", "fprio", "node_of", "coldq", "hedge_t", "att", "stolen", "qseq",
    "unhedge", "hedge_t2", "rearr", "rord", "xq", "rq_rt", "enq_t",
    "to_t", "rto", "eps", "ratt", "nfl", "fcz", "qsq",
)
_PRK_INF = frozenset({"hedge_t", "hedge_t2", "rearr", "to_t", "rto"})
_PRK_BOOL = frozenset({"pend", "coldq", "stolen", "unhedge", "xq", "nfl"})
_PRK_INT = frozenset({"node_of", "att", "qseq", "rord", "ratt", "fcz",
                      "qsq"})


# ---------------------------------------------------------------------------
# growable per-event accumulator (indexed by global event id)
# ---------------------------------------------------------------------------
class _Acc:
    __slots__ = ("n", "cap", "t", "fnid", "p", "cnt", "start", "finish",
                 "prio", "node", "att", "stolen", "cold", "fcz", "ratt")

    def __init__(self, cap: int = 1024):
        cap = max(int(cap), 16)
        self.n = 0
        self.cap = cap
        self.t = np.zeros(cap)
        self.fnid = np.zeros(cap, dtype=np.int64)
        self.p = np.zeros(cap)
        self.cnt = np.zeros(cap, dtype=np.int64)
        self.start = np.full(cap, np.nan)
        self.finish = np.full(cap, np.nan)
        self.prio = np.zeros(cap)
        self.node = np.zeros(cap, dtype=np.int64)
        self.att = np.zeros(cap, dtype=np.int64)
        self.stolen = np.zeros(cap, dtype=bool)
        self.cold = np.zeros(cap, dtype=bool)
        self.fcz = np.zeros(cap, dtype=np.int8)
        self.ratt = np.zeros(cap, dtype=np.int64)

    def grow(self, need: int) -> None:
        if need <= self.cap:
            return
        new = max(need, 2 * self.cap)
        for k in self.__slots__[2:]:
            old = getattr(self, k)
            arr = np.zeros(new, dtype=old.dtype)
            if old.dtype == np.float64 and k in ("start", "finish"):
                arr[:] = np.nan
            arr[: self.cap] = old
            setattr(self, k, arr)
        self.cap = new


# ---------------------------------------------------------------------------
# result container
# ---------------------------------------------------------------------------
@dataclass
class StreamResult:
    """Per-event outcome of a chunked replay, in global event order, plus the
    exact counters the single-shot scan reports.  ``failed`` is 0 for served
    events, 1 for resilience timeouts, 2 for sheds (those rows have NaN
    ``start``/``finish``/``resp``)."""

    fns: tuple
    t: np.ndarray
    fnid: np.ndarray
    p: np.ndarray
    start: np.ndarray
    finish: np.ndarray
    prio: np.ndarray
    node: np.ndarray
    attempts: np.ndarray
    cold: np.ndarray
    failed: np.ndarray
    resp: np.ndarray
    stretch: np.ndarray
    counters: dict
    nodes_used: int
    timeline: object | None
    n: int
    chunks: int
    peak_rows: int
    peak_bytes: int
    wall_s: float

    def summary(self) -> dict:
        ok = self.failed == 0
        resp = self.resp[ok]
        out = {
            "n": self.n,
            "served": int(ok.sum()),
            "chunks": self.chunks,
            "peak_rows": self.peak_rows,
            "peak_bytes": self.peak_bytes,
            "wall_s": self.wall_s,
            "rate": self.n / self.wall_s if self.wall_s > 0 else 0.0,
            "nodes_used": self.nodes_used,
        }
        if resp.size:
            out.update(mean_resp=float(resp.mean()),
                       p50=float(np.percentile(resp, 50)),
                       p99=float(np.percentile(resp, 99)),
                       mean_stretch=float(self.stretch[ok].mean()))
        out.update(self.counters)
        return out

    def write_back(self, requests, order) -> None:
        """Scatter per-event outcomes back onto ``requests`` with the exact
        :func:`~repro.core.fastpath._run_scan_cells` write-back semantics
        (``order`` from :func:`stream_from_requests`)."""
        for e, ridx in enumerate(np.asarray(order).tolist()):
            req = requests[ridx]
            req.node = f"node{int(self.node[e])}"
            req.r_prime = float(self.t[e])
            req.priority = float(self.prio[e])
            req.cold_start = bool(self.cold[e])
            if int(self.failed[e]):
                req.start = req.finish = req.c = None
                req.failed = ("timeout" if int(self.failed[e]) == 1
                              else "shed")
                req.attempts = max(int(self.ratt_minus_one(e)), 0)
                continue
            req.start = float(self.start[e])
            req.finish = float(self.finish[e])
            req.c = req.finish + RESP_OVERHEAD_S
            req.failed = None
            req.attempts = int(self.attempts[e])

    def ratt_minus_one(self, e: int) -> int:
        return int(self.attempts[e])

    def trace(self, order=None) -> "SimTrace":
        """Canonical flight-recorder stream (arrival/dispatch/complete/fail)
        reconstructed straight from the event-order output arrays — same
        schema as :func:`repro.core.flight.trace_from_result`, so a
        streamed replay is comparable event-for-event against a traced
        reference or single-shot scan run.  ``order`` (the permutation from
        ``stream_from_requests``) labels events with their original request
        index; without it the event position is the request id."""
        import math as _math

        from .flight import SimTrace, TraceEvent, _KIND_RANK

        ids = (np.asarray(order).tolist() if order is not None
               else list(range(self.n)))
        events = []
        for e in range(self.n):
            rid = int(ids[e])
            fn = self.fns[int(self.fnid[e])]
            att = max(int(self.attempts[e]), 0)
            events.append(TraceEvent(float(self.t[e]), "arrival", rid, -1,
                                     fn, 0))
            if int(self.failed[e]):
                cause = "timeout" if int(self.failed[e]) == 1 else "shed"
                events.append(TraceEvent(float("nan"), "fail", rid,
                                         int(self.node[e]), fn, att, cause))
                continue
            info = "cold" if bool(self.cold[e]) else ""
            events.append(TraceEvent(float(self.start[e]), "dispatch", rid,
                                     int(self.node[e]), fn, att, info))
            events.append(TraceEvent(float(self.finish[e]), "complete", rid,
                                     int(self.node[e]), fn, att))
        events.sort(key=lambda ev: (_math.inf if _math.isnan(ev.t) else ev.t,
                                    _KIND_RANK.get(ev.kind, 99), ev.req))
        return SimTrace(events=events, nodes=self.nodes_used,
                        meta={"backend": "streamscan", "chunks": self.chunks,
                              "canonical": True})


# ---------------------------------------------------------------------------
# the chunked replay driver
# ---------------------------------------------------------------------------
def _fn_tables(fns, nodes):
    """Per-function constants reused every chunk: channel cost (NaN for
    unprofiled names, resolved per-row from ``p``), the §V-A warm-seed
    median, and the home-routing hash."""
    from .traces import stable_hash

    nf = len(fns)
    cost = np.full(nf, np.nan)
    wseed = np.full(nf, 0.1)
    for i, f in enumerate(fns):
        if f in PROFILES:
            cost[i] = OURS_BASE + OURS_SCALE * container_weight(f, np.nan)
            wseed[i] = PROFILES[f].median_s
    home = np.array([stable_hash(f) for f in fns], dtype=np.int64) % max(
        nodes, 1)
    sref = np.array([STRETCH_REFERENCE_S.get(f) or np.nan for f in fns])
    return cost, wseed, home, sref


def _row_cost(fn_ids, p, fn_cost):
    c = fn_cost[fn_ids]
    unk = np.isnan(c)
    if unk.any():
        c = np.where(unk,
                     OURS_BASE + OURS_SCALE * np.minimum(p, WEIGHT_CAP_S),
                     c)
    return c


class _FcWindow:
    """Cross-chunk prefix state for the FC sliding-window features: every
    arrival still inside ``(t_stop - horizon, t_stop]`` with its function id
    and global event id.  Serves three consumers -- history rows for the
    pull-FC cumulative-count difference, per-arrival window counts for the
    freeze single-node static-FC ``cnt`` feature, and the running max window
    count that sizes the push-FC rings."""

    def __init__(self, horizon: float):
        self.horizon = horizon
        self.t = np.zeros(0)
        self.fn = np.zeros(0, dtype=np.int64)
        self.gid = np.zeros(0, dtype=np.int64)
        self.max_count = 0

    def counts(self, t, fn) -> np.ndarray:
        """#(fn, (t_i - horizon, t_i]] including the arrival itself, for a
        fresh batch, against buffer + batch (exactly the unchunked global
        count: anything older than the buffer is outside every window)."""
        out = np.zeros(len(t), dtype=np.int64)
        all_t = np.concatenate([self.t, t])
        all_fn = np.concatenate([self.fn, fn])
        tags = np.concatenate([np.full(len(self.t), -1),
                               np.arange(len(t))])
        for f in np.unique(fn):
            sel = all_fn == f
            tf = all_t[sel]
            tg = tags[sel]
            fresh = tg >= 0
            lo = np.searchsorted(tf, tf[fresh] - self.horizon, side="right")
            out[tg[fresh]] = np.arange(1, tf.size + 1)[fresh] - lo
        if out.size:
            self.max_count = max(self.max_count, int(out.max()))
        return out

    def push(self, t, fn, gid, t_stop: float) -> None:
        self.t = np.concatenate([self.t, t])
        self.fn = np.concatenate([self.fn, fn])
        self.gid = np.concatenate([self.gid, gid])
        if np.isfinite(t_stop):
            keep = self.t > t_stop - self.horizon
            self.t, self.fn = self.t[keep], self.fn[keep]
            self.gid = self.gid[keep]

    def hist(self, live_gids: np.ndarray):
        """Window arrivals *not* re-materialized as live rows: these become
        inert history rows (never dispatched, never queued) that keep the
        chunk-local cumulative counts window-complete."""
        if not self.gid.size:
            return self.t, self.fn, self.gid
        drop = np.isin(self.gid, live_gids)
        keep = ~drop
        return self.t[keep], self.fn[keep], self.gid[keep]


def simulate_cluster_stream(
    stream: ArrivalStream,
    *,
    nodes: int,
    cores_per_node: int = 18,
    policy: str = "fc",
    assignment: str = "pull",
    lb: str = "least_loaded",
    warm: bool = True,
    memory_mb: int = CLUSTER_MEMORY_MB,
    container_mb: int = CLUSTER_CONTAINER_MB,
    dynamics=None,
    profile=None,
    hedging=None,
    resilience=None,
    chunk: int = 8192,
    progress: Callable[[int, int, float], None] | None = None,
) -> StreamResult:
    """Replay an :class:`ArrivalStream` through the chunked scan kernel with
    O(chunk) peak device memory.  ``chunk`` is the padded-row budget per
    kernel launch: each batch's fresh slice is sized adaptively so carried
    backlog + history + fresh arrivals fill one compiled power-of-two row
    shape (see ``_fresh_target``).  Semantics are identical to
    :func:`~repro.core.fastpath.simulate_cluster_scan` on streams that fit
    both ways: exact counters bit-identical, clocks within the documented
    cross-check tolerance (bitwise in practice -- every event computes from
    identical carried state)."""
    import jax.numpy as jnp

    t_begin = time.perf_counter()
    if not stream_supported(policy=policy, assignment=assignment, lb=lb,
                            warm=warm, dynamics=dynamics, profile=profile,
                            hedging=hedging, resilience=resilience):
        raise ValueError(
            "chunked stream path requires the scan kernel's feature "
            f"envelope minus duplicate hedging (policy={policy!r}, "
            f"assignment={assignment!r}, lb={lb!r}, warm={warm}, "
            f"dynamics={dynamics!r}, hedging={hedging!r}, "
            f"resilience={resilience!r})")
    if not warm:
        # _cold_regime_ok reads only the distinct function count off the
        # request list -- the stream knows its table upfront
        class _F:
            __slots__ = ("fn",)

            def __init__(self, fn):
                self.fn = fn

        if not _cold_regime_ok([_F(f) for f in stream.fns],
                               cores_per_node, memory_mb, container_mb):
            raise ValueError(
                "warm=False stream outside the ample-memory prewarm regime")
    dyn = dynamics is not None and not dynamics.is_static
    het = profile is not None and not profile.is_uniform
    hedge = hedging is not None and assignment == "push"
    res = resilience is not None and not resilience.is_null
    cold = not warm
    freeze = assignment != "pull"
    use_fc = (not freeze) and policy == "fc"
    fc_push = (freeze and policy == "fc"
               and (nodes > 1 or dyn or hedge or res))
    fc_static = freeze and policy == "fc" and not fc_push
    node_cap = (dynamics.capacity_bound(nodes)
                if dynamics is not None else nodes)
    if dyn and dynamics.fail:
        failed = {idx for idx, _ in dynamics.fail}
        if (max(failed) >= nodes or len(failed) >= nodes or nodes < 2
                or any(at < 0 for _, at in dynamics.fail)):
            raise ValueError("failure schedule outside the scan envelope")
    if profile is not None and len(profile.speeds) > node_cap:
        raise ValueError("speed profile longer than the capacity bound")

    fns = tuple(stream.fns)
    nf = len(fns)
    nodes_b = _pow2(node_cap)
    slots_b = _pow2(cores_per_node)
    f_b = _pow2(max(nf, 1))
    window = DEFAULT_WINDOW
    n_ep = _pow2(max(1, len(profile.episodes))) if het else 1
    fc_mult = 1
    if hedge:
        fc_mult = 1 + int(hedging.max_backups)
    if res:
        fc_mult = max(fc_mult, int(resilience.max_attempts))
    mask = _feature_mask(freeze=freeze, use_fc=use_fc, fc_push=fc_push,
                         cold=cold, hedge=hedge, dup=False, het=het,
                         dyn=dyn, res=res, stream=True)
    flags = _mask_features(mask)
    use64 = _use64(flags)
    fdt = np.float64 if use64 else np.float32

    fn_cost, fn_wseed, fn_home, fn_sref = _fn_tables(fns, nodes)
    seed_n = min(cores_per_node, window)
    coef = np.zeros(5)
    if not freeze:
        coef[:5 if dyn else 4] = (_PULL_COEF_DYN[policy] if dyn
                                  else _PULL_COEF[policy])
    else:
        coef[:4] = _POLICY_COEF[policy]
    killt_spec = np.full(nodes_b, np.inf)
    dynp = np.zeros(5)
    if dyn:
        d = dynamics
        for idx, at in d.fail:
            killt_spec[idx] = min(killt_spec[idx], at)
        dynp[:] = (d.autoscale_interval_s, d.scale_up_queue_per_slot,
                   d.provision_delay_s, d.failure_detect_s,
                   1.0 if d.autoscale else 0.0)
    het_arrays = profile.arrays(nodes_b, n_ep) if het else None
    res_arrays = resilience.arrays() if res else None

    fcw = _FcWindow(DEFAULT_FC_HORIZON) if policy == "fc" else None
    acc = _Acc()
    n_b = 0
    fc_ring = 1
    xtra = 0
    layout = None
    layout_key = None
    peak_rows = 0
    peak_bytes = 0
    gid_next = 0
    chunks_run = 0
    prev = None                      # boundary handoff state
    final_st = None
    max_attempts_res = int(resilience.max_attempts) if res else 1

    row_budget = _pow2(max(int(chunk), 1))
    fresh_floor = max(row_budget // 8, 1)

    def _fresh_target() -> int:
        # Adaptive batching: ``chunk`` is a padded-row budget, not a fixed
        # fresh-event count.  Size the fresh slice so history + carried
        # live rows + fresh events together fill the current compiled row
        # shape instead of straddling the next power-of-two boundary --
        # under a steady backlog a fixed fresh count pays ~2x padding on
        # every chunk.  The floor keeps forward progress through bursts
        # whose carry alone exceeds the budget (the shape then grows
        # sticky, and the budget ratchets with it).
        budget = max(row_budget, n_b)
        carried = 0
        if prev is not None:
            carried += int(prev["live"].size)   # index array, not a mask
        if use_fc and fcw is not None:
            carried += int(fcw.gid.size)   # upper bound on history rows
        return max(budget - carried, fresh_floor)

    for bt, bfn, bp, t_stop, final in _batches(stream, _fresh_target):
        n_fresh = len(bt)
        fresh_gid = np.arange(gid_next, gid_next + n_fresh, dtype=np.int64)
        fresh_cnt = (fcw.counts(bt, bfn) if fcw is not None
                     else np.zeros(n_fresh, dtype=np.int64))

        # ---- merge rows: history + carried live + fresh, gid order -------
        if prev is not None:
            lv = prev["live"]
            c_gid = prev["gid"][lv]
            c_t, c_fn = prev["t"][lv], prev["fn"][lv]
            c_p, c_cost = prev["p"][lv], prev["cost"][lv]
            c_cnt = prev["cnt"][lv]
        else:
            c_gid = np.zeros(0, dtype=np.int64)
            c_t = c_p = c_cost = np.zeros(0)
            c_fn = np.zeros(0, dtype=np.int64)
            c_cnt = np.zeros(0, dtype=np.int64)
        if use_fc and fcw is not None:
            h_t, h_fn, h_gid = fcw.hist(c_gid)
        else:
            h_t = np.zeros(0)
            h_fn = h_gid = np.zeros(0, dtype=np.int64)
        acc.grow(gid_next + n_fresh)
        acc.t[fresh_gid] = bt
        acc.fnid[fresh_gid] = bfn
        acc.p[fresh_gid] = bp
        acc.cnt[fresh_gid] = fresh_cnt
        fresh_cost = _row_cost(bfn, bp, fn_cost)

        all_gid = np.concatenate([h_gid, c_gid, fresh_gid])
        morder = np.argsort(all_gid, kind="stable")
        row_gid_rows = all_gid[morder]
        row_t = np.concatenate([h_t, c_t, bt])[morder]
        row_fn = np.concatenate([h_fn, c_fn, bfn])[morder]
        row_p = np.concatenate([np.zeros(len(h_t)), c_p, bp])[morder]
        row_cost = np.concatenate(
            [np.zeros(len(h_t)), c_cost, fresh_cost])[morder]
        row_cnt = np.concatenate(
            [np.zeros(len(h_t), dtype=np.int64), c_cnt,
             fresh_cnt])[morder]
        kind = np.concatenate(
            [np.zeros(len(h_t), dtype=np.int8),
             np.ones(len(c_gid), dtype=np.int8),
             np.full(n_fresh, 2, dtype=np.int8)])[morder]
        n_rows = len(row_t)
        is_hist = kind == 0
        ai0 = int(len(h_t) + len(c_gid))   # hist+carried all precede fresh

        # ---- sticky shape growth ----------------------------------------
        n_b = max(n_b, _pow2(max(n_rows, 1)))
        if fc_push and fcw is not None:
            need_ring = _pow2(max(fcw.max_count, 1) * fc_mult)
            if need_ring > fc_ring:
                if prev is not None:
                    prev["st"] = _grow_fc_ring(prev["st"], need_ring)
                fc_ring = need_ring
        n1 = n_b + 1
        row_gid = np.full(n1, -1, dtype=np.int64)
        row_gid[:n_rows] = row_gid_rows
        hist_mask = np.zeros(n1, dtype=bool)
        hist_mask[:n_rows] = is_hist

        # ---- per-chunk step budget --------------------------------------
        need_x = 64
        if hedge:
            need_x += n_b
        if res:
            need_x += 2 * n_b
        if dyn:
            d = dynamics
            kills = len(d.fail)
            need_x += 2 * kills * (cores_per_node + 1) + kills
            if d.autoscale:
                t_lo = float(row_t[0]) if n_rows else 0.0
                if np.isfinite(t_stop):
                    span = t_stop - t_lo
                else:
                    drain = (float(np.sum(row_p[~is_hist]))
                             / max(node_cap * cores_per_node, 1))
                    span = ((float(row_t[n_rows - 1]) if n_rows else 0.0)
                            - t_lo + drain + 2 * d.autoscale_interval_s)
                ticks = int(math.ceil(
                    max(span, 0.0) / max(d.autoscale_interval_s, 1e-6))) + 4
                grow = max(0, node_cap - nodes)
                need_x += ticks + grow * (1 + cores_per_node)
        xtra = max(xtra, _pow2(need_x))

        shape_key = (mask, n_b, nodes_b, slots_b, f_b, 1, window, fc_ring,
                     n_ep, 1, xtra)
        peak_rows = max(peak_rows, n_b)

        # ---- fill inputs -------------------------------------------------
        inp = _alloc_bucket_inputs(shape_key, 1)
        inp["t"][0, :n_rows] = row_t
        inp["fnid"][0, :n_rows] = row_fn
        inp["p"][0, :n_rows] = row_p
        inp["cost"][0, :n_rows] = row_cost
        if fc_static:
            inp["cnt"][0, :n_rows] = row_cnt
        inp["coef"][0] = coef
        inp["cores"][0] = cores_per_node
        inp["nodes"][0] = nodes
        inp["t_stop"][0] = t_stop
        if freeze and lb == "home":
            inp["route"][0] = 1
            inp["home0"][0, :n_rows] = fn_home[row_fn]
        if warm and freeze:
            # pull cells never seed the estimator rings (the warm-seed
            # block in _run_scan_bucket is skipped by the pull `continue`)
            inp["ring0"][0, :, :nf, :seed_n] = fn_wseed[None, :, None]
            inp["rsum0"][0, :, :nf] = seed_n * fn_wseed
            inp["rlen0"][0, :, :nf] = seed_n
            inp["rpos0"][0, :, :nf] = seed_n % window
        if use_fc:
            onehot = np.zeros((n_rows, f_b), dtype=np.float32)
            onehot[np.arange(n_rows), row_fn] = 1.0
            inp["cumf"][0, 1:n_rows + 1] = np.cumsum(onehot, axis=0)
            inp["cumf"][0, n_rows + 1:] = inp["cumf"][0, n_rows]
        if not freeze:
            ent_fn, ent_row, qcnt0 = _csr_entries(
                prev, row_gid_rows, row_fn, kind, f_b)
            inp["fnev"][0, :len(ent_row)] = ent_row
            counts = np.bincount(ent_fn, minlength=f_b)
            inp["fnst"][0] = np.concatenate(
                ([0], np.cumsum(counts)))[:f_b]
        else:
            qcnt0 = None
        if dyn:
            inp["act0"][0, :nodes] = 0.0
            inp["killt"][0] = killt_spec
            inp["dynp"][0] = dynp
            inp["maxn"][0] = node_cap
            inp["nreq"][0] = (gid_next + n_fresh if final else 2 ** 30)
        if het:
            spd, epn, ept0, ept1, epf = het_arrays
            inp["spd"][0] = spd
            inp["epn"][0] = epn
            inp["ept0"][0] = ept0
            inp["ept1"][0] = ept1
            inp["epf"][0] = epf
        if hedge:
            inp["hmult"][0] = hedging.multiple
            inp["hfloor"][0] = hedging.floor_s
            inp["hmax"][0] = hedging.max_backups
        if res:
            t4, r6, a2 = res_arrays
            inp["rto_p"][0] = t4
            inp["rrt_p"][0] = r6
            inp["adm_p"][0] = a2
            inp["gseq"][0, :n_rows] = row_gid_rows

        # ---- layout + handoff planes ------------------------------------
        lkey = shape_key[:-1]
        if lkey != layout_key:
            import jax

            spec = {k: jax.ShapeDtypeStruct(v.shape[1:], v.dtype)
                    for k, v in _alloc_bucket_inputs(shape_key, 1).items()}
            with _x64_ctx(use64):
                layout = _carry_layout(
                    spec, n_nodes=nodes_b, n_slots=slots_b, window=window,
                    freeze=freeze, fc_push=fc_push, dyn=dyn, het=het,
                    hedge=hedge, cold=cold, dup=False, n_copies=1,
                    fc_ring=fc_ring, res=res, stream=True)
            layout_key = lkey
        planes0 = None
        if prev is not None:
            st0 = _handoff_state(
                prev, row_gid_rows, kind, n1, row_t, freeze=freeze,
                qcnt0=qcnt0, f_b=f_b, ai0=ai0, fdt=fdt)
            planes0 = _np_pack(layout, st0, fdt)

        # ---- dispatch with retry-doubled step budget --------------------
        attempt = 0
        while True:
            key = (mask, n_b, nodes_b, slots_b, f_b, 1, window, fc_ring,
                   n_ep, 1, xtra, 1)
            init_c, scan_c = _scan_runner(key)
            with _x64_ctx(use64):
                arrs = {k: jnp.asarray(v) for k, v in inp.items()}
                if planes0 is None:
                    clk0, ctr0 = init_c(arrs)
                    planes0 = (np.asarray(clk0)[0], np.asarray(ctr0)[0])
                clk = jnp.asarray(planes0[0][None])
                ctr = jnp.asarray(planes0[1][None])
                (clk_f, ctr_f), recs = scan_c(clk, ctr, arrs)
            st = _np_unpack(layout, np.asarray(clk_f)[0],
                            np.asarray(ctr_f)[0])
            if _chunk_drained(st, t_stop, ai0 + n_fresh, dyn=dyn,
                              hedge=hedge, res=res, freeze=freeze):
                break
            attempt += 1
            if attempt > 8:
                raise StreamBudgetError(
                    f"chunk {chunks_run} not drained at xtra={xtra} "
                    f"(n_rows={n_rows}, t_stop={t_stop})")
            xtra = _pow2(2 * xtra if xtra else n_b)
        peak_bytes = max(peak_bytes, _bucket_bytes(shape_key, 1))

        # ---- accumulate dispatch records (last-wins in step order) ------
        j_s = np.asarray(recs[0])[0]
        es_s = np.asarray(recs[1], dtype=np.float64)[0]
        fs_s = np.asarray(recs[2], dtype=np.float64)[0]
        pj_s = np.asarray(recs[3], dtype=np.float64)[0]
        kd_s = np.asarray(recs[4])[0]
        valid = j_s < n_b
        rows_v = j_s[valid]
        g = row_gid[rows_v]
        keep = g >= 0
        gi = g[keep]
        acc.start[gi] = es_s[valid][keep]
        acc.finish[gi] = fs_s[valid][keep]
        if not freeze:
            acc.prio[gi] = pj_s[valid][keep]
            acc.node[gi] = kd_s[valid][keep]

        # ---- per-row state snapshots (live rows re-snapshot next chunk) -
        snap = (row_gid >= 0) & ~hist_mask
        gs = row_gid[snap]
        if freeze:
            acc.prio[gs] = st["fprio"][snap]
            acc.node[gs] = st["node_of"][snap]
        if cold:
            acc.cold[gs] = st["coldq"][snap]
        if hedge:
            acc.att[gs] = st["att"][snap]
            acc.stolen[gs] = st["stolen"][snap]
        if res:
            acc.ratt[gs] = st["ratt"][snap]
            acc.fcz[gs] = np.where(st["nfl"][snap],
                                   st["fcz"][snap], 0).astype(np.int8)

        # ---- liveness extraction ----------------------------------------
        live_mask, q_fn, q_gid = _extract_live(
            st, row_gid, hist_mask, n_b, freeze=freeze, dyn=dyn, res=res,
            f_b=f_b, inp=inp)
        prev = {
            "st": st, "gid": row_gid, "live": np.nonzero(live_mask)[0],
            "t": _pad_to(row_t, n1, np.inf),
            "fn": _pad_to(row_fn, n1, 0),
            "p": _pad_to(row_p, n1, 0.0),
            "cost": _pad_to(row_cost, n1, 0.0),
            "cnt": _pad_to(row_cnt, n1, 0),
            "q_fn": q_fn, "q_gid": q_gid, "n1": n1,
        }
        if fcw is not None:
            fcw.push(bt, bfn, fresh_gid, t_stop)
        gid_next += n_fresh
        chunks_run += 1
        final_st = st
        if progress is not None:
            progress(chunks_run, gid_next, time.perf_counter() - t_begin)
        if final:
            break

    n = gid_next
    wall = time.perf_counter() - t_begin
    if final_st is None:
        empty = np.zeros(0)
        counters = {"failures": 0, "backups_issued": 0, "steals_won": 0,
                    "cold_starts": 0, "evictions": 0, "timed_out": 0,
                    "shed": 0, "retries_issued": 0, "wasted_work": 0.0,
                    "n_failed": 0}
        return StreamResult(
            fns=fns, t=empty, fnid=empty.astype(np.int64), p=empty,
            start=empty, finish=empty, prio=empty,
            node=empty.astype(np.int64), attempts=empty.astype(np.int64),
            cold=empty.astype(bool), failed=empty.astype(np.int8),
            resp=empty, stretch=empty, counters=counters, nodes_used=nodes,
            timeline=None, n=0, chunks=0, peak_rows=0, peak_bytes=0,
            wall_s=wall)

    st = final_st
    counters = {
        "failures": int(st.get("nfail", 0)),
        "backups_issued": int(st.get("nbk", 0)),
        "steals_won": int(acc.stolen[:n].sum()),
        "cold_starts": int(st.get("ncold", 0)),
        "evictions": int(st.get("nevt", 0)),
        "timed_out": int(st.get("nto", 0)),
        "shed": int(st.get("nsh", 0)),
        "retries_issued": int(st.get("nrt", 0)),
        "wasted_work": float(st.get("wst", 0.0)),
        "n_failed": int(acc.fcz[:n].astype(bool).sum()),
    }
    nodes_used = int(st["prov"]) if dyn else nodes
    timeline = None
    if dyn:
        from .cluster import CapacityTimeline

        timeline = CapacityTimeline(
            activate=[float(a) for a in st["act_t"][:nodes_used]],
            deactivate=[float(killt_spec[k]) if bool(st["dead"][k])
                        else float("inf") for k in range(nodes_used)])

    failed = acc.fcz[:n].copy()
    served = failed == 0
    start = np.where(served, acc.start[:n], np.nan)
    finish = np.where(served, acc.finish[:n], np.nan)
    resp = finish + RESP_OVERHEAD_S - (acc.t[:n] - REQ_OVERHEAD_S)
    ref = fn_sref[acc.fnid[:n]]
    denom = np.maximum(np.where(np.isnan(ref), acc.p[:n], ref), 1e-9)
    stretch = resp / denom
    attempts = acc.att[:n].copy()
    if res:
        attempts = np.maximum(acc.ratt[:n] - 1, 0)
    return StreamResult(
        fns=fns, t=acc.t[:n].copy(), fnid=acc.fnid[:n].copy(),
        p=acc.p[:n].copy(), start=start, finish=finish,
        prio=acc.prio[:n].copy(), node=acc.node[:n].copy(),
        attempts=attempts, cold=acc.cold[:n].copy(), failed=failed,
        resp=resp, stretch=stretch, counters=counters,
        nodes_used=nodes_used, timeline=timeline, n=n, chunks=chunks_run,
        peak_rows=peak_rows, peak_bytes=peak_bytes, wall_s=wall)


# ---------------------------------------------------------------------------
# handoff helpers
# ---------------------------------------------------------------------------
def _pad_to(a: np.ndarray, n1: int, fill) -> np.ndarray:
    out = np.full(n1, fill, dtype=a.dtype if a.dtype != np.float64
                  else np.float64)
    out[: len(a)] = a
    return out


def _grow_fc_ring(st: dict, new_ring: int) -> dict:
    """Grow the per-(node, fn) FC arrival-time rings in place on the host:
    gather each ring oldest-first in circular order, pad with -inf (outside
    every window), and rebase the write cursor to the old length.  The
    kernel's window count sums ``ring > now - horizon``, so entry *position*
    never matters -- only the multiset of times."""
    fcr, fcp = st["fcr"], st["fcp"]
    old = fcr.shape[-1]
    idx = (fcp[..., None] + np.arange(old)) % old
    ordered = np.take_along_axis(fcr, idx, axis=-1)
    grown = np.full(fcr.shape[:-1] + (new_ring,), -np.inf, dtype=fcr.dtype)
    grown[..., :old] = ordered
    st = dict(st)
    st["fcr"] = grown
    st["fcp"] = np.full_like(fcp, old)
    return st


def _csr_entries(prev, row_gid_rows, row_fn, kind, f_b):
    """Chunk-local CSR pull-queue lists: carried queued entries first (their
    old per-function order preserved -- the pull tie-break takes the *lowest
    row index*, and gid-sorted rows preserve relative order), then every
    fresh row in arrival (gid) order.  Returns ``(entry_fn, entry_row,
    qcnt0)`` with ``qcnt0`` the per-function carried-queued counts that
    pre-validate the head window."""
    pos_of = np.searchsorted(row_gid_rows, prev["q_gid"]) if prev is not None \
        else np.zeros(0, dtype=np.int64)
    if prev is not None and len(prev["q_gid"]):
        cq_fn = prev["q_fn"]
        cq_row = pos_of
        # rank within fn = old queue order; a stable per-fn counter
        rank_c = np.zeros(len(cq_fn), dtype=np.int64)
        seen: dict = {}
        for i, f in enumerate(cq_fn.tolist()):
            rank_c[i] = seen.get(f, 0)
            seen[f] = rank_c[i] + 1
    else:
        cq_fn = np.zeros(0, dtype=np.int64)
        cq_row = rank_c = np.zeros(0, dtype=np.int64)
    fresh_rows = np.nonzero(kind == 2)[0]
    fr_fn = row_fn[fresh_rows]
    ent_fn = np.concatenate([cq_fn, fr_fn])
    ent_row = np.concatenate([cq_row, fresh_rows]).astype(np.int32)
    grp = np.concatenate([np.zeros(len(cq_fn), dtype=np.int8),
                          np.ones(len(fr_fn), dtype=np.int8)])
    rank = np.concatenate([rank_c, fresh_rows])
    order = np.lexsort((rank, grp, ent_fn))
    qcnt0 = np.bincount(cq_fn, minlength=f_b).astype(np.int32)
    return ent_fn[order], ent_row[order], qcnt0


def _handoff_state(prev, row_gid_rows, kind, n1, row_t, *, freeze, qcnt0,
                   f_b, ai0, fdt) -> dict:
    """Build the next chunk's initial carry from the previous chunk's final
    one: per-request entries relocate (defaults for fresh rows, previous
    values scattered onto the carried rows' new positions), slot back-
    pointers are value-remapped, the arrival cursor rebases to the first
    fresh row, and everything else copies verbatim."""
    st_old = prev["st"]
    old_live = prev["live"]
    carried_new = np.searchsorted(row_gid_rows, prev["gid"][old_live])
    st = {}
    for k, v in st_old.items():
        if k in _PER_REQUEST_KEYS or k in ("ai", "head", "qcnt", "idx_s"):
            continue
        st[k] = v
    for k in _PER_REQUEST_KEYS:
        if k not in st_old:
            continue
        old = st_old[k]
        if k == "enq_t":
            new = _pad_to(row_t, n1, np.inf).astype(old.dtype)
        elif k in _PRK_INF:
            new = np.full(n1, np.inf, dtype=old.dtype)
        elif k in _PRK_BOOL:
            new = np.zeros(n1, dtype=bool)
        elif k in _PRK_INT:
            new = np.zeros(n1, dtype=old.dtype)
        else:
            new = np.zeros(n1, dtype=old.dtype)
        new[carried_new] = old[old_live]
        st[k] = new
    val_map = np.zeros(prev["n1"], dtype=np.int32)
    val_map[old_live] = carried_new.astype(np.int32)
    st["idx_s"] = val_map[st_old["idx_s"]]
    st["ai"] = np.int32(ai0)
    st["head"] = np.zeros(f_b, dtype=np.int32)
    if not freeze and "qcnt" in st_old:
        st["qcnt"] = qcnt0
    return st


def _extract_live(st, row_gid, hist_mask, n_b, *, freeze, dyn, res, f_b,
                  inp):
    """Rows still in flight at the chunk horizon: running (finite slot
    finish), queued (frozen ``pend`` / CSR head window), pull re-queues
    (``xq``), pending kill re-arrivals (finite ``rearr``) and retry
    backoffs (finite ``rto``).  Returns the mask plus the queued entries'
    (fn, gid) in queue order for the next chunk's CSR build."""
    n1 = len(row_gid)
    live = np.zeros(n1, dtype=bool)
    fin = st["fin_s"]
    run_rows = st["idx_s"][np.isfinite(fin)]
    live[run_rows] = True
    q_fn_list = []
    q_gid_list = []
    if freeze:
        live |= st["pend"][:n1]
    else:
        fnev = inp["fnev"][0]
        fnst = inp["fnst"][0]
        head = st["head"]
        qcnt = st["qcnt"]
        backlog = np.nonzero(qcnt - head > 0)[0]
        for f in backlog.tolist():
            rows = fnev[fnst[f] + head[f]: fnst[f] + qcnt[f]]
            rows = rows[rows < n_b]
            live[rows] = True
            q_fn_list.append(np.full(len(rows), f, dtype=np.int64))
            q_gid_list.append(row_gid[rows])
        if dyn:
            live |= st["xq"][:n1]
    if dyn:
        live |= np.isfinite(st["rearr"][:n1])
    if res:
        live |= np.isfinite(st["rto"][:n1])
    live &= row_gid >= 0
    live &= ~hist_mask
    q_fn = (np.concatenate(q_fn_list) if q_fn_list
            else np.zeros(0, dtype=np.int64))
    q_gid = (np.concatenate(q_gid_list) if q_gid_list
             else np.zeros(0, dtype=np.int64))
    return live, q_fn, q_gid


def _chunk_drained(st, t_stop, n_arr, *, dyn, hedge, res, freeze) -> bool:
    """True when the chunk processed every event strictly below its horizon:
    all fresh arrivals consumed and no pending event candidate (completion,
    kill, re-arrival, activation, autoscaler tick, hedge deadline, timeout,
    retry) earlier than ``t_stop``.  A shortfall means the step budget ran
    out mid-chunk -- the caller re-runs the same planes at a doubled
    budget."""
    if int(st["ai"]) < n_arr:
        return False
    cands = [float(st["fin_s"].min())]
    if dyn:
        cands.append(float(st["killq"].min()))
        cands.append(float(st["rearr"].min()))
        pend = st["act_pend"]
        if pend.any():
            cands.append(float(st["act_t"][pend].min()))
        cands.append(float(st["next_tick"]))
    if hedge:
        cands.append(float(st["hedge_t"].min()))
        if "hedge_t2" in st:
            cands.append(float(st["hedge_t2"].min()))
    if res:
        cands.append(float(st["to_t"].min()))
        cands.append(float(st["rto"].min()))
    nxt = min(cands)
    if np.isinf(t_stop):
        return bool(np.isinf(nxt))
    return bool(nxt >= t_stop)
