"""Multi-node FaaS infrastructure (paper §III, §VIII) + large-scale features.

Implements both OpenWhisk request-assignment models the paper discusses:

* **push** -- the controller (load balancer) assigns each call to an invoker
  at arrival; the decision cannot be reversed, and "if the invoker fails, the
  assigned requests are lost" (§III).  We optionally re-issue lost calls
  after a detection delay (client retry).
* **pull** -- the new OpenWhisk model [17]: calls wait in global per-function
  queues; an invoker with a free slot pulls the best head according to its
  *node-local* scheduling policy.  Failures lose only the running calls;
  queued calls are simply pulled by surviving nodes.  The paper's policies
  are orthogonal to this model and plug straight in (§III, last paragraph).

Large-scale extensions (beyond the paper, required for 1000+-node operation):

* **straggler mitigation** -- a call still *queued* past
  ``straggler_factor x max(E[p], floor)`` is stolen from its slow node and
  re-submitted to the least-loaded peer (estimate-driven work stealing;
  running calls are never duplicated -- non-preemptive by design).
  Estimates come from the same last-10 estimator the policies use.
* **elastic scaling** -- a queue-depth autoscaler provisions a node after
  ``provision_delay`` (the paper's "dozens of seconds", §I) and retires idle
  nodes.  The paper's point -- that good node-level scheduling needs *fewer*
  machines for the same tail latency -- is benchmarked in fig6/engine_bench.
"""

from __future__ import annotations

import math

from dataclasses import dataclass, field

from dataclasses import replace as _dc_replace

from .estimator import RuntimeEstimator
from .flight import FlightRecorder, trace_from_result
from .request import Request
from .resilience import ResilienceSpec
from .stragglers import HedgingSpec, NodeSpeedProfile
from .traces import stable_hash
from .simulator import (
    EventLoop,
    OursNodeSim,
    REQ_OVERHEAD_S,
    RESP_OVERHEAD_S,
    SimResult,
)


# ---------------------------------------------------------------------------
# time-varying capacity as a first-class object
# ---------------------------------------------------------------------------
@dataclass
class CapacityTimeline:
    """Per-node activation/deactivation intervals: node ``i`` serves requests
    during ``[activate[i], deactivate[i])``.

    This is the *realized* capacity of a run -- the initial fleet, every
    autoscaler provision (recorded at the moment the node comes up, i.e.
    after the provision delay) and every injected failure.  The reference
    :class:`Cluster` maintains one as it runs; the scan backend reconstructs
    the same object from its per-node activation tensors, so the two engines
    can be compared on *capacity* as well as on latency metrics."""

    activate: list[float] = field(default_factory=list)
    deactivate: list[float] = field(default_factory=list)

    @classmethod
    def static(cls, nodes: int,
               fail: tuple[tuple[int, float], ...] = ()) -> "CapacityTimeline":
        """A fixed fleet of ``nodes`` machines active from t=0, minus any
        scheduled ``(node, kill_time)`` failures."""
        tl = cls(activate=[0.0] * nodes, deactivate=[math.inf] * nodes)
        for idx, at in fail:
            tl.kill(idx, at)
        return tl

    @property
    def nodes_total(self) -> int:
        return len(self.activate)

    def add_node(self, at: float) -> int:
        """Record a node coming up at ``at``; returns its index."""
        self.activate.append(float(at))
        self.deactivate.append(math.inf)
        return len(self.activate) - 1

    def kill(self, idx: int, at: float) -> None:
        self.deactivate[idx] = min(self.deactivate[idx], float(at))

    def active_at(self, t: float) -> list[bool]:
        return [a <= t < d
                for a, d in zip(self.activate, self.deactivate)]

    def count_active(self, t: float) -> int:
        return sum(self.active_at(t))

    def arrays(self, n_pad: int):
        """``(activate, deactivate)`` float arrays padded to ``n_pad`` nodes
        with +inf activations (the scan kernel's never-provisioned value)."""
        import numpy as np
        act = np.full(n_pad, np.inf, dtype=np.float64)
        kill = np.full(n_pad, np.inf, dtype=np.float64)
        act[: self.nodes_total] = self.activate
        kill[: self.nodes_total] = self.deactivate
        return act, kill


@dataclass(frozen=True)
class ClusterDynamics:
    """Declarative capacity-dynamics of a cluster scenario: injected
    failures plus the autoscaler rule.  Both engines consume it -- the
    reference :class:`Cluster` turns it into scheduled events, the scan
    kernel into per-node activation tensors updated inside the scan step --
    so a sweep cell means the same thing on either backend."""

    fail: tuple[tuple[int, float], ...] = ()   # (node index, kill time)
    failure_detect_s: float = 1.0
    autoscale: bool = False
    autoscale_interval_s: float = 5.0
    scale_up_queue_per_slot: float = 4.0
    provision_delay_s: float = 30.0
    max_nodes: int = 64

    @property
    def is_static(self) -> bool:
        return not self.fail and not self.autoscale

    def capacity_bound(self, nodes: int) -> int:
        """Largest node count the scenario can ever reach (the scan kernel
        sizes its node axis with this; the autoscaler never schedules past
        ``max_nodes``)."""
        return max(nodes, self.max_nodes) if self.autoscale else nodes

    def initial_timeline(self, nodes: int) -> CapacityTimeline:
        return CapacityTimeline.static(nodes, fail=self.fail)


# ---------------------------------------------------------------------------
# routing decisions as pure functions
# ---------------------------------------------------------------------------
# The controller's load-balancing choices are kept as standalone functions of
# plain sequences so the scan backend (core/fastpath.py) replicates exactly
# these rules in array form inside its scan step; the Cluster methods below
# and the tests both call them, keeping the two implementations honest.

def least_loaded_index(loads) -> int:
    """Push balancer: the least-loaded node (busy + queued), first on ties."""
    best = 0
    for i, v in enumerate(loads):
        if v < loads[best]:
            best = i
    return best


def most_free_index(free_slots) -> int:
    """Pull dispatch: the invoker with the most free slots, first on ties."""
    best = 0
    for i, v in enumerate(free_slots):
        if v > free_slots[best]:
            best = i
    return best


def home_invoker_index(fn: str, free_slots) -> int:
    """OpenWhisk home invoker: CRC32 the action name, walk forward from the
    home node to the first one with a free slot, else stay home."""
    k = len(free_slots)
    start = stable_hash(fn) % k
    for step in range(k):
        cand = (start + step) % k
        if free_slots[cand] > 0:
            return cand
    return start


# ClusterDynamics is the single source of the dynamics defaults;
# ClusterConfig mirrors them below so the reference event loop and the scan
# kernel can never silently run different autoscaler parameters
_DYN_DEFAULTS = ClusterDynamics()


@dataclass
class ClusterConfig:
    nodes: int = 4
    cores_per_node: int = 18          # §VIII: 20-core VMs, 2 reserved
    policy: str = "fc"
    assignment: str = "pull"          # "pull" | "push"
    lb: str = "least_loaded"          # push balancer: round_robin|least_loaded|home
    memory_mb: int = 40 * 1024
    container_mb: int = 128
    # fault tolerance
    retry_on_failure: bool = True
    failure_detect_s: float = _DYN_DEFAULTS.failure_detect_s
    # stragglers: ``hedging`` is the full spec (multiple/floor/max/mode);
    # the three legacy knobs below survive as sugar -- ``backup_requests=True``
    # without a spec resolves to HedgingSpec(straggler_factor,
    # straggler_floor_s) in steal mode, the historical behavior
    hedging: HedgingSpec | None = None
    backup_requests: bool = False
    straggler_factor: float = 3.0
    straggler_floor_s: float = 0.5
    # heterogeneity: static speeds + degradation episodes; the legacy
    # ``node_speeds`` dict keeps working and folds into the profile
    speed_profile: NodeSpeedProfile | None = None
    # request-lifecycle resilience: timeouts / client retries / admission
    # control (see repro.core.resilience); None = infinitely patient clients
    resilience: ResilienceSpec | None = None
    # elasticity
    autoscale: bool = False
    autoscale_interval_s: float = _DYN_DEFAULTS.autoscale_interval_s
    scale_up_queue_per_slot: float = _DYN_DEFAULTS.scale_up_queue_per_slot
    provision_delay_s: float = _DYN_DEFAULTS.provision_delay_s
    max_nodes: int = _DYN_DEFAULTS.max_nodes
    node_speeds: dict[int, float] = field(default_factory=dict)


class Cluster:
    def __init__(self, cfg: ClusterConfig, warm_functions: list[str] | None = None,
                 trace: "FlightRecorder | None" = None):
        self.cfg = cfg
        self.loop = EventLoop()
        self.warm_functions = warm_functions
        # flight recorder (set before _add_node: nodes share the sink);
        # every emission site below is a single None-check when disabled
        self._flight = trace
        self.nodes: list[OursNodeSim] = []
        self.completed: dict[int, Request] = {}
        self.failures = 0
        self.backups_issued = 0
        self.steals_won = 0
        self._rr = 0
        self._expected = 0
        self._global_queue: list[Request] = []   # pull model
        self._estimator = RuntimeEstimator()     # controller-side (stragglers)
        self._watched: dict[int, Request] = {}
        # hedging spec: explicit > legacy boolean sugar > off
        self.hedging = cfg.hedging
        if self.hedging is None and cfg.backup_requests:
            self.hedging = HedgingSpec(multiple=cfg.straggler_factor,
                                       floor_s=cfg.straggler_floor_s)
        self._stolen_ids: set[int] = set()       # steal mode
        self._dup_copies: dict[int, Request] = {}  # duplicate mode: id -> copy
        # request-lifecycle resilience (timeouts / retries / shedding)
        self.res = ResilienceSpec.from_any(cfg.resilience)
        if self.res is not None and self.hedging is not None:
            # a hedge copy and a deadline watch would both re-dispatch the
            # same request id with conflicting completion semantics; the
            # combination is a documented exclusion, not a silent best-effort
            raise ValueError(
                "resilience (timeouts/retries/shedding) and straggler "
                "hedging cannot be combined on the same cluster")
        self.timed_out = 0
        self.shed = 0
        self.retries_issued = 0
        self.wasted_work = 0.0
        self._res_qep = 0.0                      # sum of queued E[p] snapshots
        self._res_eps: dict[int, float] = {}     # per queued call: its snapshot
        self._res_att: dict[int, int] = {}       # submissions per request id
        self._res_seq: dict[int, int] = {}       # stable arrival rank (jitter)
        self._to_tok: dict[int, int] = {}        # timeout-watch validity token
        self._res_failed = 0                     # permanently failed calls
        # heterogeneity: explicit profile > legacy node_speeds dict > uniform
        self.profile = cfg.speed_profile
        if self.profile is None and cfg.node_speeds:
            self.profile = NodeSpeedProfile.from_any(cfg.node_speeds)
        self.timeline = CapacityTimeline()       # realized capacity intervals
        self._provisioned = cfg.nodes            # incl. scheduled provisions
        for i in range(cfg.nodes):
            self._add_node()

    # ---------------------------------------------------------------- nodes
    def _add_node(self) -> OursNodeSim:
        idx = len(self.nodes)
        name = f"node{idx}"
        speed, speed_fn = 1.0, None
        if self.profile is not None:
            if self.profile.episodes:
                speed_fn = lambda t, i=idx: self.profile.speed_at(i, t)  # noqa: E731
            else:
                speed = self.profile.base_speed(idx)
        node = OursNodeSim(
            self.loop,
            cores=self.cfg.cores_per_node,
            policy=self.cfg.policy,
            memory_mb=self.cfg.memory_mb,
            container_mb=self.cfg.container_mb,
            name=name,
            speed=speed,
            speed_fn=speed_fn,
            warm_functions=self.warm_functions,
            on_complete=self._on_complete,
            on_start=self._on_start if self.res is not None else None,
            trace=self._flight,
            trace_node=idx,
        )
        self.nodes.append(node)
        self.timeline.add_node(self.loop.now)
        if self._flight is not None:
            self._flight.emit(self.loop.now, "node_up", node=idx)
        return node

    def _alive_nodes(self) -> list[OursNodeSim]:
        return [n for n in self.nodes if n.alive]

    # ---------------------------------------------------------------- submit
    def submit(self, req: Request) -> None:
        """Client issued the call at req.r; controller sees it a hop later."""
        self.loop.schedule(req.r + REQ_OVERHEAD_S, lambda: self._route(req))

    def _route(self, req: Request) -> None:
        if self._flight is not None:
            self._flight.emit(self.loop.now, "arrival", req=req.id,
                              fn=req.fn, attempt=req.attempts)
        self._estimator.observe_arrival(req.fn, self.loop.now)
        if self.hedging is not None:
            self._arm_straggler_watch(req)
        if self.res is not None and not self._res_admit(req):
            return                               # shed (maybe retried later)
        if self.cfg.assignment == "push":
            node = self._pick_node(req)
            node.submit(req)
        else:  # pull
            if self._flight is not None:         # global queue: node = -1
                self._flight.emit(self.loop.now, "enqueue", req=req.id,
                                  fn=req.fn, attempt=req.attempts)
            self._global_queue.append(req)
            self._pull_round()

    # ------------------------------------------------------------- resilience
    # The scan kernel's ``res`` carry segment mirrors this logic line for
    # line (same controller estimate, same accumulation order, the identical
    # integer-hash jitter), so the timed_out / shed / retries_issued counters
    # cross-check *exactly*.  Keep the two in sync.
    def _res_admit(self, req: Request) -> bool:
        """Admission + watch arming for an arriving or re-arriving call;
        returns False when the controller sheds it."""
        spec = self.res
        att = self._res_att.get(req.id, 0) + 1
        self._res_att[req.id] = att
        if spec.admission is not None:
            free = sum(n.free_slots for n in self._alive_nodes())
            if spec.admission.shed(self._res_qep, free):
                self.shed += 1
                if self._flight is not None:
                    self._flight.emit(self.loop.now, "shed", req=req.id,
                                      fn=req.fn, attempt=att)
                self._res_fail_or_retry(req, "shed", att)
                return False
        e = self._estimator.estimate(req.fn)
        self._res_eps[req.id] = e
        self._res_qep += e
        if spec.timeout is not None:
            tok = self._to_tok.get(req.id, 0) + 1
            self._to_tok[req.id] = tok
            deadline = spec.timeout.deadline(self.loop.now, e)
            self.loop.schedule(deadline,
                               lambda: self._maybe_timeout(req, tok))
        return True

    def _on_start(self, req: Request) -> None:
        """A call left its queue for a slot: drop its queued-E[p] snapshot."""
        e = self._res_eps.pop(req.id, None)
        if e is not None:
            self._res_qep -= e

    def _maybe_timeout(self, req: Request, tok: int) -> None:
        """Deadline watch fired.  Still queued -> cancel the call; running
        -> free the slot mid-execution and count the elapsed time as wasted
        work.  Either way the attempt is over: retry or fail permanently."""
        if self._to_tok.get(req.id) != tok or req.id in self.completed:
            return                               # stale watch / already done
        self._to_tok[req.id] = tok + 1           # consume the watch
        node = next((n for n in self.nodes
                     if n.name == req.node and n.alive), None)
        queued_cancel = running_cancel = False
        if node is not None and node.cancel_queued(req):
            queued_cancel = True
        elif (node is not None and req.start is not None
                and node.cancel_running(req)):
            running_cancel = True
            self.wasted_work += max(0.0, self.loop.now - req.start)
        elif req in self._global_queue:          # pull: not yet at any node
            self._global_queue.remove(req)
            queued_cancel = True
        if not (queued_cancel or running_cancel):
            return                               # raced with completion/kill
        if queued_cancel:
            self._on_start(req)                  # snapshot leaves the queue
        self.timed_out += 1
        if self._flight is not None:
            self._flight.emit(
                self.loop.now, "timeout", req=req.id, fn=req.fn,
                node=(node.trace_node if node is not None else -1),
                attempt=self._res_att[req.id],
                info="running" if running_cancel else "queued")
        self._res_fail_or_retry(req, "timeout", self._res_att[req.id])
        if running_cancel and self.cfg.assignment == "pull":
            self._pull_round()                   # the freed slot pulls

    def _res_fail_or_retry(self, req: Request, cause: str, att: int) -> None:
        """A submission ended in failure ``cause``: schedule the client's
        retry re-arrival (deterministic backoff + jitter) or give up."""
        rt = self.res.retry
        if rt is not None and rt.should_retry(cause, att):
            delay = rt.delay(self._res_seq.get(req.id, req.id), att)
            if self._flight is not None:
                self._flight.emit(self.loop.now, "retry", req=req.id,
                                  fn=req.fn, attempt=att,
                                  info=f"{cause} delay={delay:.4f}")
            self.retries_issued += 1
            req.attempts += 1
            req.r_prime = None
            req.start = None
            req.finish = None
            req.priority = None
            req.node = None
            req.cold_start = False
            self.loop.schedule(self.loop.now + delay,
                               lambda: self._route(req))
        else:
            req.failed = "lost" if cause == "kill" else cause
            self._res_failed += 1
            if self._flight is not None:
                self._flight.emit(self.loop.now, "fail", req=req.id,
                                  fn=req.fn, attempt=att, info=req.failed)

    # push-model load balancing ------------------------------------------------
    def _pick_node(self, req: Request) -> OursNodeSim:
        alive = self._alive_nodes()
        assert alive, "no alive nodes"
        if self.cfg.lb == "round_robin":
            self._rr = (self._rr + 1) % len(alive)
            return alive[self._rr]
        if self.cfg.lb == "home":
            # OpenWhisk-style home invoker (CRC32, not builtin hash():
            # per-interpreter salting would make sweep cells
            # non-deterministic across runs)
            return alive[home_invoker_index(
                req.fn, [n.free_slots for n in alive])]
        return alive[least_loaded_index([n.load for n in alive])]

    # pull model -----------------------------------------------------------------
    def _pull_round(self) -> None:
        """Invokers with free slots pull the globally best queued call, ranked
        by the cluster policy on controller-side history."""
        moved = True
        while moved and self._global_queue:
            moved = False
            free = [n for n in self._alive_nodes() if n.free_slots > 0]
            if not free:
                return
            # rank queue by the node policy (same formula, controller history)
            node = free[most_free_index([n.free_slots for n in free])]
            best_i = min(
                range(len(self._global_queue)),
                key=lambda i: node.scheduler.policy.priority(
                    self._global_queue[i], self._estimator, self.loop.now
                ),
            )
            req = self._global_queue.pop(best_i)
            node.submit(req)
            moved = True

    # completion ------------------------------------------------------------------
    def _on_complete(self, req: Request) -> None:
        prev = self.completed.get(req.id)
        if prev is None or (req.c is not None and req.c < prev.c):
            self.completed[req.id] = req
        self._estimator.observe_completion(req.fn, req.p_true)
        self._watched.pop(req.id, None)
        self._to_tok.pop(req.id, None)           # timeout watch is void
        if self.cfg.assignment == "pull":
            self._pull_round()

    # ------------------------------------------------------------- fault inject
    def fail_node(self, idx: int, at: float) -> None:
        """Schedule node ``idx`` to crash at time ``at``."""
        self.loop.schedule(at, lambda: self._do_fail(idx))

    def _do_fail(self, idx: int) -> None:
        node = self.nodes[idx]
        if not node.alive:
            return
        lost = node.kill()
        self.timeline.kill(idx, self.loop.now)
        if self._flight is not None:
            self._flight.emit(self.loop.now, "node_down", node=idx,
                              info=f"lost={len(lost)}")
        self.failures += len(lost)
        if self.res is not None:
            # kill-lost calls flow through the resilience retry path: void
            # their watches, drop still-queued E[p] snapshots, then apply
            # the retry policy (cause "kill") with backoff instead of the
            # plain failure-detection re-route below
            for req in lost:
                self._to_tok.pop(req.id, None)
                self._on_start(req)
                self._stolen_ids.discard(req.id)
                self._res_fail_or_retry(
                    req, "kill", self._res_att.get(req.id, 1))
            return
        if self.cfg.assignment == "pull":
            # queued work is recovered from the global queue semantics; the
            # running calls are re-queued after failure detection
            for req in lost:
                req.attempts += 1
                # a failure re-route voids any earlier hedge credit: if the
                # call completes now, the winning run is the failure retry,
                # not the steal (it may be stolen again and re-counted)
                self._stolen_ids.discard(req.id)
                self.loop.schedule(
                    self.loop.now + self.cfg.failure_detect_s,
                    lambda r=req: (self._global_queue.append(r), self._pull_round()),
                )
        elif self.cfg.retry_on_failure:
            for req in lost:
                req.attempts += 1
                self._stolen_ids.discard(req.id)
                self.loop.schedule(
                    self.loop.now + self.cfg.failure_detect_s,
                    lambda r=req: self._route(r),
                )

    # ------------------------------------------------------------- stragglers
    def _arm_straggler_watch(self, req: Request) -> None:
        deadline = self.hedging.deadline(self.loop.now,
                                         self._estimator.estimate(req.fn))
        if self._flight is not None:
            self._flight.emit(self.loop.now, "hedge_arm", req=req.id,
                              fn=req.fn, attempt=req.attempts,
                              info=f"deadline={deadline:.4f}")
        self._watched[req.id] = req
        self.loop.schedule(deadline, lambda: self._maybe_backup(req))

    def _maybe_backup(self, req: Request) -> None:
        """Straggler mitigation on a hedging deadline.  ``mode="steal"``
        (default): a call still queued past its deadline is cancelled on its
        (slow/overloaded) node and re-submitted to the least-loaded peer.
        Executing calls are left alone -- the system is non-preemptive by
        design (paper §IV-A), and duplicating running work floods healthy
        nodes under overload.  ``mode="duplicate"``: the original stays
        queued and a backup copy races it on the least-loaded peer; the
        first completion wins (``_on_complete`` keeps the min-c run)."""
        h = self.hedging
        if req.id not in self._watched or req.id in self.completed:
            return
        if req.start is not None or req.attempts >= h.max_backups:
            return                                  # already executing
        node = next((n for n in self.nodes
                     if n.name == req.node and n.alive), None)
        if node is None:
            return                                  # still globally queued
        if h.mode == "steal":
            if not node.scheduler.cancel(req):
                return                              # gone or about to run
            others = [n for n in self._alive_nodes() if n is not node]
            target = min(others, key=lambda n: n.load) if others else node
            req.attempts += 1
            self.backups_issued += 1
            self._stolen_ids.add(req.id)
            if self._flight is not None:
                self._flight.emit(self.loop.now, "steal", req=req.id,
                                  fn=req.fn, node=target.trace_node,
                                  attempt=req.attempts,
                                  info=f"from=node{node.trace_node}")
            target.submit(req)
        else:                                       # duplicate
            others = [n for n in self._alive_nodes() if n is not node]
            if not others:
                return                              # nowhere to race
            target = min(others, key=lambda n: n.load)
            dup = _dc_replace(req, r_prime=None, start=None, finish=None,
                              c=None, priority=None, node=None,
                              cold_start=False, attempts=req.attempts + 1,
                              is_backup=True)
            req.attempts += 1
            self.backups_issued += 1
            self._dup_copies[req.id] = dup
            if self._flight is not None:
                self._flight.emit(self.loop.now, "duplicate", req=req.id,
                                  fn=req.fn, node=target.trace_node,
                                  attempt=dup.attempts,
                                  info=f"from=node{node.trace_node}")
            target.submit(dup)
        self._arm_straggler_watch(req)              # keep watching

    # ------------------------------------------------------------- autoscaler
    def _autoscale_tick(self) -> None:
        if len(self.completed) + self._res_failed >= self._expected:
            return                        # burst drained: stop ticking
        alive = self._alive_nodes()
        queued = len(self._global_queue) + sum(n.scheduler.queued for n in alive)
        slots = sum(n.scheduler.slots for n in alive)
        if self._flight is not None:
            self._flight.emit(self.loop.now, "autoscale_tick",
                              info=f"queued={queued} slots={slots} "
                                   f"provisioned={self._provisioned}")
        if (
            queued > self.cfg.scale_up_queue_per_slot * max(slots, 1)
            and self._provisioned < self.cfg.max_nodes
        ):
            # pending provisions count toward the cap: with a provision delay
            # of several tick intervals, counting only *added* nodes would let
            # a sustained backlog overshoot max_nodes before the first new
            # node ever comes up
            self._provisioned += 1
            self.loop.schedule(
                self.loop.now + self.cfg.provision_delay_s,
                lambda: (self._add_node(), self._pull_round()),
            )
        self.loop.schedule(
            self.loop.now + self.cfg.autoscale_interval_s, self._autoscale_tick
        )

    # ------------------------------------------------------------------- run
    def run(self, requests: list[Request], until: float | None = None) -> SimResult:
        self._expected = len(requests)
        if self.res is not None:
            # stable arrival rank = the retry-jitter sequence number; the
            # scan kernel's event index is the same stable sort by r, so
            # both engines hash identical (seq, attempt) pairs
            order = sorted(range(len(requests)), key=lambda i: requests[i].r)
            self._res_seq = {requests[i].id: rank
                             for rank, i in enumerate(order)}
        for req in requests:
            self.submit(req)
        if self.cfg.autoscale:
            self.loop.schedule(self.cfg.autoscale_interval_s, self._autoscale_tick)
        self.loop.run(until=until)
        done = [r for r in requests if self.completed.get(r.id) is not None]
        if self.res is not None:
            # resilience runs report every decided call: completions plus
            # terminal failures (timed out / shed / lost), so downstream
            # metrics can see the failed population, not just survivors
            done = done + [r for r in requests if r.failed is not None
                           and self.completed.get(r.id) is None]
        for r in requests:  # propagate winner's completion onto the original
            w = self.completed.get(r.id)
            if w is not None and r.c is None:
                r.c = w.c
                r.finish = w.finish
                r.start = w.start if r.start is None else r.start
            elif w is not None and w is not r and w.c is not None:
                # duplicate-mode: the original also ran to completion, but
                # the racing backup copy won (completed keeps the min-c run)
                # -- the client saw the winner's response, so report it
                if r.c is None or w.c < r.c:
                    r.c = w.c
                    r.finish = w.finish
                    r.start = w.start
                    r.node = w.node
        cold = sum(getattr(n.scheduler.pool, "cold_starts", 0) for n in self.nodes)
        # steals_won: hedged calls whose *winning* run was the hedge action --
        # in steal mode every completed stolen call won (the original queue
        # entry was cancelled), in duplicate mode the backup copy must have
        # beaten the original to completion
        self.steals_won = sum(
            1 for rid in self._stolen_ids if rid in self.completed)
        self.steals_won += sum(
            1 for rid in self._dup_copies
            if getattr(self.completed.get(rid), "is_backup", False))
        trace = None
        if self._flight is not None:
            for rid in self._dup_copies:
                w = self.completed.get(rid)
                if getattr(w, "is_backup", False):
                    self._flight.emit(w.finish, "dup_win", req=rid, fn=w.fn,
                                      node=w.node, attempt=w.attempts)
            trace = self._flight.to_trace(
                nodes=len(self.nodes),
                slots_per_node=self.cfg.cores_per_node,
                meta={"policy": self.cfg.policy,
                      "assignment": self.cfg.assignment,
                      "backend": "reference"})
        return SimResult(
            requests=done,
            cold_starts=cold,
            evictions=sum(n.scheduler.pool.evictions for n in self.nodes),
            creations=sum(n.scheduler.pool.creations for n in self.nodes),
            failures=self.failures,
            backups_issued=self.backups_issued,
            steals_won=self.steals_won,
            nodes_used=len(self.nodes),
            timed_out=self.timed_out,
            shed=self.shed,
            retries_issued=self.retries_issued,
            wasted_work=self.wasted_work,
            timeline=self.timeline,
            trace=trace,
            meta={"policy": self.cfg.policy, "assignment": self.cfg.assignment},
        )


# ClusterConfig knobs that define capacity dynamics; simulate_cluster keeps
# a cell scan-eligible when only these (plus lb/memory sizing) are customized
_DYNAMICS_KWARGS = ("autoscale", "autoscale_interval_s",
                    "scale_up_queue_per_slot", "provision_delay_s",
                    "max_nodes", "failure_detect_s")


def _dynamics_from_kwargs(kwargs: dict, fail_at: float | None,
                          fail_spec=()) -> ClusterDynamics:
    defaults = ClusterConfig()
    vals = {k: kwargs.get(k, getattr(defaults, k)) for k in _DYNAMICS_KWARGS}
    if fail_spec:
        fail = tuple((int(i), float(t)) for i, t in fail_spec)
    else:
        fail = ((0, fail_at),) if fail_at is not None else ()
    return ClusterDynamics(fail=fail, **vals)


def simulate_cluster(
    requests: list[Request],
    nodes: int,
    cores_per_node: int = 18,
    policy: str = "fc",
    assignment: str = "pull",
    warm: bool = True,
    backend: str = "reference",
    fail_at: float | None = None,
    fail_spec=(),
    node_speeds=None,
    degrade=(),
    hedging: HedgingSpec | None = None,
    resilience: ResilienceSpec | None = None,
    trace: bool = False,
    **kwargs,
) -> SimResult:
    """Run one burst on an N-node cluster.

    ``backend`` selects the engine: ``"reference"`` (the event-loop
    :class:`Cluster` above), ``"scan"`` (the batched multi-node
    ``jax.lax.scan`` kernel -- raises ``ValueError`` when the scenario is
    outside its envelope, see
    :func:`~repro.core.fastpath.cluster_scan_eligible`) or ``"auto"`` (scan
    where eligible, reference elsewhere).  ``fail_at`` injects a node-0
    crash at that time; ``fail_spec`` a whole ``((node, time), ...)`` kill
    schedule (see :func:`~repro.core.stragglers.rolling_restart`) -- both
    run natively on either engine.  ``node_speeds`` (dict or per-node
    sequence of speed multipliers) and ``degrade`` (``(node, t0, t1,
    slowdown)`` episodes) declare a heterogeneous fleet; ``hedging`` (a
    :class:`~repro.core.stragglers.HedgingSpec`) arms estimate-multiple
    straggler deadlines in steal or duplicate mode.  The scan path models
    capacity dynamics, heterogeneous fleets, both hedging modes and the
    cold-start regime (``warm=False``) natively, in any eligible
    combination; kwargs outside that set (legacy ``backup_requests`` sugar,
    retry tuning) force the reference event loop.

    ``trace=True`` attaches a flight-recorder lifecycle stream to
    ``result.trace`` (see :mod:`~repro.core.flight`): the reference loop
    emits the rich instrumented stream (enqueue/channel/steal/container
    events, probes over live queue depth), the scan path attaches the
    canonical reconstruction from its written-back request tensors -- the
    two streams share one schema and are directly comparable with
    :func:`~repro.core.flight.first_divergence`."""
    if backend not in ("reference", "scan", "auto"):
        raise ValueError(f"unknown cluster backend {backend!r}; "
                         "available: ('reference', 'scan', 'auto')")
    kills = (tuple((int(i), float(t)) for i, t in fail_spec) if fail_spec
             else (((0, float(fail_at)),) if fail_at is not None else ()))
    for idx, at in kills:
        if not 0 <= idx < nodes:
            raise ValueError(
                f"fail_spec kills node {idx} at t={at:g}, outside the "
                f"{nodes}-node initial fleet")
    profile = NodeSpeedProfile.from_any(node_speeds, degrade)
    resilience = ResilienceSpec.from_any(resilience)
    if backend in ("scan", "auto"):
        from .fastpath import (
            CLUSTER_CONTAINER_MB,
            CLUSTER_MEMORY_MB,
            cluster_scan_eligible,
            simulate_cluster_scan,
        )
        lb = kwargs.get("lb", "least_loaded")
        memory_mb = kwargs.get("memory_mb", CLUSTER_MEMORY_MB)
        container_mb = kwargs.get("container_mb", CLUSTER_CONTAINER_MB)
        extra = (set(kwargs) - {"lb", "memory_mb", "container_mb"}
                 - set(_DYNAMICS_KWARGS))
        dynamics = _dynamics_from_kwargs(kwargs, fail_at, fail_spec)
        try:
            import jax  # noqa: F401
            have_jax = True
        except ImportError:
            have_jax = False
        eligible = (have_jax and not extra and cluster_scan_eligible(
            requests, nodes, cores_per_node, policy, assignment=assignment,
            lb=lb, warm=warm, memory_mb=memory_mb,
            container_mb=container_mb, dynamics=dynamics,
            profile=profile, hedging=hedging, resilience=resilience))
        if eligible:
            res = simulate_cluster_scan(
                requests, nodes, cores_per_node, policy,
                assignment=assignment, lb=lb, warm=warm,
                memory_mb=memory_mb, container_mb=container_mb,
                dynamics=dynamics, profile=profile, hedging=hedging,
                resilience=resilience)
            if trace:
                res.trace = trace_from_result(
                    res, requests=requests, slots_per_node=cores_per_node,
                    meta={"backend": "scan", "policy": policy,
                          "assignment": assignment})
            return res
        if backend == "scan":
            raise ValueError(
                "scan cluster backend requires jax and the ours regime with "
                "supported dynamics/heterogeneity/hedging (and, for cold "
                "cells, ample container memory) "
                f"(policy={policy!r}, nodes={nodes}, cores={cores_per_node}, "
                f"assignment={assignment!r}, warm={warm}, "
                f"hedging={hedging!r}); use backend='auto' to fall back to "
                "the reference event loop")
    cfg = ClusterConfig(
        nodes=nodes, cores_per_node=cores_per_node, policy=policy,
        assignment=assignment, speed_profile=profile, hedging=hedging,
        resilience=resilience,
        **kwargs,
    )
    warm_fns = sorted({r.fn for r in requests}) if warm else None
    cluster = Cluster(cfg, warm_functions=warm_fns,
                      trace=FlightRecorder() if trace else None)
    for idx, at in kills:
        cluster.fail_node(idx, at=at)
    return cluster.run(requests)


def simulate_baseline_cluster(
    requests: list[Request],
    nodes: int,
    cores_per_node: int = 18,
    memory_mb: int = 40 * 1024,
    warm: bool = True,
) -> SimResult:
    """Stock OpenWhisk cluster (paper §VIII baseline): the controller assigns
    each action to its *home invoker* (hash of the action name), walking
    forward only when the home node has no free capacity.  This concentrates
    each function's containers on one node -- good for warm starts, terrible
    for load balance under a burst."""
    from .simulator import BaselineNodeSim, EventLoop

    loop = EventLoop()
    warm_fns = sorted({r.fn for r in requests}) if warm else None
    workers = [
        BaselineNodeSim(loop, cores_per_node, memory_mb=memory_mb,
                        warm_functions=warm_fns, name=f"node{i}")
        for i in range(nodes)
    ]

    def route(req: Request) -> None:
        workers[home_invoker_index(
            req.fn, [w.free_slots for w in workers])].submit(req)

    for req in requests:
        loop.schedule(req.r + REQ_OVERHEAD_S, lambda r=req: route(r))
    loop.run()
    done = [r for r in requests if r.c is not None]
    return SimResult(
        requests=done,
        cold_starts=sum(w.pool.cold_starts for w in workers),
        evictions=sum(w.pool.evictions for w in workers),
        creations=sum(w.pool.creations for w in workers),
        nodes_used=nodes,
        meta={"policy": "baseline", "assignment": "home"},
    )
