"""Container / warm-executable pool (paper §III, §IV-A, §VI).

Models the three container states OpenWhisk distinguishes:

  * **free pool** -- initialised with the runtime *and* the function (warm);
  * **prewarm pool** -- runtime only, function not yet initialised;
  * **busy** -- currently executing a call.

Two admission disciplines:

  * ``baseline`` (stock OpenWhisk): *memory-based*.  Any pending request with
    no matching free container greedily triggers creation of a new container
    if memory allows, evicting idle non-matching free containers if needed.
    The number of busy containers is unbounded → CPU oversubscription → OS
    preemption (modelled by the simulator's processor-sharing execution).
  * ``ours`` (paper §IV-A): *CPU-based*.  Busy containers ≤ #cores and each
    busy container owns exactly one core.  Warm containers are kept per
    function (bounded by #cores each), so with RAM ≥ #fns × cores × size the
    eviction count -- and therefore measured cold starts -- drops to ≈0
    (paper Fig. 2b: flat from 32 GB).

In the TPU serving engine the same class tracks *resident endpoint state*
(compiled program + weights + KV slab) against the HBM pool; only the cost
constants change (see serving/engine.py).
"""

from __future__ import annotations

from dataclasses import dataclass, field

# Cost constants (seconds) -- calibrated to the paper's measurements:
# "It takes 500 ms on the average [21] (and, in our measurements, up to 2 s)
#  to fully initialize a new container."
COLD_CREATE_S = 1.8      # create container from scratch (docker run + init)
PREWARM_INIT_S = 0.6     # initialise the function inside a prewarm container


@dataclass
class Container:
    fn: str | None           # None => prewarm (runtime only)
    memory_mb: int
    busy: bool = False
    last_used: float = 0.0
    created_at: float = 0.0


@dataclass
class AcquireResult:
    container: Container
    startup_delay: float     # 0 for warm, PREWARM_INIT_S / COLD_CREATE_S otherwise
    cold_start: bool         # true when the request pays any initialisation


@dataclass
class ContainerPool:
    memory_mb: int                     # node memory pool (OpenWhisk userMemory)
    container_mb: int = 256            # default per-container reservation
    discipline: str = "ours"           # "ours" | "baseline"
    cores: int = 10                    # used by "ours" to bound the pool
    prewarm_count: int = 2             # stock OpenWhisk keeps a few prewarms
    fn_memory: dict | None = None      # per-function container sizes (MB)
    containers: list[Container] = field(default_factory=list)
    # counters (read by benchmarks / Fig. 2)
    cold_starts: int = 0
    evictions: int = 0
    creations: int = 0

    def __post_init__(self) -> None:
        for _ in range(self.prewarm_count):
            if self._mem_used() + self.container_mb <= self.memory_mb:
                self.containers.append(Container(fn=None, memory_mb=self.container_mb))

    def _size(self, fn: str | None) -> int:
        if fn is not None and self.fn_memory:
            return int(self.fn_memory.get(fn, self.container_mb))
        return self.container_mb

    # -- queries -------------------------------------------------------------
    def _mem_used(self) -> int:
        return sum(c.memory_mb for c in self.containers)

    def busy_count(self) -> int:
        return sum(1 for c in self.containers if c.busy)

    def warm_count(self, fn: str | None = None) -> int:
        return sum(
            1
            for c in self.containers
            if not c.busy and c.fn is not None and (fn is None or c.fn == fn)
        )

    # -- acquisition ---------------------------------------------------------
    def acquire(self, fn: str, now: float) -> AcquireResult | None:
        """Find/create a container for ``fn``.  Returns None when the request
        must stay queued (no capacity).  Mirrors the invoker algorithm in
        paper §III: free pool -> prewarm pool -> create -> evict+create."""
        # 1. free-pool container already initialised with fn (warm start)
        best: Container | None = None
        for c in self.containers:
            if not c.busy and c.fn == fn:
                if best is None or c.last_used > best.last_used:
                    best = c
        if best is not None:
            best.busy = True
            best.last_used = now
            return AcquireResult(best, 0.0, cold_start=False)

        # 2. prewarm container (runtime present, init the function)
        for c in self.containers:
            if not c.busy and c.fn is None:
                c.fn = fn
                c.busy = True
                c.last_used = now
                self.cold_starts += 1
                self._replenish_prewarm()
                return AcquireResult(c, PREWARM_INIT_S, cold_start=True)

        # 3. create a new container if memory allows
        if self._mem_used() + self._size(fn) <= self.memory_mb:
            c = Container(fn=fn, memory_mb=self._size(fn), busy=True,
                          created_at=now, last_used=now)
            self.containers.append(c)
            self.creations += 1
            self.cold_starts += 1
            return AcquireResult(c, COLD_CREATE_S, cold_start=True)

        # 4. evict idle non-matching free-pool containers (LRU), then create
        idle = [c for c in self.containers if not c.busy and c.fn != fn]
        idle.sort(key=lambda c: c.last_used)
        while idle and self._mem_used() + self._size(fn) > self.memory_mb:
            victim = idle.pop(0)
            self.containers.remove(victim)
            self.evictions += 1
        if self._mem_used() + self._size(fn) <= self.memory_mb:
            c = Container(fn=fn, memory_mb=self._size(fn), busy=True,
                          created_at=now, last_used=now)
            self.containers.append(c)
            self.creations += 1
            self.cold_starts += 1
            return AcquireResult(c, COLD_CREATE_S, cold_start=True)

        # 5. nothing available: the call stays queued
        return None

    def release(self, container: Container, now: float) -> None:
        container.busy = False
        container.last_used = now
        if self.discipline == "ours":
            self._trim_ours(now)

    # -- warm-pool discipline --------------------------------------------------
    def _trim_ours(self, now: float) -> None:
        """Our discipline upper-bounds warm containers per function by
        ``cores`` (paper §VI: max containers = #functions × #cores)."""
        by_fn: dict[str, list[Container]] = {}
        for c in self.containers:
            if not c.busy and c.fn is not None:
                by_fn.setdefault(c.fn, []).append(c)
        for fn, lst in by_fn.items():
            if len(lst) > self.cores:
                lst.sort(key=lambda c: c.last_used)
                for victim in lst[: len(lst) - self.cores]:
                    self.containers.remove(victim)
                    self.evictions += 1

    def _replenish_prewarm(self) -> None:
        """Stock OpenWhisk keeps the prewarm pool topped up."""
        n_prewarm = sum(1 for c in self.containers if c.fn is None)
        while (
            n_prewarm < self.prewarm_count
            and self._mem_used() + self.container_mb <= self.memory_mb
        ):
            self.containers.append(Container(fn=None, memory_mb=self.container_mb))
            n_prewarm += 1

    # -- warm-up (experiment protocol §V-A) -----------------------------------
    def warm_up(self, fns: list[str], per_fn: int, now: float = 0.0) -> None:
        """Pre-create ``per_fn`` warm containers for each function, as the
        experiment warm-up phase does (c parallel calls per function)."""
        # round-robin across functions so a tight pool still warms every fn
        for i in range(per_fn):
            for fn in fns:
                if self._mem_used() + self._size(fn) <= self.memory_mb:
                    self.containers.append(
                        Container(fn=fn, memory_mb=self._size(fn), last_used=now)
                    )
