"""Node-level scheduling policies (paper §IV).

Each policy maps (request, estimator, now) -> a scalar priority; **lower is
served first**.  Priorities are computed exactly once, when the call is
enqueued, and never change afterwards (paper: "to simplify implementation,
once a priority of a particular action call is computed, it does not
change").  Ties are broken by arrival order (the queue is stable).

Starvation properties (paper §IV):
  * FIFO            -- trivially starvation-free.
  * SEPT, FC        -- may starve long/frequent functions under adversarial
                       arrivals; acceptable because overloads are short.
  * EECT            -- starvation-free: if r'(j) > r'(i) + E[p(i)] then j runs
                       after i, so i waits boundedly.
  * RECT            -- starvation-free: r̄(i) increases with time.
"""

from __future__ import annotations

from typing import Callable, Protocol

from .estimator import RuntimeEstimator
from .request import Request


class Policy(Protocol):
    name: str

    def priority(self, req: Request, est: RuntimeEstimator, now: float) -> float:
        ...


class _Base:
    name = "base"

    def priority(self, req: Request, est: RuntimeEstimator, now: float) -> float:  # pragma: no cover
        raise NotImplementedError

    def __repr__(self) -> str:
        return f"<policy {self.name}>"


class FIFO(_Base):
    """Priority = r'(i), the moment the invoker received the call."""

    name = "fifo"

    def priority(self, req: Request, est: RuntimeEstimator, now: float) -> float:
        return req.r_prime if req.r_prime is not None else now


class SEPT(_Base):
    """Shortest Expected Processing Time: priority = E[p(i)]."""

    name = "sept"

    def priority(self, req: Request, est: RuntimeEstimator, now: float) -> float:
        return est.estimate(req.fn)


class EECT(_Base):
    """Earliest Expected Completion Time: priority = r'(i) + E[p(i)]."""

    name = "eect"

    def priority(self, req: Request, est: RuntimeEstimator, now: float) -> float:
        r_prime = req.r_prime if req.r_prime is not None else now
        return r_prime + est.estimate(req.fn)


class RECT(_Base):
    """Recent Expected Completion Time: priority = r̄(i) + E[p(i)] where
    r̄(i) is the arrival moment of the *previous* call of the same function."""

    name = "rect"

    def priority(self, req: Request, est: RuntimeEstimator, now: float) -> float:
        return est.prev_arrival(req.fn, default=0.0) + est.estimate(req.fn)


class FairChoice(_Base):
    """FC: priority = #(f(i), -T) * E[p(i)] -- estimated total processing time
    the function consumed recently; deprioritises hogs, protects rare calls."""

    name = "fc"

    def priority(self, req: Request, est: RuntimeEstimator, now: float) -> float:
        return est.recent_count(req.fn, now) * est.estimate(req.fn)


POLICIES: dict[str, Callable[[], Policy]] = {
    "fifo": FIFO,
    "sept": SEPT,
    "eect": EECT,
    "rect": RECT,
    "fc": FairChoice,
}


def make_policy(name: str) -> Policy:
    try:
        return POLICIES[name.lower()]()
    except KeyError:
        raise ValueError(
            f"unknown policy {name!r}; available: {sorted(POLICIES)}"
        ) from None
