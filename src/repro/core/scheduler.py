"""Slot-based, non-preemptive node scheduler (the paper's method, §IV).

This is the component that replaces the stock OpenWhisk invoker logic:

  * the simple FIFO queue is replaced by a :class:`PriorityQueue` whose
    priorities come from a pluggable :class:`Policy` (FIFO/SEPT/EECT/RECT/FC);
  * admission is **CPU-based**: at most ``slots`` (= CPU cores / decode slots)
    calls execute concurrently, each on a dedicated slot (no oversubscription,
    hence no OS preemption);
  * priorities are computed once, at enqueue time;
  * the estimator observes arrivals (for FC/RECT) and completions (for E[p]).

The class is deliberately clock-agnostic: callers (the discrete-event
simulator, or the real serving engine) own time and I/O, and drive the
scheduler through ``receive`` / ``complete``, which return the set of calls
that should start executing *now*.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .containers import AcquireResult, ContainerPool
from .estimator import RuntimeEstimator
from .policies import Policy, make_policy
from .queues import PriorityQueue
from .request import Request


@dataclass
class StartDecision:
    """A call the scheduler decided to start executing."""

    request: Request
    acquire: AcquireResult      # container + startup delay (0 when warm)


@dataclass
class NodeScheduler:
    slots: int
    policy: Policy
    pool: ContainerPool
    estimator: RuntimeEstimator = field(default_factory=RuntimeEstimator)
    queue: PriorityQueue = field(default_factory=PriorityQueue)
    busy: int = 0

    # -- construction convenience -------------------------------------------
    @classmethod
    def build(
        cls,
        slots: int,
        policy: str = "fc",
        memory_mb: int = 32 * 1024,
        container_mb: int = 256,
        fn_memory: dict | None = None,
        estimator: RuntimeEstimator | None = None,
    ) -> "NodeScheduler":
        pool = ContainerPool(
            memory_mb=memory_mb,
            container_mb=container_mb,
            discipline="ours",
            cores=slots,
            fn_memory=fn_memory,
        )
        return cls(
            slots=slots,
            policy=make_policy(policy),
            pool=pool,
            estimator=estimator or RuntimeEstimator(),
        )

    # -- event entry points ---------------------------------------------------
    def receive(self, req: Request, now: float) -> list[StartDecision]:
        """A call was pulled from the (Kafka) queue by this invoker."""
        req.r_prime = now
        self.estimator.observe_arrival(req.fn, now)
        prio = self.policy.priority(req, self.estimator, now)
        self.queue.push(req, prio)
        return self._dispatch(now)

    def complete(self, req: Request, processing_time: float, acquire: AcquireResult,
                 now: float) -> list[StartDecision]:
        """A call finished executing; record history and backfill slots."""
        self.estimator.observe_completion(req.fn, processing_time)
        self.pool.release(acquire.container, now)
        self.busy -= 1
        assert self.busy >= 0, "slot accounting went negative"
        return self._dispatch(now)

    def cancel(self, req: Request) -> bool:
        """Remove a queued (not yet started) call; used by straggler backups."""
        return self.queue.remove(req)

    def abort(self, acquire: AcquireResult, now: float) -> list[StartDecision]:
        """A *running* call was cancelled (request timeout): free the slot
        and container and backfill, but record **no** completion history --
        the invoker never measured a processing time."""
        self.pool.release(acquire.container, now)
        self.busy -= 1
        assert self.busy >= 0, "slot accounting went negative"
        return self._dispatch(now)

    # -- core loop -------------------------------------------------------------
    def _dispatch(self, now: float) -> list[StartDecision]:
        """Start queued calls while free slots remain.  Non-preemptive: once a
        call occupies a slot it runs to completion; we never reshuffle."""
        started: list[StartDecision] = []
        while self.queue and self.busy < self.slots:
            head = self.queue.peek()
            acq = self.pool.acquire(head.fn, now)
            if acq is None:
                # Memory exhausted (cannot happen under the paper's sizing of
                # RAM >= #fns x cores x container, but stay safe): head-of-line
                # blocks rather than skipping, to preserve priority order.
                break
            req = self.queue.pop()
            assert req.id == head.id
            req.start = now + acq.startup_delay
            req.cold_start = acq.cold_start
            self.busy += 1
            started.append(StartDecision(req, acq))
        return started

    # -- introspection ----------------------------------------------------------
    @property
    def queued(self) -> int:
        return len(self.queue)

    def utilization(self) -> float:
        return self.busy / max(self.slots, 1)
