"""SeBS-derived workload model + Gatling-style burst generator (paper §V).

The paper drives OpenWhisk with the SeBS benchmark functions; Table I gives
the client-side response-time distribution of each function in an idle
system (5th percentile / median / 95th percentile, including ~10 ms of Kafka
overhead).  We treat (median - overhead) as the idle service time and fit a
lognormal to the published percentiles to sample per-call processing times.

The load generator reproduces §V-B exactly: a scenario of intensity v on a
node with c cores issues ``1.1 * c * v`` calls (c*v/10 per function, 11
functions) distributed uniformly at random in a 60-second window, with 5
different random sequences per configuration.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from .request import Request

KAFKA_OVERHEAD_S = 0.010  # "The measurements include ca. 10 ms Kafka overhead."

# Table I: function -> (p5_ms, median_ms, p95_ms), client-side, idle system.
SEBS_TABLE_I: dict[str, tuple[float, float, float]] = {
    "dna-visualisation": (8415.0, 8552.0, 8847.0),
    "sleep":             (1020.0, 1022.0, 1026.0),
    "compression":       (793.0, 807.0, 832.0),
    "video-processing":  (586.0, 593.0, 605.0),
    "uploader":          (184.0, 192.0, 405.0),
    "image-recognition": (117.0, 121.0, 237.0),
    "thumbnailer":       (112.0, 118.0, 124.0),
    "dynamic-html":      (18.0, 19.0, 22.0),
    "graph-pagerank":    (11.0, 12.0, 15.0),
    "graph-bfs":         (11.0, 12.0, 13.0),
    "graph-mst":         (11.0, 12.0, 13.0),
}

FUNCTIONS = list(SEBS_TABLE_I)

# Per-function container memory (MB).  SeBS deploys each function with its
# own memory requirement; dna-visualisation (squiggle over large FASTA) is by
# far the heaviest, the graph/html microbenchmarks are tiny.  OpenWhisk's
# admission is *memory-based*, so these sizes determine how many containers
# of each function fit on a node (the per-function capacity that throttles
# dna-visualisation in the baseline).
SEBS_MEMORY_MB: dict[str, int] = {
    "dna-visualisation": 1024,
    "sleep":             128,
    "compression":       256,
    "video-processing":  384,
    "uploader":          192,
    "image-recognition": 384,
    "thumbnailer":       192,
    "dynamic-html":      128,
    "graph-pagerank":    128,
    "graph-bfs":         128,
    "graph-mst":         128,
}

# Median client-side response times (seconds) -- the stretch denominators the
# paper uses ("instead of the processing time, we use the median response
# time measured on the level of the Gatling client", §V-A).
STRETCH_REFERENCE_S = {fn: v[1] / 1000.0 for fn, v in SEBS_TABLE_I.items()}


@dataclass(frozen=True)
class FunctionProfile:
    name: str
    median_s: float        # idle service time (median, Kafka excluded)
    sigma: float           # lognormal shape

    def sample(self, rng: np.random.Generator, n: int = 1) -> np.ndarray:
        """Sample processing times: lognormal around the median."""
        z = rng.standard_normal(n)
        return self.median_s * np.exp(self.sigma * z)


def _make_profiles() -> dict[str, FunctionProfile]:
    profiles = {}
    for fn, (p5, med, p95) in SEBS_TABLE_I.items():
        service_med = max((med - 10.0), 1.0) / 1000.0  # strip Kafka overhead
        # Fit sigma from the wider tail: for a lognormal,
        # p95/median = exp(1.645 sigma).
        up = math.log(p95 / med) / 1.645
        dn = math.log(med / p5) / 1.645
        sigma = max(up, dn, 1e-3)
        profiles[fn] = FunctionProfile(fn, service_med, sigma)
    return profiles


PROFILES = _make_profiles()

# Mean idle response time over the uniform function mix; paper: "The average
# response time for the function selected uniformly from Table I is ~1.042 s"
MEAN_IDLE_RESPONSE_S = sum(v[1] for v in SEBS_TABLE_I.values()) / len(SEBS_TABLE_I) / 1e3


def generate_burst(
    cores: int,
    intensity: int,
    seed: int,
    duration_s: float = 60.0,
    functions: list[str] | None = None,
) -> list[Request]:
    """Uniform 60-second burst: 1.1 * cores * intensity calls, equal count per
    function, arrival times ~ U(0, duration)."""
    fns = functions or FUNCTIONS
    per_fn = max(1, round(cores * intensity / 10))
    rng = np.random.default_rng(seed)
    reqs: list[Request] = []
    for fn in fns:
        profile = PROFILES[fn]
        times = rng.uniform(0.0, duration_s, size=per_fn)
        procs = profile.sample(rng, per_fn)
        for t, p in zip(times, procs):
            reqs.append(Request(fn=fn, r=float(t), p_true=float(max(p, 1e-4))))
    reqs.sort(key=lambda r: r.r)
    return reqs


def generate_fairness_burst(
    cores: int = 10,
    intensity: int = 90,
    seed: int = 0,
    duration_s: float = 60.0,
    rare_fn: str = "dna-visualisation",
    rare_count: int = 10,
) -> list[Request]:
    """§VII-D workload: exactly ``rare_count`` calls of the long, rare
    function; the remaining calls uniformly random over the other functions
    (no per-function uniformity assumption)."""
    total = round(1.1 * cores * intensity)
    others = [f for f in FUNCTIONS if f != rare_fn]
    rng = np.random.default_rng(seed)
    reqs: list[Request] = []
    for _ in range(rare_count):
        t = rng.uniform(0.0, duration_s)
        p = PROFILES[rare_fn].sample(rng, 1)[0]
        reqs.append(Request(fn=rare_fn, r=float(t), p_true=float(p)))
    for _ in range(total - rare_count):
        fn = others[int(rng.integers(len(others)))]
        t = rng.uniform(0.0, duration_s)
        p = PROFILES[fn].sample(rng, 1)[0]
        reqs.append(Request(fn=fn, r=float(t), p_true=float(max(p, 1e-4))))
    reqs.sort(key=lambda r: r.r)
    return reqs


def expected_cpu_utilization(intensity: int) -> float:
    """Paper §V-B: intensity 30 -> CPU busy ~50% of the time (ignoring
    container-management overheads)."""
    per_core_work = 1.1 * intensity * MEAN_IDLE_RESPONSE_S / 1.1 / 60.0
    return per_core_work * 1.1
