"""SeBS-derived workload model + Gatling-style burst generator (paper §V).

The paper drives OpenWhisk with the SeBS benchmark functions; Table I gives
the client-side response-time distribution of each function in an idle
system (5th percentile / median / 95th percentile, including ~10 ms of Kafka
overhead).  We treat (median - overhead) as the idle service time and fit a
lognormal to the published percentiles to sample per-call processing times.

The load generator reproduces §V-B exactly: a scenario of intensity v on a
node with c cores issues ``1.1 * c * v`` calls (c*v/10 per function, 11
functions) distributed uniformly at random in a 60-second window, with 5
different random sequences per configuration.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from .request import Request

KAFKA_OVERHEAD_S = 0.010  # "The measurements include ca. 10 ms Kafka overhead."

# Table I: function -> (p5_ms, median_ms, p95_ms), client-side, idle system.
SEBS_TABLE_I: dict[str, tuple[float, float, float]] = {
    "dna-visualisation": (8415.0, 8552.0, 8847.0),
    "sleep":             (1020.0, 1022.0, 1026.0),
    "compression":       (793.0, 807.0, 832.0),
    "video-processing":  (586.0, 593.0, 605.0),
    "uploader":          (184.0, 192.0, 405.0),
    "image-recognition": (117.0, 121.0, 237.0),
    "thumbnailer":       (112.0, 118.0, 124.0),
    "dynamic-html":      (18.0, 19.0, 22.0),
    "graph-pagerank":    (11.0, 12.0, 15.0),
    "graph-bfs":         (11.0, 12.0, 13.0),
    "graph-mst":         (11.0, 12.0, 13.0),
}

FUNCTIONS = list(SEBS_TABLE_I)

# Per-function container memory (MB).  SeBS deploys each function with its
# own memory requirement; dna-visualisation (squiggle over large FASTA) is by
# far the heaviest, the graph/html microbenchmarks are tiny.  OpenWhisk's
# admission is *memory-based*, so these sizes determine how many containers
# of each function fit on a node (the per-function capacity that throttles
# dna-visualisation in the baseline).
SEBS_MEMORY_MB: dict[str, int] = {
    "dna-visualisation": 1024,
    "sleep":             128,
    "compression":       256,
    "video-processing":  384,
    "uploader":          192,
    "image-recognition": 384,
    "thumbnailer":       192,
    "dynamic-html":      128,
    "graph-pagerank":    128,
    "graph-bfs":         128,
    "graph-mst":         128,
}

# Median client-side response times (seconds) -- the stretch denominators the
# paper uses ("instead of the processing time, we use the median response
# time measured on the level of the Gatling client", §V-A).
STRETCH_REFERENCE_S = {fn: v[1] / 1000.0 for fn, v in SEBS_TABLE_I.items()}


@dataclass(frozen=True)
class FunctionProfile:
    name: str
    median_s: float        # idle service time (median, Kafka excluded)
    sigma: float           # lognormal shape

    def sample(self, rng: np.random.Generator, n: int = 1) -> np.ndarray:
        """Sample processing times: lognormal around the median."""
        z = rng.standard_normal(n)
        return self.median_s * np.exp(self.sigma * z)


def _make_profiles() -> dict[str, FunctionProfile]:
    profiles = {}
    for fn, (p5, med, p95) in SEBS_TABLE_I.items():
        service_med = max((med - 10.0), 1.0) / 1000.0  # strip Kafka overhead
        # Fit sigma from the wider tail: for a lognormal,
        # p95/median = exp(1.645 sigma).
        up = math.log(p95 / med) / 1.645
        dn = math.log(med / p5) / 1.645
        sigma = max(up, dn, 1e-3)
        profiles[fn] = FunctionProfile(fn, service_med, sigma)
    return profiles


PROFILES = _make_profiles()

# Mean idle response time over the uniform function mix; paper: "The average
# response time for the function selected uniformly from Table I is ~1.042 s"
MEAN_IDLE_RESPONSE_S = sum(v[1] for v in SEBS_TABLE_I.values()) / len(SEBS_TABLE_I) / 1e3


def generate_burst(
    cores: int,
    intensity: int,
    seed: int,
    duration_s: float = 60.0,
    functions: list[str] | None = None,
) -> list[Request]:
    """Uniform 60-second burst: 1.1 * cores * intensity calls, equal count per
    function, arrival times ~ U(0, duration)."""
    fns = functions or FUNCTIONS
    per_fn = max(1, round(cores * intensity / 10))
    rng = np.random.default_rng(seed)
    reqs: list[Request] = []
    for fn in fns:
        profile = PROFILES[fn]
        times = rng.uniform(0.0, duration_s, size=per_fn)
        procs = profile.sample(rng, per_fn)
        for t, p in zip(times, procs):
            reqs.append(Request(fn=fn, r=float(t), p_true=float(max(p, 1e-4))))
    reqs.sort(key=lambda r: r.r)
    return reqs


def generate_fairness_burst(
    cores: int = 10,
    intensity: int = 90,
    seed: int = 0,
    duration_s: float = 60.0,
    rare_fn: str = "dna-visualisation",
    rare_count: int = 10,
) -> list[Request]:
    """§VII-D workload: exactly ``rare_count`` calls of the long, rare
    function; the remaining calls uniformly random over the other functions
    (no per-function uniformity assumption)."""
    total = round(1.1 * cores * intensity)
    others = [f for f in FUNCTIONS if f != rare_fn]
    rng = np.random.default_rng(seed)
    reqs: list[Request] = []
    for _ in range(rare_count):
        t = rng.uniform(0.0, duration_s)
        p = PROFILES[rare_fn].sample(rng, 1)[0]
        reqs.append(Request(fn=rare_fn, r=float(t), p_true=float(p)))
    for _ in range(total - rare_count):
        fn = others[int(rng.integers(len(others)))]
        t = rng.uniform(0.0, duration_s)
        p = PROFILES[fn].sample(rng, 1)[0]
        reqs.append(Request(fn=fn, r=float(t), p_true=float(max(p, 1e-4))))
    reqs.sort(key=lambda r: r.r)
    return reqs


def expected_cpu_utilization(intensity: int) -> float:
    """Paper §V-B: intensity 30 -> CPU busy ~50% of the time (ignoring
    container-management overheads)."""
    per_core_work = 1.1 * intensity * MEAN_IDLE_RESPONSE_S / 1.1 / 60.0
    return per_core_work * 1.1


# ---------------------------------------------------------------------------
# trace-driven arrival processes (beyond the paper's uniform burst)
# ---------------------------------------------------------------------------
def poisson_arrivals(
    rate_per_s: float, duration_s: float, rng: np.random.Generator
) -> np.ndarray:
    """Homogeneous Poisson process: i.i.d. exponential gaps at ``rate_per_s``.

    Returns sorted arrival times within [0, duration_s)."""
    if rate_per_s <= 0:
        return np.empty(0)
    # draw enough gaps to cover the window with high probability, then trim
    n_guess = int(rate_per_s * duration_s * 1.5 + 10 * math.sqrt(
        rate_per_s * duration_s + 1.0))
    times = np.cumsum(rng.exponential(1.0 / rate_per_s, size=n_guess))
    while times.size and times[-1] < duration_s:
        extra = np.cumsum(rng.exponential(1.0 / rate_per_s, size=n_guess))
        times = np.concatenate([times, times[-1] + extra])
    return times[times < duration_s]


def diurnal_arrivals(
    rate_per_s: float,
    duration_s: float,
    rng: np.random.Generator,
    period_s: float | None = None,
    depth: float = 0.8,
) -> np.ndarray:
    """Sine-modulated (diurnal) Poisson process by thinning.

    Instantaneous rate lambda(t) = rate * (1 + depth * sin(2 pi t / period)),
    so the *mean* rate over a whole period is ``rate_per_s``."""
    if not 0.0 <= depth <= 1.0:
        raise ValueError(f"depth must be in [0, 1], got {depth}")
    period = period_s if period_s is not None else duration_s
    peak = rate_per_s * (1.0 + depth)
    cand = poisson_arrivals(peak, duration_s, rng)
    lam = rate_per_s * (1.0 + depth * np.sin(2.0 * math.pi * cand / period))
    keep = rng.uniform(0.0, peak, size=cand.size) < lam
    return cand[keep]


def mmpp_arrivals(
    rate_per_s: float,
    duration_s: float,
    rng: np.random.Generator,
    burst_factor: float = 4.0,
    burst_fraction: float = 0.2,
    burst_sojourn_s: float = 5.0,
) -> np.ndarray:
    """Bursty 2-state Markov-modulated Poisson process.

    The process alternates between a calm and a burst state (exponential
    sojourns); the burst state emits at ``burst_factor`` x the calm rate and
    occupies ``burst_fraction`` of the time, so the long-run mean rate is
    ``rate_per_s``."""
    if burst_factor < 1.0:
        raise ValueError("burst_factor must be >= 1")
    if not 0.0 < burst_fraction < 1.0:
        raise ValueError("burst_fraction must be in (0, 1)")
    calm_rate = rate_per_s / ((1.0 - burst_fraction)
                              + burst_factor * burst_fraction)
    burst_rate = burst_factor * calm_rate
    calm_sojourn = burst_sojourn_s * (1.0 - burst_fraction) / burst_fraction
    out: list[np.ndarray] = []
    t = 0.0
    # stationary initial state: always starting calm would bias the mean
    # rate low on short windows
    bursting = bool(rng.uniform() < burst_fraction)
    while t < duration_s:
        mean_sojourn = burst_sojourn_s if bursting else calm_sojourn
        seg = min(float(rng.exponential(mean_sojourn)), duration_s - t)
        rate = burst_rate if bursting else calm_rate
        out.append(t + poisson_arrivals(rate, seg, rng))
        t += seg
        bursting = not bursting
    return np.concatenate(out) if out else np.empty(0)


def ramp_arrivals(
    rate_per_s: float,
    duration_s: float,
    rng: np.random.Generator,
    burst_factor: float = 6.0,
    burst_start_frac: float = 1.0 / 3.0,
    burst_end_frac: float = 1.0 / 2.0,
) -> np.ndarray:
    """Ramp-and-release: steady Poisson load at ``rate_per_s`` with a
    deterministic overload window in the middle -- the rate steps to
    ``burst_factor`` x base inside ``[burst_start_frac, burst_end_frac) x
    duration`` and back.  Unlike :func:`mmpp_arrivals` the burst window is
    *fixed*, so pre-burst / in-burst / post-release metrics can be compared
    across scenarios (the metastable-overload benchmark measures goodput
    recovery after the release edge)."""
    if burst_factor < 1.0:
        raise ValueError("burst_factor must be >= 1")
    if not 0.0 <= burst_start_frac < burst_end_frac <= 1.0:
        raise ValueError("need 0 <= burst_start_frac < burst_end_frac <= 1")
    base = poisson_arrivals(rate_per_s, duration_s, rng)
    t0 = burst_start_frac * duration_s
    t1 = burst_end_frac * duration_s
    extra = t0 + poisson_arrivals(rate_per_s * (burst_factor - 1.0),
                                  t1 - t0, rng)
    return np.sort(np.concatenate([base, extra]))


ARRIVAL_KINDS = ("uniform", "poisson", "diurnal", "mmpp", "ramp")


def generate_trace_burst(
    cores: int,
    intensity: int,
    seed: int,
    kind: str = "poisson",
    duration_s: float = 60.0,
    functions: list[str] | None = None,
    **kwargs,
) -> list[Request]:
    """Production-shaped variant of :func:`generate_burst`: the same expected
    call volume (1.1 * cores * intensity over ``duration_s``) but arrivals
    drawn from a stochastic process instead of the paper's uniform window.
    Functions are sampled uniformly per call; processing times from the SeBS
    lognormal profiles."""
    fns = functions or FUNCTIONS
    rate = 1.1 * cores * intensity / duration_s
    rng = np.random.default_rng(seed)
    if kind == "uniform":
        return generate_burst(cores, intensity, seed, duration_s, functions)
    if kind == "poisson":
        times = poisson_arrivals(rate, duration_s, rng)
    elif kind == "diurnal":
        times = diurnal_arrivals(rate, duration_s, rng, **kwargs)
    elif kind == "mmpp":
        times = mmpp_arrivals(rate, duration_s, rng, **kwargs)
    elif kind == "ramp":
        times = ramp_arrivals(rate, duration_s, rng, **kwargs)
    else:
        raise ValueError(f"unknown arrival kind {kind!r}")
    reqs: list[Request] = []
    for t in times:
        fn = fns[int(rng.integers(len(fns)))]
        p = PROFILES[fn].sample(rng, 1)[0]
        reqs.append(Request(fn=fn, r=float(t), p_true=float(max(p, 1e-4))))
    reqs.sort(key=lambda r: r.r)
    return reqs
