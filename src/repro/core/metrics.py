"""Response-time / stretch aggregation (paper §II).

Reported statistics mirror the paper's tables: average, 50/75/95/99th
percentiles of R(i) and S(i), plus max c(i) (the makespan of the burst) and
per-function breakdowns (§VII-D uses those to show FC's fairness).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .request import Request
from .workload import STRETCH_REFERENCE_S

PERCENTILES = (50, 75, 95, 99)


@dataclass
class Summary:
    n: int
    response_avg: float
    response_pct: dict[int, float]
    stretch_avg: float
    stretch_pct: dict[int, float]
    max_completion: float
    cold_starts: int = 0
    failures: int = 0
    per_function: dict[str, "Summary"] = field(default_factory=dict)

    def row(self) -> dict[str, float]:
        out = {
            "n": self.n,
            "R_avg": self.response_avg,
            "S_avg": self.stretch_avg,
            "max_c": self.max_completion,
            "cold_starts": self.cold_starts,
            "failures": self.failures,
        }
        for p in PERCENTILES:
            out[f"R_p{p}"] = self.response_pct[p]
            out[f"S_p{p}"] = self.stretch_pct[p]
        return out


def summarize_arrays(
    resp: np.ndarray,
    stretch: np.ndarray,
    max_completion: float,
    cold_starts: int = 0,
    failures: int = 0,
) -> Summary:
    """Aggregate pre-extracted response-time / stretch arrays.

    The array-level core of :func:`summarize` (which extracts the arrays
    from a request list first), exposed for callers that already hold
    response/stretch arrays and want to skip the per-request extraction."""
    if resp.size == 0:
        raise ValueError("no completed requests to summarize")
    # one vectorized percentile call per array: same sort + interpolation as
    # per-percentile calls (bit-identical values), ~4x fewer array passes --
    # this sits on the per-cell hot path of 100k-cell mega sweeps
    r_pct = np.percentile(resp, PERCENTILES)
    s_pct = np.percentile(stretch, PERCENTILES)
    return Summary(
        n=int(resp.size),
        response_avg=float(resp.mean()),
        response_pct=dict(zip(PERCENTILES, map(float, r_pct))),
        stretch_avg=float(stretch.mean()),
        stretch_pct=dict(zip(PERCENTILES, map(float, s_pct))),
        max_completion=float(max_completion),
        cold_starts=cold_starts,
        failures=failures,
    )


def summarize(
    requests: list[Request],
    stretch_ref: dict[str, float] | None = None,
    per_function: bool = False,
    cold_starts: int = 0,
    failures: int = 0,
) -> Summary:
    """Aggregate completed requests.  ``stretch_ref`` maps fn -> idle-system
    median response time (Table I); defaults to the SeBS table, so stretch can
    be < 1 exactly as the paper notes."""
    ref = stretch_ref if stretch_ref is not None else STRETCH_REFERENCE_S
    done = [r for r in requests if r.c is not None]
    if not done:
        raise ValueError("no completed requests to summarize")
    resp = np.array([r.response_time for r in done])
    stretch = np.array([r.stretch(ref.get(r.fn)) for r in done])
    max_c = float(max(r.c for r in done))

    summary = summarize_arrays(resp, stretch, max_c,
                               cold_starts=cold_starts, failures=failures)
    if per_function:
        fns = sorted({r.fn for r in done})
        for fn in fns:
            sub = [r for r in done if r.fn == fn]
            summary.per_function[fn] = summarize(sub, stretch_ref=ref)
    return summary


def resilience_row(
    requests: list[Request],
    *,
    timed_out: int = 0,
    shed: int = 0,
    retries_issued: int = 0,
    wasted_work: float = 0.0,
) -> dict[str, float]:
    """Resilience-scenario metrics (ISSUE 8): counters plus the derived
    ``goodput`` (successful completions per second of makespan),
    ``R_ok_p95`` (95th-percentile response over *successful* calls only --
    under shedding/timeouts the plain percentiles silently drop failures,
    so this name makes the survivorship explicit), and ``wasted_frac``
    (wasted execution seconds / total execution seconds, wasted included).

    Tolerates bursts where every call failed: the derived metrics degrade
    to 0.0 instead of raising, so a fully-shed cell still yields a row."""
    done = [r for r in requests if r.c is not None]
    failed = [r for r in requests if r.c is None and r.failed is not None]
    makespan = max((r.c for r in done), default=0.0)
    goodput = len(done) / makespan if makespan > 0 else 0.0
    if done:
        r_ok_p95 = float(np.percentile(
            np.array([r.response_time for r in done]), 95))
    else:
        r_ok_p95 = 0.0
    busy = sum(r.finish - r.start for r in done
               if r.start is not None and r.finish is not None)
    total = wasted_work + busy
    wasted_frac = wasted_work / total if total > 0 else 0.0
    return {
        "goodput": goodput,
        "R_ok_p95": r_ok_p95,
        "wasted_frac": wasted_frac,
        "timed_out": float(timed_out),
        "shed": float(shed),
        "retries_issued": float(retries_issued),
        "wasted_work": float(wasted_work),
        "n_failed": float(len(failed)),
    }


def merge_summaries(parts: list[Summary]) -> dict[str, float]:
    """Average key statistics across repetitions (the paper aggregates the
    five random call sequences per configuration)."""
    keys = parts[0].row().keys()
    return {k: float(np.mean([p.row()[k] for p in parts])) for k in keys}
