"""Stable priority queue for action calls.

Replaces the invoker's simple FIFO queue (paper §IV-B).  The priority of a
request is computed once, at push time; ties are broken by push order so the
queue degenerates to exact FIFO under the FIFO policy.
"""

from __future__ import annotations

import heapq
import itertools

from .request import Request


class PriorityQueue:
    """Min-heap of (priority, seq, request); stable for equal priorities."""

    def __init__(self) -> None:
        self._heap: list[tuple[float, int, Request]] = []
        self._seq = itertools.count()

    def push(self, req: Request, priority: float) -> None:
        req.priority = float(priority)
        heapq.heappush(self._heap, (req.priority, next(self._seq), req))

    def pop(self) -> Request:
        if not self._heap:
            raise IndexError("pop from empty PriorityQueue")
        return heapq.heappop(self._heap)[2]

    def peek(self) -> Request:
        if not self._heap:
            raise IndexError("peek from empty PriorityQueue")
        return self._heap[0][2]

    def remove(self, req: Request) -> bool:
        """Remove a specific request (O(n)); used for straggler-backup
        cancellation.  Returns True if found."""
        for i, (_, _, r) in enumerate(self._heap):
            if r.id == req.id:
                self._heap[i] = self._heap[-1]
                self._heap.pop()
                if i < len(self._heap):
                    heapq._siftup(self._heap, i)  # noqa: SLF001 - stdlib-sanctioned
                    heapq._siftdown(self._heap, 0, i)  # noqa: SLF001
                return True
        return False

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def __iter__(self):
        """Iterate in heap (not sorted) order; for inspection only."""
        return (r for _, _, r in self._heap)
