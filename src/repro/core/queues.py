"""Stable priority queue for action calls.

Replaces the invoker's simple FIFO queue (paper §IV-B).  The priority of a
request is computed once, at push time; ties are broken by push order so the
queue degenerates to exact FIFO under the FIFO policy.

``remove`` is O(1) amortised (an id -> entry map plus lazy-deletion
tombstones scrubbed at the next pop/peek): hedging-heavy straggler cells
cancel queued calls constantly, and the old linear heap scan made that an
O(n) hot path.
"""

from __future__ import annotations

import heapq
import itertools

from .request import Request


class PriorityQueue:
    """Min-heap of [priority, seq, request]; stable for equal priorities.

    Entries are mutable lists so a removed request can be tombstoned in
    place (``entry[2] = None``); the unique ``seq`` field makes comparisons
    never reach the request slot.  ``len``/truthiness count live entries
    only.
    """

    def __init__(self) -> None:
        self._heap: list[list] = []
        self._seq = itertools.count()
        self._by_id: dict[int, list] = {}    # req.id -> live heap entry
        self._live = 0

    def push(self, req: Request, priority: float) -> None:
        req.priority = float(priority)
        entry = [req.priority, next(self._seq), req]
        # same-id re-push (a stolen call re-enqueued) tracks the newest copy
        self._by_id[req.id] = entry
        heapq.heappush(self._heap, entry)
        self._live += 1

    def _scrub(self) -> None:
        while self._heap and self._heap[0][2] is None:
            heapq.heappop(self._heap)

    def pop(self) -> Request:
        self._scrub()
        if not self._heap:
            raise IndexError("pop from empty PriorityQueue")
        _, seq, req = heapq.heappop(self._heap)
        self._live -= 1
        entry = self._by_id.get(req.id)
        if entry is not None and entry[1] == seq:
            del self._by_id[req.id]
        return req

    def peek(self) -> Request:
        self._scrub()
        if not self._heap:
            raise IndexError("peek from empty PriorityQueue")
        return self._heap[0][2]

    def remove(self, req: Request) -> bool:
        """Remove a specific request (O(1) amortised); used for straggler
        cancellation.  Returns True if found."""
        entry = self._by_id.get(req.id)
        if entry is None:
            return False
        del self._by_id[req.id]
        entry[2] = None                     # tombstone; scrubbed lazily
        self._live -= 1
        return True

    def __len__(self) -> int:
        return self._live

    def __bool__(self) -> bool:
        return self._live > 0

    def __iter__(self):
        """Iterate live entries in heap (not sorted) order; inspection only."""
        return (e[2] for e in self._heap if e[2] is not None)
