"""Historical runtime estimation (paper §IV).

The scheduler estimates the expected processing time E[p(i)] of a call of
function f as the mean of the **last at most W=10 finished executions** of the
same function on this node ([18] shows 10 recent samples suffice).  If a
function has never finished on the node its estimate is 0 (paper §IV-B) --
which makes unknown functions highest-priority under SEPT, bounding the
damage of a cold estimator.

The Fair-Choice policy additionally needs #(f, -T): the number of calls of f
*received* during the last T seconds (default 60 s).
"""

from __future__ import annotations

from collections import defaultdict, deque
from dataclasses import dataclass, field

DEFAULT_WINDOW = 10
DEFAULT_FC_HORIZON = 60.0


@dataclass
class RuntimeEstimator:
    """Per-function ring buffer of recent processing times + arrival log.

    All methods are O(1) amortised; the arrival deque is pruned lazily.
    """

    window: int = DEFAULT_WINDOW
    fc_horizon: float = DEFAULT_FC_HORIZON
    default_estimate: float = 0.0
    _times: dict[str, deque] = field(default_factory=lambda: defaultdict(deque))
    _arrivals: dict[str, deque] = field(default_factory=lambda: defaultdict(deque))
    _last_arrival: dict[str, float] = field(default_factory=dict)
    _prev_arrival: dict[str, float] = field(default_factory=dict)

    # -- observations -------------------------------------------------------
    def observe_completion(self, fn: str, processing_time: float) -> None:
        """Store a finished execution's processing time (invoker-side, so it
        is *not* affected by network latency -- paper §IV)."""
        buf = self._times[fn]
        buf.append(float(processing_time))
        while len(buf) > self.window:
            buf.popleft()

    def observe_arrival(self, fn: str, now: float) -> None:
        """Log that a call of ``fn`` was received (pulled) at ``now``.

        Maintains r̄(fn) = the arrival time of the *previous* call of fn
        (needed by RECT: at enqueue of call i, r̄(i) is the previous call's
        arrival) and the FC sliding-window counter.
        """
        self._prev_arrival[fn] = self._last_arrival.get(fn, now)
        self._last_arrival[fn] = now
        arr = self._arrivals[fn]
        arr.append(now)
        self._prune(fn, now)

    # -- queries ------------------------------------------------------------
    def estimate(self, fn: str) -> float:
        """E[p] = mean of the last ≤window processing times; 0 if unseen."""
        buf = self._times.get(fn)
        if not buf:
            return self.default_estimate
        return sum(buf) / len(buf)

    def recent_count(self, fn: str, now: float) -> int:
        """#(fn, -T): calls of fn received in (now - T, now]."""
        self._prune(fn, now)
        return len(self._arrivals.get(fn, ()))

    def prev_arrival(self, fn: str, default: float = 0.0) -> float:
        """r̄(fn): arrival time of the previous call of fn (RECT)."""
        return self._prev_arrival.get(fn, default)

    def sample_count(self, fn: str) -> int:
        return len(self._times.get(fn, ()))

    # -- internals ----------------------------------------------------------
    def _prune(self, fn: str, now: float) -> None:
        arr = self._arrivals.get(fn)
        if not arr:
            return
        cutoff = now - self.fc_horizon
        while arr and arr[0] <= cutoff:
            arr.popleft()
