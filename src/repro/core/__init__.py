"""Core library: the paper's node-level call-scheduling method.

Public API:
  - policies: FIFO, SEPT, EECT, RECT, FairChoice (make_policy)
  - RuntimeEstimator: last-10 processing-time estimator (+ FC counters)
  - NodeScheduler: slot-based non-preemptive scheduler
  - ContainerPool: warm/prewarm/cold pool with both admission disciplines
  - simulator / cluster: discrete-event reproduction of the paper's setup
  - workload: SeBS Table-I profiles + Gatling-style burst generator
  - metrics: response-time / stretch summaries
"""

from .containers import AcquireResult, Container, ContainerPool
from .estimator import RuntimeEstimator
from .metrics import Summary, merge_summaries, summarize
from .policies import EECT, FIFO, FairChoice, Policy, RECT, SEPT, make_policy
from .queues import PriorityQueue
from .request import CallRecord, Request
from .scheduler import NodeScheduler, StartDecision
from .simulator import (
    BaselineNodeSim,
    EventLoop,
    OursNodeSim,
    SimResult,
    simulate_single_node,
)
from .cluster import Cluster, ClusterConfig, simulate_baseline_cluster, simulate_cluster
from .sweep import (
    CellResult,
    SweepCell,
    SweepResult,
    SweepSpec,
    run_cell,
    run_sweep,
)
from .traces import (
    generate_trace_requests,
    load_azure_trace,
    requests_from_trace,
    stable_hash,
)
from .workload import (
    ARRIVAL_KINDS,
    FUNCTIONS,
    MEAN_IDLE_RESPONSE_S,
    PROFILES,
    SEBS_TABLE_I,
    STRETCH_REFERENCE_S,
    diurnal_arrivals,
    generate_burst,
    generate_fairness_burst,
    generate_trace_burst,
    mmpp_arrivals,
    poisson_arrivals,
)

__all__ = [
    "ARRIVAL_KINDS",
    "AcquireResult",
    "BaselineNodeSim",
    "CallRecord",
    "CellResult",
    "Cluster",
    "ClusterConfig",
    "Container",
    "ContainerPool",
    "EECT",
    "EventLoop",
    "FIFO",
    "FUNCTIONS",
    "FairChoice",
    "MEAN_IDLE_RESPONSE_S",
    "NodeScheduler",
    "OursNodeSim",
    "PROFILES",
    "Policy",
    "PriorityQueue",
    "RECT",
    "Request",
    "RuntimeEstimator",
    "SEBS_TABLE_I",
    "SEPT",
    "STRETCH_REFERENCE_S",
    "SimResult",
    "StartDecision",
    "Summary",
    "SweepCell",
    "SweepResult",
    "SweepSpec",
    "diurnal_arrivals",
    "generate_burst",
    "generate_fairness_burst",
    "generate_trace_burst",
    "generate_trace_requests",
    "load_azure_trace",
    "make_policy",
    "merge_summaries",
    "mmpp_arrivals",
    "poisson_arrivals",
    "requests_from_trace",
    "run_cell",
    "run_sweep",
    "simulate_baseline_cluster",
    "simulate_cluster",
    "simulate_single_node",
    "stable_hash",
    "summarize",
]
