"""Core library: the paper's node-level call-scheduling method.

Public API:
  - policies: FIFO, SEPT, EECT, RECT, FairChoice (make_policy)
  - RuntimeEstimator: last-10 processing-time estimator (+ FC counters)
  - NodeScheduler: slot-based non-preemptive scheduler
  - ContainerPool: warm/prewarm/cold pool with both admission disciplines
  - simulator / cluster: discrete-event reproduction of the paper's setup
  - workload: SeBS Table-I profiles + Gatling-style burst generator
  - metrics: response-time / stretch summaries

Simulation backends (``simulate_single_node(..., backend=...)`` and the
``SweepSpec(backends=...)`` axis):
  - ``"reference"`` -- the discrete-event loop; supports every scenario and
    defines the semantics.
  - ``"vectorized"`` -- array fast path for the ours-mode single node
    (``core/fastpath.py``); ~10x faster and **exact** (bit-identical
    metrics), including cold starts and tight-memory eviction.
  - ``"scan"`` -- batched ``jax.lax.scan`` variant; a whole grid runs as one
    scan over a padded request tensor (``run_cells_scan``).  Covers every
    ours-mode regime: always-warm cells (``scan_eligible``) in float32
    (~1e-6 agreement), ``warm=False`` cells with per-(node, fn) container
    tensors, and clusters with **time-varying capacity** (autoscaling via
    ``ClusterDynamics``, failure injection) plus straggler hedging inside
    the same kernel under float64 with bit-identical lost/backup/steal
    counts and realized ``CapacityTimeline``\\s.
  - ``"auto"`` -- the best supported engine per ``supports()`` capability
    matrix, reference elsewhere (the stock baseline and the documented
    duplicate-hedging x failures x push rejection).
  - ``SweepSpec(validate="cross-check")`` runs sampled eligible cells on
    both backends and raises :class:`~repro.core.sweep.BackendMismatchError`
    if any reported metric drifts beyond 1%.
"""

from .containers import AcquireResult, Container, ContainerPool
from .estimator import RuntimeEstimator
from .fastpath import (
    ScanBackend,
    VectorizedBackend,
    cluster_scan_eligible,
    scan_bucket_timings,
    scan_cache_clear,
    scan_cache_stats,
    scan_eligible,
    scan_timings_clear,
    simulate_cells_scan,
    simulate_cluster_cells_scan,
    simulate_cluster_scan,
    simulate_ours_vectorized,
)
from .metrics import Summary, merge_summaries, summarize, summarize_arrays
from .policies import EECT, FIFO, FairChoice, Policy, RECT, SEPT, make_policy
from .queues import PriorityQueue
from .request import CallRecord, Request
from .resilience import (
    AdmissionPolicy,
    ResilienceSpec,
    RetryPolicy,
    TimeoutSpec,
    retry_jitter_u,
)
from .flight import (
    CANONICAL_KINDS,
    DivergenceReport,
    FlightRecorder,
    SimTrace,
    TraceEvent,
    first_divergence,
    run_manifest,
    trace_from_requests,
    trace_from_result,
    write_manifest,
)
from .scheduler import NodeScheduler, StartDecision
from .simulator import (
    BaselineNodeSim,
    EventLoop,
    OursNodeSim,
    ReferenceBackend,
    SimBackend,
    SimResult,
    available_backends,
    get_backend,
    register_backend,
    simulate_single_node,
)
from .cluster import (
    CapacityTimeline,
    Cluster,
    ClusterConfig,
    ClusterDynamics,
    home_invoker_index,
    least_loaded_index,
    most_free_index,
    simulate_baseline_cluster,
    simulate_cluster,
)
from .stragglers import (
    HedgingSpec,
    NodeSpeedProfile,
    rolling_restart,
)
from .sweep import (
    BACKEND_CHOICES,
    BackendMismatchError,
    CellResult,
    ProgressReporter,
    SweepCell,
    SweepResult,
    SweepSpec,
    run_cell,
    run_cells_scan,
    run_sweep,
    triage_cell,
)
from .traces import (
    generate_trace_requests,
    load_azure_trace,
    requests_from_trace,
    stable_hash,
    tile_trace,
)
from .workload import (
    ARRIVAL_KINDS,
    FUNCTIONS,
    MEAN_IDLE_RESPONSE_S,
    PROFILES,
    SEBS_TABLE_I,
    STRETCH_REFERENCE_S,
    diurnal_arrivals,
    generate_burst,
    generate_fairness_burst,
    generate_trace_burst,
    mmpp_arrivals,
    poisson_arrivals,
    ramp_arrivals,
)

__all__ = [
    "ARRIVAL_KINDS",
    "AcquireResult",
    "AdmissionPolicy",
    "BACKEND_CHOICES",
    "BackendMismatchError",
    "BaselineNodeSim",
    "CANONICAL_KINDS",
    "CallRecord",
    "CapacityTimeline",
    "CellResult",
    "DivergenceReport",
    "FlightRecorder",
    "Cluster",
    "ClusterConfig",
    "ClusterDynamics",
    "Container",
    "ContainerPool",
    "EECT",
    "EventLoop",
    "FIFO",
    "FUNCTIONS",
    "FairChoice",
    "HedgingSpec",
    "MEAN_IDLE_RESPONSE_S",
    "NodeScheduler",
    "NodeSpeedProfile",
    "OursNodeSim",
    "PROFILES",
    "Policy",
    "PriorityQueue",
    "ProgressReporter",
    "RECT",
    "ResilienceSpec",
    "RetryPolicy",
    "ReferenceBackend",
    "Request",
    "RuntimeEstimator",
    "SEBS_TABLE_I",
    "SEPT",
    "STRETCH_REFERENCE_S",
    "ScanBackend",
    "SimBackend",
    "SimResult",
    "SimTrace",
    "StartDecision",
    "Summary",
    "SweepCell",
    "SweepResult",
    "SweepSpec",
    "TimeoutSpec",
    "VectorizedBackend",
    "available_backends",
    "cluster_scan_eligible",
    "diurnal_arrivals",
    "generate_burst",
    "generate_fairness_burst",
    "generate_trace_burst",
    "generate_trace_requests",
    "get_backend",
    "home_invoker_index",
    "least_loaded_index",
    "load_azure_trace",
    "make_policy",
    "merge_summaries",
    "mmpp_arrivals",
    "most_free_index",
    "poisson_arrivals",
    "ramp_arrivals",
    "TraceEvent",
    "first_divergence",
    "register_backend",
    "requests_from_trace",
    "retry_jitter_u",
    "rolling_restart",
    "run_cell",
    "run_cells_scan",
    "run_manifest",
    "run_sweep",
    "scan_bucket_timings",
    "scan_cache_clear",
    "scan_cache_stats",
    "scan_timings_clear",
    "scan_eligible",
    "simulate_baseline_cluster",
    "simulate_cells_scan",
    "simulate_cluster",
    "simulate_cluster_cells_scan",
    "simulate_cluster_scan",
    "simulate_ours_vectorized",
    "simulate_single_node",
    "stable_hash",
    "summarize",
    "summarize_arrays",
    "tile_trace",
    "trace_from_requests",
    "trace_from_result",
    "triage_cell",
    "write_manifest",
]
