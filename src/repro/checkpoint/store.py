"""Checkpoint store: atomic save/restore with async writer (fault tolerance).

Layout: <dir>/step_<n>/arrays.npz + meta.json; a ``LATEST`` file is written
last (atomic rename), so a crash mid-save never corrupts the restore path.
``save_async`` offloads serialisation to a daemon thread -- the training
loop overlaps checkpoint IO with the next step (the standard large-scale
trick; on multi-host each host writes its own shard directory).
"""

from __future__ import annotations

import json
import threading
from pathlib import Path

import jax
import ml_dtypes
import numpy as np

# npz cannot serialise ml_dtypes (bfloat16 etc.); store them as bit-equal
# uint views with a dtype manifest
_BITCAST = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _encode(a: np.ndarray) -> tuple[np.ndarray, str]:
    name = a.dtype.name
    if name in _BITCAST:
        return a.view(_BITCAST[name][1]), name
    return a, name


def _decode(a: np.ndarray, name: str) -> np.ndarray:
    if name in _BITCAST:
        return a.view(_BITCAST[name][0])
    return a


class CheckpointStore:
    def __init__(self, directory: str | Path):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- save -------------------------------------------------------------
    def save(self, step: int, tree, meta: dict | None = None) -> Path:
        flat, treedef = jax.tree.flatten(tree)
        host, dtypes = [], []
        for x in flat:
            a, name = _encode(np.asarray(x))
            host.append(a)
            dtypes.append(name)
        tmp = self.dir / f".tmp_step_{step}"
        final = self.dir / f"step_{step}"
        tmp.mkdir(parents=True, exist_ok=True)
        np.savez(tmp / "arrays.npz", *host)
        (tmp / "meta.json").write_text(json.dumps({
            "step": step,
            "treedef": str(treedef),
            "dtypes": dtypes,
            "meta": meta or {},
        }))
        if final.exists():
            import shutil
            shutil.rmtree(final)
        tmp.rename(final)
        (self.dir / "LATEST.tmp").write_text(str(step))
        (self.dir / "LATEST.tmp").rename(self.dir / "LATEST")
        return final

    def save_async(self, step: int, tree, meta: dict | None = None) -> None:
        """Snapshot to host memory synchronously (cheap), write in background."""
        self.wait()
        flat, treedef = jax.tree.flatten(tree)
        host = [np.asarray(x) for x in flat]           # device->host now
        snapshot = jax.tree.unflatten(treedef, host)

        def _write():
            self.save(step, snapshot, meta)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore -----------------------------------------------------------
    def latest_step(self) -> int | None:
        latest = self.dir / "LATEST"
        if not latest.exists():
            return None
        return int(latest.read_text().strip())

    def restore(self, template, step: int | None = None):
        """Restore into the structure of ``template``; returns (tree, meta)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                return None, None
        path = self.dir / f"step_{step}"
        meta = json.loads((path / "meta.json").read_text())
        with np.load(path / "arrays.npz") as data:
            arrays = [data[f"arr_{i}"] for i in range(len(data.files))]
        arrays = [_decode(a, name) for a, name in zip(arrays, meta["dtypes"])]
        flat_t, treedef = jax.tree.flatten(template)
        assert len(flat_t) == len(arrays), (
            f"checkpoint has {len(arrays)} leaves, template {len(flat_t)}")
        return jax.tree.unflatten(treedef, arrays), meta
