import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be the first import side effect: the XLA_FLAGS line above runs before
any other import (jax locks the device count on first initialisation).

Per cell this script:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. assembles step_fn + ShapeDtypeStruct inputs + shardings (steps.py),
  3. ``jax.jit(step).lower(...)`` then ``.compile()`` -- any sharding
     mismatch, OOM-at-compile or unsupported collective fails the cell,
  4. records memory_analysis / cost_analysis / per-collective byte counts
     parsed from the optimized HLO into a JSON artifact for the roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/]
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402

from repro.configs import ARCHS, ALIASES, SHAPES, applicable_shapes, get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import build_cell  # noqa: E402

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64)"
                       r"\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every typed shape literal in an HLO result type."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective payload bytes summed over the optimized module.

    Counts each op's *result* shape once -- a faithful proxy for per-device
    link traffic of one executed instance of the op."""
    out = {c: 0 for c in COLLECTIVES}
    counts = {c: 0 for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # result-type then `= opname(`: e.g. "x = bf16[8,128]{1,0} all-gather(..."
        m = re.match(r"^[%\w.\-]+\s*=\s*(.+?)\s+([a-z\-]+)\(", s)
        if not m:
            continue
        result_ty, opname = m.groups()
        base = opname.rstrip("-start").rstrip("-done")
        for c in COLLECTIVES:
            if base == c or opname == c or opname == c + "-start":
                out[c] += _shape_bytes(result_ty)
                counts[c] += 1
                break
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def _compile_metrics(step, args, shardings, mesh) -> dict:
    with mesh:
        jitted = jax.jit(step, in_shardings=shardings)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # older JAX returns [dict]
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collectives": coll,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        },
        "hlo_instructions": hlo.count("\n"),
    }


def _calib_cfg(cfg, k: int):
    """k-period-group unrolled variant for flop calibration."""
    n = k * len(cfg.period)
    return dataclasses.replace(
        cfg, n_layers=n,
        encoder_layers=n if cfg.is_encdec else 0,
        unroll_layers=True, unroll_q_chunks=True, remat=False)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: dict | None = None) -> dict:
    """Compile the full cell (proof of lowerability + memory analysis), then
    two small unrolled variants to calibrate per-layer-group cost -- XLA's
    cost_analysis counts while-loop (scan) bodies ONCE, so the corrected
    totals are  m1 + (n_groups - 1 + tail/period) * (m2 - m1)."""
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    step, args, shardings = build_cell(arch, shape_name, mesh, cfg=cfg)
    full = _compile_metrics(step, args, shardings, mesh)
    t_full = time.time() - t0

    ms = []
    for k in (1, 2):
        ck = _calib_cfg(cfg, k)
        s2, a2, sh2 = build_cell(arch, shape_name, mesh, cfg=ck)
        ms.append(_compile_metrics(s2, a2, sh2, mesh))
    m1, m2 = ms
    mult = cfg.n_groups - 1 + cfg.n_tail / len(cfg.period)

    def corr(path1, path2=None):
        v1 = m1[path1] if path2 is None else m1[path1][path2]
        v2 = m2[path1] if path2 is None else m2[path1][path2]
        return v1 + mult * (v2 - v1)

    coll_bytes = {
        c: m1["collectives"]["bytes"][c]
        + mult * (m2["collectives"]["bytes"][c] - m1["collectives"]["bytes"][c])
        for c in COLLECTIVES
    }

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": 512 if multi_pod else 256,
        "ok": True,
        "compile_s": round(t_full, 1),
        "calib_s": round(time.time() - t0 - t_full, 1),
        # corrected per-device totals (see docstring)
        "flops": corr("flops"),
        "bytes_accessed": corr("bytes_accessed"),
        "collective_bytes": coll_bytes,
        "collective_bytes_total": sum(coll_bytes.values()),
        # raw artifacts
        "memory": full["memory"],
        "scan_raw": {"flops": full["flops"],
                     "bytes_accessed": full["bytes_accessed"],
                     "collectives": full["collectives"]},
        "calib": {"m1_flops": m1["flops"], "m2_flops": m2["flops"],
                  "mult": mult},
        "hlo_instructions": full["hlo_instructions"],
    }
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="architecture id (dashed aliases accepted)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg field override, e.g. weight_sharding=fsdp_full")
    args = ap.parse_args()
    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        if v.lower() in ("true", "false"):
            v = v.lower() == "true"
        else:
            try:
                v = int(v)
            except ValueError:
                pass
        overrides[k] = v

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    if args.all:
        cells = [(a, s) for a in ARCHS
                 for s in applicable_shapes(get_config(a))]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{ALIASES.get(arch, arch)}__{shape}__{'mp' if mp else 'sp'}"
            if overrides:
                tag += "__" + "_".join(f"{k}-{v}" for k, v in overrides.items())
            path = outdir / f"{tag}.json"
            if path.exists():
                print(f"[skip] {tag} (exists)")
                results.append(json.loads(path.read_text()))
                continue
            print(f"[run ] {tag} ...", flush=True)
            try:
                rec = run_cell(arch, shape, mp, overrides=overrides or None)
                tb = rec["collective_bytes_total"]
                print(f"[ ok ] {tag}: flops={rec['flops']:.3e} "
                      f"coll={tb/1e9:.2f}GB compile={rec['compile_s']:.0f}s",
                      flush=True)
            except Exception as e:  # noqa: BLE001 - record and continue
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16", "ok": False,
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
                print(f"[FAIL] {tag}: {type(e).__name__}: {str(e)[:200]}",
                      flush=True)
            path.write_text(json.dumps(rec, indent=2))
            results.append(rec)

    ok = sum(1 for r in results if r.get("ok"))
    print(f"\n{ok}/{len(results)} cells passed")


if __name__ == "__main__":
    main()
