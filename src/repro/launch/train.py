"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On real TPU pods this drives the full config through the production mesh
(the exact sharding proven by dryrun.py); on CPU (default here) it trains a
scaled-down same-family model so every architecture's training path is
exercisable anywhere.  XLA latency-hiding flags for overlap are set for
TPU runs.
"""

from __future__ import annotations

import argparse
import dataclasses
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--full", action="store_true",
                    help="full published config (TPU pods; needs the "
                         "production mesh)")
    args = ap.parse_args()

    if args.full:
        # overlap compute/comm on real hardware
        os.environ.setdefault(
            "LIBTPU_INIT_ARGS",
            "--xla_tpu_enable_latency_hiding_scheduler=true")

    # imports after env so jax sees the flags
    from repro.configs import get_config
    from repro.models import scale_down
    from repro.training import TrainConfig, train

    cfg = get_config(args.arch)
    if not args.full:
        cfg = dataclasses.replace(
            scale_down(cfg), vocab=2048, vocab_pad_multiple=256)
    tcfg = TrainConfig(
        steps=args.steps, global_batch=args.global_batch,
        seq_len=args.seq_len, microbatches=args.microbatches,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=max(args.steps // 4, 1))
    print(f"[launch] {cfg.name}: {cfg.param_count()/1e6:.1f}M params "
          f"({'full' if args.full else 'scaled'})")
    out = train(cfg, tcfg)
    print(f"[launch] done; final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
