"""Logical->physical sharding rules with divisibility fallback.

Every parameter / cache / input leaf gets a PartitionSpec from name-based
rules; the resolver then *checks divisibility of every sharded dim against
the mesh* and silently drops (replicates) any axis that does not divide.
This is what makes all 10 architectures lower on all meshes: 60-expert MoE
falls back from EP to expert-internal d_ff TP, 40-head attention keeps the
packed projection dim sharded instead of the head dim, the 256206-entry
seamless vocab is padded by the config, etc.

Physical axes:
  tp    = "model"                  (tensor parallel)
  dp    = ("pod", "data")          (batch / data parallel)
  fsdp  = "data"                   (ZeRO-3 weight sharding, fsdp_tp archs;
                                    intra-pod only -- weights are replicated
                                    across pods to keep layer all-gathers
                                    off the DCI)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import cache_shapes, param_shapes
from repro.models.config import ModelConfig

from .mesh import dp_axes

TP = "model"
FSDP = "data"


# ---------------------------------------------------------------------------
# resolver
# ---------------------------------------------------------------------------
def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        size = 1
        for a in axis:
            size *= mesh.shape[a]
        return size
    return mesh.shape[axis]


def resolve(mesh, spec: tuple, shape: tuple) -> NamedSharding:
    """Drop any spec entry whose mesh-axis size does not divide the dim."""
    fixed = []
    for dim, axis in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axis is not None and all(a in mesh.axis_names for a in
                                    (axis if isinstance(axis, tuple) else (axis,))):
            if dim % _axis_size(mesh, axis) == 0:
                fixed.append(axis)
                continue
        fixed.append(None)
    return NamedSharding(mesh, P(*fixed))


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------
_PARAM_RULES: dict[str, tuple] = {
    # name -> intrinsic spec (leading stack dims padded automatically)
    "embed": (TP, None),
    "lm_head": (None, TP),
    # attention / generic projections (d, out) and (in, d)
    "wq": (FSDP, TP), "wk": (FSDP, TP), "wv": (FSDP, TP), "wg": (FSDP, TP),
    "wr": (FSDP, TP),
    "wo": (TP, FSDP),
    "bq": (TP,), "bk": (TP,), "bv": (TP,),
    # dense mlp
    "w_gate": (FSDP, TP), "w_up": (FSDP, TP), "w_down": (TP, FSDP),
    "s_gate": (FSDP, TP), "s_up": (FSDP, TP), "s_down": (TP, FSDP),
    "cm_wk": (FSDP, TP), "cm_wv": (TP, FSDP), "cm_wr": (FSDP, TP),
    # moe (specialised below when EP applies)
    "router": (FSDP, None),
    "e_gate": (None, FSDP, TP), "e_up": (None, FSDP, TP),
    "e_down": (None, TP, FSDP),
    # rglru
    "w_x": (FSDP, TP), "w_y": (FSDP, TP), "w_out": (TP, FSDP),
    "conv_w": (None, TP), "conv_b": (TP,),
    "w_rg": (None, TP), "b_rg": (TP,), "w_ig": (None, TP), "b_ig": (TP,),
    "lambda": (TP,),
    # rwkv loras / misc: replicated
    "maa_w1": (FSDP, None), "maa_w2": (), "mu": (), "w0": (),
    "wd_w1": (), "wd_w2": (), "u": (), "ln_x": (),
}


def param_specs(cfg: ModelConfig, mesh) -> dict:
    """Pytree of NamedSharding matching param_shapes(cfg).

    weight_sharding schemes:
      tp        -- Megatron TP over "model"; replicated over dp.
      fsdp_tp   -- TP over "model" + ZeRO-3 over "data" (large archs).
      fsdp_full -- every weight sharded on its largest dim over
                   ("data","model") jointly; no TP math (weights gathered
                   per layer by GSPMD).  Pairs with batch_sharding="full".
    """
    shapes = param_shapes(cfg)
    use_fsdp = cfg.weight_sharding == "fsdp_tp" and "data" in mesh.axis_names
    fsdp_full = cfg.weight_sharding == "fsdp_full" and "data" in mesh.axis_names
    ep = cfg.n_experts > 0 and cfg.n_experts % mesh.shape[TP] == 0

    def spec_for(path, shape) -> NamedSharding:
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if fsdp_full:
            if len(shape) == 0 or max(shape) < 1024:
                return resolve(mesh, (), shape)
            big = shape.index(max(shape))
            spec = tuple(("data", TP) if i == big else None
                         for i in range(len(shape)))
            return resolve(mesh, spec, shape)
        base = _PARAM_RULES.get(name, ())
        if ep and name in ("e_gate", "e_up", "e_down"):
            # expert parallelism: experts across TP; expert-internal dims use
            # fsdp only
            base = {"e_gate": (TP, FSDP, None), "e_up": (TP, FSDP, None),
                    "e_down": (TP, None, FSDP)}[name]
        if cfg.moe_constraint == "ep_data" and name in ("e_gate", "e_up",
                                                        "e_down"):
            # serving EP: experts resident across the DP axis, d_ff TP --
            # fully sharded weights with zero per-step gathering
            base = {"e_gate": (FSDP, None, TP), "e_up": (FSDP, None, TP),
                    "e_down": (FSDP, TP, None)}[name]
            return resolve(mesh, (None,) * (len(shape) - 3) + base, shape)
        if not use_fsdp:
            base = tuple(None if a == FSDP else a for a in base)
        spec = (None,) * (len(shape) - len(base)) + tuple(base)
        return resolve(mesh, spec, shape)

    return jax.tree_util.tree_map_with_path(
        spec_for, shapes, is_leaf=lambda x: isinstance(x, tuple))


# ---------------------------------------------------------------------------
# cache / activation rules
# ---------------------------------------------------------------------------
def cache_specs(cfg: ModelConfig, mesh, batch: int, cache_len: int,
                enc_len: int = 0, *, long_context: bool = False) -> dict:
    """KV/state cache shardings.

    decode: batch over dp, KV sequence over TP (split-KV attention: GSPMD
    turns the softmax/sum over the sharded seq into partial reductions +
    all-reduce -- flash-decoding across chips).
    long_context (batch=1): sequence over (data, model) = all 256 chips.
    """
    dp = dp_axes(mesh)
    seq_axis = ("data", TP) if long_context else TP
    shapes = cache_shapes(cfg, batch, cache_len, enc_len)

    def spec_for(path, shape) -> NamedSharding:
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        rank = len(shape)
        if name in ("k", "v", "ck", "cv"):
            base = (dp, seq_axis, None, None)          # (B, S, H, dh)
        elif name == "wkv":
            base = ((dp, None, None, TP) if cfg.rwkv_state_tp
                    else (dp, None, None, None))       # (B, H, dh, dh)
        elif name in ("h", "shift", "cm_shift"):
            base = (dp, TP)                            # (B, w|d)
        elif name == "conv":
            base = (dp, None, TP)                      # (B, width-1, w)
        else:
            base = ()
        spec = (None,) * (rank - len(base)) + tuple(base)
        return resolve(mesh, spec, shape)

    return jax.tree_util.tree_map_with_path(
        spec_for, shapes, is_leaf=lambda x: isinstance(x, tuple))


def batch_specs(mesh, batch_tree: dict, batch_sharding: str = "dp") -> dict:
    """Input batch: leading batch dim over dp (positions (3,B,S) handled).
    batch_sharding="full" spreads the batch over every mesh axis (pairs
    with weight_sharding="fsdp_full")."""
    dp = dp_axes(mesh)
    if batch_sharding == "full":
        dp = dp + (TP,)

    def spec_for(path, sds) -> NamedSharding:
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name == "positions" and len(sds.shape) == 3:
            return resolve(mesh, (None, dp, None), sds.shape)
        spec = (dp,) + (None,) * (len(sds.shape) - 1)
        return resolve(mesh, spec, sds.shape)

    return jax.tree_util.tree_map_with_path(spec_for, batch_tree)


def replicated(mesh):
    return NamedSharding(mesh, P())
