"""Serving launcher: ``python -m repro.launch.serve --arch <id> --policy fc``.

Stands up a single serving node with the paper's scheduler over one or more
endpoints of the chosen architecture family (scaled models on CPU; full
configs on TPU pods use the dryrun-proven shardings), fires a Gatling-style
burst, and reports response-time statistics per policy.
"""

from __future__ import annotations

import argparse
import dataclasses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--policy", default="fc",
                    choices=["fifo", "sept", "eect", "rect", "fc"])
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--heavy-fraction", type=float, default=0.3,
                    help="fraction of calls hitting the long-generation "
                         "endpoint")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models import scale_down
    from repro.serving import Endpoint, ServingEngine

    base = scale_down(get_config(args.arch))
    short = Endpoint(f"{args.arch}-chat", base, prompt_len=2, gen_len=4)
    long_cfg = dataclasses.replace(base)
    long_ = Endpoint(f"{args.arch}-batch", long_cfg, prompt_len=4, gen_len=24)

    eng = ServingEngine([short, long_], slots=args.slots, policy=args.policy)
    # estimator warm-up (paper §V-A)
    for _ in range(3):
        eng.submit(short.name)
        eng.submit(long_.name)
    eng.run(max_wall_s=120)
    eng.completed.clear()

    n_heavy = int(args.requests * args.heavy_fraction)
    for i in range(args.requests):
        eng.submit(long_.name if i < n_heavy else short.name)
    eng.run(max_wall_s=300)
    s = eng.summary()
    print(f"[serve] arch={args.arch} policy={args.policy} slots={args.slots}")
    print(f"[serve] n={s['n']} R_avg={s['R_avg']*1e3:.1f}ms "
          f"R_p50={s['R_p50']*1e3:.1f}ms R_p95={s['R_p95']*1e3:.1f}ms "
          f"cold_starts={s['cold_starts']}")


if __name__ == "__main__":
    main()
