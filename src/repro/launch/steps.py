"""Step builders + dry-run input specs for every (arch x shape) cell.

``build_cell(arch, shape, mesh)`` returns (step_fn, args_shape_tree,
in_shardings, out_shardings) ready for
``jax.jit(step_fn, ...).lower(*args).compile()`` -- nothing is allocated
(ShapeDtypeStruct stand-ins throughout, params via jax.eval_shape).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, ShapeSpec, get_config
from repro.models import decode_step, forward, init, param_shapes, prefill
from repro.models import cache_shapes, init_cache
from repro.models.config import ModelConfig
from repro.training import optim

from . import sharding as sh
from .mesh import dp_axes


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, lr: float = 3e-4):
    def loss_fn(params, batch):
        logits = forward(params, cfg, batch)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        labels = batch["labels"]
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        return -jnp.mean(ll)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = optim.update(params, grads, opt_state, lr=lr)
        return params, opt_state, loss

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch, cache):
        logits, cache = prefill(params, cfg, batch, cache)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, tokens, cache, pos):
        logits, cache = decode_step(params, cfg, tokens, cache, pos)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return serve_step


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_struct(cfg: ModelConfig, B: int, S: int, *, labels: bool) -> dict:
    """Training/prefill batch stand-in for one architecture."""
    if cfg.is_encdec:
        S_dec = max(S // cfg.decoder_ratio, 16)
        batch = {
            "tokens": _sds((B, S_dec), jnp.int32),
            "enc_embeds": _sds((B, S, cfg.d_model), jnp.dtype(cfg.dtype)),
        }
        if labels:
            batch["labels"] = _sds((B, S_dec), jnp.int32)
        return batch
    batch = {"tokens": _sds((B, S), jnp.int32)}
    if cfg.mrope:
        batch["positions"] = _sds((3, B, S), jnp.int32)
    if labels:
        batch["labels"] = _sds((B, S), jnp.int32)
    return batch


def params_struct(cfg: ModelConfig):
    return jax.eval_shape(lambda: init(cfg, jax.random.PRNGKey(0)))


def cache_struct(cfg: ModelConfig, B: int, cache_len: int, enc_len: int = 0):
    return jax.eval_shape(
        lambda: init_cache(cfg, B, cache_len, enc_len=enc_len))


def input_specs(arch: str, shape_name: str):
    """Public helper: the dry-run stand-ins for one cell (no shardings)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    return _cell_structs(cfg, shape)


def _cell_structs(cfg: ModelConfig, shape: ShapeSpec):
    B, S = shape.global_batch, shape.seq_len
    if shape.step == "train":
        params = params_struct(cfg)
        batch = batch_struct(cfg, B, S, labels=True)
        opt = optim.state_shapes(params)
        return {"params": params, "opt_state": opt, "batch": batch}
    if shape.step == "prefill":
        params = params_struct(cfg)
        enc_len = S if cfg.is_encdec else 0
        cache_len = S // cfg.decoder_ratio if cfg.is_encdec else S
        batch = batch_struct(cfg, B, S, labels=False)
        cache = cache_struct(cfg, B, cache_len, enc_len)
        return {"params": params, "batch": batch, "cache": cache}
    # decode
    params = params_struct(cfg)
    enc_len = S if cfg.is_encdec else 0
    cache_len = S // cfg.decoder_ratio if cfg.is_encdec else S
    cache = cache_struct(cfg, B, cache_len, enc_len)
    return {
        "params": params,
        "tokens": _sds((B,), jnp.int32),
        "cache": cache,
        "pos": _sds((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# full cell assembly
# ---------------------------------------------------------------------------
def build_cell(arch: str, shape_name: str, mesh, cfg: ModelConfig | None = None):
    """-> (step_fn, args tuple of ShapeDtypeStructs, in_shardings tuple)."""
    if cfg is None:
        cfg = get_config(arch)
    shape = SHAPES[shape_name]
    structs = _cell_structs(cfg, shape)
    pspecs = sh.param_specs(cfg, mesh)
    long_ctx = shape.name == "long_500k"

    if shape.step == "train":
        step = make_train_step(cfg)
        opt_specs = optim.AdamWState(
            step=sh.replicated(mesh),
            m=jax.tree.map(lambda s: s, pspecs),
            v=jax.tree.map(lambda s: s, pspecs),
        )
        args = (structs["params"], structs["opt_state"], structs["batch"])
        shardings = (pspecs, opt_specs,
                     sh.batch_specs(mesh, structs["batch"],
                                    cfg.batch_sharding))
        return step, args, shardings

    B, S = shape.global_batch, shape.seq_len
    enc_len = S if cfg.is_encdec else 0
    cache_len = S // cfg.decoder_ratio if cfg.is_encdec else S
    cspecs = sh.cache_specs(cfg, mesh, B, cache_len, enc_len,
                            long_context=long_ctx)
    if shape.step == "prefill":
        step = make_prefill_step(cfg)
        args = (structs["params"], structs["batch"], structs["cache"])
        shardings = (pspecs,
                     sh.batch_specs(mesh, structs["batch"],
                                    cfg.batch_sharding), cspecs)
        return step, args, shardings

    step = make_serve_step(cfg)
    dp = dp_axes(mesh)
    tok_spec = sh.resolve(mesh, (dp,), (B,))
    args = (structs["params"], structs["tokens"], structs["cache"],
            structs["pos"])
    shardings = (pspecs, tok_spec, cspecs, sh.replicated(mesh))
    return step, args, shardings
