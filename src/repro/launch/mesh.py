"""Production mesh construction (multi-pod dry-run target).

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches JAX device state.  The single-pod mesh
is 16 x 16 = 256 chips (one v5e pod); the multi-pod mesh adds a leading
``pod`` axis (2 pods = 512 chips, pod axis crossing DCI).
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; "
            "run under dryrun.py (XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512)")
    # more devices than needed (e.g. 512 host devices, single-pod mesh):
    # build the mesh on the leading subset
    sub = np.array(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(sub, axes)


def make_smoke_mesh(shape=(2, 2), axes=("data", "model")):
    """Tiny mesh for CPU integration tests (device count forced by caller)."""
    n = int(np.prod(shape))
    sub = np.array(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(sub, axes)


def dp_axes(mesh) -> tuple:
    """Data-parallel axes: ('pod', 'data') on multi-pod, ('data',) otherwise."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
