"""AdamW optimizer (hand-rolled; no optax dependency).

Optimizer moments are fp32 and carry the same sharding as the parameters
(plus extra ZeRO-style sharding applied by the launcher's spec rules).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # scalar int32
    m: dict                  # first moment (fp32, param tree)
    v: dict                  # second moment (fp32, param tree)


def init_state(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def state_shapes(param_tree) -> AdamWState:
    """Shape tree (for eval_shape / dry-run)."""
    zeros = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), param_tree)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), m=zeros,
                      v=zeros)


def update(params, grads, state: AdamWState, *, lr=3e-4, b1=0.9, b2=0.95,
           eps=1e-8, weight_decay=0.1, grad_clip=1.0):
    """One AdamW step with global-norm clipping; returns (params, state)."""
    gnorm = jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        u = (m / c1) / (jnp.sqrt(v / c2) + eps)
        u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)
