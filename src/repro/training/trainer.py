"""Training loop: accumulation, checkpoint/restart, straggler-aware logging.

CPU-runnable for the e2e example (~100M model, few hundred steps) and
mesh-ready: the same ``train_step`` lowers onto the production meshes in
the dry-run.  Fault tolerance = deterministic data (pure fn of step) +
atomic async checkpoints + restore-on-start.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.checkpoint.store import CheckpointStore
from repro.data.pipeline import DataConfig, DataIterator
from repro.models import forward, init
from repro.models.config import ModelConfig

from . import optim


@dataclass
class TrainConfig:
    steps: int = 100
    lr: float = 3e-4
    global_batch: int = 8
    seq_len: int = 128
    microbatches: int = 1          # gradient accumulation
    checkpoint_every: int = 50
    checkpoint_dir: str | None = None
    log_every: int = 10
    seed: int = 0


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Returns jit-able (params, opt_state, batch) -> (params, opt, loss).

    With microbatches > 1, gradients accumulate over a lax.scan of
    microbatch slices (activation memory / global batch decoupling)."""

    def loss_fn(params, batch):
        logits = forward(params, cfg, batch)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, batch["labels"][..., None],
                                 axis=-1)[..., 0]
        return -jnp.mean(ll)

    if tcfg.microbatches == 1:
        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            params, opt_state = optim.update(params, grads, opt_state,
                                             lr=tcfg.lr)
            return params, opt_state, loss
        return step

    def step(params, opt_state, batch):
        mb = tcfg.microbatches
        sliced = jax.tree.map(
            lambda x: x.reshape(mb, x.shape[0] // mb, *x.shape[1:]), batch)

        def acc_fn(carry, microbatch):
            loss_sum, grad_sum = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, microbatch)
            return (loss_sum + loss,
                    jax.tree.map(jnp.add, grad_sum, grads)), None

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grad_sum), _ = jax.lax.scan(acc_fn, (0.0, zero), sliced)
        grads = jax.tree.map(lambda g: g / mb, grad_sum)
        params, opt_state = optim.update(params, grads, opt_state, lr=tcfg.lr)
        return params, opt_state, loss_sum / mb

    return step


def train(cfg: ModelConfig, tcfg: TrainConfig, verbose: bool = True) -> dict:
    """Run the loop; resumes from the latest checkpoint if one exists."""
    rng = jax.random.PRNGKey(tcfg.seed)
    params = init(cfg, rng)
    opt_state = optim.init_state(params)
    data = DataIterator(DataConfig(vocab=cfg.vocab,
                                   global_batch=tcfg.global_batch,
                                   seq_len=tcfg.seq_len, seed=tcfg.seed))
    store = None
    start_step = 0
    if tcfg.checkpoint_dir:
        store = CheckpointStore(tcfg.checkpoint_dir)
        restored, meta = store.restore((params, opt_state, data.state()))
        if restored is not None:
            params, opt_state, dstate = restored
            data.restore(dstate)
            start_step = meta["step"]
            if verbose:
                print(f"[train] resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, tcfg))
    losses = []
    t0 = time.monotonic()
    for step in range(start_step, tcfg.steps):
        batch = next(data)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, loss = step_fn(params, opt_state, batch)
        if step % tcfg.log_every == 0 or step == tcfg.steps - 1:
            loss_v = float(loss)
            losses.append((step, loss_v))
            if verbose:
                dt = time.monotonic() - t0
                print(f"[train] step {step:5d} loss {loss_v:8.4f} "
                      f"({dt:6.1f}s)", flush=True)
        if store and tcfg.checkpoint_every and \
                (step + 1) % tcfg.checkpoint_every == 0:
            store.save_async(step + 1, (params, opt_state, data.state()))
    if store:
        store.wait()
        store.save(tcfg.steps, (params, opt_state, data.state()))
    return {"losses": losses, "params": params,
            "final_loss": losses[-1][1] if losses else None}
