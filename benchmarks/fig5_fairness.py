"""Fig. 5: FC fairness -- stretch of the rare long function vs SEPT.

Paper: FC cuts dna-visualisation mean stretch 5.3 -> 2.1 while graph-bfs
rises 22.2 -> 25.8.  Declared as a SweepSpec and run through the parallel
sweep engine; per-function metrics come straight out of the cells."""

from .common import emit

from repro.core import SweepSpec, run_sweep


def spec(quick: bool = False, backend: str = "reference") -> SweepSpec:
    return SweepSpec(
        policies=("sept", "fc"),
        arrivals=("fairness",),
        cores=(10,),
        intensities=(90,),
        seeds=2 if quick else 5,
        backends=(backend,),
        per_function=("dna-visualisation", "graph-bfs"),
    )


def run(quick: bool = False, backend: str = "reference") -> list[dict]:
    result = run_sweep(spec(quick, backend))
    rows = []
    for pol in ("sept", "fc"):
        agg = result.find(policy=pol)
        rows.append({
            "name": f"fig5/{pol}",
            "us_per_call": agg["S_avg"] * 1e6,
            "derived": (f"dna_stretch={agg['S_avg:dna-visualisation']:.1f};"
                        f"graphbfs_stretch={agg['S_avg:graph-bfs']:.1f}"),
        })
    return rows


def main(quick: bool = False, backend: str = "reference") -> None:
    emit(run(quick, backend))


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--backend", default="reference")
    args = ap.parse_args()
    main(args.quick, args.backend)
