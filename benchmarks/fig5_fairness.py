"""Fig. 5: FC fairness -- stretch of the rare long function vs SEPT.

Paper: FC cuts dna-visualisation mean stretch 5.3 -> 2.1 while graph-bfs
rises 22.2 -> 25.8."""

import numpy as np

from .common import emit

from repro.core import generate_fairness_burst, simulate_single_node, summarize


def run(quick: bool = False) -> list[dict]:
    rows = []
    seeds = 2 if quick else 5
    for pol in ("sept", "fc"):
        dna, bfs, overall = [], [], []
        for seed in range(seeds):
            reqs = generate_fairness_burst(seed=seed)
            simulate_single_node(reqs, cores=10, policy=pol, mode="ours")
            s = summarize(reqs, per_function=True)
            dna.append(s.per_function["dna-visualisation"].stretch_avg)
            bfs.append(s.per_function["graph-bfs"].stretch_avg)
            overall.append(s.stretch_avg)
        rows.append({
            "name": f"fig5/{pol}",
            "us_per_call": float(np.mean(overall)) * 1e6,
            "derived": (f"dna_stretch={np.mean(dna):.1f};"
                        f"graphbfs_stretch={np.mean(bfs):.1f}"),
        })
    return rows


def main(quick: bool = False) -> None:
    emit(run(quick))


if __name__ == "__main__":
    main()
