"""Benchmark driver: one module per paper table/figure + roofline.

Prints the harness CSV contract ``name,us_per_call,derived`` for every row.
``--quick`` shrinks seed counts / grids for smoke runs.
"""

import argparse
import inspect
import sys
import time

from . import (
    engine_bench,
    fig2_cold_starts,
    fig5_fairness,
    fig6_multinode,
    roofline,
    table1_functions,
    table2_completion,
    table3_response_stretch,
    trace_replay,
)
from .common import emit

MODULES = [
    ("table1", table1_functions),
    ("table2", table2_completion),
    ("table3", table3_response_stretch),
    ("fig2", fig2_cold_starts),
    ("fig5", fig5_fairness),
    ("fig6", fig6_multinode),
    ("trace", trace_replay),
    ("engine", engine_bench),
    ("roofline", roofline),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated module prefixes")
    ap.add_argument("--backend", default=None,
                    help="simulation backend for sweep-based modules "
                         "(reference|vectorized|scan|auto|cross-check)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for name, mod in MODULES:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            kwargs = {"quick": args.quick}
            if (args.backend is not None
                    and "backend" in inspect.signature(mod.run).parameters):
                kwargs["backend"] = args.backend
            rows = mod.run(**kwargs)
            emit(rows)
            print(f"# {name}: {len(rows)} rows in {time.time()-t0:.0f}s",
                  file=sys.stderr)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"# {name} FAILED: {type(e).__name__}: {e}",
                  file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
