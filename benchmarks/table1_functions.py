"""Table I: SeBS function service-time profiles (idle system).

Validates the workload model: sampled medians must match the published
client-side medians (within sampling noise)."""

from .common import emit

import numpy as np

from repro.core import PROFILES, SEBS_TABLE_I
from repro.core.workload import KAFKA_OVERHEAD_S


def run(quick: bool = False) -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)
    n = 2000 if not quick else 200
    for fn, (p5, med, p95) in SEBS_TABLE_I.items():
        samples = PROFILES[fn].sample(rng, n) + KAFKA_OVERHEAD_S
        got_med = float(np.median(samples)) * 1000
        rel = abs(got_med - med) / med
        rows.append({
            "name": f"table1/{fn}",
            "us_per_call": got_med * 1000,       # sampled median in us
            "derived": f"paper_median_ms={med:.0f};rel_err={rel:.3f}",
        })
    return rows


def main(quick: bool = False) -> None:
    emit(run(quick))


if __name__ == "__main__":
    main()
