"""Fig. 6: multi-node -- FC on fewer machines vs stock OpenWhisk on 4.

Paper: FC@3 mean response 68 s vs baseline@4 240 s (-71%).  Our baseline
model is conservative in this regime (EXPERIMENTS.md §Repro), so the
reproduced gap is smaller; tail metrics favour FC at equal node count."""

import numpy as np

from .common import emit

from repro.core import (generate_burst, simulate_baseline_cluster,
                        simulate_cluster, summarize)


def run(quick: bool = False) -> list[dict]:
    rows = []
    seeds = 2 if quick else 5
    paper = {"baseline@4": 240.0, "fc@4": None, "fc@3": 68.0, "fc@2": 100.0}
    for label, nodes, kind in [("baseline@4", 4, "base"), ("fc@4", 4, "fc"),
                               ("fc@3", 3, "fc"), ("fc@2", 2, "fc")]:
        R, p75, p95 = [], [], []
        for seed in range(seeds):
            reqs = generate_burst(cores=72, intensity=30, seed=seed)
            if kind == "base":
                res = simulate_baseline_cluster(reqs, nodes=nodes,
                                                cores_per_node=18)
            else:
                res = simulate_cluster(reqs, nodes=nodes, cores_per_node=18,
                                       policy="fc")
            s = summarize(res.requests)
            R.append(s.response_avg)
            p75.append(s.response_pct[75])
            p95.append(s.response_pct[95])
        pv = paper.get(label)
        rows.append({
            "name": f"fig6/{label}",
            "us_per_call": float(np.mean(R)) * 1e6,
            "derived": (f"R_avg={np.mean(R):.1f};paper={pv};"
                        f"p75={np.mean(p75):.1f};p95={np.mean(p95):.1f}"),
        })
    return rows


def main(quick: bool = False) -> None:
    emit(run(quick))


if __name__ == "__main__":
    main()
