"""Fig. 6: multi-node -- FC on fewer machines vs stock OpenWhisk on 4.

Paper: FC@3 mean response 68 s vs baseline@4 240 s (-71%).  Our baseline
model is conservative in this regime (EXPERIMENTS.md §Repro), so the
reproduced gap is smaller; tail metrics favour FC at equal node count.

All four configurations share one 72-core workload (``workload_cores``) and
run as a single ragged SweepSpec through the parallel engine."""

from .common import emit

from repro.core import SweepSpec, run_sweep

PAPER = {"baseline@4": 240.0, "fc@4": None, "fc@3": 68.0, "fc@2": 100.0}


def spec(quick: bool = False) -> SweepSpec:
    return SweepSpec(
        policies=("fc",),
        modes=("ours", "baseline"),
        nodes=(2, 3, 4),
        cores=(18,),
        intensities=(30,),
        workload_cores=72,          # the paper's burst is sized for 4 nodes
        seeds=2 if quick else 5,
        # the stock baseline is only measured at the full 4-node deployment
        cell_filter=lambda c: c.mode == "ours" or c.nodes == 4,
    )


def run(quick: bool = False) -> list[dict]:
    result = run_sweep(spec(quick))
    rows = []
    for label, mode, nodes in [("baseline@4", "baseline", 4),
                               ("fc@4", "ours", 4), ("fc@3", "ours", 3),
                               ("fc@2", "ours", 2)]:
        agg = result.find(mode=mode, nodes=nodes)
        rows.append({
            "name": f"fig6/{label}",
            "us_per_call": agg["R_avg"] * 1e6,
            "derived": (f"R_avg={agg['R_avg']:.1f};paper={PAPER[label]};"
                        f"p75={agg['R_p75']:.1f};p95={agg['R_p95']:.1f}"),
        })
    return rows


def main(quick: bool = False) -> None:
    emit(run(quick))


if __name__ == "__main__":
    main()
