"""Table II: maximum request completion time, ours-FIFO / baseline ratio.

Paper: ratio < 1 at 20 cores (0.55-0.78), > 1 at 5 cores low intensity.
One SweepSpec covers both systems over the (cores, intensity) grid; the
paired cells share bursts (common random numbers), so the ratio is exactly
the paper's protocol."""

from .common import emit

from repro.core import SweepSpec, run_sweep

PAPER = {  # (cores, intensity) -> published ratio range midpoint
    (5, 30): 1.17, (5, 60): 1.015, (5, 120): 0.94,
    (10, 30): 1.19, (10, 60): 0.82, (10, 120): 0.68,
    (20, 30): 0.725, (20, 60): 0.62, (20, 120): 0.565,
}


def spec(quick: bool = False, backend: str = "reference") -> SweepSpec:
    confs = {(5, 30), (10, 60), (20, 60)} if quick else set(PAPER)
    return SweepSpec(
        # "baseline" is the sweep engine's sentinel for the stock system
        policies=("fifo", "baseline"),
        cores=tuple(sorted({c for c, _ in confs})),
        intensities=tuple(sorted({v for _, v in confs})),
        seeds=2 if quick else 3,
        # baseline cells always run on the reference event loop; a fast
        # backend selector accelerates the ours-fifo half of each ratio
        backends=(backend,),
        cell_filter=lambda c: (c.cores, c.intensity) in confs,
    )


def run(quick: bool = False, backend: str = "reference") -> list[dict]:
    sp = spec(quick, backend)
    result = run_sweep(sp)
    rows = []
    confs = sorted({(r["cores"], r["intensity"])
                    for r in result.aggregate()})
    for cores, inten in confs:
        ours = result.find(policy="fifo", cores=cores, intensity=inten)
        base = result.find(policy="baseline", cores=cores, intensity=inten)
        ratio = ours["max_c"] / base["max_c"]
        rows.append({
            "name": f"table2/c{cores}_v{inten}",
            "us_per_call": ours["max_c"] * 1e6,
            "derived": (f"fifo_to_baseline={ratio:.2f};"
                        f"paper={PAPER[(cores, inten)]:.2f}"),
        })
    return rows


def main(quick: bool = False, backend: str = "reference") -> None:
    emit(run(quick, backend))


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--backend", default="reference")
    args = ap.parse_args()
    main(args.quick, args.backend)
