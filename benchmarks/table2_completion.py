"""Table II: maximum request completion time, ours-FIFO / baseline ratio.

Paper: ratio < 1 at 20 cores (0.55-0.78), > 1 at 5 cores low intensity."""

from .common import emit, run_config

PAPER = {  # (cores, intensity) -> published ratio range midpoint
    (5, 30): 1.17, (5, 60): 1.015, (5, 120): 0.94,
    (10, 30): 1.19, (10, 60): 0.82, (10, 120): 0.68,
    (20, 30): 0.725, (20, 60): 0.62, (20, 120): 0.565,
}


def run(quick: bool = False) -> list[dict]:
    rows = []
    confs = [(5, 30), (10, 60), (20, 60)] if quick else list(PAPER)
    for cores, inten in confs:
        seeds = 2 if quick else 3
        ours = run_config(cores, inten, "fifo", "ours", seeds=seeds)
        base = run_config(cores, inten, "fifo", "baseline", seeds=seeds)
        ratio = ours["max_c"] / base["max_c"]
        rows.append({
            "name": f"table2/c{cores}_v{inten}",
            "us_per_call": ours["max_c"] * 1e6,
            "derived": f"fifo_to_baseline={ratio:.2f};paper={PAPER[(cores,inten)]:.2f}",
        })
    return rows


def main(quick: bool = False) -> None:
    emit(run(quick))


if __name__ == "__main__":
    main()
