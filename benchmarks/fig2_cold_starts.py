"""Fig. 2: cold starts vs memory pool size and intensity (10 cores).

Paper: baseline cold starts grow with intensity, nearly independent of
memory; ours drop to ~0 from 32 GB."""

from .common import emit, run_config


def run(quick: bool = False) -> list[dict]:
    rows = []
    mems = [8, 16, 32] if quick else [8, 16, 32, 64, 128]
    intens = [60] if quick else [30, 60, 120]
    for mode in ("baseline", "ours"):
        for inten in intens:
            for mem_gb in mems:
                r = run_config(10, inten, "fifo", mode, seeds=2,
                               memory_mb=mem_gb * 1024)
                rows.append({
                    "name": f"fig2/{mode}_v{inten}_mem{mem_gb}g",
                    "us_per_call": r["R_avg"] * 1e6,
                    "derived": f"cold_starts={r['cold']:.0f}",
                })
    return rows


def main(quick: bool = False) -> None:
    emit(run(quick))


if __name__ == "__main__":
    main()
