"""Beyond-paper: the policies on the REAL JAX serving engine (tiny models).

Mixed cheap/heavy endpoints under a burst; SEPT/FC should cut mean response
vs FIFO exactly as in the simulator -- but with actual XLA execution.

The policy grid is declared as a SweepSpec like every simulator benchmark,
but runs through a custom cell runner with ``workers=1``: XLA runtimes do
not survive a fork, so these cells must execute in-process."""

from functools import partial

from .common import emit

from repro.core import SweepCell, SweepSpec, run_sweep


def spec() -> SweepSpec:
    # quick mode shrinks the per-cell burst (see _engine_cell), not the grid
    return SweepSpec(policies=("fifo", "sept", "fc"), seeds=1)


def _engine_cell(cell: SweepCell, quick: bool = False) -> dict:
    """One policy on the live engine; returns sweep-shaped metrics."""
    from repro.configs import get_config
    from repro.models import scale_down
    from repro.serving import Endpoint, ServingEngine

    n_cheap, n_heavy = (6, 3) if quick else (16, 6)
    cheap_cfg = scale_down(get_config("qwen3_1_7b"))
    heavy_cfg = scale_down(get_config("deepseek_7b"), layers=4,
                           d_model=128, d_ff=256)
    eng = ServingEngine(
        [Endpoint("cheap", cheap_cfg, prompt_len=2, gen_len=2),
         Endpoint("heavy", heavy_cfg, prompt_len=4, gen_len=32)],
        slots=2, policy=cell.policy)
    for _ in range(3):          # seed the estimator
        eng.submit("cheap"); eng.submit("heavy")
    eng.run(max_wall_s=120)
    eng.completed.clear()
    for i in range(max(n_cheap, n_heavy)):
        if i < n_cheap:
            eng.submit("cheap")
        if i < n_heavy:
            eng.submit("heavy")
    eng.run(max_wall_s=240)
    s = eng.summary()
    return {"R_avg": s["R_avg"], "R_p50": s["R_p50"], "R_p95": s["R_p95"],
            "n": float(s["n"])}


def run(quick: bool = False) -> list[dict]:
    result = run_sweep(spec(), workers=1,
                       runner=partial(_engine_cell, quick=quick))
    rows = []
    for cr in result.results:
        m = cr.metrics
        rows.append({
            "name": f"engine/{cr.cell.policy}",
            "us_per_call": m["R_avg"] * 1e6,
            "derived": (f"R_p50={m['R_p50']*1e3:.0f}ms;"
                        f"R_p95={m['R_p95']*1e3:.0f}ms;n={m['n']:.0f}"),
        })
    return rows


def main(quick: bool = False) -> None:
    emit(run(quick))


if __name__ == "__main__":
    main()
