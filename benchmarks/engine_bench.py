"""Beyond-paper: the policies on the REAL JAX serving engine (tiny models).

Mixed cheap/heavy endpoints under a burst; SEPT/FC should cut mean response
vs FIFO exactly as in the simulator -- but with actual XLA execution."""

import time

from .common import emit

from repro.configs import get_config
from repro.models import scale_down
from repro.serving import Endpoint, ServingEngine


def run(quick: bool = False) -> list[dict]:
    rows = []
    n_cheap, n_heavy = (6, 3) if quick else (16, 6)
    for pol in ("fifo", "sept", "fc"):
        cheap_cfg = scale_down(get_config("qwen3_1_7b"))
        heavy_cfg = scale_down(get_config("deepseek_7b"), layers=4,
                               d_model=128, d_ff=256)
        eng = ServingEngine(
            [Endpoint("cheap", cheap_cfg, prompt_len=2, gen_len=2),
             Endpoint("heavy", heavy_cfg, prompt_len=4, gen_len=32)],
            slots=2, policy=pol)
        for _ in range(3):          # seed the estimator
            eng.submit("cheap"); eng.submit("heavy")
        eng.run(max_wall_s=120)
        eng.completed.clear()
        t0 = time.monotonic()
        for i in range(max(n_cheap, n_heavy)):
            if i < n_cheap:
                eng.submit("cheap")
            if i < n_heavy:
                eng.submit("heavy")
        eng.run(max_wall_s=240)
        s = eng.summary()
        rows.append({
            "name": f"engine/{pol}",
            "us_per_call": s["R_avg"] * 1e6,
            "derived": f"R_p50={s['R_p50']*1e3:.0f}ms;R_p95={s['R_p95']*1e3:.0f}ms;n={s['n']}",
        })
    return rows


def main(quick: bool = False) -> None:
    emit(run(quick))


if __name__ == "__main__":
    main()
