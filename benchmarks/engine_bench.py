"""Beyond-paper: the policies on the REAL JAX serving engine (tiny models),
plus the simulation-backend speedup rows.

Mixed cheap/heavy endpoints under a burst; SEPT/FC should cut mean response
vs FIFO exactly as in the simulator -- but with actual XLA execution.

The policy grid is declared as a SweepSpec like every simulator benchmark,
and runs through a custom cell runner.  XLA runtimes do not survive a fork,
so these cells execute in-process by default; ``--workers N`` fans them out
over a **spawn**-based pool instead (``run_sweep(executor="spawn")``), each
worker paying its own XLA warm-up but running concurrently.

``backend_speedup_rows`` times the simulation engines themselves on a
high-intensity sweep grid (workload generation and metric aggregation are
identical across backends and excluded): reference event loop vs the
vectorized fast path (exact), plus the batched jax.lax.scan variant when
JAX is importable.

``cluster_speedup_rows`` is the cluster-scale version: a >=1k-cell
nodes x intensity x policy x seed grid through the bucketed multi-node scan
path (one XLA dispatch per padded bucket shape) against the reference
event-loop Cluster, whose cost is estimated from a stratified cell sample.
The scan wall is measured post-compile (a warm-up pass populates the bucket
cache first); the cold wall and the bucket count are reported alongside.

``matrix_rows`` (``--rows matrix``) sweeps the closed capability-matrix
rows -- hedging x autoscale x failure schedules, duplicate-mode racing,
and the cold (``warm=False``) regime -- entirely on the scan backend,
asserting zero degraded cells and exact backup/steal/failure counts
against a stratified reference sample.

``mega_rows`` (``--rows mega``) is the fused-path headline: a 100k-cell
policy x intensity x fleet grid through the metrics-only interactive
path (shared workloads, async bucket dispatch, plane-packed carries),
cross-checked bit-identically against the write-back path and by rtol
against the reference event loop, with a roofline-style per-bucket
breakdown (build / compile / dispatch / host-sync) and the measured
cells/sec ratio over the legacy per-cell pipeline."""

import json
import time
from dataclasses import replace
from functools import partial

from .common import emit

from repro.core import (
    SweepCell,
    SweepSpec,
    rolling_restart,
    run_cells_scan,
    run_sweep,
    scan_cache_stats,
    simulate_single_node,
)
from repro.core.sweep import make_workload, run_cell


def spec() -> SweepSpec:
    # quick mode shrinks the per-cell burst (see _engine_cell), not the grid
    return SweepSpec(policies=("fifo", "sept", "fc"), seeds=1)


def speedup_spec(quick: bool = False) -> SweepSpec:
    """High-intensity grid for the backend shoot-out: every policy at the
    paper's heaviest published load (10 cores, intensity 120)."""
    return SweepSpec(policies=("fifo", "sept", "eect", "rect", "fc"),
                     intensities=(60,) if quick else (120, 180),
                     cores=(10,), seeds=1 if quick else 2)


def _time_backend(cells, backend: str) -> float:
    """Simulation wall-clock over the grid (workloads pre-generated)."""
    total = 0.0
    for cell in cells:
        reqs = make_workload(cell)
        t0 = time.perf_counter()
        simulate_single_node(reqs, cores=cell.cores, policy=cell.policy,
                             mode="ours", warm=cell.warm, backend=backend)
        total += time.perf_counter() - t0
    return total


def backend_speedup_rows(quick: bool = False,
                         backend: str = "vectorized") -> list[dict]:
    # the speedup row compares concrete fast engines against the event loop;
    # sweep-level selectors (auto/cross-check/reference) from run.py's
    # --backend, and scan without an importable jax, degrade to the
    # vectorized backend instead of erroring out
    if backend not in ("vectorized", "scan"):
        backend = "vectorized"
    if backend == "scan":
        try:
            import jax  # noqa: F401
        except ImportError:
            backend = "vectorized"
    cells = speedup_spec(quick).cells()
    t_ref = _time_backend(cells, "reference")
    t_fast = _time_backend(cells, backend)
    derived = (f"ref_s={t_ref:.2f};{backend}_s={t_fast:.3f};"
               f"speedup={t_ref / t_fast:.1f}x;cells={len(cells)}")
    if backend == "scan":
        # the per-cell timing above pays one jit compile then reuses it;
        # the batched row shows the whole grid as ONE vmapped scan
        from repro.core import run_cells_scan
        t0 = time.perf_counter()
        run_cells_scan(cells)
        derived += f";scan_batch_s={time.perf_counter() - t0:.2f}"
    return [{"name": "engine/simbackend_speedup",
             "us_per_call": t_fast / len(cells) * 1e6,
             "derived": derived}]


def cluster_speedup_spec(quick: bool = False) -> SweepSpec:
    """The cluster-scale grid: nodes x intensity x all five policies x seeds
    through the pull model (the paper's fig6 shape, scaled up).  Full mode is
    1035 cells; quick is a 36-cell smoke grid for CI."""
    if quick:
        return SweepSpec(policies=("fifo", "sept", "fc"),
                         nodes=(2, 4), cores=(8,), intensities=(20, 30),
                         seeds=3, backends=("scan",))
    return SweepSpec(policies=("fifo", "sept", "eect", "rect", "fc"),
                     nodes=(2, 4, 8), cores=(8,), intensities=(30, 50, 70),
                     seeds=23, backends=("scan",))


def cluster_speedup_rows(quick: bool = False) -> list[dict]:
    """Bucketed cluster-scan vs reference event loop on the cluster grid."""
    try:
        import jax  # noqa: F401
    except ImportError:
        return [{"name": "engine/cluster_scan_speedup", "us_per_call": 0.0,
                 "derived": "skipped=no-jax"}]
    cells = cluster_speedup_spec(quick).cells()

    before = scan_cache_stats()            # other rows may have used the
                                           # cache; report deltas, not totals
    t0 = time.perf_counter()
    run_cells_scan(cells)                  # compiles + runs (cold)
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    run_cells_scan(cells)                  # post-compile, cache hits only
    t_scan = time.perf_counter() - t0

    # reference cost from a stratified sample of the same grid (the full
    # event-loop run would take ~half an hour -- that is the point)
    stride = max(1, len(cells) // (8 if quick else 24))
    sample = cells[::stride]
    t0 = time.perf_counter()
    for cell in sample:
        run_cell(replace(cell, backend="reference", cross_check=False))
    t_ref = (time.perf_counter() - t0) / len(sample) * len(cells)
    stats = scan_cache_stats()
    derived = (f"ref_est_s={t_ref:.1f};scan_s={t_scan:.2f};"
               f"scan_cold_s={t_cold:.2f};speedup={t_ref / t_scan:.1f}x;"
               f"cells={len(cells)};ref_sample={len(sample)};"
               f"buckets={stats['misses'] - before['misses']};"
               f"cache_hits={stats['hits'] - before['hits']}")
    return [{"name": "engine/cluster_scan_speedup",
             "us_per_call": t_scan / len(cells) * 1e6,
             "derived": derived}]


def frontier_spec(quick: bool = False) -> SweepSpec:
    """The autoscaler frontier grid: initial-node-count x provision-delay x
    scale-up-threshold with a static-fleet baseline, one shared burst per
    seed (``workload_cores`` pinned to the largest static fleet so every
    node count faces the *same* offered load).  This is the paper's capstone
    scenario -- "with good scheduling, fewer machines give the same tail" --
    swept at cluster scale through the dynamic-capacity scan kernel."""
    nodes = (2, 3) if quick else (2, 3, 4, 5)
    return SweepSpec(
        policies=("fc",),
        nodes=nodes,
        cores=(8,),
        intensities=(30,) if quick else (40,),
        autoscale=(False, True),
        provision_delays=(10.0,) if quick else (10.0, 30.0, 60.0),
        scale_ups=(2.0,),
        max_nodes=max(nodes) + 2,
        seeds=2 if quick else 5,
        workload_cores=8 * max(nodes),
        backends=("scan",),
    )


def _timed_scan_sweep(spec, sample_div: int, keys=("R_avg", "R_p95",
                                                   "max_c"),
                      exact: bool = False, name: str = "scan"):
    """Shared scaffold for the frontier/straggler rows: run the sweep twice
    (cold = compiles, warm = cache hits), estimate the reference wall from a
    stratified cell sample that doubles as the cross-check (``keys`` within
    ``CLUSTER_XCHECK_RTOL``; with ``exact``, the ``CROSS_CHECK_EXACT`` count
    metrics must match bit-identically).  Returns
    ``(result, cells, timings: dict)``."""
    from repro.core.sweep import CLUSTER_XCHECK_RTOL, CROSS_CHECK_EXACT

    cells = spec.cells()
    t0 = time.perf_counter()
    run_sweep(spec, workers=1)
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    result = run_sweep(spec, workers=1)
    t_scan = time.perf_counter() - t0

    stride = max(1, len(cells) // sample_div)
    sample = cells[::stride]
    worst_err = 0.0
    t0 = time.perf_counter()
    for cell in sample:
        ref_m = run_cell(replace(cell, backend="reference",
                                 cross_check=False))
        scan_m = next(cr.metrics for cr in result.results
                      if cr.cell == cell)
        cell_err = max(abs(ref_m[k] - scan_m[k]) / max(abs(ref_m[k]), 1e-9)
                       for k in keys)
        worst_err = max(worst_err, cell_err)
        if cell_err > CLUSTER_XCHECK_RTOL:
            raise AssertionError(
                f"{name} cross-check breach on {cell.label()}: "
                f"{cell_err:.3f}")
        if exact:
            for k in CROSS_CHECK_EXACT:
                if ref_m.get(k) != scan_m.get(k):
                    raise AssertionError(
                        f"{name} count mismatch on {cell.label()}: "
                        f"{k} scan={scan_m.get(k)} ref={ref_m.get(k)}")
    t_ref = (time.perf_counter() - t0) / len(sample) * len(cells)
    return result, cells, {"scan_s": t_scan, "scan_cold_s": t_cold,
                           "ref_est_s": t_ref, "worst_err": worst_err,
                           "n_sample": len(sample)}


def frontier_rows(quick: bool = False,
                  artifacts: str | None = None) -> list[dict]:
    """Sweep the frontier grid on the scan backend, cross-check a sample
    against the reference event loop at ``CLUSTER_XCHECK_RTOL``, report the
    measured scan-vs-reference speedup, and extract the paper's claim: the
    best autoscaled config at N initial nodes vs the static fleet at N+1."""
    try:
        import jax  # noqa: F401
    except ImportError:
        return [{"name": "engine/frontier", "us_per_call": 0.0,
                 "derived": "skipped=no-jax"}]
    result, cells, t = _timed_scan_sweep(
        frontier_spec(quick), sample_div=4 if quick else 8,
        name="frontier")

    # the claim: best autoscaled config at N nodes vs static fleet at N+1
    agg = result.aggregate()
    static = {int(r["nodes"]): r for r in agg if not r["autoscale"]}
    best_auto: dict[int, dict] = {}
    for r in agg:
        if r["autoscale"]:
            n = int(r["nodes"])
            if n not in best_auto or r["R_p95"] < best_auto[n]["R_p95"]:
                best_auto[n] = r
    claim = ""
    for n in sorted(best_auto):
        big = static.get(n + 1)
        if big is None:
            continue
        small = best_auto[n]
        if small["R_p95"] <= big["R_p95"]:
            claim = (f"{n}n+auto(pd{small['provision_delay']:g}) "
                     f"p95={small['R_p95']:.2f} <= {n + 1}n static "
                     f"p95={big['R_p95']:.2f}")
            break
    if not claim:
        claim = "no-frontier-point"

    if artifacts:
        import os
        os.makedirs(artifacts, exist_ok=True)
        csv_path = f"{artifacts}/frontier.csv"
        result.to_csv(csv_path)
        try:
            from .plots import plot_frontier
            plot_frontier(agg, "R_p95", f"{artifacts}/frontier_R_p95.png")
        except Exception as e:  # noqa: BLE001  (matplotlib optional)
            print(f"# frontier plot skipped: {e}")

    derived = (f"{claim};scan_s={t['scan_s']:.2f};"
               f"scan_cold_s={t['scan_cold_s']:.2f};"
               f"ref_est_s={t['ref_est_s']:.1f};"
               f"speedup={t['ref_est_s'] / max(t['scan_s'], 1e-9):.1f}x;"
               f"cells={len(cells)};xcheck_n={t['n_sample']};"
               f"xcheck_worst={t['worst_err']:.2e}")
    return [{"name": "engine/frontier",
             "us_per_call": t["scan_s"] / len(cells) * 1e6,
             "derived": derived}]


# straggler grid intensity tiers: the hedging-recovery claim lives at
# moderate load (healthy peers have slack to absorb steals; all push cells
# run here); the pull severity curves continue into sustained backlog,
# where the reference event loop is O(queue) per pull and the scan kernel
# is not -- that asymmetry is where the grid's speedup comes from
STRAGGLER_V = {"claim": 18, "mid": 45, "heavy": 96}
STRAGGLER_V_QUICK = {"claim": 15, "mid": 15, "heavy": 15}


def straggler_spec(quick: bool = False) -> SweepSpec:
    """The straggler frontier grid: degradation severity x hedged/unhedged x
    pull vs push through the scan kernel.  One node runs ``sev`` x slow for
    most of the burst; the push model uses the OpenWhisk home-invoker
    balancer (blind hash routing -- the regime where a slow node actually
    accumulates a queue; least-loaded already self-corrects), the pull model
    is the late-binding alternative whose global queue needs no hedging.
    Tiered intensities (:data:`STRAGGLER_V`): hedged cells run at the claim
    tier, push-unhedged up to mid, pull severity curves through heavy
    backlog -- the regime the scan backend exists for."""
    severities = (2.0, 8.0) if quick else (2.0, 4.0, 6.0, 8.0)
    degrades = (None,) + tuple(((0, 2.0, 300.0, s),) for s in severities)
    tiers = STRAGGLER_V_QUICK if quick else STRAGGLER_V
    return SweepSpec(
        policies=("fc",),
        nodes=(4,),
        cores=(8,),
        intensities=tuple(sorted(set(tiers.values()))),
        assignments=("pull", "push"),
        lbs=("home",),
        degrades=degrades,
        hedge_multiples=(None, 3.0),
        seeds=2 if quick else 5,
        workload_cores=32,
        backends=("scan",),
        cell_filter=(_straggler_cell_filter_quick if quick
                     else _straggler_cell_filter),
    )


def _straggler_cell_filter(cell: SweepCell) -> bool:
    """Tiered ragged grid: hedging is a structural no-op under pull and
    pointless on a healthy fleet (dropped); push cells (hedged or not) run
    at the claim intensity; pull severity curves run at every tier."""
    if cell.hedge_multiple is not None:
        return (cell.assignment == "push" and cell.degrade is not None
                and cell.intensity == STRAGGLER_V["claim"])
    if cell.assignment == "push":
        return cell.intensity == STRAGGLER_V["claim"]
    return True


def _straggler_cell_filter_quick(cell: SweepCell) -> bool:
    if cell.hedge_multiple is None:
        return True
    return cell.assignment == "push" and cell.degrade is not None


def _severity(row: dict) -> float:
    from .plots import row_severity
    return row_severity(row)


def straggler_rows(quick: bool = False,
                   artifacts: str | None = None) -> list[dict]:
    """Sweep the straggler frontier on the scan backend, cross-check a
    sample against the reference event loop (metrics within
    ``CLUSTER_XCHECK_RTOL``; ``backups``/``steals``/``failures`` must match
    exactly), report the measured scan speedup, and extract the claim:
    hedging recovers most of the p95 a degraded node costs the push model,
    while the pull model rides it out structurally."""
    try:
        import jax  # noqa: F401
    except ImportError:
        return [{"name": "engine/straggler", "us_per_call": 0.0,
                 "derived": "skipped=no-jax"}]
    result, cells, t = _timed_scan_sweep(
        straggler_spec(quick), sample_div=4 if quick else 10,
        exact=True, name="straggler")

    # the claim, at the worst swept severity and the claim intensity tier:
    # hedging recovers most of the p95 the slow node cost the push model
    agg = result.aggregate()
    sev_max = max(_severity(r) for r in agg)
    v_claim = min(r["intensity"] for r in agg)
    def _find(assignment, sev, hedged):
        for r in agg:
            if (r["assignment"] == assignment and _severity(r) == sev
                    and r["intensity"] == v_claim
                    and (r["hedge_multiple"] is not None) == hedged):
                return r
        return None
    healthy = _find("push", 1.0, False)
    degraded = _find("push", sev_max, False)
    hedged = _find("push", sev_max, True)
    pull_deg = _find("pull", sev_max, False)
    claim = "no-straggler-point"
    if healthy and degraded and hedged:
        lost = degraded["R_p95"] - healthy["R_p95"]
        rec = (degraded["R_p95"] - hedged["R_p95"]) / max(lost, 1e-9)
        claim = (f"sev{sev_max:g}: push p95 {healthy['R_p95']:.1f}->"
                 f"{degraded['R_p95']:.1f}, hedged {hedged['R_p95']:.1f} "
                 f"(recovered {rec:.0%}, {hedged['backups']:.0f} backups)")
        if pull_deg is not None:
            claim += f", pull {pull_deg['R_p95']:.1f}"

    if artifacts:
        import os
        os.makedirs(artifacts, exist_ok=True)
        result.to_csv(f"{artifacts}/straggler.csv")
        try:
            from .plots import plot_straggler
            plot_straggler(agg, "R_p95",
                           f"{artifacts}/straggler_R_p95.png")
        except Exception as e:  # noqa: BLE001  (matplotlib optional)
            print(f"# straggler plot skipped: {e}")

    derived = (f"{claim};scan_s={t['scan_s']:.2f};"
               f"scan_cold_s={t['scan_cold_s']:.2f};"
               f"ref_est_s={t['ref_est_s']:.1f};"
               f"speedup={t['ref_est_s'] / max(t['scan_s'], 1e-9):.1f}x;"
               f"cells={len(cells)};xcheck_n={t['n_sample']};"
               f"xcheck_worst={t['worst_err']:.2e}")
    return [{"name": "engine/straggler",
             "us_per_call": t["scan_s"] / len(cells) * 1e6,
             "derived": derived}]


def _dup_matrix_filter(cell: SweepCell) -> bool:
    """Duplicate-mode x failure schedules x push is the matrix's one
    documented value-dependent rejection (racing copies of a single id
    across dying nodes); every other combination in the grid runs."""
    return not (cell.assignment == "push" and cell.fail_spec is not None)


def _cold_matrix_filter(cell: SweepCell) -> bool:
    """Tiered cold grid (same shape as the straggler tiers): the push
    model runs at the claim intensity; the pull severity curve continues
    into sustained backlog, where the reference event loop is O(queue)
    per pull and the scan kernel is not."""
    return cell.assignment == "pull" or cell.intensity == 18


def matrix_specs(quick: bool = False) -> list[tuple[str, SweepSpec]]:
    """The newly-closed capability-matrix rows as three scan sub-grids:
    ``steal`` (hedging x autoscale x failure schedules, including kills
    that lose queued calls), ``dup`` (duplicate-mode racing, static and
    under pull-side failures), and ``cold`` (the ``warm=False`` regime on
    both assignment models, with a heavy-backlog pull tier)."""
    steal = SweepSpec(
        policies=("fc",) if quick else ("fc", "sept"),
        nodes=(3,), cores=(6,),
        intensities=(16,) if quick else (16, 25),
        assignments=("push",),
        degrades=(((0, 1.0, 300.0, 5.0),),),
        hedge_multiples=(2.0,),
        fail_specs=(None, rolling_restart(1, start=8.0)),
        autoscale=(False, True),
        scale_ups=(1.0,), provision_delays=(2.0,), max_nodes=5,
        seeds=1 if quick else 2, backends=("scan",),
    )
    dup = SweepSpec(
        policies=("fc",),
        nodes=(3,), cores=(6,),
        intensities=(16,) if quick else (16, 45),
        assignments=("pull", "push"),
        degrades=(((0, 1.0, 300.0, 5.0),),),
        hedge_multiples=(2.0,), hedge_mode="duplicate",
        fail_specs=(None, rolling_restart(1, start=8.0)),
        seeds=1 if quick else 4, backends=("scan",),
        cell_filter=_dup_matrix_filter,
    )
    cold = SweepSpec(
        policies=("fc",) if quick else ("fc", "sept"),
        nodes=(4,), cores=(8,), workload_cores=32,
        intensities=(18,) if quick else (18, 96, 140),
        assignments=("pull", "push"), warm=False,
        seeds=1 if quick else 5, backends=("scan",),
        cell_filter=None if quick else _cold_matrix_filter,
    )
    return [("steal", steal), ("dup", dup), ("cold", cold)]


def matrix_rows(quick: bool = False,
                artifacts: str | None = None) -> list[dict]:
    """Run the closed capability rows end-to-end on the scan backend:
    every cell must stay on the scan path (zero degraded), the stratified
    reference cross-check must hold with ``backups``/``steals``/
    ``failures`` bit-identical, and the summary row reports the combined
    scan-vs-reference speedup."""
    try:
        import jax  # noqa: F401
    except ImportError:
        return [{"name": "engine/matrix", "us_per_call": 0.0,
                 "derived": "skipped=no-jax"}]
    rows: list[dict] = []
    tot_scan = tot_ref = 0.0
    tot_cells = 0
    for name, mspec in matrix_specs(quick):
        result, cells, t = _timed_scan_sweep(
            mspec, sample_div=4 if quick else 8, exact=True,
            name=f"matrix/{name}")
        degraded = sum(1 for cr in result.results
                       if cr.metrics.get("degraded"))
        if degraded:
            raise AssertionError(
                f"matrix/{name}: {degraded} degraded cell(s) -- a "
                "supports()=True row fell off the scan path")
        tot_scan += t["scan_s"]
        tot_ref += t["ref_est_s"]
        tot_cells += len(cells)
        if artifacts:
            import os
            os.makedirs(artifacts, exist_ok=True)
            result.to_csv(f"{artifacts}/matrix_{name}.csv")
        rows.append({
            "name": f"engine/matrix_{name}",
            "us_per_call": t["scan_s"] / len(cells) * 1e6,
            "derived": (
                f"cells={len(cells)};degraded=0;"
                f"scan_s={t['scan_s']:.2f};"
                f"scan_cold_s={t['scan_cold_s']:.2f};"
                f"ref_est_s={t['ref_est_s']:.1f};"
                f"speedup={t['ref_est_s'] / max(t['scan_s'], 1e-9):.1f}x;"
                f"xcheck_n={t['n_sample']};"
                f"xcheck_worst={t['worst_err']:.2e}"),
        })
    rows.append({
        "name": "engine/matrix",
        "us_per_call": tot_scan / max(tot_cells, 1) * 1e6,
        "derived": (f"cells={tot_cells};degraded=0;"
                    f"scan_s={tot_scan:.2f};ref_est_s={tot_ref:.1f};"
                    f"speedup={tot_ref / max(tot_scan, 1e-9):.1f}x"),
    })
    return rows


def mega_spec(quick: bool = False) -> SweepSpec:
    """The 100k-cell interactive-sweep grid: every policy x intensity x
    fleet at a pinned offered load (``workload_cores=16``, so cells that
    differ only in policy or fleet share one generated burst through the
    metrics-only path's workload cache).  Full mode is 100,000 cells
    (5 policies x 2 fleets x 5 intensities x 2000 seeds); quick is a
    240-cell CI slice of the same shape."""
    if quick:
        return SweepSpec(policies=("fifo", "sept", "fc"),
                         nodes=(2, 4), cores=(8,),
                         intensities=(10, 20), seeds=20,
                         workload_cores=16, backends=("scan",))
    return SweepSpec(policies=("fifo", "sept", "eect", "rect", "fc"),
                     nodes=(2, 4), cores=(8,),
                     intensities=(10, 15, 20, 25, 30), seeds=2000,
                     workload_cores=16, backends=("scan",))


def mega_rows(quick: bool = False,
              artifacts: str | None = None) -> list[dict]:
    """The fused-path headline: run the mega grid through
    ``run_cells_scan(metrics_only=True)`` (strict -- a single cell falling
    off the scan path fails the row), cross-check a stratified sample two
    ways (bit-identical against the write-back scan path, rtol against the
    reference event loop), report cells/sec against the legacy per-cell
    pipeline, and emit a roofline-style per-bucket breakdown of where the
    wall went (build vs compile vs dispatch vs host sync)."""
    try:
        import jax  # noqa: F401
    except ImportError:
        return [{"name": "engine/mega", "us_per_call": 0.0,
                 "derived": "skipped=no-jax"}]
    from repro.core import scan_bucket_timings, scan_timings_clear
    from repro.core.sweep import CLUSTER_XCHECK_RTOL
    from .roofline import analyse_scan_buckets

    cells = mega_spec(quick).cells()

    scan_timings_clear()
    t0 = time.perf_counter()
    rows_mo = run_cells_scan(cells, metrics_only=True)
    t_mega = time.perf_counter() - t0
    degraded = sum(1 for m in rows_mo if m.get("degraded"))
    if degraded:        # strict=True already raises; belt and braces
        raise AssertionError(f"mega: {degraded} degraded cell(s)")
    buckets = analyse_scan_buckets(scan_bucket_timings())
    tune_new = sum(b["tune_s"] for b in buckets)
    compile_new = sum(b["compile_s"] for b in buckets) + tune_new

    # legacy rate: the same cells through the PR-6-era interactive path
    # (per-cell workload generation + full write-back).  XLA compiles and
    # chunk auto-tune probes are one-time-per-process on BOTH paths, so the
    # headline ratio compares the setup-excluded walls (each path's own
    # timing records say how much of its wall was compile/tune)
    stride = max(1, len(cells) // (24 if quick else 64))
    sample_idx = list(range(0, len(cells), stride))
    sample = [cells[i] for i in sample_idx]
    scan_timings_clear()
    t0 = time.perf_counter()
    rows_wb = run_cells_scan(sample, metrics_only=False)
    t_legacy = time.perf_counter() - t0
    compile_old = sum(r["compile_s"] + r.get("tune_s", 0.0)
                      for r in scan_bucket_timings())
    rate_new = len(cells) / max(t_mega - compile_new, 1e-9)
    rate_old = len(sample) / max(t_legacy - compile_old, 1e-9)

    # cross-check 1: metrics-only rows must be BIT-identical to the
    # write-back path's rows on the stratified sample
    for i, wb in zip(sample_idx, rows_wb):
        mo = rows_mo[i]
        for k, v in wb.items():
            if mo.get(k) != v:
                raise AssertionError(
                    f"mega metrics-only mismatch on {cells[i].label()}: "
                    f"{k} {mo.get(k)} != {v}")
    # cross-check 2: a small slice against the reference event loop
    ref_n = 3 if quick else 6
    ref_idx = sample_idx[::max(1, len(sample_idx) // ref_n)]
    worst_err = 0.0
    for i in ref_idx:
        cell = cells[i]
        ref_m = run_cell(replace(cell, backend="reference",
                                 cross_check=False))
        mo = rows_mo[i]
        err = max(abs(ref_m[k] - mo[k]) / max(abs(ref_m[k]), 1e-9)
                  for k in ("R_avg", "R_p95", "max_c"))
        worst_err = max(worst_err, err)
        if err > CLUSTER_XCHECK_RTOL:
            raise AssertionError(
                f"mega reference cross-check breach on {cell.label()}: "
                f"{err:.3f}")

    if artifacts:
        import os
        os.makedirs(artifacts, exist_ok=True)
        with open(f"{artifacts}/mega_timings.json", "w") as fh:
            json.dump({"cells": len(cells), "mega_s": t_mega,
                       "compile_s": compile_new, "tune_s": tune_new,
                       "cells_per_s": rate_new,
                       "legacy_cells_per_s": rate_old,
                       "speedup": rate_new / max(rate_old, 1e-9),
                       "degraded": 0, "buckets": buckets}, fh, indent=1)
    if not quick:
        _write_bench_trajectory("BENCH_mega.json", "engine/mega",
                                cells_or_invocations=len(cells),
                                wall_s=round(t_mega, 3),
                                rate=round(rate_new, 2),
                                speedup=round(rate_new / max(rate_old, 1e-9),
                                              3))

    rows = [{
        "name": "engine/mega",
        "us_per_call": t_mega / len(cells) * 1e6,
        "derived": (
            f"cells={len(cells)};degraded=0;mega_s={t_mega:.2f};"
            f"compile_s={compile_new:.2f};tune_s={tune_new:.2f};"
            f"cells_per_s={rate_new:.0f};"
            f"legacy_cells_per_s={rate_old:.0f};"
            f"speedup={rate_new / max(rate_old, 1e-9):.1f}x;"
            f"buckets={len(buckets)};xcheck_exact_n={len(sample)};"
            f"xcheck_ref_n={len(ref_idx)};"
            f"xcheck_worst={worst_err:.2e}"),
    }]
    for i, b in enumerate(buckets[:8]):
        rows.append({
            "name": f"engine/mega_bucket{i}",
            "us_per_call": b["total_s"] / max(b["cells"], 1) * 1e6,
            "derived": (
                f"dominant={b['dominant']};{b['bucket']};bsz={b['bsz']};"
                f"cells={b['cells']};chunks={b['chunks']};"
                f"build_ms={b['build_s']*1e3:.0f};"
                f"compile_ms={b['compile_s']*1e3:.0f};"
                f"tune_ms={b['tune_s']*1e3:.0f};"
                f"dispatch_ms={b['dispatch_s']*1e3:.0f};"
                f"sync_ms={b['sync_s']*1e3:.0f};"
                f"cells_per_s={b['cells_per_s']:.0f}"),
        })
    return rows


# --------------------------------------------------------------------------
# storm: metastable-overload / retry-storm hysteresis (ISSUE 8)
# --------------------------------------------------------------------------
# Six client behaviours on the same ramp-and-release workload: no client
# retries / naive immediate retries / capped exponential backoff + jitter,
# each with and without admission control.  All share the same per-attempt
# timeout, which is what converts a transient burst into retry fuel.
STORM_SCENARIOS = (
    ("no-retry", None, False),
    ("no-retry+shed", None, True),
    ("naive", "immediate", False),
    ("naive+shed", "immediate", True),
    ("backoff", "backoff", False),
    ("backoff+shed", "backoff", True),
)


def _storm_resilience(retry_mode, shed: bool):
    from repro.core import (AdmissionPolicy, ResilienceSpec, RetryPolicy,
                            TimeoutSpec)
    retry = None
    if retry_mode is not None:
        retry = RetryPolicy(max_attempts=4, mode=retry_mode,
                            base_delay_s=0.5, cap_delay_s=8.0, jitter=0.5)
    return ResilienceSpec(
        timeout=TimeoutSpec(multiple=3.0, floor_s=2.0),
        retry=retry,
        admission=AdmissionPolicy(threshold_s=2.0) if shed else None)


def _windowed_goodput(requests, a: float, b: float) -> float:
    """Completions per second observed by clients in [a, b)."""
    n = sum(1 for r in requests if r.c is not None and a <= r.c < b)
    return n / max(b - a, 1e-9)


def storm_rows(quick: bool = False, artifacts: str | None = None,
               duration_s: float | None = None) -> list[dict]:
    """Retry-storm / metastable-overload benchmark (``--rows storm``).

    A ramp-and-release arrival process (base Poisson rate with a burst
    window at [T/3, T/2)) drives every :data:`STORM_SCENARIOS` cell through
    the batched scan kernel AND the reference event loop: the resilience
    counters (timed_out / shed / retries_issued) must match **exactly**
    per cell, and the post-compile scan wall is reported against the
    reference wall.  The hysteresis claim is computed from windowed
    goodput: naive immediate retries stay depressed after the burst
    releases, capped backoff + shedding recovers most of the pre-burst
    goodput."""
    try:
        import jax  # noqa: F401
    except ImportError:
        return [{"name": "engine/storm", "us_per_call": 0.0,
                 "derived": "skipped=no-jax"}]
    import copy

    from repro.core import (generate_trace_burst, simulate_cluster,
                            simulate_cluster_cells_scan)

    nodes, cores, policy = 2, 4, "sept"
    T = float(duration_s) if duration_s else 60.0
    seeds = range(4 if quick else 10)
    burst_t0, burst_t1 = T / 3.0, T / 2.0
    # intensity chosen so the base rate loads the cluster well below
    # saturation (~40%: pre-burst goodput tracks the offered rate with few
    # timeouts) but the 6x burst overshoots capacity ~2.5x: timeouts fire,
    # and what happens next is pure client policy
    intensity, burst_x = 14, 6.0
    bursts = {s: generate_trace_burst(
        cores=nodes * cores, intensity=intensity, seed=1000 + s,
        kind="ramp", duration_s=T, burst_factor=burst_x,
        burst_start_frac=1 / 3, burst_end_frac=1 / 2) for s in seeds}
    cells = [(name, rmode, shed, s)
             for (name, rmode, shed) in STORM_SCENARIOS for s in seeds]

    def _items():
        # fresh Request copies every run: both engines mutate in place
        return [(copy.deepcopy(bursts[s]), nodes, cores, policy, "push",
                 "least_loaded", None, None, None, True,
                 _storm_resilience(rmode, shed))
                for (name, rmode, shed, s) in cells]

    simulate_cluster_cells_scan(_items())          # compile warm-up
    t0 = time.perf_counter()
    scan_res = simulate_cluster_cells_scan(_items())
    scan_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    ref_res = []
    for (name, rmode, shed, s) in cells:
        ref_res.append(simulate_cluster(
            copy.deepcopy(bursts[s]), nodes=nodes, cores_per_node=cores,
            policy=policy, assignment="push", warm=True,
            resilience=_storm_resilience(rmode, shed)))
    ref_s = time.perf_counter() - t0

    # exact-count cross-check: every cell, every resilience counter
    for (name, rmode, shed, s), sr, rr in zip(cells, scan_res, ref_res):
        for k in ("timed_out", "shed", "retries_issued"):
            if getattr(sr, k) != getattr(rr, k):
                raise AssertionError(
                    f"storm counter mismatch on {name}#seed{s}: {k} "
                    f"scan={getattr(sr, k)} ref={getattr(rr, k)}")

    # hysteresis: windowed goodput before the burst vs after it releases.
    # The post window starts a couple of timeout periods after release so
    # a healthy policy has had time to drain the genuine backlog; a
    # metastable one is still burning slots on retries there.
    pre_w = (5.0, burst_t0)
    post_w = (burst_t1 + 0.10 * T, min(burst_t1 + 0.35 * T, T))
    summary: dict[str, dict] = {}
    for (name, rmode, shed, s), sr in zip(cells, scan_res):
        d = summary.setdefault(name, {"pre": [], "post": [], "timed_out": 0,
                                      "shed": 0, "retries_issued": 0})
        d["pre"].append(_windowed_goodput(sr.requests, *pre_w))
        d["post"].append(_windowed_goodput(sr.requests, *post_w))
        d["timed_out"] += sr.timed_out
        d["shed"] += sr.shed
        d["retries_issued"] += sr.retries_issued
    for d in summary.values():
        d["pre"] = sum(d["pre"]) / len(d["pre"])
        d["post"] = sum(d["post"]) / len(d["post"])
        d["recovery"] = d["post"] / max(d["pre"], 1e-9)

    naive, good = summary["naive"], summary["backoff+shed"]
    hysteresis = good["recovery"] - naive["recovery"]

    if artifacts:
        import csv
        import os
        os.makedirs(artifacts, exist_ok=True)
        with open(f"{artifacts}/storm.csv", "w", newline="") as fh:
            w = csv.writer(fh)
            w.writerow(["scenario", "retry_mode", "shed", "pre_goodput",
                        "post_goodput", "recovery", "timed_out", "shed_n",
                        "retries_issued"])
            for (name, rmode, shed) in STORM_SCENARIOS:
                d = summary[name]
                w.writerow([name, rmode or "none", shed,
                            f"{d['pre']:.4f}", f"{d['post']:.4f}",
                            f"{d['recovery']:.4f}", d["timed_out"],
                            d["shed"], d["retries_issued"]])
        # time-binned goodput series for the hysteresis figure
        bin_s = max(2.0, T / 40.0)
        edges = [i * bin_s for i in range(int(T / bin_s) + 1)]
        series = []
        for (name, rmode, shed) in STORM_SCENARIOS:
            reqs = [r for (cname, _rm, _sh, _s), sr in zip(cells, scan_res)
                    if cname == name for r in sr.requests]
            n_seeds = len(list(seeds))
            for a, b in zip(edges[:-1], edges[1:]):
                series.append({
                    "scenario": name, "t": (a + b) / 2.0,
                    "goodput": _windowed_goodput(reqs, a, b) / n_seeds,
                    "burst_t0": burst_t0, "burst_t1": burst_t1})
        with open(f"{artifacts}/storm_series.csv", "w", newline="") as fh:
            w = csv.DictWriter(fh, fieldnames=["scenario", "t", "goodput",
                                               "burst_t0", "burst_t1"])
            w.writeheader()
            w.writerows(series)
        try:
            from .plots import plot_storm
            plot_storm(series, out=f"{artifacts}/storm_goodput.png")
        except (ImportError, ValueError):
            pass

    rows = [{
        "name": "engine/storm",
        "us_per_call": scan_s / len(cells) * 1e6,
        "derived": (
            f"cells={len(cells)};T={T:g}s;scan_s={scan_s:.2f};"
            f"ref_s={ref_s:.2f};speedup={ref_s / max(scan_s, 1e-9):.1f}x;"
            f"xcheck_exact_n={len(cells)};"
            f"naive_recovery={naive['recovery']:.2f};"
            f"backoff_shed_recovery={good['recovery']:.2f};"
            f"hysteresis={hysteresis:.2f}"),
    }]
    for (name, rmode, shed) in STORM_SCENARIOS:
        d = summary[name]
        rows.append({
            "name": f"engine/storm_{name}",
            "us_per_call": d["post"] * 1e6,
            "derived": (
                f"pre_goodput={d['pre']:.2f}/s;"
                f"post_goodput={d['post']:.2f}/s;"
                f"recovery={d['recovery']:.2f};"
                f"timed_out={d['timed_out']};shed={d['shed']};"
                f"retries={d['retries_issued']}"),
        })
    return rows


def _engine_cell(cell: SweepCell, quick: bool = False) -> dict:
    """One policy on the live engine; returns sweep-shaped metrics."""
    from repro.configs import get_config
    from repro.models import scale_down
    from repro.serving import Endpoint, ServingEngine

    n_cheap, n_heavy = (6, 3) if quick else (16, 6)
    cheap_cfg = scale_down(get_config("qwen3_1_7b"))
    heavy_cfg = scale_down(get_config("deepseek_7b"), layers=4,
                           d_model=128, d_ff=256)
    eng = ServingEngine(
        [Endpoint("cheap", cheap_cfg, prompt_len=2, gen_len=2),
         Endpoint("heavy", heavy_cfg, prompt_len=4, gen_len=32)],
        slots=2, policy=cell.policy)
    for _ in range(3):          # seed the estimator
        eng.submit("cheap"); eng.submit("heavy")
    eng.run(max_wall_s=120)
    eng.completed.clear()
    for i in range(max(n_cheap, n_heavy)):
        if i < n_cheap:
            eng.submit("cheap")
        if i < n_heavy:
            eng.submit("heavy")
    eng.run(max_wall_s=240)
    s = eng.summary()
    return {"R_avg": s["R_avg"], "R_p50": s["R_p50"], "R_p95": s["R_p95"],
            "n": float(s["n"])}


# --------------------------------------------------------------------------
# planet: the million-invocation streaming frontier (ISSUE 9)
# --------------------------------------------------------------------------
PLANET_SEED = 7
PLANET_FNS = 10_000
# the 32-fn slice fits alpha~2.0 on its own head; the full Azure dataset's
# app popularity decays much milder, so the synthetic tail uses ~0.7 --
# steep enough to stay heavy-tailed, mild enough that all 10k functions
# are actually invoked over a day-scale stream (see synth.expand_catalog)
PLANET_TAIL_ALPHA = 0.7
PLANET_RATE_SCALE = 40.0          # ~175 invocations/s offered


def _planet_model():
    from pathlib import Path

    from repro.core.synth import expand_catalog, fit_azure_csv

    trace = (Path(__file__).resolve().parent.parent / "data"
             / "azure_trace_slice.csv")
    return expand_catalog(fit_azure_csv(trace), PLANET_FNS,
                          rate_scale=PLANET_RATE_SCALE,
                          tail_alpha=PLANET_TAIL_ALPHA)


def _planet_fleet():
    """Lambda-style fleet: single-concurrency micro-VMs (one core each, 4 MB
    per warm container so the 10k-function catalog stays resident).  The
    fleet starts at 96 nodes -- just under the stream's mean demand of ~91
    busy cores (rho ~0.95), the overnight-low provisioning a real operator
    would run -- and the queue-pressure autoscaler (one node per 15s tick,
    60s provision delay) ratchets it up to 128 across the diurnal bursts.
    The cap also sizes the kernel's pow2 node axis, so 128 keeps the padded
    node plane half the size 129+ would cost."""
    from repro.core import ClusterDynamics

    dyn = ClusterDynamics(autoscale=True, autoscale_interval_s=15.0,
                          scale_up_queue_per_slot=0.5,
                          provision_delay_s=60.0, max_nodes=128)
    return dict(nodes=96, cores_per_node=1, policy="sept", assignment="pull",
                warm=True, container_mb=4, dynamics=dyn)


def planet_rows(quick: bool = False,
                artifacts: str | None = None) -> list[dict]:
    """The streaming frontier (``--rows planet``): replay a multi-hour,
    10k-function, Azure-calibrated synthetic day (:mod:`repro.core.synth`)
    through the chunked carry-handoff path on an autoscaled 96->128-node
    fleet.  Evidence reported with the headline steady-state rate:

    * **bounded memory** -- the same stream replayed at half length must hit
      the *same* peak request-tensor footprint (peak is O(chunk), not O(n));
    * **stratified cross-check** -- materialized prefixes (remapped onto
      their active functions, which keeps the single-shot path's dense
      ``(f_b, kq)`` queue tables small) run through the single-shot scan:
      counters must match exactly and clocks within the documented
      ``CLUSTER_XCHECK_RTOL``, and the honest chunked-vs-single-shot wall
      ratio on those short streams is part of the row."""
    try:
        import jax  # noqa: F401
    except ImportError:
        return [{"name": "engine/planet", "us_per_call": 0.0,
                 "derived": "skipped=no-jax"}]
    import numpy as np

    from repro.core.fastpath import simulate_cluster_scan
    from repro.core.request import Request
    from repro.core.streamscan import (simulate_cluster_stream,
                                       stream_from_requests)
    from repro.core.sweep import CLUSTER_XCHECK_RTOL

    model = _planet_model()
    # 2^20 invocations ~= a 1.7-hour day-slice at the offered ~175/s.  The
    # chunk budget is the peak-memory knob AND the throughput knob: the
    # streaming path sizes each fresh slice adaptively so carried backlog +
    # fresh events fill a 4096-row compiled shape.  A fixed fresh count is
    # measurably worse here -- the steady ~500-1000-row queue pushed every
    # 3584-fresh chunk over the pow2 boundary to 8192 padded rows and the
    # marginal rate halved (~300/s -> ~150/s)
    n_inv = 50_000 if quick else 1 << 20
    chunk = 4096
    fleet = _planet_fleet()

    def _replay(limit):
        import sys

        def _tick(chunks_done, events_done, wall):
            print(f"planet: chunk {chunks_done} done, {events_done}/{limit} "
                  f"events, {wall:.0f}s ({events_done / max(wall, 1e-9):.0f}"
                  "/s incl. compile)", file=sys.stderr, flush=True)

        stream = model.stream(PLANET_SEED, max_invocations=limit)
        return simulate_cluster_stream(stream, chunk=chunk, progress=_tick,
                                       **fleet)

    sr = _replay(n_inv)                      # the headline run
    half = _replay(n_inv // 2)               # memory evidence: half length
    if sr.peak_rows != half.peak_rows:
        raise AssertionError(
            f"planet peak not flat: peak_rows {sr.peak_rows} at n={n_inv} "
            f"vs {half.peak_rows} at n={n_inv // 2}")
    s = sr.summary()
    sim_hours = float(sr.t[-1] - sr.t[0]) / 3600.0 if sr.n else 0.0

    # stratified cross-check: materialized prefixes vs the single-shot scan
    prefixes = (1_000, 2_500) if quick else (2_000, 5_000, 8_000)
    worst_drift = 0.0
    t_single = t_chunked = 0.0
    for k in prefixes:
        reqs = []
        for ch in model.stream(PLANET_SEED,
                               max_invocations=k).iter_chunks():
            reqs.extend(Request(fn=model.fns[fi], r=float(t),
                                p_true=float(p))
                        for t, fi, p in zip(ch.r, ch.fn, ch.p))
        t0 = time.perf_counter()
        ref = simulate_cluster_scan(
            [Request(fn=q.fn, r=q.r, p_true=q.p_true) for q in reqs],
            **fleet)
        t_single += time.perf_counter() - t0
        stream, order = stream_from_requests(reqs, chunk=1024)
        t0 = time.perf_counter()
        pr = simulate_cluster_stream(stream, chunk=1024, **fleet)
        t_chunked += time.perf_counter() - t0
        for key, want in (("failures", ref.failures),
                          ("cold_starts", ref.cold_starts),
                          ("timed_out", ref.timed_out),
                          ("shed", ref.shed),
                          ("retries_issued", ref.retries_issued),
                          ("steals_won", ref.steals_won),
                          ("backups_issued", ref.backups_issued)):
            if pr.counters[key] != want:
                raise AssertionError(
                    f"planet prefix={k} counter {key}: "
                    f"chunked={pr.counters[key]} single={want}")
        ref_start = np.array([np.nan if r.start is None else r.start
                              for r in ref.requests])[order]
        if not np.array_equal(np.isnan(pr.start), np.isnan(ref_start)):
            raise AssertionError(f"planet prefix={k}: served-set mismatch")
        ok = np.isfinite(ref_start)
        drift = float(np.max(np.abs(pr.start[ok] - ref_start[ok]) /
                             np.maximum(np.abs(ref_start[ok]), 1.0),
                             initial=0.0))
        worst_drift = max(worst_drift, drift)
        if drift > CLUSTER_XCHECK_RTOL:
            raise AssertionError(
                f"planet prefix={k}: clock drift {drift:.3e} beyond "
                f"{CLUSTER_XCHECK_RTOL}")
    # honest short-stream overhead: both walls include their own compiles
    vs_single = t_chunked / max(t_single, 1e-9)

    if artifacts:
        import csv
        import os
        os.makedirs(artifacts, exist_ok=True)
        with open(f"{artifacts}/planet.csv", "w", newline="") as fh:
            w = csv.writer(fh)
            w.writerow(["invocations", "fns", "sim_hours", "wall_s", "rate",
                        "nodes_used", "peak_rows", "peak_rows_half",
                        "peak_bytes", "chunks", "mean_resp", "p99",
                        "xcheck_prefixes", "xcheck_worst_drift",
                        "chunked_vs_single_wall"])
            w.writerow([sr.n, len(model.fns), f"{sim_hours:.3f}",
                        f"{sr.wall_s:.2f}", f"{s['rate']:.1f}",
                        sr.nodes_used, sr.peak_rows, half.peak_rows,
                        sr.peak_bytes, sr.chunks,
                        f"{s.get('mean_resp', 0.0):.4f}",
                        f"{s.get('p99', 0.0):.4f}",
                        "/".join(str(k) for k in prefixes),
                        f"{worst_drift:.3e}", f"{vs_single:.2f}"])
        # time-binned completions/s + provisioned nodes for the figure
        t_end = float(sr.t[-1]) if sr.n else 0.0
        bin_s = max(60.0, t_end / 120.0)
        fin = sr.finish[sr.failed == 0]
        act = (np.array(sr.timeline.activate)
               if sr.timeline is not None else np.zeros(sr.nodes_used))
        series = []
        for i in range(int(t_end / bin_s) + 1):
            a, b = i * bin_s, (i + 1) * bin_s
            series.append({
                "t": (a + b) / 2.0,
                "rate": float(((fin >= a) & (fin < b)).sum()) / bin_s,
                "nodes": int((act <= b).sum()),
            })
        with open(f"{artifacts}/planet_series.csv", "w", newline="") as fh:
            w = csv.DictWriter(fh, fieldnames=["t", "rate", "nodes"])
            w.writeheader()
            w.writerows(series)
        try:
            from .plots import plot_planet
            plot_planet(series, out=f"{artifacts}/planet_rate.png")
        except (ImportError, ValueError):
            pass

    if not quick:
        _write_bench_trajectory("BENCH_planet.json", "engine/planet",
                                cells_or_invocations=sr.n,
                                wall_s=round(sr.wall_s, 3),
                                rate=round(s["rate"], 2),
                                speedup=round(1.0 / max(vs_single, 1e-9), 3))

    return [{
        "name": "engine/planet",
        "us_per_call": sr.wall_s / max(sr.n, 1) * 1e6,
        "derived": (
            f"inv={sr.n};fns={len(model.fns)};sim_hours={sim_hours:.2f};"
            f"wall_s={sr.wall_s:.1f};rate={s['rate']:.0f}/s;"
            f"nodes_used={sr.nodes_used};chunks={sr.chunks};"
            f"peak_rows={sr.peak_rows};peak_rows_half={half.peak_rows};"
            f"peak_flat=yes;mean_resp={s.get('mean_resp', 0.0):.3f};"
            f"p99={s.get('p99', 0.0):.3f};"
            f"xcheck={'/'.join(str(k) for k in prefixes)};"
            f"xcheck_drift={worst_drift:.1e};"
            f"chunked_vs_single_wall={vs_single:.2f}x"),
    }]


def _write_bench_summary(group: str, rows: list[dict]) -> None:
    """Write/refresh ``BENCH_<group>.json`` at the repo root: the group's
    benchmark rows plus the flight-recorder run manifest (git sha, jax
    platform, scan-cache/bucket-timing stats, ``REPRO_*``/``JAX_*``/
    ``XLA_*`` env) so a committed number is reproducible later.  Merges
    into any existing payload -- the mega/planet trajectory keys written
    by :func:`_write_bench_trajectory` survive."""
    from pathlib import Path

    from repro.core import run_manifest

    path = Path(__file__).resolve().parent.parent / f"BENCH_{group}.json"
    payload = {}
    if path.exists():
        try:
            payload = json.loads(path.read_text())
        except (ValueError, OSError):
            payload = {}
    payload["rows"] = rows
    payload["manifest"] = run_manifest()
    path.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n")


def _write_bench_trajectory(fname: str, row: str, **metrics) -> None:
    """Append/refresh a row in a committed ``BENCH_*.json`` trajectory
    artifact at the repo root (schema: row name -> {cells_or_invocations,
    wall_s, rate, speedup})."""
    from pathlib import Path

    path = Path(__file__).resolve().parent.parent / fname
    payload = {}
    if path.exists():
        try:
            payload = json.loads(path.read_text())
        except (ValueError, OSError):
            payload = {}
    payload[row] = metrics
    path.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n")


ROW_GROUPS = ("all", "engine", "backend", "cluster", "frontier",
              "straggler", "matrix", "mega", "storm", "planet")


def run(quick: bool = False, backend: str = "vectorized",
        workers: int | None = None, rows_group: str = "all",
        artifacts: str | None = None) -> list[dict]:
    rows = []

    def _group(name: str, new_rows: list[dict]) -> None:
        rows.extend(new_rows)
        _write_bench_summary(name, new_rows)

    if rows_group in ("all", "engine"):
        # XLA engines cannot fork; workers>1 uses a spawn pool so the
        # cells run concurrently, each worker with its own runtime
        result = run_sweep(spec(), workers=workers or 1,
                           runner=partial(_engine_cell, quick=quick),
                           executor="spawn" if (workers or 1) > 1 else None)
        engine_rows = []
        for cr in result.results:
            m = cr.metrics
            engine_rows.append({
                "name": f"engine/{cr.cell.policy}",
                "us_per_call": m["R_avg"] * 1e6,
                "derived": (f"R_p50={m['R_p50']*1e3:.0f}ms;"
                            f"R_p95={m['R_p95']*1e3:.0f}ms;n={m['n']:.0f};"
                            f"workers={result.workers}"),
            })
        _group("engine", engine_rows)
    if rows_group in ("all", "backend"):
        _group("backend", backend_speedup_rows(quick, backend=backend))
    if rows_group in ("all", "cluster"):
        _group("cluster", cluster_speedup_rows(quick))
    if rows_group in ("all", "frontier"):
        _group("frontier", frontier_rows(quick, artifacts=artifacts))
    if rows_group in ("all", "straggler"):
        _group("straggler", straggler_rows(quick, artifacts=artifacts))
    if rows_group in ("all", "matrix"):
        _group("matrix", matrix_rows(quick, artifacts=artifacts))
    if rows_group in ("all", "mega"):
        _group("mega", mega_rows(quick, artifacts=artifacts))
    if rows_group in ("all", "storm"):
        _group("storm", storm_rows(quick, artifacts=artifacts))
    if rows_group in ("all", "planet"):
        _group("planet", planet_rows(quick, artifacts=artifacts))
    return rows


def main(quick: bool = False, backend: str = "vectorized",
         workers: int | None = None, rows_group: str = "all",
         json_path: str | None = None,
         artifacts: str | None = None) -> None:
    rows = run(quick, backend=backend, workers=workers,
               rows_group=rows_group, artifacts=artifacts)
    emit(rows)
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(rows, fh, indent=1)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--backend", default="vectorized",
                    choices=("vectorized", "scan"),
                    help="fast backend for the speedup row")
    ap.add_argument("--workers", type=int, default=None,
                    help="spawn-based pool size for the engine cells "
                         "(XLA cannot fork; >1 uses executor='spawn')")
    ap.add_argument("--rows", default="all", choices=ROW_GROUPS,
                    help="which benchmark rows to run")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows as a JSON artifact")
    ap.add_argument("--artifacts", default=None, metavar="DIR",
                    help="directory for the frontier CSV/plot artifacts")
    args = ap.parse_args()
    main(args.quick, backend=args.backend, workers=args.workers,
         rows_group=args.rows, json_path=args.json,
         artifacts=args.artifacts)
